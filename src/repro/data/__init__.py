from repro.data.tokens import TokenStream
from repro.data import graphs
