"""Shape-regime graph/batch generators for the GNN and recsys smoke paths.

Everything is seeded numpy on the host; batches come out as dicts matching
each model's ``loss_fn`` contract.  ``triplets_for`` builds the DimeNet
wedge lists (k→j→i) from an edge list — the 2-hop gather pattern that sits
outside plain SpMM (kernel_taxonomy §GNN).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph, from_edges, uniform_graph


def triplets_for(src: np.ndarray, dst: np.ndarray,
                 max_triplets: Optional[int] = None
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Wedge lists: pairs of edge indices (t_kj, t_ji) with
    dst(t_kj) == src(t_ji) and k≠i.  Returns (t_kj, t_ji) int32 arrays."""
    e = src.shape[0]
    t_kj, t_ji = [], []
    # reverse adjacency: edges grouped by dst (edges INTO each j)
    order_d = np.argsort(dst, kind="stable")
    indptr_d = np.zeros(int(max(src.max(initial=0), dst.max(initial=0)) + 2),
                        dtype=np.int64)
    np.add.at(indptr_d, dst[order_d] + 1, 1)
    np.cumsum(indptr_d, out=indptr_d)
    for ji in range(e):
        j, i = src[ji], dst[ji]
        lo, hi = indptr_d[j], indptr_d[j + 1]
        for p in range(lo, hi):
            kj = order_d[p]
            if src[kj] != i:                     # exclude backtracking wedge
                t_kj.append(kj)
                t_ji.append(ji)
    t_kj = np.asarray(t_kj, dtype=np.int32)
    t_ji = np.asarray(t_ji, dtype=np.int32)
    if max_triplets is not None and t_kj.shape[0] > max_triplets:
        t_kj, t_ji = t_kj[:max_triplets], t_ji[:max_triplets]
    if t_kj.shape[0] == 0:                       # degenerate tiny graphs
        t_kj = np.zeros(1, np.int32)
        t_ji = np.zeros(1, np.int32)
    return t_kj, t_ji


def molecule_batch(n_graphs: int = 8, n_atoms: int = 12, n_species: int = 8,
                   seed: int = 0, cutoff: float = 2.5) -> dict:
    """Batched small molecules: random 3D coordinates, radius-graph edges,
    per-graph scalar target.  Returns one flat batch (graph_id segments)."""
    rng = np.random.default_rng(seed)
    species, coords, srcs, dsts, gids = [], [], [], [], []
    off = 0
    for gi in range(n_graphs):
        pos = rng.normal(size=(n_atoms, 3)) * 1.5
        z = rng.integers(0, n_species, size=n_atoms)
        d = np.linalg.norm(pos[:, None] - pos[None, :], axis=-1)
        s, t = np.nonzero((d < cutoff) & (d > 1e-6))
        species.append(z)
        coords.append(pos)
        srcs.append(s + off)
        dsts.append(t + off)
        gids.append(np.full(n_atoms, gi))
        off += n_atoms
    src = np.concatenate(srcs).astype(np.int32)
    dst = np.concatenate(dsts).astype(np.int32)
    t_kj, t_ji = triplets_for(src, dst)
    species = np.concatenate(species).astype(np.int32)
    coords = np.concatenate(coords).astype(np.float32)
    gid = np.concatenate(gids).astype(np.int32)
    target = rng.normal(size=n_graphs).astype(np.float32)
    return {"species": jnp.asarray(species), "coords": jnp.asarray(coords),
            "feats": jnp.asarray(np.eye(16, dtype=np.float32)[species % 16]),
            "src": jnp.asarray(src), "dst": jnp.asarray(dst),
            "t_kj": jnp.asarray(t_kj), "t_ji": jnp.asarray(t_ji),
            "graph_id": jnp.asarray(gid), "n_graphs": n_graphs,
            "target": jnp.asarray(target)}


def mesh_batch(rows: int = 8, cols: int = 8, d_node_in: int = 8,
               d_edge_in: int = 4, d_out: int = 2, seed: int = 0) -> dict:
    """MeshGraphNet-style regular mesh with node/edge features + targets."""
    from repro.graph.structure import grid_graph
    g = grid_graph(rows, cols, seed=seed)
    src, dst, w, _ = g.host_edges()
    rng = np.random.default_rng(seed + 1)
    return {"node_x": jnp.asarray(rng.normal(size=(g.n, d_node_in))
                                  .astype(np.float32)),
            "edge_x": jnp.asarray(rng.normal(size=(src.shape[0], d_edge_in))
                                  .astype(np.float32)),
            "src": jnp.asarray(src.astype(np.int32)),
            "dst": jnp.asarray(dst.astype(np.int32)),
            "target": jnp.asarray(rng.normal(size=(g.n, d_out))
                                  .astype(np.float32))}


def cora_batch(n: int = 128, e: int = 512, d_feat: int = 64,
               n_classes: int = 7, seed: int = 0) -> dict:
    g = uniform_graph(n, e, seed=seed, weighted=False)
    src, dst, _, _ = g.host_edges()
    rng = np.random.default_rng(seed + 1)
    return {"x": jnp.asarray((rng.random((n, d_feat)) < 0.05)
                             .astype(np.float32)),
            "src": jnp.asarray(src.astype(np.int32)),
            "dst": jnp.asarray(dst.astype(np.int32)),
            "y": jnp.asarray(rng.integers(0, n_classes, n).astype(np.int32))}


def egnn_batch(n_graphs: int = 4, n_atoms: int = 10, seed: int = 0) -> dict:
    b = molecule_batch(n_graphs, n_atoms, seed=seed)
    return b


def dst_block_partition(src, dst, n: int, k: int, pad_factor: float = 1.3):
    """Partition edges by destination block (vertex-cut with local
    scatters): shard j owns nodes [j·n_loc, (j+1)·n_loc) and every edge
    whose dst falls there.  Returns dict of [k, e_pad] arrays: global src,
    LOCAL dst, mask; plus n_loc (n padded to a multiple of k)."""
    n_loc = -(-n // k)
    e_pad = max(1, int(np.ceil(src.shape[0] * pad_factor / k)))
    srcs = np.zeros((k, e_pad), np.int32)
    dsts = np.zeros((k, e_pad), np.int32)
    mask = np.zeros((k, e_pad), bool)
    blocks = dst // n_loc
    for j in range(k):
        sel = np.nonzero(blocks == j)[0][:e_pad]
        m = sel.shape[0]
        srcs[j, :m] = src[sel]
        dsts[j, :m] = dst[sel] - j * n_loc
        mask[j, :m] = True
    return {"src": srcs, "dst": dsts, "mask": mask, "n_loc": int(n_loc),
            "e_pad": e_pad}


def dlrm_batch(cfg, batch: int, seed: int = 0) -> dict:
    rng = np.random.default_rng(seed)
    shape = (batch, cfg.n_sparse) if cfg.multi_hot == 1 else \
        (batch, cfg.n_sparse, cfg.multi_hot)
    return {"dense": jnp.asarray(rng.normal(size=(batch, cfg.n_dense))
                                 .astype(np.float32)),
            "sparse": jnp.asarray(rng.integers(0, cfg.vocab, size=shape)
                                  .astype(np.int32)),
            "label": jnp.asarray(rng.integers(0, 2, size=batch)
                                 .astype(np.float32))}
