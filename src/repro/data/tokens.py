"""Deterministic synthetic LM token stream — checkpointable.

A counter-based generator (threefry on (seed, step)) so the pipeline state
is exactly one integer: restoring `step` resumes the stream bit-for-bit on
any mesh shape (elastic restore, DESIGN.md §5).  The stream has enough
structure (Zipf unigram + order-2 Markov mixing) that a ~100M model's loss
visibly falls within a few hundred steps, which is what the end-to-end
example trains on.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    @classmethod
    def from_state(cls, vocab, batch, seq, state):
        return cls(vocab=vocab, batch=batch, seq=seq,
                   seed=int(state["seed"]), step=int(state["step"]))

    def _zipf_tokens(self, key, shape):
        u = jax.random.uniform(key, shape, minval=1e-6, maxval=1.0)
        # inverse-CDF of a truncated Zipf(1.1)
        ranks = jnp.exp(u * jnp.log(float(self.vocab))) - 1.0
        return jnp.clip(ranks.astype(jnp.int32), 0, self.vocab - 1)

    def next_batch(self) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step)
        k1, k2 = jax.random.split(key)
        toks = self._zipf_tokens(k1, (self.batch, self.seq + 1))
        # order-2 structure: every third token repeats (t-2 + t-1) mod V
        mix = (jnp.roll(toks, 2, axis=1) + jnp.roll(toks, 1, axis=1)) % self.vocab
        sel = (jnp.arange(self.seq + 1) % 3 == 2)[None, :]
        toks = jnp.where(sel, mix, toks)
        self.step += 1
        return {"tokens": toks[:, :-1], "targets": toks[:, 1:]}


def host_batch(vocab: int, batch: int, seq: int, seed: int, step: int):
    """Stateless single-batch variant (numpy) for tests/benchmarks."""
    rng = np.random.default_rng((seed << 20) ^ step)
    u = rng.random((batch, seq + 1))
    toks = np.clip((np.exp(u * np.log(vocab)) - 1).astype(np.int32),
                   0, vocab - 1)
    return {"tokens": jnp.asarray(toks[:, :-1]),
            "targets": jnp.asarray(toks[:, 1:])}
