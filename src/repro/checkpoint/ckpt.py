"""Mesh-independent chunked checkpointing with an async writer.

Design for 1000+-node restore (DESIGN.md §5):

  * **Mesh-independent manifest.**  Each leaf is saved as one or more
    row-chunks of the FULL (unsharded) array plus a JSON manifest recording
    tree structure, shapes, dtypes and chunk boundaries.  Restore reads the
    chunks and re-shards onto WHATEVER mesh the restoring job runs — a
    different pod count or axis split restores fine (elastic scaling).
  * **Step-granular, atomic.**  A checkpoint directory is written under a
    tmp name and atomically renamed, so a preemption mid-write never
    corrupts the latest checkpoint; ``latest_step`` only sees completed
    renames.
  * **Async.**  ``CheckpointManager.save_async`` snapshots to host memory
    synchronously (cheap) and writes to disk on a background thread, so the
    train loop is blocked only for the device→host copy.
  * **Pipeline state included.**  The data-stream cursor and FT counters
    ride along in the manifest's ``extra`` dict, so restore resumes the
    token stream bit-for-bit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np

_CHUNK_BYTES = 256 * 1024 * 1024      # 256MB row-chunks


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save_checkpoint(directory: str, step: int, tree: Any,
                    extra: Optional[dict] = None) -> str:
    """Synchronous save. Returns the final checkpoint path."""
    os.makedirs(directory, exist_ok=True)
    final = os.path.join(directory, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": []}
    for i, (key, leaf) in enumerate(leaves):
        arr = np.asarray(jax.device_get(leaf))
        rows = max(1, _CHUNK_BYTES // max(arr.itemsize *
                                          int(np.prod(arr.shape[1:])), 1)) \
            if arr.ndim > 0 else 1
        chunks = []
        if arr.ndim == 0:
            fname = f"leaf{i:04d}_c0.npy"
            np.save(os.path.join(tmp, fname), arr)
            chunks.append({"file": fname, "rows": [0, 1]})
        else:
            for c0 in range(0, arr.shape[0], rows):
                c1 = min(c0 + rows, arr.shape[0])
                fname = f"leaf{i:04d}_c{c0}.npy"
                np.save(os.path.join(tmp, fname), arr[c0:c1])
                chunks.append({"file": fname, "rows": [int(c0), int(c1)]})
        manifest["leaves"].append({
            "key": key, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "chunks": chunks})
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                     # atomic publish
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore_checkpoint(directory: str, tree_like: Any,
                       step: Optional[int] = None,
                       shardings: Optional[Any] = None):
    """Restore into the structure of ``tree_like``; reshards onto
    ``shardings`` (a pytree of jax.sharding.Sharding) if given — the mesh
    may differ from the one that saved.  Returns (tree, step, extra)."""
    step = step if step is not None else latest_step(directory)
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    leaves, treedef = _flatten_with_paths(tree_like)
    flat_shard = None
    if shardings is not None:
        flat_shard = [s for _, s in _flatten_with_paths(shardings)[0]]
    out = []
    for j, (key, like) in enumerate(leaves):
        rec = by_key[key]
        arr = np.empty(rec["shape"], dtype=rec["dtype"])
        for ch in rec["chunks"]:
            data = np.load(os.path.join(path, ch["file"]))
            if arr.ndim == 0:
                arr = data
            else:
                arr[ch["rows"][0]:ch["rows"][1]] = data
        if flat_shard is not None:
            out.append(jax.device_put(arr, flat_shard[j]))
        else:
            out.append(jax.device_put(arr))
    restored = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(tree_like), out)
    return restored, manifest["step"], manifest.get("extra", {})


class CheckpointManager:
    """Async save + retention + restore-latest."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved: Optional[int] = None

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(s for s in (latest_step(self.directory),) if s is not None)
        all_steps = sorted(int(d.split("_")[1])
                           for d in os.listdir(self.directory)
                           if d.startswith("step_") and not d.endswith(".tmp"))
        for s in all_steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory,
                                       f"step_{s:010d}"), ignore_errors=True)

    def save_async(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            save_checkpoint(self.directory, step, host_tree, extra)
            self._gc()
            self.last_saved = step

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def restore_latest(self, tree_like, shardings=None):
        self.wait()
        return restore_checkpoint(self.directory, tree_like,
                                  shardings=shardings)
