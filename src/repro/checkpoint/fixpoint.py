"""Fingerprinted checkpointing for chunked fixpoints (DESIGN.md §12).

``kernels.ops.iterate_pallas`` can run its ``while_loop`` in host-stepped
chunks; after each chunk the FULL loop carry (state tuple, frontier, counters,
sentinel flags) is snapshotted here through the generic
``checkpoint.CheckpointManager`` (atomic tmp+rename directories, retention,
async writer).  Because the carry *is* the loop state, restoring it and
continuing reproduces the exact iteration sequence — a killed-and-resumed run
is bitwise-identical to an uninterrupted one.

A checkpoint is only as good as knowing WHAT it checkpoints: the manifest's
``extra`` dict records a JSON fingerprint of the query (graph shape, plan
structure, component signature, sources, knobs).  ``restore`` refuses a
mismatching fingerprint with ``CheckpointMismatchError`` rather than silently
continuing a different query's fixpoint.
"""
from __future__ import annotations

from typing import Any, Optional

from repro.checkpoint.ckpt import CheckpointManager, latest_step
from repro.core.guard import CheckpointMismatchError


class FixpointCheckpointer:
    """Carry snapshots for one chunked fixpoint run.

    ``save`` is durable-before-return (async write + join): the driver must
    not start the next chunk while the previous snapshot could still be
    lost to a crash, or kill-and-resume would replay iterations — still
    correct (the carry is deterministic) but no longer "resume from the
    last completed chunk".
    """

    def __init__(self, directory: str, fingerprint: dict, keep: int = 2):
        self.directory = str(directory)
        self.fingerprint = fingerprint
        self.manager = CheckpointManager(self.directory, keep=keep)

    def save(self, carry: Any, step: int) -> None:
        self.manager.save_async(int(step), carry,
                                extra={"fingerprint": self.fingerprint})
        self.manager.wait()

    def restore(self, carry_like: Any) -> Optional[Any]:
        """Newest snapshot restored into ``carry_like``'s structure, or None
        when the directory holds no completed checkpoint yet (fresh start).
        Raises ``CheckpointMismatchError`` if the snapshot was written under
        a different fingerprint."""
        if latest_step(self.directory) is None:
            return None
        carry, step, extra = self.manager.restore_latest(carry_like)
        stored = (extra or {}).get("fingerprint")
        if stored != self.fingerprint:
            raise CheckpointMismatchError(
                f"checkpoint under {self.directory} (step {step}) was "
                f"written for a different fixpoint: stored fingerprint "
                f"{stored!r} != expected {self.fingerprint!r}")
        return carry
