"""Error-feedback int8 gradient compression for the cross-pod (DCN) axis.

Multi-pod data parallelism all-reduces gradients over the slow pod axis;
int8 quantization with per-tensor scale cuts that traffic 4× (fp32) / 2×
(bf16).  Quantization error is carried in an error-feedback buffer (Seide et
al.; 1-bit Adam lineage) so the scheme is unbiased over time:

    e += g;  q = quant(e);  e -= dequant(q);  all_reduce(q)

The compressed all-reduce itself is expressed as all_reduce of the int8
payload re-expanded to int32 partial sums (psum of int32 is exact), scaled
back per-shard — semantically an all-reduce, physically 4× fewer DCN bytes.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class CompressState:
    error: object          # pytree matching grads


def init_compress_state(grads_like):
    return CompressState(error=jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_like))


def _quant(x):
    amax = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12)
    scale = amax / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, state: CompressState):
    """→ (int8 payload tree, scales tree, new state). Error feedback folded."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        q, s = _quant(acc)
        new_e = acc - _dequant(q, s)
        return q, s, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    qs = tdef.unflatten([o[0] for o in out])
    ss = tdef.unflatten([o[1] for o in out])
    new_state = CompressState(error=tdef.unflatten([o[2] for o in out]))
    return qs, ss, new_state


def decompress_grads(qs, ss):
    return jax.tree.map(lambda q, s: _dequant(q, s), qs, ss)


def error_feedback_update(grads, state: CompressState, axis_name: str):
    """Compressed cross-pod gradient all-reduce inside shard_map/pjit.

    All shards must quantize against the SAME scale (pmax of local amax) —
    summing payloads quantized at per-shard scales is not meaningful (a
    shard with small |g| would be re-scaled by the global max).  With the
    shared scale, psum of the int32 payloads is exact; per-element error is
    ≤ scale/2 per shard and carried forward by the error feedback."""
    def one(g, e):
        acc = g.astype(jnp.float32) + e
        amax = jax.lax.pmax(jnp.max(jnp.abs(acc)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(acc / scale), -127, 127).astype(jnp.int8)
        new_e = acc - q.astype(jnp.float32) * scale
        total = jax.lax.psum(q.astype(jnp.int32), axis_name)
        n = jax.lax.psum(jnp.ones((), jnp.float32), axis_name)
        return total.astype(jnp.float32) * scale / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(state.error)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    red = tdef.unflatten([o[0] for o in out])
    new_state = CompressState(error=tdef.unflatten([o[1] for o in out]))
    return red, new_state
