"""AdamW with global-norm clipping and cosine schedule.

Written directly (no optax dependency) so the optimizer-state dtype is
controllable per-config: the 671B MoE runs bf16 m/v state (DESIGN.md §5),
everything else fp32.  State shards exactly like the params (the update is
elementwise), so ZeRO-style sharding falls out of pjit with the same
PartitionSpec tree.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"       # "bfloat16" for the 671B config
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    frac = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * cos
    return cfg.lr * warm * frac


def global_norm(tree):
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def adamw_init(cfg: AdamWConfig, params):
    dt = jnp.dtype(cfg.state_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return {"m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(cfg: AdamWConfig, params, grads, state):
    """Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    lr = cosine_lr(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m32 = m.astype(jnp.float32) * b1 + gf * (1 - b1)
        v32 = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
        mhat = m32 / (1 - b1 ** step.astype(jnp.float32))
        vhat = v32 / (1 - b2 ** step.astype(jnp.float32))
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m32.astype(sdt), v32.astype(sdt))

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, \
        {"grad_norm": gnorm, "lr": lr}
