from repro.optim.adamw import (AdamWConfig, adamw_init, adamw_update,
                               cosine_lr, global_norm)
from repro.optim.compress import (compress_grads, decompress_grads,
                                  error_feedback_update, CompressState)
