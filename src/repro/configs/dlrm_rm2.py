"""dlrm-rm2 [recsys] — n_dense=13 n_sparse=26 embed_dim=64
bot_mlp=13-512-256-64 top_mlp=512-512-256-1 interaction=dot
[arXiv:1906.00091; paper].  Table rows follow the DLRM RM2 benchmark
posture (large multi-million-row tables sharded row-wise)."""
from repro.models.dlrm import DLRMConfig

ARCH_ID = "dlrm-rm2"


def full() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID, n_dense=13, n_sparse=26, embed_dim=64,
                      vocab=4_000_000,
                      bot_mlp=(13, 512, 256, 64),
                      top_mlp_hidden=(512, 512, 256, 1))


def smoke() -> DLRMConfig:
    return DLRMConfig(name=ARCH_ID + "-smoke", n_dense=13, n_sparse=4,
                      embed_dim=16, vocab=100,
                      bot_mlp=(13, 32, 16),
                      top_mlp_hidden=(32, 16, 1))
