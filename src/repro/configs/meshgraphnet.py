"""meshgraphnet [gnn] — n_layers=15 d_hidden=128 aggregator=sum
mlp_layers=2  [arXiv:2010.03409; unverified]"""
from repro.models.gnn import MGNConfig

ARCH_ID = "meshgraphnet"


def full() -> MGNConfig:
    return MGNConfig(name=ARCH_ID, n_layers=15, d_hidden=128, mlp_layers=2)


def smoke() -> MGNConfig:
    return MGNConfig(name=ARCH_ID + "-smoke", n_layers=3, d_hidden=16,
                     mlp_layers=2)
