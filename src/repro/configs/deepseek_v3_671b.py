"""deepseek-v3-671b [moe] — 61L d_model=7168 128H d_ff_expert=2048
vocab=129280, MLA (q_lora 1536 / kv_lora 512 / rope 64 / nope 128 / v 128),
MoE 1 shared + 256 routed top-8, first 3 layers dense, MTP
[arXiv:2412.19437; hf]"""
from repro.models.layers import LMConfig, MLACfg, MoECfg

ARCH_ID = "deepseek-v3-671b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
        d_ff=18432,                      # dense-layer FFN dim (first 3 layers)
        vocab=129280,
        mla=MLACfg(q_lora_rank=1536, kv_lora_rank=512, qk_nope_head_dim=128,
                   qk_rope_head_dim=64, v_head_dim=128),
        moe=MoECfg(n_experts=256, top_k=8, d_ff_expert=2048, n_shared=1,
                   capacity_factor=1.25, first_dense_layers=3),
        mtp=True, rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=3, d_model=64, n_heads=4,
        n_kv_heads=4, d_ff=128, vocab=256,
        mla=MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_head_dim=16,
                   qk_rope_head_dim=8, v_head_dim=16),
        moe=MoECfg(n_experts=8, top_k=2, d_ff_expert=32, n_shared=1,
                   first_dense_layers=1),
        mtp=True, dtype="float32", param_dtype="float32", remat="none")
