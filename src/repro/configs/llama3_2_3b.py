"""llama3.2-3b [dense] — 28L d_model=3072 24H (GQA kv=8) d_ff=8192
vocab=128256  [hf:meta-llama/Llama-3.2-3B; unverified]"""
from repro.models.layers import LMConfig

ARCH_ID = "llama3.2-3b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
        d_ff=8192, vocab=128256, d_head=128, rope_theta=500000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, d_head=16,
        dtype="float32", param_dtype="float32", remat="none")
