"""dimenet [gnn] — n_blocks=6 d_hidden=128 n_bilinear=8 n_spherical=7
n_radial=6  [arXiv:2003.03123; unverified]"""
from repro.models.gnn import DimeNetConfig

ARCH_ID = "dimenet"


def full() -> DimeNetConfig:
    return DimeNetConfig(name=ARCH_ID, n_blocks=6, d_hidden=128,
                         n_bilinear=8, n_spherical=7, n_radial=6)


def smoke() -> DimeNetConfig:
    return DimeNetConfig(name=ARCH_ID + "-smoke", n_blocks=2, d_hidden=16,
                         n_bilinear=4, n_spherical=3, n_radial=3)
