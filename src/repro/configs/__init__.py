"""Architecture registry: the 10 assigned architectures + the paper's own
analytics workload, each selectable via ``--arch <id>``.

Each arch module exposes ``full()`` (the exact published config) and
``smoke()`` (a reduced same-family config for CPU tests), plus the family
tag that picks the model code and the shape set.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

from repro.configs import (deepseek_v3_671b, dimenet, dlrm_rm2, egnn,
                           gat_cora, grafs_analytics, llama3_2_3b,
                           llama4_maverick_400b_a17b, meshgraphnet, qwen2_72b,
                           yi_9b)

# ---------------------------------------------------------------------------
# Shape sets (assigned per family; see the assignment block).
# ---------------------------------------------------------------------------

LM_SHAPES = {
    "train_4k":    {"kind": "train",   "seq": 4_096,   "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32_768,  "batch": 32},
    "decode_32k":  {"kind": "decode",  "seq": 32_768,  "batch": 128},
    "long_500k":   {"kind": "decode",  "seq": 524_288, "batch": 1},
}

GNN_SHAPES = {
    "full_graph_sm": {"kind": "full",   "n": 2_708,     "e": 10_556,
                      "d_feat": 1_433},
    "minibatch_lg":  {"kind": "sample", "n": 232_965,   "e": 114_615_892,
                      "d_feat": 602, "batch_nodes": 1_024,
                      "fanout": (15, 10)},
    "ogb_products":  {"kind": "full",   "n": 2_449_029, "e": 61_859_140,
                      "d_feat": 100},
    "molecule":      {"kind": "batch",  "n": 30, "e": 64, "batch": 128,
                      "d_feat": 16},
}

RECSYS_SHAPES = {
    "train_batch":    {"kind": "train",     "batch": 65_536},
    "serve_p99":      {"kind": "serve",     "batch": 512},
    "serve_bulk":     {"kind": "serve",     "batch": 262_144},
    "retrieval_cand": {"kind": "retrieval", "batch": 1,
                       "n_candidates": 1_000_000},
}

SHAPES_BY_FAMILY = {"lm": LM_SHAPES, "gnn": GNN_SHAPES,
                    "recsys": RECSYS_SHAPES, "analytics": {}}


@dataclasses.dataclass(frozen=True)
class ArchEntry:
    arch_id: str
    family: str            # lm | gnn | recsys | analytics
    kind: str               # lm | gat | egnn | mgn | dimenet | dlrm | grafs
    module: object

    @property
    def shapes(self):
        return SHAPES_BY_FAMILY[self.family]

    def full(self):
        return self.module.full()

    def smoke(self):
        return self.module.smoke()


ARCHS = {
    "llama3.2-3b": ArchEntry("llama3.2-3b", "lm", "lm", llama3_2_3b),
    "qwen2-72b": ArchEntry("qwen2-72b", "lm", "lm", qwen2_72b),
    "yi-9b": ArchEntry("yi-9b", "lm", "lm", yi_9b),
    "deepseek-v3-671b": ArchEntry("deepseek-v3-671b", "lm", "lm",
                                  deepseek_v3_671b),
    "llama4-maverick-400b-a17b": ArchEntry(
        "llama4-maverick-400b-a17b", "lm", "lm", llama4_maverick_400b_a17b),
    "dimenet": ArchEntry("dimenet", "gnn", "dimenet", dimenet),
    "meshgraphnet": ArchEntry("meshgraphnet", "gnn", "mgn", meshgraphnet),
    "egnn": ArchEntry("egnn", "gnn", "egnn", egnn),
    "gat-cora": ArchEntry("gat-cora", "gnn", "gat", gat_cora),
    "dlrm-rm2": ArchEntry("dlrm-rm2", "recsys", "dlrm", dlrm_rm2),
    "grafs-analytics": ArchEntry("grafs-analytics", "analytics", "grafs",
                                 grafs_analytics),
}

ASSIGNED = [a for a in ARCHS if a != "grafs-analytics"]


def get(arch_id: str) -> ArchEntry:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


def skip_reason(arch_id: str, shape: str):
    """Cells that are skipped by the assignment rules, with the reason."""
    entry = get(arch_id)
    if entry.family == "lm" and shape == "long_500k":
        cfg = entry.full()
        if cfg.attn_chunk is None:
            return ("pure full-attention arch: 512k-token decode is "
                    "quadratic-prohibitive; skipped per assignment rule "
                    "(DESIGN.md §Arch-applicability)")
    return None
