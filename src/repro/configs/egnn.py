"""egnn [gnn] — n_layers=4 d_hidden=64 equivariance=E(n)
[arXiv:2102.09844; paper]"""
from repro.models.gnn import EGNNConfig

ARCH_ID = "egnn"


def full() -> EGNNConfig:
    return EGNNConfig(name=ARCH_ID, n_layers=4, d_hidden=64)


def smoke() -> EGNNConfig:
    return EGNNConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=16)
