"""qwen2-72b [dense] — 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, QKV bias  [arXiv:2407.10671; hf]"""
from repro.models.layers import LMConfig

ARCH_ID = "qwen2-72b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
        d_ff=29568, vocab=152064, d_head=128, qkv_bias=True,
        rope_theta=1000000.0, dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=160, vocab=256, d_head=16, qkv_bias=True,
        dtype="float32", param_dtype="float32", remat="none")
