"""gat-cora [gnn] — n_layers=2 d_hidden=8 n_heads=8 aggregator=attn
[arXiv:1710.10903; paper]"""
from repro.models.gnn import GATConfig

ARCH_ID = "gat-cora"


def full() -> GATConfig:
    return GATConfig(name=ARCH_ID, n_layers=2, d_hidden=8, n_heads=8,
                     d_in=1433, n_classes=7)


def smoke() -> GATConfig:
    return GATConfig(name=ARCH_ID + "-smoke", n_layers=2, d_hidden=4,
                     n_heads=2, d_in=32, n_classes=7)
