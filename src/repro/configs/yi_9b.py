"""yi-9b [dense] — 48L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000,
llama-arch GQA  [arXiv:2403.04652; hf]"""
from repro.models.layers import LMConfig

ARCH_ID = "yi-9b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=4096, n_heads=32, n_kv_heads=4,
        d_ff=11008, vocab=64000, d_head=128, rope_theta=10000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=1, d_ff=96, vocab=256, d_head=16,
        dtype="float32", param_dtype="float32", remat="none")
