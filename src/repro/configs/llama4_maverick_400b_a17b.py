"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192(expert), vocab=202048, MoE 128 routed top-1 + 1 shared, iRoPE
chunked local attention (3 of 4 layers local @8192, every 4th global)
[hf:meta-llama/Llama-4-Maverick-17B-128E; unverified]"""
from repro.models.layers import LMConfig, MoECfg

ARCH_ID = "llama4-maverick-400b-a17b"


def full() -> LMConfig:
    return LMConfig(
        name=ARCH_ID, n_layers=48, d_model=5120, n_heads=40, n_kv_heads=8,
        d_ff=16384,                      # shared-expert/dense FFN dim
        vocab=202048, d_head=128,
        moe=MoECfg(n_experts=128, top_k=1, d_ff_expert=8192, n_shared=1,
                   capacity_factor=1.25, interleave_step=2),
        attn_chunk=8192, chunk_global_every=4, rope_theta=500000.0,
        dtype="bfloat16", param_dtype="bfloat16")


def smoke() -> LMConfig:
    return LMConfig(
        name=ARCH_ID + "-smoke", n_layers=4, d_model=64, n_heads=4,
        n_kv_heads=2, d_ff=128, vocab=256, d_head=16,
        moe=MoECfg(n_experts=8, top_k=1, d_ff_expert=32, n_shared=1),
        attn_chunk=8, chunk_global_every=4,
        dtype="float32", param_dtype="float32", remat="none")
