"""grafs-analytics — the paper's own workload as an architecture config:
a set of Grafs specifications (Fig. 1) to fuse, synthesize and execute on
a graph, with engine/model selection.  This is the arch that exercises the
paper's contribution end-to-end; the other ten are the assigned pool."""
from __future__ import annotations

import dataclasses
from typing import Sequence


@dataclasses.dataclass(frozen=True)
class GrafsConfig:
    name: str = "grafs-analytics"
    usecases: Sequence[str] = ("SSSP", "CC", "BFS", "WP", "WSP", "NSP",
                               "NWR", "Trust", "RADIUS", "DRR", "DS", "RDS")
    engine: str = "pull"          # pull | push | dense | pallas | distributed
    fused: bool = True
    n: int = 10_000               # synthetic RMAT graph size for benches
    e: int = 80_000


def full() -> GrafsConfig:
    return GrafsConfig()


def smoke() -> GrafsConfig:
    return GrafsConfig(name="grafs-analytics-smoke",
                       usecases=("SSSP", "WSP", "RADIUS"), n=64, e=256)
