"""GNN architectures: GAT, EGNN, MeshGraphNet, DimeNet.

All message passing is built on the same substrate as the GraFS engines —
edge-index gathers + ``jax.ops.segment_*`` scatters (JAX has no sparse
message-passing primitive; this IS the system, kernel_taxonomy §GNN).  The
three kernel regimes appear explicitly:

  SpMM/SDDMM        GAT (edge scores → segment softmax → weighted aggregate)
  plain scatter     EGNN / MeshGraphNet (MLP messages → segment_sum)
  triplet gather    DimeNet (angular basis over (k→j→i) wedge lists)

Every model exposes ``init_params(cfg, key)``, ``param_specs(cfg)``, and a
pure ``forward``/``loss_fn`` for pjit.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.graph import segment


# ---------------------------------------------------------------------------
# Shared MLP helper
# ---------------------------------------------------------------------------

def _init_mlp(key, dims: Sequence[int], dtype=jnp.float32):
    ks = jax.random.split(key, len(dims) - 1)
    return [{"w": (jax.random.normal(k, (a, b), jnp.float32)
                   / math.sqrt(a)).astype(dtype),
             "b": jnp.zeros((b,), dtype)}
            for k, a, b in zip(ks, dims[:-1], dims[1:])]


def _mlp_specs(dims: Sequence[int], shard_hidden: bool = True):
    out = []
    for i in range(len(dims) - 1):
        # alternate row/col sharding over "model" so TP chains without
        # resharding (Megatron-style pairs)
        if not shard_hidden:
            out.append({"w": P(None, None), "b": P(None)})
        elif i % 2 == 0:
            out.append({"w": P(None, "model"), "b": P("model")})
        else:
            out.append({"w": P("model", None), "b": P(None)})
    return out


def _mlp(params, x, act=jax.nn.relu, final_act=False):
    for i, lyr in enumerate(params):
        x = x @ lyr["w"] + lyr["b"]
        if i < len(params) - 1 or final_act:
            x = act(x)
    return x


# ---------------------------------------------------------------------------
# GAT (arXiv:1710.10903) — n_layers=2, d_hidden=8, n_heads=8 on cora.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GATConfig:
    name: str = "gat-cora"
    n_layers: int = 2
    d_hidden: int = 8
    n_heads: int = 8
    d_in: int = 1433
    n_classes: int = 7
    dtype: str = "float32"


def gat_init(cfg: GATConfig, key):
    ks = jax.random.split(key, cfg.n_layers)
    layers = []
    d_in = cfg.d_in
    for li in range(cfg.n_layers):
        last = li == cfg.n_layers - 1
        h = cfg.n_heads if not last else 1
        d_out = cfg.d_hidden if not last else cfg.n_classes
        k1, k2, k3 = jax.random.split(ks[li], 3)
        layers.append({
            "w": (jax.random.normal(k1, (d_in, h, d_out), jnp.float32)
                  / math.sqrt(d_in)),
            "a_src": jax.random.normal(k2, (h, d_out), jnp.float32) * 0.1,
            "a_dst": jax.random.normal(k3, (h, d_out), jnp.float32) * 0.1,
        })
        d_in = h * d_out if not last else d_out
    return {"layers": layers}


def gat_specs(cfg: GATConfig):
    return {"layers": [{"w": P(None, "model", None), "a_src": P("model", None),
                        "a_dst": P("model", None)}
                       for _ in range(cfg.n_layers)]}


def gat_forward(cfg: GATConfig, params, x, src, dst, n: int):
    """x [n, d_in]; edge lists src/dst [e] (messages flow src→dst)."""
    for li, p in enumerate(params["layers"]):
        last = li == len(params["layers"]) - 1
        h = jnp.einsum("nd,dhk->nhk", x, p["w"])          # [n, H, K]
        # SDDMM: per-edge attention logits
        es = jnp.einsum("nhk,hk->nh", h, p["a_src"])[src]
        ed = jnp.einsum("nhk,hk->nh", h, p["a_dst"])[dst]
        logits = jax.nn.leaky_relu(es + ed, 0.2)          # [e, H]
        alpha = jax.vmap(
            lambda s: segment.segment_softmax(s, dst, n), in_axes=1,
            out_axes=1)(logits)                           # [e, H]
        msg = h[src] * alpha[..., None]                   # [e, H, K]
        agg = jax.ops.segment_sum(msg, dst, n)            # [n, H, K]
        x = agg.reshape(n, -1) if not last else agg.mean(axis=1)
        if not last:
            x = jax.nn.elu(x)
    return x                                              # [n, n_classes]


def gat_loss(cfg: GATConfig, params, batch):
    logits = gat_forward(cfg, params, batch["x"], batch["src"],
                         batch["dst"], batch["x"].shape[0])
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, batch["y"][:, None], axis=-1)[:, 0]
    mask = batch.get("mask", jnp.ones_like(nll))
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


# ---------------------------------------------------------------------------
# EGNN (arXiv:2102.09844) — n_layers=4, d_hidden=64, E(n)-equivariant.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EGNNConfig:
    name: str = "egnn"
    n_layers: int = 4
    d_hidden: int = 64
    d_in: int = 16
    d_out: int = 1
    dtype: str = "float32"


def egnn_init(cfg: EGNNConfig, key):
    ks = jax.random.split(key, cfg.n_layers * 3 + 2)
    d = cfg.d_hidden
    layers = []
    for li in range(cfg.n_layers):
        layers.append({
            "phi_e": _init_mlp(ks[3 * li], [2 * d + 1, d, d]),
            "phi_x": _init_mlp(ks[3 * li + 1], [d, d, 1]),
            "phi_h": _init_mlp(ks[3 * li + 2], [2 * d, d, d]),
        })
    return {"embed": _init_mlp(ks[-2], [cfg.d_in, d]),
            "layers": layers,
            "head": _init_mlp(ks[-1], [d, d, cfg.d_out])}


def egnn_specs(cfg: EGNNConfig):
    d = cfg.d_hidden
    return {"embed": _mlp_specs([cfg.d_in, d]),
            "layers": [{"phi_e": _mlp_specs([2 * d + 1, d, d]),
                        "phi_x": _mlp_specs([d, d, 1]),
                        "phi_h": _mlp_specs([2 * d, d, d])}
                       for _ in range(cfg.n_layers)],
            "head": _mlp_specs([d, d, cfg.d_out])}


def egnn_forward(cfg: EGNNConfig, params, feats, coords, src, dst, n: int):
    """feats [n, d_in], coords [n, 3] → (invariant per-node out, coords')."""
    h = _mlp(params["embed"], feats)
    x = coords
    for p in params["layers"]:
        diff = x[src] - x[dst]                            # [e, 3]
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = _mlp(p["phi_e"], jnp.concatenate(
            [h[src], h[dst], d2], axis=-1), final_act=True)
        # coordinate update (equivariant): x_i += mean_j (x_i-x_j)·φ_x(m_ij)
        w = _mlp(p["phi_x"], m)                           # [e, 1]
        upd = jax.ops.segment_sum(-diff * w, dst, n)
        deg = jax.ops.segment_sum(jnp.ones((src.shape[0], 1)), dst, n)
        x = x + upd / jnp.maximum(deg, 1.0)
        # invariant update
        agg = jax.ops.segment_sum(m, dst, n)
        h = h + _mlp(p["phi_h"], jnp.concatenate([h, agg], axis=-1))
    out = _mlp(params["head"], h)
    return out, x


def egnn_loss(cfg: EGNNConfig, params, batch):
    out, x = egnn_forward(cfg, params, batch["feats"], batch["coords"],
                          batch["src"], batch["dst"],
                          batch["feats"].shape[0])
    # per-graph energy regression (segment-sum over graph ids); the graph
    # count is static from the target shape (jit-safe)
    gid = batch["graph_id"]
    ng = batch["target"].shape[0]
    energy = jax.ops.segment_sum(out[:, 0], gid, ng)
    return jnp.mean((energy - batch["target"]) ** 2)


# ---------------------------------------------------------------------------
# MeshGraphNet (arXiv:2010.03409) — 15 layers, d=128, sum agg, 2-layer MLPs.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MGNConfig:
    name: str = "meshgraphnet"
    n_layers: int = 15
    d_hidden: int = 128
    mlp_layers: int = 2
    d_node_in: int = 8
    d_edge_in: int = 4
    d_out: int = 2
    dtype: str = "float32"


def _mgn_mlp_dims(cfg: MGNConfig, d_in: int):
    return [d_in] + [cfg.d_hidden] * cfg.mlp_layers


def mgn_init(cfg: MGNConfig, key):
    d = cfg.d_hidden
    ks = jax.random.split(key, 2 * cfg.n_layers + 3)
    layers = [{"edge_mlp": _init_mlp(ks[2 * i], _mgn_mlp_dims(cfg, 3 * d)),
               "node_mlp": _init_mlp(ks[2 * i + 1], _mgn_mlp_dims(cfg, 2 * d))}
              for i in range(cfg.n_layers)]
    return {"node_enc": _init_mlp(ks[-3], _mgn_mlp_dims(cfg, cfg.d_node_in)),
            "edge_enc": _init_mlp(ks[-2], _mgn_mlp_dims(cfg, cfg.d_edge_in)),
            "layers": layers,
            "decoder": _init_mlp(ks[-1], [d, d, cfg.d_out])}


def mgn_specs(cfg: MGNConfig):
    d = cfg.d_hidden
    lyr = {"edge_mlp": _mlp_specs(_mgn_mlp_dims(cfg, 3 * d)),
           "node_mlp": _mlp_specs(_mgn_mlp_dims(cfg, 2 * d))}
    return {"node_enc": _mlp_specs(_mgn_mlp_dims(cfg, cfg.d_node_in)),
            "edge_enc": _mlp_specs(_mgn_mlp_dims(cfg, cfg.d_edge_in)),
            "layers": [lyr for _ in range(cfg.n_layers)],
            "decoder": _mlp_specs([d, d, cfg.d_out])}


def mgn_forward(cfg: MGNConfig, params, node_x, edge_x, src, dst, n: int):
    h = _mlp(params["node_enc"], node_x, final_act=True)
    e = _mlp(params["edge_enc"], edge_x, final_act=True)
    for p in params["layers"]:
        e = e + _mlp(p["edge_mlp"],
                     jnp.concatenate([e, h[src], h[dst]], axis=-1))
        agg = jax.ops.segment_sum(e, dst, n)              # sum aggregator
        h = h + _mlp(p["node_mlp"], jnp.concatenate([h, agg], axis=-1))
    return _mlp(params["decoder"], h)


def mgn_loss(cfg: MGNConfig, params, batch):
    out = mgn_forward(cfg, params, batch["node_x"], batch["edge_x"],
                      batch["src"], batch["dst"], batch["node_x"].shape[0])
    return jnp.mean((out - batch["target"]) ** 2)


# ---------------------------------------------------------------------------
# Distributed (shard_map) MeshGraphNet: dst-block vertex-cut.
#
# Under plain pjit, XLA cannot prove the edge→node scatter is local, so the
# 61M-edge full-graph cells replicate the edge-message tensor (measured:
# ~40s collective term, EXPERIMENTS.md §Perf B).  Manual vertex-cut:
# nodes row-sharded; edges partitioned by dst block so every scatter is
# LOCAL; the only collectives are one all-gather of the [n, d] node states
# per layer (for h[src]) and the gradient psum.
# ---------------------------------------------------------------------------

def mgn_forward_dist(cfg: MGNConfig, params, node_x, edge_x, src_g, dst_l,
                     emask, axes):
    """Per-shard forward.  node_x [n_loc, ·]; edges local with GLOBAL src
    ids, LOCAL dst ids, and a validity mask (dst-block partition pads)."""
    n_loc = node_x.shape[0]
    h = _mlp(params["node_enc"], node_x, final_act=True)
    e = _mlp(params["edge_enc"], edge_x, final_act=True)
    em = emask[:, None].astype(h.dtype)
    for p in params["layers"]:
        h_full = jax.lax.all_gather(h, axes, tiled=True) if axes else h
        e = e + _mlp(p["edge_mlp"],
                     jnp.concatenate([e, h_full[src_g], h[dst_l]], axis=-1))
        agg = jax.ops.segment_sum(e * em, dst_l, n_loc)        # local!
        h = h + _mlp(p["node_mlp"], jnp.concatenate([h, agg], axis=-1))
    return _mlp(params["decoder"], h)


def egnn_forward_dist(cfg: EGNNConfig, params, feats, coords, src_g, dst_l,
                      emask, axes):
    """Vertex-cut EGNN: same recipe as mgn_forward_dist — node rows
    sharded, dst-local edges, one all-gather of (h, x) per layer (the
    coordinate vector rides along: [n, d+3])."""
    n_loc = feats.shape[0]
    h = _mlp(params["embed"], feats)
    x = coords
    em = emask[:, None].astype(h.dtype)
    for p in params["layers"]:
        hx = jnp.concatenate([h, x], axis=-1)
        hx_full = jax.lax.all_gather(hx, axes, tiled=True) if axes else hx
        h_full, x_full = hx_full[:, :-3], hx_full[:, -3:]
        diff = x_full[src_g] - x[dst_l]
        d2 = jnp.sum(diff * diff, axis=-1, keepdims=True)
        m = _mlp(p["phi_e"], jnp.concatenate(
            [h_full[src_g], h[dst_l], d2], axis=-1), final_act=True)
        m = m * em
        w = _mlp(p["phi_x"], m)
        upd = jax.ops.segment_sum(-diff * w * em, dst_l, n_loc)
        deg = jax.ops.segment_sum(em, dst_l, n_loc)
        x = x + upd / jnp.maximum(deg, 1.0)
        agg = jax.ops.segment_sum(m, dst_l, n_loc)
        h = h + _mlp(p["phi_h"], jnp.concatenate([h, agg], axis=-1))
    return _mlp(params["head"], h), x


def egnn_loss_dist(cfg: EGNNConfig, params, batch, axes):
    """Per-node invariant regression (the full-graph dist cells have one
    giant graph; the per-graph energy sum of the molecule regime doesn't
    apply — documented in workloads)."""
    out, _ = egnn_forward_dist(cfg, params, batch["feats"], batch["coords"],
                               batch["src"], batch["dst"], batch["emask"],
                               axes)
    nmask = batch["nmask"][:, None].astype(out.dtype)
    sse = jnp.sum(((out - batch["target"]) ** 2) * nmask)
    cnt = jnp.sum(nmask) * out.shape[-1]
    if axes:
        sse = jax.lax.psum(sse, axes)
        cnt = jax.lax.psum(cnt, axes)
    return sse / jnp.maximum(cnt, 1.0)


def mgn_loss_dist(cfg: MGNConfig, params, batch, axes):
    """Per-shard loss; psum-normalized so every shard returns the global
    mean (replicated)."""
    out = mgn_forward_dist(cfg, params, batch["node_x"], batch["edge_x"],
                           batch["src"], batch["dst"], batch["emask"],
                           axes)
    nmask = batch["nmask"][:, None].astype(out.dtype)
    sse = jnp.sum(((out - batch["target"]) ** 2) * nmask)
    cnt = jnp.sum(nmask) * out.shape[-1]
    if axes:
        sse = jax.lax.psum(sse, axes)
        cnt = jax.lax.psum(cnt, axes)
    return sse / jnp.maximum(cnt, 1.0)


# ---------------------------------------------------------------------------
# DimeNet (arXiv:2003.03123) — 6 blocks, d=128, bilinear 8, sph 7, rad 6.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DimeNetConfig:
    name: str = "dimenet"
    n_blocks: int = 6
    d_hidden: int = 128
    n_bilinear: int = 8
    n_spherical: int = 7
    n_radial: int = 6
    cutoff: float = 5.0
    n_species: int = 16
    d_out: int = 1
    dtype: str = "float32"


def _rbf(d, cfg: DimeNetConfig):
    """DimeNet radial Bessel basis: sin(nπ d/c) / d, n = 1..n_radial."""
    n = jnp.arange(1, cfg.n_radial + 1, dtype=jnp.float32)
    d = jnp.maximum(d[:, None], 1e-6)
    return jnp.sin(n * jnp.pi * d / cfg.cutoff) / d * math.sqrt(2.0 / cfg.cutoff)


def _sbf(d, angle, cfg: DimeNetConfig):
    """Angular × radial basis on triplets.

    TPU adaptation (DESIGN.md): the spherical Bessel roots table of the
    original is replaced by a cos(ℓα)⊗Bessel-sin product basis of the same
    rank (n_spherical × n_radial) — same tensor shape and sparsity pattern,
    table-free so it stays constant-foldable in XLA.
    """
    ell = jnp.arange(cfg.n_spherical, dtype=jnp.float32)
    ang = jnp.cos(angle[:, None] * (ell + 1.0))           # [t, S]
    rad = _rbf(d, cfg)                                    # [t, R]
    return (ang[:, :, None] * rad[:, None, :]).reshape(
        d.shape[0], cfg.n_spherical * cfg.n_radial)


def dimenet_init(cfg: DimeNetConfig, key):
    d = cfg.d_hidden
    nsr = cfg.n_spherical * cfg.n_radial
    ks = jax.random.split(key, 4 * cfg.n_blocks + 4)
    blocks = []
    for i in range(cfg.n_blocks):
        k = jax.random.split(ks[i], 6)
        blocks.append({
            "w_rbf": (jax.random.normal(k[0], (cfg.n_radial, d)) / math.sqrt(cfg.n_radial)),
            "w_sbf": (jax.random.normal(k[1], (nsr, cfg.n_bilinear)) / math.sqrt(nsr)),
            "w_kj": (jax.random.normal(k[2], (d, d)) / math.sqrt(d)),
            "bilinear": (jax.random.normal(k[3], (d, cfg.n_bilinear, d)) * 0.1
                         / math.sqrt(d)),
            "mlp": _init_mlp(k[4], [d, d, d]),
            "out_mlp": _init_mlp(k[5], [d, d]),
        })
    return {"species_emb": jax.random.normal(ks[-4], (cfg.n_species, d)) * 0.1,
            "edge_emb": _init_mlp(ks[-3], [2 * d + cfg.n_radial, d]),
            "blocks": blocks,
            "head": _init_mlp(ks[-2], [d, d, cfg.d_out])}


def dimenet_specs(cfg: DimeNetConfig):
    d = cfg.d_hidden
    nsr = cfg.n_spherical * cfg.n_radial
    blk = {"w_rbf": P(None, "model"), "w_sbf": P(None, None),
           "w_kj": P(None, "model"), "bilinear": P("model", None, None),
           "mlp": _mlp_specs([d, d, d]), "out_mlp": _mlp_specs([d, d])}
    return {"species_emb": P(None, "model"),
            "edge_emb": _mlp_specs([2 * d + cfg.n_radial, d]),
            "blocks": [blk for _ in range(cfg.n_blocks)],
            "head": _mlp_specs([d, d, cfg.d_out])}


def dimenet_forward(cfg: DimeNetConfig, params, species, coords, src, dst,
                    t_kj, t_ji, n: int):
    """Directional message passing.

    species [n] int32; coords [n, 3];
    edges (j→i): src=j, dst=i, e edges;
    triplets: t_kj[t], t_ji[t] are EDGE indices with dst(t_kj) == src(t_ji)
    (wedge k→j→i); angular basis is evaluated on each wedge.
    """
    diff = coords[dst] - coords[src]
    dist = jnp.sqrt(jnp.maximum(jnp.sum(diff * diff, axis=-1), 1e-12))
    rbf = _rbf(dist, cfg)                                  # [e, R]
    z = params["species_emb"][species]
    m = _mlp(params["edge_emb"],
             jnp.concatenate([z[src], z[dst], rbf], axis=-1), final_act=True)

    # wedge angle between edge t_kj (k→j) and t_ji (j→i)
    v1 = -diff[t_kj]                                       # j→k direction
    v2 = diff[t_ji]                                        # j→i direction
    cosang = jnp.sum(v1 * v2, axis=-1) / jnp.maximum(
        jnp.linalg.norm(v1, axis=-1) * jnp.linalg.norm(v2, axis=-1), 1e-9)
    ang = jnp.arccos(jnp.clip(cosang, -1.0, 1.0))
    sbf = _sbf(dist[t_ji], ang, cfg)                       # [t, S·R]

    out_sum = jnp.zeros((n, cfg.d_hidden))
    e = src.shape[0]
    for p in params["blocks"]:
        # triplet gather: messages of incoming edges k→j modulate edge j→i
        m_kj = (m @ p["w_kj"])[t_kj]                       # [t, d]
        a = sbf @ p["w_sbf"]                               # [t, B]
        inter = jnp.einsum("td,dbk,tb->tk", m_kj, p["bilinear"], a)
        agg = jax.ops.segment_sum(inter, t_ji, e)          # [e, d]
        m = m + _mlp(p["mlp"], agg + rbf @ p["w_rbf"])
        out_sum = out_sum + jax.ops.segment_sum(
            _mlp(p["out_mlp"], m), dst, n)
    return _mlp(params["head"], out_sum)                   # [n, d_out]


def dimenet_loss(cfg: DimeNetConfig, params, batch):
    out = dimenet_forward(cfg, params, batch["species"], batch["coords"],
                          batch["src"], batch["dst"], batch["t_kj"],
                          batch["t_ji"], batch["species"].shape[0])
    energy = jax.ops.segment_sum(out[:, 0], batch["graph_id"],
                                 batch["target"].shape[0])
    return jnp.mean((energy - batch["target"]) ** 2)
