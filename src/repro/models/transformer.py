"""LM transformer: training step, prefill and KV-cache decode.

Layers are stacked ([L, ...] leaves) and executed with ``lax.scan`` so the
80-layer configs lower to compact HLO; each layer body is rematerialized
(``jax.checkpoint``) for training.  Supports:

  * GQA attention (llama3 / qwen2 / yi) with optional QKV bias,
  * MLA latent attention (deepseek-v3) with compressed-KV decode cache,
  * SwiGLU dense FFN and capacity-based top-k MoE (+ shared experts),
  * llama4 iRoPE chunked local attention (3 of 4 layers local),
  * optional depth-1 MTP head (deepseek-v3 multi-token prediction).
"""
from __future__ import annotations

import functools
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models import layers as Lyr
from repro.models.layers import LMConfig


# ---------------------------------------------------------------------------
# Parameter init + specs
# ---------------------------------------------------------------------------

def _layer_init(cfg: LMConfig, key, layer_idx_static: Optional[int] = None):
    ks = jax.random.split(key, 4)
    p = {"ln1": Lyr._norm_init(ks[0], (cfg.d_model,), Lyr._pdt(cfg)),
         "ln2": Lyr._norm_init(ks[1], (cfg.d_model,), Lyr._pdt(cfg))}
    if cfg.mla is not None:
        p["attn"] = Lyr.init_mla(cfg, ks[2])
    else:
        p["attn"] = Lyr.init_attention(cfg, ks[2])
    if cfg.moe is not None:
        # MoE layers carry BOTH a dense and a MoE FFN param set; a static
        # per-layer flag selects which one runs (keeps scan leaves uniform).
        p["ffn"] = Lyr.init_swiglu(cfg.d_model, cfg.d_ff, ks[3], Lyr._pdt(cfg))
        p["moe"] = Lyr.init_moe(cfg, ks[3])
    else:
        p["ffn"] = Lyr.init_swiglu(cfg.d_model, cfg.d_ff, ks[3], Lyr._pdt(cfg))
    return p


def init_params(cfg: LMConfig, key):
    ks = jax.random.split(key, 4)
    layer_keys = jax.random.split(ks[0], cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(cfg, k))(layer_keys)
    p = {
        "embed": Lyr._dense_init(ks[1], (cfg.vocab, cfg.d_model),
                                 Lyr._pdt(cfg), scale=0.02),
        "layers": layers,
        "ln_f": Lyr._norm_init(ks[2], (cfg.d_model,), Lyr._pdt(cfg)),
        "unembed": Lyr._dense_init(ks[3], (cfg.d_model, cfg.vocab),
                                   Lyr._pdt(cfg)),
    }
    if cfg.mtp:
        mk = jax.random.split(ks[3], 2)
        p["mtp"] = {"proj": Lyr._dense_init(mk[0], (2 * cfg.d_model,
                                                    cfg.d_model), Lyr._pdt(cfg)),
                    "layer": _layer_init(cfg, mk[1])}
    return p


def _layer_specs(cfg: LMConfig, stacked: bool):
    def add_l(spec):
        return P(*((None,) + tuple(spec))) if stacked else spec

    attn = Lyr.mla_specs(cfg) if cfg.mla is not None else Lyr.attention_specs(cfg)
    p = {"ln1": add_l(P(None)), "ln2": add_l(P(None)),
         "attn": jax.tree.map(add_l, attn,
                              is_leaf=lambda x: isinstance(x, P)),
         "ffn": jax.tree.map(add_l, Lyr.swiglu_specs(),
                             is_leaf=lambda x: isinstance(x, P))}
    if cfg.moe is not None:
        p["moe"] = jax.tree.map(add_l, Lyr.moe_specs(cfg),
                                is_leaf=lambda x: isinstance(x, P))
    return p


def param_specs(cfg: LMConfig):
    p = {
        "embed": P("model", "data"),       # vocab over model (TP logits)
        "layers": _layer_specs(cfg, stacked=True),
        "ln_f": P(None),
        "unembed": P("data", "model"),
    }
    if cfg.mtp:
        p["mtp"] = {"proj": P("data", "model"),
                    "layer": _layer_specs(cfg, stacked=False)}
    return p


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _is_global_layer(cfg: LMConfig, li: int) -> bool:
    return cfg.attn_chunk is None or (li % cfg.chunk_global_every ==
                                      cfg.chunk_global_every - 1)


def _layer_apply(cfg: LMConfig, p, x, positions, chunk, use_moe: bool,
                 cache=None):
    dt = Lyr._dt(cfg)
    # pin activations batch-sharded at every layer boundary (GSPMD's own
    # propagation replicates them at scale — see layers.shard_hint)
    x = Lyr.shard_hint(x, Lyr.BATCH_AXES, None, None, axes=cfg.hint_axes)
    h = Lyr.rms_norm(x, p["ln1"].astype(dt), cfg.norm_eps)
    if cfg.mla is not None:
        a, new_cache = Lyr.mla_attention(cfg, p["attn"], h, positions, chunk,
                                         cache)
    else:
        a, new_cache = Lyr.gqa_attention(cfg, p["attn"], h, positions, chunk,
                                         cache)
    x = x + a
    h = Lyr.rms_norm(x, p["ln2"].astype(dt), cfg.norm_eps)
    aux = jnp.float32(0.0)
    if use_moe:
        f, aux = Lyr.moe_ffn(cfg, p["moe"], h)
    else:
        f = Lyr.swiglu(p["ffn"], h, dt)
    return x + f, aux, new_cache


def _layer_pattern(cfg: LMConfig, li: int):
    use_moe = cfg.moe is not None and cfg.moe.is_moe_layer(li)
    return (use_moe, _is_global_layer(cfg, li))


def _scan_groups(cfg: LMConfig):
    """Partition layers into scan groups of statically-identical pattern.

    Layers differ statically in two ways (MoE-vs-dense, local-vs-global
    attention).  Two strategies keep the HLO O(#patterns) instead of O(L):

      * periodic: if the pattern sequence repeats with period p (llama4's
        dense/MoE × local/global 4-cycle), scan over n/p macro-steps, each
        unrolling the p-layer cycle;
      * consecutive: otherwise group equal consecutive runs (deepseek-v3's
        3-dense prefix + 58-MoE body).

    Returns ("periodic", p, patterns[:p]) or ("runs", [(lo, hi, pattern)]).
    """
    n = cfg.n_layers
    pats = [_layer_pattern(cfg, li) for li in range(n)]
    if len(set(pats)) > 1:
        for p in range(1, 9):
            if n % p == 0 and pats == pats[:p] * (n // p) and p < n:
                return ("periodic", p, pats[:p])
    runs, start = [], 0
    for li in range(1, n + 1):
        if li == n or pats[li] != pats[start]:
            runs.append((start, li, pats[start]))
            start = li
    return ("runs", runs)


def _scan_layers(cfg: LMConfig, params, x, positions, caches=None):
    """Run all layers with lax.scan over stacked params (see _scan_groups)."""
    aux_total = jnp.float32(0.0)
    plan = _scan_groups(cfg)

    def apply_one(lp, h, aux, pat, c=None):
        use_moe, glob = pat
        chunk = None if glob else cfg.attn_chunk
        h2, a, nc = _layer_apply(cfg, lp, h, positions, chunk, use_moe,
                                 cache=c)
        return h2, aux + a, nc

    if cfg.loop_impl == "unroll":
        # analysis mode: python loop so XLA cost_analysis counts every layer
        ncs = []
        for li in range(cfg.n_layers):
            lp = jax.tree.map(lambda a: a[li], params["layers"])
            c = None if caches is None else \
                jax.tree.map(lambda a: a[li], caches)
            fn = functools.partial(apply_one, pat=_layer_pattern(cfg, li),
                                   c=c)
            if cfg.remat == "full" and caches is None:
                fn = jax.checkpoint(fn, prevent_cse=False)
            x, aux_total, nc = fn(lp, x, aux_total)
            ncs.append(nc)
        new_caches = None if caches is None else jax.tree.map(
            lambda *xs: jnp.stack(xs, 0), *ncs)
        return x, aux_total, new_caches

    if plan[0] == "periodic":
        _, p, pats = plan
        n_macro = cfg.n_layers // p
        sub = jax.tree.map(
            lambda a: a.reshape((n_macro, p) + a.shape[1:]), params["layers"])
        sub_c = None if caches is None else jax.tree.map(
            lambda a: a.reshape((n_macro, p) + a.shape[1:]), caches)

        has_cache = caches is not None

        def body(carry, lp_c):
            h, aux = carry
            lp, c = lp_c if has_cache else (lp_c, None)
            ncs = []
            for k in range(p):
                lpk = jax.tree.map(lambda a: a[k], lp)
                ck = None if c is None else jax.tree.map(lambda a: a[k], c)
                h, aux, nck = apply_one(lpk, h, aux, pats[k], ck)
                ncs.append(nck)
            nc = None if c is None else jax.tree.map(
                lambda *xs: jnp.stack(xs, 0), *ncs)
            return (h, aux), nc

        body_fn = jax.checkpoint(body, prevent_cse=False) \
            if (cfg.remat == "full" and not has_cache) else body
        (x, aux_total), nc = jax.lax.scan(
            body_fn, (x, aux_total),
            (sub, sub_c) if has_cache else sub)
        new_caches = None if caches is None else jax.tree.map(
            lambda a: a.reshape((cfg.n_layers,) + a.shape[2:]), nc)
        return x, aux_total, new_caches

    _, runs = plan
    new_caches = None if caches is None else []
    for (lo, hi, pat) in runs:
        sub = jax.tree.map(lambda a: a[lo:hi], params["layers"])
        if caches is None:
            def body(carry, lp):
                h, aux = carry
                h2, aux2, _ = apply_one(lp, h, aux, pat)
                return (h2, aux2), None

            body_fn = jax.checkpoint(body, prevent_cse=False) \
                if cfg.remat == "full" else body
            (x, aux_total), _ = jax.lax.scan(body_fn, (x, aux_total), sub)
        else:
            sub_c = jax.tree.map(lambda a: a[lo:hi], caches)

            def body(carry, lp_c):
                h, aux = carry
                lp, c = lp_c
                h2, aux2, nc = apply_one(lp, h, aux, pat, c)
                return (h2, aux2), nc

            (x, aux_total), nc = jax.lax.scan(body, (x, aux_total),
                                              (sub, sub_c))
            new_caches.append(nc)
    if new_caches is not None:
        new_caches = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *new_caches) \
            if len(new_caches) > 1 else new_caches[0]
    return x, aux_total, new_caches


def forward(cfg: LMConfig, params, tokens):
    """tokens [B, S] → logits [B, S, V] (+ aux loss)."""
    dt = Lyr._dt(cfg)
    b, s = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    positions = jnp.arange(s)[None, :].repeat(b, 0)
    x, aux, _ = _scan_layers(cfg, params, x, positions)
    x = Lyr.rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["unembed"].astype(dt))
    return logits, aux, x


def loss_fn(cfg: LMConfig, params, batch):
    """Next-token cross entropy (+ MoE aux + optional MTP loss)."""
    tokens, targets = batch["tokens"], batch["targets"]
    logits, aux, x_final = forward(cfg, params, tokens)
    # batch over data axes, vocab over model (vocab-parallel cross-entropy)
    logits = Lyr.shard_hint(logits, Lyr.BATCH_AXES, None, "model",
                            axes=cfg.hint_axes)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = (targets >= 0).astype(jnp.float32)
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    if cfg.mtp:
        # depth-1 MTP: predict token t+2 from [h_t ; emb(token t+1)]
        dt = Lyr._dt(cfg)
        emb_next = params["embed"].astype(dt)[tokens[:, 1:]]
        h = jnp.concatenate([x_final[:, :-1], emb_next], axis=-1)
        h = jnp.einsum("bsd,dk->bsk", h, params["mtp"]["proj"].astype(dt))
        b, s1 = tokens.shape[0], tokens.shape[1] - 1
        pos = jnp.arange(s1)[None, :].repeat(b, 0)
        h, _, _ = _layer_apply(cfg, params["mtp"]["layer"], h, pos,
                               None, use_moe=False)
        mtp_logits = jnp.einsum("bsd,dv->bsv",
                                Lyr.rms_norm(h, params["ln_f"].astype(dt),
                                             cfg.norm_eps),
                                params["unembed"].astype(dt))
        mtp_logp = jax.nn.log_softmax(mtp_logits[:, :-1].astype(jnp.float32),
                                      axis=-1)
        mtp_tgt = targets[:, 2:] if targets.shape[1] > 2 else targets[:, 1:]
        mtp_tgt = targets[:, 1:][:, 1:]        # token t+2 stream
        mtp_nll = -jnp.take_along_axis(
            mtp_logp[:, :mtp_tgt.shape[1]], mtp_tgt[..., None], axis=-1)[..., 0]
        mmask = (mtp_tgt >= 0).astype(jnp.float32)
        loss = loss + 0.1 * jnp.sum(mtp_nll * mmask) / jnp.maximum(
            jnp.sum(mmask), 1.0)
    return loss + aux


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with KV cache.
# ---------------------------------------------------------------------------

def init_cache(cfg: LMConfig, batch: int, max_seq: int, dtype=None):
    dtype = dtype or Lyr._dt(cfg)
    l = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        return {"c_kv": jnp.zeros((l, batch, max_seq, m.kv_lora_rank), dtype),
                "k_r": jnp.zeros((l, batch, max_seq, m.qk_rope_head_dim),
                                 dtype)}
    if cfg.kv_quant:
        shape = (l, batch, max_seq, cfg.n_kv_heads, cfg.head_dim)
        return {"k": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v": jnp.zeros(shape, jnp.int8),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype),
            "v": jnp.zeros((l, batch, max_seq, cfg.n_kv_heads, cfg.head_dim),
                           dtype)}


def cache_specs(cfg: LMConfig, seq_sharded: bool = False):
    """KV cache sharding: batch over data (decode) or seq over data
    (long-context single-stream), kv-heads/latent over model."""
    if cfg.mla is not None:
        if seq_sharded:
            return {"c_kv": P(None, None, "data", "model"),
                    "k_r": P(None, None, "data", None)}
        return {"c_kv": P(None, "data", None, "model"),
                "k_r": P(None, "data", None, None)}
    if seq_sharded:
        return {"k": P(None, None, "data", "model", None),
                "v": P(None, None, "data", "model", None)}
    return {"k": P(None, "data", None, "model", None),
            "v": P(None, "data", None, "model", None)}


def prefill(cfg: LMConfig, params, tokens, cache):
    """Full-sequence prefill writing the cache; returns (logits_last, cache)."""
    dt = Lyr._dt(cfg)
    b, s = tokens.shape
    x = params["embed"].astype(dt)[tokens]
    pos_ids = jnp.arange(s)[None, :].repeat(b, 0)
    # positions drive rope + causal masking; the cache write offset is
    # positions[0,0] = 0 (cache slots beyond s stay masked: kpos > q_pos)
    x, _, new_cache = _scan_layers(cfg, params, x, pos_ids, caches=cache)
    x = Lyr.rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, -1], params["unembed"].astype(dt))
    return logits, new_cache


def decode_step(cfg: LMConfig, params, token, pos, cache):
    """One decode step: token [B], pos scalar int32 (current length).

    Returns (logits [B, V], new cache)."""
    dt = Lyr._dt(cfg)
    b = token.shape[0]
    x = params["embed"].astype(dt)[token][:, None, :]
    pos_ids = jnp.full((b, 1), pos, jnp.int32)
    x, _, new_cache = _scan_layers(cfg, params, x, pos_ids, caches=cache)
    x = Lyr.rms_norm(x, params["ln_f"].astype(dt), cfg.norm_eps)
    logits = jnp.einsum("bd,dv->bv", x[:, 0], params["unembed"].astype(dt))
    return logits, new_cache
