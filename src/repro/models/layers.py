"""Transformer building blocks: RMSNorm, RoPE, GQA/MLA attention, SwiGLU,
MoE — parameterized by LMConfig, sharding-annotated for the production mesh.

Conventions
  * params are nested dicts; leaves are jnp arrays.
  * logical mesh axes: "data" (batch / FSDP) and "model" (TP/EP); specs are
    produced next to each init so param trees and spec trees always match.
  * compute dtype is cfg.dtype (bf16 for the big configs); params live in
    cfg.param_dtype.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Configs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0                 # shared (always-on) experts
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    first_dense_layers: int = 0       # deepseek-v3: first k layers are dense
    interleave_step: int = 1          # llama4: MoE every k-th layer

    def is_moe_layer(self, li: int) -> bool:
        if li < self.first_dense_layers:
            return False
        return (li - self.first_dense_layers) % self.interleave_step == \
            self.interleave_step - 1


@dataclasses.dataclass(frozen=True)
class MLACfg:
    """DeepSeek multi-head latent attention dims."""
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: Optional[int] = None
    qkv_bias: bool = False            # qwen2
    rope_theta: float = 500000.0
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    # llama4 iRoPE: local chunked attention, every `chunk_global_every`-th
    # layer is global. None ⇒ all layers full causal attention.
    attn_chunk: Optional[int] = None
    chunk_global_every: int = 4
    norm_eps: float = 1e-5
    # MLA decode: "absorbed" folds W_uk/W_uv through the attention so
    # scores/context stay in the r-dim latent space — never expands the
    # cache to per-head K/V.  ~128× less per-token expansion FLOPs at
    # decode (EXPERIMENTS.md §Perf C).  "auto": absorbed when q_len == 1.
    mla_decode: str = "auto"          # "auto" | "absorbed" | "expanded"
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    remat: str = "full"               # "full" | "none"
    attn_impl: str = "chunked"        # "chunked" (online softmax) | "naive"
    kv_chunk: int = 1024              # KV tile for chunked attention
    # "scan": lax.scan over layers/KV tiles (production: compact HLO).
    # "unroll": python loops (analysis: XLA cost_analysis counts while
    # bodies ONCE, so exact FLOP counting needs unrolled lowering).
    loop_impl: str = "scan"
    # mesh axis names visible to shard_hint (set by the launch layer;
    # empty = no activation-sharding constraints, e.g. 1-device tests)
    hint_axes: tuple = ()
    # MoE dispatch groups = number of data shards.  Tokens bucket into
    # per-group expert queues with a LOCAL scatter; the group→expert
    # transpose is the only cross-shard movement (one all-to-all).  1 =
    # single flat group (tests / unsharded runs).
    moe_groups: int = 1
    # int8 KV cache (per-token-per-head scales, KIVI-style): halves the
    # resident cache and its read bytes — §Perf lever for the memory-bound
    # decode cells.  Dequantization is elementwise (fuses into the
    # attention read).
    kv_quant: bool = False
    # multi-token prediction (deepseek-v3): extra depth-1 MTP head
    mtp: bool = False

    @property
    def head_dim(self) -> int:
        return self.d_head if self.d_head is not None else self.d_model // self.n_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, l = self.d_model, self.n_layers
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            hd = self.head_dim
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
        if self.moe is not None:
            mo = self.moe
            moe_l = sum(mo.is_moe_layer(i) for i in range(l))
            dense_l = l - moe_l
            ffn = dense_l * 3 * d * self.d_ff + moe_l * (
                (mo.n_experts + mo.n_shared) * 3 * d * mo.d_ff_expert
                + d * mo.n_experts)
        else:
            ffn = l * 3 * d * self.d_ff
        return l * attn + ffn + 2 * self.vocab * d

    def active_param_count(self) -> int:
        """Active params per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        d, l = self.d_model, self.n_layers
        mo = self.moe
        if self.mla is not None:
            m = self.mla
            attn = (d * m.q_lora_rank
                    + m.q_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.qk_rope_head_dim)
                    + d * (m.kv_lora_rank + m.qk_rope_head_dim)
                    + m.kv_lora_rank * self.n_heads
                    * (m.qk_nope_head_dim + m.v_head_dim)
                    + self.n_heads * m.v_head_dim * d)
        else:
            hd = self.head_dim
            attn = d * (self.n_heads * hd) + 2 * d * (self.n_kv_heads * hd) \
                + (self.n_heads * hd) * d
        moe_l = sum(mo.is_moe_layer(i) for i in range(l))
        dense_l = l - moe_l
        ffn = dense_l * 3 * d * self.d_ff + moe_l * (
            (mo.top_k + mo.n_shared) * 3 * d * mo.d_ff_expert
            + d * mo.n_experts)
        return l * attn + ffn + 2 * self.vocab * d


# ---------------------------------------------------------------------------
# Small primitives
# ---------------------------------------------------------------------------

def shard_hint(x, *spec, axes=()):
    """with_sharding_constraint filtered to the mesh axes the launch layer
    declared (cfg.hint_axes); no-op when empty (single-device tests).

    GSPMD's propagation alone picks batch-replicated layouts for the
    attention internals at 256-way scale (measured: global-size [B,H,S,KC]
    buffers per device); pinning the activations at layer boundaries keeps
    every intermediate batch-sharded.
    """
    if not axes:
        return x
    names = set(axes)

    def fix(ax):
        if ax is None:
            return None
        if isinstance(ax, tuple):
            keep = tuple(a for a in ax if a in names)
            return keep if keep else None
        return ax if ax in names else None

    fixed = [fix(a) for a in spec]
    if not any(a is not None for a in fixed):
        return x
    return jax.lax.with_sharding_constraint(x, P(*fixed))


BATCH_AXES = ("pod", "data")


def _dt(cfg: LMConfig):
    return jnp.dtype(cfg.dtype)


def _pdt(cfg: LMConfig):
    return jnp.dtype(cfg.param_dtype)


def _norm_init(key, shape, dtype):
    return jnp.ones(shape, dtype)


def _dense_init(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    s = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * s).astype(dtype)


def rms_norm(x, gamma, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * gamma


def rope_freqs(positions, dim: int, theta: float, dtype=jnp.float32):
    """positions [...,] → (cos, sin) [..., dim/2]."""
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = positions.astype(jnp.float32)[..., None] * inv
    return jnp.cos(ang).astype(dtype), jnp.sin(ang).astype(dtype)


def apply_rope(x, cos, sin):
    """x [..., S, H, D]; cos/sin [..., S, D/2] (broadcast over heads)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :]
    s = sin[..., None, :]
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# Attention (GQA) — shared by train (full seq) and serve (KV-cache decode).
# ---------------------------------------------------------------------------

def init_attention(cfg: LMConfig, key):
    hd = cfg.head_dim
    ks = jax.random.split(key, 5)
    p = {
        "wq": _dense_init(ks[0], (cfg.d_model, cfg.n_heads, hd), _pdt(cfg)),
        "wk": _dense_init(ks[1], (cfg.d_model, cfg.n_kv_heads, hd), _pdt(cfg)),
        "wv": _dense_init(ks[2], (cfg.d_model, cfg.n_kv_heads, hd), _pdt(cfg)),
        "wo": _dense_init(ks[3], (cfg.n_heads, hd, cfg.d_model), _pdt(cfg)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.n_heads, hd), _pdt(cfg))
        p["bk"] = jnp.zeros((cfg.n_kv_heads, hd), _pdt(cfg))
        p["bv"] = jnp.zeros((cfg.n_kv_heads, hd), _pdt(cfg))
    return p


def attention_specs(cfg: LMConfig):
    # heads over "model" (TP); d_model rows over "data" (FSDP / ZeRO-3)
    p = {
        "wq": P("data", "model", None),
        "wk": P("data", "model", None),
        "wv": P("data", "model", None),
        "wo": P("model", None, "data"),
    }
    if cfg.qkv_bias:
        p["bq"] = P("model", None)
        p["bk"] = P("model", None)
        p["bv"] = P("model", None)
    return p


def _sdpa_naive(q, k, v, q_pos, chunk, dtype):
    """Reference attention: materializes the full [B,H,S,T] logits.
    q [B,S,H,D], k/v [B,T,Hkv,D], q_pos [B,S] absolute positions."""
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    q = q.reshape(b, s, hkv, g, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", q, k) / math.sqrt(d)
    logits = logits.astype(jnp.float32)
    kpos = jnp.arange(t)
    mask = kpos[None, None, :] <= q_pos[:, :, None]          # causal
    if chunk is not None:
        mask = mask & (kpos[None, None, :] // chunk
                       == q_pos[:, :, None] // chunk)
    logits = jnp.where(mask[:, None, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1).astype(dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, s, h, d)


def _kv_chunk_for(t: int, want: int) -> int:
    c = min(want, t)
    while t % c:
        c -= 1
    return max(c, 1)


# ---------------------------------------------------------------------------
# Flash-style chunked attention core with a custom VJP.
#
# A plain lax.scan over KV tiles is memory-correct FORWARD, but reverse-mode
# AD saves the per-tile logits/probabilities as scan residuals — i.e. the
# full [S, T] attention matrix in f32, exactly what chunking was avoiding
# (measured: 25GB-scale buffers on the 4k-train cell).  The custom VJP saves
# only (primals, row-max m, row-sum l, out) and RECOMPUTES each tile's
# probabilities in the backward — the FlashAttention recipe, expressed at
# the XLA level.
#
# The core is generic over a `chunk_fn(primals, idx) → (logits, v_tile)`:
#   logits [B, H, S, KC] f32, already masked (-inf), already scaled;
#   v_tile [B, KC, H, DV] f32.
# GQA passes (q, k, v, q_pos); MLA passes (q_nope, q_rope, c_kv, k_r, wuk,
# wuv, q_pos) so the latent up-projections are differentiated through the
# same tile recomputation (grads for wuk/wuv fall out of the per-tile vjp).
# Integer positions ride along as f32 primals (zero cotangent) because
# custom_vjp closures may not capture tracers.
# ---------------------------------------------------------------------------


def _flash_fwd_scan(chunk_fn, n_chunks, dims, primals):
    b_h_s_dv = dims
    b, h, s, dv = b_h_s_dv
    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, dv), jnp.float32)

    def step(carry, idx):
        m, l, acc = carry
        logits, v_c = chunk_fn(primals, idx)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        safe = jnp.isfinite(m_new)
        p = jnp.exp(logits - jnp.where(safe, m_new, 0.0)[..., None])
        p = jnp.where(safe[..., None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bhsk,bkhd->bhsd", p, v_c)
        return (m_new, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out, m, l


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2))
def _flash_core(chunk_fn, n_chunks, dims, primals):
    out, _, _ = _flash_fwd_scan(chunk_fn, n_chunks, dims, primals)
    return out


def _flash_core_fwd(chunk_fn, n_chunks, dims, primals):
    out, m, l = _flash_fwd_scan(chunk_fn, n_chunks, dims, primals)
    return out, (primals, m, l, out)


def _flash_core_bwd(chunk_fn, n_chunks, dims, res, dout):
    primals, m, l, out = res
    l_safe = jnp.maximum(l, 1e-30)
    dG = (dout / l_safe[..., None]).astype(jnp.float32)
    # d l from out = G/l:  dl = -Σ_dv dout·G / l² = -Σ dout·out / l
    dL = -jnp.sum(dout * out, axis=-1) / l_safe
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)

    def tile(pr, idx):
        logits, v_c = chunk_fn(pr, idx)
        p = jnp.exp(logits - m_safe[..., None])           # unnormalized
        g_c = jnp.einsum("bhsk,bkhd->bhsd", p, v_c)
        l_c = jnp.sum(p, axis=-1)
        return g_c, l_c

    def step(grads, idx):
        _, vjp = jax.vjp(lambda pr: tile(pr, idx), primals)
        (dpr,) = vjp((dG, dL))
        grads = jax.tree.map(
            lambda a, b: a + b.astype(jnp.float32), grads, dpr)
        return grads, None

    zeros = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), primals)
    grads, _ = jax.lax.scan(step, zeros, jnp.arange(n_chunks))
    grads = jax.tree.map(lambda g, x: g.astype(x.dtype), grads, primals)
    return (grads,)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def _gqa_chunk(kc, chunk, scale, primals, idx):
    """Tile logits+values for GQA (module-level: must hash for custom_vjp)."""
    q, k, v, qpos_f = primals
    b, s, h, d = q.shape
    hkv = k.shape[2]
    g = h // hkv
    c0 = idx * kc
    k_c = jax.lax.dynamic_slice_in_dim(k, c0, kc, 1).astype(jnp.float32)
    v_c = jax.lax.dynamic_slice_in_dim(v, c0, kc, 1).astype(jnp.float32)
    qr = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    logits = jnp.einsum("bskgd,btkd->bkgst", qr, k_c) * scale
    logits = logits.reshape(b, h, s, kc)
    kpos = c0 + jnp.arange(kc)
    qpos = qpos_f.astype(jnp.int32)                      # [b, s]
    mask = kpos[None, None, :] <= qpos[:, :, None]
    if chunk is not None:
        mask = mask & (kpos[None, None, :] // chunk
                       == qpos[:, :, None] // chunk)
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    v_rep = jnp.repeat(v_c, g, axis=2)                   # [b,kc,h,d]
    return logits, v_rep


def _sdpa(q, k, v, q_pos, chunk, dtype, kv_chunk: int = 1024,
          impl: str = "chunked"):
    """Online-softmax attention, scanned over KV chunks (flash-style).

    Never materializes [S, T] logits: peak extra memory is
    O(B·H·S·kv_chunk) — the hardware adaptation that makes the 32k-prefill
    and 512k-decode shapes fit HBM (DESIGN.md §Perf).  Causal and
    chunked-local (llama4 iRoPE) masking are computed per KV tile from
    positions, so no mask tensor is ever built either.
    """
    if impl == "naive":
        return _sdpa_naive(q, k, v, q_pos, chunk, dtype)
    b, s, h, d = q.shape
    t, hkv = k.shape[1], k.shape[2]
    g = h // hkv
    kc = _kv_chunk_for(t, kv_chunk)
    n_chunks = t // kc
    if impl == "chunked":
        # flash path: scan + custom VJP (tile recompute in backward)
        chunk_fn = functools.partial(_gqa_chunk, kc, chunk,
                                     1.0 / math.sqrt(d))
        dv_ = v.shape[-1]
        out = _flash_core(chunk_fn, n_chunks, (b, h, s, dv_),
                          (q, k, v, q_pos.astype(jnp.float32)))
        return out.transpose(0, 2, 1, 3).astype(dtype)    # [b,s,h,dv]
    qr = q.reshape(b, s, hkv, g, d).astype(jnp.float32)
    scale = 1.0 / math.sqrt(d)

    def body(carry, idx):
        m, l, acc = carry
        c0 = idx * kc
        k_c = jax.lax.dynamic_slice_in_dim(k, c0, kc, 1).astype(jnp.float32)
        v_c = jax.lax.dynamic_slice_in_dim(v, c0, kc, 1).astype(jnp.float32)
        logits = jnp.einsum("bskgd,btkd->bkgst", qr, k_c) * scale
        kpos = c0 + jnp.arange(kc)
        mask = kpos[None, None, :] <= q_pos[:, :, None]
        if chunk is not None:
            mask = mask & (kpos[None, None, :] // chunk
                           == q_pos[:, :, None] // chunk)
        logits = jnp.where(mask[:, None, None], logits, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        p = jnp.where(jnp.isfinite(m_new)[..., None], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_new), 0.0)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] \
            + jnp.einsum("bkgst,btkd->bkgsd", p, v_c)
        return (m_new, l, acc), None

    m0 = jnp.full((b, hkv, g, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, s), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, s, d), jnp.float32)
    if impl == "unroll":
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, i)
        m, l, acc = carry
    else:
        (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                      jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # [b,hkv,g,s,d]
    out = jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)
    return out.astype(dtype)


def gqa_attention(cfg: LMConfig, p, x, positions, chunk, cache=None):
    """Returns (out [B,S,D_model], new_cache or None).

    ``positions`` [B,S] absolute token positions (rope + causal mask);
    ``chunk`` — local-attention chunk size or None (global causal);
    cache = {"k": [B, S_max, Hkv, D], "v": …} written at positions[0,0].
    """
    dt = _dt(cfg)
    hd = cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(dt))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta, dt)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    new_cache = None
    if cache is not None and "k_s" in cache:
        # int8 cache: quantize this step's K/V (per-token-per-head scale)
        off = positions[0, 0]
        ks = jnp.max(jnp.abs(k), axis=-1) / 127.0 + 1e-9     # [B,S,Hkv]
        vs = jnp.max(jnp.abs(v), axis=-1) / 127.0 + 1e-9
        kq = jnp.round(k / ks[..., None]).astype(jnp.int8)
        vq = jnp.round(v / vs[..., None]).astype(jnp.int8)
        upd = jax.lax.dynamic_update_slice_in_dim
        new_cache = {"k": upd(cache["k"], kq, off, axis=1),
                     "k_s": upd(cache["k_s"], ks.astype(jnp.float32),
                                off, axis=1),
                     "v": upd(cache["v"], vq, off, axis=1),
                     "v_s": upd(cache["v_s"], vs.astype(jnp.float32),
                                off, axis=1)}
        k = new_cache["k"].astype(dt) * new_cache["k_s"].astype(dt)[..., None]
        v = new_cache["v"].astype(dt) * new_cache["v_s"].astype(dt)[..., None]
    elif cache is not None:
        # write this step's K/V at the first position id (prefill: 0)
        off = positions[0, 0]
        kc = jax.lax.dynamic_update_slice_in_dim(
            cache["k"], k.astype(cache["k"].dtype), off, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(
            cache["v"], v.astype(cache["v"].dtype), off, axis=1)
        new_cache = {"k": kc, "v": vc}
        k, v = kc.astype(dt), vc.astype(dt)
    impl = cfg.attn_impl if cfg.loop_impl == "scan" else "unroll"
    out = _sdpa(q, k, v, positions, chunk, dt, kv_chunk=cfg.kv_chunk,
                impl=impl if cfg.attn_impl == "chunked" else cfg.attn_impl)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# MLA attention (DeepSeek-V2/V3): low-rank compressed KV latent.
# ---------------------------------------------------------------------------

def init_mla(cfg: LMConfig, key):
    m = cfg.mla
    ks = jax.random.split(key, 7)
    qk_head = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": _dense_init(ks[0], (cfg.d_model, m.q_lora_rank), _pdt(cfg)),
        "wuq": _dense_init(ks[1], (m.q_lora_rank, cfg.n_heads, qk_head), _pdt(cfg)),
        "wdkv": _dense_init(ks[2], (cfg.d_model, m.kv_lora_rank), _pdt(cfg)),
        "wkr": _dense_init(ks[3], (cfg.d_model, m.qk_rope_head_dim), _pdt(cfg)),
        "wuk": _dense_init(ks[4], (m.kv_lora_rank, cfg.n_heads,
                                   m.qk_nope_head_dim), _pdt(cfg)),
        "wuv": _dense_init(ks[5], (m.kv_lora_rank, cfg.n_heads,
                                   m.v_head_dim), _pdt(cfg)),
        "wo": _dense_init(ks[6], (cfg.n_heads, m.v_head_dim, cfg.d_model),
                          _pdt(cfg)),
    }


def mla_specs(cfg: LMConfig):
    return {
        "wdq": P("data", "model"),
        "wuq": P(None, "model", None),
        "wdkv": P("data", "model"),
        "wkr": P("data", "model"),
        "wuk": P(None, "model", None),
        "wuv": P(None, "model", None),
        "wo": P("model", None, "data"),
    }


def _mla_chunk(kc, scale, primals, idx):
    """Tile logits+values for MLA: expands the latent tile to per-head
    (k_nope, v) inside the tile — the backward recomputes it and the vjp
    yields wuk/wuv grads."""
    q_nope, q_rope, c_kv, k_r, wuk, wuv, qpos_f = primals
    b, s, h, _ = q_nope.shape
    c0 = idx * kc
    c_c = jax.lax.dynamic_slice_in_dim(c_kv, c0, kc, 1).astype(jnp.float32)
    kr_c = jax.lax.dynamic_slice_in_dim(k_r, c0, kc, 1).astype(jnp.float32)
    k_nope = jnp.einsum("btr,rhk->bthk", c_c, wuk.astype(jnp.float32))
    v_c = jnp.einsum("btr,rhk->bthk", c_c, wuv.astype(jnp.float32))
    logits = (jnp.einsum("bshk,bthk->bhst", q_nope.astype(jnp.float32),
                         k_nope)
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                           kr_c)) * scale
    kpos = c0 + jnp.arange(kc)
    qpos = qpos_f.astype(jnp.int32)
    mask = kpos[None, None, :] <= qpos[:, :, None]
    logits = jnp.where(mask[:, None], logits, -jnp.inf)
    return logits, v_c                                    # v_c [b,kc,h,dv]


def _mla_sdpa_chunked(cfg, p, q_nope, q_rope, c_kv, k_r, q_pos, dt):
    """Online-softmax MLA attention scanned over latent chunks.

    Each KV tile expands c_kv → per-head (k_nope, v) ON TILE, so the
    full-sequence per-head K/V never exist — the latent cache plus the
    chunked expansion IS DeepSeek's MLA memory trick, kept under remat.
    """
    m = cfg.mla
    b, s, h, _ = q_nope.shape
    t = c_kv.shape[1]
    kc = _kv_chunk_for(t, cfg.kv_chunk)
    n_chunks = t // kc
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    if cfg.loop_impl != "unroll":
        chunk_fn = functools.partial(_mla_chunk, kc, scale)
        out = _flash_core(chunk_fn, n_chunks, (b, h, s, m.v_head_dim),
                          (q_nope, q_rope, c_kv, k_r, p["wuk"], p["wuv"],
                           q_pos.astype(jnp.float32)))
        return out.transpose(0, 2, 1, 3).astype(dt)       # [b,s,h,dv]
    qn = q_nope.astype(jnp.float32)
    qr = q_rope.astype(jnp.float32)
    wuk = p["wuk"].astype(jnp.float32)
    wuv = p["wuv"].astype(jnp.float32)

    def body(carry, idx):
        mx, l, acc = carry                       # [b,h,s], [b,h,s], [b,h,s,dv]
        c0 = idx * kc
        c_c = jax.lax.dynamic_slice_in_dim(c_kv, c0, kc, 1).astype(jnp.float32)
        kr_c = jax.lax.dynamic_slice_in_dim(k_r, c0, kc, 1).astype(jnp.float32)
        k_nope = jnp.einsum("btr,rhk->bthk", c_c, wuk)
        v_c = jnp.einsum("btr,rhk->bthk", c_c, wuv)
        logits = (jnp.einsum("bshk,bthk->bhst", qn, k_nope)
                  + jnp.einsum("bshk,btk->bhst", qr, kr_c)) * scale
        kpos = c0 + jnp.arange(kc)
        mask = kpos[None, None, :] <= q_pos[:, :, None]      # [b,s,kc]
        logits = jnp.where(mask[:, None], logits, -jnp.inf)
        m_new = jnp.maximum(mx, jnp.max(logits, axis=-1))
        pr = jnp.exp(logits - m_new[..., None])
        pr = jnp.where(jnp.isfinite(m_new)[..., None], pr, 0.0)
        alpha = jnp.where(jnp.isfinite(mx), jnp.exp(mx - m_new), 0.0)
        l2 = l * alpha + jnp.sum(pr, axis=-1)
        acc2 = acc * alpha[..., None] + jnp.einsum("bhst,bthk->bhsk", pr, v_c)
        return (m_new, l2, acc2), None

    m0 = jnp.full((b, h, s), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, m.v_head_dim), jnp.float32)
    if cfg.loop_impl == "unroll":
        carry = (m0, l0, a0)
        for i in range(n_chunks):
            carry, _ = body(carry, i)
        mx, l, acc = carry
    else:
        (mx, l, acc), _ = jax.lax.scan(body, (m0, l0, a0),
                                       jnp.arange(n_chunks))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, 2, 1).astype(dt)               # [b,s,h,dv]


def _mla_sdpa_absorbed(cfg, p, q_nope, q_rope, c_kv, k_r, q_pos, dt):
    """Absorbed MLA attention (DeepSeek-V2 §"matrix absorption").

    By associativity, scores = (q W_uk)·c_kv and context = (p·c_kv) W_uv —
    so the per-head K/V expansion of the WHOLE cache collapses into two
    per-QUERY projections.  Per decoded token the t-proportional work drops
    from 2·r·h·(dk+dv) to 4·h·r FLOPs (~128× for the V3 dims).
    """
    m = cfg.mla
    b, s, h, _ = q_nope.shape
    t = c_kv.shape[1]
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    # fold W_uk into the query: [b,s,h,r]
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope.astype(jnp.float32),
                       p["wuk"].astype(jnp.float32))
    logits = (jnp.einsum("bshr,btr->bhst", q_lat,
                         c_kv.astype(jnp.float32))
              + jnp.einsum("bshk,btk->bhst", q_rope.astype(jnp.float32),
                           k_r.astype(jnp.float32))) * scale
    kpos = jnp.arange(t)
    mask = kpos[None, None, :] <= q_pos[:, :, None]
    logits = jnp.where(mask[:, None], logits, -1e30)
    w = jax.nn.softmax(logits, axis=-1)
    ctx = jnp.einsum("bhst,btr->bshr", w, c_kv.astype(jnp.float32))
    out = jnp.einsum("bshr,rhk->bshk", ctx, p["wuv"].astype(jnp.float32))
    return out.astype(dt)


def mla_attention(cfg: LMConfig, p, x, positions, chunk, cache=None):
    """MLA: cache holds the compressed latent c_kv [B, S, r] and the shared
    rope key k_r [B, S, d_r] — the memory saving that IS the MLA trick."""
    dt = _dt(cfg)
    m = cfg.mla
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dr->bsr", x, p["wdq"].astype(dt))
    q = jnp.einsum("bsr,rhk->bshk", q, p["wuq"].astype(dt))
    q_nope, q_rope = jnp.split(q, [m.qk_nope_head_dim], axis=-1)
    c_kv = jnp.einsum("bsd,dr->bsr", x, p["wdkv"].astype(dt))
    k_r = jnp.einsum("bsd,dk->bsk", x, p["wkr"].astype(dt))
    cos, sin = rope_freqs(positions, m.qk_rope_head_dim, cfg.rope_theta, dt)
    q_rope = apply_rope(q_rope, cos, sin)
    k_r = apply_rope(k_r[:, :, None, :], cos, sin)[:, :, 0, :]
    new_cache = None
    if cache is not None:
        off = positions[0, 0]
        cc = jax.lax.dynamic_update_slice_in_dim(
            cache["c_kv"], c_kv.astype(cache["c_kv"].dtype), off, axis=1)
        kr = jax.lax.dynamic_update_slice_in_dim(
            cache["k_r"], k_r.astype(cache["k_r"].dtype), off, axis=1)
        new_cache = {"c_kv": cc, "k_r": kr}
        c_kv, k_r = cc.astype(dt), kr.astype(dt)
    absorbed = cfg.mla_decode == "absorbed" or \
        (cfg.mla_decode == "auto" and s == 1 and cache is not None)
    if absorbed:
        out = _mla_sdpa_absorbed(cfg, p, q_nope, q_rope, c_kv, k_r,
                                 positions, dt)
    elif cfg.attn_impl == "chunked":
        out = _mla_sdpa_chunked(cfg, p, q_nope, q_rope, c_kv, k_r,
                                positions, dt)
    else:
        # naive reference: expand the full latent, materialize logits
        k_nope = jnp.einsum("btr,rhk->bthk", c_kv, p["wuk"].astype(dt))
        v = jnp.einsum("btr,rhk->bthk", c_kv, p["wuv"].astype(dt))
        scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
        logits = (jnp.einsum("bshk,bthk->bhst", q_nope, k_nope)
                  + jnp.einsum("bshk,btk->bhst", q_rope, k_r)) * scale
        logits = logits.astype(jnp.float32)
        t = c_kv.shape[1]
        kpos = jnp.arange(t)
        msk = kpos[None, None, :] <= positions[:, :, None]
        logits = jnp.where(msk[:, None], logits, -1e30)
        w = jax.nn.softmax(logits, axis=-1).astype(dt)
        out = jnp.einsum("bhst,bthk->bshk", w, v)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(dt))
    return out, new_cache


# ---------------------------------------------------------------------------
# FFN: SwiGLU and MoE.
# ---------------------------------------------------------------------------

def init_swiglu(d_model: int, d_ff: int, key, dtype):
    ks = jax.random.split(key, 3)
    return {"wg": _dense_init(ks[0], (d_model, d_ff), dtype),
            "wu": _dense_init(ks[1], (d_model, d_ff), dtype),
            "wd": _dense_init(ks[2], (d_ff, d_model), dtype)}


def swiglu_specs():
    return {"wg": P("data", "model"), "wu": P("data", "model"),
            "wd": P("model", "data")}


def swiglu(p, x, dt):
    g = jnp.einsum("bsd,df->bsf", x, p["wg"].astype(dt))
    u = jnp.einsum("bsd,df->bsf", x, p["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fd->bsd", h, p["wd"].astype(dt))


def init_moe(cfg: LMConfig, key):
    mo = cfg.moe
    ks = jax.random.split(key, 5)
    e = mo.n_experts
    p = {
        "router": _dense_init(ks[0], (cfg.d_model, e), jnp.float32),
        "wg": _dense_init(ks[1], (e, cfg.d_model, mo.d_ff_expert), _pdt(cfg)),
        "wu": _dense_init(ks[2], (e, cfg.d_model, mo.d_ff_expert), _pdt(cfg)),
        "wd": _dense_init(ks[3], (e, mo.d_ff_expert, cfg.d_model), _pdt(cfg)),
    }
    if mo.n_shared:
        p["shared"] = init_swiglu(cfg.d_model, mo.n_shared * mo.d_ff_expert,
                                  ks[4], _pdt(cfg))
    return p


def moe_specs(cfg: LMConfig):
    """Expert weights: experts over "model" (EP) × d_model rows over "data"
    (FSDP).  §Perf iteration A2 tried 2D (hidden dim over "data") to avoid
    weight re-gathers; REFUTED — the per-layer psum of expert outputs cost
    10× more than the (loop-hoisted) weight gathers.  Reverted."""
    p = {
        "router": P(None, None),
        "wg": P("model", "data", None),   # [E, d_model, d_ff]
        "wu": P("model", "data", None),
        "wd": P("model", None, "data"),   # [E, d_ff, d_model]
    }
    if cfg.moe.n_shared:
        p["shared"] = swiglu_specs()
    return p


def _moe_rank_in_expert(top_flat, e):
    """Per-assignment rank within its expert queue (sort-based, O(n log n)
    memory-linear — never materializes a [tokens, E] one-hot)."""
    n = top_flat.shape[0]
    order = jnp.argsort(top_flat, stable=True)
    sorted_e = top_flat[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(e))
    rank_sorted = jnp.arange(n) - starts[sorted_e]
    return jnp.zeros_like(rank_sorted).at[order].set(rank_sorted)


def moe_ffn(cfg: LMConfig, p, x):
    """Capacity-based top-k MoE with GROUPED-LOCAL sort dispatch.

    §Perf iteration A3.  A flat scatter from batch-sharded tokens into the
    [E, cap, d] expert buffer makes GSPMD materialize the full buffer per
    shard and all-reduce it (measured ~10 TB/chip on deepseek-v3 train —
    the dominant term).  Instead, tokens bucket into PER-GROUP expert
    queues (groups = data shards) with a purely local scatter; the
    [G, E, C, d] → [E, G·C, d] transpose is the only cross-shard movement
    and lowers to the canonical MoE all-to-all.  Per-expert capacity is
    per-group (standard in distributed MoE; same expectation, slightly
    different tail drops).
    """
    dt = _dt(cfg)
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = mo.top_k
    e = mo.n_experts
    gcount = max(1, min(cfg.moe_groups, t))
    while t % gcount:
        gcount -= 1
    tg = t // gcount                                      # tokens per group

    xt = x.reshape(t, d)
    logits = (xt.astype(jnp.float32) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, top = jax.lax.top_k(probs, k)                  # [t, k]
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(max(1, math.ceil(tg * k / e * mo.capacity_factor)))
    top_g = top.reshape(gcount, tg * k)                  # group-major
    rank = jax.vmap(lambda tf: _moe_rank_in_expert(tf, e))(top_g)
    keep = rank < cap
    dest = jnp.where(keep, top_g * cap + rank, e * cap)  # [G, tg·k]
    token_id = jnp.repeat(jnp.arange(tg), k)             # [tg·k] local ids

    xg = xt.reshape(gcount, tg, d)
    xg = shard_hint(xg, BATCH_AXES, None, None, axes=cfg.hint_axes)

    def bucket(x_one, dest_one):
        # local scatter into this group's expert queues
        buf = jnp.zeros((e * cap + 1, d), dt)
        return buf.at[dest_one].add(x_one[token_id].astype(dt))[:e * cap]

    xb = jax.vmap(bucket)(xg, dest)                      # [G, E·cap, d]
    xb = xb.reshape(gcount, e, cap, d)
    # group-sharded → expert-sharded: THE all-to-all
    xe = jnp.swapaxes(xb, 0, 1).reshape(e, gcount * cap, d)
    xe = shard_hint(xe, "model", None, None, axes=cfg.hint_axes)
    g = jnp.einsum("ecd,edf->ecf", xe, p["wg"].astype(dt))
    u = jnp.einsum("ecd,edf->ecf", xe, p["wu"].astype(dt))
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("ecf,efd->ecd", h, p["wd"].astype(dt))

    # expert-sharded → group-sharded: the return all-to-all
    yb = jnp.swapaxes(ye.reshape(e, gcount, cap, d), 0, 1)
    yb = shard_hint(yb.reshape(gcount, e * cap, d), BATCH_AXES, None, None,
                    axes=cfg.hint_axes)

    def unbucket(y_one, dest_one, gate_one, keep_one):
        rows = jnp.concatenate([y_one, jnp.zeros((1, d), dt)], axis=0)
        contrib = rows[dest_one] * (gate_one[:, None].astype(dt)
                                    * keep_one[:, None].astype(dt))
        return jnp.zeros((tg, d), dt).at[token_id].add(contrib)

    y = jax.vmap(unbucket)(yb, dest, gate.reshape(gcount, tg * k),
                           keep)                         # [G, tg, d]
    y = y.reshape(t, d)

    if mo.n_shared:
        y = y + swiglu(p["shared"], x, dt).reshape(t, d)
    # load-balance aux loss (Switch): E · Σ_e f_e · P_e
    me = probs.mean(0)
    counts = jnp.zeros((e + 1,), jnp.float32).at[top.reshape(-1)].add(1.0)
    ce = counts[:e] / jnp.float32(t)
    aux = e * jnp.sum(me * ce) * mo.router_aux_weight
    return y.reshape(b, s, d), aux
