"""Model zoo: LM transformers (dense + MoE + MLA), GNNs, DLRM.

Params are plain pytrees (nested dicts of jnp arrays); every model exposes

  init_params(cfg, key)     parameter pytree (or eval_shape-able for dry-run)
  param_specs(cfg)          matching pytree of PartitionSpec (logical axes)
  loss_fn / apply fns       jit/pjit-ready pure functions

so the launch layer can pjit any architecture against the production mesh
without model-specific plumbing.
"""
