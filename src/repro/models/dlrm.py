"""DLRM RM2 (arXiv:1906.00091): sparse embedding tables → dot-product
feature interaction → MLPs.

The embedding lookup is the hot path: JAX has no ``nn.EmbeddingBag``, so the
lookup is a gather (single-hot fields) or the Pallas ``embedding_bag``
kernel (multi-hot).  Tables are stacked [n_sparse, vocab, dim] and sharded
row-wise over the "model" axis (lookup lowers to all-to-all under pjit);
MLPs are data-parallel.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.gnn import _init_mlp, _mlp, _mlp_specs


@dataclasses.dataclass(frozen=True)
class DLRMConfig:
    name: str = "dlrm-rm2"
    n_dense: int = 13
    n_sparse: int = 26
    embed_dim: int = 64
    vocab: int = 1_000_000          # rows per table
    bot_mlp: Sequence[int] = (13, 512, 256, 64)
    top_mlp_hidden: Sequence[int] = (512, 512, 256, 1)
    multi_hot: int = 1              # K slots per field (1 = single-hot)
    dtype: str = "float32"

    @property
    def n_feats(self) -> int:
        return self.n_sparse + 1    # embeddings + bottom-MLP output

    @property
    def d_interact(self) -> int:
        f = self.n_feats
        return f * (f - 1) // 2 + self.embed_dim

    def param_count(self) -> int:
        emb = self.n_sparse * self.vocab * self.embed_dim
        bot = sum(a * b for a, b in zip(self.bot_mlp[:-1], self.bot_mlp[1:]))
        dims = [self.d_interact] + list(self.top_mlp_hidden)
        top = sum(a * b for a, b in zip(dims[:-1], dims[1:]))
        return emb + bot + top


def dlrm_init(cfg: DLRMConfig, key):
    k1, k2, k3 = jax.random.split(key, 3)
    tables = (jax.random.normal(
        k1, (cfg.n_sparse, cfg.vocab, cfg.embed_dim), jnp.float32)
        / math.sqrt(cfg.embed_dim)).astype(jnp.dtype(cfg.dtype))
    top_dims = [cfg.d_interact] + list(cfg.top_mlp_hidden)
    return {"tables": tables,
            "bot": _init_mlp(k2, list(cfg.bot_mlp)),
            "top": _init_mlp(k3, top_dims)}


def dlrm_specs(cfg: DLRMConfig):
    top_dims = [cfg.d_interact] + list(cfg.top_mlp_hidden)
    return {"tables": P(None, "model", None),    # row-sharded tables
            "bot": _mlp_specs(list(cfg.bot_mlp)),
            "top": _mlp_specs(top_dims)}


def _lookup(cfg: DLRMConfig, tables, sparse_idx):
    """sparse_idx [B, n_sparse] (single-hot) or [B, n_sparse, K] (multi-hot)
    → [B, n_sparse, D].  vmap over fields keeps one gather per table."""
    if sparse_idx.ndim == 2:
        return jax.vmap(lambda t, i: t[i], in_axes=(0, 1), out_axes=1)(
            tables, sparse_idx)
    bags = jax.vmap(lambda t, i: t[i].sum(axis=1), in_axes=(0, 1),
                    out_axes=1)(tables, sparse_idx)
    return bags


def _interact(cfg: DLRMConfig, bot_out, emb):
    """Dot interaction: pairwise dots of the 27 feature vectors (lower
    triangle, no diagonal) concatenated with the bottom-MLP output."""
    b = bot_out.shape[0]
    z = jnp.concatenate([bot_out[:, None, :], emb], axis=1)   # [B, F, D]
    zz = jnp.einsum("bfd,bgd->bfg", z, z)                     # [B, F, F]
    f = z.shape[1]
    iu, ju = jnp.tril_indices(f, k=-1)
    dots = zz[:, iu, ju]                                      # [B, F(F-1)/2]
    return jnp.concatenate([bot_out, dots], axis=-1)


def dlrm_forward(cfg: DLRMConfig, params, dense, sparse_idx):
    """dense [B, 13] float; sparse_idx [B, 26] int32 → logits [B]."""
    bot = _mlp(params["bot"], dense, final_act=True)
    emb = _lookup(cfg, params["tables"], sparse_idx).astype(bot.dtype)
    x = _interact(cfg, bot, emb)
    out = _mlp(params["top"], x)
    return out[:, 0]


def dlrm_loss(cfg: DLRMConfig, params, batch):
    logits = dlrm_forward(cfg, params, batch["dense"], batch["sparse"])
    y = batch["label"].astype(jnp.float32)
    return jnp.mean(jnp.maximum(logits, 0) - logits * y
                    + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def dlrm_user_vector(cfg: DLRMConfig, params, dense, sparse_idx):
    """Retrieval tower: the interaction-layer input reduced to embed_dim —
    used to score candidate item embeddings with one batched dot."""
    bot = _mlp(params["bot"], dense, final_act=True)
    emb = _lookup(cfg, params["tables"], sparse_idx).astype(bot.dtype)
    return bot + emb.mean(axis=1)                             # [B, D]


def dlrm_retrieval_scores(cfg: DLRMConfig, params, dense, sparse_idx,
                          cand_emb):
    """Score 1 query (or B queries) against n_candidates item embeddings:
    a single [B, D] × [N, D]ᵀ matmul — batched-dot, never a loop."""
    u = dlrm_user_vector(cfg, params, dense, sparse_idx)      # [B, D]
    return u @ cand_emb.T                                     # [B, N]
