from repro.runtime.ft import (FTConfig, FaultTolerantDriver, StepStats,
                              StragglerDetector)
