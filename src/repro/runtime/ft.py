"""Fault-tolerance runtime: checkpoint/restart driver, straggler detection,
bounded retry, elastic remesh.

On a real multi-pod deployment each host runs this driver around the pjit'd
step; coordination state (heartbeats) goes through the cluster coordinator.
The mechanisms are host-side and hardware-agnostic, so they are exercised
here with simulated failures (tests/test_runtime.py):

  * **Checkpoint/restart** — step loop snapshots every ``ckpt_every`` steps
    through CheckpointManager (async, atomic); on failure the driver
    restores the latest complete checkpoint INCLUDING data-pipeline state
    and resumes, possibly on a different mesh (the manifest is
    mesh-independent).
  * **Bounded retry** — transient step failures (preemption signals,
    injected faults) retry up to ``max_retries`` with exponential backoff;
    a retry after restore re-runs from the last checkpoint, so at-most
    ``ckpt_every`` steps of work are lost.
  * **Straggler detection** — per-step wall-clock EWMA; a step slower than
    ``straggler_factor ×`` the EWMA is flagged, counted, and surfaced in
    StepStats (on a cluster this feeds the scheduler's hot-spare swap).
  * **Elastic remesh** — ``remesh(new_mesh)`` re-shards the live state onto
    a new device mesh via the checkpoint path (save → restore with new
    shardings) without losing pipeline position.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.ckpt import CheckpointManager


def bounded_retry(fn: Callable[[], Any], max_retries: int, backoff_s: float,
                  retryable: Optional[Callable[[BaseException], bool]] = None):
    """Call ``fn()`` with bounded retry + exponential backoff.  Returns
    ``(result, retries_used)``.  ``retryable`` filters which exceptions are
    worth another attempt (default: any ``Exception``); a non-retryable
    failure — or exhausting the budget — re-raises the last error.

    This is the engine fallback chain's retry primitive (DESIGN.md §12): the
    same budget/backoff policy as ``FaultTolerantDriver.run_step`` but free
    of checkpoint/stream state, so ``core.engine`` can wrap a whole engine
    invocation without owning a driver."""
    attempt = 0
    while True:
        try:
            return fn(), attempt
        except Exception as exc:
            if retryable is not None and not retryable(exc):
                raise
            attempt += 1
            if attempt > max_retries:
                raise
            time.sleep(backoff_s * (2 ** (attempt - 1)))


@dataclasses.dataclass
class FTConfig:
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 50
    max_retries: int = 3
    backoff_s: float = 0.05
    straggler_factor: float = 3.0
    ewma_alpha: float = 0.2


@dataclasses.dataclass
class StepStats:
    step: int = 0
    retries: int = 0
    restores: int = 0
    stragglers: int = 0
    ewma_step_s: float = 0.0
    last_step_s: float = 0.0


class StragglerDetector:
    def __init__(self, factor: float, alpha: float):
        self.factor = factor
        self.alpha = alpha
        self.ewma: Optional[float] = None
        self.flagged = 0

    def observe(self, dt: float) -> bool:
        slow = self.ewma is not None and dt > self.factor * self.ewma
        if slow:
            self.flagged += 1
        else:
            # stragglers do not poison the baseline
            self.ewma = dt if self.ewma is None else \
                (1 - self.alpha) * self.ewma + self.alpha * dt
        return slow


class FaultTolerantDriver:
    """Wraps a jitted step function with checkpoint/restart + retry.

    step_fn(state, batch) → (state, metrics); state is any pytree.
    data_state_fn() → json-able dict; data_restore_fn(dict) rewinds the
    pipeline.
    """

    def __init__(self, cfg: FTConfig, step_fn: Callable,
                 data_state_fn: Callable[[], dict],
                 data_restore_fn: Callable[[dict], None],
                 state_shardings: Any = None):
        self.cfg = cfg
        self.step_fn = step_fn
        self.data_state_fn = data_state_fn
        self.data_restore_fn = data_restore_fn
        self.state_shardings = state_shardings
        self.ckpt = CheckpointManager(cfg.ckpt_dir)
        self.detector = StragglerDetector(cfg.straggler_factor,
                                          cfg.ewma_alpha)
        self.stats = StepStats()

    # -- state management ---------------------------------------------------
    def maybe_checkpoint(self, state, step: int, force: bool = False):
        if force or (step > 0 and step % self.cfg.ckpt_every == 0):
            self.ckpt.save_async(step, state,
                                 extra={"data": self.data_state_fn()})

    def restore(self, state_like):
        state, step, extra = self.ckpt.restore_latest(
            state_like, shardings=self.state_shardings)
        if "data" in extra:
            self.data_restore_fn(extra["data"])
        self.stats.restores += 1
        return state, step

    def remesh(self, state, step: int, new_shardings):
        """Elastic re-shard: publish a checkpoint, restore onto the new
        sharding tree (possibly a different mesh shape)."""
        self.ckpt.save_async(step, state,
                             extra={"data": self.data_state_fn()})
        self.ckpt.wait()
        self.state_shardings = new_shardings
        state, _ = self.restore(state)
        return state

    # -- the guarded step ---------------------------------------------------
    def run_step(self, state, batch, state_like=None):
        """Run one step with bounded retry; on exhausting the retry budget
        restores the latest checkpoint (at most ``max_retries`` restores for
        THIS incident) and re-raises once the restore budget is spent too."""
        attempt = 0
        incident_restores = 0
        while True:
            try:
                t0 = time.perf_counter()
                state2, metrics = self.step_fn(state, batch)
                jax.block_until_ready(jax.tree.leaves(state2)[0])
                dt = time.perf_counter() - t0
                self.stats.last_step_s = dt
                if self.detector.observe(dt):
                    self.stats.stragglers += 1
                self.stats.ewma_step_s = self.detector.ewma or dt
                self.stats.step += 1
                return state2, metrics
            except Exception:
                attempt += 1
                self.stats.retries += 1
                if attempt > self.cfg.max_retries:
                    # Retry budget spent: restore and restart the budget.
                    # The abort decision uses the PER-INCIDENT restore
                    # count — the lifetime ``stats.restores`` keeps
                    # accumulating across healthy calls and must never
                    # abort a run that merely survived many incidents.
                    if state_like is None or \
                            incident_restores >= self.cfg.max_retries:
                        raise
                    state, _ = self.restore(state_like)
                    incident_restores += 1
                    attempt = 0
                    continue      # restored state retries immediately — no
                                  # backoff_s * 2**(-1) sleep from the reset
                time.sleep(self.cfg.backoff_s * (2 ** (attempt - 1)))

    def train(self, state, n_steps: int, next_batch: Callable[[], Any],
              start_step: int = 0, fail_hook: Optional[Callable] = None):
        """Step loop with periodic checkpointing.  ``fail_hook(step)`` lets
        tests inject failures."""
        step = start_step
        metrics = None
        while step < n_steps:
            batch = next_batch()
            if fail_hook is not None:
                fail_hook(step)
            state, metrics = self.run_step(state, batch, state_like=state)
            step += 1
            self.maybe_checkpoint(state, step)
        self.maybe_checkpoint(state, step, force=True)
        self.ckpt.wait()
        return state, step, metrics
