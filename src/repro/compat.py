"""Version-compatibility shims for the pinned container toolchain.

``jax.shard_map`` graduated out of ``jax.experimental`` only after the
version baked into the CI image; import it from here so every engine works
on both sides of the move.
"""
from __future__ import annotations

import jax

try:
    shard_map = jax.shard_map
except AttributeError:                  # jax < 0.5: experimental namespace
    from jax.experimental.shard_map import shard_map as _shard_map

    def shard_map(f, **kwargs):
        # the replication check was renamed check_vma -> check_rep backwards
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        # the pre-graduation replication checker rejects valid collective
        # patterns inside while_loop bodies (it suggests disabling itself);
        # the graduated jax.shard_map path above keeps its checker on
        kwargs.setdefault("check_rep", False)
        return _shard_map(f, **kwargs)

__all__ = ["shard_map"]
