"""Segment/scatter reduction primitives with explicit monoid identities.

Condition C6 of the paper (``R(n, ⊥) = n``) makes ⊥ the identity element of
every admissible reduction, so ⊥ is represented by the identity value of the
monoid (DESIGN.md §2).  Every engine (pull segment ops, push scatters, the
Pallas kernel, the distributed combiner) draws identities from here so they
agree bit-for-bit.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Large-but-finite sentinels; jnp.inf works for f32 but ints need finite caps.
_INT_INF = jnp.iinfo(jnp.int32).max // 2


def identity(op: str, dtype):
    """Monoid identity as a NumPy scalar.

    NumPy (not jnp) so the value stays concrete inside jit/while_loop traces
    (JAX ≥0.8 turns in-trace jnp constants into tracers) and can cross the
    Pallas kernel boundary as a static parameter.
    """
    import numpy as np
    dtype = jnp.dtype(dtype)
    if op == "min":
        v = _INT_INF if jnp.issubdtype(dtype, jnp.integer) else np.inf
    elif op == "max":
        v = -_INT_INF if jnp.issubdtype(dtype, jnp.integer) else -np.inf
    elif op in ("sum",):
        v = 0
    elif op == "prod":
        v = 1
    elif op == "or":
        v = False
    elif op == "and":
        v = True
    else:
        raise ValueError(f"unknown reduction {op}")
    return np.dtype(dtype).type(v)


def segment_reduce(op: str, data, segment_ids, num_segments: int):
    """Pull-side reduction: dst-keyed segment reduce with identity fill."""
    if op == "min":
        # segment_min fills empty segments with the dtype max; clamp to our
        # finite identity so downstream arithmetic stays overflow-free.
        out = jax.ops.segment_min(data, segment_ids, num_segments)
        return jnp.minimum(out, identity("min", data.dtype))
    if op == "max":
        out = jax.ops.segment_max(data, segment_ids, num_segments)
        return jnp.maximum(out, identity("max", data.dtype))
    if op == "sum":
        return jax.ops.segment_sum(data, segment_ids, num_segments)
    if op == "prod":
        return jax.ops.segment_prod(data, segment_ids, num_segments)
    if op == "or":
        return jax.ops.segment_max(data.astype(jnp.int32), segment_ids,
                                   num_segments).astype(data.dtype)
    if op == "and":
        return jax.ops.segment_min(data.astype(jnp.int32), segment_ids,
                                   num_segments).astype(data.dtype)
    raise ValueError(f"unknown reduction {op}")


def scatter_reduce(op: str, init, data, segment_ids):
    """Push-side reduction: ``init.at[ids].op(data)``. ``init`` must already
    hold current values (idempotent path) or identities (non-idempotent)."""
    if op == "min":
        return init.at[segment_ids].min(data)
    if op == "max":
        return init.at[segment_ids].max(data)
    if op == "sum":
        return init.at[segment_ids].add(data)
    if op == "prod":
        return init.at[segment_ids].mul(data)
    if op == "or":
        return init.at[segment_ids].max(data.astype(init.dtype))
    if op == "and":
        return init.at[segment_ids].min(data.astype(init.dtype))
    raise ValueError(f"unknown reduction {op}")


def combine(op: str, a, b):
    """Elementwise monoid combine (used to merge partials across shards)."""
    if op == "min":
        return jnp.minimum(a, b)
    if op == "max":
        return jnp.maximum(a, b)
    if op == "sum":
        return a + b
    if op == "prod":
        return a * b
    if op == "or":
        return jnp.maximum(a, b)          # dtype-preserving ∨ on {0,1}/bool
    if op == "and":
        return jnp.minimum(a, b)
    raise ValueError(f"unknown reduction {op}")


def psum_like(op: str, x, axis_name):
    """Cross-shard combine for the distributed engine."""
    if op == "sum":
        return jax.lax.psum(x, axis_name)
    if op == "min":
        return jax.lax.pmin(x, axis_name)
    if op == "max":
        return jax.lax.pmax(x, axis_name)
    if op == "or":
        return jax.lax.pmax(x.astype(jnp.int32), axis_name).astype(x.dtype)
    if op == "and":
        return jax.lax.pmin(x.astype(jnp.int32), axis_name).astype(x.dtype)
    if op == "prod":
        # no native pprod; log-space would lose sign — use all_gather+prod.
        g = jax.lax.all_gather(x, axis_name)
        return jnp.prod(g, axis=0)
    raise ValueError(f"unknown reduction {op}")


def segment_softmax(scores, segment_ids, num_segments: int):
    """Numerically-stable per-segment softmax (GAT edge attention)."""
    smax = jax.ops.segment_max(scores, segment_ids, num_segments)
    smax = jnp.maximum(smax, identity("max", scores.dtype))
    ex = jnp.exp(scores - smax[segment_ids])
    denom = jax.ops.segment_sum(ex, segment_ids, num_segments)
    return ex / jnp.maximum(denom[segment_ids], 1e-30)
