"""Edge partitioning for the distributed / sharded-pallas (shard_map) engines.

PowerGraph-style vertex-cut: edges are split into ``k`` equal, padded blocks;
each shard reduces into a *full* local vertex-state vector with segment ops
(Gather + Apply), and the partial states are combined across shards with a
monoid collective (Scatter).  Padding edges point at vertex 0 with a False
mask, which the engines turn into reduction identities, so padding never
changes a result (condition C6).

``shard_subgraphs`` re-expresses the SAME edge blocks as per-shard ``Graph``
subgraphs over the full vertex id space — the input of the sharded
blocked-ELL layouts (``structure.sharded_ell_cached``) that let the
``pallas_sharded`` engine run the fused Pallas sweeps shard-locally
(DESIGN.md §11).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph, from_edges


@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """[k, e_pad] stacked edge blocks, ready to feed shard_map."""
    k: int
    e_pad: int
    src: jnp.ndarray       # [k, e_pad] int32
    dst: jnp.ndarray       # [k, e_pad] int32
    weight: jnp.ndarray    # [k, e_pad] f32
    capacity: jnp.ndarray  # [k, e_pad] f32
    mask: jnp.ndarray      # [k, e_pad] bool


def partition_edges(g: Graph, k: int, strategy: str = "contiguous") -> EdgePartition:
    src, dst, w, c = g.host_edges()
    e = src.shape[0]
    e_pad = -(-e // k) * k
    if strategy == "contiguous":
        order = np.arange(e)                    # dst-sorted: locality per shard
    elif strategy == "dst_hash":
        order = np.argsort(dst % k, kind="stable")  # balances high-degree dsts
    else:
        raise ValueError(strategy)

    def pad(a, fill):
        out = np.full((e_pad,), fill, dtype=a.dtype)
        out[:e] = a[order]
        return out.reshape(k, e_pad // k)

    return EdgePartition(
        k=k, e_pad=e_pad // k,
        src=jnp.asarray(pad(src, 0)), dst=jnp.asarray(pad(dst, 0)),
        weight=jnp.asarray(pad(w, 0.0)), capacity=jnp.asarray(pad(c, 0.0)),
        mask=jnp.asarray(pad(np.ones(e, dtype=bool), False)),
    )


def shard_subgraphs(g: Graph, k: int, strategy: str = "contiguous") -> list:
    """Per-shard vertex-cut subgraphs: shard j holds exactly the real edges
    of ``partition_edges(g, k, strategy)``'s j-th block, as a ``Graph`` over
    the FULL vertex id space (vertices are replicated across shards — the
    PowerGraph vertex-cut model — so per-shard reductions land in full
    [n]-length partial state vectors that monoid collectives can combine).

    Built from the ``EdgePartition`` blocks rather than re-deriving the
    split so the ``pallas_sharded`` engine's shard-local layouts can never
    disagree with the ``distributed`` engine's edge blocks about which shard
    owns an edge.  Empty shards (k > |E|) are legal and yield edgeless
    subgraphs whose layouts are all-padding (every tile skips)."""
    part = partition_edges(g, k, strategy)
    src = np.asarray(part.src)
    dst = np.asarray(part.dst)
    w = np.asarray(part.weight)
    c = np.asarray(part.capacity)
    mask = np.asarray(part.mask)
    out = []
    for j in range(k):
        m = mask[j]
        out.append(from_edges(g.n, src[j][m], dst[j][m], w[j][m], c[j][m]))
    return out
