"""Edge partitioning for the distributed (shard_map) engine.

PowerGraph-style vertex-cut: edges are split into ``k`` equal, padded blocks;
each shard reduces into a *full* local vertex-state vector with segment ops
(Gather + Apply), and the partial states are combined across shards with a
monoid collective (Scatter).  Padding edges point at vertex 0 with a False
mask, which the engines turn into reduction identities, so padding never
changes a result (condition C6).
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass(frozen=True)
class EdgePartition:
    """[k, e_pad] stacked edge blocks, ready to feed shard_map."""
    k: int
    e_pad: int
    src: jnp.ndarray       # [k, e_pad] int32
    dst: jnp.ndarray       # [k, e_pad] int32
    weight: jnp.ndarray    # [k, e_pad] f32
    capacity: jnp.ndarray  # [k, e_pad] f32
    mask: jnp.ndarray      # [k, e_pad] bool


def partition_edges(g: Graph, k: int, strategy: str = "contiguous") -> EdgePartition:
    src, dst, w, c = g.host_edges()
    e = src.shape[0]
    e_pad = -(-e // k) * k
    if strategy == "contiguous":
        order = np.arange(e)                    # dst-sorted: locality per shard
    elif strategy == "dst_hash":
        order = np.argsort(dst % k, kind="stable")  # balances high-degree dsts
    else:
        raise ValueError(strategy)

    def pad(a, fill):
        out = np.full((e_pad,), fill, dtype=a.dtype)
        out[:e] = a[order]
        return out.reshape(k, e_pad // k)

    return EdgePartition(
        k=k, e_pad=e_pad // k,
        src=jnp.asarray(pad(src, 0)), dst=jnp.asarray(pad(dst, 0)),
        weight=jnp.asarray(pad(w, 0.0)), capacity=jnp.asarray(pad(c, 0.0)),
        mask=jnp.asarray(pad(np.ones(e, dtype=bool), False)),
    )
