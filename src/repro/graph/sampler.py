"""Fanout neighbour sampler (GraphSAGE-style) for ``minibatch_lg`` training.

Host-side (numpy) — this is data-pipeline code, not jitted.  Produces
fixed-shape padded batches so the jitted train step never recompiles.

All hops share ONE local node universe (the union of every frontier, padded
to ``max_nodes``); each hop's edge block is (src_local, dst_local, mask) and
the forward pass aggregates over the full universe per hop, which keeps every
shape static at the cost of some masked compute — the TPU-idiomatic tradeoff
(DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from repro.graph.structure import Graph


@dataclasses.dataclass
class SampledBatch:
    nodes: np.ndarray        # [max_nodes] int32 global ids (0-padded)
    node_mask: np.ndarray    # [max_nodes] bool
    seeds_local: np.ndarray  # [batch] int32 positions of seeds in `nodes`
    # per hop, outermost (farthest from seeds) first:
    edge_src: list           # [n_edges_hop] int32 local ids
    edge_dst: list           # [n_edges_hop] int32 local ids
    edge_mask: list          # [n_edges_hop] bool


def max_nodes_for(batch: int, fanouts: Sequence[int]) -> int:
    total, frontier = batch, batch
    for f in fanouts:
        frontier *= f
        total += frontier
    return total


class NeighborSampler:
    """Uniform with-replacement in-neighbour sampler over a CSR built once."""

    def __init__(self, g: Graph, fanouts: Sequence[int], seed: int = 0):
        src, dst, _, _ = g.host_edges()
        order = np.argsort(dst, kind="stable")
        self.src_sorted = src[order]
        self.indptr = np.zeros(g.n + 1, dtype=np.int64)
        np.add.at(self.indptr, dst + 1, 1)
        np.cumsum(self.indptr, out=self.indptr)
        self.fanouts = list(fanouts)
        self.rng = np.random.default_rng(seed)
        self.n = g.n

    def sample(self, seeds: np.ndarray) -> SampledBatch:
        seeds = np.asarray(seeds, dtype=np.int64)
        batch = seeds.shape[0]
        cap = max_nodes_for(batch, self.fanouts)

        hops = []                       # (src_global, dst_global, mask)
        frontier = seeds
        for f in self.fanouts:
            n_dst = frontier.shape[0]
            starts = self.indptr[frontier]
            degs = self.indptr[frontier + 1] - starts
            offs = (self.rng.random((n_dst, f)) * np.maximum(degs, 1)[:, None]).astype(np.int64)
            idx = np.minimum(starts[:, None] + offs, len(self.src_sorted) - 1)
            srcs = self.src_sorted[idx].astype(np.int64)
            mask = degs[:, None] > 0
            hops.append((srcs.ravel(), np.repeat(frontier, f), mask.ravel()))
            frontier = np.unique(np.concatenate([frontier, srcs.ravel()]))

        universe = np.unique(np.concatenate([seeds] + [h[0] for h in hops]
                                            + [h[1] for h in hops]))
        lut = universe                   # sorted — searchsorted gives local ids
        nodes = np.zeros(cap, dtype=np.int32)
        nodes[:universe.shape[0]] = universe
        node_mask = np.zeros(cap, dtype=bool)
        node_mask[:universe.shape[0]] = True

        def local(ids):
            return np.searchsorted(lut, ids).astype(np.int32)

        edge_src, edge_dst, edge_mask = [], [], []
        for s, d, m in reversed(hops):   # outermost hop first for forward pass
            edge_src.append(local(s))
            edge_dst.append(local(d))
            edge_mask.append(m)
        return SampledBatch(nodes=nodes, node_mask=node_mask,
                            seeds_local=local(seeds),
                            edge_src=edge_src, edge_dst=edge_dst,
                            edge_mask=edge_mask)
