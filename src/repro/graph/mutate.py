"""Batched edge insert/delete with in-place blocked-ELL layout patching.

The incremental half of DESIGN.md §15: a mutation produces a NEW immutable
``Graph`` (every downstream cache is identity-keyed, so mutating in place
would silently serve stale layouts), but the expensive derived structures —
the pull/push blocked-ELL rectangles and the dst-sorted push resolution —
are carried over by an O(delta) patch instead of an O(E) rebuild whenever
the edit fits the existing padding:

* **Deletes** clear the edge's mask slot and decrement the owning tile's
  ``tile_nnz`` — the slot becomes reusable padding.
* **Inserts** take the first free slot of their row (freed or original
  padding).  A row whose free slots run out overflows the layout's padded
  width; that layout falls back to a **counted rebuild** (the patched entry
  is simply not installed, so the canonical lazy build runs for the new
  graph) and the fallback is visible in ``MUTATION_STATS`` / the returned
  ``MutationDelta``.

Patched layouts are *non-canonical*: an edge's slot is wherever a free slot
was, not the left-to-right fill order ``to_blocked_ell`` would assign.
That is value-safe for the idempotent reductions (min/max/or/and are
order-insensitive bitwise) but it means the push resolution can NEVER be
rebuilt canonically against a patched out rectangle — its ``in2out``
permutation would address the wrong slots.  The coupling rule: whenever
either direction's layout is patched, a resolution consistent with the
ACTUAL slot assignments of both directions is derived and installed
alongside (``_resolution_from_slots``), and the per-edge slot maps are
recorded in ``structure._SLOT_CACHE`` so chained mutations keep patching
from the real positions.

Touched-vertex contract (consumed by the delta-seeded fixpoint,
``engine.run_program(..., delta=...)``): ``MutationDelta.touched`` is the
unique endpoint set of every inserted and deleted edge — a superset of the
vertices whose fixpoint values can change in one propagation step, which
is exactly the frontier seed that makes warm-started idempotent rounds
sound for insert-only edits (DESIGN.md §15).
"""
from __future__ import annotations

import dataclasses
import weakref

import jax.numpy as jnp
import numpy as np

from repro.core.guard import GraphValidationError
from repro.graph import structure
from repro.graph.structure import (
    BlockedELL, Graph, PushResolution, _check_edge_arrays, _fill_order_slots,
    _padded_width, from_edges)

# Global patch/rebuild accounting (bench + tests; reset like SWEEP_STATS).
MUTATION_STATS = {
    "mutations": 0,          # mutate_edges calls
    "patched_layouts": 0,    # cached layouts carried over by in-place patch
    "rebuilt_layouts": 0,    # cached layouts dropped to a counted rebuild
}


def reset_mutation_stats() -> None:
    for k in MUTATION_STATS:
        MUTATION_STATS[k] = 0


@dataclasses.dataclass(frozen=True)
class MutationDelta:
    """Summary of one ``mutate_edges`` batch: the planner's mutation-size
    statistics (``plan_execution(mutation=...)``) and the delta-fixpoint's
    frontier seed (``touched``)."""
    inserted: int            # edges added (post-policy filtering)
    deleted: int             # edges removed (explicit batch + policy drops)
    touched: np.ndarray      # unique int64 endpoint ids of every edit
    has_deletes: bool        # deletions retract support: idempotent rounds
                             # cannot warm-start over them (DESIGN.md §15)
    patched_layouts: int     # cached layouts patched in place this batch
    rebuilt_layouts: int     # cached layouts that overflowed to a rebuild


def _cache_hit(cache: dict, key, g):
    hit = cache.get(key)
    if hit is None:
        return None
    ref, val = hit
    return val if ref() is g else None


def _install(cache: dict, key, g, val) -> None:
    cache[key] = (weakref.ref(g), val)
    weakref.finalize(g, cache.pop, key, None)


def _slot_maps(g: Graph, block_v: int, block_e: int):
    """(k_in, k_out) per edge, aligned to ``host_edges`` (dst-sorted) order:
    the recorded maps of a previously-patched graph, or the canonical
    left-to-right fill order (exactly what ``to_blocked_ell`` /
    ``to_push_resolution`` assign) for a graph built from scratch."""
    maps = _cache_hit(structure._SLOT_CACHE, (id(g), block_v, block_e), g)
    if maps is not None:
        return maps
    src, dst, _w, _c = g.host_edges()
    return _fill_order_slots(dst, g.n), _fill_order_slots(src, g.n)


def _patch_ell(ell: BlockedELL, row_old, k_old, keep,
               row_ins, nbr_ins, w_ins, c_ins):
    """Patch one cached blocked-ELL layout: free deleted slots, place
    inserted edges in free slots of their rows, ±1 the affected tiles'
    ``tile_nnz``.  Returns ``(patched_ell, k_ins)`` with the inserted
    edges' slot indices, or None when an inserted row has no free slot left
    (overflow → counted rebuild)."""
    bv, be = ell.block_v, ell.block_e
    nbrs = np.array(ell.nbrs)
    ws = np.array(ell.weight)
    cs = np.array(ell.capacity)
    mask = np.array(ell.mask)
    tile_nnz = np.array(ell.tile_nnz)
    drop = ~keep
    if drop.any():
        r_del = row_old[drop]
        k_del = k_old[drop]
        mask[r_del, k_del] = False
        nbrs[r_del, k_del] = 0
        ws[r_del, k_del] = 0.0
        cs[r_del, k_del] = 0.0
        np.subtract.at(tile_nnz, (r_del // bv, k_del // be), 1)
    k_ins = np.empty(row_ins.shape[0], dtype=np.int64)
    free: dict = {}
    for i in range(row_ins.shape[0]):
        r = int(row_ins[i])
        slots = free.get(r)
        if slots is None:
            slots = list(np.flatnonzero(~mask[r]))
            free[r] = slots
        if not slots:
            return None
        k = int(slots.pop(0))
        k_ins[i] = k
        mask[r, k] = True
        nbrs[r, k] = nbr_ins[i]
        ws[r, k] = w_ins[i]
        cs[r, k] = c_ins[i]
    if row_ins.size:
        np.add.at(tile_nnz, (np.asarray(row_ins, np.int64) // bv,
                             k_ins // be), 1)
    patched = BlockedELL(
        n=ell.n, n_pad=ell.n_pad, width=ell.width,
        block_v=bv, block_e=be,
        nbrs=jnp.asarray(nbrs), weight=jnp.asarray(ws),
        capacity=jnp.asarray(cs), mask=jnp.asarray(mask),
        tile_nnz=jnp.asarray(tile_nnz), direction=ell.direction)
    return patched, k_ins


def _resolution_from_slots(n, src, dst, k_in, k_out, w_in, w_out,
                           block_v, block_e) -> PushResolution:
    """``to_push_resolution`` generalized to EXPLICIT per-edge slot
    assignments and rectangle widths — the resolution of a patched layout
    pair, whose slots are no longer the canonical fill order.  Arrays are
    host_edges (dst-sorted) order; same int32 overflow guard, same contrib
    construction as the canonical builder."""
    n_pad = ((n + block_v - 1) // block_v) * block_v
    in2out = np.zeros((n_pad, w_in), dtype=np.int64)
    valid = np.zeros((n_pad, w_in), dtype=bool)
    in2out[dst, k_in] = src.astype(np.int64) * w_out + k_out
    valid[dst, k_in] = True
    if n_pad * w_out >= 2 ** 31:
        raise ValueError(
            f"out rectangle {n_pad}×{w_out} overflows int32 flat indices; "
            "the dst-sorted resolution layout needs an int64 gather path "
            "for graphs this hub-heavy")
    n_j_out = w_out // block_e
    out_row = in2out // w_out
    out_col = in2out % w_out
    src_tile = (out_row // block_v) * n_j_out + out_col // block_e
    tile_nnz = valid.reshape(n_pad // block_v, block_v,
                             w_in // block_e, block_e) \
        .sum(axis=(1, 3)).astype(np.int32)
    n_j_in = w_in // block_e
    n_tiles = (n_pad // block_v) * n_j_in
    n_out_tiles = (n_pad // block_v) * n_j_out
    r_tile = (dst // block_v).astype(np.int64) * n_j_in + k_in // block_e
    s_tile = (src // block_v).astype(np.int64) * n_j_out + k_out // block_e
    pair = np.unique(r_tile * n_out_tiles + s_tile)
    r_ids = pair // n_out_tiles
    s_ids = pair % n_out_tiles
    counts = np.bincount(r_ids, minlength=n_tiles)
    c_max = int(max(1, counts.max() if counts.size else 1))
    contrib = np.full((n_tiles, c_max), -1, dtype=np.int32)
    slot = np.arange(r_ids.size) - np.searchsorted(r_ids, r_ids)
    contrib[r_ids, slot] = s_ids
    return PushResolution(
        n=n, n_pad=n_pad, width=w_in, out_width=w_out,
        block_v=block_v, block_e=block_e,
        in2out=jnp.asarray(in2out.astype(np.int32)),
        valid=jnp.asarray(valid),
        src_tile=jnp.asarray(src_tile.astype(np.int32)),
        tile_nnz=jnp.asarray(tile_nnz),
        contrib=jnp.asarray(contrib))


def mutate_edges(g: Graph, insert=None, delete=None, *,
                 self_loops: str = "allow", duplicates: str = "allow"):
    """Apply one batched edge mutation; returns ``(new_graph, delta)``.

    ``insert`` is ``(src, dst[, weight[, capacity]])`` arrays (weight and
    capacity default to 1.0, like ``from_edges``); ``delete`` is
    ``(src, dst)`` pairs that must all exist — a k-fold request consumes k
    occurrences of a parallel edge, and naming a missing edge raises
    ``GraphValidationError``.  The merged edge list is validated under the
    ``self_loops`` / ``duplicates`` policies of ``from_edges`` (so
    inserting a duplicate under ``duplicates="error"`` raises with the
    standard text, and ``self_loops="drop"`` filters — counted as deletes
    when it removes surviving old edges).

    Every blocked-ELL layout and push resolution cached for ``g`` is
    carried to the new graph by an in-place patch when the edit fits the
    padded widths, falling back to a counted rebuild per layout on row
    overflow (module docstring; DESIGN.md §15)."""
    if self_loops not in ("allow", "drop", "error"):
        raise ValueError(f"self_loops must be allow|drop|error, "
                         f"got {self_loops!r}")
    if duplicates not in ("allow", "error"):
        raise ValueError(f"duplicates must be allow|error, got {duplicates!r}")
    if insert is None and delete is None:
        raise ValueError("mutate_edges needs an insert batch, a delete "
                         "batch, or both")
    src, dst, w, c = g.host_edges()
    n, e = g.n, int(src.shape[0])

    # ---- resolve the delete batch against the current edge list ----------
    keep = np.ones(e, dtype=bool)
    if delete is not None:
        if len(tuple(delete)) != 2:
            raise ValueError("delete must be a (src, dst) pair of vectors")
        dsrc = np.asarray(delete[0])
        ddst = np.asarray(delete[1])
        if dsrc.size == 0:
            dsrc = dsrc.astype(np.int32)
            ddst = ddst.astype(np.int32)
        for name, a in (("src", dsrc), ("dst", ddst)):
            if a.ndim != 1 or not np.issubdtype(a.dtype, np.integer):
                raise GraphValidationError(
                    f"delete {name} must be a 1-d integer vector, got "
                    f"shape {a.shape} dtype {a.dtype}")
        if dsrc.shape != ddst.shape:
            raise GraphValidationError(
                f"delete src/dst length mismatch: {dsrc.shape[0]} vs "
                f"{ddst.shape[0]}")
        if dsrc.size:
            if (dsrc.min() < 0 or dsrc.max() >= n
                    or ddst.min() < 0 or ddst.max() >= n):
                raise GraphValidationError(
                    f"delete batch endpoints out of range [0, {n})")
            key = src.astype(np.int64) * n + dst
            dkey = dsrc.astype(np.int64) * n + ddst.astype(np.int64)
            order = np.argsort(key, kind="stable")
            skey = key[order]
            dorder = np.argsort(dkey, kind="stable")
            sdkey = dkey[dorder]
            # rank-within-key: the j-th request for one (src, dst) key
            # consumes the j-th occurrence of that parallel edge
            rank = np.arange(sdkey.size) - np.searchsorted(sdkey, sdkey)
            lo = np.searchsorted(skey, sdkey, side="left")
            hi = np.searchsorted(skey, sdkey, side="right")
            missing = rank >= (hi - lo)
            if missing.any():
                i = int(dorder[np.flatnonzero(missing)[0]])
                raise GraphValidationError(
                    f"delete batch names {int(missing.sum())} edge(s) not "
                    f"present in the graph, first "
                    f"({int(dsrc[i])} -> {int(ddst[i])})")
            keep[order[lo + rank]] = False

    # ---- the insert batch -------------------------------------------------
    if insert is not None:
        parts = tuple(insert)
        if len(parts) < 2:
            raise ValueError(
                "insert must be (src, dst[, weight[, capacity]]) vectors")
        isrc = np.asarray(parts[0])
        idst = np.asarray(parts[1])
        if isrc.size == 0:
            isrc = isrc.astype(np.int32)
            idst = idst.astype(np.int32)
        n_req = isrc.shape[0] if isrc.ndim else 0
        iw = (np.asarray(parts[2], dtype=np.float32)
              if len(parts) > 2 and parts[2] is not None
              else np.ones(n_req, np.float32))
        ic = (np.asarray(parts[3], dtype=np.float32)
              if len(parts) > 3 and parts[3] is not None
              else np.ones(n_req, np.float32))
    else:
        isrc = np.zeros(0, np.int32)
        idst = np.zeros(0, np.int32)
        iw = np.zeros(0, np.float32)
        ic = np.zeros(0, np.float32)

    # ---- merged edge list, validated under the caller's policies ----------
    new_src = np.concatenate([src[keep], isrc])
    new_dst = np.concatenate([dst[keep], idst])
    new_w = np.concatenate([w[keep], iw]).astype(np.float32)
    new_c = np.concatenate([c[keep], ic]).astype(np.float32)
    fmask = _check_edge_arrays(n, new_src, new_dst, new_w, new_c,
                               self_loops, duplicates)
    if fmask is not None:            # self_loops="drop" filtered the merge
        kept_idx = np.flatnonzero(keep)
        keep[kept_idx[~fmask[:kept_idx.size]]] = False
        ins_keep = fmask[kept_idx.size:]
        isrc, idst = isrc[ins_keep], idst[ins_keep]
        iw, ic = iw[ins_keep], ic[ins_keep]
        new_src, new_dst = new_src[fmask], new_dst[fmask]
        new_w, new_c = new_w[fmask], new_c[fmask]
    new_src = new_src.astype(np.int32, copy=False)
    new_dst = new_dst.astype(np.int32, copy=False)

    new_g = from_edges(n, new_src, new_dst, new_w, new_c, validate=False)
    n_ins = int(isrc.shape[0])
    n_del = e - int(keep.sum())
    touched = np.unique(np.concatenate([
        src[~keep].astype(np.int64), dst[~keep].astype(np.int64),
        isrc.astype(np.int64), idst.astype(np.int64)]))

    # ---- carry cached layouts over by patch (or count the rebuild) --------
    patched = rebuilt = 0
    shapes = set()
    for (gid, bv, be, _d), (ref, _ell) in list(structure._ELL_CACHE.items()):
        if gid == id(g) and ref() is g:
            shapes.add((bv, be))
    perm_new = np.argsort(new_dst, kind="stable")   # from_edges' by_dst order
    for bv, be in sorted(shapes):
        k_in_old, k_out_old = _slot_maps(g, bv, be)
        ell_in = _cache_hit(structure._ELL_CACHE, (id(g), bv, be, "in"), g)
        ell_out = _cache_hit(structure._ELL_CACHE, (id(g), bv, be, "out"), g)
        res_old = _cache_hit(structure._RES_CACHE, (id(g), bv, be), g)
        in_patch = out_patch = None
        if ell_in is not None:
            in_patch = _patch_ell(ell_in, dst, k_in_old, keep,
                                  idst, isrc, iw, ic)
            if in_patch is None:
                rebuilt += 1
        if ell_out is not None:
            out_patch = _patch_ell(ell_out, src, k_out_old, keep,
                                   isrc, idst, iw, ic)
            if out_patch is None:
                rebuilt += 1
        if in_patch is None and out_patch is None:
            if res_old is not None:
                rebuilt += 1         # its layouts rebuild, it follows them
            continue
        # Final per-edge slot maps of the new graph, host_edges-aligned:
        # the patched positions where the patch succeeded, the canonical
        # fill order where the layout falls back to a lazy rebuild.
        if in_patch is not None:
            new_in, k_in_ins = in_patch
            k_in_full = np.concatenate([k_in_old[keep], k_in_ins])[perm_new]
            w_in_f = new_in.width
        else:
            k_in_full = _fill_order_slots(new_dst[perm_new], n)
            w_in_f = _padded_width(np.bincount(new_dst, minlength=n), be)
        if out_patch is not None:
            new_out, k_out_ins = out_patch
            k_out_full = np.concatenate([k_out_old[keep],
                                         k_out_ins])[perm_new]
            w_out_f = new_out.width
        else:
            k_out_full = _fill_order_slots(new_src[perm_new], n)
            w_out_f = _padded_width(np.bincount(new_src, minlength=n), be)
        if in_patch is not None:
            _install(structure._ELL_CACHE, (id(new_g), bv, be, "in"),
                     new_g, new_in)
            patched += 1
        if out_patch is not None:
            _install(structure._ELL_CACHE, (id(new_g), bv, be, "out"),
                     new_g, new_out)
            patched += 1
        # The resolution MUST match the actual slot assignments of both
        # directions (module docstring) — derive and install it whenever
        # either direction is non-canonical.
        res = _resolution_from_slots(
            n, new_src[perm_new], new_dst[perm_new],
            k_in_full, k_out_full, w_in_f, w_out_f, bv, be)
        _install(structure._RES_CACHE, (id(new_g), bv, be), new_g, res)
        if res_old is not None:
            patched += 1
        _install(structure._SLOT_CACHE, (id(new_g), bv, be),
                 new_g, (k_in_full, k_out_full))

    MUTATION_STATS["mutations"] += 1
    MUTATION_STATS["patched_layouts"] += patched
    MUTATION_STATS["rebuilt_layouts"] += rebuilt
    return new_g, MutationDelta(
        inserted=n_ins, deleted=n_del, touched=touched,
        has_deletes=bool(n_del), patched_layouts=patched,
        rebuilt_layouts=rebuilt)
