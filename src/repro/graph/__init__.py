from repro.graph.structure import Graph, BlockedELL, rmat_graph, uniform_graph, grid_graph, line_graph, cora_like
from repro.graph import segment
