"""Graph containers shared by the GraFS engines and the GNN models.

Edges are stored twice, in destination-sorted order (pull / CSR-style:
``segment_*`` reductions key on ``dst``) and in source-sorted order
(push / CSC-style: frontier-masked scatters key on ``src``).  Both orders
refer to the same logical edge set; per-edge data (weight, capacity) is
carried alongside each order so engines never re-permute at run time.

A ``BlockedELL`` layout additionally pads per-vertex in-degrees to a fixed
width so the Pallas TPU kernel sees fully regular tiles (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import weakref
from functools import partial

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class EdgeOrder:
    """One ordering of the edge list plus its per-edge data."""
    src: jnp.ndarray        # [E] int32
    dst: jnp.ndarray        # [E] int32
    weight: jnp.ndarray     # [E] float32
    capacity: jnp.ndarray   # [E] float32


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    by_dst: EdgeOrder       # sorted by dst (pull engines)
    by_src: EdgeOrder       # sorted by src (push engines)
    in_deg: jnp.ndarray     # [n] int32
    out_deg: jnp.ndarray    # [n] int32

    @property
    def num_edges(self) -> int:
        return int(self.by_dst.src.shape[0])

    def host_edges(self):
        """(src, dst, weight, capacity) as numpy, dst-sorted."""
        e = self.by_dst
        return (np.asarray(e.src), np.asarray(e.dst),
                np.asarray(e.weight), np.asarray(e.capacity))


def from_edges(n: int, src, dst, weight=None, capacity=None) -> Graph:
    src = np.asarray(src, dtype=np.int32)
    dst = np.asarray(dst, dtype=np.int32)
    e = src.shape[0]
    if weight is None:
        weight = np.ones((e,), dtype=np.float32)
    if capacity is None:
        capacity = np.ones((e,), dtype=np.float32)
    weight = np.asarray(weight, dtype=np.float32)
    capacity = np.asarray(capacity, dtype=np.float32)

    def order(key):
        perm = np.argsort(key, kind="stable")
        return EdgeOrder(src=jnp.asarray(src[perm]), dst=jnp.asarray(dst[perm]),
                         weight=jnp.asarray(weight[perm]),
                         capacity=jnp.asarray(capacity[perm]))

    in_deg = np.bincount(dst, minlength=n).astype(np.int32)
    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    return Graph(n=n, by_dst=order(dst), by_src=order(src),
                 in_deg=jnp.asarray(in_deg), out_deg=jnp.asarray(out_deg))


# ---------------------------------------------------------------------------
# Blocked-ELL layout for the Pallas edge kernel.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockedELL:
    """Degree-padded neighbour lists in one direction.

    With ``direction="in"`` (the pull layout) row v holds the predecessors of
    v: ``nbrs[v, k]`` is the k-th *source* of an in-edge of v.  With
    ``direction="out"`` (the push layout) row v holds the successors:
    ``nbrs[v, k]`` is the k-th *destination* of an out-edge of v.  ``mask[v,
    k]`` marks real slots.  ``n_pad`` and ``width`` are multiples of the
    requested tile sizes so a Pallas grid covers the arrays exactly.

    ``tile_nnz[i, j]`` counts the real slots inside grid tile (i, j) for the
    layout's own (block_v, block_e); power-law degree distributions leave most
    tail column-tiles fully padded, and the fused sweeps skip those tiles
    before doing any work (DESIGN.md §2).
    """
    n: int                  # logical vertex count
    n_pad: int
    width: int              # padded max degree (in- or out-, per direction)
    block_v: int            # tile sizes the layout was built for
    block_e: int
    nbrs: jnp.ndarray       # [n_pad, width] int32 neighbour vertex ids
    weight: jnp.ndarray     # [n_pad, width] float32
    capacity: jnp.ndarray   # [n_pad, width] float32
    mask: jnp.ndarray       # [n_pad, width] bool
    tile_nnz: jnp.ndarray   # [n_pad/block_v, width/block_e] int32
    direction: str = "in"   # "in" (rows = dst, pull) | "out" (rows = src, push)

    @property
    def srcs(self) -> jnp.ndarray:
        """Pull-layout alias: with ``direction="in"`` the neighbour ids ARE
        the edge sources (kept for the original pull-sweep call sites).
        Guarded so an out-layout can never leak destination ids under the
        name ``srcs`` into gather-side code."""
        if self.direction != "in":
            raise AttributeError(
                "BlockedELL.srcs is only meaningful on the pull layout "
                f"(direction='in'); this layout is direction={self.direction!r}"
                " — use .nbrs")
        return self.nbrs


def to_blocked_ell(g: Graph, block_v: int = 8, block_e: int = 128,
                   direction: str = "in") -> BlockedELL:
    """Build the blocked-ELL layout keyed by dst (``direction="in"``, the
    pull sweep's predecessor lists) or by src (``direction="out"``, the push
    sweep's successor lists).  Both directions carry the same per-edge
    weight/capacity so the synthesized P functions see identical edges."""
    src, dst, w, c = g.host_edges()
    n = g.n
    if direction == "in":
        row_of, nbr_of = dst, src
    elif direction == "out":
        row_of, nbr_of = src, dst
    else:
        raise ValueError(f"direction must be 'in' or 'out', got {direction!r}")
    deg = np.bincount(row_of, minlength=n)
    width = int(max(1, deg.max() if deg.size else 1))
    width = ((width + block_e - 1) // block_e) * block_e
    n_pad = ((n + block_v - 1) // block_v) * block_v
    nbrs = np.zeros((n_pad, width), dtype=np.int32)
    ws = np.zeros((n_pad, width), dtype=np.float32)
    cs = np.zeros((n_pad, width), dtype=np.float32)
    mask = np.zeros((n_pad, width), dtype=bool)
    slot = np.zeros(n, dtype=np.int64)
    # edges fill their row left to right
    for i in range(src.shape[0]):
        v = row_of[i]
        k = slot[v]
        nbrs[v, k] = nbr_of[i]
        ws[v, k] = w[i]
        cs[v, k] = c[i]
        mask[v, k] = True
        slot[v] = k + 1
    tile_nnz = mask.reshape(n_pad // block_v, block_v,
                            width // block_e, block_e) \
        .sum(axis=(1, 3)).astype(np.int32)
    return BlockedELL(n=n, n_pad=n_pad, width=width,
                      block_v=block_v, block_e=block_e,
                      nbrs=jnp.asarray(nbrs), weight=jnp.asarray(ws),
                      capacity=jnp.asarray(cs), mask=jnp.asarray(mask),
                      tile_nnz=jnp.asarray(tile_nnz), direction=direction)


_ELL_CACHE: dict = {}


def blocked_ell_cached(g: Graph, block_v: int = 8, block_e: int = 128,
                       direction: str = "in") -> BlockedELL:
    """Memoized ``to_blocked_ell``: the padded layout is immutable per graph,
    so repeated queries / rounds / benchmark repeats reuse one conversion.
    The pull ("in") and push ("out") layouts of one graph are separate
    entries, so a direction-optimized executor can hold both at once.

    Keyed on object identity; a weakref guards against id() reuse, and a
    finalizer drops the entry when the graph is garbage-collected so dead
    layouts never pin their padded arrays."""
    key = (id(g), block_v, block_e, direction)
    hit = _ELL_CACHE.get(key)
    if hit is not None:
        ref, ell = hit
        if ref() is g:
            return ell
    ell = to_blocked_ell(g, block_v=block_v, block_e=block_e,
                         direction=direction)
    _ELL_CACHE[key] = (weakref.ref(g), ell)
    weakref.finalize(g, _ELL_CACHE.pop, key, None)
    return ell


# ---------------------------------------------------------------------------
# Synthetic graph generators (seeded, host-side numpy).
# ---------------------------------------------------------------------------

def _dedupe(n, src, dst):
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx]


def rmat_graph(n: int, e: int, seed: int = 0, weighted: bool = True,
               a=0.57, b=0.19, c=0.19) -> Graph:
    """R-MAT power-law generator (Chakrabarti et al.), deduped, no self loops."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_round = 1 << scale
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    for level in range(scale):
        r = rng.random(e)
        right = r >= a + b            # quadrant column
        bottom = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src = src * 2 + bottom
        dst = dst * 2 + right
    src, dst = src % n, dst % n
    src, dst = _dedupe(n, src, dst)
    w = rng.integers(1, 64, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    cap = rng.integers(1, 64, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    return from_edges(int(n), src.astype(np.int32), dst.astype(np.int32), w, cap)


def uniform_graph(n: int, e: int, seed: int = 0, weighted: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=e)
    dst = rng.integers(0, n, size=e)
    src, dst = _dedupe(n, src, dst)
    w = rng.integers(1, 16, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    cap = rng.integers(1, 16, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    return from_edges(int(n), src.astype(np.int32), dst.astype(np.int32), w, cap)


def line_graph(n: int, weighted: bool = False, seed: int = 0) -> Graph:
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 9, size=n - 1).astype(np.float32) if weighted \
        else np.ones(n - 1, np.float32)
    return from_edges(n, src, dst, w, w[::-1].copy())


def grid_graph(rows: int, cols: int, seed: int = 0) -> Graph:
    """4-neighbour mesh, bidirectional edges (MeshGraphNet-style)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    s, d = [], []
    s.append(idx[:, :-1].ravel()); d.append(idx[:, 1:].ravel())
    s.append(idx[:-1, :].ravel()); d.append(idx[1:, :].ravel())
    src = np.concatenate(s + d)   # both directions
    dst = np.concatenate(d + s)
    rng = np.random.default_rng(seed)
    w = rng.random(src.shape[0]).astype(np.float32) + 0.5
    return from_edges(rows * cols, src, dst, w, w)


def cora_like(n: int = 2708, e: int = 10556, d_feat: int = 1433, seed: int = 0):
    """Cora-shaped citation graph + features + labels (synthetic, seeded)."""
    g = uniform_graph(n, e + e // 4, seed=seed, weighted=False)
    rng = np.random.default_rng(seed + 1)
    x = (rng.random((n, d_feat)) < 0.012).astype(np.float32)  # sparse bag-of-words
    y = rng.integers(0, 7, size=n).astype(np.int32)
    return g, jnp.asarray(x), jnp.asarray(y)


def undirected(g: Graph) -> Graph:
    """Symmetrize: add reverse edges (CC in the paper assumes undirected).
    Deduplicates — the dense engine represents edges as an adjacency
    MATRIX, so parallel edges would change non-idempotent reductions
    (PageRank) relative to the edge-list engines."""
    src, dst, w, c = g.host_edges()
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    w2 = np.concatenate([w, w])
    c2 = np.concatenate([c, c])
    key = s2.astype(np.int64) * g.n + d2
    _, idx = np.unique(key, return_index=True)
    return from_edges(g.n, s2[idx], d2[idx], w2[idx], c2[idx])
