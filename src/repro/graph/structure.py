"""Graph containers shared by the GraFS engines and the GNN models.

Edges are stored twice, in destination-sorted order (pull / CSR-style:
``segment_*`` reductions key on ``dst``) and in source-sorted order
(push / CSC-style: frontier-masked scatters key on ``src``).  Both orders
refer to the same logical edge set; per-edge data (weight, capacity) is
carried alongside each order so engines never re-permute at run time.

A ``BlockedELL`` layout additionally pads per-vertex in-degrees to a fixed
width so the Pallas TPU kernel sees fully regular tiles (see DESIGN.md §2).
"""
from __future__ import annotations

import dataclasses
import weakref
from functools import partial
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.guard import GraphValidationError


@dataclasses.dataclass(frozen=True)
class EdgeOrder:
    """One ordering of the edge list plus its per-edge data."""
    src: jnp.ndarray        # [E] int32
    dst: jnp.ndarray        # [E] int32
    weight: jnp.ndarray     # [E] float32
    capacity: jnp.ndarray   # [E] float32


@dataclasses.dataclass(frozen=True)
class Graph:
    n: int
    by_dst: EdgeOrder       # sorted by dst (pull engines)
    by_src: EdgeOrder       # sorted by src (push engines)
    in_deg: jnp.ndarray     # [n] int32
    out_deg: jnp.ndarray    # [n] int32
    w_out_deg: Optional[jnp.ndarray] = None   # [n] float32 Σ outgoing weight

    @property
    def num_edges(self) -> int:
        return int(self.by_dst.src.shape[0])

    def host_edges(self):
        """(src, dst, weight, capacity) as numpy, dst-sorted."""
        e = self.by_dst
        return (np.asarray(e.src), np.asarray(e.dst),
                np.asarray(e.weight), np.asarray(e.capacity))


def _check_edge_arrays(n: int, src, dst, weight, capacity,
                       self_loops: str, duplicates: str):
    """Host-side structural validation of raw edge arrays (the contract of
    every engine: DESIGN.md §12).  Raises ``GraphValidationError``; returns
    a boolean keep-mask when ``self_loops="drop"`` asks for filtering, else
    None."""
    if n < 1:
        raise GraphValidationError(f"graph needs n >= 1 vertices, got {n}")
    for name, a in (("src", src), ("dst", dst)):
        if a.ndim != 1:
            raise GraphValidationError(
                f"{name} must be a 1-d index vector, got shape {a.shape}")
        if not np.issubdtype(a.dtype, np.integer):
            raise GraphValidationError(
                f"{name} must be an integer vector, got dtype {a.dtype}")
    if src.shape != dst.shape:
        raise GraphValidationError(
            f"src/dst length mismatch: {src.shape[0]} vs {dst.shape[0]}")
    if src.size and (src.min() < 0 or src.max() >= n or
                     dst.min() < 0 or dst.max() >= n):
        bad = np.flatnonzero((src < 0) | (src >= n) | (dst < 0) | (dst >= n))
        raise GraphValidationError(
            f"edge endpoints out of range [0, {n}): {bad.size} bad edges, "
            f"first at position {int(bad[0])} "
            f"({int(src[bad[0]])} -> {int(dst[bad[0]])})")
    for name, a in (("weight", weight), ("capacity", capacity)):
        if a.shape != src.shape:
            raise GraphValidationError(
                f"{name} length {a.shape} does not match edge count "
                f"{src.shape}")
        if a.size and not np.isfinite(a).all():
            bad = np.flatnonzero(~np.isfinite(a))
            raise GraphValidationError(
                f"{name} has {bad.size} non-finite entries (NaN/Inf), "
                f"first at edge {int(bad[0])}")
    loops = src == dst
    n_loops = int(loops.sum())
    if n_loops and self_loops == "error":
        raise GraphValidationError(
            f"graph has {n_loops} self-loops under self_loops='error' "
            f"policy, first at edge {int(np.flatnonzero(loops)[0])}")
    if duplicates == "error" and src.size:
        key = src.astype(np.int64) * n + dst
        n_dup = key.size - np.unique(key).size
        if n_dup:
            raise GraphValidationError(
                f"graph has {n_dup} duplicate edges under "
                "duplicates='error' policy")
    if n_loops and self_loops == "drop":
        return ~loops
    return None


def from_edges(n: int, src, dst, weight=None, capacity=None,
               validate: bool = True, self_loops: str = "allow",
               duplicates: str = "allow") -> Graph:
    """Build a Graph from raw edge arrays.

    ``validate`` (default on) runs the host-side structural checks —
    index bounds, dtypes, finite weights/capacities — and the
    ``self_loops`` ("allow" | "drop" | "error") and ``duplicates``
    ("allow" | "error") policies; violations raise a structured
    ``GraphValidationError`` instead of corrupting engine state downstream.
    The generators below pre-dedupe, so their calls keep the default
    allow-all policies."""
    if self_loops not in ("allow", "drop", "error"):
        raise ValueError(f"self_loops must be allow|drop|error, "
                         f"got {self_loops!r}")
    if duplicates not in ("allow", "error"):
        raise ValueError(f"duplicates must be allow|error, got {duplicates!r}")
    src = np.asarray(src)
    dst = np.asarray(dst)
    if src.size == 0:                    # [] defaults to float64; the
        src = src.astype(np.int32)       # zero-edge graph is legal
    if dst.size == 0:
        dst = dst.astype(np.int32)
    e = src.shape[0] if src.ndim else 0
    if weight is None:
        weight = np.ones((e,), dtype=np.float32)
    if capacity is None:
        capacity = np.ones((e,), dtype=np.float32)
    weight = np.asarray(weight, dtype=np.float32)
    capacity = np.asarray(capacity, dtype=np.float32)
    if validate:
        keep = _check_edge_arrays(n, src, dst, weight, capacity,
                                  self_loops, duplicates)
        if keep is not None:
            src, dst = src[keep], dst[keep]
            weight, capacity = weight[keep], capacity[keep]
    src = src.astype(np.int32, copy=False)
    dst = dst.astype(np.int32, copy=False)

    def order(key):
        perm = np.argsort(key, kind="stable")
        return EdgeOrder(src=jnp.asarray(src[perm]), dst=jnp.asarray(dst[perm]),
                         weight=jnp.asarray(weight[perm]),
                         capacity=jnp.asarray(capacity[perm]))

    in_deg = np.bincount(dst, minlength=n).astype(np.int32)
    out_deg = np.bincount(src, minlength=n).astype(np.int32)
    w_out = np.bincount(src, weights=weight.astype(np.float64),
                        minlength=n).astype(np.float32)
    return Graph(n=n, by_dst=order(dst), by_src=order(src),
                 in_deg=jnp.asarray(in_deg), out_deg=jnp.asarray(out_deg),
                 w_out_deg=jnp.asarray(w_out))


@dataclasses.dataclass(frozen=True)
class GraphCheck:
    """Validation summary of one graph: the facts engine entry points need
    to guard a query — value ranges for the termination-precondition probe
    (conditions.violated_preconditions), loop/duplicate counts for
    diagnostics.  Computed once per graph (identity-keyed weakref cache,
    like the layout caches) so per-query serving never re-scans edges."""
    n: int
    num_edges: int
    w_min: float
    w_max: float
    c_min: float
    c_max: float
    self_loops: int
    duplicates: int


_VALID_CACHE: dict = {}


def validate_graph(g: Graph) -> GraphCheck:
    """Validate an already-built Graph and return its ``GraphCheck``.

    Engine entry points (``engine.run_program`` / ``run_direct`` /
    ``run_program_batch``) call this on every query; the O(E) host scan runs
    once per graph and is memoized.  Graphs built by ``from_edges`` with
    ``validate=True`` re-verify here too — cheap, and it catches graphs
    assembled by hand or mutated layouts."""
    key = id(g)
    hit = _VALID_CACHE.get(key)
    if hit is not None:
        ref, chk = hit
        if ref() is g:
            return chk
    src, dst, w, c = g.host_edges()
    _check_edge_arrays(g.n, src, dst, w, c,
                       self_loops="allow", duplicates="allow")
    loops = int((src == dst).sum())
    if src.size:
        key64 = src.astype(np.int64) * g.n + dst
        dups = int(key64.size - np.unique(key64).size)
    else:
        dups = 0
    chk = GraphCheck(
        n=g.n, num_edges=int(src.shape[0]),
        w_min=float(w.min()) if w.size else 0.0,
        w_max=float(w.max()) if w.size else 0.0,
        c_min=float(c.min()) if c.size else 0.0,
        c_max=float(c.max()) if c.size else 0.0,
        self_loops=loops, duplicates=dups)
    _VALID_CACHE[key] = (weakref.ref(g), chk)
    weakref.finalize(g, _VALID_CACHE.pop, key, None)
    return chk


@dataclasses.dataclass(frozen=True)
class GraphStats:
    """Per-graph statistics the query planner resolves knobs from
    (``core.plan.plan_execution``; DESIGN.md §14): size, degree shape
    (average/max out-degree and their ratio — the skew signal that separates
    power-law R-MAT graphs from uniform ones), weight range (weighted
    kernels engage the weighted-degree normalizer and min-plus
    preconditions), and the process's device topology.  Computed from the
    host arrays directly — unlike ``validate_graph`` this never *raises* on
    contract violations, because plans must also resolve for
    ``validate=False`` runs on malformed graphs."""
    n: int
    num_edges: int
    avg_degree: float               # |E| / n (out == in in aggregate)
    max_out_degree: int
    max_in_degree: int
    degree_skew: float              # max_out_degree / avg_degree (≥ 1-ish on
                                    # uniform graphs, ≫ 1 on power-law hubs)
    weighted: bool                  # any edge weight ≠ 1.0
    w_min: float
    w_max: float
    device_count: int               # process-visible accelerator topology
    backend: str


_STATS_CACHE: dict = {}


def graph_stats(g: Graph) -> GraphStats:
    """Memoized per-graph statistics (identity key, weakref-guarded,
    finalizer-evicted like every structure cache) — the planner's input;
    one O(E) host scan per graph, never per query."""
    key = id(g)
    hit = _STATS_CACHE.get(key)
    if hit is not None:
        ref, st = hit
        if ref() is g:
            return st
    import jax
    out_deg = np.asarray(g.out_deg)
    in_deg = np.asarray(g.in_deg)
    w = np.asarray(g.by_dst.weight)
    e = int(w.shape[0])
    avg = e / g.n
    max_out = int(out_deg.max()) if out_deg.size else 0
    st = GraphStats(
        n=g.n, num_edges=e, avg_degree=avg,
        max_out_degree=max_out,
        max_in_degree=int(in_deg.max()) if in_deg.size else 0,
        degree_skew=(max_out / avg) if avg > 0 else 0.0,
        weighted=bool(e and np.any(w != 1.0)),
        w_min=float(w.min()) if e else 0.0,
        w_max=float(w.max()) if e else 0.0,
        device_count=jax.device_count(),
        backend=jax.default_backend())
    _STATS_CACHE[key] = (weakref.ref(g), st)
    weakref.finalize(g, _STATS_CACHE.pop, key, None)
    return st


_WDEG_CACHE: dict = {}


def w_out_deg(g: Graph) -> jnp.ndarray:
    """Weighted out-degree (Σ outgoing edge weight per vertex) as the P
    environment's ``wdeg`` normalizer (weighted PageRank-style kernels).

    Computed host-side ONCE per graph — `from_edges` stores the raw sums on
    the Graph and the clamped device vector is memoized here (identity key,
    weakref-guarded like the layout caches) so per-query serving never pays
    a host round-trip — and shared by every engine: pull segment ops, push
    scatters, dense, distributed, and both pallas sweep directions
    normalize by the bit-identical vector (a per-engine recomputation would
    associate the float sums differently and break the pull ≡ push bitwise
    parity the direct-kernel tests assert).  Vertices with no out-edges
    read 1.0 (the value is only ever consumed on edges *leaving* a vertex,
    so the clamp is unreachable on real slots — it just keeps padding-lane
    arithmetic finite)."""
    key = id(g)
    hit = _WDEG_CACHE.get(key)
    if hit is not None:
        ref, wdeg = hit
        if ref() is g:
            return wdeg
    if g.w_out_deg is not None:
        w = np.asarray(g.w_out_deg, dtype=np.float32)
    else:                                    # legacy Graph built by hand
        src, _dst, wt, _c = g.host_edges()
        w = np.bincount(src, weights=wt.astype(np.float64),
                        minlength=g.n).astype(np.float32)
    wdeg = jnp.asarray(np.where(w > 0, w, np.float32(1.0)))
    _WDEG_CACHE[key] = (weakref.ref(g), wdeg)
    weakref.finalize(g, _WDEG_CACHE.pop, key, None)
    return wdeg


# ---------------------------------------------------------------------------
# Blocked-ELL layout for the Pallas edge kernel.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockedELL:
    """Degree-padded neighbour lists in one direction.

    With ``direction="in"`` (the pull layout) row v holds the predecessors of
    v: ``nbrs[v, k]`` is the k-th *source* of an in-edge of v.  With
    ``direction="out"`` (the push layout) row v holds the successors:
    ``nbrs[v, k]`` is the k-th *destination* of an out-edge of v.  ``mask[v,
    k]`` marks real slots.  ``n_pad`` and ``width`` are multiples of the
    requested tile sizes so a Pallas grid covers the arrays exactly.

    ``tile_nnz[i, j]`` counts the real slots inside grid tile (i, j) for the
    layout's own (block_v, block_e); power-law degree distributions leave most
    tail column-tiles fully padded, and the fused sweeps skip those tiles
    before doing any work (DESIGN.md §2).
    """
    n: int                  # logical vertex count
    n_pad: int
    width: int              # padded max degree (in- or out-, per direction)
    block_v: int            # tile sizes the layout was built for
    block_e: int
    nbrs: jnp.ndarray       # [n_pad, width] int32 neighbour vertex ids
    weight: jnp.ndarray     # [n_pad, width] float32
    capacity: jnp.ndarray   # [n_pad, width] float32
    mask: jnp.ndarray       # [n_pad, width] bool
    tile_nnz: jnp.ndarray   # [n_pad/block_v, width/block_e] int32
    direction: str = "in"   # "in" (rows = dst, pull) | "out" (rows = src, push)

    @property
    def srcs(self) -> jnp.ndarray:
        """Pull-layout alias: with ``direction="in"`` the neighbour ids ARE
        the edge sources (kept for the original pull-sweep call sites).
        Guarded so an out-layout can never leak destination ids under the
        name ``srcs`` into gather-side code."""
        if self.direction != "in":
            raise AttributeError(
                "BlockedELL.srcs is only meaningful on the pull layout "
                f"(direction='in'); this layout is direction={self.direction!r}"
                " — use .nbrs")
        return self.nbrs


def _padded_width(deg: np.ndarray, block_e: int) -> int:
    """Max degree padded up to the slot-tile size — THE width rule of every
    blocked layout (shared with the push-resolution permutation, which must
    agree with the layouts by construction)."""
    width = int(max(1, deg.max() if deg.size else 1))
    return ((width + block_e - 1) // block_e) * block_e


def _fill_order_slots(row_of: np.ndarray, n: int) -> np.ndarray:
    """Per-edge slot index under the left-to-right row fill rule, edges in
    ``host_edges()`` (dst-sorted) order — THE slot assignment of
    ``to_blocked_ell``.  ``to_push_resolution`` replays the same function,
    so the dst-major permutation can never desynchronize from the layouts
    it permutes between."""
    e = row_of.shape[0]
    # vectorized running-count: stable sort groups each row's edges in
    # original order, rank-within-group = position − first occurrence
    perm = np.argsort(row_of, kind="stable")
    sorted_rows = row_of[perm]
    out = np.empty(e, dtype=np.int64)
    out[perm] = np.arange(e, dtype=np.int64) - \
        np.searchsorted(sorted_rows, sorted_rows)
    return out


def to_blocked_ell(g: Graph, block_v: int = 8, block_e: int = 128,
                   direction: str = "in") -> BlockedELL:
    """Build the blocked-ELL layout keyed by dst (``direction="in"``, the
    pull sweep's predecessor lists) or by src (``direction="out"``, the push
    sweep's successor lists).  Both directions carry the same per-edge
    weight/capacity so the synthesized P functions see identical edges."""
    src, dst, w, c = g.host_edges()
    n = g.n
    if direction == "in":
        row_of, nbr_of = dst, src
    elif direction == "out":
        row_of, nbr_of = src, dst
    else:
        raise ValueError(f"direction must be 'in' or 'out', got {direction!r}")
    width = _padded_width(np.bincount(row_of, minlength=n), block_e)
    n_pad = ((n + block_v - 1) // block_v) * block_v
    nbrs = np.zeros((n_pad, width), dtype=np.int32)
    ws = np.zeros((n_pad, width), dtype=np.float32)
    cs = np.zeros((n_pad, width), dtype=np.float32)
    mask = np.zeros((n_pad, width), dtype=bool)
    ks = _fill_order_slots(row_of, n)
    nbrs[row_of, ks] = nbr_of
    ws[row_of, ks] = w
    cs[row_of, ks] = c
    mask[row_of, ks] = True
    tile_nnz = mask.reshape(n_pad // block_v, block_v,
                            width // block_e, block_e) \
        .sum(axis=(1, 3)).astype(np.int32)
    return BlockedELL(n=n, n_pad=n_pad, width=width,
                      block_v=block_v, block_e=block_e,
                      nbrs=jnp.asarray(nbrs), weight=jnp.asarray(ws),
                      capacity=jnp.asarray(cs), mask=jnp.asarray(mask),
                      tile_nnz=jnp.asarray(tile_nnz), direction=direction)


_ELL_CACHE: dict = {}


def blocked_ell_cached(g: Graph, block_v: int = 8, block_e: int = 128,
                       direction: str = "in") -> BlockedELL:
    """Memoized ``to_blocked_ell``: the padded layout is immutable per graph,
    so repeated queries / rounds / benchmark repeats reuse one conversion.
    The pull ("in") and push ("out") layouts of one graph are separate
    entries, so a direction-optimized executor can hold both at once.

    Keyed on object identity; a weakref guards against id() reuse, and a
    finalizer drops the entry when the graph is garbage-collected so dead
    layouts never pin their padded arrays."""
    key = (id(g), block_v, block_e, direction)
    hit = _ELL_CACHE.get(key)
    if hit is not None:
        ref, ell = hit
        if ref() is g:
            return ell
    ell = to_blocked_ell(g, block_v=block_v, block_e=block_e,
                         direction=direction)
    _ELL_CACHE[key] = (weakref.ref(g), ell)
    weakref.finalize(g, _ELL_CACHE.pop, key, None)
    return ell


# ---------------------------------------------------------------------------
# Sharded blocked-ELL layouts for the pallas_sharded engine (DESIGN.md §11).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedELL:
    """Per-shard blocked-ELL layouts of one vertex-cut, stacked on a leading
    shard axis so ``shard_map`` can split them with ``P(axes)``.

    Shard j's slice ``[j]`` is exactly ``to_blocked_ell`` of the j-th
    ``partition.shard_subgraphs`` block — same fill rule, same tile shapes —
    padded on the slot axis to the widest shard (``width`` = max over
    shards, already a multiple of ``block_e``) with masked-out slots, so
    every shard sees identically-shaped arrays (SPMD requires one trace).
    Padding slots carry ``mask=False`` and ``tile_nnz=0`` and therefore
    reduce to identities / skip entirely (C6).

    ``row_deg[j, v]`` counts shard j's real slots in row v — for the
    ``direction="out"`` layout that is v's shard-local out-degree, whose
    ``psum`` over shards reconstructs the global out-degree exactly (integer
    sums): the signal of the GLOBAL Gemini direction switch every shard must
    agree on (DESIGN.md §11)."""
    k: int
    n: int
    n_pad: int
    width: int              # max over shards, padded to block_e
    block_v: int
    block_e: int
    direction: str
    strategy: str
    nbrs: jnp.ndarray       # [k, n_pad, width] int32
    weight: jnp.ndarray     # [k, n_pad, width] float32
    capacity: jnp.ndarray   # [k, n_pad, width] float32
    mask: jnp.ndarray       # [k, n_pad, width] bool
    tile_nnz: jnp.ndarray   # [k, n_pad/block_v, width/block_e] int32
    row_deg: jnp.ndarray    # [k, n_pad] float32 real slots per row
    num_edges: int          # Σ real edges across shards (== graph |E|)


def to_sharded_ell(g: Graph, k: int, strategy: str = "contiguous",
                   block_v: int = 8, block_e: int = 128,
                   direction: str = "in") -> ShardedELL:
    """Build the stacked per-shard blocked-ELL layout of a k-way vertex-cut.

    Each shard's layout is built by the exact single-device rules
    (``to_blocked_ell`` on its ``shard_subgraphs`` block) and then widened to
    the widest shard; a shard's local reduction over its slice is therefore
    bit-identical to a single-device sweep over that shard's edge subset,
    which is what makes the cross-shard monoid combine exact (DESIGN.md
    §11)."""
    from repro.graph.partition import shard_subgraphs  # lazy: partition
    # imports this module at top level
    subs = shard_subgraphs(g, k, strategy)
    ells = [to_blocked_ell(sg, block_v=block_v, block_e=block_e,
                           direction=direction) for sg in subs]
    width = max(e.width for e in ells)
    n_pad = ells[0].n_pad
    n_i, n_j = n_pad // block_v, width // block_e

    def widen(a, fill):
        out = np.full((n_pad, width), fill, dtype=np.asarray(a).dtype)
        out[:, :a.shape[1]] = np.asarray(a)
        return out

    nbrs = np.stack([widen(e.nbrs, 0) for e in ells])
    ws = np.stack([widen(e.weight, 0.0) for e in ells])
    cs = np.stack([widen(e.capacity, 0.0) for e in ells])
    mask = np.stack([widen(e.mask, False) for e in ells])
    tile_nnz = mask.reshape(k, n_i, block_v, n_j, block_e) \
        .sum(axis=(2, 4)).astype(np.int32)
    row_deg = mask.sum(axis=2).astype(np.float32)
    return ShardedELL(
        k=k, n=g.n, n_pad=n_pad, width=width, block_v=block_v,
        block_e=block_e, direction=direction, strategy=strategy,
        nbrs=jnp.asarray(nbrs), weight=jnp.asarray(ws),
        capacity=jnp.asarray(cs), mask=jnp.asarray(mask),
        tile_nnz=jnp.asarray(tile_nnz), row_deg=jnp.asarray(row_deg),
        num_edges=int(mask.sum()))


_SHARDED_ELL_CACHE: dict = {}


def sharded_ell_cached(g: Graph, k: int, strategy: str = "contiguous",
                       block_v: int = 8, block_e: int = 128,
                       direction: str = "in") -> ShardedELL:
    """Memoized ``to_sharded_ell`` — cached per (graph, k, strategy, tile
    shape, direction) exactly like ``blocked_ell_cached`` (identity key,
    weakref-guarded, finalizer-evicted), so repeated sharded queries never
    re-partition or re-pad."""
    key = (id(g), k, strategy, block_v, block_e, direction)
    hit = _SHARDED_ELL_CACHE.get(key)
    if hit is not None:
        ref, ell = hit
        if ref() is g:
            return ell
    ell = to_sharded_ell(g, k, strategy=strategy, block_v=block_v,
                         block_e=block_e, direction=direction)
    _SHARDED_ELL_CACHE[key] = (weakref.ref(g), ell)
    weakref.finalize(g, _SHARDED_ELL_CACHE.pop, key, None)
    return ell


# ---------------------------------------------------------------------------
# Dst-sorted push-resolution layout (DESIGN.md §10).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PushResolution:
    """Dst-major permutation of the out-edge rectangle, as segment metadata.

    The push sweep emits its per-edge candidates at *out-layout* positions
    (rows = sources).  Resolving the dst-keyed reduction used to mean a
    full-rectangle XLA scatter; this layout instead precomputes where each
    out-slot's candidate lands in the **dst-major rectangle** — the same
    `[n_pad, width_in]` shape as the pull layout, where row v is exactly
    the contiguous segment of candidates competing for vertex v and the
    segment boundary IS the row boundary (column tiles are resolved by the
    pull sweep's existing cross-tile fold, so the `plan_merge` contract is
    unchanged).

    ``in2out[v, k]`` — flat index into the out rectangle of the edge that
    is the k-th dst-major candidate slot of v (fill order matches
    ``to_blocked_ell(direction="in")`` slot order, so a reduction over this
    rectangle is bit-identical to the pull sweep's reduction tree).
    ``valid`` marks real slots; ``src_tile[v, k]`` is the flat id of the
    out-layout grid tile owning the slot.  ``contrib[t]`` is the
    precomputed *contributing out-tile list* of resolution tile t (flat
    row-major tile ids, −1 padded to the widest list): the unique out-tiles
    whose candidates land in t.  The per-iteration activity test
    (`edge_reduce.resolution_tile_activity`) ORs the push sweep's frontier
    tile-activity bitmap over these lists — O(tiles·c_max) instead of a
    dense O(n_pad·width) gather over ``src_tile`` — candidates born in a
    skipped out-tile are identities, so their resolution tiles skip too,
    making resolution work frontier-proportional.  ``tile_nnz`` counts real
    slots per resolution tile (the skip test + the work accounting unit).
    """
    n: int
    n_pad: int
    width: int              # dst-major (in-rectangle) padded width
    out_width: int          # the out rectangle's width (gather domain)
    block_v: int
    block_e: int
    in2out: jnp.ndarray     # [n_pad, width] int32 flat out-rectangle index
    valid: jnp.ndarray      # [n_pad, width] bool
    src_tile: jnp.ndarray   # [n_pad, width] int32 flat out-tile id
    tile_nnz: jnp.ndarray   # [n_pad/block_v, width/block_e] int32
    contrib: jnp.ndarray    # [n_tiles, c_max] int32 out-tile ids, −1 pad


def to_push_resolution(g: Graph, block_v: int = 8, block_e: int = 128,
                       min_width: int = 0,
                       min_out_width: int = 0) -> PushResolution:
    """Build the dst-major resolution permutation for the push sweep.

    Slot assignment replays ``_fill_order_slots`` / ``_padded_width`` — the
    exact rules ``to_blocked_ell`` builds both directions with — so the
    correspondence is exact by construction: edge i sits at out-slot
    ``(src[i], k_out)`` and dst-major slot ``(dst[i], k_in)``, and
    ``in2out[dst[i], k_in] = src[i]·width_out + k_out``.

    ``min_width`` / ``min_out_width`` (multiples of ``block_e``) floor the
    padded rectangle widths: the sharded stack widens every shard's
    resolution to the widest shard so the flat ``in2out`` indices address
    the widened out rectangles ``to_sharded_ell`` actually sweeps.  Slot
    assignment never changes — widening only appends padding columns."""
    src, dst, _w, _c = g.host_edges()
    n = g.n
    w_in = max(_padded_width(np.bincount(dst, minlength=n), block_e),
               int(min_width))
    w_out = max(_padded_width(np.bincount(src, minlength=n), block_e),
                int(min_out_width))
    n_pad = ((n + block_v - 1) // block_v) * block_v
    in2out = np.zeros((n_pad, w_in), dtype=np.int64)
    valid = np.zeros((n_pad, w_in), dtype=bool)
    k_out = _fill_order_slots(src, n)
    k_in = _fill_order_slots(dst, n)
    in2out[dst, k_in] = src.astype(np.int64) * w_out + k_out
    valid[dst, k_in] = True
    if n_pad * w_out >= 2 ** 31:
        raise ValueError(
            f"out rectangle {n_pad}×{w_out} overflows int32 flat indices; "
            "the dst-sorted resolution layout needs an int64 gather path "
            "for graphs this hub-heavy")
    n_j_out = w_out // block_e
    out_row = in2out // w_out
    out_col = in2out % w_out
    src_tile = (out_row // block_v) * n_j_out + out_col // block_e
    tile_nnz = valid.reshape(n_pad // block_v, block_v,
                             w_in // block_e, block_e) \
        .sum(axis=(1, 3)).astype(np.int32)
    # Contributing out-tile lists: for each resolution tile, the unique
    # out-layout tiles whose real slots land in it (one host pass over the
    # edges).  Per-edge tile coordinates need no rectangle materialisation:
    # the edge at dst-major slot (dst, k_in) sits in resolution tile
    # (dst//block_v, k_in//block_e) and came from out-tile
    # (src//block_v, k_out//block_e).
    n_j_in = w_in // block_e
    n_tiles = (n_pad // block_v) * n_j_in
    n_out_tiles = (n_pad // block_v) * n_j_out
    r_tile = (dst // block_v).astype(np.int64) * n_j_in + k_in // block_e
    s_tile = (src // block_v).astype(np.int64) * n_j_out + k_out // block_e
    pair = np.unique(r_tile * n_out_tiles + s_tile)
    r_ids = pair // n_out_tiles
    s_ids = pair % n_out_tiles
    counts = np.bincount(r_ids, minlength=n_tiles)
    c_max = int(max(1, counts.max() if counts.size else 1))
    contrib = np.full((n_tiles, c_max), -1, dtype=np.int32)
    # np.unique returns pairs sorted, so r_ids is sorted: rank-within-group
    # via searchsorted, exactly like _fill_order_slots
    slot = np.arange(r_ids.size) - np.searchsorted(r_ids, r_ids)
    contrib[r_ids, slot] = s_ids
    return PushResolution(
        n=n, n_pad=n_pad, width=w_in, out_width=w_out,
        block_v=block_v, block_e=block_e,
        in2out=jnp.asarray(in2out.astype(np.int32)),
        valid=jnp.asarray(valid),
        src_tile=jnp.asarray(src_tile.astype(np.int32)),
        tile_nnz=jnp.asarray(tile_nnz),
        contrib=jnp.asarray(contrib))


_RES_CACHE: dict = {}


def push_resolution_cached(g: Graph, block_v: int = 8,
                           block_e: int = 128) -> PushResolution:
    """Memoized ``to_push_resolution`` — cached per graph exactly like the
    blocked-ELL layouts (identity key, weakref-guarded, finalizer-evicted),
    so the dst-major permutation is built once per graph per tile shape."""
    key = (id(g), block_v, block_e)
    hit = _RES_CACHE.get(key)
    if hit is not None:
        ref, res = hit
        if ref() is g:
            return res
    res = to_push_resolution(g, block_v=block_v, block_e=block_e)
    _RES_CACHE[key] = (weakref.ref(g), res)
    weakref.finalize(g, _RES_CACHE.pop, key, None)
    return res


# ---------------------------------------------------------------------------
# Sharded push-resolution stacks for the pallas_sharded engine (DESIGN.md §11).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ShardedPushResolution:
    """Per-shard dst-sorted resolution layouts of one vertex-cut, stacked on
    a leading shard axis so ``shard_map`` can split them with ``P(axes)``.

    Shard j's slice ``[j]`` is ``to_push_resolution`` of the j-th
    ``partition.shard_subgraphs`` block, built directly against the WIDENED
    rectangle widths (max over shards, the widths ``to_sharded_ell``
    actually sweeps) so the flat ``in2out`` indices address the widened
    out-rectangle candidates without any re-indexing.  A shard-local sorted
    resolve over its slice is therefore bit-identical to a single-device
    sorted resolve over that shard's edge subset, and the cross-shard
    monoid/lex combine contract is unchanged (DESIGN.md §11).  ``contrib``
    slices are −1-padded to the widest shard's list width."""
    k: int
    n: int
    n_pad: int
    width: int              # dst-major width, max over shards
    out_width: int          # out-rectangle width, max over shards
    block_v: int
    block_e: int
    strategy: str
    in2out: jnp.ndarray     # [k, n_pad, width] int32
    valid: jnp.ndarray      # [k, n_pad, width] bool
    src_tile: jnp.ndarray   # [k, n_pad, width] int32
    tile_nnz: jnp.ndarray   # [k, n_pad/block_v, width/block_e] int32
    contrib: jnp.ndarray    # [k, n_tiles, c_max] int32, −1 pad


def to_sharded_push_resolution(g: Graph, k: int, strategy: str = "contiguous",
                               block_v: int = 8,
                               block_e: int = 128) -> ShardedPushResolution:
    """Build the stacked per-shard push-resolution stack of a k-way
    vertex-cut.  The widened widths are computed FIRST (max over shards of
    each shard's own padded widths — the same rule ``to_sharded_ell`` pads
    with) and every shard's permutation is built against them, so in2out is
    valid for the widened out rectangles by construction rather than by a
    fragile post-hoc index fixup."""
    from repro.graph.partition import shard_subgraphs  # lazy (see above)
    subs = shard_subgraphs(g, k, strategy)
    w_in = w_out = 0
    for sub in subs:
        s_src, s_dst, _w, _c = sub.host_edges()
        w_in = max(w_in, _padded_width(np.bincount(s_dst, minlength=sub.n),
                                       block_e))
        w_out = max(w_out, _padded_width(np.bincount(s_src, minlength=sub.n),
                                         block_e))
    rs = [to_push_resolution(sub, block_v=block_v, block_e=block_e,
                             min_width=w_in, min_out_width=w_out)
          for sub in subs]
    c_max = max(r.contrib.shape[1] for r in rs)

    def widen_contrib(c):
        out = np.full((c.shape[0], c_max), -1, dtype=np.int32)
        out[:, :c.shape[1]] = np.asarray(c)
        return out

    return ShardedPushResolution(
        k=k, n=g.n, n_pad=rs[0].n_pad, width=w_in, out_width=w_out,
        block_v=block_v, block_e=block_e, strategy=strategy,
        in2out=jnp.asarray(np.stack([np.asarray(r.in2out) for r in rs])),
        valid=jnp.asarray(np.stack([np.asarray(r.valid) for r in rs])),
        src_tile=jnp.asarray(np.stack([np.asarray(r.src_tile) for r in rs])),
        tile_nnz=jnp.asarray(np.stack([np.asarray(r.tile_nnz) for r in rs])),
        contrib=jnp.asarray(np.stack([widen_contrib(r.contrib) for r in rs])))


_SHARDED_RES_CACHE: dict = {}


def sharded_push_resolution_cached(g: Graph, k: int,
                                   strategy: str = "contiguous",
                                   block_v: int = 8,
                                   block_e: int = 128) -> ShardedPushResolution:
    """Memoized ``to_sharded_push_resolution`` — cached per (graph, k,
    strategy, tile shape) exactly like ``sharded_ell_cached`` (identity key,
    weakref-guarded, finalizer-evicted), so repeated sharded push queries
    never re-partition or re-sort."""
    key = (id(g), k, strategy, block_v, block_e)
    hit = _SHARDED_RES_CACHE.get(key)
    if hit is not None:
        ref, res = hit
        if ref() is g:
            return res
    res = to_sharded_push_resolution(g, k, strategy=strategy,
                                     block_v=block_v, block_e=block_e)
    _SHARDED_RES_CACHE[key] = (weakref.ref(g), res)
    weakref.finalize(g, _SHARDED_RES_CACHE.pop, key, None)
    return res


# Per-graph edge→slot maps maintained by graph.mutate: for a PATCHED graph
# the blocked-ELL slot of each edge is no longer the canonical left-to-right
# fill order, so mutate records the actual (k_in, k_out) per edge (aligned to
# host_edges order) here and chained mutations patch from it.  Same
# (identity key, weakref, finalizer) contract as every other structure cache.
_SLOT_CACHE: dict = {}


def clear_graph_caches(g: Graph) -> int:
    """Drop every cached derived structure of ONE graph — the selective
    counterpart of ``engine.clear_program_caches`` used by the serving
    layer's bounded per-graph cache (DESIGN.md §13): evicting a graph from
    residency frees its blocked-ELL layouts, sharded layouts, push
    resolutions, weighted degrees, validation summary and mutation slot
    maps without disturbing the other resident graphs (or the
    graph-shape-generic compiled executors, which carry no per-graph data).
    Returns the number of entries dropped."""
    dropped = 0
    for cache in (_ELL_CACHE, _SHARDED_ELL_CACHE, _RES_CACHE,
                  _SHARDED_RES_CACHE, _WDEG_CACHE, _VALID_CACHE,
                  _STATS_CACHE, _SLOT_CACHE):
        stale = [k for k, (ref, _) in list(cache.items()) if ref() is g]
        for k in stale:
            if cache.pop(k, None) is not None:
                dropped += 1
    return dropped


# ---------------------------------------------------------------------------
# Synthetic graph generators (seeded, host-side numpy).
# ---------------------------------------------------------------------------

def _dedupe(n, src, dst):
    keep = src != dst  # drop self loops
    src, dst = src[keep], dst[keep]
    key = src.astype(np.int64) * n + dst
    _, idx = np.unique(key, return_index=True)
    return src[idx], dst[idx]


def rmat_graph(n: int, e: int, seed: int = 0, weighted: bool = True,
               a=0.57, b=0.19, c=0.19) -> Graph:
    """R-MAT power-law generator (Chakrabarti et al.), deduped, no self loops."""
    rng = np.random.default_rng(seed)
    scale = int(np.ceil(np.log2(max(n, 2))))
    n_round = 1 << scale
    src = np.zeros(e, dtype=np.int64)
    dst = np.zeros(e, dtype=np.int64)
    for level in range(scale):
        r = rng.random(e)
        right = r >= a + b            # quadrant column
        bottom = ((r >= a) & (r < a + b)) | (r >= a + b + c)
        src = src * 2 + bottom
        dst = dst * 2 + right
    src, dst = src % n, dst % n
    src, dst = _dedupe(n, src, dst)
    w = rng.integers(1, 64, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    cap = rng.integers(1, 64, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    return from_edges(int(n), src.astype(np.int32), dst.astype(np.int32), w, cap)


def uniform_graph(n: int, e: int, seed: int = 0, weighted: bool = True) -> Graph:
    rng = np.random.default_rng(seed)
    src = rng.integers(0, n, size=e)
    dst = rng.integers(0, n, size=e)
    src, dst = _dedupe(n, src, dst)
    w = rng.integers(1, 16, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    cap = rng.integers(1, 16, size=src.shape[0]).astype(np.float32) if weighted \
        else np.ones(src.shape[0], np.float32)
    return from_edges(int(n), src.astype(np.int32), dst.astype(np.int32), w, cap)


def line_graph(n: int, weighted: bool = False, seed: int = 0) -> Graph:
    src = np.arange(n - 1)
    dst = np.arange(1, n)
    rng = np.random.default_rng(seed)
    w = rng.integers(1, 9, size=n - 1).astype(np.float32) if weighted \
        else np.ones(n - 1, np.float32)
    return from_edges(n, src, dst, w, w[::-1].copy())


def grid_graph(rows: int, cols: int, seed: int = 0) -> Graph:
    """4-neighbour mesh, bidirectional edges (MeshGraphNet-style)."""
    idx = np.arange(rows * cols).reshape(rows, cols)
    s, d = [], []
    s.append(idx[:, :-1].ravel()); d.append(idx[:, 1:].ravel())
    s.append(idx[:-1, :].ravel()); d.append(idx[1:, :].ravel())
    src = np.concatenate(s + d)   # both directions
    dst = np.concatenate(d + s)
    rng = np.random.default_rng(seed)
    w = rng.random(src.shape[0]).astype(np.float32) + 0.5
    return from_edges(rows * cols, src, dst, w, w)


def cora_like(n: int = 2708, e: int = 10556, d_feat: int = 1433, seed: int = 0):
    """Cora-shaped citation graph + features + labels (synthetic, seeded)."""
    g = uniform_graph(n, e + e // 4, seed=seed, weighted=False)
    rng = np.random.default_rng(seed + 1)
    x = (rng.random((n, d_feat)) < 0.012).astype(np.float32)  # sparse bag-of-words
    y = rng.integers(0, 7, size=n).astype(np.int32)
    return g, jnp.asarray(x), jnp.asarray(y)


def undirected(g: Graph) -> Graph:
    """Symmetrize: add reverse edges (CC in the paper assumes undirected).
    Deduplicates — the dense engine represents edges as an adjacency
    MATRIX, so parallel edges would change non-idempotent reductions
    (PageRank) relative to the edge-list engines."""
    src, dst, w, c = g.host_edges()
    s2 = np.concatenate([src, dst])
    d2 = np.concatenate([dst, src])
    w2 = np.concatenate([w, w])
    c2 = np.concatenate([c, c])
    key = s2.astype(np.int64) * g.n + d2
    _, idx = np.unique(key, return_index=True)
    return from_edges(g.n, s2[idx], d2[idx], w2[idx], c2[idx])
