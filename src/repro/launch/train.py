"""Training driver: pjit'd train step + fault-tolerant loop + checkpoints.

Usage (CPU smoke scale):
    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \\
        --shape train_4k --steps 20 --smoke

On a real cluster each host runs this same entrypoint; jax.distributed
initializes from the cluster env and the mesh spans all pods
(``--multi-pod``).  The FT driver supplies checkpoint/restart, bounded
retry, straggler detection; restore works across mesh shapes (elastic).
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--host-mesh", action="store_true",
                    help="small mesh over local devices (default off-TPU)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.mesh import make_host_mesh, make_production_mesh
    from repro.launch.workloads import build_workload
    from repro.runtime.ft import FTConfig, FaultTolerantDriver
    from repro.data.tokens import TokenStream
    from repro.data import graphs as dgraphs
    import repro.configs as configs

    if args.host_mesh or jax.default_backend() == "cpu":
        mesh = make_host_mesh(data=min(2, len(jax.devices())), model=1)
    else:
        mesh = make_production_mesh(multi_pod=args.multi_pod)

    wl = build_workload(args.arch, args.shape, mesh, smoke=args.smoke)
    assert wl.kind == "train", f"{args.shape} is not a training shape"
    entry = configs.get(args.arch)
    cfg = entry.smoke() if args.smoke else entry.full()

    # materialize params/opt on the mesh
    key = jax.random.PRNGKey(0)
    p_abs, o_abs, b_abs = wl.abstract_args
    psh, osh, _ = wl.in_shardings
    from repro.models import transformer as tf
    from repro.models import gnn as gnn_mod, dlrm as dlrm_mod
    from repro.optim.adamw import AdamWConfig, adamw_init

    with mesh:
        if entry.family == "lm":
            import dataclasses as dc
            cfg = dc.replace(cfg, hint_axes=tuple(mesh.axis_names))
            init = lambda k: tf.init_params(cfg, k)
        elif entry.family == "gnn":
            init = {"gat": gnn_mod.gat_init, "egnn": gnn_mod.egnn_init,
                    "mgn": gnn_mod.mgn_init,
                    "dimenet": gnn_mod.dimenet_init}[entry.kind]
            init = (lambda f: (lambda k: f(cfg, k)))(init)
        else:
            init = lambda k: dlrm_mod.dlrm_init(cfg, k)
        params = jax.jit(init, out_shardings=psh)(key)
        opt_cfg = AdamWConfig()
        opt_state = jax.jit(lambda p: adamw_init(opt_cfg, p),
                            out_shardings=osh)(params)

        step_jit = jax.jit(wl.step_fn, in_shardings=wl.in_shardings,
                           out_shardings=wl.out_shardings)

    # --- data pipeline ------------------------------------------------------
    if entry.family == "lm":
        bshape = b_abs["tokens"].shape
        stream = TokenStream(vocab=cfg.vocab, batch=bshape[0],
                             seq=bshape[1], seed=17)
        next_batch = stream.next_batch
        data_state, data_restore = stream.state, \
            lambda st: stream.__dict__.update(
                {"seed": int(st["seed"]), "step": int(st["step"])})
    else:
        counter = {"step": 0}

        def next_batch():
            counter["step"] += 1
            if entry.family == "recsys":
                return dgraphs.dlrm_batch(cfg, b_abs["dense"].shape[0],
                                          seed=counter["step"])
            gen = {"gat": lambda: dgraphs.cora_batch(
                       n=b_abs["x"].shape[0], e=b_abs["src"].shape[0],
                       d_feat=cfg.d_in, seed=counter["step"]),
                   "egnn": lambda: dgraphs.egnn_batch(seed=counter["step"]),
                   "mgn": lambda: dgraphs.mesh_batch(seed=counter["step"]),
                   "dimenet": lambda: dgraphs.molecule_batch(
                       seed=counter["step"])}[entry.kind]
            b = gen()
            b.pop("n_graphs", None)
            return b

        data_state = lambda: dict(counter)
        data_restore = lambda st: counter.update(step=int(st["step"]))

    ft = FaultTolerantDriver(
        FTConfig(ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every),
        lambda state, batch: _split(step_jit(state[0], state[1], batch)),
        data_state, data_restore,
        state_shardings=(psh, osh))

    state = (params, opt_state)
    start = 0
    if args.resume:
        try:
            state, start = ft.restore(state)
            print(f"resumed from step {start}")
        except FileNotFoundError:
            pass

    with mesh:
        t0 = time.time()
        state, step, metrics = ft.train(state, args.steps, next_batch,
                                        start_step=start)
        dt = time.time() - t0
    loss = float(metrics["loss"]) if metrics else float("nan")
    print(f"[train] arch={args.arch} shape={args.shape} steps={step} "
          f"loss={loss:.4f} wall={dt:.1f}s "
          f"stragglers={ft.stats.stragglers} retries={ft.stats.retries}")
    return 0


def _split(out):
    params, opt_state, metrics = out
    return (params, opt_state), metrics


if __name__ == "__main__":
    raise SystemExit(main())
