"""Continuous-batching analytics service (DESIGN.md §13).

The deployment story the paper's fusion rules exist for: a long-lived
service holds resident graphs and answers declarative analytics REQUESTS,
and the runtime — not the caller — decides how each request executes:

* **Continuous batching** (LLM-serving style): same-(graph, kind)
  single-source queries share a fixed-slot vmapped batch.  The scheduler
  launches the fused fixpoint in bounded chunks (``chunk_iters`` iterations
  per launch, ``run_program_batch(init_state=..., return_state=True)``);
  converged slots retire with their answers while unconverged queries carry
  their state into the next launch, and queued arrivals join retired slots
  with fresh C1/C2 init rows (``batch_init_state``).  A short query never
  waits for a long one sharing its batch, and a late joiner produces the
  exact bits of a solo run (the idempotent-round unique-fixpoint argument,
  verified by ``verify_sequential``).
* **Cross-kind scalar fusion**: queued scalar requests (radius/drr/ecc
  style r-terms) fuse into ONE round via ``fusion.fuse_many`` — FRPAIR
  pairs the vertex reductions, common-operation elimination dedups shared
  eccentricity sweeps — and every request reads its OWN answer from the
  single execution (no N+1 re-runs).
* **Solo lane**: everything else (multi-round LetRound chains,
  vertex-valued one-offs) runs as a plain ``run_program``.
* **Graph mutation under traffic** (``mutate_graph``, DESIGN.md §15):
  batched edge inserts/deletes drain the graph's in-flight batch lanes
  (queued requests hold), patch the blocked-ELL layouts in place
  (``graph.mutate``), swap the resident graph, and let queued repeat
  queries warm-start from the retired-answer memo — invalidated by
  deletions, whose stale monotone values cannot retract.
* **Bounded graph residency**: an LRU over resident graphs; evicting a
  graph drops exactly its derived layouts via
  ``engine.clear_graph_caches`` (compiled executors are shape-generic and
  stay, bounded by their own LRU), so a service under graph churn holds
  cache memory ∝ ``max_graphs``, verified by ``program_cache_stats``.

Scheduling runs on a **virtual clock**: each launch advances simulated
time by ``launch_overhead_s + iter_cost_s × (max live-slot iterations)``.
Arrivals are an OPEN-loop process (timestamps independent of service
progress — ``open_loop_arrivals``), so queueing pressure is real, yet
every scheduling decision — batch membership, launch counts, occupancy,
virtual latencies — is a deterministic function of the seeded trace and
the graph.  That is what lets CI gate the serving bench on its metrics;
wall-clock latencies are measured too but only ever reported.
"""
from __future__ import annotations

import dataclasses
import time
from collections import OrderedDict, deque
from typing import Callable, Optional

import numpy as np

from repro.core import engine, fusion
from repro.core import lang as L

# virtual service-time model: deterministic stand-ins for device time, so
# the simulated schedule (and every gated metric) reproduces bit-for-bit
# across machines.  One fixpoint iteration costs ITER_COST_S; every launch
# pays LAUNCH_OVERHEAD_S dispatch overhead.
ITER_COST_S = 1e-3
LAUNCH_OVERHEAD_S = 5e-4


@dataclasses.dataclass
class ServiceConfig:
    engine: str = "pallas"
    max_batch: int = 8             # continuous-batch slots per (graph, kind)
    chunk_iters: int = 4           # scheduler quantum: fixpoint iterations
                                   # per launch (small → short queries retire
                                   # fast; large → fewer launch overheads)
    max_scalar_fuse: int = 8       # scalar requests paired per fused round
    max_graphs: int = 4            # resident-graph LRU bound
    iter_cost_s: float = ITER_COST_S
    launch_overhead_s: float = LAUNCH_OVERHEAD_S
    max_chunks_per_query: int = 1000   # scheduler livelock guard
    adaptive: bool = False         # opt into the planner's recorded-stats
                                   # feedback (switch_k/resolution adapt per
                                   # resident graph under live traffic;
                                   # DESIGN.md §14)


@dataclasses.dataclass
class Request:
    """One analytics request.  Either a registered ``kind`` + query
    ``source`` (continuous-batch candidates: BFS/SSSP/WP-style sweeps) or a
    raw ``spec`` term (scalar requests pair via fuse_many; anything else
    runs solo)."""
    rid: int = -1
    kind: Optional[str] = None
    source: Optional[int] = None
    spec: Optional[object] = None
    # filled by the service:
    gname: str = ""
    lane: str = ""                 # "batch" | "scalar" | "solo"
    arrival: float = 0.0           # virtual admission time
    completed: float = 0.0         # virtual completion time
    wall_latency_s: float = 0.0    # wall time submit→answer (reported only)
    value: object = None
    iterations: int = 0
    chunks: int = 0                # chunk launches this request rode
    joined_launch: int = -1        # global launch seq of its first chunk


class _BatchLane:
    """Fixed-slot continuous batch for one (graph, kind): per-slot request,
    per-slot source, and the carried per-component [B, n] fixpoint state."""

    def __init__(self, prog, max_batch):
        self.prog = prog
        self.pending: deque = deque()
        self.slots: list = [None] * max_batch
        self.sources = np.zeros(max_batch, np.int64)
        self.state: Optional[list] = None   # [comp][B, n] carried between
                                            # launches; None ⇒ cold batch

    def live(self):
        return [i for i, r in enumerate(self.slots) if r is not None]

    def busy(self):
        return bool(self.pending) or any(r is not None for r in self.slots)


class _QueueLane:
    def __init__(self):
        self.pending: deque = deque()

    def busy(self):
        return bool(self.pending)


def _fusable_scalar(spec) -> bool:
    """Single-round scalar r-terms pair via fuse_many; LetRound chains and
    vertex-valued terms run solo."""
    return fusion._is_r_term(spec) and not isinstance(spec, L.LetRound)


class AnalyticsService:
    """Admission queues + lane scheduler over resident graphs.

    ``register(kind, spec_fn)`` declares a query shape (``spec_fn(source)``
    → Term); shapes whose fused program passes
    ``engine.batchable_program`` serve through the continuous-batching
    lane, the rest solo.  ``submit`` enqueues, ``step`` executes one
    launch, ``run_open_loop`` drives a whole seeded arrival trace."""

    def __init__(self, config: Optional[ServiceConfig] = None):
        self.cfg = config or ServiceConfig()
        self.clock = 0.0               # virtual seconds
        self._graphs: OrderedDict = OrderedDict()
        self._kinds: dict = {}         # kind -> (spec_fn, prog, batchable)
        self._lanes: OrderedDict = OrderedDict()  # key -> lane
        self._rr = 0                   # round-robin cursor over lane keys
        self._launch_seq = 0
        self.completed: list = []      # finished Requests, completion order
        # counters (all deterministic under the virtual clock)
        self.batch_launches = 0
        self.batch_completed = 0
        self.scalar_rounds = 0
        self.scalar_fused = 0
        self.solo_runs = 0
        self.graph_evictions = 0
        self.total_iterations = 0
        self.mutations = 0             # mutate_graph batches applied
        self.patched_layouts = 0       # blocked-ELL layouts patched in place
        self.rebuilt_layouts = 0       # layouts that fell back to a rebuild
        self.drain_launches = 0        # extra launches spent draining lanes
                                       # before a mutation swapped the graph
        self.warm_joins = 0            # batch joiners seeded from a retired
                                       # answer instead of a cold init row
        self._retired: OrderedDict = OrderedDict()  # (gname, kind, source) ->
                                       # per-component [n] converged state
        self._occupancy: list = []     # live/max per batch launch
        self._wall_t0: Optional[float] = None
        self.wall_s = 0.0

    _RETIRED_MAX = 256                 # retired-answer memo LRU bound

    # ----- graphs (bounded residency, LRU) ---------------------------------

    @property
    def graphs(self):
        return dict(self._graphs)

    def add_graph(self, name: str, g) -> None:
        if name in self._graphs:
            self._graphs.move_to_end(name)
            self._graphs[name] = g
            return
        self._graphs[name] = g
        self._evict_over_capacity()

    def _graph_busy(self, name: str) -> bool:
        return any(lane.busy() for key, lane in self._lanes.items()
                   if key[1] == name)

    def _evict_over_capacity(self) -> None:
        """Evict least-recently-used IDLE graphs down to ``max_graphs``:
        drop the graph's derived-structure caches (clear_graph_caches) and
        its lanes.  Graphs with queued or in-flight work are never evicted
        (capacity is a soft bound under pathological pinning)."""
        while len(self._graphs) > self.cfg.max_graphs:
            victim = None
            names = list(self._graphs)
            for name in names[:-1]:        # newest (just added) is protected
                if not self._graph_busy(name):
                    victim = name
                    break
            if victim is None:
                break
            g = self._graphs.pop(victim)
            engine.clear_graph_caches(g)
            for key in [k for k in self._lanes if k[1] == victim]:
                del self._lanes[key]
            self._drop_retired(victim)
            self._rr = 0
            self.graph_evictions += 1

    # ----- graph mutation (DESIGN.md §15) ----------------------------------

    def _drop_retired(self, gname: str) -> None:
        for key in [k for k in self._retired if k[0] == gname]:
            del self._retired[key]

    def mutate_graph(self, gname: str, insert=None, delete=None, **kw):
        """Apply one batched edge insert/delete to a resident graph under
        live traffic: drain the graph's in-flight batch lanes to completion
        (queued requests stay queued — they join on the MUTATED graph),
        patch the blocked-ELL layouts through ``graph.mutate.mutate_edges``,
        and swap the resident graph.  Queued repeat queries of retired
        (kind, source) answers warm-start from the retired-answer memo —
        bitwise-safe for the idempotent batch-lane rounds under insert-only
        edits (the unique-fixpoint argument), so the memo survives inserts
        and is invalidated by deletions, whose stale values cannot retract.
        Returns the ``MutationDelta``."""
        from repro.graph import mutate as _mutate
        if gname not in self._graphs:
            raise KeyError(f"graph {gname!r} is not resident; add_graph it")
        for key in [k for k in self._lanes if k[0] == "batch"
                    and k[1] == gname]:
            lane = self._lanes[key]
            while lane.live():
                self.drain_launches += 1
                self._step_batch(gname, lane, admit=False)
        old_g = self._graphs[gname]
        new_g, md = _mutate.mutate_edges(old_g, insert=insert, delete=delete,
                                         **kw)
        self._graphs[gname] = new_g
        self._graphs.move_to_end(gname)
        engine.clear_graph_caches(old_g)
        if md.has_deletes:
            self._drop_retired(gname)
        self.mutations += 1
        self.patched_layouts += md.patched_layouts
        self.rebuilt_layouts += md.rebuilt_layouts
        return md

    # ----- registration / admission ----------------------------------------

    def register(self, kind: str, spec_fn: Callable) -> bool:
        """Declare a query shape.  Returns True when it will serve through
        the continuous-batching lane (single idempotent sourced round)."""
        prog = fusion.fuse(spec_fn(0))
        batchable = engine.batchable_program(prog)
        self._kinds[kind] = (spec_fn, prog, batchable)
        return batchable

    def _lane(self, key):
        lane = self._lanes.get(key)
        if lane is None:
            if key[0] == "batch":
                _, prog, _ = self._kinds[key[2]]
                lane = _BatchLane(prog, self.cfg.max_batch)
            else:
                lane = _QueueLane()
            self._lanes[key] = lane
        return lane

    def submit(self, gname: str, req: Request) -> None:
        if gname not in self._graphs:
            raise KeyError(f"graph {gname!r} is not resident; add_graph it")
        self._graphs.move_to_end(gname)    # touch: residency is usage-driven
        req.gname = gname
        req._wall_submit = time.perf_counter()
        if req.kind is not None:
            if req.kind not in self._kinds:
                raise KeyError(f"unregistered request kind {req.kind!r}")
            spec_fn, _, batchable = self._kinds[req.kind]
            if batchable and req.source is not None:
                req.lane = "batch"
                self._lane(("batch", gname, req.kind)).pending.append(req)
                return
            req.spec = spec_fn(req.source)
            req.lane = "solo"
            self._lane(("solo", gname, None)).pending.append(req)
            return
        if req.spec is None:
            raise ValueError("a request needs a registered kind or a spec")
        if _fusable_scalar(req.spec):
            req.lane = "scalar"
            self._lane(("scalar", gname, None)).pending.append(req)
        else:
            req.lane = "solo"
            self._lane(("solo", gname, None)).pending.append(req)

    def _has_work(self) -> bool:
        return any(lane.busy() for lane in self._lanes.values())

    # ----- one scheduling step ---------------------------------------------

    def step(self) -> bool:
        """Execute ONE launch on the next lane with work (round-robin over
        lanes for fairness) and advance the virtual clock.  Returns False
        when every lane is idle."""
        keys = list(self._lanes)
        if not keys:
            return False
        for off in range(len(keys)):
            key = keys[(self._rr + off) % len(keys)]
            lane = self._lanes[key]
            if not lane.busy():
                continue
            self._rr = (self._rr + off + 1) % len(keys)
            if key[0] == "batch":
                return self._step_batch(key[1], lane)
            if key[0] == "scalar":
                return self._step_scalar(key[1], lane)
            return self._step_solo(key[1], lane)
        return False

    def _advance(self, iterations: int) -> None:
        self.clock += (self.cfg.launch_overhead_s
                       + self.cfg.iter_cost_s * int(iterations))

    def _complete(self, req: Request) -> None:
        req.completed = self.clock
        req.wall_latency_s = time.perf_counter() - req._wall_submit
        self.completed.append(req)

    def _step_batch(self, gname: str, lane: _BatchLane,
                    admit: bool = True) -> bool:
        g = self._graphs[gname]
        B = self.cfg.max_batch
        # 1. join: queued arrivals take over free slots with fresh init rows
        # (or a retired answer's converged rows — the repeat-query warm
        # start).  ``admit=False`` is the mutation drain: in-flight slots
        # run to retirement, the queue holds for the mutated graph.
        joiners = []
        if admit:
            for i in range(B):
                if lane.slots[i] is None and lane.pending:
                    req = lane.pending.popleft()
                    lane.slots[i] = req
                    lane.sources[i] = int(req.source)
                    req.joined_launch = self._launch_seq
                    joiners.append(i)
        live = lane.live()
        if not live:
            return False
        kind = lane.slots[live[0]].kind
        memo_hits = {i: self._retired.get((gname, kind,
                                           int(lane.sources[i])))
                     for i in joiners}
        memo_hits = {i: rows for i, rows in memo_hits.items()
                     if rows is not None}
        if lane.state is None and memo_hits:
            # cold lane with a warm joiner: materialize the full carried
            # state so the memo rows have somewhere to splice into
            lane.state = [np.array(r) for r in engine.batch_init_state(
                g, lane.prog, [int(s) for s in lane.sources])]
        if lane.state is None:
            init = None                # cold batch: C1/C2 init from sources
        else:
            cold_joiners = [i for i in joiners if i not in memo_hits]
            if cold_joiners:
                rows = engine.batch_init_state(
                    g, lane.prog,
                    [int(lane.sources[i]) for i in cold_joiners])
                for c in range(len(lane.state)):
                    for j, i in enumerate(cold_joiners):
                        lane.state[c][i] = np.asarray(rows[c][j])
            for i, mrows in memo_hits.items():
                self._retired.move_to_end((gname, kind,
                                           int(lane.sources[i])))
                self.warm_joins += 1
                for c in range(len(lane.state)):
                    lane.state[c][i] = np.array(mrows[c])
            init = tuple(lane.state)
        # 2. one bounded chunk launch; converged slots retire, the rest carry.
        # The service plans ONCE per (graph, kind, hints) — repeated chunk
        # launches of a lane reuse the cached ExecutionPlan (and, with
        # cfg.adaptive, pick up the recorded-stats feedback of this graph).
        plan = engine.plan_execution(
            g, lane.prog, engine=self.cfg.engine, batch=B,
            on_nonconverge="ignore", adaptive=self.cfg.adaptive,
            default_engine="pallas")
        outs, state = engine.run_program_batch(
            g, lane.prog, [int(s) for s in lane.sources],
            max_iter=self.cfg.chunk_iters,
            init_state=init, return_state=True, plan=plan)
        lane.state = [np.array(s) for s in state]   # host copy: splices write
        self._launch_seq += 1
        self.batch_launches += 1
        self._occupancy.append(len(live) / B)
        chunk_iters = 0
        for i in live:
            req = lane.slots[i]
            it = int(outs[i].stats.iterations)
            req.iterations += it
            req.chunks += 1
            chunk_iters = max(chunk_iters, it)
            if req.chunks > self.cfg.max_chunks_per_query:
                raise RuntimeError(
                    f"request {req.rid} ({req.kind}@{req.source}) exceeded "
                    f"{self.cfg.max_chunks_per_query} chunks without "
                    "converging")
        self.total_iterations += chunk_iters
        self._advance(chunk_iters)
        for i in live:
            req = lane.slots[i]
            if outs[i].stats.converged:
                req.value = np.array(np.asarray(outs[i].value))
                self.batch_completed += 1
                self._complete(req)
                # retired-answer memo: the slot's converged per-component
                # state seeds future repeat queries of this (kind, source)
                self._retired[(gname, req.kind, int(lane.sources[i]))] = \
                    [np.array(lane.state[c][i])
                     for c in range(len(lane.state))]
                self._retired.move_to_end((gname, req.kind,
                                           int(lane.sources[i])))
                while len(self._retired) > self._RETIRED_MAX:
                    self._retired.popitem(last=False)
                lane.slots[i] = None
        if not lane.busy():
            lane.state = None          # drained: next arrival cold-starts
        return True

    def _step_scalar(self, gname: str, lane: _QueueLane) -> bool:
        g = self._graphs[gname]
        batch = []
        while lane.pending and len(batch) < self.cfg.max_scalar_fuse:
            batch.append(lane.pending.popleft())
        prog = fusion.fuse_many([(r.rid, r.spec) for r in batch])
        res = engine.run_program(g, prog, engine=self.cfg.engine,
                                 adaptive=self.cfg.adaptive)
        self.scalar_rounds += 1
        self.scalar_fused += len(batch)
        self.total_iterations += int(res.stats.iterations)
        self._advance(res.stats.iterations)
        for r in batch:
            r.value = float(np.asarray(res.value[r.rid]))
            r.iterations = int(res.stats.iterations)
            self._complete(r)
        return True

    def _step_solo(self, gname: str, lane: _QueueLane) -> bool:
        g = self._graphs[gname]
        req = lane.pending.popleft()
        res = engine.run_program(g, fusion.fuse(req.spec),
                                 engine=self.cfg.engine,
                                 adaptive=self.cfg.adaptive)
        self.solo_runs += 1
        self.total_iterations += int(res.stats.iterations)
        self._advance(res.stats.iterations)
        v = np.asarray(res.value)
        req.value = np.array(v) if v.ndim else float(v)
        req.iterations = int(res.stats.iterations)
        self._complete(req)
        return True

    # ----- the open-loop driver --------------------------------------------

    def run_open_loop(self, arrivals) -> dict:
        """Drive a whole arrival trace ([(t, gname, Request)] — see
        ``open_loop_arrivals``) to completion on the virtual clock: admit
        everything due, launch, repeat; idle gaps fast-forward to the next
        arrival.  Returns ``metrics()``."""
        evs = sorted(arrivals, key=lambda e: (e[0], e[2].rid))
        self._wall_t0 = time.perf_counter()
        i = 0
        while i < len(evs) or self._has_work():
            while i < len(evs) and evs[i][0] <= self.clock + 1e-12:
                t, gname, req = evs[i]
                req.arrival = t
                self.submit(gname, req)
                i += 1
            if not self._has_work():
                self.clock = evs[i][0]     # idle: jump to the next arrival
                continue
            self.step()
        self.wall_s = time.perf_counter() - self._wall_t0
        return self.metrics()

    def metrics(self) -> dict:
        """Deterministic serving metrics (virtual clock) + reported-only
        wall numbers.  ``queries_per_launch`` > 1 is the continuous-batching
        win: more than one answer per compiled launch."""
        v_lat = np.array([r.completed - r.arrival for r in self.completed]
                         or [0.0])
        w_lat = np.array([r.wall_latency_s for r in self.completed] or [0.0])
        bl = max(self.batch_launches, 1)
        return {
            "completed": len(self.completed),
            "batch_launches": self.batch_launches,
            "batch_completed": self.batch_completed,
            "queries_per_launch": round(self.batch_completed / bl, 6),
            "occupancy": round(float(np.mean(self._occupancy))
                               if self._occupancy else 0.0, 6),
            "scalar_rounds": self.scalar_rounds,
            "scalar_fused": self.scalar_fused,
            "solo_runs": self.solo_runs,
            "graph_evictions": self.graph_evictions,
            "total_iterations": self.total_iterations,
            "mutations": self.mutations,
            "patched_layouts": self.patched_layouts,
            "rebuilt_layouts": self.rebuilt_layouts,
            "drain_launches": self.drain_launches,
            "warm_joins": self.warm_joins,
            "virtual_s": round(self.clock, 9),
            "v_p50_ms": round(float(np.percentile(v_lat, 50)) * 1e3, 6),
            "v_p99_ms": round(float(np.percentile(v_lat, 99)) * 1e3, 6),
            "v_qps": round(len(self.completed) / self.clock, 3)
            if self.clock > 0 else 0.0,
            # wall numbers: machine-dependent, never gated
            "wall_s": round(self.wall_s, 6),
            "wall_qps": round(len(self.completed) / self.wall_s, 3)
            if self.wall_s > 0 else 0.0,
            "wall_p50_ms": round(float(np.percentile(w_lat, 50)) * 1e3, 3),
            "wall_p99_ms": round(float(np.percentile(w_lat, 99)) * 1e3, 3),
        }


# ---------------------------------------------------------------------------
# Synthetic open-loop arrivals + the bitwise verification oracle.
# ---------------------------------------------------------------------------


def open_loop_arrivals(n_requests: int, rate: float, seed: int,
                       make_request: Callable) -> list:
    """Seeded OPEN-loop arrival trace: exponential interarrival times
    (Poisson process) whose timestamps are independent of service progress —
    a backed-up service keeps receiving work, so queueing pressure (and the
    batching opportunity) is real.  ``make_request(rng, i) -> (gname,
    Request)`` draws each request; the trace is a pure function of the seed,
    which is what makes every downstream scheduling metric CI-gateable.
    Returns [(t, gname, Request)]."""
    rng = np.random.default_rng(seed)
    t = 0.0
    out = []
    for i in range(int(n_requests)):
        t += float(rng.exponential(1.0 / rate))
        gname, req = make_request(rng, i)
        req.rid = i
        out.append((t, gname, req))
    return out


def standard_mix(gname: str, n_vertices: int,
                 batch_kinds=("BFS", "SSSP"), scalar_share: float = 0.25):
    """``make_request`` factory for the serving bench/smoke: a seeded mix
    of single-source sweep queries over the registered ``batch_kinds``
    (random sources — the continuous-batching traffic) and cross-kind
    scalar queries (radius/drr over random vertex pairs — the fuse_many
    traffic)."""
    from repro.core import usecases as U

    def make(rng, i):
        if rng.random() >= scalar_share:
            kind = batch_kinds[int(rng.integers(len(batch_kinds)))]
            return gname, Request(kind=kind,
                                  source=int(rng.integers(n_vertices)))
        a = int(rng.integers(n_vertices))
        b = int(rng.integers(n_vertices))
        spec = U.radius(a, b) if rng.random() < 0.5 else U.drr(a, b)
        return gname, Request(spec=spec)
    return make


def _bitwise_equal(a, b) -> bool:
    a = np.asarray(a)
    b = np.asarray(b)
    return a.shape == b.shape and a.dtype == b.dtype \
        and a.tobytes() == b.tobytes()


def verify_sequential(svc: AnalyticsService, graphs: Optional[dict] = None,
                      engine_name: Optional[str] = None) -> int:
    """Re-run every completed request SOLO (plain ``run_program`` — one
    monolithic, unbatched, unchunked execution per request) and assert each
    service answer is bitwise-identical.  This is the serving layer's
    correctness oracle: continuous batching, chunked warm-resume, slot
    joins and cross-kind scalar fusion must all be invisible in the bits.
    Returns the number of requests checked."""
    graphs = dict(svc.graphs, **(graphs or {}))
    eng = engine_name or svc.cfg.engine
    checked = 0
    for req in svc.completed:
        g = graphs.get(req.gname)
        if g is None:                  # evicted graph without an override
            continue
        if req.lane == "batch":
            _, prog, _ = svc._kinds[req.kind]
            ref = engine.run_program(g, prog, engine=eng,
                                     source=req.source).value
        else:
            ref = engine.run_program(g, fusion.fuse(req.spec),
                                     engine=eng).value
        ref = np.asarray(ref)
        got = np.asarray(req.value)
        if ref.ndim == 0:
            ref = ref.astype(np.float64)
            got = np.asarray(float(got), np.float64)
        if not _bitwise_equal(got, ref):
            raise AssertionError(
                f"request {req.rid} ({req.lane} lane, kind={req.kind!r}, "
                f"source={req.source}) diverged from its solo run")
        checked += 1
    return checked
