import os
if "--xla" not in str(os.environ.get("XLA_FLAGS", "")):
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Dry-run of the PAPER'S OWN workload at production scale: one fused
GraFS iteration (the WSP lexicographic plan — FPNEST's output) over an
ogb_products-scale edge set, vertex-cut across the full mesh, lowered and
compiled on the (16,16) and (2,16,16) meshes.

    PYTHONPATH=src python -m repro.launch.analytics_dryrun [--multi-pod]

This is the shard_map distributed engine (PowerGraph/Gemini analogue) with
abstract inputs: per-shard edge blocks, replicated vertex state, monoid
collectives for the cross-shard lexicographic combine.  Writes
reports/dryrun/<mesh>/grafs-analytics__ogb_scale.json in the same format
as the 40 assigned cells so the roofline table picks it up.
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.compat import shard_map


def build_step(mesh, n, e, max_iter=64):
    """One fused WSP (lex min-length → max-capacity) fixpoint under
    shard_map, abstract-shaped."""
    from repro.core import fusion, iterate, usecases as U
    from repro.core.synthesis import synthesize_round
    from repro.graph import segment

    prog = fusion.fuse(U.wsp(0))
    round_ = prog.rounds[0][1]
    synth = synthesize_round(round_)
    comps = iterate.comp_runtimes(
        round_, {k: v for k, v in synth.items() if not isinstance(k, tuple)})
    plans = [leaf.plan for leaf in round_.leaves]
    comps_by_idx = {cr.idx: cr for cr in comps}
    axes = tuple(mesh.axis_names)
    k_shards = int(np.prod(list(mesh.shape.values())))
    e_loc = -(-e // k_shards)

    def shard_fn(src, dst, w, c, mask, out_deg):
        env = {"w": w, "c": c, "esrc": src, "edst": dst,
               "outdeg": out_deg[src], "nv": jnp.float32(n)}

        def cross_plan(plan, red):
            best = segment.psum_like(plan.op, red[plan.comp], axes)
            out = {plan.comp: best}
            if isinstance(plan, fusion.Lex):
                tie = red[plan.comp] == best
                masked = {j: jnp.where(tie, red[j], comps_by_idx[j].ident)
                          for j in iterate._plan_comps(plan.secondary)}
                out.update(cross_plan(plan.secondary, masked))
            return out

        def body(carry):
            state, active, it = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            evals = iterate._propagate(comps, state, src, env)
            eactive = active[src] & mask
            masked = {i: jnp.where(eactive, evals[i],
                                   comps_by_idx[i].ident) for i in evals}
            red = {}
            for p in plans:
                red.update(iterate.plan_segment_reduce(
                    p, masked, dst, n, comps_by_idx))
            for p in plans:
                red.update(cross_plan(p, red))
            new_d = {}
            for p in plans:
                new_d.update(iterate.plan_merge(p, state_d, red,
                                                comps_by_idx))
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = iterate._changed(comps, new, state, 0.0)
            return new, ch, it + 1

        def cond(carry):
            _, active, it = carry
            return jnp.any(active) & (it < max_iter)

        state0 = iterate._init_state(comps, n)
        state, active, it = jax.lax.while_loop(
            cond, body, (state0, jnp.ones(n, bool), jnp.int32(0)))
        return state, it

    espec = P(axes)
    fn = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(espec, espec, espec, espec, espec, P()),
        out_specs=(tuple(P() for _ in comps), P()),
        check_vma=False)

    args = (
        jax.ShapeDtypeStruct((k_shards * e_loc,), jnp.int32),   # src
        jax.ShapeDtypeStruct((k_shards * e_loc,), jnp.int32),   # dst
        jax.ShapeDtypeStruct((k_shards * e_loc,), jnp.float32),
        jax.ShapeDtypeStruct((k_shards * e_loc,), jnp.float32),
        jax.ShapeDtypeStruct((k_shards * e_loc,), jnp.bool_),
        jax.ShapeDtypeStruct((n,), jnp.int32),                  # out_deg
    )
    shardings = tuple(NamedSharding(mesh, s) for s in
                      (espec, espec, espec, espec, espec, P()))
    return fn, args, shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--n", type=int, default=2_449_029)    # ogb_products
    ap.add_argument("--e", type=int, default=61_859_140)
    ap.add_argument("--out", default="reports/dryrun")
    args = ap.parse_args(argv)

    from repro.launch.dryrun import _mem_dict, _mesh_tag, collective_bytes
    from repro.launch.mesh import make_production_mesh, mesh_devices

    mesh = make_production_mesh(multi_pod=args.multi_pod)
    tag = _mesh_tag(args.multi_pod)
    t0 = time.perf_counter()
    fn, fargs, shardings = build_step(mesh, args.n, args.e)
    with mesh:
        lowered = jax.jit(fn, in_shardings=shardings).lower(*fargs)
        compiled = lowered.compile()
    rec = {"arch": "grafs-analytics", "shape": "ogb_scale", "mesh": tag,
           "status": "ok", "kind": "analytics",
           "devices": mesh_devices(mesh),
           "compile_s": round(time.perf_counter() - t0, 2),
           "meta": {"n": args.n, "e": args.e,
                    # per fixpoint iteration: each edge does P + R
                    "model_flops": 4.0 * args.e},
           "memory_analysis": _mem_dict(compiled)}
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
    except Exception as ex:
        rec["cost_analysis"] = {"error": str(ex)}
    rec["analysis_cost"] = dict(rec["cost_analysis"])
    hlo = compiled.as_text()
    rec["collectives"], rec["collective_top_ops"] = collective_bytes(hlo)
    out_dir = os.path.join(args.out, tag)
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, "grafs-analytics__ogb_scale.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    coll = sum(v["operand_bytes"] for v in rec["collectives"].values())
    print(f"[analytics:{tag}] ok compile={rec['compile_s']}s "
          f"mem={rec['memory_analysis']} coll/chip={coll / 1e9:.2f}GB")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
