import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × shape) cell on
the production meshes and record the roofline inputs.

MUST be imported before any other jax-touching module — the two lines above
run before ANY other import so the 512 placeholder devices exist when jax
initializes.  (Do not set that env var globally: smoke tests and benches
should see 1 device.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --multi-pod      # 512-chip
    PYTHONPATH=src python -m repro.launch.dryrun --list

Each cell writes reports/dryrun/<mesh>/<arch>__<shape>.json with:
    memory_analysis   bytes per device (args/outputs/temps) — proves it fits
    cost_analysis     HLO flops / bytes accessed
    collectives       per-op-kind operand bytes parsed from the SPMD HLO
    meta              model flops, token counts (for §Roofline)
"""
import argparse
import json
import re
import sys
import time
import traceback


def _mesh_tag(multi_pod: bool) -> str:
    return "pod2x16x16" if multi_pod else "pod16x16"


# ---------------------------------------------------------------------------
# HLO collective parsing.
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2,
                "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_COLL_KINDS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_COLL_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"\b(pred|s8|u8|s16|u16|f16|bf16|s32|u32|f32|s64|u64"
                       r"|f64|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_CALLEE_RE = re.compile(r"(?:to_apply|calls)=%?([\w.\-]+)")
_CONST_RE = re.compile(r"\bconstant\((\d+)\)")
_DEF_RE = re.compile(r"^(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[a-z0-9]+\[[^=]*?)\s[a-z][\w\-]*\(")
_NAME_RE = re.compile(r"%([\w.\-]+)")


def _parse_computations(hlo_text: str):
    """Split HLO text into {computation_name: [op lines]} plus a global
    {op_name: shape_string} map; returns (computations, entry, shapes).

    Header lines end with '{' and contain '->' (params may nest parens, so
    the name is just the token before the first '(')."""
    comps, entry, shapes = {}, None, {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and \
                ("(" in stripped):
            head = stripped.split("(")[0].strip()
            is_entry = head.startswith("ENTRY")
            name = head.replace("ENTRY", "").strip().lstrip("%")
            if name:
                cur = name
                comps[cur] = []
                if is_entry:
                    entry = cur
                continue
        if stripped == "}":
            cur = None
            continue
        if cur is not None:
            comps[cur].append(stripped)
            dm = _DEF_RE.match(stripped)
            if dm:
                shapes[dm.group(1)] = dm.group(2)
    return comps, entry, shapes


def _trip_count(cond_lines) -> int:
    """Static trip count of a while condition: the integer constant it
    compares the counter against (scan emits `counter < constant(N)`).
    Dynamic conditions (GraFS fixpoints) have none → multiplier 1."""
    consts = [int(c) for line in cond_lines for c in _CONST_RE.findall(line)]
    return max(consts) if consts else 1


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-kind operand bytes from the post-SPMD module
    (per-device), with while-loop bodies WEIGHTED BY TRIP COUNT — XLA's
    text lists a scan body once, but the collectives inside it run every
    iteration (nested whiles multiply).  Operand shapes are resolved
    through the definition map (optimized HLO omits inline shapes)."""
    comps, entry, shapes = _parse_computations(hlo_text)
    # edges: computation → (callee, multiplier)
    mult = {name: 0 for name in comps}
    if entry is None:
        entry = next(iter(comps), None)
    if entry is not None:
        mult[entry] = 1
    # propagate multipliers (few nesting levels; fixed-point iterate)
    for _ in range(8):
        changed = False
        for name, lines in comps.items():
            m = mult.get(name, 0)
            if m == 0:
                continue
            for line in lines:
                wm = _WHILE_RE.search(line)
                if wm:
                    cond, body = wm.group(1), wm.group(2)
                    trips = _trip_count(comps.get(cond, []))
                    for callee, k in ((body, m * trips), (cond, m)):
                        if mult.get(callee, 0) < k:
                            mult[callee] = k
                            changed = True
                for callee in _CALLEE_RE.findall(line):
                    if callee in comps and mult.get(callee, 0) < m:
                        mult[callee] = m
                        changed = True
        if not changed:
            break

    out = {k: {"count": 0, "operand_bytes": 0} for k in _COLL_KINDS}
    top_ops = []
    for name, lines in comps.items():
        m = max(mult.get(name, 0), 1)
        for line in lines:
            if "-done" in line:
                continue
            cm = _COLL_RE.search(line)
            if not cm:
                continue
            kind = cm.group(1)
            args = line[cm.end():]
            depth, end = 1, 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            ops = args[:end] if end else args
            # inline shapes if present, else resolve operand names
            total = sum(_shape_bytes(d, s)
                        for d, s in _SHAPE_RE.findall(ops))
            if total == 0:
                for opname in _NAME_RE.findall(ops):
                    total += sum(_shape_bytes(d, s) for d, s in
                                 _SHAPE_RE.findall(shapes.get(opname, "")))
            out[kind]["count"] += m
            out[kind]["operand_bytes"] += m * total
            if total:
                shp = _SHAPE_RE.search(line)
                top_ops.append((m * total, kind, m,
                                shp.group(0) if shp else "?", name[:40]))
    top_ops.sort(reverse=True)
    top = [{"bytes": b, "kind": k, "trips": m, "result_shape": s, "comp": c}
           for b, k, m, s, c in top_ops[:12]]
    return out, top


def _mem_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape: str, multi_pod: bool, out_dir: str,
             smoke: bool = False, variant: str = "baseline") -> dict:
    import jax
    import numpy as np
    from repro.launch.mesh import make_production_mesh, mesh_devices
    from repro.launch.workloads import build_workload
    import repro.configs as configs

    skip = configs.skip_reason(arch, shape)
    rec = {"arch": arch, "shape": shape,
           "mesh": _mesh_tag(multi_pod), "status": None}
    if skip:
        rec["status"] = "skipped"
        rec["skip_reason"] = skip
        return rec

    t0 = time.perf_counter()
    mesh = make_production_mesh(multi_pod=multi_pod)
    wl = build_workload(arch, shape, mesh, smoke=smoke, variant=variant)
    rec["kind"] = wl.kind
    rec["meta"] = {k: (int(v) if isinstance(v, (int, np.integer)) else v)
                   for k, v in wl.meta.items()}
    with mesh:
        jitted = jax.jit(wl.step_fn, in_shardings=wl.in_shardings,
                         out_shardings=wl.out_shardings,
                         donate_argnums=wl.donate)
        lowered = jitted.lower(*wl.abstract_args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    rec["lower_s"] = round(t_lower, 2)
    rec["compile_s"] = round(t_compile, 2)
    rec["devices"] = mesh_devices(mesh)
    mem = _mem_dict(compiled)
    rec["memory_analysis"] = mem
    print(f"  memory_analysis: {mem}")
    try:
        ca = compiled.cost_analysis()
        ca = ca[0] if isinstance(ca, (list, tuple)) else ca
        rec["cost_analysis"] = {k: float(v) for k, v in ca.items()
                                if isinstance(v, (int, float))}
    except Exception as ex:                     # pragma: no cover
        rec["cost_analysis"] = {"error": str(ex)}
    print(f"  cost_analysis: flops={rec['cost_analysis'].get('flops')} "
          f"bytes={rec['cost_analysis'].get('bytes accessed')}")
    hlo = compiled.as_text()
    rec["collectives"], rec["collective_top_ops"] = collective_bytes(hlo)
    rec["hlo_bytes"] = len(hlo)

    # exact-FLOP analysis lowering: unrolled loops, single logical device,
    # lower only (never compiled/allocated) — see workloads.build_workload.
    try:
        wl_an = build_workload(arch, shape, mesh, smoke=smoke, analysis=True)
        an_lowered = jax.jit(wl_an.step_fn).lower(*wl_an.abstract_args)
        an = an_lowered.cost_analysis()
        an = an[0] if isinstance(an, (list, tuple)) else an
        rec["analysis_cost"] = {k: float(v) for k, v in an.items()
                                if isinstance(v, (int, float))}
        print(f"  analysis_cost(total): flops={rec['analysis_cost'].get('flops')}")
    except Exception as ex:
        rec["analysis_cost"] = {"error": str(ex)[:500]}
    rec["status"] = "ok"
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced configs (CI sanity, not the deliverable)")
    ap.add_argument("--variant", default="baseline",
                    help="workload variant (e.g. 'dist' for the shard_map "
                         "vertex-cut GNN step)")
    ap.add_argument("--out", default="reports/dryrun")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    from repro.launch.workloads import all_cells

    cells = [(a, s, sk) for (a, s, sk) in all_cells()
             if (args.arch is None or a == args.arch)
             and (args.shape is None or s == args.shape)]
    if args.list:
        for a, s, sk in cells:
            print(f"{a:28s} {s:16s} {'SKIP: ' + sk if sk else ''}")
        return 0

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = 0
    for multi_pod in meshes:
        tag = _mesh_tag(multi_pod)
        out_dir = os.path.join(args.out, tag)
        os.makedirs(out_dir, exist_ok=True)
        for arch, shape, _ in cells:
            path = os.path.join(out_dir, f"{arch}__{shape}.json")
            print(f"[dryrun:{tag}] {arch} × {shape}", flush=True)
            try:
                rec = run_cell(arch, shape, multi_pod, out_dir,
                               smoke=args.smoke, variant=args.variant)
            except Exception:
                rec = {"arch": arch, "shape": shape, "mesh": tag,
                       "status": "error",
                       "error": traceback.format_exc(limit=20)}
                failures += 1
                print(f"  ERROR\n{rec['error']}", flush=True)
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            print(f"  -> {rec['status']} "
                  f"(compile {rec.get('compile_s', '-')}s)", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
