"""Serving driver for the continuous-batching analytics service.

    PYTHONPATH=src python -m repro.launch.analytics --smoke

``--smoke`` runs a small seeded open-loop trace (mixed BFS/SSSP sweep
queries + fused scalar radius/drr queries over an R-MAT graph) through
``repro.launch.service.AnalyticsService``, prints the deterministic
serving metrics, then replays EVERY completed request as a solo
``run_program`` and asserts the service answers are bitwise-identical
(``verify_sequential``) and that continuous batching actually batched
(queries_per_launch > 1).  Exit status is the CI contract.

The production-mesh compile dry-run that used to live at this module path
moved to ``repro.launch.analytics_dryrun``; ``--dryrun`` delegates there
in a subprocess (its XLA host-device flags must be set before jax
imports, so it cannot be imported from an already-initialised process).
"""
from __future__ import annotations

import argparse
import json
import subprocess
import sys


def run_smoke(seed: int = 0, n_requests: int = 24, engine_name: str = "pallas",
              verbose: bool = True) -> dict:
    """The open-loop serving smoke: returns the metrics dict (with the
    bitwise-verification count added) or raises on any violation."""
    from repro.core import usecases as U
    from repro.graph import structure
    from repro.launch import service as S

    g = structure.rmat_graph(192, 768, seed=7, weighted=True)
    cfg = S.ServiceConfig(engine=engine_name, max_batch=4, chunk_iters=3,
                          max_scalar_fuse=6)
    svc = S.AnalyticsService(cfg)
    svc.add_graph("rmat", g)
    svc.register("BFS", U.bfs)
    svc.register("SSSP", U.sssp)

    # arrival rate ~8× the per-chunk virtual service time: enough pressure
    # that batches fill and scalar requests queue up to be paired
    arrivals = S.open_loop_arrivals(
        n_requests, rate=1.0 / (cfg.launch_overhead_s + cfg.iter_cost_s),
        seed=seed, make_request=S.standard_mix("rmat", g.n))
    metrics = svc.run_open_loop(arrivals)

    checked = S.verify_sequential(svc)
    metrics["verified_bitwise"] = checked
    if checked != n_requests:
        raise AssertionError(
            f"verified {checked}/{n_requests} requests — some never "
            "completed or lost their graph")
    if metrics["queries_per_launch"] <= 1.0:
        raise AssertionError(
            "continuous batching did not batch: queries_per_launch = "
            f"{metrics['queries_per_launch']} <= 1")
    if verbose:
        print(f"[analytics --smoke] {json.dumps(metrics, indent=1)}")
        print(f"[analytics --smoke] ok: {checked} answers bitwise-equal to "
              f"solo runs, queries_per_launch="
              f"{metrics['queries_per_launch']}")
    return metrics


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="seeded open-loop serving run + bitwise check")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--engine", default="pallas")
    ap.add_argument("--dryrun", action="store_true",
                    help="delegate to repro.launch.analytics_dryrun")
    args, rest = ap.parse_known_args(argv)

    if args.dryrun:
        return subprocess.call(
            [sys.executable, "-m", "repro.launch.analytics_dryrun"] + rest)
    if rest:
        ap.error(f"unrecognized arguments: {rest}")
    if not args.smoke:
        ap.error("nothing to do: pass --smoke (serving check) or "
                 "--dryrun (mesh compile dry-run)")
    run_smoke(seed=args.seed, n_requests=args.requests,
              engine_name=args.engine)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
