"""Serving driver: prefill + batched decode with a KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-3b --smoke \\
        --prompt-len 16 --decode-steps 8 --batch 2

Production posture: the same prefill/decode step functions the dry-run
lowers for the (16,16) and (2,16,16) meshes, jit'd here on the host mesh.
Requests are batched; decode is one token across the whole batch per step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--decode-steps", type=int, default=8)
    args = ap.parse_args(argv)

    import dataclasses as dc
    import repro.configs as configs
    from repro.launch.mesh import make_host_mesh
    from repro.models import transformer as tf

    entry = configs.get(args.arch)
    assert entry.family == "lm", "serve.py drives LM archs"
    cfg = entry.smoke() if args.smoke else entry.full()

    mesh = make_host_mesh(data=1, model=1)
    key = jax.random.PRNGKey(0)
    params = tf.init_params(cfg, key)
    max_seq = args.prompt_len + args.decode_steps
    max_seq = 1 << (max_seq - 1).bit_length()          # pow2 cache
    cache = tf.init_cache(cfg, args.batch, max_seq)

    prefill = jax.jit(lambda p, t, c: tf.prefill(cfg, p, t, c))
    decode = jax.jit(lambda p, tk, pos, c: tf.decode_step(cfg, p, tk, pos, c))

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)
    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    tok = jnp.argmax(logits, axis=-1)
    out = [tok]
    for i in range(args.decode_steps - 1):
        logits, cache = decode(params, tok,
                               jnp.int32(args.prompt_len + i), cache)
        tok = jnp.argmax(logits, axis=-1)
        out.append(tok)
    toks = jnp.stack(out, axis=1)
    jax.block_until_ready(toks)
    dt = time.time() - t0
    tps = args.batch * args.decode_steps / dt
    print(f"[serve] arch={args.arch} batch={args.batch} "
          f"prompt={args.prompt_len} decoded={args.decode_steps} "
          f"tokens/s={tps:.1f}")
    print("sampled token ids:", toks[0][:8].tolist())
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
