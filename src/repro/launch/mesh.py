"""Production meshes.

Single-pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the
"pod" axis is pure data parallelism over the DCN (gradient all-reduce only),
"data" is in-pod FSDP/batch, "model" is TP/EP.  Functions, not module
constants, so importing this module never touches jax device state.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // max(data, 1)))
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple:
    """Mesh axes the global batch shards over ("pod"+"data" when present)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def mesh_devices(mesh) -> int:
    import numpy as np
    return int(np.prod(list(mesh.shape.values())))
