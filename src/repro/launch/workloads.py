"""Per-(architecture × shape) workload construction for pjit.

``build_workload(arch, shape, mesh)`` returns everything the dry-run,
trainer and server need:

  step_fn           the pure function to jit (train_step / serve_step / …)
  abstract_args     ShapeDtypeStruct pytree (weak-type-correct, shardable,
                    never allocated)
  in_shardings / out_shardings   NamedSharding pytrees
  donate            arg indices safe to donate (params/opt/cache)
  meta              roofline bookkeeping (model flops, token counts, …)

Sharding strategy (DESIGN.md §5):
  * params: FSDP rows over "data" × TP columns/heads/experts over "model";
    replicated over "pod" (pure DP on the DCN — gradient all-reduce only).
  * LM batch: global batch over ("pod","data").
  * KV caches: batch over ("pod","data"), sequence over "model"
    (kv-head counts like 8 don't divide a 16-way model axis; the sequence
    axis always does).  long_500k (batch=1) shards the sequence over EVERY
    axis.
  * GNN: edges over the whole mesh (vertex-cut), node features over
    ("pod","data") rows and the feature dim over "model".
  * DLRM: embedding tables row-sharded over "model"; batch over
    ("pod","data").
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.configs as configs
from repro.compat import shard_map
from repro.launch.mesh import batch_axes, mesh_devices
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

F32, I32 = jnp.float32, jnp.int32


@dataclasses.dataclass
class Workload:
    arch: str
    shape: str
    kind: str                   # train | prefill | decode | serve | retrieval
    step_fn: Callable
    abstract_args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple
    meta: dict


def _sanitize_spec(mesh, spec: P, shape) -> P:
    """Drop (or shorten) per-dim axis assignments that don't divide the dim.

    jit argument shardings must divide evenly; e.g. 24 attention heads can't
    split 16 ways, and a batch of 1 can't split at all.  For tuple
    assignments, fall back to the longest dividing prefix: ("pod","data")
    over batch 32 with pod·data=32 stays, over batch 16 becomes ("pod",).
    """
    new = []
    for i, dim in enumerate(shape):
        ax = spec[i] if i < len(spec) else None
        if ax is None:
            new.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        keep = None
        for k in range(len(axes), 0, -1):
            size = int(np.prod([mesh.shape[a] for a in axes[:k]]))
            if dim % size == 0:
                keep = axes[:k] if k > 1 else axes[0]
                break
        new.append(keep)
    return P(*new)


def _shard_tree(mesh, spec_tree, abs_tree=None):
    """Spec tree → NamedSharding tree, sanitized against the abstract
    shapes when given."""
    if abs_tree is None:
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                            is_leaf=lambda x: isinstance(x, P))

    def one(s, a):
        return NamedSharding(mesh, _sanitize_spec(mesh, s, a.shape))

    flat_a, tdef = jax.tree.flatten(abs_tree)
    flat_s = tdef.flatten_up_to(spec_tree)
    return tdef.unflatten([one(s, a) for s, a in zip(flat_s, flat_a)])


def _abstract(fn, *args):
    return jax.eval_shape(fn, *args)


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _opt_specs(param_spec_tree):
    return {"m": param_spec_tree, "v": param_spec_tree, "step": P()}


def _lm_remap(cfg):
    """Production LM configs keep bf16 params/compute; nothing to remap —
    hook kept for per-shape dtype overrides."""
    return cfg


# ---------------------------------------------------------------------------
# LM workloads
# ---------------------------------------------------------------------------

def _lm_workload(arch: str, shape_name: str, shape: dict, mesh,
                 smoke: bool = False, analysis: bool = False,
                 variant: str = "baseline") -> Workload:
    entry = configs.get(arch)
    cfg = entry.smoke() if smoke else _lm_remap(entry.full())
    dp_all = int(np.prod([mesh.shape[a] for a in batch_axes(mesh)]))
    cfg = dataclasses.replace(cfg, hint_axes=tuple(mesh.axis_names),
                              moe_groups=dp_all)
    if variant == "kvq" and shape["kind"] in ("decode", "prefill"):
        cfg = dataclasses.replace(cfg, kv_quant=True)
    if analysis:
        # exact-FLOP lowering: unroll layer/KV loops (XLA cost_analysis
        # counts while bodies once), one KV tile (same math/FLOPs), no
        # sharding constraints (lowered single-device, no mesh context)
        cfg = dataclasses.replace(cfg, loop_impl="unroll", kv_chunk=1 << 30,
                                  hint_axes=())
    bat = batch_axes(mesh)
    key = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda k: tf.init_params(cfg, k), key)
    pspec = tf.param_specs(cfg)
    psh = _shard_tree(mesh, pspec, params_abs)
    seq, batch = shape["seq"], shape["batch"]
    if smoke:
        seq, batch = min(seq, 64), min(batch, 4)

    meta = {"params": cfg.param_count(),
            "active_params": cfg.active_param_count(),
            "tokens": batch * seq if shape["kind"] != "decode" else batch,
            "seq": seq, "batch": batch}

    if shape["kind"] == "train":
        opt_cfg = AdamWConfig(
            state_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32")
        opt_abs = _abstract(lambda p: adamw_init(opt_cfg, p), params_abs)
        osh = _shard_tree(mesh, _opt_specs(pspec), opt_abs)
        batch_abs = {"tokens": _sds((batch, seq), I32),
                     "targets": _sds((batch, seq), I32)}
        bsh = _shard_tree(mesh, {"tokens": P(bat, None),
                                 "targets": P(bat, None)}, batch_abs)

        # microbatching (gradient accumulation): cap the live activation
        # stack at ~8k local tokens per microbatch — the remat stack is the
        # dominant HBM term at 4k×256 (DESIGN.md §Perf).  Analysis mode
        # runs n_micro=1 (same total FLOPs: attention is batch-diagonal).
        dp = int(np.prod([mesh.shape[a] for a in bat]))
        local_b = max(batch // dp, 1)
        n_micro = 1
        if not (smoke or analysis):
            target = max(1, (local_b * seq + 8191) // 8192)
            n_micro = max(d for d in range(1, local_b + 1)
                          if local_b % d == 0 and d <= target)
        meta["n_micro"] = n_micro

        def train_step(params, opt_state, b):
            if n_micro == 1:
                loss, grads = jax.value_and_grad(
                    lambda p: tf.loss_fn(cfg, p, b))(params)
            else:
                # strided split (row i goes to micro i%n) so each device
                # contributes rows to every microbatch — no resharding
                def split(x):
                    y = x.reshape((x.shape[0] // n_micro, n_micro)
                                  + x.shape[1:])
                    y = jnp.swapaxes(y, 0, 1)
                    spec = P(None, bat, *([None] * (y.ndim - 2)))
                    return jax.lax.with_sharding_constraint(
                        y, NamedSharding(mesh, _sanitize_spec(
                            mesh, spec, y.shape)))

                mb = jax.tree.map(split, b)

                def micro(carry, one):
                    gsum, lsum = carry
                    l, g = jax.value_and_grad(
                        lambda p: tf.loss_fn(cfg, p, one))(params)
                    # §Perf A4: pin the raw (bf16) grads to the param
                    # sharding BEFORE the f32 accumulate — the per-micro
                    # cross-"data" grad reduction then runs on bf16
                    # operands (half the bytes of reducing the f32 sum)
                    g = jax.tree.map(
                        lambda x, s: jax.lax.with_sharding_constraint(x, s),
                        g, psh)
                    gsum = jax.tree.map(
                        lambda a, x: a + x.astype(jnp.float32), gsum, g)
                    # keep the f32 accumulator sharded exactly like the
                    # params (unconstrained, GSPMD replicates it)
                    gsum = jax.tree.map(
                        lambda a, s: jax.lax.with_sharding_constraint(a, s),
                        gsum, psh)
                    return (gsum, lsum + l), None

                zeros = jax.tree.map(
                    lambda x: jnp.zeros(x.shape, jnp.float32), params)
                (gsum, lsum), _ = jax.lax.scan(
                    micro, (zeros, jnp.float32(0.0)), mb)
                grads = jax.tree.map(lambda g: g / n_micro, gsum)
                loss = lsum / n_micro
            params, opt_state, m = adamw_update(opt_cfg, params, grads,
                                                opt_state)
            return params, opt_state, {"loss": loss, **m}

        # MODEL_FLOPS = 6·N_active·D tokens (fwd+bwd)
        meta["model_flops"] = 6 * cfg.active_param_count() * batch * seq
        return Workload(arch, shape_name, "train", train_step,
                        (params_abs, opt_abs, batch_abs),
                        (psh, osh, bsh), (psh, osh, None), (0, 1), meta)

    # serving shapes --------------------------------------------------------
    seq_sharded = batch == 1                       # long_500k: shard the seq
    cache_abs = _abstract(
        lambda: tf.init_cache(cfg, batch, seq))
    csp = _cache_specs(cfg, bat, seq_sharded, cache_abs)
    csh = _shard_tree(mesh, csp, cache_abs)

    if shape["kind"] == "prefill":
        toks_abs = _sds((batch, seq), I32)
        tsh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat, None),
                                                  (batch, seq)))

        def prefill_step(params, tokens, cache):
            return tf.prefill(cfg, params, tokens, cache)

        meta["model_flops"] = 2 * cfg.active_param_count() * batch * seq
        lsh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat, None),
                                                  (batch, cfg.vocab)))
        return Workload(arch, shape_name, "prefill", prefill_step,
                        (params_abs, toks_abs, cache_abs),
                        (psh, tsh, csh), (lsh, csh), (2,), meta)

    # decode: one new token against a seq-long cache
    tok_abs = _sds((batch,), I32)
    tok_sh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat), (batch,)))
    pos_abs = _sds((), I32)

    def serve_step(params, token, pos, cache):
        return tf.decode_step(cfg, params, token, pos, cache)

    meta["model_flops"] = 2 * cfg.active_param_count() * batch
    lsh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat, None),
                                              (batch, cfg.vocab)))
    return Workload(arch, shape_name, "decode", serve_step,
                    (params_abs, tok_abs, pos_abs, cache_abs),
                    (psh, tok_sh, NamedSharding(mesh, P()), csh),
                    (lsh, csh), (3,), meta)


def _cache_specs(cfg, bat, seq_sharded: bool, cache_abs):
    """Cache sharding by leaf rank: [L, B, S, ...] — batch over the data
    axes, sequence over "model" (or over everything for batch=1 streams).
    Rank-driven so int8-quantization scale arrays [L,B,S,H] get the same
    prefix treatment as their [L,B,S,H,D] payloads."""
    all_ax = bat + ("model",)

    def one(leaf):
        nd = leaf.ndim
        if seq_sharded:
            prefix = [None, None, all_ax]
        else:
            prefix = [None, bat, "model"]
        return P(*(prefix + [None] * (nd - 3)))

    return jax.tree.map(one, cache_abs)


# ---------------------------------------------------------------------------
# GNN workloads
# ---------------------------------------------------------------------------

def _gnn_sizes(shape: dict, smoke: bool):
    n, e = shape["n"], shape["e"]
    if shape["kind"] == "sample":
        from repro.graph.sampler import max_nodes_for
        bn, fan = shape["batch_nodes"], shape["fanout"]
        if smoke:
            bn, fan = 8, (3, 2)
        n = max_nodes_for(bn, list(fan))
        e = sum(bn * int(np.prod(fan[:i + 1])) for i in range(len(fan)))
    elif shape["kind"] == "batch":
        n = shape["n"] * shape["batch"]
        e = shape["e"] * shape["batch"]
    if smoke:
        n, e = min(n, 256), min(e, 1024)
    return n, e


def _gnn_batch_abs(kind: str, cfg, shape: dict, n: int, e: int,
                   smoke: bool) -> tuple:
    d_feat = shape.get("d_feat", 16)
    ng = shape.get("batch", 32) if shape["kind"] == "batch" else \
        max(1, n // 30)
    if kind == "gat":
        return ({"x": _sds((n, cfg.d_in), F32), "src": _sds((e,), I32),
                 "dst": _sds((e,), I32), "y": _sds((n,), I32)},
                {"x": P(("data",), None), "src": P(("data",)),
                 "dst": P(("data",)), "y": P(("data",))})
    if kind == "egnn":
        return ({"feats": _sds((n, cfg.d_in), F32),
                 "coords": _sds((n, 3), F32),
                 "src": _sds((e,), I32), "dst": _sds((e,), I32),
                 "graph_id": _sds((n,), I32), "target": _sds((ng,), F32)},
                {"feats": P(("data",), None), "coords": P(("data",), None),
                 "src": P(("data",)), "dst": P(("data",)),
                 "graph_id": P(("data",)), "target": P()})
    if kind == "mgn":
        return ({"node_x": _sds((n, cfg.d_node_in), F32),
                 "edge_x": _sds((e, cfg.d_edge_in), F32),
                 "src": _sds((e,), I32), "dst": _sds((e,), I32),
                 "target": _sds((n, cfg.d_out), F32)},
                {"node_x": P(("data",), None), "edge_x": P(("data",), None),
                 "src": P(("data",)), "dst": P(("data",)),
                 "target": P(("data",), None)})
    if kind == "dimenet":
        avg_deg = max(1, min(e // max(n, 1), 32))
        t = min(e * avg_deg, 2_000_000_000 // 8)          # wedge count
        if smoke:
            t = min(t, 4096)
        return ({"species": _sds((n,), I32), "coords": _sds((n, 3), F32),
                 "src": _sds((e,), I32), "dst": _sds((e,), I32),
                 "t_kj": _sds((t,), I32), "t_ji": _sds((t,), I32),
                 "graph_id": _sds((n,), I32), "target": _sds((ng,), F32)},
                {"species": P(("data",)), "coords": P(("data",), None),
                 "src": P(("data",)), "dst": P(("data",)),
                 "t_kj": P(("data",)), "t_ji": P(("data",)),
                 "graph_id": P(("data",)), "target": P()})
    raise ValueError(kind)


_GNN_LOSS = {"gat": gnn_mod.gat_loss, "egnn": gnn_mod.egnn_loss,
             "mgn": gnn_mod.mgn_loss, "dimenet": gnn_mod.dimenet_loss}
_GNN_INIT = {"gat": gnn_mod.gat_init, "egnn": gnn_mod.egnn_init,
             "mgn": gnn_mod.mgn_init, "dimenet": gnn_mod.dimenet_init}
_GNN_SPECS = {"gat": gnn_mod.gat_specs, "egnn": gnn_mod.egnn_specs,
              "mgn": gnn_mod.mgn_specs, "dimenet": gnn_mod.dimenet_specs}


def _gnn_dist_workload(arch, shape_name, shape, mesh, smoke):
    """Hillclimb B generalized: shard_map dst-block vertex-cut for the
    full-graph GNN cells (models.gnn.{mgn,egnn}_forward_dist) — local
    scatters, one node-state all-gather per layer, gradient psum."""
    entry = configs.get(arch)
    kind = entry.kind
    cfg = entry.smoke() if smoke else entry.full()
    n, e = _gnn_sizes(shape, smoke)
    axes = tuple(mesh.axis_names)
    k = mesh_devices(mesh)
    n_loc = -(-n // k)
    e_pad = max(1, int(math.ceil(e * 1.3 / k)))

    key = jax.random.PRNGKey(0)
    init = {"mgn": gnn_mod.mgn_init, "egnn": gnn_mod.egnn_init}[kind]
    loss = {"mgn": gnn_mod.mgn_loss_dist,
            "egnn": gnn_mod.egnn_loss_dist}[kind]
    params_abs = _abstract(lambda k_: init(cfg, k_), key)
    # params replicated inside shard_map (MLPs are small); grads psum'd
    psh = jax.tree.map(lambda _: NamedSharding(mesh, P()), params_abs)
    opt_cfg = AdamWConfig()
    opt_abs = _abstract(lambda p: adamw_init(opt_cfg, p), params_abs)
    osh = jax.tree.map(lambda _: NamedSharding(mesh, P()), opt_abs)

    batch_abs = {"src": _sds((k * e_pad,), I32),
                 "dst": _sds((k * e_pad,), I32),
                 "emask": _sds((k * e_pad,), jnp.bool_),
                 "nmask": _sds((k * n_loc,), jnp.bool_)}
    if kind == "mgn":
        batch_abs.update(
            node_x=_sds((k * n_loc, cfg.d_node_in), F32),
            edge_x=_sds((k * e_pad, cfg.d_edge_in), F32),
            target=_sds((k * n_loc, cfg.d_out), F32))
    else:
        batch_abs.update(
            feats=_sds((k * n_loc, cfg.d_in), F32),
            coords=_sds((k * n_loc, 3), F32),
            target=_sds((k * n_loc, cfg.d_out), F32))

    def shard_fn(params, opt_state, batch):
        l, grads = jax.value_and_grad(
            lambda p: loss(cfg, p, batch, axes))(params)
        grads = jax.lax.psum(grads, axes)
        params, opt_state, m = adamw_update(opt_cfg, params, grads,
                                            opt_state)
        return params, opt_state, {"loss": l, **m}

    rep = P()
    bspecs = {k_: P(axes, None) if v.ndim == 2 else P(axes)
              for k_, v in batch_abs.items()}
    bsh = {k_: NamedSharding(mesh, sp) for k_, sp in bspecs.items()}
    step = shard_map(
        shard_fn, mesh=mesh,
        in_specs=(jax.tree.map(lambda _: rep, params_abs),
                  jax.tree.map(lambda _: rep, opt_abs), bspecs),
        out_specs=(jax.tree.map(lambda _: rep, params_abs),
                   jax.tree.map(lambda _: rep, opt_abs),
                   {"loss": rep, "grad_norm": rep, "lr": rep}),
        check_vma=False)

    meta = {"n": n, "e": e, "variant": "dist",
            "model_flops": _gnn_model_flops(kind, cfg, n, e, batch_abs)}
    return Workload(arch, shape_name, "train", step,
                    (params_abs, opt_abs, batch_abs),
                    (psh, osh, bsh), (psh, osh, None), (0, 1), meta)


def _gnn_workload(arch: str, shape_name: str, shape: dict, mesh,
                  smoke: bool = False) -> Workload:
    entry = configs.get(arch)
    cfg = entry.smoke() if smoke else entry.full()
    kind = entry.kind
    if kind == "gat":
        cfg = dataclasses.replace(cfg, d_in=shape.get("d_feat", cfg.d_in))
    n, e = _gnn_sizes(shape, smoke)
    bat = batch_axes(mesh)

    key = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda k: _GNN_INIT[kind](cfg, k), key)
    pspec = _GNN_SPECS[kind](cfg)
    psh = _shard_tree(mesh, pspec, params_abs)
    opt_cfg = AdamWConfig()
    opt_abs = _abstract(lambda p: adamw_init(opt_cfg, p), params_abs)
    osh = _shard_tree(mesh, _opt_specs(pspec), opt_abs)

    batch_abs, bspec = _gnn_batch_abs(kind, cfg, shape, n, e, smoke)
    # remap the data axis to include the pod axis when present
    bspec = jax.tree.map(
        lambda s: P(*[bat if ax == ("data",) or ax == "data" else ax
                      for ax in s]),
        bspec, is_leaf=lambda x: isinstance(x, P))
    bsh = _shard_tree(mesh, bspec, batch_abs)
    loss = _GNN_LOSS[kind]

    def train_step(params, opt_state, b):
        l, grads = jax.value_and_grad(lambda p: loss(cfg, p, b))(params)
        params, opt_state, m = adamw_update(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": l, **m}

    meta = {"n": n, "e": e,
            "model_flops": _gnn_model_flops(kind, cfg, n, e, batch_abs)}
    return Workload(arch, shape_name, "train", train_step,
                    (params_abs, opt_abs, batch_abs),
                    (psh, osh, bsh), (psh, osh, None), (0, 1), meta)


def _gnn_model_flops(kind, cfg, n, e, batch_abs) -> float:
    """Hand-derived useful FLOPs (fwd+bwd ≈ 3× fwd matmul flops)."""
    if kind == "gat":
        total, d_in = 0, cfg.d_in
        for li in range(cfg.n_layers):
            last = li == cfg.n_layers - 1
            h = 1 if last else cfg.n_heads
            d_out = cfg.n_classes if last else cfg.d_hidden
            total += 2 * n * d_in * h * d_out + 6 * e * h
            d_in = d_out if last else h * d_out
        return 3 * total
    if kind == "egnn":
        d = cfg.d_hidden
        per_layer = 2 * e * (2 * d + 1) * d + 2 * e * d * d * 2 + 2 * n * 2 * d * d
        return 3 * cfg.n_layers * per_layer
    if kind == "mgn":
        d = cfg.d_hidden
        per_layer = 2 * e * (3 * d) * d + 2 * e * d * d + 2 * n * (2 * d) * d + 2 * n * d * d
        return 3 * cfg.n_layers * per_layer
    if kind == "dimenet":
        d = cfg.d_hidden
        t = batch_abs["t_kj"].shape[0]
        per_block = (2 * e * d * d                      # w_kj
                     + 2 * t * d * cfg.n_bilinear * d   # bilinear
                     + 2 * e * d * d * 2 + 2 * e * d * d)
        return 3 * cfg.n_blocks * per_block
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# DLRM workloads
# ---------------------------------------------------------------------------

def _dlrm_workload(arch: str, shape_name: str, shape: dict, mesh,
                   smoke: bool = False) -> Workload:
    entry = configs.get(arch)
    cfg = entry.smoke() if smoke else entry.full()
    bat = batch_axes(mesh)
    batch = shape["batch"]
    if smoke:
        batch = min(batch, 32)
    key = jax.random.PRNGKey(0)
    params_abs = _abstract(lambda k: dlrm_mod.dlrm_init(cfg, k), key)
    pspec = dlrm_mod.dlrm_specs(cfg)
    psh = _shard_tree(mesh, pspec, params_abs)

    dense_abs = _sds((batch, cfg.n_dense), F32)
    sparse_abs = _sds((batch, cfg.n_sparse), I32)
    dsh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat, None),
                                             (batch, cfg.n_dense)))
    ssh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat, None),
                                             (batch, cfg.n_sparse)))
    meta = {"params": cfg.param_count(), "batch": batch}

    if shape["kind"] == "train":
        opt_cfg = AdamWConfig()
        opt_abs = _abstract(lambda p: adamw_init(opt_cfg, p), params_abs)
        osh = _shard_tree(mesh, _opt_specs(pspec), opt_abs)
        batch_abs = {"dense": dense_abs, "sparse": sparse_abs,
                     "label": _sds((batch,), F32)}
        bsh = {"dense": dsh, "sparse": ssh,
               "label": NamedSharding(mesh, _sanitize_spec(
                   mesh, P(bat), (batch,)))}

        def train_step(params, opt_state, b):
            l, grads = jax.value_and_grad(
                lambda p: dlrm_mod.dlrm_loss(cfg, p, b))(params)
            params, opt_state, m = adamw_update(opt_cfg, params, grads,
                                                opt_state)
            return params, opt_state, {"loss": l, **m}

        meta["model_flops"] = 3 * batch * _dlrm_dense_flops(cfg)
        return Workload(arch, shape_name, "train", train_step,
                        (params_abs, opt_abs, batch_abs),
                        (psh, osh, bsh), (psh, osh, None), (0, 1), meta)

    if shape["kind"] == "serve":
        def serve_step(params, dense, sparse):
            return dlrm_mod.dlrm_forward(cfg, params, dense, sparse)

        meta["model_flops"] = batch * _dlrm_dense_flops(cfg)
        out_sh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat), (batch,)))
        return Workload(arch, shape_name, "serve", serve_step,
                        (params_abs, dense_abs, sparse_abs),
                        (psh, dsh, ssh), out_sh, (), meta)

    # retrieval: score batch×n_candidates with one matmul
    nc = shape["n_candidates"]
    if smoke:
        nc = min(nc, 1024)
    cand_abs = _sds((nc, cfg.embed_dim), F32)
    csh = NamedSharding(mesh, _sanitize_spec(mesh, P(bat + ("model",), None),
                                             (nc, cfg.embed_dim)))

    def retrieval_step(params, dense, sparse, cand):
        return dlrm_mod.dlrm_retrieval_scores(cfg, params, dense, sparse,
                                              cand)

    meta["model_flops"] = 2 * batch * nc * cfg.embed_dim \
        + batch * _dlrm_dense_flops(cfg)
    meta["n_candidates"] = nc
    out_sh = NamedSharding(mesh, _sanitize_spec(
        mesh, P(None, bat + ("model",)), (batch, nc)))
    return Workload(arch, shape_name, "retrieval", retrieval_step,
                    (params_abs, dense_abs, sparse_abs, cand_abs),
                    (psh, dsh, ssh, csh), out_sh, (), meta)


def _dlrm_dense_flops(cfg) -> float:
    bot = sum(2 * a * b for a, b in zip(cfg.bot_mlp[:-1], cfg.bot_mlp[1:]))
    dims = [cfg.d_interact] + list(cfg.top_mlp_hidden)
    top = sum(2 * a * b for a, b in zip(dims[:-1], dims[1:]))
    inter = 2 * cfg.n_feats * cfg.n_feats * cfg.embed_dim
    return bot + top + inter


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------

def build_workload(arch: str, shape_name: str, mesh,
                   smoke: bool = False, analysis: bool = False,
                   variant: str = "baseline") -> Workload:
    entry = configs.get(arch)
    shape = entry.shapes[shape_name]
    if entry.family == "lm":
        return _lm_workload(arch, shape_name, shape, mesh, smoke, analysis,
                            variant)
    if entry.family == "gnn":
        if variant == "dist" and entry.kind in ("mgn", "egnn") \
                and not analysis:
            return _gnn_dist_workload(arch, shape_name, shape, mesh, smoke)
        return _gnn_workload(arch, shape_name, shape, mesh, smoke)
    if entry.family == "recsys":
        return _dlrm_workload(arch, shape_name, shape, mesh, smoke)
    raise ValueError(f"{arch}: family {entry.family} has no shaped workloads")


def all_cells():
    """The 40 assigned (arch × shape) cells, with skip annotations."""
    cells = []
    for arch in configs.ASSIGNED:
        for shape in configs.get(arch).shapes:
            cells.append((arch, shape, configs.skip_reason(arch, shape)))
    return cells
