"""Blocked-ELL gather → propagate → reduce Pallas TPU kernel.

This is the hardware adaptation of the paper's per-edge kernel-function
application (DESIGN.md §2): the CPU frameworks' per-edge atomics / worklists
become a dst-tiled, degree-padded ELL sweep where every Pallas grid step
processes a fully regular ``(BLOCK_V dst vertices × BLOCK_E predecessor
slots)`` tile in VMEM:

  1. gather the predecessor values ``state[srcs]`` (VREG gather from the
     VMEM-resident state vector),
  2. apply the synthesized propagation function P (a jnp-traceable closure
     from repro.core.synthesis — the paper's "kernel function" IS the
     kernel body),
  3. masked-reduce along the slot axis with the reduction monoid R, and
  4. accumulate across slot-tiles in the output block (the grid's minor
     axis walks the slot tiles, so ``out_ref`` accumulation is safe).

Lexicographic plans (fused nested reductions, rule FPNEST) run one kernel
invocation per lex level: later levels recompute the earlier levels'
propagated values and mask to tie slots — the classic two-pass trick, kept
on-chip per tile.

Padding slots and frontier-inactive sources carry the reduction identity
(condition C6 makes that sound).  Tiles default to (8, 128): the VPU lane
layout, and the slot axis a multiple of 128.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph import segment

BLOCK_V = 8
BLOCK_E = 128

# boolean monoids run as int32 min/max inside the kernel
_INT_OP = {"or": "max", "and": "min"}


def _combine(op: str, a, b):
    return {"min": jnp.minimum, "max": jnp.maximum,
            "sum": lambda x, y: x + y, "prod": lambda x, y: x * y}[op](a, b)


def _row_reduce(op: str, x, axis):
    return {"min": jnp.min, "max": jnp.max, "sum": jnp.sum,
            "prod": jnp.prod}[op](x, axis=axis)


def _level_kernel(srcs_ref, w_ref, c_ref, mask_ref, active_ref, outdeg_ref,
                  *state_and_best, out_ref, op, p_fns, idents, bots,
                  n_levels, nv, block_v, mode):
    """One (BLOCK_V, BLOCK_E) tile of one lex level.

    state_and_best = (state_0 .. state_{L-1}, best_0 .. best_{L-2}):
    full per-vertex state vectors for every level plus the already-reduced
    best values of the PRIOR levels (tie masks).  Level L-1 is the one being
    reduced; ``op`` is its monoid.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    srcs = srcs_ref[...]
    mask = mask_ref[...]
    act = active_ref[...][srcs] != 0
    mask = mask & act

    rows = i * block_v + jax.lax.broadcasted_iota(jnp.int32, srcs.shape, 0)
    env_common = {"w": w_ref[...], "c": c_ref[...], "esrc": srcs,
                  "edst": rows, "outdeg": outdeg_ref[...][srcs],
                  "nv": jnp.float32(nv)}

    state_refs = state_and_best[:n_levels]
    best_refs = state_and_best[n_levels:]

    def prop(level):
        nvals = state_refs[level][...][srcs]
        p = p_fns[level]({"n": nvals, **env_common})
        p = jnp.asarray(p, dtype=nvals.dtype)
        return jnp.where(nvals == bots[level], idents[level], p), nvals

    # tie masks from the prior levels
    for lvl in range(n_levels - 1):
        pv, _ = prop(lvl)
        mask = mask & (pv == best_refs[lvl][...][:, None])

    pv, nvals = prop(n_levels - 1)
    if mode == "nonbot":                       # has-pred probe (pull− models)
        vals = (nvals != bots[n_levels - 1]).astype(out_ref.dtype)
    else:
        vals = pv.astype(out_ref.dtype)
    ident = jnp.asarray(idents[n_levels - 1], out_ref.dtype) if mode == "value" \
        else jnp.asarray(0, out_ref.dtype)
    red_op = op if mode == "value" else "max"
    vals = jnp.where(mask, vals, ident)
    partial = _row_reduce(red_op, vals, axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, ident, out_ref.dtype)

    out_ref[...] = _combine(red_op, out_ref[...], partial)


def ell_level_reduce(ell, op: str, p_fns: Sequence[Callable],
                     states: Sequence[jnp.ndarray],
                     idents: Sequence, active: jnp.ndarray,
                     outdeg: jnp.ndarray,
                     bests: Sequence[jnp.ndarray] = (),
                     mode: str = "value",
                     block_v: int = BLOCK_V, block_e: int = BLOCK_E,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Reduce one lex level over the blocked-ELL edges.

    ell       BlockedELL layout (repro.graph.structure.to_blocked_ell)
    op        monoid of the level being reduced
    p_fns     propagation closures, one per level (priors first)
    states    [n_pad] per-vertex value vectors, one per level
    idents    reduction identities (= ⊥ sentinels), one per level
    bests     [n_pad] best values of the PRIOR levels (len = len(states)-1)
    mode      "value" (reduce P values) | "nonbot" (count non-⊥ preds)

    Returns the [n_pad] per-vertex partial reduction.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_levels = len(states)
    assert len(bests) == n_levels - 1
    kernel_op = _INT_OP.get(op, op)
    # Pallas kernels may not close over traced constants — identities must be
    # Python scalars.
    idents = tuple(
        (int(i) if jnp.issubdtype(s.dtype, jnp.integer) else float(i))
        for i, s in zip(idents, states))

    out_dtype = states[-1].dtype if mode == "value" else jnp.int32
    n_pad, width = ell.srcs.shape
    grid = (n_pad // block_v, width // block_e)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)
    vrow = pl.BlockSpec((block_v,), lambda i, j: (i,))

    kern = functools.partial(
        _level_kernel, op=kernel_op, p_fns=tuple(p_fns),
        idents=tuple(idents), bots=tuple(idents), n_levels=n_levels,
        nv=float(ell.n), block_v=block_v, mode=mode)

    args = [ell.srcs, ell.weight, ell.capacity, ell.mask,
            active.astype(jnp.int32), outdeg]
    specs = [tile, tile, tile, tile, full(active), full(outdeg)]
    for s in states:
        args.append(s)
        specs.append(full(s))
    for b in bests:
        args.append(b)
        specs.append(vrow)

    fn = pl.pallas_call(
        lambda *refs: kern(*refs[:-1], out_ref=refs[-1]),
        grid=grid,
        in_specs=specs,
        out_specs=vrow,
        out_shape=jax.ShapeDtypeStruct((n_pad,), out_dtype),
        interpret=interpret,
    )
    return fn(*args)
