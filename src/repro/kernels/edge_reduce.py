"""Blocked-ELL gather → propagate → reduce Pallas TPU kernel.

This is the hardware adaptation of the paper's per-edge kernel-function
application (DESIGN.md §2): the CPU frameworks' per-edge atomics / worklists
become a dst-tiled, degree-padded ELL sweep where every Pallas grid step
processes a fully regular ``(BLOCK_V dst vertices × BLOCK_E predecessor
slots)`` tile in VMEM:

  1. gather the predecessor values ``state[srcs]`` (VREG gather from the
     VMEM-resident state vector),
  2. apply the synthesized propagation function P (a jnp-traceable closure
     from repro.core.synthesis — the paper's "kernel function" IS the
     kernel body),
  3. masked-reduce along the slot axis with the reduction monoid R, and
  4. accumulate across slot-tiles in the output block (the grid's minor
     axis walks the slot tiles, so ``out_ref`` accumulation is safe).

Three sweep entry points:

``fused_ell_sweep`` — the single-pass PULL engine sweep (DESIGN.md §2).  ONE
``pallas_call`` evaluates every plan of the fused round: each tile gathers
each component's state once, applies all propagation functions, performs the
full lexicographic reduction chain on-chip, and emits per-tile candidate
blocks (plus, optionally, the fused has-predecessor probe of the pull−
models).  Cross-tile lexicographic ties are resolved by a short jnp pass
over the ``[n_pad, width/BLOCK_E]`` candidate arrays — no second kernel
launch.  Tiles whose ``tile_act`` bit is 0 (no real slots, or no frontier-
active source) short-circuit via ``pl.when`` and contribute exactly the
reduction identities.

``fused_ell_push_sweep`` — the single-pass PUSH sweep (Defs. 3/4) over the
out-edge (source-keyed successor) layout.  ONE ``pallas_call`` applies every
propagation function across the frontier-active source tiles — state is read
per ROW (no gather), and a sparse frontier skips whole row blocks, which is
what makes BFS/SSSP iteration cost scale with the frontier instead of the
graph — then the dst-keyed lexicographic reduction resolves either through
the default dst-sorted segment-reduction path (``resolution="sorted"``,
DESIGN.md §10: candidates gather through the precomputed dst-major
permutation into the in-rectangle, where each row is one contiguous dst
segment, and a second Pallas tile pass lex-reduces only the tiles whose
candidates came from frontier-active source tiles) or as the reference
full-rectangle scatter pass in plain jnp (``resolution="scatter"``).  Both
feed the same ``plan_merge`` contract as the pull sweep (bit-for-bit
⊥-as-identity, C6).

``ell_level_reduce`` — the original one-launch-per-lex-level pull sweep,
kept as a reference path and for kernel-level tests; later levels recompute
the earlier levels' propagated values and mask to tie slots.

Padding slots and frontier-inactive sources carry the reduction identity
(condition C6 makes that sound).  Tiles default to (8, 128): the VPU lane
layout, and the slot axis a multiple of 128.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.graph import segment

BLOCK_V = 8
BLOCK_E = 128

# boolean monoids run as int32 min/max inside the kernel
_INT_OP = {"or": "max", "and": "min"}

# Sweep statistics.  "launches"/"pull_launches"/"push_launches" are
# trace-time counters: each EDGE-SWEEP pallas_call issued during tracing
# increments them exactly once AFTER the call traces successfully (a
# launch whose construction raises must not skew bench launch counts), so
# for a pull- or push-only executor they ARE sweeps-per-iteration; a
# direction-optimized executor traces BOTH branches of its lax.cond, so it
# counts one pull and one push launch per round while executing exactly
# one per iteration.  "resolve_launches" counts the dst-sorted push
# RESOLUTION tile passes separately (one per traced push sweep under
# ``resolution="sorted"``, zero under "scatter"/pull) — they are not edge
# sweeps, so the sweep-launch contract tests stay direction-symmetric.
# "pull_iters"/"push_iters" are runtime counters, filled in by
# ops.iterate_pallas from the while-loop carry after the fixpoint runs:
# they record which direction each executed iteration actually took;
# "resolve_work" likewise accumulates the runtime resolution edge work
# (Σ tile_nnz of the resolution tiles actually processed — the quantity
# fusion_bench gates as frontier-proportional).  "gather_work" counts the
# candidate slots actually read through the in2out permutation by the
# in-kernel gather (Σ tile_nnz of the ACTIVE resolution tiles per push
# iteration): skipped tiles gather zero bytes, so the counter is strictly
# below the full out-rectangle n_pad·width the pre-kernel XLA gather used
# to touch every iteration — the frontier-proportional data-movement
# quantity fusion_bench gates.
SWEEP_STATS = {"launches": 0, "pull_launches": 0, "push_launches": 0,
               "resolve_launches": 0,
               "pull_iters": 0, "push_iters": 0, "resolve_work": 0.0,
               "gather_work": 0.0}


def reset_sweep_stats():
    for k in SWEEP_STATS:
        SWEEP_STATS[k] = 0


def comps_in_plan_order(plans):
    """Component ids in first-appearance order over the static plan specs
    ((comp, op) lex levels, primary first).  Every layer that walks a fused
    round — both sweeps and the executor's state tuple — derives its
    component ordering from this one function so kernel argument order can
    never desynchronize from the executor's state order."""
    order = []
    for spec in plans:
        for c, _op in spec:
            if c not in order:
                order.append(c)
    return order


def _ident_scalars(comps_order, states, idents):
    """Identities as Python scalars (Pallas kernels may not close over
    traced constants), coerced to the component state dtype's kind."""
    def scalar(c):
        i = idents[c]
        return int(i) if jnp.issubdtype(states[c].dtype, jnp.integer) \
            else float(i)
    return tuple(scalar(c) for c in comps_order)


def _combine(op: str, a, b):
    return {"min": jnp.minimum, "max": jnp.maximum,
            "sum": lambda x, y: x + y, "prod": lambda x, y: x * y}[op](a, b)


def _row_reduce(op: str, x, axis):
    return {"min": jnp.min, "max": jnp.max, "sum": jnp.sum,
            "prod": jnp.prod}[op](x, axis=axis)


def _fold_tile_candidates(plans, plan_specs, ident_scalars, outs):
    """Cross-tile lexicographic resolution: fold the ``plan_merge``
    recurrence over the tile axis of per-tile candidate arrays
    ``outs[level][n_pad, n_tiles]``, in plain jnp.  Shared verbatim by the
    pull sweep and the dst-sorted push resolution so both directions reduce
    with the identical monoid tree (the bitwise pull ≡ push(sorted)
    guarantee of DESIGN.md §10 rests on this).  Returns ({comp: [n_pad]
    reduction}, levels consumed)."""
    red, oi = {}, 0
    for spec, mapped in zip(plans, plan_specs):
        tie = jnp.ones(outs[oi].shape, bool)
        for (c, _op), (pos, op) in zip(spec, mapped):
            ident = jnp.asarray(ident_scalars[pos], outs[oi].dtype)
            vals = jnp.where(tie, outs[oi], ident)
            best = _row_reduce(op, vals, axis=1)
            red[c] = best
            tie = tie & (vals == best[:, None])
            oi += 1
    return red, oi


# ---------------------------------------------------------------------------
# Fused single-pass sweep: all plans × lex levels (+ has-pred) in one launch.
# ---------------------------------------------------------------------------


def _fused_kernel(tile_act_ref, srcs_ref, w_ref, c_ref, mask_ref, active_ref,
                  outdeg_ref, wdeg_ref, *rest, n_comps, plan_specs,
                  hp_positions, p_fns, idents, nv, block_v):
    """One (BLOCK_V, BLOCK_E) tile of the fused sweep.

    ``rest`` = the per-component state vectors (``n_comps`` of them) followed
    by the output refs: one [block_v, 1] candidate block per plan per lex
    level, then one [block_v, 1] has-pred block per entry of
    ``hp_positions``.  ``plan_specs`` is static: per plan a tuple of
    (state position, monoid) levels, primary first.

    Every output block is owned by exactly one grid step — no cross-step
    accumulation — so cross-tile lexicographic resolution can run outside
    the kernel on the [n_pad, n_tiles] candidates.
    """
    i = pl.program_id(0)
    state_refs = rest[:n_comps]
    out_refs = rest[n_comps:]

    # Identity-fill every output first: tiles skipped below contribute ⊥
    # (= the identity, C6) bit-for-bit.
    oi = 0
    for spec in plan_specs:
        for (pos, _op) in spec:
            out_refs[oi][...] = jnp.full(out_refs[oi].shape, idents[pos],
                                         out_refs[oi].dtype)
            oi += 1
    for _pos in hp_positions:
        out_refs[oi][...] = jnp.zeros(out_refs[oi].shape, out_refs[oi].dtype)
        oi += 1

    @pl.when(tile_act_ref[0, 0] != 0)
    def _tile_body():
        srcs = srcs_ref[...]
        raw_mask = mask_ref[...]
        mask = raw_mask & (active_ref[...][srcs] != 0)
        rows = i * block_v + jax.lax.broadcasted_iota(jnp.int32, srcs.shape, 0)
        env = {"w": w_ref[...], "c": c_ref[...], "esrc": srcs, "edst": rows,
               "outdeg": outdeg_ref[...][srcs], "wdeg": wdeg_ref[...][srcs],
               "nv": jnp.float32(nv)}
        gathered, props = [], []
        for k in range(n_comps):                 # ONE gather per component
            nvals = state_refs[k][...][srcs]
            p = jnp.asarray(p_fns[k]({"n": nvals, **env}), nvals.dtype)
            gathered.append(nvals)
            props.append(jnp.where(nvals == idents[k], idents[k], p))
        oi = 0
        for spec in plan_specs:
            tie = mask
            for l, (pos, op) in enumerate(spec):
                ident = jnp.asarray(idents[pos], props[pos].dtype)
                vals = jnp.where(tie, props[pos], ident)
                best = _row_reduce(op, vals, axis=1)
                out_refs[oi][...] = best[:, None].astype(out_refs[oi].dtype)
                oi += 1
                if l + 1 < len(spec):
                    tie = tie & (props[pos] == best[:, None])
        for pos in hp_positions:                 # fused has-pred probe
            nb = (raw_mask & (gathered[pos] != idents[pos])).astype(jnp.int32)
            out_refs[oi][...] = jnp.max(nb, axis=1)[:, None]
            oi += 1


def fused_ell_sweep(srcs, weight, capacity, mask, tile_act, states, active,
                    outdeg, *, plans, idents, p_fns, nv,
                    need_haspred: bool = False, wdeg=None,
                    block_v: int = BLOCK_V, block_e: int = BLOCK_E,
                    interpret: Optional[bool] = None,
                    return_candidates: bool = False):
    """Single-launch fused edge sweep over every plan of a fused round.

    srcs/weight/capacity/mask   [n_pad, width] blocked-ELL arrays
    tile_act  [n_pad/block_v, width/block_e] int32 — 0 short-circuits a tile
    states    {comp: [n_pad] value vector}
    active    [n_pad] int32 frontier (1 = source eligible)
    outdeg    [n_pad] float32 (gathered per edge into the P environment)
    wdeg      [n_pad] float32 weighted out-degree (env "wdeg"; None → 1s)
    plans     static: per plan a tuple of (comp, op) lex levels, primary first
    idents    {comp: identity scalar};  p_fns {comp: propagation closure}

    Returns ``(red, hp)``: ``red[comp]`` is the [n_pad] cross-tile-resolved
    reduction of that level, ``hp[comp]`` the [n_pad] bool has-pred vector
    (empty dict unless ``need_haspred``).  With ``return_candidates`` the raw
    per-tile candidate arrays are appended: ``(red, hp, cands)``.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    comps_order = comps_in_plan_order(plans)
    pos_of = {c: k for k, c in enumerate(comps_order)}
    ident_scalars = _ident_scalars(comps_order, states, idents)
    plan_specs = tuple(tuple((pos_of[c], _INT_OP.get(op, op)) for c, op in spec)
                       for spec in plans)
    hp_positions = tuple(range(len(comps_order))) if need_haspred else ()

    n_pad, width = srcs.shape
    n_i, n_j = n_pad // block_v, width // block_e
    grid = (n_i, n_j)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)
    cand = pl.BlockSpec((block_v, 1), lambda i, j: (i, j))

    if wdeg is None:
        wdeg = jnp.ones_like(outdeg)
    args = [tile_act, srcs, weight, capacity, mask,
            jnp.asarray(active, jnp.int32), outdeg, wdeg]
    specs = [one, tile, tile, tile, tile, full(active), full(outdeg),
             full(wdeg)]
    for c in comps_order:
        args.append(states[c])
        specs.append(full(states[c]))

    out_shapes, out_specs = [], []
    for spec in plans:
        for c, _op in spec:
            out_shapes.append(jax.ShapeDtypeStruct((n_pad, n_j),
                                                   states[c].dtype))
            out_specs.append(cand)
    for _ in hp_positions:
        out_shapes.append(jax.ShapeDtypeStruct((n_pad, n_j), jnp.int32))
        out_specs.append(cand)

    kern = functools.partial(
        _fused_kernel, n_comps=len(comps_order), plan_specs=plan_specs,
        hp_positions=hp_positions,
        p_fns=tuple(p_fns[c] for c in comps_order),
        idents=ident_scalars, nv=float(nv), block_v=block_v)

    outs = pl.pallas_call(
        kern, grid=grid, in_specs=specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret)(*args)
    SWEEP_STATS["launches"] += 1
    SWEEP_STATS["pull_launches"] += 1
    outs = list(outs) if isinstance(outs, (tuple, list)) else [outs]

    # Cross-tile lexicographic resolution (the "short second pass"): a fold
    # of the plan_merge recurrence over the tile axis, in plain jnp — zero
    # extra kernel launches.
    red, oi = _fold_tile_candidates(plans, plan_specs, ident_scalars, outs)
    hp = {}
    if need_haspred:
        for k, c in enumerate(comps_order):
            hp[c] = jnp.max(outs[oi + k], axis=1) > 0
    if return_candidates:
        return red, hp, outs
    return red, hp


def tile_activity(srcs, mask, tile_nnz, active_i32, block_v: int, block_e: int):
    """Frontier-aware per-tile activity bitmap: a tile runs iff it has real
    slots AND at least one frontier-active source.  One gather + block
    reduction in XLA — far cheaper than the propagation work it skips."""
    n_i, n_j = tile_nnz.shape
    act = (active_i32[srcs] != 0) & mask
    any_act = act.reshape(n_i, block_v, n_j, block_e).any(axis=(1, 3))
    return ((tile_nnz > 0) & any_act).astype(jnp.int32)


def tile_activity_push(tile_nnz, active_i32, block_v: int):
    """Push-side activity bitmap over the out-edge (source-keyed) layout.

    Rows ARE sources, so a tile is active iff its row block contains a
    frontier-active vertex — no gather at all, just a block-any over the
    frontier, and work scales with the number of active *source rows*
    rather than "tiles that happen to contain an active source" (the pull
    criterion, which a sparse frontier of hub predecessors still lights up
    almost everywhere).  This asymmetry is why the push direction wins the
    sparse tail of BFS/SSSP (DESIGN.md §2)."""
    n_i, _n_j = tile_nnz.shape
    row_act = (active_i32.reshape(n_i, block_v) != 0).any(axis=1)
    return ((tile_nnz > 0) & row_act[:, None]).astype(jnp.int32)


def resolution_tile_activity(res_contrib, push_tile_act, res_tile_nnz):
    """Per-tile activity bitmap of the dst-sorted resolution pass.

    A resolution tile holds candidates gathered from out-layout slots; a
    candidate is non-identity only if its OUT tile ran (``push_tile_act``
    from ``tile_activity_push``), so a resolution tile whose real slots all
    map into skipped out-tiles contains only identities and can skip too.
    ``res_contrib`` is the precomputed per-resolution-tile contributing
    out-tile list (structure.PushResolution.contrib, −1 padded): the test
    is a tile-granular gather + OR over those lists — O(tiles·c_max), not
    the O(n_pad·width) dense gather over the slot→tile map the first
    version paid every iteration.  Σ res_tile_nnz over the tiles this
    bitmap keeps IS the resolution edge work fusion_bench gates as
    frontier-proportional."""
    n_i, n_j = res_tile_nnz.shape
    flat_act = push_tile_act.reshape(-1)
    hit = (res_contrib >= 0) & \
        (flat_act[jnp.clip(res_contrib, 0, flat_act.shape[0] - 1)] != 0)
    any_act = hit.any(axis=1).reshape(n_i, n_j)
    return ((res_tile_nnz > 0) & any_act).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Fused push sweep: frontier-active source tiles → per-edge candidates →
# dst-keyed lexicographic scatter resolution.
# ---------------------------------------------------------------------------


def _push_kernel(tile_act_ref, dsts_ref, w_ref, c_ref, mask_ref, active_ref,
                 outdeg_ref, wdeg_ref, *rest, n_comps, p_fns, idents, nv,
                 block_v):
    """One (BLOCK_V sources × BLOCK_E successor slots) tile of the push sweep.

    ``rest`` = the per-component state row blocks (``n_comps`` of them,
    [block_v] slices — push reads its OWN row's state, no gather) followed by
    one [block_v, block_e] per-edge candidate output per component.

    The kernel's job is the propagation half of Defs. 3/4: apply every
    synthesized P to the row's state across the row's out-edges, masking
    frontier-inactive sources and padding slots to the reduction identity
    (C6) so the dst-keyed scatter outside absorbs them as no-ops.  Inactive
    tiles short-circuit via ``pl.when`` and emit identities bit-for-bit."""
    i = pl.program_id(0)
    state_refs = rest[:n_comps]
    out_refs = rest[n_comps:]

    for k in range(n_comps):
        out_refs[k][...] = jnp.full(out_refs[k].shape, idents[k],
                                    out_refs[k].dtype)

    @pl.when(tile_act_ref[0, 0] != 0)
    def _tile_body():
        dsts = dsts_ref[...]
        mask = mask_ref[...] & (active_ref[...][:, None] != 0)
        rows = i * block_v + jax.lax.broadcasted_iota(jnp.int32, dsts.shape, 0)
        env = {"w": w_ref[...], "c": c_ref[...], "esrc": rows, "edst": dsts,
               "outdeg": jnp.broadcast_to(outdeg_ref[...][:, None],
                                          dsts.shape),
               "wdeg": jnp.broadcast_to(wdeg_ref[...][:, None], dsts.shape),
               "nv": jnp.float32(nv)}
        for k in range(n_comps):
            nvals = jnp.broadcast_to(state_refs[k][...][:, None], dsts.shape)
            ident = jnp.asarray(idents[k], nvals.dtype)
            p = jnp.asarray(p_fns[k]({"n": nvals, **env}), nvals.dtype)
            p = jnp.where(nvals == ident, ident, p)        # C3: ⊥ stays ⊥
            out_refs[k][...] = jnp.where(mask, p, ident).astype(
                out_refs[k].dtype)


def fused_ell_push_sweep(dsts, weight, capacity, mask, tile_act, states,
                         active, outdeg, *, plans, idents, p_fns, nv,
                         need_haspred: bool = False, wdeg=None,
                         resolution: str = "scatter", res=None,
                         block_v: int = BLOCK_V, block_e: int = BLOCK_E,
                         interpret: Optional[bool] = None,
                         return_candidates: bool = False):
    """Single-launch fused PUSH edge sweep over every plan of a fused round.

    dsts/weight/capacity/mask  [n_pad, width] out-edge blocked-ELL arrays
                               (``to_blocked_ell(..., direction="out")``:
                               rows are sources, slots hold destinations)
    tile_act  [n_pad/block_v, width/block_e] int32 — 0 short-circuits a tile
    states    {comp: [n_pad] value vector}
    active    [n_pad] int32 frontier (1 = source eligible; push+ masks
              inactive sources, push− passes all-ones)
    wdeg      [n_pad] float32 weighted out-degree (env "wdeg"; None → 1s)
    plans     static: per plan a tuple of (comp, op) lex levels, primary first
    idents    {comp: identity scalar};  p_fns {comp: propagation closure}

    Contract (DESIGN.md §2/§10): ONE ``pallas_call`` applies every
    synthesized P over the frontier-active source tiles and emits per-edge
    *candidates* (identity-filled where inactive, per C6).  The dst-keyed
    lexicographic reduction then resolves by ``resolution``:

    ``"sorted"`` — the dst-sorted segment-reduction path.  ``res`` must be
    ``(in2out, valid, res_tile_act)`` from ``structure.PushResolution`` +
    ``resolution_tile_activity``: a second Pallas tile pass lex-reduces
    only the resolution tiles whose candidates came from frontier-active
    out-tiles, gathering each kept tile's candidates through the dst-major
    permutation INSIDE the kernel (row v = the contiguous candidate
    segment of dst v; skipped tiles move zero candidate bytes), finishing
    with the SAME cross-tile fold as the pull sweep — resolution work is
    Σ tile_nnz of processed resolution tiles, and the reduction is
    bit-identical to the pull sweep's tree (even for float sums).

    ``"scatter"`` — the reference full-rectangle scatter pass in plain jnp
    (the original path, kept as fallback and as the equivalence oracle).

    Both produce exactly the identity-initialised reduction that
    ``iterate.plan_merge`` resolves against the old state, so push and pull
    rounds share one merge contract bit-for-bit.

    Returns ``(red, hp)`` like ``fused_ell_sweep``: ``red[comp]`` is the
    [n_pad] dst-keyed reduction of that level over the candidates, ``hp``
    the has-predecessor vectors of the push− models (from the non-⊥ source
    states — no extra sweep launch).  ``return_candidates`` appends the raw
    [n_pad, width] per-edge candidate arrays (out-layout positions).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    if resolution not in ("scatter", "sorted"):
        raise ValueError(f"resolution must be 'scatter' or 'sorted', "
                         f"got {resolution!r}")
    if resolution == "sorted" and res is None:
        raise ValueError("resolution='sorted' needs res=(in2out, valid, "
                         "res_tile_act) from structure.PushResolution")
    comps_order = comps_in_plan_order(plans)
    pos_of = {c: k for k, c in enumerate(comps_order)}
    ident_scalars = _ident_scalars(comps_order, states, idents)

    n_pad, width = dsts.shape
    n_i, n_j = n_pad // block_v, width // block_e
    grid = (n_i, n_j)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    vrow = pl.BlockSpec((block_v,), lambda i, j: (i,))

    if wdeg is None:
        wdeg = jnp.ones_like(outdeg)
    args = [tile_act, dsts, weight, capacity, mask,
            jnp.asarray(active, jnp.int32), outdeg, wdeg]
    specs = [one, tile, tile, tile, tile, vrow, vrow, vrow]
    for c in comps_order:
        args.append(states[c])
        specs.append(vrow)

    out_shapes = [jax.ShapeDtypeStruct((n_pad, width), states[c].dtype)
                  for c in comps_order]
    out_specs = [tile for _ in comps_order]

    kern = functools.partial(
        _push_kernel, n_comps=len(comps_order),
        p_fns=tuple(p_fns[c] for c in comps_order),
        idents=ident_scalars, nv=float(nv), block_v=block_v)

    outs = pl.pallas_call(
        kern, grid=grid, in_specs=specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret)(*args)
    SWEEP_STATS["launches"] += 1
    SWEEP_STATS["push_launches"] += 1
    outs = list(outs) if isinstance(outs, (tuple, list)) else [outs]

    if resolution == "sorted":
        in2out, valid, res_tile_act = res
        red = _resolve_push_sorted(
            outs, in2out, valid, res_tile_act, plans=plans,
            comps_order=comps_order, ident_scalars=ident_scalars,
            dtypes=[states[c].dtype for c in comps_order],
            block_v=block_v, block_e=block_e, interpret=interpret)
    else:
        # Dst-keyed lexicographic scatter resolution (reference path): the
        # push analogue of the pull sweep's cross-tile fold, over the full
        # out rectangle.  Identity-initialised (NOT onto the old state) so
        # the result obeys the same plan_merge contract as the pull
        # reduction; ties mask the next level to identity exactly like
        # plan_segment_reduce does on the pull side.
        flat_dst = dsts.reshape(-1)
        flat = {c: outs[pos_of[c]].reshape(-1) for c in comps_order}
        red = {}
        for spec in plans:
            tie = jnp.ones_like(flat_dst, dtype=bool)
            for l, (c, op) in enumerate(spec):
                ident = jnp.asarray(ident_scalars[pos_of[c]], flat[c].dtype)
                init = jnp.full((n_pad,), ident, flat[c].dtype)
                vals = jnp.where(tie, flat[c], ident)
                prim = segment.scatter_reduce(op, init, vals, flat_dst)
                red[c] = prim
                if l + 1 < len(spec):
                    tie = tie & (vals == prim[flat_dst])

    hp = {}
    if need_haspred:
        # Def. 4's CPreds ≠ ∅ probe from "source state non-⊥" over real
        # out-edges.  Pure jnp on data already resident — no launch.  The
        # sorted path reads it through the dst-major permutation (the same
        # booleans the pull sweep's fused probe computes); scatter keeps
        # the scatter-OR.
        for c in comps_order:
            ident = jnp.asarray(ident_scalars[pos_of[c]], states[c].dtype)
            nonbot = (mask & (states[c][:, None] != ident)).astype(jnp.int32)
            if resolution == "sorted":
                in2out, valid, _res_tile_act = res
                hp[c] = jnp.any(
                    valid & (nonbot.reshape(-1)[in2out] != 0), axis=1)
            else:
                hp[c] = segment.scatter_reduce(
                    "or", jnp.zeros((n_pad,), jnp.int32), nonbot.reshape(-1),
                    dsts.reshape(-1)) > 0
    if return_candidates:
        return red, hp, outs
    return red, hp


def _resolve_kernel(tile_act_ref, valid_ref, in2out_ref, *rest, n_comps,
                    plan_specs, idents):
    """One (BLOCK_V dst rows × BLOCK_E candidate slots) tile of the
    dst-sorted push resolution.

    ``rest`` = the push sweep's FULL out-rectangle candidate arrays
    (``n_comps`` whole-array refs — every grid step maps the same (0, 0)
    block) followed by one [block_v, 1] output per plan per lex level.
    The permutation gather lives HERE, under ``pl.when``: each active tile
    reads its own ``in2out`` block and gathers its candidates out of the
    out rectangle, identity-filling invalid slots — so a tile whose
    ``tile_act`` bit is 0 (all candidates born in skipped out-tiles, or all
    padding) short-circuits and performs ZERO gather work, where the old
    pre-kernel XLA gather touched the full rectangle every iteration.  The
    reduction body is exactly the reduction half of ``_fused_kernel`` —
    same lex chain, same tie masking, same per-tile candidate outputs — so
    the fold that finishes the job is the pull sweep's
    ``_fold_tile_candidates`` and the overall reduction tree is
    bit-identical to pull's."""
    cand_refs = rest[:n_comps]
    out_refs = rest[n_comps:]

    oi = 0
    for spec in plan_specs:
        for (pos, _op) in spec:
            out_refs[oi][...] = jnp.full(out_refs[oi].shape, idents[pos],
                                         out_refs[oi].dtype)
            oi += 1

    @pl.when(tile_act_ref[0, 0] != 0)
    def _tile_body():
        mask = valid_ref[...]
        idx = in2out_ref[...]
        cands = []
        for k in range(n_comps):
            ident = jnp.asarray(idents[k], cand_refs[k].dtype)
            got = cand_refs[k][...].reshape(-1)[idx]
            cands.append(jnp.where(mask, got, ident))
        oi = 0
        for spec in plan_specs:
            tie = mask
            for l, (pos, op) in enumerate(spec):
                ident = jnp.asarray(idents[pos], cands[pos].dtype)
                vals = jnp.where(tie, cands[pos], ident)
                best = _row_reduce(op, vals, axis=1)
                out_refs[oi][...] = best[:, None].astype(out_refs[oi].dtype)
                oi += 1
                if l + 1 < len(spec):
                    tie = tie & (cands[pos] == best[:, None])


def _resolve_push_sorted(cand_outs, in2out, valid, res_tile_act, *, plans,
                         comps_order, ident_scalars, dtypes, block_v, block_e,
                         interpret):
    """Dst-sorted segment-reduction resolution (DESIGN.md §10).

    Runs the ``_resolve_kernel`` tile pass over the resolution tiles
    ``res_tile_act`` keeps, with the permutation gather INSIDE the kernel:
    the raw out-rectangle candidates go in whole (a (0, 0)-mapped
    whole-array BlockSpec per component, the pull sweep's ``full`` idiom)
    and each active tile gathers only its own slots through its ``in2out``
    block — skipped tiles move zero candidate bytes.  Finishes with the
    pull sweep's cross-tile fold."""
    pos_of = {c: k for k, c in enumerate(comps_order)}
    plan_specs = tuple(tuple((pos_of[c], _INT_OP.get(op, op)) for c, op in s)
                       for s in plans)
    n_pad, w_in = valid.shape
    n_i, n_j = n_pad // block_v, w_in // block_e
    grid = (n_i, n_j)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    one = pl.BlockSpec((1, 1), lambda i, j: (i, j))
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)
    cand = pl.BlockSpec((block_v, 1), lambda i, j: (i, j))

    args = [res_tile_act, valid, in2out] + list(cand_outs)
    specs = [one, tile, tile] + [full(c) for c in cand_outs]
    out_shapes, out_specs = [], []
    for spec in plans:
        for c, _op in spec:
            out_shapes.append(jax.ShapeDtypeStruct((n_pad, n_j),
                                                   dtypes[pos_of[c]]))
            out_specs.append(cand)

    kern = functools.partial(_resolve_kernel, n_comps=len(comps_order),
                             plan_specs=plan_specs, idents=ident_scalars)
    outs = pl.pallas_call(
        kern, grid=grid, in_specs=specs, out_specs=out_specs,
        out_shape=out_shapes, interpret=interpret)(*args)
    SWEEP_STATS["resolve_launches"] += 1
    outs = list(outs) if isinstance(outs, (tuple, list)) else [outs]
    red, _ = _fold_tile_candidates(plans, plan_specs, ident_scalars, outs)
    return red


def _level_kernel(srcs_ref, w_ref, c_ref, mask_ref, active_ref, outdeg_ref,
                  wdeg_ref, *state_and_best, out_ref, op, p_fns, idents, bots,
                  n_levels, nv, block_v, mode):
    """One (BLOCK_V, BLOCK_E) tile of one lex level.

    state_and_best = (state_0 .. state_{L-1}, best_0 .. best_{L-2}):
    full per-vertex state vectors for every level plus the already-reduced
    best values of the PRIOR levels (tie masks).  Level L-1 is the one being
    reduced; ``op`` is its monoid.
    """
    i = pl.program_id(0)
    j = pl.program_id(1)
    srcs = srcs_ref[...]
    mask = mask_ref[...]
    act = active_ref[...][srcs] != 0
    mask = mask & act

    rows = i * block_v + jax.lax.broadcasted_iota(jnp.int32, srcs.shape, 0)
    env_common = {"w": w_ref[...], "c": c_ref[...], "esrc": srcs,
                  "edst": rows, "outdeg": outdeg_ref[...][srcs],
                  "wdeg": wdeg_ref[...][srcs], "nv": jnp.float32(nv)}

    state_refs = state_and_best[:n_levels]
    best_refs = state_and_best[n_levels:]

    def prop(level):
        nvals = state_refs[level][...][srcs]
        p = p_fns[level]({"n": nvals, **env_common})
        p = jnp.asarray(p, dtype=nvals.dtype)
        return jnp.where(nvals == bots[level], idents[level], p), nvals

    # tie masks from the prior levels
    for lvl in range(n_levels - 1):
        pv, _ = prop(lvl)
        mask = mask & (pv == best_refs[lvl][...][:, None])

    pv, nvals = prop(n_levels - 1)
    if mode == "nonbot":                       # has-pred probe (pull− models)
        vals = (nvals != bots[n_levels - 1]).astype(out_ref.dtype)
    else:
        vals = pv.astype(out_ref.dtype)
    ident = jnp.asarray(idents[n_levels - 1], out_ref.dtype) if mode == "value" \
        else jnp.asarray(0, out_ref.dtype)
    red_op = op if mode == "value" else "max"
    vals = jnp.where(mask, vals, ident)
    partial = _row_reduce(red_op, vals, axis=1)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.full(out_ref.shape, ident, out_ref.dtype)

    out_ref[...] = _combine(red_op, out_ref[...], partial)


def ell_level_reduce(ell, op: str, p_fns: Sequence[Callable],
                     states: Sequence[jnp.ndarray],
                     idents: Sequence, active: jnp.ndarray,
                     outdeg: jnp.ndarray,
                     bests: Sequence[jnp.ndarray] = (),
                     mode: str = "value", wdeg=None,
                     block_v: int = BLOCK_V, block_e: int = BLOCK_E,
                     interpret: Optional[bool] = None) -> jnp.ndarray:
    """Reduce one lex level over the blocked-ELL edges.

    ell       BlockedELL layout (repro.graph.structure.to_blocked_ell)
    op        monoid of the level being reduced
    p_fns     propagation closures, one per level (priors first)
    states    [n_pad] per-vertex value vectors, one per level
    idents    reduction identities (= ⊥ sentinels), one per level
    bests     [n_pad] best values of the PRIOR levels (len = len(states)-1)
    mode      "value" (reduce P values) | "nonbot" (count non-⊥ preds)

    Returns the [n_pad] per-vertex partial reduction.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_levels = len(states)
    assert len(bests) == n_levels - 1
    kernel_op = _INT_OP.get(op, op)
    # Pallas kernels may not close over traced constants — identities must be
    # Python scalars.
    idents = tuple(
        (int(i) if jnp.issubdtype(s.dtype, jnp.integer) else float(i))
        for i, s in zip(idents, states))

    out_dtype = states[-1].dtype if mode == "value" else jnp.int32
    n_pad, width = ell.srcs.shape
    grid = (n_pad // block_v, width // block_e)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    full = lambda a: pl.BlockSpec(a.shape, lambda i, j: (0,) * a.ndim)
    vrow = pl.BlockSpec((block_v,), lambda i, j: (i,))

    kern = functools.partial(
        _level_kernel, op=kernel_op, p_fns=tuple(p_fns),
        idents=tuple(idents), bots=tuple(idents), n_levels=n_levels,
        nv=float(ell.n), block_v=block_v, mode=mode)

    if wdeg is None:
        wdeg = jnp.ones_like(outdeg)
    args = [ell.srcs, ell.weight, ell.capacity, ell.mask,
            active.astype(jnp.int32), outdeg, wdeg]
    specs = [tile, tile, tile, tile, full(active), full(outdeg), full(wdeg)]
    for s in states:
        args.append(s)
        specs.append(full(s))
    for b in bests:
        args.append(b)
        specs.append(vrow)

    fn = pl.pallas_call(
        lambda *refs: kern(*refs[:-1], out_ref=refs[-1]),
        grid=grid,
        in_specs=specs,
        out_specs=vrow,
        out_shape=jax.ShapeDtypeStruct((n_pad,), out_dtype),
        interpret=interpret,
    )
    out = fn(*args)
    SWEEP_STATS["launches"] += 1
    return out
