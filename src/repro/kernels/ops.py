"""Jit'd dispatch wrappers around the Pallas kernels.

``iterate_pallas`` is the GraphIt-analogue engine (DESIGN.md §2): the same
fixpoint semantics as ``iterate.iterate_graph`` but with every edge sweep
executed by the blocked-ELL Pallas kernel.  One engine iteration issues
exactly ONE ``pallas_call`` — ``fused_ell_sweep`` evaluates every plan of
the fused round (all lexicographic levels plus, for the pull− models, the
has-predecessor probe) in a single launch, and cross-tile lexicographic
ties resolve in a short jnp pass over the per-tile candidates.

The fixpoint itself is compiled once per (plan structure, kernel set,
graph shape) and memoized in ``_EXEC_CACHE``: repeated queries, multi-round
programs (RDS, Trust) and benchmark repeats reuse the traced
``lax.while_loop`` instead of rebuilding it per call (DESIGN.md §8).

The other wrappers expose the embedding-bag and ELL-softmax kernels behind
plain jit'd functions that the models call.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import iterate
from repro.core.fusion import Lex
from repro.graph.structure import Graph, blocked_ell_cached
from repro.kernels import edge_reduce as _er
from repro.kernels import embedding_bag as _eb
from repro.kernels import segment_softmax as _ss

embedding_bag = jax.jit(_eb.embedding_bag,
                        static_argnames=("mode", "block_b", "block_d",
                                         "interpret"))
ell_softmax = jax.jit(_ss.ell_softmax,
                      static_argnames=("block_v", "block_e", "interpret"))


def _plan_levels(plan):
    levels = []
    p = plan
    while isinstance(p, Lex):
        levels.append((p.comp, p.op))
        p = p.secondary
    levels.append((p.comp, p.op))
    return levels


# ---------------------------------------------------------------------------
# Compiled-executor cache.
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict = {}
_EXEC_CACHE_MAX = 128


def clear_executor_cache():
    _EXEC_CACHE.clear()


def executor_cache_size() -> int:
    return len(_EXEC_CACHE)


def _comps_key(comps):
    """Kernel-set identity: stable across calls because synthesize_round
    memoizes its compiled closures per round structure."""
    return tuple((cr.idx, cr.op, str(cr.dtype), cr.source,
                  id(cr.p_fn), id(cr.init_fn),
                  None if cr.e_fn is None else id(cr.e_fn)) for cr in comps)


def _build_pallas_executor(comps, plans, n, max_iter, tol,
                           block_v, block_e, interpret):
    """Trace + jit the whole fixpoint once.  The returned function takes the
    blocked-ELL arrays and out-degrees as arguments (NOT closure constants),
    so one compiled executor serves every graph with the same padded shape."""
    comps_by_idx = {cr.idx: cr for cr in comps}
    plan_levels = tuple(tuple(_plan_levels(p)) for p in plans)
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    comps_order = []
    for spec in plan_levels:
        for c, _op in spec:
            if c not in comps_order:
                comps_order.append(c)
    idents = {c: comps_by_idx[c].ident for c in comps_order}
    p_fns = {c: comps_by_idx[c].p_fn for c in comps_order}

    def run(srcs, weight, capacity, mask, tile_nnz, out_deg):
        n_pad = srcs.shape[0]
        out_deg_pad = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            jnp.maximum(out_deg, 1).astype(jnp.float32))
        out_deg_real = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            out_deg.astype(jnp.float32))
        num_edges = jnp.sum(mask.astype(jnp.float32))
        tiles_static = (tile_nnz > 0).astype(jnp.int32)
        ones_act = jnp.ones(n_pad, jnp.int32)

        def pad_state(x, ident):
            return jnp.full((n_pad,), ident, x.dtype).at[:n].set(x)

        def init_state():
            base = iterate._init_state(comps, n)
            return tuple(pad_state(s, cr.ident)
                         for s, cr in zip(base, comps))

        def sweep(state_d, active_i32, tile_act, need_hp):
            states = {c: state_d[c] for c in comps_order}
            return _er.fused_ell_sweep(
                srcs, weight, capacity, mask, tile_act, states, active_i32,
                out_deg_pad, plans=plan_levels, idents=idents, p_fns=p_fns,
                nv=float(n), need_haspred=need_hp,
                block_v=block_v, block_e=block_e, interpret=interpret)

        def body(carry):
            state, active, k, work = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            if idempotent:
                # pull+: frontier-masked; skip tiles with no active source.
                active_i32 = active.astype(jnp.int32)
                work = work + jnp.sum(out_deg_real
                                      * active.astype(jnp.float32))
                tile_act = _er.tile_activity(srcs, mask, tile_nnz,
                                             active_i32, block_v, block_e)
                red, _ = sweep(state_d, active_i32, tile_act, False)
                new_d = {}
                for p in plans:
                    new_d.update(iterate.plan_merge(p, state_d, red,
                                                    comps_by_idx))
            else:
                # pull−: full recompute; has-pred probe fused in the same
                # launch; only all-padding tiles skip.
                work = work + num_edges
                red, hp = sweep(state_d, ones_act, tiles_static, True)
                red = iterate._apply_epilogue(comps, red)
                new_d = iterate._recompute_merge(plans, comps_by_idx,
                                                 state_d, red, hp)
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = iterate._changed(comps, new, state, tol)
            return new, ch, k + 1, work

        def cond(carry):
            _, active, k, _ = carry
            return jnp.any(active) & (k < max_iter)

        state0 = init_state()
        state, active, k, work = jax.lax.while_loop(
            cond, body, (state0, jnp.ones(n_pad, bool), jnp.int32(0),
                         jnp.float32(0)))
        return state, k, work

    return jax.jit(run)


def iterate_pallas(g: Graph, comps, plans, max_iter: Optional[int] = None,
                   tol: float = 0.0, block_v: int = 8, block_e: int = 128,
                   interpret: Optional[bool] = None) -> iterate.IterationResult:
    """Fixpoint of the fused reduction with single-launch Pallas edge sweeps.

    Semantics match the pull model (Def. 1 / Def. 2): idempotent plans run
    frontier-masked (pull+), non-idempotent plans run full-recompute (pull−),
    per-level lexicographic reductions per fused plan.
    """
    n = g.n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    ell = blocked_ell_cached(g, block_v=block_v, block_e=block_e)
    key = (n, tuple(tuple(_plan_levels(p)) for p in plans), _comps_key(comps),
           max_iter, tol, block_v, block_e, interpret)
    run = _EXEC_CACHE.get(key)
    if run is None:
        while len(_EXEC_CACHE) >= _EXEC_CACHE_MAX:     # evict oldest entry
            _EXEC_CACHE.pop(next(iter(_EXEC_CACHE)))
        run = _build_pallas_executor(comps, plans, n, max_iter, tol,
                                     block_v, block_e, interpret)
        _EXEC_CACHE[key] = run
    state, k, work = run(ell.srcs, ell.weight, ell.capacity, ell.mask,
                         ell.tile_nnz, g.out_deg)
    return iterate.IterationResult(
        state=tuple(s[:n] for s in state),
        iterations=iterate._host(k, int),
        edge_work=iterate._host(work, float))
