"""Jit'd dispatch wrappers around the Pallas kernels.

``iterate_pallas`` is the GraphIt-analogue engine (DESIGN.md §2): the same
fixpoint semantics as ``iterate.iterate_graph`` but with every edge sweep
executed by the blocked-ELL Pallas kernel.  The other wrappers expose the
embedding-bag and ELL-softmax kernels behind plain jit'd functions that the
models call.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import iterate
from repro.core.fusion import Lex, Prim
from repro.graph.structure import Graph, to_blocked_ell
from repro.kernels import edge_reduce as _er
from repro.kernels import embedding_bag as _eb
from repro.kernels import segment_softmax as _ss

embedding_bag = jax.jit(_eb.embedding_bag,
                        static_argnames=("mode", "block_b", "block_d",
                                         "interpret"))
ell_softmax = jax.jit(_ss.ell_softmax,
                      static_argnames=("block_v", "block_e", "interpret"))


def _plan_levels(plan):
    levels = []
    p = plan
    while isinstance(p, Lex):
        levels.append((p.comp, p.op))
        p = p.secondary
    levels.append((p.comp, p.op))
    return levels


def iterate_pallas(g: Graph, comps, plans, max_iter: Optional[int] = None,
                   tol: float = 0.0, block_v: int = 8, block_e: int = 128,
                   interpret: Optional[bool] = None) -> iterate.IterationResult:
    """Fixpoint of the fused reduction with Pallas edge sweeps.

    Semantics match the pull model (Def. 1 / Def. 2): idempotent plans run
    frontier-masked (pull+), non-idempotent plans run full-recompute (pull−),
    per-level lexicographic reductions per fused plan.
    """
    n = g.n
    ell = to_blocked_ell(g, block_v=block_v, block_e=block_e)
    n_pad = ell.n_pad
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    comps_by_idx = {cr.idx: cr for cr in comps}
    out_deg_pad = jnp.zeros(n_pad, jnp.float32).at[:n].set(
        jnp.maximum(g.out_deg, 1).astype(jnp.float32))
    out_deg_real = jnp.zeros(n_pad, jnp.float32).at[:n].set(
        g.out_deg.astype(jnp.float32))

    def pad_state(x, ident):
        return jnp.full((n_pad,), ident, x.dtype).at[:n].set(x)

    def init_state():
        base = iterate._init_state(comps, n)
        return tuple(pad_state(s, cr.ident) for s, cr in zip(base, comps))

    def run_plan(plan, state_d, active_i32):
        levels = _plan_levels(plan)
        bests, out = [], {}
        for l, (cidx, op) in enumerate(levels):
            lv = [levels[i][0] for i in range(l + 1)]
            red = _er.ell_level_reduce(
                ell, op,
                p_fns=[comps_by_idx[c].p_fn for c in lv],
                states=[state_d[c] for c in lv],
                idents=[comps_by_idx[c].ident for c in lv],
                active=active_i32, outdeg=out_deg_pad,
                bests=bests, block_v=block_v, block_e=block_e,
                interpret=interpret)
            out[cidx] = red
            bests.append(red)
        return out

    def has_pred_of(plan, state_d, active_i32):
        levels = _plan_levels(plan)
        out = {}
        for l, (cidx, _) in enumerate(levels):
            lv = [levels[i][0] for i in range(l + 1)]
            hp = _er.ell_level_reduce(
                ell, "max",
                p_fns=[comps_by_idx[c].p_fn for c in lv],
                states=[state_d[c] for c in lv],
                idents=[comps_by_idx[c].ident for c in lv],
                active=active_i32, outdeg=out_deg_pad,
                bests=[], mode="nonbot", block_v=block_v, block_e=block_e,
                interpret=interpret)
            out[cidx] = hp.astype(bool)
        return out

    ones_active = jnp.ones(n_pad, jnp.int32)

    def body(carry):
        state, active, k, work = carry
        state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
        if idempotent:
            active_i32 = active.astype(jnp.int32)
            work = work + jnp.sum(out_deg_real * active.astype(jnp.float32))
            red = {}
            for p in plans:
                red.update(run_plan(p, state_d, active_i32))
            new_d = {}
            for p in plans:
                new_d.update(iterate.plan_merge(p, state_d, red, comps_by_idx))
        else:
            work = work + jnp.float32(g.num_edges)
            red = {}
            for p in plans:
                red.update(run_plan(p, state_d, ones_active))
            red = iterate._apply_epilogue(comps, red)
            has_pred = {}
            for p in plans:
                for cidx, _ in _plan_levels(p):
                    has_pred.update(has_pred_of(Prim("max", cidx), state_d,
                                                ones_active))
            new_d = iterate._recompute_merge(plans, comps_by_idx, state_d,
                                             red, has_pred)
        new = tuple(new_d[cr.idx] for cr in comps)
        ch = iterate._changed(comps, new, state, tol)
        return new, ch, k + 1, work

    def cond(carry):
        _, active, k, _ = carry
        return jnp.any(active) & (k < max_iter)

    state0 = init_state()
    state, active, k, work = jax.lax.while_loop(
        cond, body, (state0, jnp.ones(n_pad, bool), jnp.int32(0),
                     jnp.float32(0)))
    return iterate.IterationResult(
        state=tuple(s[:n] for s in state), iterations=int(k),
        edge_work=float(work))
