"""Jit'd dispatch wrappers around the Pallas kernels.

``iterate_pallas`` is the direction-optimized GraphIt/Gemini-analogue engine
(DESIGN.md §2): the same fixpoint semantics as ``iterate.iterate_graph`` but
with every edge sweep executed by a blocked-ELL Pallas kernel.  One engine
iteration executes exactly ONE ``pallas_call`` — either the pull sweep
(``fused_ell_sweep``: dst-keyed gather over predecessor tiles) or the push
sweep (``fused_ell_push_sweep``: source-keyed propagate over frontier-active
row tiles) — chosen per iteration by a Gemini-style frontier-density
heuristic when ``direction="auto"``.  Both sweeps produce the identity-
initialised per-plan reduction that ``iterate.plan_merge`` resolves against
the old state, so the direction switch is invisible to the plan algebra.
Non-idempotent rounds always run the pull− full recompute (has-pred probe
fused in the same launch) unless the push direction is forced, in which
case the push− scatter recompute runs instead.

The fixpoint itself is compiled once per (plan structure, kernel set,
graph shape, direction) and memoized in ``_EXEC_CACHE``: repeated queries,
multi-round programs (RDS, Trust) and benchmark repeats reuse the traced
``lax.while_loop`` instead of rebuilding it per call (DESIGN.md §8).

The other wrappers expose the embedding-bag and ELL-softmax kernels behind
plain jit'd functions that the models call.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import iterate
from repro.core.fusion import Lex
from repro.graph.structure import Graph, blocked_ell_cached
from repro.kernels import edge_reduce as _er
from repro.kernels import embedding_bag as _eb
from repro.kernels import segment_softmax as _ss

embedding_bag = jax.jit(_eb.embedding_bag,
                        static_argnames=("mode", "block_b", "block_d",
                                         "interpret"))
ell_softmax = jax.jit(_ss.ell_softmax,
                      static_argnames=("block_v", "block_e", "interpret"))


def _plan_levels(plan):
    levels = []
    p = plan
    while isinstance(p, Lex):
        levels.append((p.comp, p.op))
        p = p.secondary
    levels.append((p.comp, p.op))
    return levels


# ---------------------------------------------------------------------------
# Compiled-executor cache.
# ---------------------------------------------------------------------------

_EXEC_CACHE: dict = {}
_EXEC_CACHE_MAX = 128


def clear_executor_cache():
    _EXEC_CACHE.clear()


def executor_cache_size() -> int:
    return len(_EXEC_CACHE)


def _comps_key(comps):
    """Kernel-set identity: stable across calls because synthesize_round
    memoizes its compiled closures per round structure."""
    return tuple((cr.idx, cr.op, str(cr.dtype), cr.source,
                  id(cr.p_fn), id(cr.init_fn),
                  None if cr.e_fn is None else id(cr.e_fn)) for cr in comps)


DENSE_FRONTIER = 0.05      # Gemini switch point: frontier fraction above
                           # which the pull sweep wins (dense reads beat
                           # frontier-proportional row skipping)


def _directions_used(direction: str, idempotent: bool):
    """Which sweep layouts an executor needs.  The heuristic only arbitrates
    idempotent (+model) rounds — Gemini's precondition: both directions must
    be admissible, which the push+/push− conditions (Defs. 3/4, checked by
    core/conditions via the shared plan algebra) grant exactly when pull's
    are.  Non-idempotent rounds run one full-recompute direction."""
    if direction == "auto":
        return ("pull", "push") if idempotent else ("pull",)
    if direction == "pull":
        return ("pull",)
    if direction == "push":
        return ("push",)
    raise ValueError(f"direction must be auto|pull|push, got {direction!r}")


def _build_pallas_executor(comps, plans, n, max_iter, tol, block_v, block_e,
                           interpret, use, dense_threshold):
    """Trace + jit the whole fixpoint once.  The returned function takes the
    blocked-ELL arrays (one 5-tuple per direction in ``use``, pull first)
    and out-degrees as arguments (NOT closure constants), so one compiled
    executor serves every graph with the same padded shapes.

    ``use`` = ("pull",) | ("push",) | ("pull", "push"); with both, each
    iteration picks its sweep by frontier density via ``lax.cond`` — both
    branches trace (two pallas_calls appear in the HLO) but exactly one
    executes per iteration at runtime."""
    comps_by_idx = {cr.idx: cr for cr in comps}
    plan_levels = tuple(tuple(_plan_levels(p)) for p in plans)
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    comps_order = _er.comps_in_plan_order(plan_levels)
    idents = {c: comps_by_idx[c].ident for c in comps_order}
    p_fns = {c: comps_by_idx[c].p_fn for c in comps_order}

    def run(*arrays):
        ell = {d: arrays[5 * i:5 * i + 5] for i, d in enumerate(use)}
        out_deg = arrays[5 * len(use)]
        n_pad = ell[use[0]][0].shape[0]
        out_deg_pad = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            jnp.maximum(out_deg, 1).astype(jnp.float32))
        num_edges = jnp.sum(ell[use[0]][3].astype(jnp.float32))
        ones_act = jnp.ones(n_pad, jnp.int32)

        def pad_state(x, ident):
            return jnp.full((n_pad,), ident, x.dtype).at[:n].set(x)

        def init_state():
            base = iterate._init_state(comps, n)
            return tuple(pad_state(s, cr.ident)
                         for s, cr in zip(base, comps))

        def sweep(d, state_d, active_i32, tile_act, need_hp):
            nbrs, weight, capacity, mask, _nnz = ell[d]
            fn = _er.fused_ell_sweep if d == "pull" else _er.fused_ell_push_sweep
            states = {c: state_d[c] for c in comps_order}
            return fn(nbrs, weight, capacity, mask, tile_act, states,
                      active_i32, out_deg_pad, plans=plan_levels,
                      idents=idents, p_fns=p_fns, nv=float(n),
                      need_haspred=need_hp, block_v=block_v, block_e=block_e,
                      interpret=interpret)

        def masked_branch(d):
            """One frontier-masked (+model) sweep in direction ``d``; edge
            work is the real slots inside the tiles actually processed."""
            def branch(args):
                state_d, active_i32 = args
                nbrs, _w, _c, mask, tile_nnz = ell[d]
                if d == "pull":
                    tile_act = _er.tile_activity(nbrs, mask, tile_nnz,
                                                 active_i32, block_v, block_e)
                else:
                    tile_act = _er.tile_activity_push(tile_nnz, active_i32,
                                                      block_v)
                red, _ = sweep(d, state_d, active_i32, tile_act, False)
                w_inc = jnp.sum((tile_nnz * tile_act)).astype(jnp.float32)
                return tuple(red[c] for c in comps_order), w_inc
            return branch

        def body(carry):
            state, active, k, work, pushes = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            if idempotent:
                active_i32 = active.astype(jnp.int32)
                if len(use) == 2:
                    # Gemini heuristic: sparse frontier → push (work ∝
                    # active rows), dense frontier → pull (gather tiles).
                    # Density over the LOGICAL vertex count — padding rows
                    # (never active after iteration 1) must not dilute it.
                    frac = jnp.sum(active.astype(jnp.float32)) / n
                    use_push = frac <= dense_threshold
                    red_t, w_inc = jax.lax.cond(
                        use_push, masked_branch("push"), masked_branch("pull"),
                        (state_d, active_i32))
                    pushes = pushes + use_push.astype(jnp.int32)
                else:
                    red_t, w_inc = masked_branch(use[0])((state_d, active_i32))
                    pushes = pushes + (1 if use[0] == "push" else 0)
                red = {c: red_t[i] for i, c in enumerate(comps_order)}
                work = work + w_inc
                new_d = {}
                for p in plans:
                    new_d.update(iterate.plan_merge(p, state_d, red,
                                                    comps_by_idx))
            else:
                # full recompute (− models): has-pred probe in the same
                # launch; only all-padding tiles skip.
                d = use[0]
                work = work + num_edges
                tiles_static = (ell[d][4] > 0).astype(jnp.int32)
                red, hp = sweep(d, state_d, ones_act, tiles_static, True)
                red = iterate._apply_epilogue(comps, red)
                new_d = iterate._recompute_merge(plans, comps_by_idx,
                                                 state_d, red, hp)
                pushes = pushes + (1 if d == "push" else 0)
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = iterate._changed(comps, new, state, tol)
            return new, ch, k + 1, work, pushes

        def cond(carry):
            _, active, k, _, _ = carry
            return jnp.any(active) & (k < max_iter)

        state0 = init_state()
        state, active, k, work, pushes = jax.lax.while_loop(
            cond, body, (state0, jnp.ones(n_pad, bool), jnp.int32(0),
                         jnp.float32(0), jnp.int32(0)))
        return state, k, work, pushes

    return jax.jit(run)


def iterate_pallas(g: Graph, comps, plans, max_iter: Optional[int] = None,
                   tol: float = 0.0, block_v: int = 8, block_e: int = 128,
                   interpret: Optional[bool] = None, direction: str = "auto",
                   dense_threshold: float = DENSE_FRONTIER) -> iterate.IterationResult:
    """Fixpoint of the fused reduction with single-launch Pallas edge sweeps.

    ``direction`` selects the sweep model per DESIGN.md §2:

      "auto"  (default) Gemini-style: idempotent rounds pick push vs pull
              per iteration from the frontier density; non-idempotent
              rounds run pull− full recompute.
      "pull"  dst-keyed gather sweeps only (Def. 1 / Def. 2).
      "push"  src-keyed scatter sweeps only (Def. 3 / Def. 4).

    The returned result carries ``pull_iters``/``push_iters`` — the runtime
    per-direction iteration counts — which are also accumulated into
    ``edge_reduce.SWEEP_STATS`` for benchmarks.
    """
    n = g.n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    use = _directions_used(direction, idempotent)
    ells = {"pull": blocked_ell_cached(g, block_v=block_v, block_e=block_e,
                                       direction="in") if "pull" in use else None,
            "push": blocked_ell_cached(g, block_v=block_v, block_e=block_e,
                                       direction="out") if "push" in use else None}
    key = (n, tuple(tuple(_plan_levels(p)) for p in plans), _comps_key(comps),
           max_iter, tol, block_v, block_e, interpret, use, dense_threshold)
    run = _EXEC_CACHE.get(key)
    if run is None:
        while len(_EXEC_CACHE) >= _EXEC_CACHE_MAX:     # evict oldest entry
            _EXEC_CACHE.pop(next(iter(_EXEC_CACHE)))
        run = _build_pallas_executor(comps, plans, n, max_iter, tol,
                                     block_v, block_e, interpret, use,
                                     dense_threshold)
        _EXEC_CACHE[key] = run
    args = []
    for d in use:
        e = ells[d]
        args += [e.nbrs, e.weight, e.capacity, e.mask, e.tile_nnz]
    args.append(g.out_deg)
    state, k, work, pushes = run(*args)
    k_i = iterate._host(k, int)
    p_i = iterate._host(pushes, int)
    if isinstance(k_i, int) and isinstance(p_i, int):
        _er.SWEEP_STATS["push_iters"] += p_i
        _er.SWEEP_STATS["pull_iters"] += k_i - p_i
    res = iterate.IterationResult(
        state=tuple(s[:n] for s in state),
        iterations=k_i,
        edge_work=iterate._host(work, float))
    res.push_iters = p_i
    res.pull_iters = k_i - p_i        # valid for ints and tracers alike
    return res
