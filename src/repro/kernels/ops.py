"""Jit'd dispatch wrappers around the Pallas kernels.

``iterate_pallas`` is the direction-optimized GraphIt/Gemini-analogue engine
(DESIGN.md §2): the same fixpoint semantics as ``iterate.iterate_graph`` but
with every edge sweep executed by a blocked-ELL Pallas kernel.  One engine
iteration executes exactly ONE ``pallas_call`` — either the pull sweep
(``fused_ell_sweep``: dst-keyed gather over predecessor tiles) or the push
sweep (``fused_ell_push_sweep``: source-keyed propagate over frontier-active
row tiles, dst-keyed resolution through the dst-sorted segment layout by
default — ``push_resolution="sorted"``, one extra frontier-proportional
resolution tile pass; ``"scatter"`` keeps the reference full-rectangle
scatter) — chosen per iteration by the Gemini |E_frontier| ≤ |E|/k rule
when ``direction="auto"`` (``switch_k`` tunes k per query; ``switch_k=None``
falls back to the ``DENSE_FRONTIER`` vertex-fraction threshold).  Both
sweeps produce the identity-initialised per-plan reduction that
``iterate.plan_merge`` resolves against the old state, so the direction
switch is invisible to the plan algebra.
Non-idempotent rounds always run the pull− full recompute (has-pred probe
fused in the same launch) unless the push direction is forced, in which
case the push− scatter recompute runs instead.

The fixpoint itself is compiled once per (plan structure, kernel set,
graph shape, direction) and memoized in ``_EXEC_CACHE`` — a true LRU keyed
WITHOUT the query source: the source vertex enters the compiled program as
a traced argument (``run(*arrays, srcs)``), not a closure constant, so a
32-source BFS/SSSP sweep reuses ONE traced ``lax.while_loop`` instead of
retracing per source (DESIGN.md §8).  ``iterate_pallas_batch`` goes one
step further and ``jax.vmap``s the same fixpoint over a batch of sources
sharing one blocked-ELL layout: B concurrent queries per launch, per-query
convergence via the existing active mask (DESIGN.md §9).

``iterate_pallas_sharded`` composes this engine with the distributed
vertex-cut model (DESIGN.md §11): every shard holds its own blocked-ELL
pair (``structure.sharded_ell_cached``), runs the SAME fused sweeps
shard-locally inside ``shard_map`` — including the dst-sorted push
resolution over each shard's own ``PushResolution`` stack
(``structure.sharded_push_resolution_cached``) — and merges per-vertex
partials with monoid/lex collectives; the direction switch stays global
via a psum'd frontier edge mass, so the sharded fixpoint walks the exact
iteration sequence of the single-device one.

The other wrappers expose the embedding-bag and ELL-softmax kernels behind
plain jit'd functions that the models call.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import iterate
from repro.core.fusion import Lex
from repro.graph import segment
from repro.graph.structure import (Graph, blocked_ell_cached,
                                   push_resolution_cached,
                                   sharded_ell_cached,
                                   sharded_push_resolution_cached, w_out_deg)
from repro.kernels import edge_reduce as _er
from repro.kernels import embedding_bag as _eb
from repro.kernels import segment_softmax as _ss

embedding_bag = jax.jit(_eb.embedding_bag,
                        static_argnames=("mode", "block_b", "block_d",
                                         "interpret"))
ell_softmax = jax.jit(_ss.ell_softmax,
                      static_argnames=("block_v", "block_e", "interpret"))


def _plan_levels(plan):
    levels = []
    p = plan
    while isinstance(p, Lex):
        levels.append((p.comp, p.op))
        p = p.secondary
    levels.append((p.comp, p.op))
    return levels


# ---------------------------------------------------------------------------
# Compiled-executor cache (true LRU, source-free keys).
# ---------------------------------------------------------------------------

_EXEC_CACHE: OrderedDict = OrderedDict()
_EXEC_CACHE_MAX = 128


def clear_executor_cache():
    _EXEC_CACHE.clear()


def executor_cache_size() -> int:
    return len(_EXEC_CACHE)


def _exec_cache_get(key):
    hit = _EXEC_CACHE.get(key)
    if hit is None:
        return None
    _EXEC_CACHE.move_to_end(key)       # hits refresh recency: under serving
    return hit[0]                      # churn the hot executor survives


def _exec_cache_put(key, run, comps) -> None:
    while len(_EXEC_CACHE) >= _EXEC_CACHE_MAX:
        _EXEC_CACHE.popitem(last=False)          # evict least-recently-USED
    # The key carries id(p_fn)/id(init_fn)/id(e_fn).  Keep strong references
    # to exactly those closures in the value so a GC'd kernel set can never
    # hand its id to a new closure while the entry is alive (the id-reuse
    # hazard structure.blocked_ell_cached guards with a weakref; functions
    # are tiny, so pinning them is the simpler mirror).
    keyed = tuple((cr.p_fn, cr.init_fn, cr.e_fn) for cr in comps)
    _EXEC_CACHE[key] = (run, keyed)


def _comps_key(comps):
    """Kernel-set identity: stable across calls because synthesize_round
    memoizes its compiled closures per round structure.  The source VALUE is
    deliberately absent — it is a traced argument of the executor, so every
    query source shares one entry; only sourced-ness (the ⊥-masking shape of
    the initial state) is structural."""
    return tuple((cr.idx, cr.op, str(cr.dtype), cr.source is not None,
                  id(cr.p_fn), id(cr.init_fn),
                  None if cr.e_fn is None else id(cr.e_fn)) for cr in comps)


# Knob semantics live in the planner (core.plan, DESIGN.md §14) — ops
# re-exports the documented constants and normalizers for direct kernel
# callers; engine-level callers arrive with an already-normalized
# ExecutionPlan whose fields are asserted (never re-parsed) below.
from repro.core.plan import (DENSE_FRONTIER,            # noqa: E402
                             PUSH_RESOLUTION, SWITCH_K, _check_resolution,
                             _normalize_switch_k, assert_normalized)


def _apply_plan(plan, direction, dense_threshold, switch_k, push_resolution,
                idempotent):
    """Resolve the direction-switch/resolution knobs of one kernels call:
    from an ``ExecutionPlan`` (fields pre-normalized by ``plan_execution`` —
    asserted here) when the engine lowered through the planner, else by
    normalizing the legacy kwargs exactly as before.  Returns
    ``(use, dense_threshold, switch_k, push_resolution)``."""
    if plan is not None:
        assert_normalized(plan)
        use = _directions_used(plan.direction, idempotent)
        return use, plan.dense_threshold, plan.switch_k, plan.push_resolution
    use = _directions_used(direction, idempotent)
    # the dense_threshold-vs-Gemini conflict only exists when a switch is
    # actually traced; pinned directions ignore both knobs
    switch_k = _normalize_switch_k(
        switch_k, dense_threshold if len(use) == 2 else DENSE_FRONTIER)
    return use, dense_threshold, switch_k, _check_resolution(push_resolution)


def _directions_used(direction: str, idempotent: bool):
    """Which sweep layouts an executor needs.  The heuristic only arbitrates
    idempotent (+model) rounds — Gemini's precondition: both directions must
    be admissible, which the push+/push− conditions (Defs. 3/4, checked by
    core/conditions via the shared plan algebra) grant exactly when pull's
    are.  Non-idempotent rounds run one full-recompute direction."""
    if direction == "auto":
        return ("pull", "push") if idempotent else ("pull",)
    if direction == "pull":
        return ("pull",)
    if direction == "push":
        return ("push",)
    raise ValueError(f"direction must be auto|pull|push, got {direction!r}")


def _padded_init_state(comps, n, n_pad, srcs):
    """Initial per-component state padded to the layout rectangle, with the
    traced per-component sources applied (the executor-argument contract of
    DESIGN.md §8).  Shared by the single-device and sharded builders so
    their fixpoints can never diverge on the C1/C2 initial state."""
    overrides = {cr.idx: srcs[i] for i, cr in enumerate(comps)
                 if cr.source is not None}
    base = iterate._init_state(comps, n, overrides)
    return tuple(jnp.full((n_pad,), cr.ident, s.dtype).at[:n].set(s)
                 for s, cr in zip(base, comps))


def _build_pallas_executor(comps, plans, n, max_iter, tol, block_v, block_e,
                           interpret, use, dense_threshold, switch_k,
                           push_resolution, batch=False, sentinel=True,
                           chunked=False, warm=False):
    """Trace + jit the whole fixpoint once.  The returned function takes the
    blocked-ELL arrays (one 5-tuple per direction in ``use``, pull first),
    out-degrees (plain + weighted), the dst-sorted resolution arrays (when
    the push direction resolves ``"sorted"``), AND the per-component query
    sources as arguments (NOT closure constants): ``run(*arrays, srcs)``
    with ``srcs`` an [n_comps] int32 vector, so one compiled executor serves
    every graph with the same padded shapes and EVERY query source without
    retracing.  It returns the full exit diagnostics
    ``(state, k, work, pushes, res_work, gather_work, div, resid,
    active_n)``.

    ``use`` = ("pull",) | ("push",) | ("pull", "push"); with both, each
    iteration picks its sweep via ``lax.cond`` — both branches trace (two
    pallas_calls appear in the HLO) but exactly one executes per iteration
    at runtime.  The switch is the Gemini rule when ``switch_k`` is a
    number (push while Σ out_deg over the frontier ≤ |E|/k) and the
    legacy frontier-fraction threshold when ``switch_k`` is None.

    With ``batch=True`` the same fixpoint is ``jax.vmap``ped over a leading
    source axis (``srcs`` [B, n_comps]; the ELL arrays stay shared): state
    and frontier grow a batch dimension, the while_loop's batching rule
    keeps per-query convergence exact (converged queries stop updating via
    the per-element carry select), and the direction lax.cond lowers to a
    per-query select — bit-identical to the sequential runs (DESIGN.md §9).

    ``sentinel`` folds the NaN/Inf divergence sentinel + last-iteration
    residual into the loop carry (elementwise reductions, zero extra
    launches); off, the carry keeps constant placeholders so both variants
    share one signature.

    With ``warm=True`` (batch only) the vmapped fixpoint additionally takes
    one per-component ``[B, n]`` state block after ``srcs`` —
    ``run(*arrays, srcs, *state0)`` — and overrides each batch element's
    initial state rows with its own supplied block (padding rows keep the
    reduction identity, the frontier resets to all-ones exactly like
    ``_warm_start_carry``).  This is the continuous-batching join point
    (DESIGN.md §13): unconverged queries resume from their last chunk's
    state while fresh joiners ride in with their C1/C2 init rows, all in
    the same launch.

    With ``chunked=True`` the SAME traced body is exposed as a host-steppable
    pair ``(init, step)``: ``init(*arrays, srcs)`` builds the initial carry,
    ``step(*arrays, carry, k_stop)`` advances the while_loop until ``k ==
    k_stop`` or quiescence.  The loop body is the identical jaxpr in both
    variants and the carry crosses the host boundary as concrete buffers, so
    a chunked run visits the exact iteration sequence of the monolithic one
    and stays bitwise-identical (DESIGN.md §12) — which is what lets long
    fixpoints snapshot through CheckpointManager and warm-resume."""
    comps_by_idx = {cr.idx: cr for cr in comps}
    plan_levels = tuple(tuple(_plan_levels(p)) for p in plans)
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    comps_order = _er.comps_in_plan_order(plan_levels)
    idents = {c: comps_by_idx[c].ident for c in comps_order}
    p_fns = {c: comps_by_idx[c].p_fn for c in comps_order}
    sorted_res = push_resolution == "sorted" and "push" in use

    def _split(arrays):
        """(ELL dict, out_deg, wdeg, resolution arrays|None, rest)."""
        ell = {d: arrays[5 * i:5 * i + 5] for i, d in enumerate(use)}
        idx = 5 * len(use)
        out_deg = arrays[idx]
        wdeg = arrays[idx + 1]
        idx += 2
        res = None
        if sorted_res:
            res = arrays[idx:idx + 4]
            idx += 4
        return ell, out_deg, wdeg, res, arrays[idx:]

    def _fixpoint(arrays, carry0, k_stop):
        """Run the while_loop from ``carry0`` until quiescence or ``k ==
        k_stop`` — THE single traced body both the monolithic executor
        (k_stop = max_iter, static) and the chunked stepper (k_stop traced)
        share."""
        ell, out_deg, wdeg, res_arrays, _ = _split(arrays)
        if sorted_res:
            res_in2out, res_valid, res_contrib, res_nnz = res_arrays
        n_pad = ell[use[0]][0].shape[0]
        out_deg_pad = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            jnp.maximum(out_deg, 1).astype(jnp.float32))
        # UNclamped degrees for the Gemini |E_frontier| estimate: the clamp
        # exists for PageRank division, but zero-out-degree vertices carry
        # zero frontier edges and must not inflate the switch signal.
        out_deg_raw = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            out_deg.astype(jnp.float32))
        wdeg_pad = jnp.ones(n_pad, jnp.float32).at[:n].set(
            wdeg.astype(jnp.float32))
        num_edges = jnp.sum(ell[use[0]][3].astype(jnp.float32))
        ones_act = jnp.ones(n_pad, jnp.int32)

        def sweep(d, state_d, active_i32, tile_act, need_hp):
            """One fused sweep + its dst-keyed resolution.  Returns
            (red, hp, resolution edge work, gather work): 0/0 for pull (the
            cross-tile fold is O(n_pad·n_tiles) elementwise — not edge
            work), the kept resolution tiles' Σ nnz for sorted push (the
            in-kernel gather reads exactly those slots — skipped tiles move
            zero candidate bytes), and rectangle/0 for the reference
            scatter (full-rectangle work, no permutation gather)."""
            nbrs, weight, capacity, mask, _nnz = ell[d]
            states = {c: state_d[c] for c in comps_order}
            common = dict(plans=plan_levels, idents=idents, p_fns=p_fns,
                          nv=float(n), need_haspred=need_hp, wdeg=wdeg_pad,
                          block_v=block_v, block_e=block_e,
                          interpret=interpret)
            if d == "pull":
                red, hp = _er.fused_ell_sweep(
                    nbrs, weight, capacity, mask, tile_act, states,
                    active_i32, out_deg_pad, **common)
                return red, hp, jnp.float32(0), jnp.float32(0)
            if sorted_res:
                res_tile_act = _er.resolution_tile_activity(
                    res_contrib, tile_act, res_nnz)
                red, hp = _er.fused_ell_push_sweep(
                    nbrs, weight, capacity, mask, tile_act, states,
                    active_i32, out_deg_pad, resolution="sorted",
                    res=(res_in2out, res_valid, res_tile_act), **common)
                res_w = jnp.sum(res_nnz * res_tile_act).astype(jnp.float32)
                return red, hp, res_w, res_w
            red, hp = _er.fused_ell_push_sweep(
                nbrs, weight, capacity, mask, tile_act, states,
                active_i32, out_deg_pad, resolution="scatter", **common)
            return (red, hp, jnp.float32(nbrs.shape[0] * nbrs.shape[1]),
                    jnp.float32(0))

        def masked_branch(d):
            """One frontier-masked (+model) sweep in direction ``d``; edge
            work is the real slots inside the tiles actually processed."""
            def branch(args):
                state_d, active_i32 = args
                nbrs, _w, _c, mask, tile_nnz = ell[d]
                if d == "pull":
                    tile_act = _er.tile_activity(nbrs, mask, tile_nnz,
                                                 active_i32, block_v, block_e)
                else:
                    tile_act = _er.tile_activity_push(tile_nnz, active_i32,
                                                      block_v)
                red, _, res_w, gat_w = sweep(d, state_d, active_i32, tile_act,
                                             False)
                w_inc = jnp.sum((tile_nnz * tile_act)).astype(jnp.float32)
                return tuple(red[c] for c in comps_order), w_inc, res_w, gat_w
            return branch

        def body(carry):
            (state, active, k, work, pushes, res_work, gather_work, div,
             resid) = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            if idempotent:
                active_i32 = active.astype(jnp.int32)
                if len(use) == 2:
                    # Direction switch: sparse frontier → push (work ∝
                    # active rows), dense frontier → pull (gather tiles).
                    if switch_k is not None:
                        # Gemini rule: compare the frontier's outgoing
                        # EDGE mass against |E|/k — degree data already in
                        # the layout.  Padding rows carry 0 in out_deg_raw.
                        e_frontier = jnp.sum(active.astype(jnp.float32)
                                             * out_deg_raw)
                        use_push = e_frontier <= num_edges / switch_k
                    else:
                        # documented fallback: frontier VERTEX fraction
                        # over the logical vertex count (padding rows,
                        # never active after iteration 1, must not dilute).
                        frac = jnp.sum(active.astype(jnp.float32)) / n
                        use_push = frac <= dense_threshold
                    red_t, w_inc, res_w, gat_w = jax.lax.cond(
                        use_push, masked_branch("push"), masked_branch("pull"),
                        (state_d, active_i32))
                    pushes = pushes + use_push.astype(jnp.int32)
                else:
                    red_t, w_inc, res_w, gat_w = masked_branch(use[0])(
                        (state_d, active_i32))
                    pushes = pushes + (1 if use[0] == "push" else 0)
                red = {c: red_t[i] for i, c in enumerate(comps_order)}
                work = work + w_inc
                res_work = res_work + res_w
                gather_work = gather_work + gat_w
                new_d = {}
                for p in plans:
                    new_d.update(iterate.plan_merge(p, state_d, red,
                                                    comps_by_idx))
            else:
                # full recompute (− models): has-pred probe in the same
                # launch; only all-padding tiles skip.
                d = use[0]
                work = work + num_edges
                tiles_static = (ell[d][4] > 0).astype(jnp.int32)
                red, hp, res_w, gat_w = sweep(d, state_d, ones_act,
                                              tiles_static, True)
                res_work = res_work + res_w
                gather_work = gather_work + gat_w
                red = iterate._apply_epilogue(comps, red)
                new_d = iterate._recompute_merge(plans, comps_by_idx,
                                                 state_d, red, hp)
                pushes = pushes + (1 if d == "push" else 0)
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = iterate._changed(comps, new, state, tol)
            if sentinel:
                # fold divergence + residual into the existing carry: pure
                # elementwise reductions, no extra kernel launches.  A fired
                # sentinel drains the frontier so the loop exits on its own
                # condition.
                div = div | iterate._divergence(comps, new)
                resid = iterate._residual(comps, new, state)
                ch = ch & ~div
            return (new, ch, k + 1, work, pushes, res_work, gather_work,
                    div, resid)

        def cond(carry):
            _, active, k, _, _, _, _, _, _ = carry
            return jnp.any(active) & (k < k_stop)

        return jax.lax.while_loop(cond, body, carry0)

    def _init(arrays):
        """Initial carry from the shared arrays (+ srcs, the trailing one)."""
        ell, _, _, _, rest = _split(arrays)
        srcs = rest[0]
        n_pad = ell[use[0]][0].shape[0]
        state0 = _padded_init_state(comps, n, n_pad, srcs)
        return (state0, jnp.ones(n_pad, bool), jnp.int32(0),
                jnp.float32(0), jnp.int32(0), jnp.float32(0),
                jnp.float32(0), jnp.asarray(False), jnp.float32(0))

    def run(*arrays):
        carry = _fixpoint(arrays, _init(arrays), max_iter)
        (state, active, k, work, pushes, res_work, gather_work, div,
         resid) = carry
        active_n = jnp.sum(active[:n].astype(jnp.int32))
        return (state, k, work, pushes, res_work, gather_work, div, resid,
                active_n)

    if warm and not batch:
        raise ValueError("warm start rows are a batched-executor feature; "
                         "single queries warm-start via init_state= on the "
                         "chunked path")
    if chunked:
        if batch:
            raise ValueError("chunked execution does not batch")

        def step(*args_carry):
            *arrays, carry, k_stop = args_carry
            return _fixpoint(tuple(arrays), carry, k_stop)

        def init(*arrays):
            return _init(tuple(arrays))

        return jax.jit(init), jax.jit(step)
    if batch:
        # everything but srcs (ELL tuples, degrees, resolution arrays) is
        # shared across the batch
        n_shared = 5 * len(use) + 2 + (4 if sorted_res else 0)
        if warm:
            def run_warm(*all_args):
                arrays = all_args[:n_shared + 1]      # shared + this row's srcs
                state0 = all_args[n_shared + 1:]      # per-component [n] rows
                (st, active, k, work, pushes, res_work, gather_work, div,
                 resid) = _init(arrays)
                st = tuple(ref.at[:n].set(s.astype(ref.dtype))
                           for ref, s in zip(st, state0))
                carry = _fixpoint(
                    arrays, (st, active, k, work, pushes, res_work,
                             gather_work, div, resid), max_iter)
                (state, active, k, work, pushes, res_work, gather_work, div,
                 resid) = carry
                active_n = jnp.sum(active[:n].astype(jnp.int32))
                return (state, k, work, pushes, res_work, gather_work, div,
                        resid, active_n)

            return jax.jit(jax.vmap(
                run_warm,
                in_axes=(None,) * n_shared + (0,) * (1 + len(comps))))
        return jax.jit(jax.vmap(run, in_axes=(None,) * n_shared + (0,)))
    return jax.jit(run)


def _srcs_vector(comps, sources=None):
    """Per-component source ids as an [n_comps] int32 vector: the executor's
    traced source argument.  ``sources`` optionally overrides ``cr.source``
    per component index (sourced components only — sourced-ness is
    structural); sourceless components carry an ignored −1 placeholder."""
    vals = []
    for cr in comps:
        if cr.source is None:
            vals.append(-1)
        elif sources is not None and cr.idx in sources:
            vals.append(int(sources[cr.idx]))
        else:
            vals.append(int(cr.source))
    return jnp.asarray(vals, jnp.int32)


def _pallas_executor(g, comps, plans, max_iter, tol, block_v, block_e,
                     interpret, use, dense_threshold, switch_k,
                     push_resolution, batch=False, sentinel=True,
                     chunked=False, warm=False):
    """Cache lookup / build of the compiled fixpoint, plus the shared
    argument prefix (ELL arrays + degree vectors + dst-sorted resolution
    arrays) it runs on."""
    ells = {"pull": blocked_ell_cached(g, block_v=block_v, block_e=block_e,
                                       direction="in") if "pull" in use else None,
            "push": blocked_ell_cached(g, block_v=block_v, block_e=block_e,
                                       direction="out") if "push" in use else None}
    # Normalize knobs a pinned executor never reads out of its cache key,
    # so e.g. model="pull" runs with different push_resolution values share
    # one compiled entry instead of retracing per knob.
    if len(use) != 2:                # pinned direction: no switch traced
        dense_threshold = None
        switch_k = None
    if "push" not in use:            # no push sweep: no resolution traced
        push_resolution = "unused"
    res = push_resolution_cached(g, block_v=block_v, block_e=block_e) \
        if (push_resolution == "sorted" and "push" in use) else None
    key = (g.n, tuple(tuple(_plan_levels(p)) for p in plans),
           _comps_key(comps), max_iter, tol, block_v, block_e, interpret,
           use, dense_threshold, switch_k, push_resolution, batch,
           sentinel, chunked, warm)
    run = _exec_cache_get(key)
    if run is None:
        run = _build_pallas_executor(comps, plans, g.n, max_iter, tol,
                                     block_v, block_e, interpret, use,
                                     dense_threshold, switch_k,
                                     push_resolution, batch=batch,
                                     sentinel=sentinel, chunked=chunked,
                                     warm=warm)
        _exec_cache_put(key, run, comps)
    args = []
    for d in use:
        e = ells[d]
        args += [e.nbrs, e.weight, e.capacity, e.mask, e.tile_nnz]
    args.append(g.out_deg)
    args.append(w_out_deg(g))
    if res is not None:
        args += [res.in2out, res.valid, res.contrib, res.tile_nnz]
    return run, args


def _fixpoint_fingerprint(g, comps, plans, use, max_iter, tol, block_v,
                          block_e, push_resolution, switch_k, srcs):
    """JSON-able identity of a chunked fixpoint: a checkpoint written under
    one fingerprint must never warm-resume an executor built for another
    (different graph, plan structure, query sources, or knobs would silently
    continue a DIFFERENT query — ``CheckpointMismatchError`` instead)."""
    return {
        "n": int(g.n), "num_edges": int(g.num_edges),
        "plans": repr(tuple(tuple(_plan_levels(p)) for p in plans)),
        "comps": repr(tuple((cr.idx, cr.op, str(np.dtype(cr.dtype)),
                             cr.e_fn is not None) for cr in comps)),
        "use": list(use), "max_iter": int(max_iter), "tol": float(tol),
        "block_v": int(block_v), "block_e": int(block_e),
        "push_resolution": str(push_resolution),
        "switch_k": None if switch_k is None else float(switch_k),
        "srcs": [int(s) for s in np.asarray(srcs)],
    }


def _warm_start_carry(carry, comps, init_state, n):
    """Override the initial carry's state with user-supplied per-component
    [n] arrays (the warm-start primitive): padding rows keep the reduction
    identity, the frontier resets to all-ones so the first sweep re-derives
    the true active set from the supplied state."""
    (state0, active, k, work, pushes, res_work, gather_work, div,
     resid) = carry
    init_state = tuple(init_state)
    if len(init_state) != len(comps):
        raise ValueError(
            f"init_state has {len(init_state)} arrays for "
            f"{len(comps)} components")
    new_state = []
    for ref, cr, arr in zip(state0, comps, init_state):
        a = jnp.asarray(arr, dtype=ref.dtype)
        if a.shape != (n,):
            raise ValueError(
                f"init_state for component {cr.idx} has shape {a.shape}, "
                f"expected ({n},)")
        new_state.append(ref.at[:n].set(a))
    return (tuple(new_state), active, k, work, pushes, res_work, gather_work,
            div, resid)


def iterate_pallas(g: Graph, comps, plans, max_iter: Optional[int] = None,
                   tol: float = 0.0, block_v: int = 8, block_e: int = 128,
                   interpret: Optional[bool] = None, direction: str = "auto",
                   dense_threshold: float = DENSE_FRONTIER,
                   switch_k="auto", push_resolution: str = PUSH_RESOLUTION,
                   sources: Optional[dict] = None,
                   divergence_sentinel: bool = True,
                   init_state=None, delta=None,
                   checkpoint_every: Optional[int] = None,
                   ckpt_dir=None, resume: bool = False,
                   fault_hook=None, plan=None) -> iterate.IterationResult:
    """Fixpoint of the fused reduction with single-launch Pallas edge sweeps.

    ``direction`` selects the sweep model per DESIGN.md §2:

      "auto"  (default) Gemini-style: idempotent rounds pick push vs pull
              per iteration from the frontier; non-idempotent rounds run
              pull− full recompute.
      "pull"  dst-keyed gather sweeps only (Def. 1 / Def. 2).
      "push"  src-keyed scatter sweeps only (Def. 3 / Def. 4).

    ``switch_k`` tunes the "auto" switch: "auto" (default) applies the
    Gemini rule with k = ``SWITCH_K`` (push while |E_frontier| ≤ |E|/k,
    from the out-degree data already in the layout), a positive number
    overrides k per query, and None falls back to the documented
    ``DENSE_FRONTIER`` vertex-fraction threshold (``dense_threshold`` —
    only read under switch_k=None; a custom threshold with the Gemini
    rule active raises rather than being silently inert).

    ``push_resolution`` selects the push sweep's dst-keyed resolution
    (DESIGN.md §10): "sorted" (default) resolves through the precomputed
    dst-major segment layout with a frontier-proportional Pallas tile
    pass; "scatter" keeps the reference full-rectangle XLA scatter.

    ``sources`` optionally overrides per-component query sources; overrides
    (like the spec's own sources) are runtime arguments of the compiled
    executor, never trace constants.

    The returned result carries ``pull_iters``/``push_iters`` — the runtime
    per-direction iteration counts — and ``resolve_work`` — the resolution
    edge work actually performed — which are also accumulated into
    ``edge_reduce.SWEEP_STATS`` for benchmarks.

    Guarded-execution knobs (DESIGN.md §12):

    ``divergence_sentinel``
        fold the NaN/Inf sentinel + last-iteration residual into the loop
        carry (default on; zero extra launches — off only for overhead
        benchmarking).
    ``init_state``
        per-component [n] arrays to warm-start the fixpoint from (e.g. a
        previous query's converged state); padding and the frontier reset
        are handled here.
    ``delta``
        vertex ids whose values may have changed (a mutation's touched set,
        ``mutate.MutationDelta.touched``): seeds the warm-started frontier
        with exactly these vertices instead of all-ones, so an idempotent
        round after a small edit converges in a handful of
        frontier-proportional sweeps (DESIGN.md §15).  Requires
        ``init_state``; for non-idempotent rounds (whose per-iteration
        recompute ignores the frontier — the warm state, not the mask, is
        the saving) a positive ``tol`` is required, because their
        convergence to the unique attractive fixpoint is a tolerance
        statement, not a bitwise one.
    ``checkpoint_every`` / ``ckpt_dir`` / ``resume``
        run the SAME traced loop body in host-stepped chunks of
        ``checkpoint_every`` iterations, snapshotting the carry through
        ``checkpoint.FixpointCheckpointer`` after each chunk;
        ``resume=True`` restores the newest fingerprint-matching snapshot
        and continues.  Chunked execution is bitwise-identical to the
        monolithic loop (shared body jaxpr, exact integer chunk bounds).
    ``fault_hook``
        test-only callable invoked with the iteration count after each
        chunk — fault-injection tests raise from it to kill a run
        mid-fixpoint.

    ``plan``
        an engine-resolved ``core.plan.ExecutionPlan``: overrides
        ``direction``/``dense_threshold``/``switch_k``/``push_resolution``/
        ``divergence_sentinel`` with the plan's pre-normalized fields
        (asserted, not re-parsed — DESIGN.md §14).  Cache keys are identical
        to the legacy-kwarg path for identical decisions.
    """
    n = g.n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    use, dense_threshold, switch_k, push_resolution = _apply_plan(
        plan, direction, dense_threshold, switch_k, push_resolution,
        idempotent)
    if plan is not None:
        divergence_sentinel = plan.divergence_sentinel
    if checkpoint_every is not None and int(checkpoint_every) < 1:
        raise ValueError("checkpoint_every must be >= 1")
    if (checkpoint_every is not None or resume) and ckpt_dir is None:
        raise ValueError("checkpoint_every/resume require ckpt_dir")
    srcs = _srcs_vector(comps, sources)
    if delta is not None:
        if init_state is None:
            raise ValueError(
                "delta= seeds the frontier of a warm start; pass init_state= "
                "(the previous solution) with it")
        if not idempotent and not tol > 0:
            raise ValueError(
                "delta warm start of a non-idempotent round requires tol > 0:"
                " convergence to the unique attractive fixpoint is a "
                "tolerance statement, not a bitwise one (DESIGN.md §15)")
        delta = np.asarray(delta, dtype=np.int64).ravel()
        if delta.size and (delta.min() < 0 or delta.max() >= n):
            raise ValueError(f"delta vertex ids out of range [0, {n})")
    chunk_mode = (checkpoint_every is not None or init_state is not None
                  or resume or fault_hook is not None)
    if not chunk_mode:
        run, args = _pallas_executor(g, comps, plans, max_iter, tol, block_v,
                                     block_e, interpret, use, dense_threshold,
                                     switch_k, push_resolution,
                                     sentinel=divergence_sentinel)
        (state, k, work, pushes, res_work, gather_work, div, resid,
         act_n) = run(*args, srcs)
    else:
        pair, args = _pallas_executor(g, comps, plans, max_iter, tol, block_v,
                                      block_e, interpret, use,
                                      dense_threshold, switch_k,
                                      push_resolution,
                                      sentinel=divergence_sentinel,
                                      chunked=True)
        init_f, step_f = pair
        ckpt = None
        if ckpt_dir is not None:
            from repro.checkpoint.fixpoint import FixpointCheckpointer
            ckpt = FixpointCheckpointer(
                ckpt_dir,
                fingerprint=_fixpoint_fingerprint(
                    g, comps, plans, use, max_iter, tol, block_v, block_e,
                    push_resolution, switch_k, srcs))
        carry = None
        carry0 = init_f(*args, srcs)
        if resume:
            carry = ckpt.restore(carry0)
        if carry is None:
            carry = carry0
            if init_state is not None:
                carry = _warm_start_carry(carry, comps, init_state, n)
            if delta is not None:
                # replace the all-ones warm-start frontier with exactly the
                # mutation's touched vertices: the first sweep propagates
                # only from them (padding rows stay inactive)
                seed = np.zeros(int(carry[1].shape[0]), dtype=bool)
                seed[delta] = True
                carry = (carry[0], jnp.asarray(seed)) + tuple(carry[2:])
        chunk = int(checkpoint_every) if checkpoint_every else max_iter
        while True:
            k_h = int(np.asarray(carry[2]))
            # the FULL padded frontier, exactly the monolithic loop condition
            if k_h >= max_iter or not bool(np.any(np.asarray(carry[1]))):
                break
            carry = step_f(*args, carry,
                           jnp.int32(min(k_h + chunk, max_iter)))
            k_done = int(np.asarray(carry[2]))
            if ckpt is not None and checkpoint_every is not None:
                ckpt.save(carry, k_done)
            if fault_hook is not None:
                fault_hook(k_done)
        (state, active, k, work, pushes, res_work, gather_work, div,
         resid) = carry
        act_n = jnp.sum(active[:n].astype(jnp.int32))
    k_i = iterate._host(k, int)
    p_i = iterate._host(pushes, int)
    rw = iterate._host(res_work, float)
    gw = iterate._host(gather_work, float)
    if isinstance(k_i, int) and isinstance(p_i, int):
        _er.SWEEP_STATS["push_iters"] += p_i
        _er.SWEEP_STATS["pull_iters"] += k_i - p_i
    if isinstance(rw, float):
        _er.SWEEP_STATS["resolve_work"] += rw
    if isinstance(gw, float):
        _er.SWEEP_STATS["gather_work"] += gw
    res = iterate.IterationResult(
        state=tuple(s[:n] for s in state),
        iterations=k_i,
        edge_work=iterate._host(work, float),
        converged=iterate._host(jnp.logical_and(~div, act_n == 0), bool),
        diverged=iterate._host(div, bool),
        active_count=iterate._host(act_n, int),
        residual=iterate._host(resid, float))
    res.push_iters = p_i
    res.pull_iters = k_i - p_i        # valid for ints and tracers alike
    res.resolve_work = rw
    res.gather_work = gw
    return res


def iterate_pallas_batch(g: Graph, comps, plans, sources: Sequence,
                         max_iter: Optional[int] = None, tol: float = 0.0,
                         block_v: int = 8, block_e: int = 128,
                         interpret: Optional[bool] = None,
                         direction: str = "auto",
                         dense_threshold: float = DENSE_FRONTIER,
                         switch_k="auto",
                         push_resolution: str = PUSH_RESOLUTION,
                         init_state=None, plan=None) -> iterate.IterationResult:
    """Run B concurrent queries of one fused round in ONE launch (DESIGN.md
    §9): the compiled fixpoint of ``iterate_pallas``, ``jax.vmap``ped over a
    batch of query sources sharing one blocked-ELL layout.

    ``sources`` is either a [B] sequence of source ids (applied to every
    sourced component — the single-source query case: BFS/SSSP/WP sweeps) or
    a [B, n_comps] array of per-component sources.  Each query converges
    independently through its own active mask (the while_loop batching rule
    selects per-element carries), so results are bit-identical to B
    sequential ``iterate_pallas`` calls; the batch reuses the SAME traced
    executor family (one ``_EXEC_CACHE`` entry per direction set, regardless
    of B — jit re-specializes on the batch shape inside the entry).

    ``init_state`` optionally warm-starts every batch element: one
    per-component ``[B, n]`` array, each row overriding that element's
    initial state (the frontier resets to all-ones, mirroring the
    single-query ``iterate_pallas(init_state=...)`` contract).  This is the
    continuous-batching join hook (DESIGN.md §13): carry the returned state
    between bounded-``max_iter`` chunk launches, splicing fresh C1/C2 init
    rows into retired slots as new queries join.

    Returns an ``IterationResult`` whose ``state`` entries are [B, n], and
    whose ``iterations`` / ``edge_work`` / ``push_iters`` / ``pull_iters``
    are per-query [B] vectors."""
    n = g.n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    srcs = jnp.asarray(sources, jnp.int32)
    if srcs.ndim == 1:                     # [B] → [B, n_comps] per-component
        per_comp = jnp.asarray([-1 if cr.source is None else 0
                                for cr in comps], jnp.int32)
        srcs = jnp.where(per_comp[None, :] < 0, per_comp[None, :],
                         srcs[:, None])
    if srcs.ndim != 2 or srcs.shape[1] != len(comps):
        raise ValueError(f"sources must be [B] or [B, {len(comps)}], got "
                         f"shape {srcs.shape}")
    use, dense_threshold, switch_k, push_resolution = _apply_plan(
        plan, direction, dense_threshold, switch_k, push_resolution,
        idempotent)
    if init_state is not None:
        init_state = tuple(jnp.asarray(a) for a in init_state)
        if len(init_state) != len(comps):
            raise ValueError(f"init_state has {len(init_state)} arrays for "
                             f"{len(comps)} components")
        B = int(srcs.shape[0])
        for cr, a in zip(comps, init_state):
            if a.shape != (B, n):
                raise ValueError(
                    f"init_state for component {cr.idx} has shape "
                    f"{a.shape}, expected ({B}, {n})")
    run, args = _pallas_executor(g, comps, plans, max_iter, tol, block_v,
                                 block_e, interpret, use, dense_threshold,
                                 switch_k, push_resolution, batch=True,
                                 warm=init_state is not None)
    if init_state is not None:
        (state, k, work, pushes, res_work, gather_work, div, resid,
         act_n) = run(*args, srcs, *init_state)
    else:
        (state, k, work, pushes, res_work, gather_work, div, resid,
         act_n) = run(*args, srcs)
    res = iterate.IterationResult(
        state=tuple(s[:, :n] for s in state),
        iterations=k,                     # [B] per-query iteration counts
        edge_work=work,                   # [B] per-query edge work
        converged=jnp.logical_and(~div, act_n == 0),   # [B]
        diverged=div,                     # [B] per-query sentinel flags
        active_count=act_n,               # [B]
        residual=resid)                   # [B]
    res.push_iters = pushes
    res.pull_iters = k - pushes
    res.resolve_work = res_work           # [B] per-query resolution work
    res.gather_work = gather_work         # [B] per-query gather work
    try:
        _er.SWEEP_STATS["push_iters"] += int(jnp.sum(pushes))
        _er.SWEEP_STATS["pull_iters"] += int(jnp.sum(k - pushes))
        _er.SWEEP_STATS["resolve_work"] += float(jnp.sum(res_work))
        _er.SWEEP_STATS["gather_work"] += float(jnp.sum(gather_work))
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        pass
    return res


# ---------------------------------------------------------------------------
# Sharded pallas engine: shard-local fused ELL sweeps under shard_map
# (DESIGN.md §11).
# ---------------------------------------------------------------------------


def _axes_tuple(axes):
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _mesh_cache_key(mesh, axes):
    """Mesh identity for the executor cache: the device set (ids), the mesh
    axis name→size layout, and the shard axes the executor reduces over.
    Two meshes over the same devices with the same layout share one
    compiled entry; a different device set or a RESHAPED mesh (same ids,
    different axis sizes — which changes how shard_map splits the stacked
    layouts) retraces."""
    return (tuple(int(d.id) for d in np.ravel(mesh.devices)),
            tuple((str(a), int(mesh.shape[a])) for a in mesh.axis_names),
            _axes_tuple(axes))


def cross_combines_per_iter(plans, comps, idempotent: bool) -> int:
    """Cross-shard state-combine collectives one ``pallas_sharded`` (or
    ``distributed``) iteration executes: one monoid psum/pmin/pmax per lex
    level of every plan, plus one OR-combine per component for the has-pred
    probe of non-idempotent rounds.  (The direction-switch edge-mass psum
    and the work accounting are control traffic, not state combines, and are
    not counted.)"""
    c = sum(len(_plan_levels(p)) for p in plans)
    if not idempotent:
        c += len(comps)
    return c


def _build_sharded_executor(comps, plans, n, max_iter, tol, block_v, block_e,
                            interpret, use, dense_threshold, switch_k,
                            push_resolution, mesh, axes):
    """Trace + jit the sharded fixpoint once per (plan structure, kernel set,
    graph shape, direction set, resolution, mesh).  The returned function
    takes one 6-tuple of STACKED ``[k, ...]`` sharded-ELL arrays per
    direction in ``use`` (nbrs, weight, capacity, mask, tile_nnz, row_deg —
    split on the shard axis by ``shard_map``), then (when the push
    direction resolves ``"sorted"``) the 4 stacked per-shard resolution
    arrays of ``structure.ShardedPushResolution`` (in2out, valid, contrib,
    tile_nnz — also shard-split), the replicated degree vectors, and the
    traced per-component query sources: ``run(*arrays, srcs)``.

    Inside ``shard_map`` every shard runs the SAME fused Pallas sweeps as
    the single-device engine over its own blocked-ELL pair — frontier-aware
    tile skipping included — producing an identity-initialised per-vertex
    partial reduction; partials merge across shards with the monoid/lex
    ``cross_plan`` combine (primary via psum/pmin/pmax, tie-masked
    secondaries, k× less traffic than an all_gather), and the replicated
    merged state feeds ``plan_merge`` / ``_recompute_merge`` exactly like
    the single-device fixpoint.  The per-iteration direction switch stays
    GLOBAL: the frontier's outgoing edge mass is a psum of shard-local
    out-layout row degrees, so every shard compares the same (integer-exact)
    mass against |E|/k and picks the same sweep.  State is replicated, so
    the convergence flag is identical on every shard and the while_loop is
    collective-safe.  The push sweep resolves its dst-keyed reduction
    shard-locally with the dst-sorted segment pass by default (each shard's
    own ``PushResolution`` stack over its widened out-layout — the
    in-kernel gather and the frontier-proportional tile skipping work
    per shard exactly as on one device, and the cross-shard monoid/lex
    combine contract is unchanged); ``"scatter"`` keeps the per-shard
    reference scatter as the oracle (DESIGN.md §11)."""
    from jax.sharding import PartitionSpec as P

    from repro.compat import shard_map

    ax = _axes_tuple(axes)
    comps_by_idx = {cr.idx: cr for cr in comps}
    plan_levels = tuple(tuple(_plan_levels(p)) for p in plans)
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    comps_order = _er.comps_in_plan_order(plan_levels)
    idents = {c: comps_by_idx[c].ident for c in comps_order}
    p_fns = {c: comps_by_idx[c].p_fn for c in comps_order}
    sorted_res = push_resolution == "sorted" and "push" in use

    def shard_fn(*arrays):
        ell = {}
        idx = 0
        for d in use:
            ell[d] = tuple(a[0] for a in arrays[idx:idx + 6])  # [1,...] → [...]
            idx += 6
        if sorted_res:
            res_in2out, res_valid, res_contrib, res_nnz = \
                tuple(a[0] for a in arrays[idx:idx + 4])
            idx += 4
        out_deg = arrays[idx]
        wdeg = arrays[idx + 1]
        srcs = arrays[idx + 2]
        n_pad = ell[use[0]][0].shape[0]
        out_deg_pad = jnp.zeros(n_pad, jnp.float32).at[:n].set(
            jnp.maximum(out_deg, 1).astype(jnp.float32))
        wdeg_pad = jnp.ones(n_pad, jnp.float32).at[:n].set(
            wdeg.astype(jnp.float32))
        # Shard-local real-edge count; the direction switch compares against
        # the GLOBAL |E| via psum so every shard sees the same threshold.
        local_edges = jnp.sum(ell[use[0]][3].astype(jnp.float32))
        num_edges_g = jax.lax.psum(local_edges, ax)
        ones_act = jnp.ones(n_pad, jnp.int32)

        def cross_plan(plan, red: dict) -> dict:
            """Cross-shard lexicographic combine with monoid collectives
            only (the distributed engine's combiner over the pallas sweeps'
            partials): global primary via psum/pmin/pmax, tie-mask the local
            secondaries to identity, recurse.  Replicated across shards."""
            best = segment.psum_like(plan.op, red[plan.comp], ax)
            out = {plan.comp: best}
            if isinstance(plan, Lex):
                tie = red[plan.comp] == best
                masked = {j: jnp.where(tie, red[j], comps_by_idx[j].ident)
                          for j in iterate._plan_comps(plan.secondary)}
                out.update(cross_plan(plan.secondary, masked))
            return out

        def cross_shard(red: dict) -> dict:
            out = dict(red)
            for p in plans:
                out.update(cross_plan(p, red))
            return out

        def sweep(d, state_d, active_i32, tile_act, need_hp):
            """One shard-local fused sweep: the SAME pallas kernels as the
            single-device engine, over this shard's blocked-ELL slice.
            Returns (red, hp, resolution edge work, gather work) exactly
            like the single-device ``sweep`` — the sorted push resolve runs
            shard-locally over this shard's own ``PushResolution`` slice."""
            nbrs, weight, capacity, mask, _nnz, _rdeg = ell[d]
            states = {c: state_d[c] for c in comps_order}
            common = dict(plans=plan_levels, idents=idents, p_fns=p_fns,
                          nv=float(n), need_haspred=need_hp, wdeg=wdeg_pad,
                          block_v=block_v, block_e=block_e,
                          interpret=interpret)
            if d == "pull":
                red, hp = _er.fused_ell_sweep(
                    nbrs, weight, capacity, mask, tile_act, states,
                    active_i32, out_deg_pad, **common)
                return red, hp, jnp.float32(0), jnp.float32(0)
            if sorted_res:
                res_tile_act = _er.resolution_tile_activity(
                    res_contrib, tile_act, res_nnz)
                red, hp = _er.fused_ell_push_sweep(
                    nbrs, weight, capacity, mask, tile_act, states,
                    active_i32, out_deg_pad, resolution="sorted",
                    res=(res_in2out, res_valid, res_tile_act), **common)
                res_w = jnp.sum(res_nnz * res_tile_act).astype(jnp.float32)
                return red, hp, res_w, res_w
            red, hp = _er.fused_ell_push_sweep(
                nbrs, weight, capacity, mask, tile_act, states,
                active_i32, out_deg_pad, resolution="scatter", **common)
            return (red, hp, jnp.float32(nbrs.shape[0] * nbrs.shape[1]),
                    jnp.float32(0))

        def masked_branch(d):
            """One frontier-masked (+model) shard-local sweep; edge work is
            the real slots inside the tiles THIS shard processed."""
            def branch(args):
                state_d, active_i32 = args
                nbrs, _w, _c, mask, tile_nnz, _rdeg = ell[d]
                if d == "pull":
                    tile_act = _er.tile_activity(nbrs, mask, tile_nnz,
                                                 active_i32, block_v, block_e)
                else:
                    tile_act = _er.tile_activity_push(tile_nnz, active_i32,
                                                      block_v)
                red, _, res_w, gat_w = sweep(d, state_d, active_i32, tile_act,
                                             False)
                w_inc = jnp.sum((tile_nnz * tile_act)).astype(jnp.float32)
                return tuple(red[c] for c in comps_order), w_inc, res_w, gat_w
            return branch

        def body(carry):
            (state, active, k, work, pushes, res_work, gather_work, div,
             resid) = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            if idempotent:
                active_i32 = active.astype(jnp.int32)
                if len(use) == 2:
                    if switch_k is not None:
                        # Gemini rule, computed GLOBALLY: psum the frontier's
                        # shard-local out-edge mass (out-layout row degrees —
                        # padding rows carry 0) so every shard compares the
                        # identical (integer-exact) edge mass and picks the
                        # same direction as the single-device engine.
                        local_mass = jnp.sum(active.astype(jnp.float32)
                                             * ell["push"][5])
                        e_frontier = jax.lax.psum(local_mass, ax)
                        use_push = e_frontier <= num_edges_g / switch_k
                    else:
                        # fallback frontier-fraction rule: the frontier is
                        # replicated, so this is shard-invariant by itself.
                        frac = jnp.sum(active.astype(jnp.float32)) / n
                        use_push = frac <= dense_threshold
                    red_t, w_inc, res_w, gat_w = jax.lax.cond(
                        use_push, masked_branch("push"), masked_branch("pull"),
                        (state_d, active_i32))
                    pushes = pushes + use_push.astype(jnp.int32)
                else:
                    red_t, w_inc, res_w, gat_w = masked_branch(use[0])(
                        (state_d, active_i32))
                    pushes = pushes + (1 if use[0] == "push" else 0)
                red = cross_shard({c: red_t[i]
                                   for i, c in enumerate(comps_order)})
                work = work + w_inc
                res_work = res_work + res_w
                gather_work = gather_work + gat_w
                new_d = {}
                for p in plans:
                    new_d.update(iterate.plan_merge(p, state_d, red,
                                                    comps_by_idx))
            else:
                # full recompute (− models): every shard sweeps its real
                # tiles, partial sums/extrema combine across shards, then
                # the epilogue applies to the GLOBAL reduction.
                d = use[0]
                work = work + local_edges
                tiles_static = (ell[d][4] > 0).astype(jnp.int32)
                red, hp, res_w, gat_w = sweep(d, state_d, ones_act,
                                              tiles_static, True)
                res_work = res_work + res_w
                gather_work = gather_work + gat_w
                red = cross_shard(red)
                hp = {c: segment.psum_like(
                    "or", hp[c].astype(jnp.int32), ax).astype(bool)
                    for c in hp}
                red = iterate._apply_epilogue(comps, red)
                new_d = iterate._recompute_merge(plans, comps_by_idx,
                                                 state_d, red, hp)
                pushes = pushes + (1 if d == "push" else 0)
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = iterate._changed(comps, new, state, tol)
            # divergence sentinel on the REPLICATED post-combine state: every
            # shard computes the identical flag, so draining the frontier
            # through it stays collective-safe.
            div = div | iterate._divergence(comps, new)
            resid = iterate._residual(comps, new, state)
            ch = ch & ~div
            return (new, ch, k + 1, work, pushes, res_work, gather_work,
                    div, resid)

        def cond(carry):
            _, active, k, _, _, _, _, _, _ = carry
            return jnp.any(active) & (k < max_iter)

        state0 = _padded_init_state(comps, n, n_pad, srcs)
        (state, active, k, work, pushes, res_work, gather_work, div,
         resid) = jax.lax.while_loop(
            cond, body, (state0, jnp.ones(n_pad, bool), jnp.int32(0),
                         jnp.float32(0), jnp.int32(0), jnp.float32(0),
                         jnp.float32(0), jnp.asarray(False),
                         jnp.float32(0)))
        # k/pushes/div/resid/active_n are replicated (k and pushes asserted
        # host-side); work/res_work/gather_work are per-shard.
        active_n = jnp.sum(active[:n].astype(jnp.int32))
        return (state, k[None], work[None], pushes[None], res_work[None],
                gather_work[None], div[None], resid[None], active_n[None])

    pspec = P(ax)
    in_specs = tuple([pspec] * (6 * len(use))
                     + ([pspec] * 4 if sorted_res else [])
                     + [P(), P(), P()])
    out_specs = (tuple(P() for _ in comps), P(ax), P(ax), P(ax), P(ax),
                 P(ax), P(ax), P(ax), P(ax))
    # check_vma off: the pre-graduation checker rejects collectives inside
    # while_loop bodies, and the graduated checker cannot see through
    # interpret-mode pallas_call — replication of state/k/pushes is a
    # engine-level contract asserted on the host instead.
    fn = shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                   out_specs=out_specs, check_vma=False)
    return jax.jit(fn)


def _sharded_executor(g, comps, plans, mesh, axes, strategy, max_iter, tol,
                      block_v, block_e, interpret, use, dense_threshold,
                      switch_k, push_resolution):
    """Cache lookup / build of the compiled sharded fixpoint, plus the
    stacked argument prefix it runs on."""
    ax = _axes_tuple(axes)
    k_shards = int(np.prod([mesh.shape[a] for a in ax]))
    ells = {d: sharded_ell_cached(
        g, k_shards, strategy=strategy, block_v=block_v, block_e=block_e,
        direction={"pull": "in", "push": "out"}[d]) for d in use}
    if len(use) != 2:                # pinned direction: no switch traced
        dense_threshold = None
        switch_k = None
    if "push" not in use:            # no push sweep: resolution never traced
        push_resolution = "unused"
    key = ("sharded", g.n, tuple(tuple(_plan_levels(p)) for p in plans),
           _comps_key(comps), max_iter, tol, block_v, block_e, interpret,
           use, dense_threshold, switch_k, push_resolution, strategy,
           _mesh_cache_key(mesh, ax))
    run = _exec_cache_get(key)
    if run is None:
        run = _build_sharded_executor(comps, plans, g.n, max_iter, tol,
                                      block_v, block_e, interpret, use,
                                      dense_threshold, switch_k,
                                      push_resolution, mesh, ax)
        _exec_cache_put(key, run, comps)
    args = []
    for d in use:
        e = ells[d]
        args += [e.nbrs, e.weight, e.capacity, e.mask, e.tile_nnz, e.row_deg]
    if push_resolution == "sorted":
        sres = sharded_push_resolution_cached(
            g, k_shards, strategy=strategy, block_v=block_v, block_e=block_e)
        args += [sres.in2out, sres.valid, sres.contrib, sres.tile_nnz]
    args.append(g.out_deg)
    args.append(w_out_deg(g))
    return run, args, k_shards


def iterate_pallas_sharded(g: Graph, comps, plans, mesh, axes=("data",),
                           strategy: str = "contiguous",
                           max_iter: Optional[int] = None, tol: float = 0.0,
                           block_v: int = 8, block_e: int = 128,
                           interpret: Optional[bool] = None,
                           direction: str = "auto",
                           dense_threshold: float = DENSE_FRONTIER,
                           switch_k="auto",
                           push_resolution: Optional[str] = None,
                           sources: Optional[dict] = None,
                           plan=None) -> iterate.IterationResult:
    """Fixpoint of the fused reduction with SHARD-LOCAL fused Pallas sweeps
    under ``shard_map`` (DESIGN.md §11): each vertex-cut shard holds its own
    blocked-ELL pair, runs the existing pull/push sweeps locally (one
    ``pallas_call`` per shard per iteration — frontier-aware tile skipping
    included), and merges per-vertex partials across shards with the
    monoid/lex ``cross_plan`` combine.  The per-iteration direction switch
    is GLOBAL (psum'd frontier edge mass), so the sharded engine takes the
    same push/pull sequence — and produces bitwise-identical states for
    idempotent rounds — as the single-device ``iterate_pallas``.

    ``strategy`` picks the edge partitioning (``partition.partition_edges``:
    "contiguous" | "dst_hash").  ``push_resolution`` selects the shard-local
    dst-keyed resolution exactly like the single-device engine: "sorted"
    (default) resolves through each shard's own precomputed dst-major
    segment layout (``structure.to_sharded_push_resolution`` — per-shard
    ``PushResolution`` stacks over the widened out-layout, in-kernel gather
    and frontier-proportional tile skipping included), "scatter" keeps the
    per-shard reference full-rectangle XLA scatter as the oracle.  Both are
    exact for the idempotent min/max plans and feed the same cross-shard
    monoid/lex combine, so the choice never changes results.

    The result carries ``shards`` / ``shard_work`` (per-shard processed-tile
    edge work) / ``shard_launches`` (traced pallas launches per shard per
    round) / ``cross_combines`` (cross-shard state-combine collectives
    executed) on top of the usual pallas stats (including ``resolve_work``
    and ``gather_work``, summed over shards)."""
    n = g.n
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(iterate.plan_idempotent(p) for p in plans)
    if plan is not None:
        assert_normalized(plan)
        push_resolution = plan.push_resolution
        use = _directions_used(plan.direction, idempotent)
        dense_threshold, switch_k = plan.dense_threshold, plan.switch_k
        strategy = plan.shard_strategy
    else:
        use = _directions_used(direction, idempotent)
        switch_k = _normalize_switch_k(
            switch_k, dense_threshold if len(use) == 2 else DENSE_FRONTIER)
        push_resolution = _check_resolution(
            PUSH_RESOLUTION if push_resolution is None else push_resolution)
        if strategy not in ("contiguous", "dst_hash"):
            raise ValueError(f"unknown shard strategy {strategy!r}")
    run, args, k_shards = _sharded_executor(
        g, comps, plans, mesh, axes, strategy, max_iter, tol, block_v,
        block_e, interpret, use, dense_threshold, switch_k, push_resolution)
    state, k, work, pushes, res_work, gather_work, div, resid, act_n = run(
        *args, _srcs_vector(comps, sources))
    k_host = np.asarray(k)
    work_host = np.asarray(work)
    push_host = np.asarray(pushes)
    # Replication contract: every shard must have run the identical fixpoint
    # (same iteration count, same direction sequence).  A divergence means
    # the collective combine or the global switch broke — fail loud, naming
    # the offending shards, instead of trusting shard 0.
    iterate.check_shard_replication(k_host, "iteration count",
                                    "pallas_sharded")
    iterate.check_shard_replication(push_host, "push-iteration count",
                                    "pallas_sharded")
    k_i = int(k_host[0])
    p_i = int(push_host[0])
    div_h = bool(np.asarray(div)[0])
    act_h = int(np.asarray(act_n)[0])
    _er.SWEEP_STATS["push_iters"] += p_i
    _er.SWEEP_STATS["pull_iters"] += k_i - p_i
    res = iterate.IterationResult(
        state=tuple(s[:n] for s in state),
        iterations=k_i,
        edge_work=float(work_host.sum()),
        converged=(not div_h) and act_h == 0,
        diverged=div_h,
        active_count=act_h,
        residual=float(np.asarray(resid)[0]))
    res.push_iters = p_i
    res.pull_iters = k_i - p_i
    res.resolve_work = float(np.asarray(res_work).sum())
    res.gather_work = float(np.asarray(gather_work).sum())
    _er.SWEEP_STATS["resolve_work"] += res.resolve_work
    _er.SWEEP_STATS["gather_work"] += res.gather_work
    res.shards = k_shards
    res.shard_work = tuple(float(w) for w in work_host)
    res.shard_launches = len(use)        # traced sweeps per shard per round
    res.cross_combines = k_i * cross_combines_per_iter(plans, comps,
                                                       idempotent)
    return res
