"""Flash-attention forward Pallas kernel (TPU tiling of the online-softmax
attention the LM stack uses everywhere).

Grid: (B·H, S/BLOCK_Q, T/BLOCK_K); the KV axis is the minor grid dim, so
the output blocks act as accumulators for the online recurrence:

  m ← max(m, rowmax(logits));  p = exp(logits − m)
  l ← l·α + rowsum(p);         acc ← acc·α + p @ V_tile,  α = exp(m_old − m)

Causal and chunked-local (llama4 iRoPE) masks are computed per tile from
iota — no mask tensor exists.  A final jnp epilogue divides acc by l.

Tiles default to (128, 128): MXU-aligned on both matmul dims; the VMEM
working set per step is q(BQ·D) + k/v(BK·D) + logits(BQ·BK) + acc(BQ·D)
≈ 4·128·128·4B ≈ 260 KB at D=128 — comfortably inside one core's VMEM.
Validated against ``ref.ref_flash_attention`` (interpret mode off-TPU).
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_Q = 128
BLOCK_K = 128

_NEG = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, acc_ref, m_ref, l_ref, *,
                  scale, causal, chunk, block_q, block_k):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)                      # [BQ, D]
    k = k_ref[0].astype(jnp.float32)                      # [BK, D]
    v = v_ref[0].astype(jnp.float32)
    logits = q @ k.T * scale                              # [BQ, BK]

    qpos = iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(
        jnp.int32, logits.shape, 1)
    mask = jnp.ones_like(logits, dtype=jnp.bool_)
    if causal:
        mask = mask & (kpos <= qpos)
    if chunk is not None:
        mask = mask & (kpos // chunk == qpos // chunk)
    logits = jnp.where(mask, logits, _NEG)

    m_old = m_ref[0]
    m_new = jnp.maximum(m_old, jnp.max(logits, axis=-1))
    p = jnp.where(mask, jnp.exp(logits - m_new[:, None]), 0.0)
    alpha = jnp.exp(m_old - m_new)
    l_ref[0] = l_ref[0] * alpha + jnp.sum(p, axis=-1)
    acc_ref[0] = acc_ref[0] * alpha[:, None] + p @ v
    m_ref[0] = m_new


def flash_attention(q, k, v, causal: bool = True,
                    chunk: Optional[int] = None,
                    block_q: int = BLOCK_Q, block_k: int = BLOCK_K,
                    interpret: Optional[bool] = None):
    """q [B, H, S, D]; k/v [B, Hkv, T, D] (GQA: H a multiple of Hkv).

    Returns [B, H, S, D].  Forward only (training uses the XLA-level flash
    custom-VJP in models.layers; this kernel is the serving/TPU hot path).
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, s, d = q.shape
    hkv, t = k.shape[1], k.shape[2]
    rep = h // hkv
    if rep > 1:
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(block_q, s)
    while s % bq:
        bq //= 2
    bk = min(block_k, t)
    while t % bk:
        bk //= 2
    qf = q.reshape(b * h, s, d)
    kf = k.reshape(b * h, t, d)
    vf = v.reshape(b * h, t, d)
    grid = (b * h, s // bq, t // bk)

    q_spec = pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0))
    kv_spec = pl.BlockSpec((1, bk, d), lambda bh, i, j: (bh, j, 0))
    o_spec = pl.BlockSpec((1, bq, d), lambda bh, i, j: (bh, i, 0))
    s_spec = pl.BlockSpec((1, bq), lambda bh, i, j: (bh, i))

    kern = functools.partial(
        _flash_kernel, scale=1.0 / math.sqrt(d), causal=causal,
        chunk=chunk, block_q=bq, block_k=bk)
    acc, m, l = pl.pallas_call(
        kern, grid=grid,
        in_specs=[q_spec, kv_spec, kv_spec],
        out_specs=(o_spec, s_spec, s_spec),
        out_shape=(jax.ShapeDtypeStruct((b * h, s, d), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, s), jnp.float32),
                   jax.ShapeDtypeStruct((b * h, s), jnp.float32)),
        interpret=interpret,
    )(qf, kf, vf)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, h, s, d).astype(q.dtype)
