"""EmbeddingBag Pallas kernel (DLRM hot path).

JAX has no native ``nn.EmbeddingBag``; the system-level primitive is a
ragged gather over the vocab followed by a per-bag reduce.  DLRM bags are
fixed-width multi-hot (K slots per field), so the TPU layout is dense:

  idx   [B, K]   int32 row ids into the table
  table [V, D]   float32/bf16 embedding rows
  out   [B, D]   per-bag sum/mean

Tiling: grid over (B / BLOCK_B, D / BLOCK_D).  The embedding-dim axis is
blocked at 128 (lane width); each grid step gathers BLOCK_B × K rows of the
current D-slice and reduces over K in VREGs.  The table is presented as a
(V, BLOCK_D) VMEM block per step; production tables larger than VMEM stream
row-ranges via double-buffered DMA — the BlockSpec boundary below is where
that DMA pipeline attaches (see DESIGN.md §5, DLRM sharding: table rows are
sharded over the model axis so V_local stays VMEM-resident for RM2 at 64-wide
embeddings).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_B = 128
BLOCK_D = 128


def _bag_kernel(idx_ref, table_ref, out_ref, *, mode, k):
    idx = idx_ref[...]                         # [BB, K]
    rows = table_ref[...][idx]                 # [BB, K, BD] VREG gather
    acc = jnp.sum(rows, axis=1)
    if mode == "mean":
        acc = acc / jnp.float32(k)
    out_ref[...] = acc.astype(out_ref.dtype)


def _bag_kernel_weighted(idx_ref, wgt_ref, table_ref, out_ref, *, mode, k):
    idx = idx_ref[...]
    rows = table_ref[...][idx]                 # [BB, K, BD]
    rows = rows * wgt_ref[...][..., None]
    acc = jnp.sum(rows, axis=1)
    if mode == "mean":
        acc = acc / jnp.float32(k)
    out_ref[...] = acc.astype(out_ref.dtype)


def embedding_bag(table: jnp.ndarray, idx: jnp.ndarray,
                  weights: Optional[jnp.ndarray] = None, mode: str = "sum",
                  block_b: int = BLOCK_B, block_d: int = BLOCK_D,
                  interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fixed-width EmbeddingBag: table [V, D], idx [B, K] → [B, D]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    v, d = table.shape
    b, k = idx.shape
    block_b = min(block_b, b)
    block_d = min(block_d, d)
    assert b % block_b == 0 and d % block_d == 0, (b, d, block_b, block_d)
    grid = (b // block_b, d // block_d)

    idx_spec = pl.BlockSpec((block_b, k), lambda i, j: (i, 0))
    tab_spec = pl.BlockSpec((v, block_d), lambda i, j: (0, j))
    out_spec = pl.BlockSpec((block_b, block_d), lambda i, j: (i, j))

    if weights is None:
        fn = pl.pallas_call(
            functools.partial(_bag_kernel, mode=mode, k=k),
            grid=grid, in_specs=[idx_spec, tab_spec], out_specs=out_spec,
            out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
            interpret=interpret)
        return fn(idx, table)
    wgt_spec = pl.BlockSpec((block_b, k), lambda i, j: (i, 0))
    fn = pl.pallas_call(
        functools.partial(_bag_kernel_weighted, mode=mode, k=k),
        grid=grid, in_specs=[idx_spec, wgt_spec, tab_spec], out_specs=out_spec,
        out_shape=jax.ShapeDtypeStruct((b, d), table.dtype),
        interpret=interpret)
    return fn(idx, weights, table)
