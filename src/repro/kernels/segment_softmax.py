"""Masked row softmax over the blocked-ELL layout (GAT edge attention).

GAT computes a softmax over each vertex's incoming-edge scores.  In the
degree-padded ELL layout that is a masked softmax along the slot axis.  The
slot axis can exceed VMEM for power-law graphs, so the kernel is *online*
(flash-style) in two passes without materializing exp() over the full row:

  pass 1 (stats):  running (row-max m, row-sumexp s) accumulated across
                   slot tiles — the classic online-softmax recurrence,
  pass 2 (norm):   weights = exp(score − m) / s per tile.

Both passes are (BLOCK_V × BLOCK_E) tiles; masked/padded slots produce
exactly 0 weight (condition-C6 style identity padding).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_V = 8
BLOCK_E = 128

_NEG = -1e30


def _stats_kernel(scores_ref, mask_ref, m_ref, s_ref):
    j = pl.program_id(1)
    scores = jnp.where(mask_ref[...], scores_ref[...].astype(jnp.float32), _NEG)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        s_ref[...] = jnp.zeros_like(s_ref)

    m_old = m_ref[...]
    m_tile = jnp.max(scores, axis=1)
    m_new = jnp.maximum(m_old, m_tile)
    # rescale the running sum, then fold in this tile
    e = jnp.where(mask_ref[...], jnp.exp(scores - m_new[:, None]), 0.0)
    s_ref[...] = s_ref[...] * jnp.exp(m_old - m_new) + jnp.sum(e, axis=1)
    m_ref[...] = m_new


def _norm_kernel(scores_ref, mask_ref, m_ref, s_ref, out_ref):
    scores = scores_ref[...].astype(jnp.float32)
    e = jnp.exp(scores - m_ref[...][:, None])
    w = e / jnp.maximum(s_ref[...][:, None], 1e-30)
    out_ref[...] = jnp.where(mask_ref[...], w, 0.0).astype(out_ref.dtype)


def ell_softmax(scores: jnp.ndarray, mask: jnp.ndarray,
                block_v: int = BLOCK_V, block_e: int = BLOCK_E,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """scores/mask [n_pad, width] → masked row-softmax weights [n_pad, width]."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n_pad, width = scores.shape
    block_v = min(block_v, n_pad)
    block_e = min(block_e, width)
    assert n_pad % block_v == 0 and width % block_e == 0
    grid = (n_pad // block_v, width // block_e)

    tile = pl.BlockSpec((block_v, block_e), lambda i, j: (i, j))
    vrow = pl.BlockSpec((block_v,), lambda i, j: (i,))

    m, s = pl.pallas_call(
        _stats_kernel, grid=grid,
        in_specs=[tile, tile], out_specs=(vrow, vrow),
        out_shape=(jax.ShapeDtypeStruct((n_pad,), jnp.float32),
                   jax.ShapeDtypeStruct((n_pad,), jnp.float32)),
        interpret=interpret)(scores, mask)

    out = pl.pallas_call(
        _norm_kernel, grid=grid,
        in_specs=[tile, tile, vrow, vrow], out_specs=tile,
        out_shape=jax.ShapeDtypeStruct((n_pad, width), scores.dtype),
        interpret=interpret)(scores, mask, m, s)
    return out
