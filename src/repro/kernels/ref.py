"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``ref_*`` function computes exactly what the corresponding kernel
computes, with plain jnp ops and no tiling, so the kernel test sweeps can
``assert_allclose`` against them across shapes and dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.graph import segment


def ref_ell_reduce(op: str, values, mask, ident):
    """Masked row-reduction over a blocked-ELL tile layout.

    values [n_pad, width], mask [n_pad, width] → [n_pad].
    """
    masked = jnp.where(mask, values, ident)
    fn = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum, "prod": jnp.prod}[op]
    return fn(masked, axis=1)


def ref_edge_level(op: str, state, srcs, mask, p_of, ident, bot,
                   tie_masks=None):
    """One lex level of the blocked-ELL gather→propagate→reduce.

    state [n] per-vertex values; srcs/mask [n_pad, width]; ``p_of(nvals, row,
    col_srcs)`` applies the synthesized propagation to the gathered values.
    ``tie_masks`` [n_pad, width] further restricts eligible slots (lex ties).
    Returns [n_pad] per-vertex partial reduction.
    """
    nvals = state[srcs]
    p = p_of(nvals, srcs)
    p = jnp.where(nvals == bot, ident, p)            # C3: ⊥ propagates ⊥
    m = mask if tie_masks is None else (mask & tie_masks)
    return ref_ell_reduce(op, p, m, ident)


def ref_embedding_bag(table, idx, offsets=None, mode: str = "sum",
                      weights=None):
    """EmbeddingBag: gather rows of ``table`` [V, D] for flat indices
    ``idx`` [N] grouped into bags by ``offsets`` [B] (start positions), or
    fixed-width bags when ``idx`` is [B, K].

    JAX has no native EmbeddingBag — this gather + segment-sum IS the
    reference semantics (kernel_taxonomy §RecSys).
    """
    if idx.ndim == 2:                                 # fixed-width bags
        rows = table[idx]                             # [B, K, D]
        if weights is not None:
            rows = rows * weights[..., None]
        if mode == "sum":
            return rows.sum(axis=1)
        if mode == "mean":
            return rows.mean(axis=1)
        if mode == "max":
            return rows.max(axis=1)
        raise ValueError(mode)
    assert offsets is not None
    n, b = idx.shape[0], offsets.shape[0]
    seg = jnp.cumsum(
        jnp.zeros(n, jnp.int32).at[offsets[1:]].add(1)) if b > 1 else \
        jnp.zeros(n, jnp.int32)
    rows = table[idx]
    if weights is not None:
        rows = rows * weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, seg, b)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, seg, b)
        cnt = jax.ops.segment_sum(jnp.ones(n), seg, b)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, seg, b)
    raise ValueError(mode)


def ref_ell_softmax(scores, mask):
    """Masked row softmax over an ELL tile layout (GAT edge attention).

    scores/mask [n_pad, width] → attention weights [n_pad, width] with
    masked slots exactly 0 and each real row summing to 1.
    """
    neg = jnp.finfo(scores.dtype).min
    s = jnp.where(mask, scores, neg)
    m = jnp.max(s, axis=1, keepdims=True)
    e = jnp.where(mask, jnp.exp(s - m), 0.0)
    denom = jnp.sum(e, axis=1, keepdims=True)
    return e / jnp.maximum(denom, 1e-30)


def ref_segment_softmax(scores, segment_ids, num_segments):
    return segment.segment_softmax(scores, segment_ids, num_segments)


def ref_flash_attention(q, k, v, causal: bool = True, scale=None,
                        chunk: int | None = None):
    """Plain softmax attention oracle (optionally local/chunked).

    q [B, H, S, D], k/v [B, Hkv, S, D] with H a multiple of Hkv (GQA).
    ``chunk`` restricts attention to the same chunk of size ``chunk``
    (llama4-style chunked local attention).
    """
    b, h, s, d = q.shape
    hkv = k.shape[1]
    rep = h // hkv
    k = jnp.repeat(k, rep, axis=1)
    v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else 1.0 / jnp.sqrt(d).astype(q.dtype)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    qi = jnp.arange(s)[:, None]
    ki = jnp.arange(s)[None, :]
    m = jnp.ones((s, s), bool)
    if causal:
        m = m & (ki <= qi)
    if chunk is not None:
        m = m & (qi // chunk == ki // chunk)
    logits = jnp.where(m, logits, jnp.finfo(logits.dtype).min)
    p = jax.nn.softmax(logits.astype(jnp.float32), axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
