"""The paper's use-case specifications (Fig. 1), written in the Grafs
specification language.

Each function returns a spec AST; run it with

    prog = fusion.fuse(spec)
    result = engine.run_program(graph, prog, engine="pull")

``handwritten_*`` variants at the bottom mirror the frameworks' reference
implementations (hand-coded kernel functions) for the synthesized-vs-
handwritten experiments (paper Fig. 11 / Table 1).
"""
from __future__ import annotations

from repro.core.lang import (AllPaths, ArgsRestrict, CAPACITY, Cardinality,
                             HEAD, LENGTH, LetRound, MBin, MConst, ONE,
                             PathReduce, PathSel, PENULTIMATE, RBin, RConst,
                             ScalarRef, Term, VertexReduce, WEIGHT)


# --- single path-based reductions ------------------------------------------

def sssp(s: int) -> Term:
    """SSSP(s)(v) = min_{p∈Paths(s,v)} weight(p)"""
    return PathReduce("min", WEIGHT, AllPaths(s))


def cc() -> Term:
    """CC(v) = min_{p∈Paths(v)} head(p)   (undirected graphs)"""
    return PathReduce("min", HEAD, AllPaths(None))


def bfs(s: int) -> Term:
    """BFS(s)(v) = penultimate(arg min_{p∈Paths(s,v)} length(p))"""
    return PathSel(PENULTIMATE, "min", LENGTH, AllPaths(s))


def bfs_depth(s: int) -> Term:
    """Hop count — the 'simpler specification' variant of BFS."""
    return PathReduce("min", LENGTH, AllPaths(s))


def wp(s: int) -> Term:
    """WP: widest path — max capacity over all paths (Table 1 use-case)."""
    return PathReduce("max", CAPACITY, AllPaths(s))


def reach(s: int) -> Term:
    """REACH(s)(v): is v reachable from s?  An ∨-reduction over paths
    (appendix use-case; exercises the boolean monoids end to end).
    Encoded as min-length < ∞ at the spec level with an `or` vertex
    aggregate available via DS-style constraints; the direct boolean
    path-reduction uses ONE with the `or` monoid."""
    return PathReduce("or", ONE, AllPaths(s))


def n_reachable(s: int) -> Term:
    """|{v : reachable from s}| — Σ over vertices of the boolean (sugar:
    sum-reduce the 0/1 reach vector)."""
    return VertexReduce("sum", reach(s))


# --- nested path-based reductions -------------------------------------------

def wsp(s: int) -> Term:
    """WSP(s)(v): widest among the shortest paths (nested; rule FPNEST)."""
    return PathReduce("max", CAPACITY,
                      ArgsRestrict("min", LENGTH, AllPaths(s)))


def nsp(s: int) -> Term:
    """NSP(s)(v) = |args min length|: number of shortest paths."""
    return Cardinality(ArgsRestrict("min", LENGTH, AllPaths(s)))


# --- operators between path-based reductions --------------------------------

def nwr(s: int) -> Term:
    """NWR(s)(v) = narrowest / widest path ratio."""
    return MBin("/", PathReduce("min", CAPACITY, AllPaths(s)),
                PathReduce("max", CAPACITY, AllPaths(s)))


def trust(s1: int, s2: int) -> Term:
    """Trust({s1,s2})(v): wider (stronger) and shorter (closer) paths are
    more trustworthy — division and maximum over 4 path reductions."""
    def per_source(s):
        return MBin("/", PathReduce("max", CAPACITY, AllPaths(s)),
                    MBin("+", PathReduce("min", LENGTH, AllPaths(s)),
                         MConst(1.0)))
    return MBin("max", per_source(s1), per_source(s2))


# --- vertex-based reductions -------------------------------------------------

def ecc(s: int) -> Term:
    """Eccentricity of s: max over v of the shortest length."""
    return VertexReduce("max", PathReduce("min", LENGTH, AllPaths(s)))


def radius(s1: int, s2: int) -> Term:
    """RADIUS sampled over {s1, s2} (paper Fig. 2)."""
    return RBin("min", ecc(s1), ecc(s2))


def diameter(s1: int, s2: int) -> Term:
    return RBin("max", ecc(s1), ecc(s2))


def drr(s1: int, s2: int) -> Term:
    """DRR = Diameter / Radius (common-operation elimination shares the two
    eccentricity computations)."""
    return RBin("/", diameter(s1, s2), radius(s1, s2))


def ds(s: int, k: float = 7.0) -> Term:
    """DS(s) = {v | dist(s, v) ≥ k} (constrained vertex reduction → mask)."""
    dist = PathReduce("min", WEIGHT, AllPaths(s))
    return VertexReduce("collect", MConst(1.0),
                        cond=MBin(">=", dist, MConst(k)))


def rds(s1: int, s2: int) -> Term:
    """RDS: the narrowest of the widest paths to vertices within the radius
    (nested triple-lets → two iteration-map-reduce rounds)."""
    inner = radius(s1, s2)
    widest = PathReduce("max", CAPACITY, AllPaths(s1))
    hops = PathReduce("min", LENGTH, AllPaths(s1))
    body = VertexReduce("min", widest,
                        cond=MBin("<=", hops, ScalarRef("k")))
    return LetRound("k", inner, body)


ALL_SPECS = {
    "SSSP": lambda: sssp(0), "CC": cc, "BFS": lambda: bfs(0),
    "WP": lambda: wp(0), "WSP": lambda: wsp(0), "NSP": lambda: nsp(0),
    "NWR": lambda: nwr(0), "Trust": lambda: trust(0, 1),
    "RADIUS": lambda: radius(0, 1), "DRR": lambda: drr(0, 1),
    "DS": lambda: ds(0, 3.0), "RDS": lambda: rds(0, 1),
    "REACH": lambda: reach(0), "NREACH": lambda: n_reachable(0),
}


# ---------------------------------------------------------------------------
# Handwritten kernel baselines (paper Fig. 11 / Table 1): the reference
# vertex programs shipped with the frameworks, written directly against the
# iteration engines — bypassing fusion and synthesis.
# ---------------------------------------------------------------------------

from repro.core.synthesis import (DirectKernels, pagerank_kernels,  # noqa: E402
                                  weighted_pagerank_kernels)


# The init kernels are SOURCE-GENERIC (``init_fn(v, s)`` + a ``source``
# default): the engines pass the query source as runtime data, so one
# compiled executor serves every source and ``run_direct(..., sources=[...])``
# can batch queries (DESIGN.md §8/§9).  The engine's ⊥-mask keeps every
# vertex but s at the reduction identity, exactly like the synthesized path.

def handwritten_sssp(s: int) -> DirectKernels:
    import jax.numpy as jnp
    return DirectKernels(
        name="sssp", rop="min", dtype="float",
        p_fn=lambda env: env["n"] + env["w"],
        init_fn=lambda v, s: jnp.where(v == s, 0.0, jnp.inf),
        source=s)


def handwritten_bfs_depth(s: int) -> DirectKernels:
    import jax.numpy as jnp
    from repro.graph.segment import identity
    return DirectKernels(
        name="bfs", rop="min", dtype="int",
        p_fn=lambda env: env["n"] + 1,
        init_fn=lambda v, s: jnp.where(v == s, 0, identity("min", jnp.int32)),
        source=s)


def handwritten_cc() -> DirectKernels:
    return DirectKernels(
        name="cc", rop="min", dtype="int",
        p_fn=lambda env: env["n"],
        init_fn=lambda v: v)


def handwritten_wp(s: int) -> DirectKernels:
    import jax.numpy as jnp
    return DirectKernels(
        name="wp", rop="max", dtype="float",
        p_fn=lambda env: jnp.minimum(env["n"], env["c"]),
        init_fn=lambda v, s: jnp.where(v == s, jnp.inf, -jnp.inf),
        source=s)


def handwritten_pagerank(n: int, gamma: float = 0.85) -> DirectKernels:
    return pagerank_kernels(n, gamma)


def handwritten_weighted_pagerank(n: int, gamma: float = 0.85) -> DirectKernels:
    """Edge-weight-proportional PageRank (P = n·w/wdeg(src)) — the weighted
    push− epilogue round; see synthesis.weighted_pagerank_kernels."""
    return weighted_pagerank_kernels(n, gamma)


HANDWRITTEN = {
    "SSSP": lambda: handwritten_sssp(0),
    "BFS": lambda: handwritten_bfs_depth(0),
    "CC": handwritten_cc,
    "WP": lambda: handwritten_wp(0),
}
