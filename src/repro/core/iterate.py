"""Iterative reduction engines (paper §3, Fig. 5) on JAX.

Implements the paper's four synchronous models —

  pull+  Def. 1: gather from predecessors, merge with previous value
                 (idempotent R)
  pull−  Def. 2: gather from ALL predecessors, full recompute (non-idempotent)
  push+  Def. 3: frontier-masked scatter from changed predecessors
  push−  Def. 4: scatter recompute from all predecessors

— over *reduction plans*: trees of ``Prim`` (componentwise monoid) and
``Lex`` (lexicographic tie-break, the result of fusing nested reductions,
rule FPNEST).  Lexicographic reductions use the classic two-pass trick
(extremize the primary key, then reduce the secondaries over the tied edges),
which keeps everything expressible with ``segment_*`` / scatter primitives —
the TPU-idiomatic replacement for the CPU frameworks' per-edge atomics
(DESIGN.md §2).

Engines in this module: pull/push (sparse, frontier-masked), dense (GridGraph
analogue), distributed (PowerGraph-style vertex-cut over shard_map).  The
Pallas engine lives in repro.kernels and reuses this plan algebra.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compat import shard_map
from repro.core.fusion import FusedRound, Lex, Prim
from repro.graph import segment
from repro.graph.partition import partition_edges
from repro.graph.structure import Graph, w_out_deg as structure_w_out_deg

DTYPES = {"int": jnp.int32, "float": jnp.float32, "vert": jnp.int32}

_IDEMPOTENT_OPS = ("min", "max", "or", "and")


@dataclasses.dataclass(frozen=True)
class CompRuntime:
    """Everything an engine needs for one component of the fused tuple.

    ``source`` is the component's *default* query source from the spec; the
    engines treat the value as runtime data (``_init_state`` accepts per-call
    overrides, the pallas executor takes it as a traced argument), so only
    ``source is not None`` — whether the initial state is ⊥-masked to one
    vertex at all — is structural."""
    idx: int
    op: str                          # monoid from its plan position
    dtype: object                    # jnp dtype
    p_fn: Callable                   # env → propagated value (synthesized P)
    init_fn: Callable                # (v_ids, src) → initial value (synthesized
                                     # I; legacy single-arg closures accepted)
    source: Optional[int]
    e_fn: Optional[Callable] = None  # epilogue (PageRank); None = identity

    @property
    def ident(self):
        return segment.identity(self.op, self.dtype)


def comp_runtimes(round_: FusedRound, synth: dict) -> list:
    """Assign each component its plan-position monoid + synthesized kernels.

    ``synth[idx]`` = (p_fn, init_fn[, e_fn]) from repro.core.synthesis."""
    ops = {}

    def walk(plan):
        ops[plan.comp] = plan.op
        if isinstance(plan, Lex):
            walk(plan.secondary)

    for leaf in round_.leaves:
        walk(leaf.plan)
    out = []
    for comp in round_.components:
        entry = synth[comp.idx]
        p_fn, init_fn = entry[0], entry[1]
        e_fn = entry[2] if len(entry) > 2 else None
        out.append(CompRuntime(
            idx=comp.idx, op=ops[comp.idx], dtype=DTYPES[comp.f.dtype],
            p_fn=p_fn, init_fn=init_fn, source=comp.source, e_fn=e_fn))
    return out


# ---------------------------------------------------------------------------
# Plan algebra: segment-reduce, scatter-reduce and two-state merge.
# ---------------------------------------------------------------------------

def _plan_comps(plan):
    if isinstance(plan, Prim):
        return (plan.comp,)
    return (plan.comp,) + _plan_comps(plan.secondary)


def plan_idempotent(plan) -> bool:
    if isinstance(plan, Prim):
        return plan.op in _IDEMPOTENT_OPS
    return plan_idempotent(plan.secondary)   # Lex primary is always min/max


def plan_segment_reduce(plan, evals: dict, dst, n: int, comps) -> dict:
    """Reduce per-edge values into per-vertex partials (pull side)."""
    if isinstance(plan, Prim):
        return {plan.comp: segment.segment_reduce(plan.op, evals[plan.comp], dst, n)}
    prim = segment.segment_reduce(plan.op, evals[plan.comp], dst, n)
    tie = evals[plan.comp] == prim[dst]
    masked = dict(evals)
    for j in _plan_comps(plan.secondary):
        masked[j] = jnp.where(tie, evals[j], comps[j].ident)
    return {plan.comp: prim,
            **plan_segment_reduce(plan.secondary, masked, dst, n, comps)}


def plan_scatter_reduce(plan, old: dict, evals: dict, dst, eactive, keep, comps) -> dict:
    """Push side: scatter per-edge values onto (lex-masked) old state.

    ``keep`` [n] marks vertices whose old value is still lexicographically
    eligible at this plan level; ``eactive`` [E] marks eligible edges."""
    c = plan.comp
    init = jnp.where(keep, old[c], comps[c].ident)
    vals = jnp.where(eactive, evals[c], comps[c].ident)
    prim = segment.scatter_reduce(plan.op, init, vals, dst)
    if isinstance(plan, Prim):
        return {c: prim}
    tie_e = eactive & (evals[c] == prim[dst])
    keep2 = keep & (old[c] == prim)
    rec = plan_scatter_reduce(plan.secondary, old, evals, dst, tie_e, keep2, comps)
    return {c: prim, **rec}


def plan_merge(plan, a: dict, b: dict, comps) -> dict:
    """Lexicographic/componentwise merge of two candidate states.

    Associative + commutative given per-component identities, so it is also
    the cross-shard combiner of the distributed engine."""
    c = plan.comp
    prim = segment.combine(plan.op, a[c], b[c])
    if isinstance(plan, Prim):
        return {c: prim}
    a_w = a[c] == prim
    b_w = b[c] == prim
    tie = a_w & b_w
    rec = plan_merge(plan.secondary, a, b, comps)
    out = {c: prim}
    for j in _plan_comps(plan.secondary):
        out[j] = jnp.where(tie, rec[j], jnp.where(a_w, a[j], b[j]))
    return out


def _recompute_merge(plans, comps_by_idx, state_d, red, has_pred) -> dict:
    """Update rule of the non-idempotent (−) models: the recomputed value
    wins unless the previous value is strictly better (protects the source's
    trivial-path init, cf. Thm. 3/5 side conditions), and vertices with no
    non-⊥ predecessor contribution keep their value (Def. 2/4: update only
    when CPreds ≠ ∅).  Components with an epilogue (PageRank) always take the
    recomputed value — E supplies the base term."""
    new_d = {}
    for p in plans:
        c = p.comp
        if comps_by_idx[c].e_fn is not None:
            for j in _plan_comps(p):
                new_d[j] = red[j]
            continue
        if isinstance(p, Prim) and p.op not in _IDEMPOTENT_OPS:
            new_d[c] = jnp.where(has_pred[c], red[c], state_d[c])
            continue
        comb = segment.combine(p.op, state_d[c], red[c])
        strictly = (comb == state_d[c]) & (state_d[c] != red[c])
        take_old = strictly | ~has_pred[c]
        for j in _plan_comps(p):
            new_d[j] = jnp.where(take_old, state_d[j], red[j])
    return new_d


# ---------------------------------------------------------------------------
# Shared iteration scaffolding.
# ---------------------------------------------------------------------------


def _host(x, cast):
    """Host-convert when concrete; pass tracers through (lets the engines
    be wrapped in jax.jit for HLO inspection, e.g. benchmarks/state_metrics)."""
    try:
        return cast(x)
    except (jax.errors.ConcretizationTypeError, jax.errors.TracerArrayConversionError):
        return x

@dataclasses.dataclass
class IterationResult:
    state: tuple                     # per-component [n] arrays
    iterations: int
    edge_work: float
    converged: object = True         # fixpoint reached (no active vertices,
                                     # sentinel clean) — bool, or [B]/tracer
    diverged: object = False         # NaN/Inf sentinel fired in-loop
    active_count: object = 0         # still-active vertices at exit (> 0
                                     # exactly when max_iter exhausted)
    residual: object = 0.0           # max |Δ| of the LAST iteration over
                                     # float components (0 if none)


def _divergence(comps, new):
    """In-loop NaN/Inf sentinel (zero extra launches: elementwise reductions
    folded into the fixpoint body).  NaN anywhere is divergence; ±Inf is
    divergence only for non-extremal components (sum/prod or an epilogue),
    where the identities are finite and Inf means overflow — for min/max
    components ±Inf is the legitimate ⊥."""
    bad = jnp.asarray(False)
    for i, cr in enumerate(comps):
        if not jnp.issubdtype(cr.dtype, jnp.floating):
            continue
        bad = bad | jnp.any(jnp.isnan(new[i]))
        if cr.op in ("sum", "prod") or cr.e_fn is not None:
            bad = bad | jnp.any(jnp.isinf(new[i]))
    return bad


def _residual(comps, new, old):
    """Max |new − old| over float components — the last iteration's residual,
    reported in NonConvergence diagnostics.  Non-finite diffs (a vertex
    leaving ⊥) are masked: 'changed from unreachable' is active_count's
    story, not a numeric residual."""
    r = jnp.float32(0)
    for i, cr in enumerate(comps):
        if not jnp.issubdtype(cr.dtype, jnp.floating):
            continue
        d = jnp.abs(new[i] - old[i])
        r = jnp.maximum(r, jnp.max(jnp.where(jnp.isfinite(d), d,
                                             jnp.float32(0))))
    return r


def _finish_result(comps, state, active, k, work, div, resid) -> IterationResult:
    """Shared exit bookkeeping: host-convert the loop carry into an
    ``IterationResult`` with structured convergence fields.  ``active`` may
    be longer than n (padded engines pass the logical slice)."""
    active_n = jnp.sum(active.astype(jnp.int32))
    return IterationResult(
        state=state, iterations=_host(k, int), edge_work=_host(work, float),
        converged=_host(jnp.logical_and(~div, active_n == 0), bool),
        diverged=_host(div, bool),
        active_count=_host(active_n, int),
        residual=_host(resid, float))


def check_shard_replication(counts, what: str, engine: str) -> None:
    """Replication contract of the sharded engines: state (and with it the
    iteration count / direction sequence) is replicated, so every shard must
    report the identical value.  On divergence, report the per-shard values
    and the offending shard ids — the minority shards whose collectives
    broke — instead of a bare mismatch."""
    counts = np.asarray(counts)
    if counts.size == 0 or (counts == counts.flat[0]).all():
        return
    vals, freq = np.unique(counts, return_counts=True)
    majority = vals[int(freq.argmax())]
    offenders = np.flatnonzero(counts != majority)
    raise RuntimeError(
        f"{engine} shards diverged on {what}: per-shard {what} = "
        f"{counts.tolist()}; majority value {majority} held by "
        f"{int(freq.max())}/{counts.size} shards, offending shard ids "
        f"{offenders.tolist()} — replicated-state contract broken")


def _init_arity(init_fn) -> int:
    """Positional arity of an init kernel: 2 for the source-generic form
    ``init_fn(v, src)``, 1 for legacy closures that bake the source in."""
    try:
        params = inspect.signature(init_fn).parameters.values()
    except (TypeError, ValueError):          # builtins / odd callables
        return 1
    n_pos = sum(p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                for p in params)
    return 2 if n_pos >= 2 else 1


def _init_state(comps, n: int, sources: Optional[dict] = None):
    """Initial per-component state (condition C1/C2): the synthesized I on
    the source vertex, ⊥ everywhere else; sourceless components initialize
    every vertex.

    ``sources`` optionally overrides ``cr.source`` per component index with a
    runtime value — a Python int or a TRACED scalar.  Tracing through the
    source (rather than closing over it) is what lets one compiled executor
    serve every query source (DESIGN.md §8); overrides only apply to
    components that are sourced in the spec (sourced-ness is structural)."""
    v = jnp.arange(n, dtype=jnp.int32)
    state = []
    for cr in comps:
        src = cr.source
        if sources is not None and cr.source is not None:
            src = sources.get(cr.idx, cr.source)
        if _init_arity(cr.init_fn) >= 2:
            vals = cr.init_fn(v, src)
        else:
            vals = cr.init_fn(v)
        vals = jnp.asarray(vals, dtype=cr.dtype)
        vals = jnp.broadcast_to(vals, (n,))
        if cr.source is not None:
            vals = jnp.where(v == src, vals, cr.ident)
        state.append(vals)
    return tuple(state)


def _edge_env(src, dst, w, c, out_deg, n, wdeg=None):
    env = {"w": w, "c": c, "esrc": src, "edst": dst,
           "outdeg": jnp.maximum(out_deg, 1).astype(jnp.float32)[src],
           "nv": jnp.float32(n)}
    # weighted out-degree normalizer ("wdeg", weighted-PageRank-style P);
    # computed ONCE per graph (structure.w_out_deg) so every engine — and
    # both pallas sweep directions — divides by the bit-identical vector
    env["wdeg"] = jnp.ones_like(env["outdeg"]) if wdeg is None else wdeg[src]
    return env


def _propagate(comps, state, src, env):
    """P'(n, e): synthesized P wrapped with the ⊥ guard (condition C3)."""
    evals = {}
    for cr in comps:
        nvals = state[cr.idx][src]
        p = jnp.asarray(cr.p_fn({"n": nvals, **env}), dtype=cr.dtype)
        evals[cr.idx] = jnp.where(nvals == cr.ident, cr.ident, p)
    return evals


def _changed(comps, new, old, tol):
    ch = jnp.zeros(new[0].shape, dtype=bool)
    for i, cr in enumerate(comps):
        if tol > 0 and jnp.issubdtype(cr.dtype, jnp.floating):
            ch = ch | (jnp.abs(new[i] - old[i]) > tol)
        else:
            ch = ch | (new[i] != old[i])
    return ch


def _apply_epilogue(comps, red: dict) -> dict:
    out = dict(red)
    for cr in comps:
        if cr.e_fn is not None:
            out[cr.idx] = jnp.asarray(cr.e_fn({"n": red[cr.idx]}), dtype=cr.dtype)
    return out


def _has_pred(comps, state, src, dst, valid_e, n) -> dict:
    out = {}
    for cr in comps:
        nonbot = (state[cr.idx][src] != cr.ident) & valid_e
        out[cr.idx] = segment.segment_reduce("or", nonbot, dst, n)
    return out


# ---------------------------------------------------------------------------
# pull / push engines.
# ---------------------------------------------------------------------------

def iterate_graph(g: Graph, comps, plans, model: str = "pull+",
                  max_iter: Optional[int] = None, tol: float = 0.0,
                  sources: Optional[dict] = None) -> IterationResult:
    """Run the fused reduction to fixpoint.  ``plans`` = [leaf.plan, ...].
    ``sources`` optionally overrides per-component query sources
    (see ``_init_state``)."""
    n = g.n
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(plan_idempotent(p) for p in plans)
    if model in ("pull+", "push+") and not idempotent:
        model = {"pull+": "pull-", "push+": "push-"}[model]
    comps_by_idx = {cr.idx: cr for cr in comps}

    eo = g.by_dst if model.startswith("pull") else g.by_src
    src, dst = eo.src, eo.dst
    env = _edge_env(src, dst, eo.weight, eo.capacity, g.out_deg, n,
                    wdeg=structure_w_out_deg(g))
    valid_e = jnp.ones_like(src, dtype=bool)

    def body(carry):
        state, active, k, work, div, resid = carry
        state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
        evals = _propagate(comps, state, src, env)
        if model in ("pull+", "push+"):
            eactive = active[src]
            work = work + jnp.sum(eactive.astype(jnp.float32))
            if model == "pull+":
                masked = {i: jnp.where(eactive, evals[i], comps_by_idx[i].ident)
                          for i in evals}
                red = {}
                for p in plans:
                    red.update(plan_segment_reduce(p, masked, dst, n, comps_by_idx))
                new_d = {}
                for p in plans:
                    new_d.update(plan_merge(p, state_d, red, comps_by_idx))
            else:
                new_d = {}
                keep = jnp.ones(n, dtype=bool)
                for p in plans:
                    new_d.update(plan_scatter_reduce(
                        p, state_d, evals, dst, eactive, keep, comps_by_idx))
        else:
            # pull−/push−: ALL predecessors propagate; full recompute.
            work = work + jnp.float32(src.shape[0])
            red = {}
            if model == "pull-":
                for p in plans:
                    red.update(plan_segment_reduce(p, evals, dst, n, comps_by_idx))
            else:
                ident = {cr.idx: jnp.full((n,), cr.ident, cr.dtype) for cr in comps}
                keep = jnp.zeros(n, dtype=bool)
                for p in plans:
                    red.update(plan_scatter_reduce(
                        p, ident, evals, dst, valid_e, keep, comps_by_idx))
            red = _apply_epilogue(comps, red)
            has_pred = _has_pred(comps, state, src, dst, valid_e, n)
            new_d = _recompute_merge(plans, comps_by_idx, state_d, red, has_pred)
        new = tuple(new_d[cr.idx] for cr in comps)
        ch = _changed(comps, new, state, tol)
        div = div | _divergence(comps, new)
        resid = _residual(comps, new, state)
        ch = ch & ~div                     # divergence drains the frontier:
        return new, ch, k + 1, work, div, resid   # the loop exits next cond

    def cond(carry):
        _, active, k, _, _, _ = carry
        return jnp.any(active) & (k < max_iter)

    state0 = _init_state(comps, n, sources)
    state, active, k, work, div, resid = jax.lax.while_loop(
        cond, body, (state0, jnp.ones(n, bool), jnp.int32(0), jnp.float32(0),
                     jnp.asarray(False), jnp.float32(0)))
    return _finish_result(comps, state, active, k, work, div, resid)


# ---------------------------------------------------------------------------
# adaptive engine (Gemini): per-iteration push/pull direction switch.
# ---------------------------------------------------------------------------

def iterate_adaptive(g: Graph, comps, plans, max_iter: Optional[int] = None,
                     tol: float = 0.0, dense_threshold: float = 0.05,
                     sources: Optional[dict] = None) -> IterationResult:
    """Gemini's signature feature: each iteration picks the propagation
    direction from the frontier density — a dense frontier favours the
    pull-side segment reduce (sequential reads, no contention), a sparse
    frontier favours the push-side frontier-masked scatter (work ∝ active
    out-degree).  Idempotent plans only (Gemini requires both a push and a
    pull implementation; non-idempotent falls back to pull−)."""
    n = g.n
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    if not all(plan_idempotent(p) for p in plans):
        return iterate_graph(g, comps, plans, model="pull-",
                             max_iter=max_iter, tol=tol, sources=sources)
    comps_by_idx = {cr.idx: cr for cr in comps}
    pull_eo, push_eo = g.by_dst, g.by_src
    wdeg = structure_w_out_deg(g)
    env_pull = _edge_env(pull_eo.src, pull_eo.dst, pull_eo.weight,
                         pull_eo.capacity, g.out_deg, n, wdeg=wdeg)
    env_push = _edge_env(push_eo.src, push_eo.dst, push_eo.weight,
                         push_eo.capacity, g.out_deg, n, wdeg=wdeg)

    def pull_branch(args):
        state, active = args
        state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
        evals = _propagate(comps, state, pull_eo.src, env_pull)
        eactive = active[pull_eo.src]
        masked = {i: jnp.where(eactive, evals[i], comps_by_idx[i].ident)
                  for i in evals}
        red = {}
        for p in plans:
            red.update(plan_segment_reduce(p, masked, pull_eo.dst, n,
                                           comps_by_idx))
        new_d = {}
        for p in plans:
            new_d.update(plan_merge(p, state_d, red, comps_by_idx))
        return tuple(new_d[cr.idx] for cr in comps)

    def push_branch(args):
        state, active = args
        state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
        evals = _propagate(comps, state, push_eo.src, env_push)
        eactive = active[push_eo.src]
        new_d = {}
        keep = jnp.ones(n, dtype=bool)
        for p in plans:
            new_d.update(plan_scatter_reduce(
                p, state_d, evals, push_eo.dst, eactive, keep, comps_by_idx))
        return tuple(new_d[cr.idx] for cr in comps)

    def body(carry):
        state, active, k, work, pulls, div, resid = carry
        frac = jnp.mean(active.astype(jnp.float32))
        use_pull = frac > dense_threshold
        new = jax.lax.cond(use_pull, pull_branch, push_branch,
                           (state, active))
        work = work + jnp.sum(active.astype(jnp.float32)
                              * g.out_deg.astype(jnp.float32))
        ch = _changed(comps, new, state, tol)
        div = div | _divergence(comps, new)
        resid = _residual(comps, new, state)
        ch = ch & ~div
        return (new, ch, k + 1, work, pulls + use_pull.astype(jnp.int32),
                div, resid)

    def cond(carry):
        _, active, k, _, _, _, _ = carry
        return jnp.any(active) & (k < max_iter)

    state0 = _init_state(comps, n, sources)
    state, active, k, work, pulls, div, resid = jax.lax.while_loop(
        cond, body,
        (state0, jnp.ones(n, bool), jnp.int32(0), jnp.float32(0),
         jnp.int32(0), jnp.asarray(False), jnp.float32(0)))
    res = _finish_result(comps, state, active, k, work, div, resid)
    res.pull_iters = _host(pulls, int)
    return res


# ---------------------------------------------------------------------------
# dense engine (GridGraph analogue): dense semiring products.
# ---------------------------------------------------------------------------

def iterate_dense(g: Graph, comps, plans, model: str = "pull+",
                  max_iter: Optional[int] = None, tol: float = 0.0,
                  sources: Optional[dict] = None) -> IterationResult:
    """Reference engine on a dense [n, n] edge matrix (small graphs only)."""
    n = g.n
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    src, dst, w, c = g.host_edges()
    adj = np.zeros((n, n), dtype=bool)
    wm = np.zeros((n, n), dtype=np.float32)
    cm = np.zeros((n, n), dtype=np.float32)
    adj[src, dst] = True
    wm[src, dst] = w
    cm[src, dst] = c
    adj, wm, cm = jnp.asarray(adj), jnp.asarray(wm), jnp.asarray(cm)
    comps_by_idx = {cr.idx: cr for cr in comps}
    idempotent = all(plan_idempotent(p) for p in plans)

    vs = jnp.arange(n, dtype=jnp.int32)
    env = {"w": wm, "c": cm,
           "esrc": jnp.broadcast_to(vs[:, None], (n, n)),
           "edst": jnp.broadcast_to(vs[None, :], (n, n)),
           "outdeg": jnp.broadcast_to(
               jnp.maximum(g.out_deg, 1).astype(jnp.float32)[:, None], (n, n)),
           "wdeg": jnp.broadcast_to(
               structure_w_out_deg(g)[:, None], (n, n)),
           "nv": jnp.float32(n)}

    _DENSE_RED = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum,
                  "prod": jnp.prod, "or": jnp.max, "and": jnp.min}

    def dense_reduce(plan, mats: dict) -> dict:
        cidx = plan.comp
        if isinstance(plan, Prim):
            red = _DENSE_RED[plan.op](mats[cidx], axis=0)
            return {cidx: red}
        prim = _DENSE_RED[plan.op](mats[cidx], axis=0)
        tie = mats[cidx] == prim[None, :]
        masked = dict(mats)
        for j in _plan_comps(plan.secondary):
            masked[j] = jnp.where(tie, mats[j], comps_by_idx[j].ident)
        return {cidx: prim, **dense_reduce(plan.secondary, masked)}

    def body(carry):
        state, active, k, work, div, resid = carry
        state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
        work = work + jnp.float32(g.num_edges)
        mats = {}
        for cr in comps:
            nmat = jnp.broadcast_to(state_d[cr.idx][:, None], (n, n))
            p = jnp.asarray(cr.p_fn({"n": nmat, **env}), dtype=cr.dtype)
            bot = state_d[cr.idx][:, None] == cr.ident
            mats[cr.idx] = jnp.where(adj & ~bot, p, cr.ident)
        red = {}
        for pl in plans:
            red.update(dense_reduce(pl, mats))
        red = _apply_epilogue(comps, red)
        if idempotent:
            new_d = {}
            for pl in plans:
                new_d.update(plan_merge(pl, state_d, red, comps_by_idx))
        else:
            has_pred = {cr.idx: jnp.any(adj & (state_d[cr.idx][:, None] != cr.ident),
                                        axis=0) for cr in comps}
            new_d = _recompute_merge(plans, comps_by_idx, state_d, red, has_pred)
        new = tuple(new_d[cr.idx] for cr in comps)
        ch = _changed(comps, new, state, tol)
        div = div | _divergence(comps, new)
        resid = _residual(comps, new, state)
        ch = ch & ~div
        return new, ch, k + 1, work, div, resid

    def cond(carry):
        _, active, k, _, _, _ = carry
        return jnp.any(active) & (k < max_iter)

    state0 = _init_state(comps, n, sources)
    state, active, k, work, div, resid = jax.lax.while_loop(
        cond, body, (state0, jnp.ones(n, bool), jnp.int32(0), jnp.float32(0),
                     jnp.asarray(False), jnp.float32(0)))
    return _finish_result(comps, state, active, k, work, div, resid)


# ---------------------------------------------------------------------------
# distributed engine: PowerGraph-style vertex-cut over shard_map.
# ---------------------------------------------------------------------------

def iterate_distributed(g: Graph, comps, plans, mesh, axes=("data",),
                        model: str = "pull+", max_iter: Optional[int] = None,
                        tol: float = 0.0,
                        sources: Optional[dict] = None) -> IterationResult:
    """Edge-partitioned fused reduction under shard_map.

    Each shard: local masked segment-reduce (Gather+Apply); partials merge
    across shards with monoid collectives (Scatter).  State is replicated, so
    the convergence flag is identical on every shard and the while_loop is
    collective-safe."""
    from jax.sharding import PartitionSpec as P

    n = g.n
    axes = tuple(axes) if not isinstance(axes, str) else (axes,)
    k_shards = int(np.prod([mesh.shape[a] for a in axes]))
    part = partition_edges(g, k_shards)
    max_iter = max_iter if max_iter is not None else 2 * n + 4
    idempotent = all(plan_idempotent(p) for p in plans)
    if model == "pull+" and not idempotent:
        model = "pull-"
    comps_by_idx = {cr.idx: cr for cr in comps}
    out_deg = jnp.maximum(g.out_deg, 1).astype(jnp.float32)
    wdeg_v = structure_w_out_deg(g)

    def shard_fn(src, dst, w, c, mask):
        src, dst = src[0], dst[0]            # [1, e_loc] → [e_loc]
        w, c, mask = w[0], c[0], mask[0]
        env = {"w": w, "c": c, "esrc": src, "edst": dst,
               "outdeg": out_deg[src], "wdeg": wdeg_v[src],
               "nv": jnp.float32(n)}

        def cross_plan(plan, red: dict) -> dict:
            """Cross-shard lexicographic combine with monoid collectives only:
            global primary via pmin/pmax, tie-mask the local secondaries to
            identity, recurse.  Value-invariant across shards (replicated),
            and k× less traffic than an all_gather merge."""
            best = segment.psum_like(plan.op, red[plan.comp], axes)
            out = {plan.comp: best}
            if isinstance(plan, Lex):
                tie = red[plan.comp] == best
                masked = {j: jnp.where(tie, red[j], comps_by_idx[j].ident)
                          for j in _plan_comps(plan.secondary)}
                out.update(cross_plan(plan.secondary, masked))
            return out

        def cross_shard(red: dict) -> dict:
            out = dict(red)
            for p in plans:
                out.update(cross_plan(p, red))
            return out

        def body(carry):
            state, active, k, work, div, resid = carry
            state_d = {cr.idx: state[i] for i, cr in enumerate(comps)}
            evals = _propagate(comps, state, src, env)
            eactive = (active[src] & mask) if model == "pull+" else mask
            # SHARD-LOCAL work (no psum): the [k] output vector surfaces the
            # per-shard balance; the total is their host-side sum.
            work = work + jnp.sum(eactive.astype(jnp.float32))
            masked = {i: jnp.where(eactive, evals[i], comps_by_idx[i].ident)
                      for i in evals}
            red = {}
            for p in plans:
                red.update(plan_segment_reduce(p, masked, dst, n, comps_by_idx))
            red = cross_shard(red)
            if model == "pull+":
                new_d = {}
                for p in plans:
                    new_d.update(plan_merge(p, state_d, red, comps_by_idx))
            else:
                red = _apply_epilogue(comps, red)
                nonbot = {cr.idx: segment.segment_reduce(
                    "or", (state_d[cr.idx][src] != cr.ident) & mask, dst, n)
                    for cr in comps}
                has_pred = {i: segment.psum_like("or", nonbot[i], axes).astype(bool)
                            for i in nonbot}
                new_d = _recompute_merge(plans, comps_by_idx, state_d, red, has_pred)
            new = tuple(new_d[cr.idx] for cr in comps)
            ch = _changed(comps, new, state, tol)
            # sentinel on the replicated post-combine state: every shard
            # computes the identical flag, so the drain stays collective-safe
            div = div | _divergence(comps, new)
            resid = _residual(comps, new, state)
            ch = ch & ~div
            return new, ch, k + 1, work, div, resid

        def cond(carry):
            _, active, k, _, _, _ = carry
            return jnp.any(active) & (k < max_iter)

        state0 = _init_state(comps, n, sources)
        state, active, k, work, div, resid = jax.lax.while_loop(
            cond, body, (state0, jnp.ones(n, bool), jnp.int32(0),
                         jnp.float32(0), jnp.asarray(False), jnp.float32(0)))
        active_n = jnp.sum(active.astype(jnp.int32))
        return (state, k[None], work[None], div[None], resid[None],
                active_n[None])

    pspec = P(axes)
    fn = shard_map(shard_fn, mesh=mesh,
                   in_specs=(pspec, pspec, pspec, pspec, pspec),
                   out_specs=(tuple(P() for _ in comps), P(axes), P(axes),
                              P(axes), P(axes), P(axes)))
    state, k, work, div, resid, active_n = fn(
        part.src, part.dst, part.weight, part.capacity, part.mask)
    k_host = np.asarray(k)
    work_host = np.asarray(work)
    # Replication contract: the state (and with it the convergence flag) is
    # replicated, so every shard must report the same iteration count.  A
    # mismatch means a collective went wrong — fail loud (naming the
    # offending shards) instead of silently trusting shard 0.
    check_shard_replication(k_host, "iteration count", "distributed")
    div_h = bool(np.asarray(div)[0])
    act_h = int(np.asarray(active_n)[0])
    res = IterationResult(state=state, iterations=int(k_host[0]),
                          edge_work=float(work_host.sum()),
                          converged=(not div_h) and act_h == 0,
                          diverged=div_h, active_count=act_h,
                          residual=float(np.asarray(resid)[0]))
    res.shards = k_shards
    res.shard_work = tuple(float(w) for w in work_host)   # per-shard balance
    return res
