"""Fusion transformations (paper §4.2, Fig. 8) → triple-let normal form.

Every specification term is rewritten into a ``FusedProgram``: a sequence of
rounds (nested triple-lets, §4.3), each round being exactly the paper's

    ilet X := R F in mlet X' := E in rlet X'' := R'⟨X'⟩ in e

* **ilet**: ONE fused path-based reduction over a tuple of components
  (rules FPRED, FPNEST, FMRED, FILETBIN, FMINILET, FMPAIR + common-operation
  elimination).  Nested ``args min/max`` restrictions become lexicographic
  reduction plans (FPNEST); pairs of flat reductions become tuple plans
  (FMPAIR); duplicate (F, source) components are shared (CSE).
* **mlet**: per-vertex expressions over the component outputs (the map).
* **rlet**: fused vertex-based reductions (FVRED, FLETSBIN, FRINLETS,
  FRPAIR), with optional per-vertex boolean constraints (§4.3 sugar).
* **out**: the final scalar/vertex expression.

Semantics preservation (paper Thm. 1) is checked empirically by
``tests/test_fusion.py`` against the path-enumeration oracle in ``lang.py``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.core import lang as L
from repro.core.kernel_lang import Bin, Expr, ITE, Lit, Var

# ---------------------------------------------------------------------------
# Fused IR
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Component:
    """One slot of the fused tuple-valued path reduction."""
    idx: int
    f: L.PathFn
    source: Optional[int]          # None ⇒ Paths(v) (all sources)


@dataclasses.dataclass(frozen=True)
class Prim:
    """Plain reduction of component `comp` with monoid `op`."""
    op: str
    comp: int


@dataclasses.dataclass(frozen=True)
class Lex:
    """Lexicographic: extremize `(op, comp)` first; reduce `secondary` over
    the tied paths (result of rules FPNEST / FMRED)."""
    op: str                        # "min" | "max"
    comp: int
    secondary: "Prim | Lex"


Plan = "Prim | Lex"


def plan_components(plan) -> tuple:
    if isinstance(plan, Prim):
        return (plan.comp,)
    return (plan.comp,) + plan_components(plan.secondary)


def plan_output(plan) -> int:
    """Component index whose value the leaf variable binds to."""
    if isinstance(plan, Prim):
        return plan.comp
    return plan_output(plan.secondary)


def plan_key(plan, comps) -> str:
    if isinstance(plan, Prim):
        c = comps[plan.comp]
        return f"{plan.op}:{c.f.kind}@{c.source}"
    c = comps[plan.comp]
    return f"lex[{plan.op}:{c.f.kind}@{c.source}]->{plan_key(plan.secondary, comps)}"


@dataclasses.dataclass(frozen=True)
class Leaf:
    """A bound ilet variable: one (possibly lexicographic) path reduction."""
    name: str
    plan: object                   # Plan


@dataclasses.dataclass
class FusedRound:
    components: list               # [Component]
    leaves: list                   # [Leaf]
    maps: list                     # [(name, Expr over leaf names / ScalarRefs)]
    vreduces: list                 # [(name, op, map_name, cond_map_name|None)]
    out_kind: str                  # "vertex" | "scalar"
    out: Expr                      # over map names (vertex) or vreduce names
    multi_out: Optional[list] = None
                                   # [(key, Expr)] set by fuse_many: every
                                   # paired request's OWN output expression —
                                   # the engine returns {key: value} instead
                                   # of evaluating ``out`` alone


@dataclasses.dataclass
class FusedProgram:
    rounds: list                   # [(bind_name|None, FusedRound)] last = result
    stats: "FusionStats"


@dataclasses.dataclass
class FusionStats:
    fpnest: int = 0                # nested path reductions flattened
    fmred: int = 0                 # PathSel desugared
    fmpair: int = 0                # path-reduction pairings
    frpair: int = 0                # vertex-reduction pairings
    fbin: int = 0                  # operator fusions (FILETBIN/FLETSBIN)
    cse: int = 0                   # common operations eliminated
    wall_ms: float = 0.0

    def total_rules(self):
        return self.fpnest + self.fmred + self.fmpair + self.frpair + self.fbin


# ---------------------------------------------------------------------------
# The fusion pass.
# ---------------------------------------------------------------------------


class _RoundBuilder:
    def __init__(self, stats: FusionStats):
        self.stats = stats
        self.components: list = []
        self.leaves: list = []
        self._leaf_key: dict = {}
        self.maps: list = []
        self.vreduces: list = []
        self._fresh = 0
        self._pending_comps = 0        # components added for a leaf under test

    def fresh(self, prefix):
        self._fresh += 1
        return f"{prefix}{self._fresh}"

    def component(self, f: L.PathFn, source) -> int:
        # NOTE: components are per plan-position, NOT deduped on (f, source) —
        # two leaves reducing the same F with different monoids (e.g. NWR's
        # min-capacity and max-capacity) need distinct iteration state.
        # Common-operation elimination happens at leaf granularity below.
        idx = len(self.components)
        self.components.append(Component(idx=idx, f=f, source=source))
        self._pending_comps += 1
        return idx

    def leaf(self, plan) -> str:
        key = plan_key(plan, self.components)
        if key in self._leaf_key:
            # common-operation elimination: identical reduction already fused —
            # roll back this leaf's freshly added components.
            del self.components[len(self.components) - self._pending_comps:]
            self._pending_comps = 0
            self.stats.cse += 1
            return self._leaf_key[key]
        self._pending_comps = 0
        if self.leaves:
            self.stats.fmpair += 1     # pairing with the existing fused tuple
        name = self.fresh("x")
        self.leaves.append(Leaf(name=name, plan=plan))
        self._leaf_key[key] = name
        return name

    # ----- path-set flattening (FPNEST) ------------------------------------
    def flatten_paths(self, pathset, final_op: str, final_f: L.PathFn):
        """Build the lexicographic plan for (possibly nested) restricted
        paths; returns (plan, source)."""
        restricts = []
        ps = pathset
        while isinstance(ps, L.ArgsRestrict):
            restricts.append(ps)
            ps = ps.inner
        assert isinstance(ps, L.AllPaths)
        source = ps.source
        plan = Prim(final_op, self.component(final_f, source))
        # FPNEST flattens innermost-first: for
        # ArgsRestrict(r2,f2, ArgsRestrict(r1,f1, All)) the primary key is f1
        # (innermost restrict), then f2, then the final F.  `restricts` is
        # outermost-first, so wrapping in list order leaves the innermost
        # restrict as the outermost Lex key.
        for r in restricts:
            self.stats.fpnest += 1
            plan = Lex(op=r.r, comp=self.component(r.f, source), secondary=plan)
        return plan, source

    # ----- m-terms → per-vertex Expr ----------------------------------------
    def lower_m(self, t) -> Expr:
        if isinstance(t, L.PathReduce):
            plan, _ = self.flatten_paths(t.paths, t.r, t.f)
            return Var(self.leaf(plan), "float")
        if isinstance(t, L.PathSel):
            self.stats.fmred += 1
            return self.lower_m(L.PathReduce(
                "min", t.f, L.ArgsRestrict(t.r, t.f_sel, t.paths)))
        if isinstance(t, L.Cardinality):
            return self.lower_m(L.PathReduce("sum", L.ONE, t.paths))
        if isinstance(t, L.MBin):
            self.stats.fbin += 1
            return Bin(t.op, self.lower_m(t.a), self.lower_m(t.b))
        if isinstance(t, L.MConst):
            return Lit(t.val, "float")
        if isinstance(t, L.ScalarRef):
            return Var(f"$scalar:{t.name}", "float")
        raise TypeError(t)

    def add_map(self, expr: Expr) -> str:
        name = self.fresh("m")
        self.maps.append((name, expr))
        return name

    def add_vreduce(self, op, map_name, cond_name) -> str:
        if self.vreduces:
            self.stats.frpair += 1
        name = self.fresh("r")
        self.vreduces.append((name, op, map_name, cond_name))
        return name


def _lower_r(b: _RoundBuilder, t) -> Expr:
    """r-term → scalar Expr over vreduce names."""
    if isinstance(t, L.VertexReduce):
        m_expr = b.lower_m(t.m)
        m_name = b.add_map(m_expr)
        cond_name = None
        if t.cond is not None:
            cond_name = b.add_map(b.lower_m(t.cond))
        r_name = b.add_vreduce(t.r, m_name, cond_name)
        return Var(r_name, "float")
    if isinstance(t, L.RBin):
        b.stats.fbin += 1
        return Bin(t.op, _lower_r(b, t.a), _lower_r(b, t.b))
    if isinstance(t, L.RConst):
        return Lit(t.val, "float")
    if isinstance(t, L.ScalarRef):
        return Var(f"$scalar:{t.name}", "float")
    raise TypeError(t)


def _is_r_term(t) -> bool:
    if isinstance(t, (L.VertexReduce, L.RConst, L.LetRound)):
        return True
    if isinstance(t, L.RBin):
        return True
    return False


def fuse(term, stats: Optional[FusionStats] = None) -> FusedProgram:
    t0 = time.perf_counter()
    stats = stats or FusionStats()
    rounds = []

    def one_round(t, bind_name=None):
        b = _RoundBuilder(stats)
        if _is_r_term(t):
            out = _lower_r(b, t)
            kind = "scalar"
        else:
            expr = b.lower_m(t)
            m_name = b.add_map(expr)
            out = Var(m_name, "float")
            kind = "vertex"
        rounds.append((bind_name, FusedRound(
            components=b.components, leaves=b.leaves, maps=b.maps,
            vreduces=b.vreduces, out_kind=kind, out=out)))

    def walk(t, bind_name=None):
        if isinstance(t, L.LetRound):
            walk(t.bound, bind_name=t.name)   # earlier round(s)
            walk(t.body, bind_name=bind_name)
        else:
            one_round(t, bind_name)

    walk(term, None)
    stats.wall_ms = (time.perf_counter() - t0) * 1e3
    return FusedProgram(rounds=rounds, stats=stats)


def fuse_many(named_terms, stats: Optional[FusionStats] = None) -> FusedProgram:
    """Fuse MANY scalar requests into ONE round with per-request answers.

    ``named_terms`` is a dict (or [(key, term)] sequence) of single-round
    scalar (r-term) specifications — different users' RADIUS/DRR/ECC-style
    queries over one graph.  All of them lower into a SINGLE shared round
    builder, so the paper's pairing rules apply across requests exactly as
    they do within one: shared path reductions dedup through
    common-operation elimination, distinct ones pair via FMPAIR, and the
    vertex reductions pair via FRPAIR.  Unlike the ``r1 + 0*r2`` pairing
    trick (the examples/analytics_service.py sketch), the fused round keeps
    EVERY request's own output expression in ``multi_out``, so one
    execution of the program yields ``{key: value}`` — no per-request
    re-execution (the N+1 the sketch suffered).

    Multi-round (LetRound) and vertex-valued specifications don't pair —
    they raise ``TypeError`` and should run solo via ``fuse``."""
    t0 = time.perf_counter()
    stats = stats or FusionStats()
    items = list(named_terms.items()) if isinstance(named_terms, dict) \
        else list(named_terms)
    if not items:
        raise ValueError("fuse_many needs at least one request")
    b = _RoundBuilder(stats)
    outs = []
    for key, t in items:
        if isinstance(t, L.LetRound) or not _is_r_term(t):
            raise TypeError(
                f"fuse_many pairs single-round scalar requests; request "
                f"{key!r} is a {type(t).__name__} (vertex-valued or "
                "multi-round specifications run solo via fuse)")
        outs.append((key, _lower_r(b, t)))
    round_ = FusedRound(components=b.components, leaves=b.leaves,
                        maps=b.maps, vreduces=b.vreduces, out_kind="scalar",
                        out=outs[0][1], multi_out=outs)
    stats.wall_ms = (time.perf_counter() - t0) * 1e3
    return FusedProgram(rounds=[(None, round_)], stats=stats)


# ---------------------------------------------------------------------------
# Unfused lowering (baseline for the fusion experiments, Fig. 13/14):
# every path reduction / vertex reduction becomes its own single-leaf round.
# ---------------------------------------------------------------------------

def lower_unfused(term) -> FusedProgram:
    """Like ``fuse()``, but every path reduction leaf becomes its OWN round
    (its own iterative pass over the edges) and every vertex reduction its
    own vertex pass — the unfused baseline of the paper's Fig. 13/14."""
    stats = FusionStats()              # stays all-zero: nothing fuses
    rounds = []
    counter = [0]

    def fresh():
        counter[0] += 1
        return f"u{counter[0]}"

    def lower_m(t) -> Expr:
        """m-term → Expr over $vec refs; each leaf emits a vertex round."""
        if isinstance(t, (L.PathReduce, L.PathSel, L.Cardinality)):
            # paper-unfused semantics: every nested restriction (args
            # min/max) is its OWN phase over the edges — the unfused WSP
            # computes shortest lengths in pass 1 and the widest capacity
            # in pass 2 (Fig. 13); only FPNEST merges them.
            ps = getattr(t, "paths", L.AllPaths())
            restricts = []
            while isinstance(ps, L.ArgsRestrict):
                restricts.append(ps)
                ps = ps.inner
            for rr in reversed(restricts):           # innermost first
                b0 = _RoundBuilder(FusionStats())
                e0 = b0.lower_m(L.PathReduce(rr.r, rr.f, ps))
                m0 = b0.add_map(e0)
                rounds.append((fresh(), FusedRound(
                    components=b0.components, leaves=b0.leaves,
                    maps=b0.maps, vreduces=[], out_kind="vertex",
                    out=Var(m0, "float"))))
            b = _RoundBuilder(FusionStats())
            expr = b.lower_m(t)
            m = b.add_map(expr)
            name = fresh()
            rounds.append((name, FusedRound(
                components=b.components, leaves=b.leaves, maps=b.maps,
                vreduces=[], out_kind="vertex", out=Var(m, "float"))))
            return Var(f"$vec:{name}", "float")
        if isinstance(t, L.MBin):
            return Bin(t.op, lower_m(t.a), lower_m(t.b))
        if isinstance(t, L.MConst):
            return Lit(t.val, "float")
        if isinstance(t, L.ScalarRef):
            return Var(f"$scalar:{t.name}", "float")
        raise TypeError(t)

    def lower_r(t) -> Expr:
        if isinstance(t, L.VertexReduce):
            m_expr = lower_m(t.m)
            maps = [("m1", m_expr)]
            cond_name = None
            if t.cond is not None:
                maps.append(("m2", lower_m(t.cond)))
                cond_name = "m2"
            name = fresh()
            rounds.append((name, FusedRound(
                components=[], leaves=[], maps=maps,
                vreduces=[("r1", t.r, "m1", cond_name)],
                out_kind="scalar", out=Var("r1", "float"))))
            return Var(f"$scalar:{name}", "float")
        if isinstance(t, L.RBin):
            return Bin(t.op, lower_r(t.a), lower_r(t.b))
        if isinstance(t, L.RConst):
            return Lit(t.val, "float")
        if isinstance(t, L.ScalarRef):
            return Var(f"$scalar:{t.name}", "float")
        raise TypeError(t)

    def final_round(t, bind_name):
        if _is_r_term(t):
            expr = lower_r(t)
            rounds.append((bind_name, FusedRound(
                components=[], leaves=[], maps=[], vreduces=[],
                out_kind="scalar", out=expr)))
        else:
            expr = lower_m(t)
            rounds.append((bind_name, FusedRound(
                components=[], leaves=[], maps=[("m1", expr)], vreduces=[],
                out_kind="vertex", out=Var("m1", "float"))))

    def walk(t, bind_name=None):
        if isinstance(t, L.LetRound):
            walk(t.bound, bind_name=t.name)
            walk(t.body, bind_name=bind_name)
        else:
            final_round(t, bind_name)

    walk(term)
    return FusedProgram(rounds=rounds, stats=stats)
