"""ExecutionPlan: the query planner of the GraFS executor.

Grafs synthesizes *kernels* from specs; this module extends the same idea
to *execution strategy* (GraphIt's schedule/algorithm decoupling, GraphMat's
one-tuned-backend mapping): every knob the engines used to thread by hand —
engine choice, sweep direction, the Gemini ``switch_k``, push resolution,
shard strategy, batching, validation and fallback policy — is resolved in
ONE place, ``plan_execution``, from cached per-graph statistics
(``structure.graph_stats``) with caller kwargs acting as hints/overrides
that are normalized exactly once.  The resolved ``ExecutionPlan`` is frozen
and hashable: the engine entry points lower through it, ``ops.iterate_pallas*``
*asserts* (not re-parses) its fields, and the compiled-executor cache keys
derive from it, so identical decisions hit identical cache entries.

Default plans reproduce the documented heuristics bitwise — Gemini
``SWITCH_K``, ``"sorted"`` resolution, ``"auto"`` direction — so planned
execution is bit-identical to the historical explicit-kwarg paths.

A recorded-stats feedback cache closes the loop (DESIGN.md §14): each
executed query records its observed push/pull split, resolve work and
convergence per (graph, query kind); subsequent queries that opt in
(``adaptive=True``) get a ``switch_k``/resolution adjusted within bounded
factors of the defaults.  Adaptation is restricted to idempotent rounds —
where push and pull sweeps are bitwise-interchangeable per iteration, so a
different direction sequence can never change the fixpoint value — and the
cache is LRU-bounded and evicted per graph via ``clear_graph_plans`` /
``engine.clear_graph_caches``.
"""
from __future__ import annotations

import dataclasses
import weakref
from collections import OrderedDict
from typing import Optional

from repro.core import iterate
from repro.core.fusion import FusedProgram, Lex
from repro.core.synthesis import DirectKernels

# ---------------------------------------------------------------------------
# Documented knob defaults (moved here from kernels/ops.py, which re-exports
# them — the planner is the single owner of knob semantics).
# ---------------------------------------------------------------------------

DENSE_FRONTIER = 0.05      # documented FALLBACK switch point (switch_k=None):
                           # frontier fraction above which the pull sweep
                           # wins (dense reads beat frontier-proportional
                           # row skipping)

SWITCH_K = 20.0            # the default Gemini rule: push while the
                           # frontier's outgoing edge count |E_frontier|
                           # (Σ out_deg over active vertices — degree data
                           # already in the layout) stays ≤ |E| / k.  This
                           # is Gemini's actual criterion (edge mass, not
                           # vertex fraction): a few active hubs can carry
                           # pull-worthy edge volume, and many active leaves
                           # can still be push-cheap.  Override per query
                           # with switch_k=<float>; switch_k=None falls back
                           # to the DENSE_FRONTIER vertex-fraction rule.

PUSH_RESOLUTION = "sorted"  # default dst-keyed resolution of the push
                            # sweep: "sorted" = dst-sorted segment-reduce
                            # tile pass (frontier-proportional, DESIGN.md
                            # §10); "scatter" = full-rectangle XLA scatter
                            # (the reference/fallback path)

# Feedback-adaptation bounds: an adapted switch_k never leaves
# [SWITCH_K / ADAPT_SPAN, SWITCH_K * ADAPT_SPAN], and the push-fraction
# thresholds that move it are deliberately coarse (a 2× step per signal).
ADAPT_SPAN = 4.0
ADAPT_PUSH_HI = 0.75        # ≥ this push fraction → the switch under-pushes
                            # never mattered; probe pull earlier (k / 2)
ADAPT_PUSH_LO = 0.25        # ≤ this push fraction (with pushes observed) →
                            # push rarely won; raise the bar (k * 2)

ENGINES = ("pull", "push", "adaptive", "dense", "pallas", "distributed",
           "pallas_sharded")

INCREMENTAL_DELTA = 0.05    # incremental-execution threshold (DESIGN.md §15):
                            # a mutation batch editing ≤ this fraction of |E|
                            # plans the warm+delta propagation; a larger edit
                            # plans a full recompute (the touched frontier
                            # would sweep most of the graph anyway, and the
                            # warm state buys nothing over the identity init)


# ---------------------------------------------------------------------------
# Knob normalizers — THE single copy (engine.py and ops.py used to each run
# their own).  Error texts are load-bearing: existing tests match them.
# ---------------------------------------------------------------------------

def _normalize_switch_k(switch_k, dense_threshold=DENSE_FRONTIER):
    """"auto" → the default Gemini k; None → the DENSE_FRONTIER fallback;
    a positive number → that k.  Returned value is part of the executor
    cache key.  A non-default ``dense_threshold`` combined with an active
    Gemini rule is rejected rather than silently ignored — the fraction
    threshold only governs the ``switch_k=None`` fallback."""
    if isinstance(switch_k, str):
        if switch_k != "auto":
            raise ValueError(f"switch_k must be 'auto', None or a number, "
                             f"got {switch_k!r}")
        switch_k = SWITCH_K
    elif switch_k is not None:
        switch_k = float(switch_k)
        if not switch_k > 0:
            raise ValueError(f"switch_k must be > 0 (push while |E_frontier|"
                             f" <= |E|/k), got {switch_k}")
    if switch_k is not None and dense_threshold != DENSE_FRONTIER:
        raise ValueError(
            "dense_threshold only governs the switch_k=None fallback; pass "
            "switch_k=None to use a custom frontier-fraction threshold, or "
            "tune the Gemini rule via switch_k")
    return switch_k


def _check_resolution(push_resolution) -> str:
    """None → the engine default, so callers (engine.py) can forward their
    own optional knob unconditionally."""
    if push_resolution is None:
        return PUSH_RESOLUTION
    if push_resolution not in ("scatter", "sorted"):
        raise ValueError(f"push_resolution must be 'scatter' or 'sorted', "
                         f"got {push_resolution!r}")
    return push_resolution


def _pallas_direction(model) -> str:
    """Map the engine-level ``model`` to the pallas sweep-direction policy:
    None/"auto" → per-iteration heuristic, "pull"/"pull+"/"pull−" → pull
    sweeps only, "push"/… → push sweeps only."""
    if model in (None, "auto"):
        return "auto"
    base = str(model).rstrip("+-")
    if base in ("pull", "push"):
        return base
    raise ValueError(f"pallas engine: unknown model {model!r}")


def _check_on_nonconverge(on_nonconverge: str) -> str:
    if on_nonconverge not in ("raise", "warn", "ignore"):
        raise ValueError(f"on_nonconverge must be 'raise', 'warn' or "
                         f"'ignore', got {on_nonconverge!r}")
    return on_nonconverge


def assert_normalized(plan: "ExecutionPlan") -> None:
    """The kernels-layer contract: a plan that reaches ``ops`` is already
    normalized — fields are asserted, never re-parsed (satellite 1)."""
    assert plan.direction in ("auto", "pull", "push"), plan.direction
    assert plan.switch_k is None or (isinstance(plan.switch_k, float)
                                     and plan.switch_k > 0), plan.switch_k
    assert plan.push_resolution in ("sorted", "scatter"), plan.push_resolution
    assert plan.on_nonconverge in ("raise", "warn", "ignore"), \
        plan.on_nonconverge
    assert plan.shard_strategy in ("contiguous", "dst_hash"), \
        plan.shard_strategy
    assert plan.incremental in (None, "delta", "full"), plan.incremental


# ---------------------------------------------------------------------------
# The plan itself.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    """Every resolved execution decision of one query, in one frozen value.

    ``engine``/``model`` select the executor; ``direction`` is the pallas
    sweep-direction policy derived from ``model``; ``switch_k`` /
    ``dense_threshold`` / ``push_resolution`` are the normalized
    direction-switch and push-resolution knobs (exactly the values the
    executor cache keys carry); ``shard_strategy``/``axes`` shape the
    vertex-cut engines; ``batch_size``/``batch_lane`` describe source
    batching ("vmapped" = one fused launch, "sequential" = the per-source
    degradation recorded as an explicit decision); the remaining fields are
    the guarded-execution policy.  ``resolution_hint`` keeps the RAW caller
    hint so a fallback re-plan for a different engine re-resolves it (a
    sharded plan's "scatter" must not leak into a single-device retry that
    would default to "sorted")."""
    engine: str
    model: Optional[str] = None
    direction: str = "auto"
    switch_k: Optional[float] = SWITCH_K
    dense_threshold: float = DENSE_FRONTIER
    push_resolution: str = PUSH_RESOLUTION
    resolution_hint: Optional[str] = None
    shard_strategy: str = "contiguous"
    axes: tuple = ("data",)
    batch_size: Optional[int] = None
    batch_lane: Optional[str] = None
    validate: bool = True
    on_nonconverge: str = "raise"
    fallback: bool = False
    divergence_sentinel: bool = True
    adaptive: bool = False
    incremental: Optional[str] = None  # mutation-aware execution mode: None
                                       # (no mutation hint), "delta" (warm
                                       # start + touched-set frontier seed) or
                                       # "full" (planned cold recompute — the
                                       # warm hints are dropped; DESIGN.md §15)
    kind: tuple = ()                 # structural query-shape key (plan cache
                                     # + feedback identity; source-free)

    def knobs(self) -> dict:
        """Every resolved knob, by name — the explain/ExecStats surface."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


@dataclasses.dataclass
class PlanExplanation:
    """``explain=True`` payload: the plan, the graph statistics that drove
    it, the feedback snapshot (if any), and one human-readable reason per
    resolved field."""
    plan: ExecutionPlan
    stats: object                   # structure.GraphStats
    feedback: Optional[dict]
    decisions: dict                 # field -> reason string


# ---------------------------------------------------------------------------
# Query-shape ("kind") keys: structural, source-free — exactly the identity
# the executor cache uses for plan levels + sourced-ness.
# ---------------------------------------------------------------------------

def _plan_levels(plan):
    levels = []
    p = plan
    while isinstance(p, Lex):
        levels.append((p.comp, p.op))
        p = p.secondary
    levels.append((p.comp, p.op))
    return levels


def program_kind(prog) -> tuple:
    """Structural identity of a query shape: per-round plan levels and
    sourced-ness for fused programs, (rop, dtype, epilogue?) for direct
    kernel sets.  Source VALUES are deliberately absent — every query source
    of one shape shares a plan-cache/feedback entry, mirroring the
    source-free executor cache (DESIGN.md §8)."""
    if isinstance(prog, FusedProgram):
        rounds = []
        for _name, round_ in prog.rounds:
            rounds.append((
                tuple(tuple(_plan_levels(leaf.plan)) for leaf in round_.leaves),
                tuple(c.source is not None for c in round_.components)))
        return ("program", tuple(rounds))
    if isinstance(prog, DirectKernels):
        return ("direct", prog.rop, str(prog.dtype),
                prog.e_fn is not None, prog.source is not None)
    return ("adhoc",)


def _prog_idempotent(prog) -> bool:
    """True when every iteration round of the query is idempotent (+model):
    the regime where push and pull sweeps are bitwise-interchangeable per
    iteration, so feedback adaptation of the direction switch is value-safe."""
    if isinstance(prog, FusedProgram):
        leaves = [leaf for _n, r in prog.rounds for leaf in r.leaves]
        return bool(leaves) and all(iterate.plan_idempotent(leaf.plan)
                                    for leaf in leaves)
    if isinstance(prog, DirectKernels):
        return prog.rop in iterate._IDEMPOTENT_OPS and prog.e_fn is None
    return False


# ---------------------------------------------------------------------------
# Plan cache + recorded-stats feedback cache (both LRU-bounded, identity
# keyed on the graph with weakref guards like the structure caches).
# ---------------------------------------------------------------------------

_PLAN_CACHE: OrderedDict = OrderedDict()
_PLAN_CACHE_MAX = 256

_FEEDBACK: OrderedDict = OrderedDict()
_FEEDBACK_MAX = 256


@dataclasses.dataclass
class FeedbackRecord:
    """Per-(graph, kind) observed execution statistics — the planner's
    recorded-stats feedback loop (tentpole).  Updated by the engine entry
    points after every executed query from ``ExecStats`` (which aggregates
    the kernels' SWEEP_STATS-visible counters)."""
    queries: int = 0
    iterations: int = 0
    push_iters: int = 0
    pull_iters: int = 0
    edge_work: float = 0.0
    resolve_work: float = 0.0
    nonconverged: int = 0
    epoch: int = 0                  # bumps on every record: plan-cache keys
                                    # carry it so adaptive plans refresh

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _lru_put(cache: OrderedDict, maxlen: int, key, value) -> None:
    cache[key] = value
    cache.move_to_end(key)
    while len(cache) > maxlen:
        cache.popitem(last=False)


def plan_cache_size() -> int:
    return len(_PLAN_CACHE)


def feedback_cache_size() -> int:
    return len(_FEEDBACK)


def clear_plan_caches() -> None:
    _PLAN_CACHE.clear()
    _FEEDBACK.clear()


def clear_graph_plans(g) -> int:
    """Drop ONE graph's plan-cache and feedback entries (the planner's share
    of ``engine.clear_graph_caches`` — the serving LRU's eviction hook).
    Returns the number of entries dropped."""
    dropped = 0
    for cache in (_PLAN_CACHE, _FEEDBACK):
        stale = [k for k, (ref, _) in list(cache.items()) if ref() is g]
        for k in stale:
            if cache.pop(k, None) is not None:
                dropped += 1
    return dropped


def record_feedback(g, kind: tuple, stats) -> None:
    """Fold one executed query's ``ExecStats`` into the (graph, kind)
    feedback record.  Tracer-valued stats (vmapped batches report per-query
    host ints, so this only guards exotic callers) are skipped."""
    iters = getattr(stats, "iterations", 0)
    if not isinstance(iters, (int, float)):
        return
    key = (id(g), kind)
    hit = _FEEDBACK.get(key)
    rec = None
    if hit is not None:
        ref, rec = hit
        if ref() is not g:          # id reuse after GC: start fresh
            rec = None
    if rec is None:
        rec = FeedbackRecord()
        _lru_put(_FEEDBACK, _FEEDBACK_MAX, key, (weakref.ref(g), rec))
        weakref.finalize(g, _FEEDBACK.pop, key, None)
    else:
        _FEEDBACK.move_to_end(key)
    rec.queries += 1
    rec.iterations += int(iters)
    rec.push_iters += int(getattr(stats, "push_iters", 0) or 0)
    rec.pull_iters += int(getattr(stats, "pull_iters", 0) or 0)
    rec.edge_work += float(getattr(stats, "edge_work", 0.0) or 0.0)
    rec.resolve_work += float(getattr(stats, "resolve_work", 0.0) or 0.0)
    if not getattr(stats, "converged", True):
        rec.nonconverged += 1
    rec.epoch += 1


def feedback_for(g, kind: tuple) -> Optional[FeedbackRecord]:
    hit = _FEEDBACK.get((id(g), kind))
    if hit is None:
        return None
    ref, rec = hit
    return rec if ref() is g else None


def _adapted_switch_k(rec: FeedbackRecord) -> float:
    """Feedback rule (DESIGN.md §14): a query shape that ran ≥ ADAPT_PUSH_HI
    of its iterations as pushes gets a halved k (push keeps winning — let it
    run longer before the pull switch); one that pushed ≤ ADAPT_PUSH_LO gets
    a doubled k (push rarely paid off — raise the bar).  Always clamped to
    [SWITCH_K/ADAPT_SPAN, SWITCH_K*ADAPT_SPAN]."""
    if rec.iterations <= 0:
        return SWITCH_K
    frac = rec.push_iters / rec.iterations
    if frac >= ADAPT_PUSH_HI:
        k = SWITCH_K / 2.0
    elif frac <= ADAPT_PUSH_LO:
        k = SWITCH_K * 2.0
    else:
        k = SWITCH_K
    return float(min(max(k, SWITCH_K / ADAPT_SPAN), SWITCH_K * ADAPT_SPAN))


def _adapted_resolution(rec: FeedbackRecord) -> Optional[str]:
    """Flip to the reference scatter when the dst-sorted resolution pass did
    MORE edge work than the full rectangles it replaced would have (hub-free
    graphs where every resolution tile stays live) — observed, per graph."""
    if rec.push_iters > 0 and rec.resolve_work > rec.edge_work > 0:
        return "scatter"
    return None


# ---------------------------------------------------------------------------
# plan_execution — the single resolution point.
# ---------------------------------------------------------------------------

def plan_execution(g, prog=None, *, engine: Optional[str] = None,
                   model: Optional[str] = None,
                   mesh=None, axes=("data",),
                   switch_k="auto", dense_threshold: Optional[float] = None,
                   push_resolution: Optional[str] = None,
                   shard_strategy: Optional[str] = None,
                   batch: Optional[int] = None,
                   validate: bool = True,
                   on_nonconverge: str = "raise",
                   fallback: bool = False,
                   divergence_sentinel: bool = True,
                   adaptive: bool = False,
                   mutation=None,
                   default_engine: str = "pull",
                   explain: bool = False):
    """Resolve every execution knob of one query into an ``ExecutionPlan``.

    Hint precedence (DESIGN.md §14): an explicit caller kwarg always wins;
    ``engine=None`` takes the entry point's documented default
    (``default_engine``); ``engine="auto"`` picks from the graph statistics
    and device topology; unset knobs take the documented defaults —
    bitwise-identical to the historical explicit-kwarg paths.  With
    ``adaptive=True`` AND an idempotent query shape, unset ``switch_k`` /
    ``push_resolution`` consult the recorded-stats feedback of this
    (graph, kind) instead (bounded adjustments; see ``FeedbackRecord``).

    ``mutation=`` (a ``graph.mutate.MutationDelta`` or anything with
    ``inserted``/``deleted``/``touched``/``has_deletes``) resolves the
    ``incremental`` knob from mutation-size statistics: an edit touching
    ≤ ``INCREMENTAL_DELTA`` of |E| plans ``"delta"`` (warm start + touched
    frontier seed), a larger one — or an idempotent query after deletions,
    whose stale monotone values cannot retract — plans ``"full"``.

    Plans are cached per (graph identity, kind, hints[, feedback epoch]) in
    a bounded LRU; ``explain=True`` bypasses the cache and returns a
    ``PlanExplanation`` carrying the statistics behind each choice."""
    from repro.graph import structure

    decisions: dict = {} if explain else None
    kind = program_kind(prog)
    idempotent = _prog_idempotent(prog)

    fb = feedback_for(g, kind) if adaptive else None
    fb_epoch = fb.epoch if fb is not None else 0
    mut_key = None
    if mutation is not None:
        touched = getattr(mutation, "touched", None)
        mut_key = (int(getattr(mutation, "inserted", 0)),
                   int(getattr(mutation, "deleted", 0)),
                   0 if touched is None else int(getattr(touched, "size",
                                                         len(touched))),
                   bool(getattr(mutation, "has_deletes", False)))
    # The plan depends on the mesh only through its device count (the mesh
    # object itself is threaded to execution separately) — keying the hint
    # by id(mesh) would go stale when a freed mesh's id is reused.
    hints_key = (engine, model,
                 None if mesh is None else _mesh_device_count(mesh),
                 _axes_key(axes), switch_k, dense_threshold, push_resolution,
                 shard_strategy, batch, validate, on_nonconverge, fallback,
                 divergence_sentinel, adaptive, mut_key, default_engine)
    cache_key = (id(g), kind, hints_key, fb_epoch)
    if not explain:
        hit = _PLAN_CACHE.get(cache_key)
        if hit is not None:
            ref, plan = hit
            if ref() is g:
                _PLAN_CACHE.move_to_end(cache_key)
                return plan

    stats = structure.graph_stats(g)
    _check_on_nonconverge(on_nonconverge)

    # --- engine ------------------------------------------------------------
    if engine is None:
        eng = default_engine
        reason = f"entry-point default ({default_engine!r})"
    elif engine == "auto":
        if mesh is not None and _mesh_device_count(mesh) > 1:
            eng = "pallas_sharded"
            reason = (f"auto: mesh with {_mesh_device_count(mesh)} devices "
                      "→ shard-local fused sweeps")
        else:
            eng = "pallas"
            reason = "auto: single device → fused blocked-ELL kernel engine"
    else:
        eng = engine
        reason = "caller hint"
    if eng not in ENGINES:
        raise ValueError(f"unknown engine {eng}")
    if decisions is not None:
        decisions["engine"] = reason

    # --- direction policy ----------------------------------------------------
    if eng in ("pallas", "pallas_sharded"):
        direction = _pallas_direction(model)
        if decisions is not None:
            decisions["direction"] = (
                "forced by model hint" if direction != "auto" else
                ("per-iteration Gemini switch (idempotent rounds)"
                 if idempotent else
                 "auto (non-idempotent rounds run the pull− recompute)"))
    else:
        direction = "auto"
        if decisions is not None:
            decisions["direction"] = "reference engines take model directly"

    # --- switch_k ------------------------------------------------------------
    dt = DENSE_FRONTIER if dense_threshold is None else float(dense_threshold)
    k_norm = _normalize_switch_k(switch_k, dt)
    k_reason = ("caller hint" if switch_k != "auto"
                else f"documented Gemini default k={SWITCH_K}")
    if (adaptive and idempotent and switch_k == "auto" and fb is not None
            and fb.queries > 0):
        k_norm = _adapted_switch_k(fb)
        k_reason = (f"feedback: {fb.push_iters}/{fb.iterations} push "
                    f"iterations over {fb.queries} queries → k={k_norm}")
    if decisions is not None:
        decisions["switch_k"] = k_reason
        decisions["dense_threshold"] = (
            "caller hint (switch_k=None fallback)" if dense_threshold
            is not None else "documented DENSE_FRONTIER default")

    # --- push resolution -----------------------------------------------------
    # Engine-independent since the sharded engine grew its own per-shard
    # resolution stack: every pallas engine takes the dst-sorted default,
    # "scatter" stays the reference oracle everywhere.
    res = _check_resolution(push_resolution)
    res_reason = ("caller hint" if push_resolution is not None else
                  "documented dst-sorted default (all pallas engines)")
    if (adaptive and idempotent and push_resolution is None
            and fb is not None):
        flipped = _adapted_resolution(fb)
        if flipped is not None:
            res = flipped
            res_reason = (f"feedback: resolve_work {fb.resolve_work:.0f} > "
                          f"edge_work {fb.edge_work:.0f} → reference scatter")
    if decisions is not None:
        decisions["push_resolution"] = res_reason

    # --- sharding / batching -------------------------------------------------
    strat = shard_strategy if shard_strategy is not None else "contiguous"
    if strat not in ("contiguous", "dst_hash"):
        raise ValueError(f"unknown shard strategy {strat!r}")
    if decisions is not None:
        decisions["shard_strategy"] = ("caller hint" if shard_strategy
                                       is not None else "contiguous default")
    lane = None
    if batch is not None:
        lane = "vmapped" if eng == "pallas" else "sequential"
        if decisions is not None:
            decisions["batch_lane"] = (
                f"B={batch} sources in one vmapped launch" if lane == "vmapped"
                else f"engine {eng!r} has no batched fixpoint — B={batch} "
                     "sequential runs (recorded degradation)")

    # --- incremental (mutation-aware) mode -----------------------------------
    inc = None
    if mut_key is not None:
        n_ins, n_del, _n_touched, has_del = mut_key
        sz = n_ins + n_del
        small = sz <= INCREMENTAL_DELTA * max(1, stats.num_edges)
        if idempotent and has_del:
            inc = "full"
            inc_reason = ("idempotent round after deletions: stale monotone "
                          "values cannot retract — planned full recompute")
        elif small:
            inc = "delta"
            inc_reason = (f"{sz} mutated edges ≤ {INCREMENTAL_DELTA:.0%} of "
                          f"|E|={stats.num_edges} → warm+delta propagation")
        else:
            inc = "full"
            inc_reason = (f"{sz} mutated edges > {INCREMENTAL_DELTA:.0%} of "
                          f"|E|={stats.num_edges} → planned full recompute")
        if decisions is not None:
            decisions["incremental"] = inc_reason

    plan = ExecutionPlan(
        engine=eng, model=model, direction=direction,
        switch_k=k_norm, dense_threshold=dt,
        push_resolution=res, resolution_hint=push_resolution,
        shard_strategy=strat, axes=_axes_key(axes),
        batch_size=batch, batch_lane=lane,
        validate=validate, on_nonconverge=on_nonconverge,
        fallback=fallback, divergence_sentinel=divergence_sentinel,
        adaptive=adaptive, incremental=inc, kind=kind)

    if explain:
        return PlanExplanation(
            plan=plan, stats=stats,
            feedback=fb.as_dict() if fb is not None else None,
            decisions=decisions)
    _lru_put(_PLAN_CACHE, _PLAN_CACHE_MAX, cache_key, (weakref.ref(g), plan))
    weakref.finalize(g, _PLAN_CACHE.pop, cache_key, None)
    return plan


def degrade_plan(plan: ExecutionPlan, engine: str) -> ExecutionPlan:
    """The plan a guard-fallback step executes under: same normalized knobs,
    target engine, with the resolution re-resolved from the raw hint —
    resolution is engine-independent now that the sharded engine runs its
    own per-shard sorted stack, so an explicit caller hint (e.g. a pinned
    "scatter" oracle) survives the hop and a hintless plan lands back on
    the documented dst-sorted default."""
    if engine == plan.engine:
        return plan
    return dataclasses.replace(
        plan, engine=engine,
        push_resolution=_check_resolution(plan.resolution_hint))


def _axes_key(axes) -> tuple:
    return (axes,) if isinstance(axes, str) else tuple(axes)


def _mesh_device_count(mesh) -> int:
    try:
        import numpy as np
        return int(np.ravel(mesh.devices).size)
    except Exception:
        return 1
