"""Triple-let executor: iteration → map → reduce (paper §5).

Runs a ``FusedProgram`` (from fusion.fuse or fusion.lower_unfused) on a
graph under one of the five engines:

  pull | push   sparse frontier engines (iterate.iterate_graph)
  adaptive      Gemini-style per-iteration push/pull switch (segment ops)
  dense         dense edge-matrix reference engine
  pallas        direction-optimized blocked-ELL TPU kernel engine
                (repro.kernels; ``model`` forces "pull"/"push", default
                picks per iteration by frontier density)
  distributed   shard_map vertex-cut engine (needs a mesh)

The three primitives map exactly as §5 prescribes: the fused ilet runs as an
iterative path reduction, the mlet as a vectorized per-vertex map, the rlet
as (masked) reductions over the vertex dimension, and the final expression
evaluates on the results.  ⊥ values (reduction identities / ±inf) are
excluded from vertex reductions per C6 (R(n, ⊥) = n).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core import iterate
from repro.core.fusion import FusedProgram, FusedRound, plan_output
from repro.core.kernel_lang import eval_expr
from repro.core.synthesis import DirectKernels, synthesize_round

_BOT_CUTOFF = 1e8


def clear_program_caches():
    """Drop every layer of the compiled-program cache: synthesized round
    kernels, blocked-ELL layouts, and jitted pallas executors.  Mostly for
    tests and benchmarks that need cold-start numbers; normal callers keep
    the caches warm across rounds, repeated queries and repeats."""
    from repro.core import synthesis
    from repro.graph import structure
    synthesis._ROUND_CACHE.clear()
    structure._ELL_CACHE.clear()
    try:
        from repro.kernels import ops as kops
        kops.clear_executor_cache()
    except ImportError:                 # pallas backend unavailable
        pass


def program_cache_stats() -> dict:
    from repro.core import synthesis
    from repro.graph import structure
    out = {"synth_rounds": len(synthesis._ROUND_CACHE),
           "ell_layouts": len(structure._ELL_CACHE)}
    try:
        from repro.kernels import ops as kops
        out["pallas_executors"] = kops.executor_cache_size()
    except ImportError:
        out["pallas_executors"] = 0
    return out


@dataclasses.dataclass
class ExecStats:
    rounds: int = 0
    iterations: int = 0
    edge_work: float = 0.0
    synth_ms: float = 0.0


@dataclasses.dataclass
class ExecResult:
    value: object                  # final result (array for vertex queries)
    named: dict                    # bound intermediate results
    stats: ExecStats


def _pallas_direction(model) -> str:
    """Map run_program's ``model`` to the pallas engine's sweep direction:
    None/"auto" → per-iteration heuristic, "pull"/"pull+"/"pull−" → pull
    sweeps only, "push"/… → push sweeps only."""
    if model in (None, "auto"):
        return "auto"
    base = str(model).rstrip("+-")
    if base in ("pull", "push"):
        return base
    raise ValueError(f"pallas engine: unknown model {model!r}")


def _valid_mask(x):
    xf = x.astype(jnp.float32)
    return jnp.isfinite(xf) & (jnp.abs(xf) < _BOT_CUTOFF)


def _vertex_reduce(op: str, vals, mask):
    vals = vals.astype(jnp.float32)
    if op == "collect":
        return mask
    ident = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0, "prod": 1.0}[op]
    masked = jnp.where(mask, vals, ident)
    fn = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum, "prod": jnp.prod}[op]
    return fn(masked)


def _run_iteration(g, round_: FusedRound, engine: str, model: str,
                   mesh, axes, max_iter, tol, synth_override=None):
    synth = synth_override if synth_override is not None else synthesize_round(round_)
    comps = iterate.comp_runtimes(round_, {k: v for k, v in synth.items()
                                           if not isinstance(k, tuple)})
    plans = [leaf.plan for leaf in round_.leaves]
    if engine in ("pull", "push"):
        m = model or ("pull+" if engine == "pull" else "push+")
        res = iterate.iterate_graph(g, comps, plans, model=m,
                                    max_iter=max_iter, tol=tol)
    elif engine == "adaptive":
        res = iterate.iterate_adaptive(g, comps, plans, max_iter=max_iter,
                                       tol=tol)
    elif engine == "dense":
        res = iterate.iterate_dense(g, comps, plans, max_iter=max_iter, tol=tol)
    elif engine == "distributed":
        assert mesh is not None, "distributed engine needs a mesh"
        res = iterate.iterate_distributed(g, comps, plans, mesh, axes=axes,
                                          model=model or "pull+",
                                          max_iter=max_iter, tol=tol)
    elif engine == "pallas":
        from repro.kernels import ops as kops
        res = kops.iterate_pallas(g, comps, plans, max_iter=max_iter, tol=tol,
                                  direction=_pallas_direction(model))
    else:
        raise ValueError(f"unknown engine {engine}")
    return res, comps


def run_program(g, prog: FusedProgram, engine: str = "pull",
                model: Optional[str] = None, mesh=None, axes=("data",),
                max_iter: Optional[int] = None, tol: float = 0.0) -> ExecResult:
    stats = ExecStats()
    named: dict = {}
    final = None
    for bind_name, round_ in prog.rounds:
        env: dict = {}
        for key, val in named.items():
            env[key] = val
        if round_.leaves:
            res, comps = _run_iteration(g, round_, engine, model, mesh, axes,
                                        max_iter, tol)
            stats.rounds += 1
            stats.iterations += res.iterations
            stats.edge_work += res.edge_work
            for leaf in round_.leaves:
                env[leaf.name] = res.state[plan_output(leaf.plan)]
        # mlet: vectorized per-vertex map
        for name, expr in round_.maps:
            env[name] = eval_expr(expr, env, jnp)
        # rlet: masked vertex reductions
        for name, op, m_name, cond_name in round_.vreduces:
            vals = jnp.asarray(env[m_name])
            vals = jnp.broadcast_to(vals, (g.n,)) if vals.ndim == 0 else vals
            mask = _valid_mask(vals)
            if cond_name is not None:
                cond = jnp.asarray(env[cond_name])
                mask = mask & jnp.broadcast_to(cond.astype(bool), (g.n,))
            env[name] = _vertex_reduce(op, vals, mask)
        out = eval_expr(round_.out, env, jnp)
        if bind_name is not None:
            prefix = "$vec:" if round_.out_kind == "vertex" else "$scalar:"
            named[prefix + bind_name] = out
        final = out
    return ExecResult(value=final, named=named, stats=stats)


# ---------------------------------------------------------------------------
# Direct-kernel execution (PageRank and other Fig. 4b style kernel sets).
# ---------------------------------------------------------------------------

def run_direct(g, dk: DirectKernels, engine: str = "pull",
               mesh=None, axes=("data",),
               model: Optional[str] = None) -> ExecResult:
    from repro.core.fusion import Component, FusedRound, Leaf, Prim
    from repro.core.lang import PATH_FNS, WEIGHT

    comp = iterate.CompRuntime(
        idx=0, op=dk.rop, dtype=iterate.DTYPES[dk.dtype],
        p_fn=dk.p_fn, init_fn=dk.init_fn, source=None, e_fn=dk.e_fn)
    plans = [Prim(dk.rop, 0)]
    # frontier-masked (+) models for idempotent kernels (BFS/CC/SSSP/WP);
    # full-recompute (−) for non-idempotent / epilogue kernels (PageRank)
    idempotent = dk.rop in iterate._IDEMPOTENT_OPS and dk.e_fn is None
    pull_like = engine in ("pull", "dense", "distributed")
    model = ("pull+" if pull_like else "push+") if idempotent else \
        ("pull-" if pull_like else "push-")
    if engine in ("pull", "push"):
        res = iterate.iterate_graph(g, [comp], plans, model=model,
                                    max_iter=dk.max_iter, tol=dk.tol)
    elif engine == "dense":
        res = iterate.iterate_dense(g, [comp], plans, max_iter=dk.max_iter,
                                    tol=dk.tol)
    elif engine == "distributed":
        res = iterate.iterate_distributed(g, [comp], plans, mesh, axes=axes,
                                          model="pull-", max_iter=dk.max_iter,
                                          tol=dk.tol)
    elif engine == "pallas":
        from repro.kernels import ops as kops
        res = kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                                  tol=dk.tol,
                                  direction=_pallas_direction(model))
    else:
        raise ValueError(engine)
    stats = ExecStats(rounds=1, iterations=res.iterations, edge_work=res.edge_work)
    return ExecResult(value=res.state[0], named={}, stats=stats)
