"""Triple-let executor: iteration → map → reduce (paper §5).

Runs a ``FusedProgram`` (from fusion.fuse or fusion.lower_unfused) on a
graph under one of the engines:

  pull | push     sparse frontier engines (iterate.iterate_graph)
  adaptive        Gemini-style per-iteration push/pull switch (segment ops)
  dense           dense edge-matrix reference engine
  pallas          direction-optimized blocked-ELL TPU kernel engine
                  (repro.kernels; ``model`` forces "pull"/"push", default
                  picks per iteration by frontier density)
  distributed     shard_map vertex-cut engine, plain segment-reduce per
                  shard (needs a mesh)
  pallas_sharded  shard_map vertex-cut engine running the fused blocked-ELL
                  Pallas sweeps SHARD-LOCALLY with monoid cross-shard
                  combines and a global direction switch (needs a mesh;
                  DESIGN.md §11)

The three primitives map exactly as §5 prescribes: the fused ilet runs as an
iterative path reduction, the mlet as a vectorized per-vertex map, the rlet
as (masked) reductions over the vertex dimension, and the final expression
evaluates on the results.  ⊥ values (reduction identities / ±inf) are
excluded from vertex reductions per C6 (R(n, ⊥) = n).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import iterate
from repro.core.fusion import FusedProgram, FusedRound, plan_output
from repro.core.kernel_lang import eval_expr
from repro.core.synthesis import DirectKernels, synthesize_round

_BOT_CUTOFF = 1e8


def clear_program_caches():
    """Drop every layer of the compiled-program cache: synthesized round
    kernels, blocked-ELL layouts (single-device and sharded), and jitted
    pallas executors.  Mostly for tests and benchmarks that need cold-start
    numbers; normal callers keep the caches warm across rounds, repeated
    queries and repeats."""
    from repro.core import synthesis
    from repro.graph import structure
    synthesis._ROUND_CACHE.clear()
    structure._ELL_CACHE.clear()
    structure._RES_CACHE.clear()
    structure._WDEG_CACHE.clear()
    structure._SHARDED_ELL_CACHE.clear()
    try:
        from repro.kernels import ops as kops
        kops.clear_executor_cache()
    except ImportError:                 # pallas backend unavailable
        pass


def program_cache_stats() -> dict:
    from repro.core import synthesis
    from repro.graph import structure
    out = {"synth_rounds": len(synthesis._ROUND_CACHE),
           "ell_layouts": len(structure._ELL_CACHE),
           "sharded_layouts": len(structure._SHARDED_ELL_CACHE),
           "push_resolutions": len(structure._RES_CACHE)}
    try:
        from repro.kernels import ops as kops
        out["pallas_executors"] = kops.executor_cache_size()
    except ImportError:
        out["pallas_executors"] = 0
    return out


@dataclasses.dataclass
class ExecStats:
    rounds: int = 0
    iterations: int = 0
    edge_work: float = 0.0
    synth_ms: float = 0.0           # wall time inside synthesize_round
                                    # (~0 on round-cache hits)
    push_iters: int = 0             # runtime per-direction iteration counts
    pull_iters: int = 0             # (direction-aware engines; 0 elsewhere)
    resolve_work: float = 0.0       # push-resolution edge work (pallas
                                    # engine; Σ resolution-tile nnz under
                                    # "sorted", full rectangle under
                                    # "scatter", 0 on pull iterations)
    shards: int = 0                 # shard count of the sharded engines
                                    # (distributed / pallas_sharded)
    shard_launches: int = 0         # traced pallas launches PER SHARD
                                    # summed over rounds (pallas_sharded:
                                    # one per direction branch per round)
    cross_combines: int = 0         # cross-shard state-combine collectives
                                    # executed (iterations × per-iteration
                                    # lex-level psums; pallas_sharded)
    shard_work: tuple = ()          # per-shard edge work ([k]; its sum is
                                    # edge_work's sharded contribution)


@dataclasses.dataclass
class ExecResult:
    value: object                  # final result (array for vertex queries)
    named: dict                    # bound intermediate results
    stats: ExecStats


def _pallas_direction(model) -> str:
    """Map run_program's ``model`` to the pallas engine's sweep direction:
    None/"auto" → per-iteration heuristic, "pull"/"pull+"/"pull−" → pull
    sweeps only, "push"/… → push sweeps only."""
    if model in (None, "auto"):
        return "auto"
    base = str(model).rstrip("+-")
    if base in ("pull", "push"):
        return base
    raise ValueError(f"pallas engine: unknown model {model!r}")


def _valid_mask(x):
    xf = x.astype(jnp.float32)
    return jnp.isfinite(xf) & (jnp.abs(xf) < _BOT_CUTOFF)


def _vertex_reduce(op: str, vals, mask):
    vals = vals.astype(jnp.float32)
    if op == "collect":
        return mask
    ident = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0, "prod": 1.0}[op]
    masked = jnp.where(mask, vals, ident)
    fn = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum, "prod": jnp.prod}[op]
    return fn(masked)


def _source_overrides(round_, source) -> Optional[dict]:
    """{comp idx: source} re-sourcing every SOURCED component of a round to
    one query source (single-source programs: BFS/SSSP/WP/…).  Sourceless
    components (Paths(v)) are untouched — sourced-ness is structural."""
    if source is None:
        return None
    return {comp.idx: int(source) for comp in round_.components
            if comp.source is not None}


def _synthesize_timed(round_, synth_override=None):
    """(synth dict, wall ms spent synthesizing) — cache hits report ~0."""
    if synth_override is not None:
        return synth_override, 0.0
    t0 = time.perf_counter()
    synth = synthesize_round(round_)
    return synth, (time.perf_counter() - t0) * 1e3


def _round_runtime(round_, synth):
    comps = iterate.comp_runtimes(round_, {k: v for k, v in synth.items()
                                           if not isinstance(k, tuple)})
    plans = [leaf.plan for leaf in round_.leaves]
    return comps, plans


def _run_iteration(g, round_: FusedRound, engine: str, model: str,
                   mesh, axes, max_iter, tol, synth_override=None,
                   source=None, push_resolution=None, switch_k="auto",
                   shard_strategy="contiguous"):
    synth, synth_ms = _synthesize_timed(round_, synth_override)
    comps, plans = _round_runtime(round_, synth)
    sources = _source_overrides(round_, source)
    if engine in ("pull", "push"):
        m = model or ("pull+" if engine == "pull" else "push+")
        res = iterate.iterate_graph(g, comps, plans, model=m,
                                    max_iter=max_iter, tol=tol,
                                    sources=sources)
    elif engine == "adaptive":
        res = iterate.iterate_adaptive(g, comps, plans, max_iter=max_iter,
                                       tol=tol, sources=sources)
    elif engine == "dense":
        res = iterate.iterate_dense(g, comps, plans, max_iter=max_iter,
                                    tol=tol, sources=sources)
    elif engine == "distributed":
        assert mesh is not None, "distributed engine needs a mesh"
        res = iterate.iterate_distributed(g, comps, plans, mesh, axes=axes,
                                          model=model or "pull+",
                                          max_iter=max_iter, tol=tol,
                                          sources=sources)
    elif engine == "pallas":
        from repro.kernels import ops as kops
        res = kops.iterate_pallas(g, comps, plans, max_iter=max_iter, tol=tol,
                                  direction=_pallas_direction(model),
                                  sources=sources, switch_k=switch_k,
                                  push_resolution=push_resolution)
    elif engine == "pallas_sharded":
        assert mesh is not None, "pallas_sharded engine needs a mesh"
        from repro.kernels import ops as kops
        res = kops.iterate_pallas_sharded(
            g, comps, plans, mesh, axes=axes, strategy=shard_strategy,
            max_iter=max_iter, tol=tol, direction=_pallas_direction(model),
            sources=sources, switch_k=switch_k,
            push_resolution=push_resolution)
    else:
        raise ValueError(f"unknown engine {engine}")
    return res, comps, synth_ms


def _finish_round(g, round_: FusedRound, env: dict):
    """mlet (vectorized per-vertex maps) + rlet (masked vertex reductions) +
    the round's output expression, over an env already holding the leaf
    results.  Shared by the sequential and batched program runners."""
    for name, expr in round_.maps:
        env[name] = eval_expr(expr, env, jnp)
    for name, op, m_name, cond_name in round_.vreduces:
        vals = jnp.asarray(env[m_name])
        vals = jnp.broadcast_to(vals, (g.n,)) if vals.ndim == 0 else vals
        mask = _valid_mask(vals)
        if cond_name is not None:
            cond = jnp.asarray(env[cond_name])
            mask = mask & jnp.broadcast_to(cond.astype(bool), (g.n,))
        env[name] = _vertex_reduce(op, vals, mask)
    return eval_expr(round_.out, env, jnp)


def _accumulate(stats: ExecStats, res, synth_ms: float) -> None:
    stats.rounds += 1
    stats.iterations += res.iterations
    stats.edge_work += res.edge_work
    stats.synth_ms += synth_ms
    pi = getattr(res, "push_iters", 0)
    li = getattr(res, "pull_iters", 0)
    rw = getattr(res, "resolve_work", 0.0)
    if isinstance(pi, int):
        stats.push_iters += pi
    if isinstance(li, int):
        stats.pull_iters += li
    if isinstance(rw, (int, float)):
        stats.resolve_work += float(rw)
    stats.shards = max(stats.shards, getattr(res, "shards", 0))
    stats.shard_launches += getattr(res, "shard_launches", 0)
    stats.cross_combines += getattr(res, "cross_combines", 0)
    sw = tuple(getattr(res, "shard_work", ()))
    if sw:
        if len(stats.shard_work) == len(sw):
            stats.shard_work = tuple(a + b
                                     for a, b in zip(stats.shard_work, sw))
        elif not stats.shard_work:
            stats.shard_work = sw
        else:                       # shard count changed between rounds
            stats.shard_work = stats.shard_work + sw


def run_program(g, prog: FusedProgram, engine: str = "pull",
                model: Optional[str] = None, mesh=None, axes=("data",),
                max_iter: Optional[int] = None, tol: float = 0.0,
                source: Optional[int] = None,
                push_resolution: Optional[str] = None,
                switch_k="auto",
                shard_strategy: str = "contiguous") -> ExecResult:
    """Execute a fused program.  ``source`` optionally re-sources every
    sourced component to one query source — the program (and with it every
    compiled-executor cache entry) is source-generic, so querying another
    source never re-fuses, re-synthesizes or retraces (DESIGN.md §8).

    ``push_resolution`` ("sorted"/"scatter", pallas engine only) selects
    the push sweep's dst-keyed resolution path; ``switch_k`` tunes the
    direction switch per query (DESIGN.md §2/§10) — None falls back to the
    frontier-fraction threshold, a number overrides the Gemini k.
    ``shard_strategy`` picks the vertex-cut edge partitioning of the
    ``pallas_sharded`` engine ("contiguous" | "dst_hash")."""
    stats = ExecStats()
    named: dict = {}
    final = None
    for bind_name, round_ in prog.rounds:
        env: dict = dict(named)
        if round_.leaves:
            res, comps, synth_ms = _run_iteration(
                g, round_, engine, model, mesh, axes, max_iter, tol,
                source=source, push_resolution=push_resolution,
                switch_k=switch_k, shard_strategy=shard_strategy)
            _accumulate(stats, res, synth_ms)
            for leaf in round_.leaves:
                env[leaf.name] = res.state[plan_output(leaf.plan)]
        out = _finish_round(g, round_, env)
        if bind_name is not None:
            prefix = "$vec:" if round_.out_kind == "vertex" else "$scalar:"
            named[prefix + bind_name] = out
        final = out
    return ExecResult(value=final, named=named, stats=stats)


def run_program_batch(g, prog: FusedProgram, sources: Sequence,
                      engine: str = "pallas", model: Optional[str] = None,
                      mesh=None, axes=("data",),
                      max_iter: Optional[int] = None, tol: float = 0.0,
                      push_resolution: Optional[str] = None,
                      switch_k="auto") -> list:
    """Serve B concurrent single-source queries of one program in ONE
    compiled launch per round (DESIGN.md §9).

    ``sources`` is a [B] sequence of query sources; every sourced component
    of every round is re-sourced per batch element (single-source programs —
    BFS/SSSP/WP sweeps and friends).  On the pallas engine the iteration
    rounds run as ``jax.vmap``-batched fixpoints over the shared blocked-ELL
    layout — per-query convergence via the active mask, results bit-identical
    to B sequential ``run_program(..., source=s)`` calls, and ONE executor
    cache entry regardless of B.  Other engines fall back to the sequential
    loop (the reference semantics this path is tested against).

    Returns a list of B ``ExecResult``s, each with its own per-query stats
    (iterations, edge work, push/pull split; ``synth_ms`` is the shared
    per-round synthesis cost, reported on each)."""
    src_arr = np.asarray(sources)
    if src_arr.ndim != 1:
        raise ValueError(
            f"run_program_batch sources must be a [B] vector of query "
            f"sources, got shape {src_arr.shape}; per-component [B, n_comps] "
            "batching is the kernels-layer iterate_pallas_batch API")
    src_list = [int(s) for s in src_arr]
    B = len(src_list)
    if engine != "pallas":
        return [run_program(g, prog, engine=engine, model=model, mesh=mesh,
                            axes=axes, max_iter=max_iter, tol=tol, source=s)
                for s in src_list]
    pallas_kw = dict(switch_k=switch_k, push_resolution=push_resolution)
    from repro.kernels import ops as kops
    stats = [ExecStats() for _ in range(B)]
    named: list = [{} for _ in range(B)]
    finals: list = [None] * B
    for bind_name, round_ in prog.rounds:
        envs = [dict(nm) for nm in named]
        if round_.leaves:
            synth, synth_ms = _synthesize_timed(round_)
            comps, plans = _round_runtime(round_, synth)
            res = kops.iterate_pallas_batch(
                g, comps, plans, src_list, max_iter=max_iter, tol=tol,
                direction=_pallas_direction(model), **pallas_kw)
            iters = np.asarray(res.iterations)
            works = np.asarray(res.edge_work)
            pushes = np.asarray(res.push_iters)
            res_ws = np.asarray(res.resolve_work)
            for b in range(B):
                st = stats[b]
                st.rounds += 1
                st.iterations += int(iters[b])
                st.edge_work += float(works[b])
                st.synth_ms += synth_ms
                st.push_iters += int(pushes[b])
                st.pull_iters += int(iters[b]) - int(pushes[b])
                st.resolve_work += float(res_ws[b])
                for leaf in round_.leaves:
                    envs[b][leaf.name] = res.state[plan_output(leaf.plan)][b]
        for b in range(B):
            out = _finish_round(g, round_, envs[b])
            if bind_name is not None:
                prefix = "$vec:" if round_.out_kind == "vertex" else "$scalar:"
                named[b][prefix + bind_name] = out
            finals[b] = out
    return [ExecResult(value=finals[b], named=named[b], stats=stats[b])
            for b in range(B)]


# ---------------------------------------------------------------------------
# Direct-kernel execution (PageRank and other Fig. 4b style kernel sets).
# ---------------------------------------------------------------------------

def run_direct(g, dk: DirectKernels, engine: str = "pull",
               mesh=None, axes=("data",),
               model: Optional[str] = None,
               source: Optional[int] = None,
               sources: Optional[Sequence] = None,
               push_resolution: Optional[str] = None,
               switch_k="auto",
               shard_strategy: str = "contiguous"):
    """Execute a direct kernel set on one engine.

    ``model`` optionally pins the pallas sweep direction ("pull"/"push");
    the default is the engine's documented behaviour — the per-iteration
    frontier-density heuristic for idempotent kernels, full-recompute for
    the rest — NOT a forced direction.  ``source`` overrides ``dk.source``
    for one query; ``sources`` runs a [B] batch of queries (one vmapped
    launch on the pallas engine, a sequential loop elsewhere) and returns a
    list of per-query ``ExecResult``s.  Both need a source-generic kernel
    set (``dk.source`` not None)."""
    from repro.core.fusion import Prim

    if (source is not None or sources is not None) and dk.source is None:
        raise ValueError(
            "run_direct source overrides need a source-generic DirectKernels "
            "(init_fn(v, s) with source=...); this kernel set is sourceless "
            "or bakes its source into the init closure")
    if dk.source is not None and iterate._init_arity(dk.init_fn) < 2:
        raise ValueError(
            "DirectKernels.source requires a source-generic init_fn(v, s); "
            "a single-argument closure bakes its own source, so re-sourcing "
            "would move the ⊥-mask without moving the init value")
    pallas_kw = dict(switch_k=switch_k, push_resolution=push_resolution)
    if sources is not None:
        if engine == "pallas":
            from repro.kernels import ops as kops
            comp = iterate.CompRuntime(
                idx=0, op=dk.rop, dtype=iterate.DTYPES[dk.dtype],
                p_fn=dk.p_fn, init_fn=dk.init_fn, source=dk.source,
                e_fn=dk.e_fn)
            res = kops.iterate_pallas_batch(
                g, [comp], [Prim(dk.rop, 0)], sources,
                max_iter=dk.max_iter, tol=dk.tol,
                direction=_pallas_direction(model), **pallas_kw)
            iters = np.asarray(res.iterations)
            works = np.asarray(res.edge_work)
            pushes = np.asarray(res.push_iters)
            res_ws = np.asarray(res.resolve_work)
            return [ExecResult(
                value=res.state[0][b], named={},
                stats=ExecStats(rounds=1, iterations=int(iters[b]),
                                edge_work=float(works[b]),
                                push_iters=int(pushes[b]),
                                pull_iters=int(iters[b]) - int(pushes[b]),
                                resolve_work=float(res_ws[b])))
                for b in range(len(iters))]
        return [run_direct(g, dk, engine=engine, mesh=mesh, axes=axes,
                           model=model, source=int(s),
                           push_resolution=push_resolution,
                           switch_k=switch_k,
                           shard_strategy=shard_strategy) for s in sources]

    comp = iterate.CompRuntime(
        idx=0, op=dk.rop, dtype=iterate.DTYPES[dk.dtype],
        p_fn=dk.p_fn, init_fn=dk.init_fn, source=dk.source, e_fn=dk.e_fn)
    plans = [Prim(dk.rop, 0)]
    src_over = None if source is None else {0: int(source)}
    # frontier-masked (+) models for idempotent kernels (BFS/CC/SSSP/WP);
    # full-recompute (−) for non-idempotent / epilogue kernels (PageRank)
    idempotent = dk.rop in iterate._IDEMPOTENT_OPS and dk.e_fn is None
    pull_like = engine in ("pull", "dense", "distributed")
    eng_model = ("pull+" if pull_like else "push+") if idempotent else \
        ("pull-" if pull_like else "push-")
    if engine in ("pull", "push"):
        res = iterate.iterate_graph(g, [comp], plans, model=eng_model,
                                    max_iter=dk.max_iter, tol=dk.tol,
                                    sources=src_over)
    elif engine == "dense":
        res = iterate.iterate_dense(g, [comp], plans, max_iter=dk.max_iter,
                                    tol=dk.tol, sources=src_over)
    elif engine == "distributed":
        res = iterate.iterate_distributed(g, [comp], plans, mesh, axes=axes,
                                          model="pull-", max_iter=dk.max_iter,
                                          tol=dk.tol, sources=src_over)
    elif engine == "pallas":
        # The engine's documented default: per-iteration direction heuristic
        # for idempotent kernels (pull− recompute otherwise), forced only by
        # an explicit model — NOT derived from pull_like, which omits pallas
        # and used to pin push for every direct kernel.
        from repro.kernels import ops as kops
        res = kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                                  tol=dk.tol,
                                  direction=_pallas_direction(model),
                                  sources=src_over, **pallas_kw)
    elif engine == "pallas_sharded":
        assert mesh is not None, "pallas_sharded engine needs a mesh"
        from repro.kernels import ops as kops
        res = kops.iterate_pallas_sharded(
            g, [comp], plans, mesh, axes=axes, strategy=shard_strategy,
            max_iter=dk.max_iter, tol=dk.tol,
            direction=_pallas_direction(model), sources=src_over,
            **pallas_kw)
    else:
        raise ValueError(engine)
    stats = ExecStats()
    _accumulate(stats, res, 0.0)
    return ExecResult(value=res.state[0], named={}, stats=stats)
