"""Triple-let executor: iteration → map → reduce (paper §5).

Runs a ``FusedProgram`` (from fusion.fuse or fusion.lower_unfused) on a
graph under one of the engines:

  pull | push     sparse frontier engines (iterate.iterate_graph)
  adaptive        Gemini-style per-iteration push/pull switch (segment ops)
  dense           dense edge-matrix reference engine
  pallas          direction-optimized blocked-ELL TPU kernel engine
                  (repro.kernels; ``model`` forces "pull"/"push", default
                  picks per iteration by frontier density)
  distributed     shard_map vertex-cut engine, plain segment-reduce per
                  shard (needs a mesh)
  pallas_sharded  shard_map vertex-cut engine running the fused blocked-ELL
                  Pallas sweeps SHARD-LOCALLY with monoid cross-shard
                  combines and a global direction switch (needs a mesh;
                  DESIGN.md §11)

The three primitives map exactly as §5 prescribes: the fused ilet runs as an
iterative path reduction, the mlet as a vectorized per-vertex map, the rlet
as (masked) reductions over the vertex dimension, and the final expression
evaluates on the results.  ⊥ values (reduction identities / ±inf) are
excluded from vertex reductions per C6 (R(n, ⊥) = n).
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import conditions as _conditions
from repro.core import guard, iterate
from repro.core import plan as _plan
from repro.core.fusion import FusedProgram, FusedRound, plan_output
from repro.core.kernel_lang import eval_expr
from repro.core.plan import ExecutionPlan, plan_execution  # noqa: F401
from repro.core.synthesis import DirectKernels, synthesize_round

_BOT_CUTOFF = 1e8

# Engine-invocation retry knobs used when the caller enables ``fallback``
# without providing an ``ft_config``: one retry of the same engine before
# degrading (lowering failures are deterministic — long budgets just delay
# the fallback), minimal backoff.
_FALLBACK_RETRIES = 1
_FALLBACK_BACKOFF_S = 0.01


def clear_program_caches():
    """Drop every layer of the compiled-program cache: synthesized round
    kernels, blocked-ELL layouts (single-device and sharded), and jitted
    pallas executors.  Mostly for tests and benchmarks that need cold-start
    numbers; normal callers keep the caches warm across rounds, repeated
    queries and repeats."""
    from repro.core import synthesis
    from repro.graph import structure
    synthesis._ROUND_CACHE.clear()
    structure._ELL_CACHE.clear()
    structure._RES_CACHE.clear()
    structure._WDEG_CACHE.clear()
    structure._SHARDED_ELL_CACHE.clear()
    structure._SHARDED_RES_CACHE.clear()
    structure._VALID_CACHE.clear()
    structure._STATS_CACHE.clear()
    structure._SLOT_CACHE.clear()
    _plan.clear_plan_caches()
    from repro.graph import mutate as _mutate
    _mutate.reset_mutation_stats()
    try:
        from repro.kernels import ops as kops
        kops.clear_executor_cache()
    except ImportError:                 # pallas backend unavailable
        pass


def clear_graph_caches(g) -> int:
    """Selective per-graph eviction (DESIGN.md §13): drop ONE graph's
    derived layouts / degrees / validation summary from the structure
    caches, leaving other resident graphs and the graph-shape-generic
    compiled executors alone.  The serving layer's bounded graph LRU calls
    this when a graph loses residency; ``program_cache_stats`` verifies the
    bound.  Also evicts the graph's cached plans and recorded-stats feedback
    (core.plan) so an evicted graph's adaptation history dies with it.
    Returns the number of cache entries dropped."""
    from repro.graph import structure
    return structure.clear_graph_caches(g) + _plan.clear_graph_plans(g)


def program_cache_stats() -> dict:
    from repro.core import synthesis
    from repro.graph import structure
    out = {"synth_rounds": len(synthesis._ROUND_CACHE),
           "ell_layouts": len(structure._ELL_CACHE),
           "sharded_layouts": len(structure._SHARDED_ELL_CACHE),
           "push_resolutions": len(structure._RES_CACHE),
           "sharded_resolutions": len(structure._SHARDED_RES_CACHE),
           "graph_stats": len(structure._STATS_CACHE),
           "slot_maps": len(structure._SLOT_CACHE),
           "plans": _plan.plan_cache_size(),
           "feedback": _plan.feedback_cache_size()}
    try:
        from repro.kernels import ops as kops
        out["pallas_executors"] = kops.executor_cache_size()
    except ImportError:
        out["pallas_executors"] = 0
    return out


@dataclasses.dataclass
class ExecStats:
    rounds: int = 0
    iterations: int = 0
    edge_work: float = 0.0
    synth_ms: float = 0.0           # wall time inside synthesize_round
                                    # (~0 on round-cache hits)
    push_iters: int = 0             # runtime per-direction iteration counts
    pull_iters: int = 0             # (direction-aware engines; 0 elsewhere)
    resolve_work: float = 0.0       # push-resolution edge work (pallas
                                    # engines; Σ resolution-tile nnz under
                                    # "sorted", full rectangle under
                                    # "scatter", 0 on pull iterations;
                                    # summed over shards when sharded)
    gather_work: float = 0.0        # candidate slots read through the
                                    # in-kernel permutation gather (pallas
                                    # engines; equals resolve_work under
                                    # "sorted" — skipped tiles move zero
                                    # bytes — and 0 under "scatter", which
                                    # performs no permutation gather)
    shards: int = 0                 # shard count of the sharded engines
                                    # (distributed / pallas_sharded)
    shard_launches: int = 0         # traced pallas launches PER SHARD
                                    # summed over rounds (pallas_sharded:
                                    # one per direction branch per round)
    cross_combines: int = 0         # cross-shard state-combine collectives
                                    # executed (iterations × per-iteration
                                    # lex-level psums; pallas_sharded)
    shard_work: tuple = ()          # per-shard edge work ([k]; its sum is
                                    # edge_work's sharded contribution)
    engine_used: str = ""           # engine that actually produced the
                                    # result (differs from the request only
                                    # after a fallback)
    converged: bool = True          # False when a round exhausted max_iter
                                    # with live vertices (only observable
                                    # under on_nonconverge="ignore"/"warn" —
                                    # the continuous-batching scheduler's
                                    # retire-or-carry signal)
    fallbacks: tuple = ()           # (from_engine, to_engine, error) per
                                    # degradation step (guard.FallbackEvent)
    exec_retries: int = 0           # same-engine retries spent before each
                                    # success/fallback (ft.bounded_retry)
    plan: object = None             # the resolved core.plan.ExecutionPlan
                                    # this query lowered through — every
                                    # knob decision, inspectable after the
                                    # fact (None only on hand-built stats)


@dataclasses.dataclass
class ExecResult:
    value: object                  # final result (array for vertex queries)
    named: dict                    # bound intermediate results
    stats: ExecStats


def _valid_mask(x):
    xf = x.astype(jnp.float32)
    return jnp.isfinite(xf) & (jnp.abs(xf) < _BOT_CUTOFF)


def _vertex_reduce(op: str, vals, mask):
    vals = vals.astype(jnp.float32)
    if op == "collect":
        return mask
    ident = {"min": jnp.inf, "max": -jnp.inf, "sum": 0.0, "prod": 1.0}[op]
    masked = jnp.where(mask, vals, ident)
    fn = {"min": jnp.min, "max": jnp.max, "sum": jnp.sum, "prod": jnp.prod}[op]
    return fn(masked)


def _source_overrides(round_, source) -> Optional[dict]:
    """{comp idx: source} re-sourcing every SOURCED component of a round to
    one query source (single-source programs: BFS/SSSP/WP/…).  Sourceless
    components (Paths(v)) are untouched — sourced-ness is structural."""
    if source is None:
        return None
    return {comp.idx: int(source) for comp in round_.components
            if comp.source is not None}


def _synthesize_timed(round_, synth_override=None):
    """(synth dict, wall ms spent synthesizing) — cache hits report ~0."""
    if synth_override is not None:
        return synth_override, 0.0
    t0 = time.perf_counter()
    synth = synthesize_round(round_)
    return synth, (time.perf_counter() - t0) * 1e3


def _round_runtime(round_, synth):
    comps = iterate.comp_runtimes(round_, {k: v for k, v in synth.items()
                                           if not isinstance(k, tuple)})
    plans = [leaf.plan for leaf in round_.leaves]
    return comps, plans


def _validate_inputs(g, source=None, sources=None):
    """Graph structural validation + query-source range check (guarded
    execution, DESIGN.md §12).  Returns the cached ``GraphCheck`` so the
    termination-precondition probe can reuse the edge-value ranges."""
    from repro.graph import structure
    chk = structure.validate_graph(g)
    probe = []
    if source is not None:
        probe.append(source)
    if sources is not None:
        probe.extend(np.asarray(sources).ravel().tolist())
    for s in probe:
        s = int(s)
        if not 0 <= s < g.n:
            raise guard.GraphValidationError(
                f"query source {s} out of range [0, {g.n})")
    return chk


def _check_preconditions(chk, comps, plans):
    """Raise ``TerminationPreconditionError`` when the graph's actual
    edge-value ranges void the spec's synthesis-time termination proof
    (strengthened C10, §5.2) — min-plus on negative weights being the
    canonical never-terminating case.  In-contract graphs (w ≥ 0, c > 0)
    return immediately without probing."""
    if chk is None:
        return
    bad = _conditions.violated_preconditions(
        comps, plans, (chk.w_min, chk.w_max), (chk.c_min, chk.c_max))
    if bad:
        v = bad[0]
        raise guard.TerminationPreconditionError(
            f"termination precondition {v['condition']} violated for "
            f"component {v['component']} (op {v['op']}) on this graph "
            f"(w ∈ [{chk.w_min}, {chk.w_max}], c ∈ [{chk.c_min}, "
            f"{chk.c_max}]): {v['detail']} — the fixpoint may not "
            "terminate; fix the graph or run with validate=False",
            condition=v["condition"], component=v["component"],
            detail=v["detail"])


def _check_outcome(res, max_iter_eff, on_nonconverge):
    """Surface the structured convergence outcome of one finished round:
    a fired divergence sentinel raises ``DivergenceError``; exhausting
    ``max_iter`` with live vertices raises (or warns, per
    ``on_nonconverge``) ``NonConvergenceError`` with the exit diagnostics
    instead of returning a silent partial state.  Tracer-valued outcomes
    (batched results) are the callers' responsibility."""
    if on_nonconverge == "ignore":
        return
    divg = getattr(res, "diverged", False)
    conv = getattr(res, "converged", True)
    if isinstance(divg, (bool, np.bool_)) and divg:
        raise guard.DivergenceError(
            f"fixpoint diverged after {res.iterations} iterations: the "
            "NaN/Inf sentinel fired (values left the monoid's meaningful "
            "domain)", iterations=int(res.iterations))
    if isinstance(conv, (bool, np.bool_)) and not conv:
        active = int(getattr(res, "active_count", 0))
        resid = float(getattr(res, "residual", float("nan")))
        msg = (f"fixpoint exhausted max_iter={max_iter_eff} without "
               f"converging: {active} vertices still active after "
               f"{res.iterations} iterations, last-iteration residual "
               f"{resid:.3e}")
        if on_nonconverge == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
            return
        raise guard.NonConvergenceError(
            msg, iterations=int(res.iterations), max_iter=int(max_iter_eff),
            active_count=active, residual=resid)


def _check_batch_outcomes(res, src_list, max_iter_eff, on_nonconverge):
    """Per-query convergence outcomes of one batched round ([B]-valued
    ``converged``/``diverged``), naming the offending query sources."""
    if on_nonconverge == "ignore":
        return
    divg = np.asarray(res.diverged)
    conv = np.asarray(res.converged)
    if divg.any():
        bad = [src_list[i] for i in np.flatnonzero(divg)]
        raise guard.DivergenceError(
            f"batched fixpoint diverged for query sources {bad}: the "
            "NaN/Inf sentinel fired",
            iterations=int(np.asarray(res.iterations).max()))
    if not conv.all():
        bad = np.flatnonzero(~conv)
        acts = np.asarray(res.active_count)
        iters = np.asarray(res.iterations)
        msg = (f"batched fixpoint exhausted max_iter={max_iter_eff} for "
               f"query sources {[src_list[i] for i in bad]} "
               f"(active counts {[int(acts[i]) for i in bad]})")
        if on_nonconverge == "warn":
            warnings.warn(msg, RuntimeWarning, stacklevel=3)
            return
        raise guard.NonConvergenceError(
            msg, iterations=int(iters.max()), max_iter=int(max_iter_eff),
            active_count=int(acts[bad].sum()))


def _dispatch_guarded(call, engine, fallback, ft_config):
    """Run ``call(engine)``; on infrastructure-shaped failure
    (``guard.recoverable``) retry the SAME engine with a bounded budget,
    then degrade one step down ``guard.FALLBACK_CHAIN`` and repeat.  Guard
    verdicts and programming errors propagate unchanged.  Returns
    ``(result, engine_used, fallback_events, retries_used)``."""
    if not fallback:
        return call(engine), engine, (), 0
    from repro.runtime import ft as _ft
    retries = _FALLBACK_RETRIES if ft_config is None else ft_config.max_retries
    backoff = _FALLBACK_BACKOFF_S if ft_config is None else ft_config.backoff_s
    eng = engine
    events = []
    retries_used = 0
    while True:
        try:
            out, r = _ft.bounded_retry(lambda: call(eng), retries, backoff,
                                       retryable=guard.recoverable)
            return out, eng, tuple(events), retries_used + r
        except Exception as exc:
            retries_used += retries
            if not guard.recoverable(exc):
                raise
            nxt = guard.FALLBACK_CHAIN.get(eng)
            if nxt is None:
                raise
            events.append(guard.FallbackEvent(eng, nxt,
                                              f"{type(exc).__name__}: {exc}"))
            eng = nxt


def _rescale_warm_state(init_state, comps, n):
    """Guarded warm start of a NON-idempotent round from a previous solution
    (DESIGN.md §15): a (−) recompute round re-derives every vertex from its
    neighborhood each sweep and contracts to its unique attractive fixpoint
    from ANY finite state, so the warm state needs sanitizing, not
    re-deriving.  For mass-conserving "sum" components (PR-style) non-finite
    entries (values a structural edit invalidated) are replaced by the
    finite mean and the result rescaled to keep the retired answer's total
    mass — the fixpoint mass is graph-dependent (dangling-vertex leakage),
    so the previous converged mass, not an a-priori invariant, is the best
    unbiased seed after a small edit.  All-finite states pass bitwise
    untouched."""
    out = []
    for a, cr in zip(init_state, comps):
        arr = np.array(a)
        if cr.op == "sum":
            finite = np.isfinite(arr)
            if not finite.all():
                mass = float(arr[finite].sum()) if finite.any() else 0.0
                fill = mass / max(1, int(finite.sum()))
                arr = np.where(finite, arr, fill).astype(arr.dtype)
                tot = float(arr.sum())
                if np.isfinite(tot) and tot != 0.0 and mass != 0.0:
                    arr = (arr * (mass / tot)).astype(arr.dtype)
        out.append(jnp.asarray(arr))
    return tuple(out)


def _run_iteration(g, round_: FusedRound, engine: str, plan: ExecutionPlan,
                   mesh, axes, max_iter, tol, synth_override=None,
                   source=None, graph_check=None, checkpoint_every=None,
                   ckpt_dir=None, resume=False, init_state=None, delta=None):
    """One iteration round under ``plan`` on ``engine`` — which differs from
    ``plan.engine`` only while walking the guard fallback chain, in which
    case the engine-dependent plan fields re-resolve (``degrade_plan``)."""
    eff = _plan.degrade_plan(plan, engine)
    model = eff.model
    synth, synth_ms = _synthesize_timed(round_, synth_override)
    comps, plans = _round_runtime(round_, synth)
    _check_preconditions(graph_check, comps, plans)
    sources = _source_overrides(round_, source)
    if engine in ("pull", "push"):
        m = model or ("pull+" if engine == "pull" else "push+")
        res = iterate.iterate_graph(g, comps, plans, model=m,
                                    max_iter=max_iter, tol=tol,
                                    sources=sources)
    elif engine == "adaptive":
        res = iterate.iterate_adaptive(g, comps, plans, max_iter=max_iter,
                                       tol=tol, sources=sources)
    elif engine == "dense":
        res = iterate.iterate_dense(g, comps, plans, max_iter=max_iter,
                                    tol=tol, sources=sources)
    elif engine == "distributed":
        assert mesh is not None, "distributed engine needs a mesh"
        res = iterate.iterate_distributed(g, comps, plans, mesh, axes=axes,
                                          model=model or "pull+",
                                          max_iter=max_iter, tol=tol,
                                          sources=sources)
    elif engine == "pallas":
        from repro.kernels import ops as kops
        ist = init_state
        if (delta is not None and ist is not None
                and not all(iterate.plan_idempotent(p) for p in plans)):
            ist = _rescale_warm_state(ist, comps, g.n)
        res = kops.iterate_pallas(g, comps, plans, max_iter=max_iter, tol=tol,
                                  sources=sources, plan=eff,
                                  checkpoint_every=checkpoint_every,
                                  ckpt_dir=ckpt_dir, resume=resume,
                                  init_state=ist, delta=delta)
    elif engine == "pallas_sharded":
        assert mesh is not None, "pallas_sharded engine needs a mesh"
        from repro.kernels import ops as kops
        res = kops.iterate_pallas_sharded(
            g, comps, plans, mesh, axes=axes,
            max_iter=max_iter, tol=tol, sources=sources, plan=eff)
    else:
        raise ValueError(f"unknown engine {engine}")
    return res, comps, synth_ms


def _finish_round(g, round_: FusedRound, env: dict):
    """mlet (vectorized per-vertex maps) + rlet (masked vertex reductions) +
    the round's output expression, over an env already holding the leaf
    results.  Shared by the sequential and batched program runners."""
    for name, expr in round_.maps:
        env[name] = eval_expr(expr, env, jnp)
    for name, op, m_name, cond_name in round_.vreduces:
        vals = jnp.asarray(env[m_name])
        vals = jnp.broadcast_to(vals, (g.n,)) if vals.ndim == 0 else vals
        mask = _valid_mask(vals)
        if cond_name is not None:
            cond = jnp.asarray(env[cond_name])
            mask = mask & jnp.broadcast_to(cond.astype(bool), (g.n,))
        env[name] = _vertex_reduce(op, vals, mask)
    if getattr(round_, "multi_out", None):
        # fuse_many round: every paired request's own answer from the ONE
        # shared execution — {key: scalar}, no re-execution.
        return {key: eval_expr(e, env, jnp) for key, e in round_.multi_out}
    return eval_expr(round_.out, env, jnp)


def _accumulate(stats: ExecStats, res, synth_ms: float) -> None:
    stats.rounds += 1
    stats.iterations += res.iterations
    stats.edge_work += res.edge_work
    stats.synth_ms += synth_ms
    conv = getattr(res, "converged", True)
    if isinstance(conv, (bool, np.bool_)):      # tracer-valued on vmapped runs
        stats.converged = stats.converged and bool(conv)
    pi = getattr(res, "push_iters", 0)
    li = getattr(res, "pull_iters", 0)
    rw = getattr(res, "resolve_work", 0.0)
    gw = getattr(res, "gather_work", 0.0)
    if isinstance(pi, int):
        stats.push_iters += pi
    if isinstance(li, int):
        stats.pull_iters += li
    if isinstance(rw, (int, float)):
        stats.resolve_work += float(rw)
    if isinstance(gw, (int, float)):
        stats.gather_work += float(gw)
    stats.shards = max(stats.shards, getattr(res, "shards", 0))
    stats.shard_launches += getattr(res, "shard_launches", 0)
    stats.cross_combines += getattr(res, "cross_combines", 0)
    sw = tuple(getattr(res, "shard_work", ()))
    if sw:
        if len(stats.shard_work) == len(sw):
            stats.shard_work = tuple(a + b
                                     for a, b in zip(stats.shard_work, sw))
        elif not stats.shard_work:
            stats.shard_work = sw
        else:                       # shard count changed between rounds
            stats.shard_work = stats.shard_work + sw


def run_program(g, prog: FusedProgram, engine: Optional[str] = None,
                model: Optional[str] = None, mesh=None, axes=("data",),
                max_iter: Optional[int] = None, tol: float = 0.0,
                source: Optional[int] = None,
                push_resolution: Optional[str] = None,
                switch_k="auto",
                shard_strategy: Optional[str] = None,
                validate: bool = True,
                on_nonconverge: str = "raise",
                fallback: bool = False, ft_config=None,
                divergence_sentinel: bool = True,
                checkpoint_every: Optional[int] = None,
                ckpt_dir=None, resume: bool = False,
                init_state=None, delta=None, return_state: bool = False,
                adaptive: bool = False,
                plan: Optional[ExecutionPlan] = None,
                explain: bool = False):
    """Execute a fused program.  ``source`` optionally re-sources every
    sourced component to one query source — the program (and with it every
    compiled-executor cache entry) is source-generic, so querying another
    source never re-fuses, re-synthesizes or retraces (DESIGN.md §8).

    Every knob kwarg is a HINT to the query planner (``core.plan``,
    DESIGN.md §14): ``plan_execution`` resolves engine (None → "pull",
    "auto" → statistics-driven), direction, ``switch_k`` (the Gemini rule;
    None falls back to the frontier-fraction threshold), ``push_resolution``
    ("sorted"/"scatter", pallas engine only) and ``shard_strategy``
    ("contiguous" | "dst_hash", ``pallas_sharded``) into one frozen
    ``ExecutionPlan``, normalized exactly once; explicit hints always win,
    and default plans reproduce the documented heuristics bitwise.  The
    resolved plan is recorded in ``ExecResult.stats.plan``; ``explain=True``
    skips execution and returns the ``PlanExplanation`` (plan + the graph
    statistics and per-field reasons behind it).  ``adaptive=True`` lets
    unpinned knobs consult the recorded-stats feedback of this
    (graph, kind).  A pre-resolved ``plan=`` bypasses planning entirely.

    Guarded execution (DESIGN.md §12): ``validate`` (default on) checks the
    graph's structural contract, the query source's range, and the per-round
    termination preconditions (C10 against the actual edge-value ranges)
    before any kernel launches.  ``on_nonconverge`` ("raise"/"warn"/
    "ignore") governs what a round that exhausts ``max_iter`` — or trips
    the divergence sentinel — does.  ``fallback=True`` degrades
    infrastructure failures down ``guard.FALLBACK_CHAIN``
    (pallas_sharded → pallas → adaptive) with bounded retry (``ft_config``
    tunes the budget), recording every event in the stats.
    ``checkpoint_every``/``ckpt_dir``/``resume`` thread the chunked
    checkpointed fixpoint (pallas engine only).

    Incremental execution (DESIGN.md §15; pallas engine, single-round
    programs): ``init_state=prev`` warm-starts the fixpoint from a previous
    solution and ``delta=`` seeds the frontier with only the vertices whose
    values may have changed — pass a ``graph.mutate.MutationDelta`` (its
    ``touched`` set becomes the frontier seed AND its mutation-size
    statistics feed the planner's ``incremental`` knob: small touched sets
    resolve to ``"delta"``, large ones — or idempotent rounds after
    deletions, whose stale values cannot retract — to ``"full"``, which
    runs the planned cold recompute ignoring the warm hints) or a raw
    vertex-id array (always honored verbatim).  Idempotent rounds converge
    bitwise-equal to a cold recompute on the mutated graph; non-idempotent
    (PR-style) rounds take the guarded rescaled-warm-start path and need
    ``tol > 0``.  ``return_state=True`` returns ``(result, state)`` with the
    round's final per-component ``[n]`` state — feed it back as the next
    edit's ``init_state``."""
    mutation = None
    delta_ids = delta
    if delta is not None and hasattr(delta, "touched"):
        mutation = delta
        delta_ids = np.asarray(mutation.touched)
    if plan is None or explain:
        planned = plan_execution(
            g, prog, engine=engine, model=model, mesh=mesh, axes=axes,
            switch_k=switch_k, push_resolution=push_resolution,
            shard_strategy=shard_strategy, validate=validate,
            on_nonconverge=on_nonconverge, fallback=fallback,
            divergence_sentinel=divergence_sentinel, adaptive=adaptive,
            mutation=mutation,
            default_engine="pallas" if (init_state is not None
                                        or delta is not None or return_state)
            else "pull", explain=explain)
        if explain:
            return planned
        plan = planned
    if mutation is not None and plan.incremental == "full":
        # The planner judged the warm+delta path unsound or unprofitable
        # (touched set too large, or an idempotent round after deletions —
        # stale monotone values cannot retract): planned full recompute,
        # warm hints dropped.  The decision is visible in stats.plan.
        init_state = None
        delta_ids = None
    if (checkpoint_every is not None or resume) and plan.engine != "pallas":
        raise ValueError("checkpointed fixpoints are a pallas-engine "
                         f"feature; got engine={plan.engine!r}")
    if init_state is not None or delta_ids is not None or return_state:
        if plan.engine != "pallas":
            raise ValueError(
                "init_state/delta/return_state warm-start hooks are a "
                f"pallas-engine feature; got engine={plan.engine!r}")
        iter_rounds = [r for _, r in prog.rounds if r.leaves]
        if len(prog.rounds) != 1 or len(iter_rounds) != 1:
            raise ValueError(
                "init_state/delta/return_state need a single-round program "
                f"(one iteration round, no LetRound chain); got "
                f"{len(prog.rounds)} rounds")
    chk = _validate_inputs(g, source=source) if plan.validate else None
    max_iter_eff = max_iter if max_iter is not None else 2 * g.n + 4
    stats = ExecStats(engine_used=plan.engine, plan=plan)
    named: dict = {}
    final = None
    state_out = None
    for bind_name, round_ in prog.rounds:
        env: dict = dict(named)
        if round_.leaves:
            def call(eng, round_=round_):
                return _run_iteration(
                    g, round_, eng, plan, mesh, axes, max_iter, tol,
                    source=source, graph_check=chk,
                    checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir,
                    resume=resume, init_state=init_state, delta=delta_ids)
            (res, comps, synth_ms), eng_used, events, retries = \
                _dispatch_guarded(call, plan.engine, plan.fallback, ft_config)
            stats.engine_used = eng_used
            stats.fallbacks += tuple(ev.as_tuple() for ev in events)
            stats.exec_retries += retries
            _accumulate(stats, res, synth_ms)
            _check_outcome(res, max_iter_eff, plan.on_nonconverge)
            if return_state:
                state_out = tuple(np.asarray(s) for s in res.state)
            for leaf in round_.leaves:
                env[leaf.name] = res.state[plan_output(leaf.plan)]
        out = _finish_round(g, round_, env)
        if bind_name is not None:
            prefix = "$vec:" if round_.out_kind == "vertex" else "$scalar:"
            named[prefix + bind_name] = out
        final = out
    _plan.record_feedback(g, plan.kind, stats)
    result = ExecResult(value=final, named=named, stats=stats)
    if return_state:
        return result, state_out
    return result


def run_program_batch(g, prog: FusedProgram, sources: Sequence,
                      engine: Optional[str] = None, model: Optional[str] = None,
                      mesh=None, axes=("data",),
                      max_iter: Optional[int] = None, tol: float = 0.0,
                      push_resolution: Optional[str] = None,
                      switch_k="auto",
                      validate: bool = True,
                      on_nonconverge: str = "raise",
                      fallback: bool = False, ft_config=None,
                      init_state=None, return_state=False,
                      adaptive: bool = False,
                      plan: Optional[ExecutionPlan] = None,
                      explain: bool = False):
    """Serve B concurrent single-source queries of one program in ONE
    compiled launch per round (DESIGN.md §9).

    ``sources`` is a [B] sequence of query sources; every sourced component
    of every round is re-sourced per batch element (single-source programs —
    BFS/SSSP/WP sweeps and friends).  On the pallas engine the iteration
    rounds run as ``jax.vmap``-batched fixpoints over the shared blocked-ELL
    layout — per-query convergence via the active mask, results bit-identical
    to B sequential ``run_program(..., source=s)`` calls, and ONE executor
    cache entry regardless of B.  Other engines fall back to the sequential
    loop (the reference semantics this path is tested against).

    Returns a list of B ``ExecResult``s, each with its own per-query stats
    (iterations, edge work, push/pull split; ``synth_ms`` is the shared
    per-round synthesis cost, reported on each).

    Guarded execution mirrors ``run_program``: upfront validation (graph +
    every batch source), per-round termination preconditions, per-QUERY
    convergence outcomes, and — with ``fallback=True`` — degradation of a
    recoverably-failing batched pallas launch to the sequential reference
    loop (recorded in each query's stats).

    Continuous-batching hooks (DESIGN.md §13; pallas engine, single-round
    programs only): ``init_state`` warm-starts every batch slot from one
    per-component ``[B, n]`` array (an earlier chunk's carried state, with
    fresh ``batch_init_state`` rows spliced in where new queries joined);
    ``return_state=True`` returns ``(results, state)`` where ``state`` is
    the round's final per-component ``[B, n]`` state — feed it back as the
    next chunk's ``init_state``.  Bound ``max_iter`` to the scheduler's
    chunk quantum and read each query's ``stats.converged`` (under
    ``on_nonconverge="ignore"``) to decide retire-vs-carry per slot.

    Knob kwargs are planner HINTS (``core.plan``, DESIGN.md §14), resolved
    through ``plan_execution(default_engine="pallas", batch=B)`` exactly as
    in ``run_program``; the resolved plan — including the explicit
    ``batch_lane`` decision ("vmapped" one-launch batch vs. the recorded
    "sequential" degradation of non-pallas engines) — lands in every
    query's ``stats.plan``."""
    src_arr = np.asarray(sources)
    if src_arr.ndim != 1:
        raise ValueError(
            f"run_program_batch sources must be a [B] vector of query "
            f"sources, got shape {src_arr.shape}; per-component [B, n_comps] "
            "batching is the kernels-layer iterate_pallas_batch API")
    if plan is None or explain:
        planned = plan_execution(
            g, prog, engine=engine, model=model, mesh=mesh, axes=axes,
            switch_k=switch_k, push_resolution=push_resolution,
            batch=len(src_arr), validate=validate,
            on_nonconverge=on_nonconverge, fallback=fallback,
            adaptive=adaptive, default_engine="pallas", explain=explain)
        if explain:
            return planned
        plan = planned
    if init_state is not None or return_state:
        if plan.engine != "pallas":
            raise ValueError("init_state/return_state are pallas-engine "
                             f"continuous-batching hooks; got {plan.engine!r}")
        if plan.fallback:
            raise ValueError("init_state/return_state cannot degrade to the "
                             "sequential fallback loop (a warm-started batch "
                             "has no per-query equivalent there); run with "
                             "fallback=False")
        iter_rounds = [r for _, r in prog.rounds if r.leaves]
        if len(prog.rounds) != 1 or len(iter_rounds) != 1:
            raise ValueError(
                "init_state/return_state need a single-round program (one "
                f"iteration round, no LetRound chain); got "
                f"{len(prog.rounds)} rounds")
    chk = _validate_inputs(g, sources=src_arr) if plan.validate else None
    max_iter_eff = max_iter if max_iter is not None else 2 * g.n + 4
    src_list = [int(s) for s in src_arr]
    B = len(src_list)
    if plan.engine != "pallas":
        # The planner already recorded this as an explicit decision
        # (batch_lane="sequential"); the guard event makes it visible in the
        # same place every other degradation lands (satellite 3).
        ev = guard.batch_degradation(plan.engine, B).as_tuple()
        outs = [run_program(g, prog, mesh=mesh, axes=axes, max_iter=max_iter,
                            tol=tol, source=s, ft_config=ft_config, plan=plan)
                for s in src_list]
        for o in outs:
            o.stats.fallbacks = (ev,) + o.stats.fallbacks
        return outs
    from repro.kernels import ops as kops
    stats = [ExecStats(engine_used="pallas", plan=plan) for _ in range(B)]
    named: list = [{} for _ in range(B)]
    finals: list = [None] * B
    state_out = None
    for bind_name, round_ in prog.rounds:
        envs = [dict(nm) for nm in named]
        if round_.leaves:
            synth, synth_ms = _synthesize_timed(round_)
            comps, plans = _round_runtime(round_, synth)
            _check_preconditions(chk, comps, plans)
            try:
                res = kops.iterate_pallas_batch(
                    g, comps, plans, src_list, max_iter=max_iter, tol=tol,
                    init_state=init_state, plan=plan)
            except Exception as exc:
                if not plan.fallback or not guard.recoverable(exc):
                    raise
                # batched launch degraded: the whole batch re-runs through
                # the sequential reference loop, the event recorded on
                # every query's stats.
                ev = guard.FallbackEvent(
                    "pallas", "adaptive",
                    f"{type(exc).__name__}: {exc}").as_tuple()
                outs = [run_program(g, prog, engine="adaptive", model=None,
                                    max_iter=max_iter, tol=tol, source=s,
                                    validate=plan.validate,
                                    on_nonconverge=plan.on_nonconverge,
                                    fallback=plan.fallback,
                                    ft_config=ft_config) for s in src_list]
                for o in outs:
                    o.stats.fallbacks = (ev,) + o.stats.fallbacks
                    o.stats.engine_used = "adaptive"
                return outs
            _check_batch_outcomes(res, src_list, max_iter_eff,
                                  plan.on_nonconverge)
            iters = np.asarray(res.iterations)
            works = np.asarray(res.edge_work)
            pushes = np.asarray(res.push_iters)
            res_ws = np.asarray(res.resolve_work)
            gat_ws = np.asarray(res.gather_work)
            convs = np.asarray(res.converged)
            for b in range(B):
                st = stats[b]
                st.rounds += 1
                st.iterations += int(iters[b])
                st.edge_work += float(works[b])
                st.synth_ms += synth_ms
                st.push_iters += int(pushes[b])
                st.pull_iters += int(iters[b]) - int(pushes[b])
                st.resolve_work += float(res_ws[b])
                st.gather_work += float(gat_ws[b])
                st.converged = st.converged and bool(convs[b])
                for leaf in round_.leaves:
                    envs[b][leaf.name] = res.state[plan_output(leaf.plan)][b]
            if return_state:
                state_out = res.state
        for b in range(B):
            out = _finish_round(g, round_, envs[b])
            if bind_name is not None:
                prefix = "$vec:" if round_.out_kind == "vertex" else "$scalar:"
                named[b][prefix + bind_name] = out
            finals[b] = out
    for st in stats:
        _plan.record_feedback(g, plan.kind, st)
    results = [ExecResult(value=finals[b], named=named[b], stats=stats[b])
               for b in range(B)]
    if return_state:
        return results, state_out
    return results


def batchable_program(prog: FusedProgram) -> bool:
    """True when a fused program fits the continuous-batching contract
    (DESIGN.md §13): exactly one round, with an iteration (leaves), every
    plan idempotent (monotone (+) rounds — the unique-fixpoint argument that
    makes chunked warm-resume bitwise-safe; (−) recompute rounds depend on
    the iteration count and must run monolithically), and every component
    sourced (so a per-slot source re-sources the whole round).  Programs
    that fail this run solo or through the scalar fuse_many lane."""
    if len(prog.rounds) != 1:
        return False
    _, round_ = prog.rounds[0]
    if not round_.leaves:
        return False
    if not all(iterate.plan_idempotent(leaf.plan) for leaf in round_.leaves):
        return False
    return all(c.source is not None for c in round_.components)


def batch_init_state(g, prog: FusedProgram, sources: Sequence) -> tuple:
    """Fresh per-component ``[B, n]`` initial state blocks for a batch of
    query sources of a single-round program — the rows a continuous-batching
    scheduler splices into its carried state when new queries take over
    retired slots (``run_program_batch(init_state=...)``).  Row b is exactly
    the C1/C2 initial state of a solo ``source=sources[b]`` run."""
    iter_rounds = [r for _, r in prog.rounds if r.leaves]
    if len(iter_rounds) != 1:
        raise ValueError("batch_init_state needs a single-round program; "
                         f"got {len(iter_rounds)} iteration rounds")
    round_ = iter_rounds[0]
    synth, _ = _synthesize_timed(round_)
    comps, _plans = _round_runtime(round_, synth)
    rows = [iterate._init_state(comps, g.n,
                                _source_overrides(round_, int(s)))
            for s in sources]
    return tuple(jnp.stack([r[i] for r in rows])
                 for i in range(len(comps)))


# ---------------------------------------------------------------------------
# Direct-kernel execution (PageRank and other Fig. 4b style kernel sets).
# ---------------------------------------------------------------------------

def run_direct(g, dk: DirectKernels, engine: Optional[str] = None,
               mesh=None, axes=("data",),
               model: Optional[str] = None,
               source: Optional[int] = None,
               sources: Optional[Sequence] = None,
               push_resolution: Optional[str] = None,
               switch_k="auto",
               shard_strategy: Optional[str] = None,
               validate: bool = True,
               on_nonconverge: str = "raise",
               fallback: bool = False, ft_config=None,
               divergence_sentinel: bool = True,
               checkpoint_every: Optional[int] = None,
               ckpt_dir=None, resume: bool = False,
               init_state=None, delta=None,
               adaptive: bool = False,
               plan: Optional[ExecutionPlan] = None,
               explain: bool = False):
    """Execute a direct kernel set on one engine.

    ``model`` optionally pins the pallas sweep direction ("pull"/"push");
    the default is the engine's documented behaviour — the per-iteration
    frontier-density heuristic for idempotent kernels, full-recompute for
    the rest — NOT a forced direction.  ``source`` overrides ``dk.source``
    for one query; ``sources`` runs a [B] batch of queries (one vmapped
    launch on the pallas engine, a sequential loop elsewhere) and returns a
    list of per-query ``ExecResult``s.  Both need a source-generic kernel
    set (``dk.source`` not None).

    As in ``run_program``, every knob kwarg is a hint resolved by the query
    planner into one frozen ``ExecutionPlan`` (recorded in ``stats.plan``;
    ``explain=True`` returns the ``PlanExplanation`` without executing;
    ``plan=`` supplies a pre-resolved plan; ``adaptive=True`` opts into the
    recorded-stats feedback for unpinned knobs).

    Guarded execution matches ``run_program``: ``validate`` /
    ``on_nonconverge`` / ``fallback`` + ``ft_config`` /
    ``divergence_sentinel``, plus the chunked-checkpoint knobs
    (``checkpoint_every``/``ckpt_dir``/``resume``/``init_state``, pallas
    engine only; ``init_state`` warm-starts the fixpoint from per-component
    [n] arrays).  ``delta=`` (a ``mutate.MutationDelta`` or raw vertex-id
    array, with ``init_state``) takes the incremental path exactly as in
    ``run_program`` — for the non-idempotent kernels this engine mostly
    serves (PR-style), that is the guarded rescaled warm start, converging
    to the same tolerance-fixed answer as a cold run (DESIGN.md §15)."""
    from repro.core.fusion import Prim

    mutation = None
    delta_ids = delta
    if delta is not None and hasattr(delta, "touched"):
        mutation = delta
        delta_ids = np.asarray(mutation.touched)
    if plan is None or explain:
        planned = plan_execution(
            g, dk, engine=engine, model=model, mesh=mesh, axes=axes,
            switch_k=switch_k, push_resolution=push_resolution,
            shard_strategy=shard_strategy,
            batch=None if sources is None else len(sources),
            validate=validate, on_nonconverge=on_nonconverge,
            fallback=fallback, divergence_sentinel=divergence_sentinel,
            adaptive=adaptive, mutation=mutation,
            default_engine="pallas" if (init_state is not None
                                        or delta is not None) else "pull",
            explain=explain)
        if explain:
            return planned
        plan = planned
    if mutation is not None and plan.incremental == "full":
        init_state = None
        delta_ids = None
    if delta_ids is not None and sources is not None:
        raise ValueError("delta warm starts are a solo-query path; "
                         "batched sources cannot share one touched set")
    if (checkpoint_every is not None or resume or init_state is not None
            or delta_ids is not None) and plan.engine != "pallas":
        raise ValueError("checkpointed/warm-started fixpoints are a "
                         f"pallas-engine feature; got engine={plan.engine!r}")
    if (source is not None or sources is not None) and dk.source is None:
        raise ValueError(
            "run_direct source overrides need a source-generic DirectKernels "
            "(init_fn(v, s) with source=...); this kernel set is sourceless "
            "or bakes its source into the init closure")
    if dk.source is not None and iterate._init_arity(dk.init_fn) < 2:
        raise ValueError(
            "DirectKernels.source requires a source-generic init_fn(v, s); "
            "a single-argument closure bakes its own source, so re-sourcing "
            "would move the ⊥-mask without moving the init value")
    chk = _validate_inputs(g, source=source, sources=sources) \
        if plan.validate else None
    max_iter_eff = dk.max_iter if dk.max_iter is not None else 2 * g.n + 4
    comp = iterate.CompRuntime(
        idx=0, op=dk.rop, dtype=iterate.DTYPES[dk.dtype],
        p_fn=dk.p_fn, init_fn=dk.init_fn, source=dk.source, e_fn=dk.e_fn)
    plans = [Prim(dk.rop, 0)]
    _check_preconditions(chk, [comp], plans)
    if sources is not None:
        if plan.engine == "pallas":
            from repro.kernels import ops as kops
            try:
                res = kops.iterate_pallas_batch(
                    g, [comp], plans, sources,
                    max_iter=dk.max_iter, tol=dk.tol, plan=plan)
            except Exception as exc:
                if not plan.fallback or not guard.recoverable(exc):
                    raise
                ev = guard.FallbackEvent(
                    "pallas", "adaptive",
                    f"{type(exc).__name__}: {exc}").as_tuple()
                outs = [run_direct(g, dk, engine="adaptive", model=None,
                                   source=int(s), validate=plan.validate,
                                   on_nonconverge=plan.on_nonconverge,
                                   fallback=plan.fallback,
                                   ft_config=ft_config)
                        for s in sources]
                for o in outs:
                    o.stats.fallbacks = (ev,) + o.stats.fallbacks
                    o.stats.engine_used = "adaptive"
                return outs
            _check_batch_outcomes(res, [int(s) for s in sources],
                                  max_iter_eff, plan.on_nonconverge)
            iters = np.asarray(res.iterations)
            works = np.asarray(res.edge_work)
            pushes = np.asarray(res.push_iters)
            res_ws = np.asarray(res.resolve_work)
            gat_ws = np.asarray(res.gather_work)
            outs = [ExecResult(
                value=res.state[0][b], named={},
                stats=ExecStats(rounds=1, iterations=int(iters[b]),
                                edge_work=float(works[b]),
                                push_iters=int(pushes[b]),
                                pull_iters=int(iters[b]) - int(pushes[b]),
                                resolve_work=float(res_ws[b]),
                                gather_work=float(gat_ws[b]),
                                engine_used="pallas", plan=plan))
                for b in range(len(iters))]
            for o in outs:
                _plan.record_feedback(g, plan.kind, o.stats)
            return outs
        # Non-pallas engines have no batched fixpoint: the planner resolved
        # batch_lane="sequential" and the guard event records the
        # degradation on every query (satellite 3).
        ev = guard.batch_degradation(plan.engine, len(sources)).as_tuple()
        outs = [run_direct(g, dk, mesh=mesh, axes=axes, source=int(s),
                           ft_config=ft_config, plan=plan)
                for s in sources]
        for o in outs:
            o.stats.fallbacks = (ev,) + o.stats.fallbacks
        return outs

    src_over = None if source is None else {0: int(source)}
    # frontier-masked (+) models for idempotent kernels (BFS/CC/SSSP/WP);
    # full-recompute (−) for non-idempotent / epilogue kernels (PageRank)
    idempotent = dk.rop in iterate._IDEMPOTENT_OPS and dk.e_fn is None
    if delta_ids is not None and init_state is not None and not idempotent:
        init_state = _rescale_warm_state(init_state, [comp], g.n)

    def call(engine):
        eff = _plan.degrade_plan(plan, engine)
        pull_like = engine in ("pull", "dense", "distributed")
        eng_model = ("pull+" if pull_like else "push+") if idempotent else \
            ("pull-" if pull_like else "push-")
        if engine in ("pull", "push"):
            return iterate.iterate_graph(g, [comp], plans, model=eng_model,
                                         max_iter=dk.max_iter, tol=dk.tol,
                                         sources=src_over)
        if engine == "adaptive":
            return iterate.iterate_adaptive(g, [comp], plans,
                                            max_iter=dk.max_iter, tol=dk.tol,
                                            sources=src_over)
        if engine == "dense":
            return iterate.iterate_dense(g, [comp], plans,
                                         max_iter=dk.max_iter,
                                         tol=dk.tol, sources=src_over)
        if engine == "distributed":
            assert mesh is not None, "distributed engine needs a mesh"
            return iterate.iterate_distributed(
                g, [comp], plans, mesh, axes=axes, model="pull-",
                max_iter=dk.max_iter, tol=dk.tol, sources=src_over)
        if engine == "pallas":
            # The engine's documented default: per-iteration direction
            # heuristic for idempotent kernels (pull− recompute otherwise),
            # forced only by an explicit model — NOT derived from pull_like,
            # which omits pallas and used to pin push for every direct
            # kernel.
            from repro.kernels import ops as kops
            return kops.iterate_pallas(
                g, [comp], plans, max_iter=dk.max_iter, tol=dk.tol,
                sources=src_over,
                checkpoint_every=checkpoint_every, ckpt_dir=ckpt_dir,
                resume=resume, init_state=init_state, delta=delta_ids,
                plan=eff)
        if engine == "pallas_sharded":
            assert mesh is not None, "pallas_sharded engine needs a mesh"
            from repro.kernels import ops as kops
            return kops.iterate_pallas_sharded(
                g, [comp], plans, mesh, axes=axes,
                max_iter=dk.max_iter, tol=dk.tol,
                sources=src_over, plan=eff)
        raise ValueError(engine)

    res, eng_used, events, retries = _dispatch_guarded(call, plan.engine,
                                                       plan.fallback,
                                                       ft_config)
    stats = ExecStats(engine_used=eng_used,
                      fallbacks=tuple(ev.as_tuple() for ev in events),
                      exec_retries=retries, plan=plan)
    _accumulate(stats, res, 0.0)
    _check_outcome(res, max_iter_eff, plan.on_nonconverge)
    _plan.record_feedback(g, plan.kind, stats)
    return ExecResult(value=res.state[0], named={}, stats=stats)
