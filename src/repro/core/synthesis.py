"""Type-guided enumerative synthesis of the iteration kernel functions
(paper §5.2).

Given a factored path-based reduction ``R F`` the synthesizer searches the
grammar of Fig. 4a (kernel_lang) in order of increasing expression size for

  I — the initialization function, specified by C1/C2,
  P — the propagation function, specified by C4/C5 (wrapped into P' for C3),
  R — the reduction function, validated against C6–C9,

memoizing candidate pools per type and caching results per (F, R).  The
result is a correct-by-construction kernel set plus printable source for the
five engine backends (the paper's "code generation").
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Optional

import numpy as np

from repro.core import conditions as C
from repro.core import lang as L
from repro.core.kernel_lang import (Enumerator, Expr, Lit, Var, FLT, INT, VERT,
                                    compile_expr, default_terminals, expr_size)


@dataclasses.dataclass
class SynthesizedKernels:
    f: L.PathFn
    rop: str
    p_expr: Expr
    i_expr: Expr                  # on-source branch (C2's ⊥ branch is structural)
    idempotent: bool
    terminating: bool             # strengthened C10 verified
    candidates_tried: int
    wall_ms: float

    def p_fn(self):
        return compile_expr(self.p_expr)

    def init_fn(self):
        """Source-GENERIC init kernel ``init_fn(v, s=None)`` (DESIGN.md §8).

        The on-source branch is only ever read where ``v == s`` (the engine
        masks everything else to ⊥ per C2), so the source enters as a plain
        value — a traced scalar works as well as a Python int, which is what
        lets one compiled executor serve every query source.  ``s=None``
        (sourceless components, Paths(v)) evaluates the trivial path at each
        vertex, i.e. ``s := v``."""
        fn = compile_expr(self.i_expr)
        return lambda v, s=None: fn({"v": v, "s": v if s is None else s})

    def describe(self) -> str:
        return (f"I := λv. if (v = s) {self.i_expr} else ⊥\n"
                f"P := λn, e. {self.p_expr}\n"
                f"R := {self.rop}  (idempotent={self.idempotent})\n"
                f"E := λn. n")


_VALUE_TY = {"int": INT, "float": FLT, "vert": VERT}
_CACHE: dict = {}


class SynthesisError(Exception):
    pass


def synthesize_component(f: L.PathFn, rop: str,
                         require_idempotent: bool = False) -> SynthesizedKernels:
    key = (f.kind, rop, require_idempotent)
    if key in _CACHE:
        return _CACHE[key]
    t0 = time.perf_counter()
    rng = np.random.default_rng(0xC0FFEE)
    ty = _VALUE_TY[f.dtype]

    if not C.check_R(rop, require_idempotent, rng):
        raise SynthesisError(f"reduction {rop} violates C7–C9 "
                             f"(idempotent={require_idempotent})")

    # --- P: C5 then C4, smallest first ------------------------------------
    tried = 0
    p_expr = None
    enum = Enumerator(default_terminals(ty))
    for cand in enum.upto(ty, 5):
        tried += 1
        if C.check_C5(cand, f, rng) and C.check_C4(cand, f, rop, rng):
            p_expr = cand
            break
    if p_expr is None:
        raise SynthesisError(f"no propagation function found for {rop} {f}")

    # --- I: the on-source branch must match F(⟨v,v⟩) (C1) ------------------
    init_terms = [Lit(0, INT), Lit(1, INT), Lit(L.CAP_INF, FLT),
                  Var("v", VERT), Var("s", VERT)]
    i_expr = None
    ienum = Enumerator(init_terms)
    for cand in ienum.upto(ty, 3):
        tried += 1
        if C.check_I(cand, f, rng):
            i_expr = cand
            break
    if i_expr is None:
        raise SynthesisError(f"no initialization function found for {f}")

    terminating = C.check_C10(f, rop, rng)
    out = SynthesizedKernels(
        f=f, rop=rop, p_expr=p_expr, i_expr=i_expr,
        idempotent=L.IDEMPOTENT[rop], terminating=terminating,
        candidates_tried=tried, wall_ms=(time.perf_counter() - t0) * 1e3)
    _CACHE[key] = out
    return out


_ROUND_CACHE: dict = {}


def _plan_position_ops(round_) -> dict:
    """{comp idx: monoid} from each leaf plan's lex-level positions."""
    from repro.core.fusion import Lex

    ops = {}

    def walk(plan):
        ops[plan.comp] = plan.op
        if isinstance(plan, Lex):
            walk(plan.secondary)

    for leaf in round_.leaves:
        walk(leaf.plan)
    return ops


def round_structure_key(round_) -> tuple:
    """Structural identity of a round's iteration part: component path
    functions, sourced-ness and plan-position monoids.  Two rounds with the
    same key synthesize (and compile) the same kernel closures, so downstream
    compiled-executor caches key on the closure identities this memo keeps
    stable (DESIGN.md §8).

    The source VALUE is deliberately absent: init kernels are source-generic
    (``init_fn(v, s)``) and every engine takes the source as runtime data, so
    BFS(0) and BFS(5) share one closure set — and with it one compiled
    executor — instead of retracing the fixpoint per query source.  Only
    whether a component has a source at all (Paths(s,·) vs Paths(v)) is
    structural: it decides the ⊥-masking shape of the initial state."""
    ops = _plan_position_ops(round_)
    return tuple((comp.idx, comp.f.kind, comp.source is not None,
                  ops[comp.idx])
                 for comp in round_.components)


def synthesize_round(round_) -> dict:
    """Synthesize kernels for every component of a FusedRound.

    Returns {comp_idx: (p_fn, init_fn)} for iterate.comp_runtimes, plus the
    SynthesizedKernels records under key ("kernels", idx).  Memoized per
    round structure so the compiled per-component closures (and with them
    every downstream executor cache entry) are reused across rounds,
    repeated queries and benchmark repeats."""
    key = round_structure_key(round_)
    hit = _ROUND_CACHE.get(key)
    if hit is not None:
        return hit

    ops = _plan_position_ops(round_)
    out = {}
    for comp in round_.components:
        sk = synthesize_component(comp.f, ops[comp.idx])
        out[comp.idx] = (sk.p_fn(), sk.init_fn())
        out[("kernels", comp.idx)] = sk
    _ROUND_CACHE[key] = out
    return out


# ---------------------------------------------------------------------------
# Direct kernel specification (PageRank — paper Fig. 4b gives the kernels
# explicitly; PR's damped-path F is outside the spec language).
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DirectKernels:
    """User-supplied kernels, same shape the synthesizer produces.

    ``init_fn`` may be source-generic (``(v, s) → value`` with ``source``
    naming the default query source) or legacy single-argument (``v →
    value`` with the source baked into the closure).  Only the source-
    generic form lets the compiled-executor cache serve every source from
    one trace and admits ``run_direct(..., sources=[...])`` batching; the
    engines detect the arity and support both."""
    name: str
    rop: str
    dtype: str                      # "int" | "float"
    p_fn: object                    # env → value
    init_fn: object                 # (v, s) → value  (or legacy v → value)
    e_fn: Optional[object] = None   # epilogue
    tol: float = 0.0
    max_iter: Optional[int] = None
    source: Optional[int] = None    # default query source (None = sourceless)


def pagerank_kernels(n: int, gamma: float = 0.85, tol: float = 1e-6,
                     max_iter: int = 100) -> DirectKernels:
    """Fig. 4b: I = λv. 1/|V|;  P = λn,e. n / outdeg(src(e));  R = sum;
    E = λn. γ·n + (1−γ)/|V|."""
    return DirectKernels(
        name="pagerank", rop="sum", dtype="float",
        p_fn=lambda env: env["n"] / env["outdeg"],
        init_fn=lambda v: v * 0 + 1.0 / n,
        e_fn=lambda env: gamma * env["n"] + (1.0 - gamma) / n,
        tol=tol, max_iter=max_iter)


def weighted_pagerank_kernels(n: int, gamma: float = 0.85, tol: float = 1e-6,
                              max_iter: int = 100) -> DirectKernels:
    """Weighted PageRank: mass flows along an edge in proportion to its
    weight — P = λn,e. n · w(e) / wdeg(src(e)) with ``wdeg`` the weighted
    out-degree from the P environment (Σ outgoing weight, precomputed once
    per graph in ``structure.w_out_deg`` so every engine and both pallas
    sweep directions normalize by the bit-identical vector); I and E as in
    unweighted PageRank.  This is the weighted push− epilogue round: on the
    pallas engine ``model="push"`` runs it as a push− scatter recompute
    whose dst-sorted resolution reduces the same dst-major rectangle as the
    pull sweep, so push ≡ pull holds bitwise (DESIGN.md §10)."""
    return DirectKernels(
        name="weighted_pagerank", rop="sum", dtype="float",
        p_fn=lambda env: env["n"] * env["w"] / env["wdeg"],
        init_fn=lambda v: v * 0 + 1.0 / n,
        e_fn=lambda env: gamma * env["n"] + (1.0 - gamma) / n,
        tol=tol, max_iter=max_iter)


# ---------------------------------------------------------------------------
# Backend code generation: printable per-engine source for a kernel set.
# ---------------------------------------------------------------------------

_ENGINE_TEMPLATES = {
    "pull": """# pull engine (PowerGraph-pull analogue) — generated by Grafs
def propagate(n, w, c, esrc, edst, outdeg, nv):
    return {p}
def init(v, s):
    return jnp.where(v == s, {i}, IDENT)   # IDENT = ⊥ of {rop}
# per iteration: vals = propagate(state[src], ...);  segment_{rop}(vals, dst)
""",
    "push": """# push engine (Ligra analogue) — generated by Grafs
def propagate(n, w, c, esrc, edst, outdeg, nv):
    return {p}
# per iteration: frontier-masked  state.at[dst].{rop}(propagate(state[src]))
""",
    "dense": """# dense engine (GridGraph analogue) — generated by Grafs
# new[v] = {rop} over u of P(state[u], W[u,v]) on the dense edge matrix
def propagate(n, w, c, esrc, edst, outdeg, nv):
    return {p}
""",
    "distributed": """# distributed engine (Gemini analogue) — generated by Grafs
# per shard: local segment_{rop}; cross-shard combine: {collective}
def propagate(n, w, c, esrc, edst, outdeg, nv):
    return {p}
""",
    "pallas": """# pallas engine (GraphIt analogue) — generated by Grafs
# blocked-ELL tile kernel: gather → propagate → masked {rop}-reduce in VMEM
def propagate(n, w, c, esrc, edst, outdeg, nv):
    return {p}
""",
}

_COLLECTIVE = {"min": "lax.pmin", "max": "lax.pmax", "sum": "lax.psum",
               "or": "lax.pmax", "and": "lax.pmin", "prod": "all_gather+prod"}


def emit_source(sk: SynthesizedKernels, engine: str) -> str:
    tpl = _ENGINE_TEMPLATES[engine]
    return tpl.format(p=str(sk.p_expr), i=str(sk.i_expr), rop=sk.rop,
                      collective=_COLLECTIVE[sk.rop])
