"""Structured errors and policies of the guarded execution layer.

The paper separates *what* a fused program computes from the *conditions*
under which the iterative models are correct and terminate (Fig. 9).  The
engines enforce the execution-side half of that contract at runtime:

  * malformed graphs (out-of-range indices, non-finite weights) fail
    ``GraphValidationError`` before any kernel launches
    (``structure.validate_graph``);
  * specs whose termination proof assumed graph contracts the input breaks
    (min-plus on negative weights) fail ``TerminationPreconditionError``
    naming the violated condition (``conditions.violated_preconditions``);
  * fixpoints that exhaust ``max_iter`` raise ``NonConvergenceError`` with
    the exit diagnostics (iterations, residual, active count) instead of
    returning a silent partial state;
  * NaN/Inf blow-ups inside the fixpoint trip a divergence sentinel folded
    into the loop condition (zero extra launches) and raise
    ``DivergenceError`` with the iteration they fired on;
  * infrastructure failures degrade down ``FALLBACK_CHAIN`` with bounded
    retry (``runtime.ft.bounded_retry``), recorded in ``ExecStats``.

This module is dependency-free (no jax, no repro imports) so every layer —
graph containers, reference engines, pallas kernels, the executor — can
raise the same exception types without import cycles.
"""
from __future__ import annotations

import dataclasses


class GuardError(Exception):
    """Base of every structured guard failure."""


class GraphValidationError(GuardError, ValueError):
    """The input graph (or a query source) violates the structural contract:
    edge indices out of [0, n), wrong dtype, non-finite weights/capacities,
    or a policy violation (self-loops/duplicates under an 'error' policy)."""


class TerminationPreconditionError(GuardError, ValueError):
    """The spec's termination condition is violated by this graph's actual
    edge-value ranges (e.g. strengthened C10 fails for min-plus once weights
    go negative).  ``condition`` names the violated paper condition."""

    def __init__(self, message: str, condition: str = "C10",
                 component: int = -1, detail: str = ""):
        super().__init__(message)
        self.condition = condition
        self.component = component
        self.detail = detail


class NonConvergenceError(GuardError, RuntimeError):
    """The fixpoint exhausted ``max_iter`` with vertices still active."""

    def __init__(self, message: str, iterations: int = 0, max_iter: int = 0,
                 active_count: int = 0, residual: float = float("nan")):
        super().__init__(message)
        self.iterations = iterations
        self.max_iter = max_iter
        self.active_count = active_count
        self.residual = residual


class DivergenceError(GuardError, RuntimeError):
    """The in-loop NaN/Inf sentinel fired: the iteration produced values
    outside the monoid's meaningful domain (a blown-up sum/prod component or
    a NaN anywhere)."""

    def __init__(self, message: str, iterations: int = 0):
        super().__init__(message)
        self.iterations = iterations


class CheckpointMismatchError(GuardError, RuntimeError):
    """A fixpoint checkpoint's fingerprint (graph shape, plan structure,
    query sources, knobs) does not match the resuming executor — resuming
    would silently continue a DIFFERENT query."""


@dataclasses.dataclass(frozen=True)
class FallbackEvent:
    """One engine-degradation step, recorded in ``ExecStats.fallbacks``."""
    from_engine: str
    to_engine: str
    error: str

    def as_tuple(self):
        return (self.from_engine, self.to_engine, self.error)


def batch_degradation(engine: str, batch_size: int) -> FallbackEvent:
    """The planner's recorded decision that a [B]-source batch on a
    non-pallas engine runs as B sequential queries (the engine has no
    batched fixpoint).  Not an error — the event mirrors the plan's
    ``batch_lane="sequential"`` so batch degradations surface in the same
    ``ExecStats.fallbacks`` stream as guard fallbacks (DESIGN.md §14)."""
    return FallbackEvent(
        f"batch[{batch_size}]:{engine}", f"sequential:{engine}",
        f"engine {engine!r} has no batched fixpoint; plan resolved "
        "batch_lane='sequential'")


# Degradation order: the sharded kernel engine falls back to the
# single-device kernel engine (same fused sweeps, no collectives), which
# falls back to the adaptive reference engine (plain segment ops — the
# semantics every kernel engine is tested against).  ``adaptive`` is the
# floor: its failures propagate.
FALLBACK_CHAIN = {
    "pallas_sharded": "pallas",
    "pallas": "adaptive",
}


# Failures that retry/fallback must NEVER swallow: guard verdicts are
# engine-independent (a validation error or a diverged fixpoint fails the
# same way on every engine), and programming errors (bad knobs, wrong
# types, broken invariants) are not infrastructure flakes.
NON_RECOVERABLE = (GuardError, ValueError, TypeError, AssertionError,
                   KeyboardInterrupt)


def recoverable(exc: BaseException) -> bool:
    """True for infrastructure-shaped failures worth a retry or a fallback
    (lowering errors, runtime launch failures, OOM); False for guard
    verdicts and programming errors, which must propagate unchanged."""
    return isinstance(exc, Exception) and not isinstance(exc, NON_RECOVERABLE)
