"""Correctness and termination conditions C1–C10 (paper Fig. 9) as
executable, bounded-verification checkers.

The paper discharges these universally-quantified conditions with Z3.  Z3 is
unavailable offline, so we check validity by exhaustive evaluation over small
integer domains plus dense random float sampling, using the *extension laws*
of the path functions (lang.PathFn.extend) to replace quantification over
paths with quantification over (value, edge) pairs:

  C4  P(R(F(p1), F(p2)), e) = R(F(p1·e), F(p2·e))
      →  ∀ n1, n2, e:  P(R(n1,n2), e) = R(ext_F(n1,e), ext_F(n2,e))
  C5  P(F(p), e) = F(p·e)        →  ∀ n, e:  P(n,e) = ext_F(n,e)
  C10 (strengthened, §5.2)       →  ∀ n, e:  R(n, ext_F(n,e)) = n

All candidate bodies are piecewise-affine min/max arithmetic over the
grammar of Fig. 4a; hypothesis-based property tests in tests/ re-check the
accepted kernels with thousands of random samples, and the end-to-end suite
cross-validates against the path-enumeration oracle.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.core import lang as L
from repro.core.kernel_lang import Expr, eval_expr

_REL_TOL = 1e-6


def _eq(a, b) -> bool:
    if isinstance(a, bool) or isinstance(b, bool):
        return bool(a) == bool(b)
    if a == b:
        return True
    try:
        return math.isclose(float(a), float(b), rel_tol=_REL_TOL, abs_tol=1e-9)
    except (TypeError, OverflowError):
        return False


def sample_edges(f: L.PathFn, rng: np.random.Generator, k: int = 24):
    """Edge tuples (src, dst, w, c) + env extras, honoring graph contracts
    (w ≥ 0 — paper's SSSP termination assumes non-negative edges; c > 0)."""
    edges = []
    for w in (0.0, 1.0, 2.5):
        for c in (0.5, 1.0, 3.0):
            edges.append((1, 2, w, c))
    for _ in range(k):
        edges.append((int(rng.integers(0, 6)), int(rng.integers(0, 6)),
                      float(np.round(rng.uniform(0, 8), 3)),
                      float(np.round(rng.uniform(0.1, 8), 3))))
    return edges


def sample_values(f: L.PathFn, rng: np.random.Generator, k: int = 12):
    """Plausible F-codomain values (finite — ⊥ is handled by the P'/R'
    wrappers, conditions C3/C6 hold by construction)."""
    if f.kind == "length":
        return [0, 1, 2, 3, 5, 9]
    if f.kind == "one":
        return [1, 2, 3, 7]
    if f.kind in ("head", "penultimate"):
        return [0, 1, 2, 5]
    if f.kind == "capacity":
        base = [0.5, 1.0, 3.0, L.CAP_INF]
    else:
        base = [0.0, 1.0, 2.5, 7.0]
    return base + [float(np.round(rng.uniform(0, 9), 3)) for _ in range(k)]


def _env(n, edge):
    src, dst, w, c = edge
    return {"n": n, "w": w, "c": c, "esrc": src, "edst": dst,
            "outdeg": 2.0, "nv": 8.0}


def check_C5(p: Expr, f: L.PathFn, rng) -> bool:
    for n in sample_values(f, rng):
        for e in sample_edges(f, rng, 8):
            if not _eq(eval_expr(p, _env(n, e), np), f.extend(n, e)):
                return False
    return True


def check_C4(p: Expr, f: L.PathFn, rop: str, rng) -> bool:
    vals = sample_values(f, rng, 6)
    for n1, n2 in itertools.product(vals, vals):
        for e in sample_edges(f, rng, 4):
            lhs = eval_expr(p, _env(L.reduce_op(rop, n1, n2), e), np)
            rhs = L.reduce_op(rop, f.extend(n1, e), f.extend(n2, e))
            if not _eq(lhs, rhs):
                return False
    return True


def check_R(rop: str, require_idempotent: bool, rng) -> bool:
    """C6 holds by the R' wrapper; check C7 (comm), C8 (assoc), C9 (idem)."""
    vals = [0.0, 1.0, 2.5, 7.0, -3.0] + list(np.round(rng.uniform(-9, 9, 4), 3))
    for a, b, c in itertools.product(vals, vals, vals):
        if not _eq(L.reduce_op(rop, a, b), L.reduce_op(rop, b, a)):
            return False
        if not _eq(L.reduce_op(rop, L.reduce_op(rop, a, b), c),
                   L.reduce_op(rop, a, L.reduce_op(rop, b, c))):
            return False
    if require_idempotent:
        for a in vals:
            if not _eq(L.reduce_op(rop, a, a), a):
                return False
    return True


def check_I(i_expr: Expr, f: L.PathFn, rng) -> bool:
    """C1: the on-source branch must equal F(⟨v,v⟩) (C2 — the off-source ⊥
    branch — holds by construction of the structured I)."""
    for v in range(6):
        env = {"v": v, "s": v, "w": 0.0, "c": 0.0, "esrc": v, "edst": v,
               "outdeg": 1.0, "nv": 8.0, "n": 0}
        if not _eq(eval_expr(i_expr, env, np), f.trivial(v)):
            return False
    return True


def check_C10(f: L.PathFn, rop: str, rng) -> bool:
    """Strengthened termination (§5.2): R(F(p), F(p·e)) = F(p) for every
    edge extension, under the graph contracts."""
    for n in sample_values(f, rng):
        if n >= L.CAP_INF and f.kind != "capacity":
            continue
        for e in sample_edges(f, rng, 8):
            ext = f.extend(n, e)
            if not _eq(L.reduce_op(rop, n, ext), n):
                return False
    return True


# ---------------------------------------------------------------------------
# Runtime termination preconditions (the guarded-execution entry check).
# ---------------------------------------------------------------------------

_IN_CONTRACT_W_MIN = 0.0      # sample_edges' graph contract: w >= 0, c > 0.
                              # Synthesis verifies C10 under exactly these
                              # ranges, so in-contract graphs need no re-probe.


def _probe_values(dtype_str: str):
    """Plausible finite F-codomain samples per component dtype (⊥ excluded —
    the P'/R' wrappers handle it, C3/C6)."""
    if dtype_str in ("int", "vert"):
        return [0, 1, 2, 5]
    return [0.0, 1.0, 2.5, 7.0]


def violated_preconditions(comps, plans, w_range, c_range) -> list:
    """Probe the strengthened termination condition C10 — R(n, P'(n, e)) = n
    (§5.2) — against a graph's ACTUAL edge-value ranges.

    Synthesis discharges C10 under the graph contracts ``w >= 0, c > 0``
    (``sample_edges``); a graph outside those ranges (negative weights under
    min-plus being the canonical case) voids that proof, so the engine entry
    points re-probe here with (value, edge) samples drawn from the real
    ranges before launching a fixpoint that may never terminate.  In-contract
    graphs return ``[]`` without probing.

    ``comps`` are the runtime components (``iterate.CompRuntime``: the
    synthesized ``p_fn`` closures evaluate P exactly as the engines do);
    only each plan's PRIMARY level is probed — lexicographic secondaries
    ride the primary's ordering (FPNEST), and non-idempotent reductions
    (PageRank-style sum/prod with an epilogue) terminate by tol/max_iter,
    not by C10.  Returns a list of violation dicts
    ``{"condition", "component", "op", "detail"}``."""
    import numpy as _np

    w_lo, w_hi = float(w_range[0]), float(w_range[1])
    c_lo, c_hi = float(c_range[0]), float(c_range[1])
    in_contract = w_lo >= _IN_CONTRACT_W_MIN and c_lo > 0.0
    if in_contract:
        return []
    comps_by_idx = {cr.idx: cr for cr in comps}
    edge_vals = sorted({w_lo, w_hi, (w_lo + w_hi) / 2.0})
    cap_vals = sorted({c_lo, c_hi, (c_lo + c_hi) / 2.0})
    out = []
    for plan in plans:
        cr = comps_by_idx[plan.comp]
        if plan.op not in ("min", "max", "or", "and") or cr.e_fn is not None:
            continue                      # tol/max_iter-bounded, not C10
        dtype_str = "int" if _np.issubdtype(_np.dtype(cr.dtype), _np.integer) \
            else "float"
        for n0 in _probe_values(dtype_str):
            for w0 in edge_vals:
                for c0 in cap_vals:
                    env = {"n": n0, "w": w0, "c": c0, "esrc": 1, "edst": 2,
                           "outdeg": 2.0, "wdeg": 1.0, "nv": 8.0}
                    try:
                        ext = float(_np.asarray(cr.p_fn(env)))
                    except Exception:     # non-scalar/odd P: can't probe
                        continue
                    red = L.reduce_op(plan.op, n0, ext)
                    if not _eq(red, n0):
                        out.append({
                            "condition": "C10",
                            "component": cr.idx,
                            "op": plan.op,
                            "detail": (
                                f"R(n, P(n, e)) != n at n={n0}, "
                                f"w={w0}, c={c0}: "
                                f"{plan.op}({n0}, {ext}) = {red}"),
                        })
                        break
                else:
                    continue
                break
            else:
                continue
            break
    return out
