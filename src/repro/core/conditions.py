"""Correctness and termination conditions C1–C10 (paper Fig. 9) as
executable, bounded-verification checkers.

The paper discharges these universally-quantified conditions with Z3.  Z3 is
unavailable offline, so we check validity by exhaustive evaluation over small
integer domains plus dense random float sampling, using the *extension laws*
of the path functions (lang.PathFn.extend) to replace quantification over
paths with quantification over (value, edge) pairs:

  C4  P(R(F(p1), F(p2)), e) = R(F(p1·e), F(p2·e))
      →  ∀ n1, n2, e:  P(R(n1,n2), e) = R(ext_F(n1,e), ext_F(n2,e))
  C5  P(F(p), e) = F(p·e)        →  ∀ n, e:  P(n,e) = ext_F(n,e)
  C10 (strengthened, §5.2)       →  ∀ n, e:  R(n, ext_F(n,e)) = n

All candidate bodies are piecewise-affine min/max arithmetic over the
grammar of Fig. 4a; hypothesis-based property tests in tests/ re-check the
accepted kernels with thousands of random samples, and the end-to-end suite
cross-validates against the path-enumeration oracle.
"""
from __future__ import annotations

import itertools
import math

import numpy as np

from repro.core import lang as L
from repro.core.kernel_lang import Expr, eval_expr

_REL_TOL = 1e-6


def _eq(a, b) -> bool:
    if isinstance(a, bool) or isinstance(b, bool):
        return bool(a) == bool(b)
    if a == b:
        return True
    try:
        return math.isclose(float(a), float(b), rel_tol=_REL_TOL, abs_tol=1e-9)
    except (TypeError, OverflowError):
        return False


def sample_edges(f: L.PathFn, rng: np.random.Generator, k: int = 24):
    """Edge tuples (src, dst, w, c) + env extras, honoring graph contracts
    (w ≥ 0 — paper's SSSP termination assumes non-negative edges; c > 0)."""
    edges = []
    for w in (0.0, 1.0, 2.5):
        for c in (0.5, 1.0, 3.0):
            edges.append((1, 2, w, c))
    for _ in range(k):
        edges.append((int(rng.integers(0, 6)), int(rng.integers(0, 6)),
                      float(np.round(rng.uniform(0, 8), 3)),
                      float(np.round(rng.uniform(0.1, 8), 3))))
    return edges


def sample_values(f: L.PathFn, rng: np.random.Generator, k: int = 12):
    """Plausible F-codomain values (finite — ⊥ is handled by the P'/R'
    wrappers, conditions C3/C6 hold by construction)."""
    if f.kind == "length":
        return [0, 1, 2, 3, 5, 9]
    if f.kind == "one":
        return [1, 2, 3, 7]
    if f.kind in ("head", "penultimate"):
        return [0, 1, 2, 5]
    if f.kind == "capacity":
        base = [0.5, 1.0, 3.0, L.CAP_INF]
    else:
        base = [0.0, 1.0, 2.5, 7.0]
    return base + [float(np.round(rng.uniform(0, 9), 3)) for _ in range(k)]


def _env(n, edge):
    src, dst, w, c = edge
    return {"n": n, "w": w, "c": c, "esrc": src, "edst": dst,
            "outdeg": 2.0, "nv": 8.0}


def check_C5(p: Expr, f: L.PathFn, rng) -> bool:
    for n in sample_values(f, rng):
        for e in sample_edges(f, rng, 8):
            if not _eq(eval_expr(p, _env(n, e), np), f.extend(n, e)):
                return False
    return True


def check_C4(p: Expr, f: L.PathFn, rop: str, rng) -> bool:
    vals = sample_values(f, rng, 6)
    for n1, n2 in itertools.product(vals, vals):
        for e in sample_edges(f, rng, 4):
            lhs = eval_expr(p, _env(L.reduce_op(rop, n1, n2), e), np)
            rhs = L.reduce_op(rop, f.extend(n1, e), f.extend(n2, e))
            if not _eq(lhs, rhs):
                return False
    return True


def check_R(rop: str, require_idempotent: bool, rng) -> bool:
    """C6 holds by the R' wrapper; check C7 (comm), C8 (assoc), C9 (idem)."""
    vals = [0.0, 1.0, 2.5, 7.0, -3.0] + list(np.round(rng.uniform(-9, 9, 4), 3))
    for a, b, c in itertools.product(vals, vals, vals):
        if not _eq(L.reduce_op(rop, a, b), L.reduce_op(rop, b, a)):
            return False
        if not _eq(L.reduce_op(rop, L.reduce_op(rop, a, b), c),
                   L.reduce_op(rop, a, L.reduce_op(rop, b, c))):
            return False
    if require_idempotent:
        for a in vals:
            if not _eq(L.reduce_op(rop, a, a), a):
                return False
    return True


def check_I(i_expr: Expr, f: L.PathFn, rng) -> bool:
    """C1: the on-source branch must equal F(⟨v,v⟩) (C2 — the off-source ⊥
    branch — holds by construction of the structured I)."""
    for v in range(6):
        env = {"v": v, "s": v, "w": 0.0, "c": 0.0, "esrc": v, "edst": v,
               "outdeg": 1.0, "nv": 8.0, "n": 0}
        if not _eq(eval_expr(i_expr, env, np), f.trivial(v)):
            return False
    return True


def check_C10(f: L.PathFn, rop: str, rng) -> bool:
    """Strengthened termination (§5.2): R(F(p), F(p·e)) = F(p) for every
    edge extension, under the graph contracts."""
    for n in sample_values(f, rng):
        if n >= L.CAP_INF and f.kind != "capacity":
            continue
        for e in sample_edges(f, rng, 8):
            ext = f.extend(n, e)
            if not _eq(L.reduce_op(rop, n, ext), n):
                return False
    return True
