"""The Grafs specification language (paper §2, §4.1) and its denotational
semantics.

An analytics query is a term over:
  * path-based reductions   R_{p ∈ P} F(p)      (m-terms: one value per vertex)
  * vertex-based reductions R_{v ∈ V} m(v)      (r-terms: one scalar)
  * arithmetic operators between terms, nesting via restricted path sets
    (args min/max), and syntactic sugar (cardinality, path selection,
    constrained vertex reductions).

``paths_semantics`` below is the *denotational semantics oracle*: it
evaluates a specification by explicit bounded path enumeration (Def. 5/6 of
the paper) on small host-side graphs.  Everything else in the system —
fusion, synthesis, the five iteration engines — is validated against it.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence

import numpy as np

from repro.graph.structure import Graph

# ---------------------------------------------------------------------------
# Path functions F and their algebra (extension laws — DESIGN.md §2).
# ---------------------------------------------------------------------------

INF = float("inf")

# The capacity of the zero-length path is +∞ mathematically, but +∞ is also
# the ⊥/identity of min-reductions (C6).  To keep "source initialized" and
# "unreachable" distinguishable in the engines, the trivial capacity is a
# large FINITE sentinel — any value above engine.BOT_CUTOFF reads as
# "no constraining edge yet" at result interpretation time (DESIGN.md §6).
CAP_INF = 1e30


@dataclasses.dataclass(frozen=True)
class PathFn:
    kind: str          # length|weight|capacity|head|penultimate|one
    dtype: str         # "int"|"float"|"vert"

    def trivial(self, v):
        """F(⟨v,v⟩): value on the zero-length path at v."""
        return {"length": 0, "weight": 0.0, "capacity": CAP_INF, "head": v,
                "penultimate": v, "one": 1}[self.kind]

    def extend(self, n, edge):
        """F(p·e) given F(p)=n and e=(src,dst,w,c) — the extension law."""
        src, dst, w, c = edge
        return {"length": lambda: n + 1,
                "weight": lambda: n + w,
                "capacity": lambda: min(n, c),
                "head": lambda: n,
                "penultimate": lambda: src,
                "one": lambda: n}[self.kind]()

    def __str__(self):
        return self.kind


LENGTH = PathFn("length", "int")
WEIGHT = PathFn("weight", "float")
CAPACITY = PathFn("capacity", "float")
HEAD = PathFn("head", "vert")
PENULTIMATE = PathFn("penultimate", "vert")
ONE = PathFn("one", "int")

PATH_FNS = {f.kind: f for f in
            (LENGTH, WEIGHT, CAPACITY, HEAD, PENULTIMATE, ONE)}

# Reduction functions R (commutative + associative; C7, C8).
IDEMPOTENT = {"min": True, "max": True, "or": True, "and": True,
              "sum": False, "prod": False}


def reduce_op(op: str, a, b):
    return {"min": min, "max": max, "sum": lambda x, y: x + y,
            "prod": lambda x, y: x * y,
            "or": lambda x, y: bool(x) or bool(y),
            "and": lambda x, y: bool(x) and bool(y)}[op](a, b)


def reduce_identity(op: str):
    return {"min": INF, "max": -INF, "sum": 0, "prod": 1,
            "or": False, "and": True}[op]


# ---------------------------------------------------------------------------
# Specification AST.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Term:
    pass


# ----- path sets ------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class AllPaths:
    """Paths(v) (source=None) or Paths(s, v)."""
    source: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class ArgsRestrict:
    """args min/max_{p∈inner} F'(p): the subset of inner paths whose F' value
    is extremal (rule FPNEST flattens this to a lexicographic reduction)."""
    r: str                    # "min" | "max"
    f: PathFn
    inner: "AllPaths | ArgsRestrict"


# ----- m-terms (per-vertex values) ------------------------------------------

@dataclasses.dataclass(frozen=True)
class PathReduce(Term):
    """R_{p ∈ paths} F(p)."""
    r: str
    f: PathFn
    paths: "AllPaths | ArgsRestrict" = AllPaths()


@dataclasses.dataclass(frozen=True)
class PathSel(Term):
    """F(arg R'_{p ∈ paths} F'(p)) — sugar, rule FMRED (used by BFS)."""
    f: PathFn                 # applied to the selected path
    r: str                    # "min" | "max" over f_sel
    f_sel: PathFn
    paths: "AllPaths | ArgsRestrict" = AllPaths()


@dataclasses.dataclass(frozen=True)
class Cardinality(Term):
    """|paths| — sugar for Σ_{p∈paths} 1 (used by NSP)."""
    paths: "AllPaths | ArgsRestrict" = AllPaths()


@dataclasses.dataclass(frozen=True)
class MBin(Term):
    op: str                   # + - * / min max
    a: Term
    b: Term


@dataclasses.dataclass(frozen=True)
class MConst(Term):
    val: float


# ----- r-terms (scalars) -----------------------------------------------------

@dataclasses.dataclass(frozen=True)
class VertexReduce(Term):
    """R_{v ∈ V [∧ cond]} m(v).  `collect` gathers {v | cond} as a mask
    (set-valued domain extension, §4.3)."""
    r: str                    # min|max|sum|or|and|collect
    m: Term
    cond: Optional[Term] = None   # boolean m-term constraint on v


@dataclasses.dataclass(frozen=True)
class RBin(Term):
    op: str
    a: Term
    b: Term


@dataclasses.dataclass(frozen=True)
class RConst(Term):
    val: float


@dataclasses.dataclass(frozen=True)
class LetRound(Term):
    """Nested triple-lets (§4.3 Nested Triple-lets): bind the scalar result of
    r-term `bound` to `name`, usable inside `body` (→ a second
    iteration-map-reduce round, e.g. RDS)."""
    name: str
    bound: Term
    body: Term


@dataclasses.dataclass(frozen=True)
class ScalarRef(Term):
    """Reference to a LetRound-bound scalar inside m/r expressions."""
    name: str


# ---------------------------------------------------------------------------
# Denotational semantics oracle: explicit bounded path enumeration.
# ---------------------------------------------------------------------------

def _enum_paths(g: Graph, max_len: int):
    """All paths of length ≤ max_len, as lists of edge tuples, grouped by
    destination.  Exponential — test-sized graphs only."""
    src, dst, w, c = g.host_edges()
    out_edges = [[] for _ in range(g.n)]
    for i in range(src.shape[0]):
        out_edges[int(src[i])].append((int(src[i]), int(dst[i]),
                                       float(w[i]), float(c[i])))
    by_dst = [[] for _ in range(g.n)]
    for v in range(g.n):
        by_dst[v].append((v, []))           # trivial path ⟨v,v⟩ (head=v)
        stack = [(v, [])]
        while stack:
            u, path = stack.pop()
            if len(path) >= max_len:
                continue
            for e in out_edges[u]:
                p2 = path + [e]
                by_dst[e[1]].append((v, p2))
                stack.append((e[1], p2))
    return by_dst


def _path_value(f: PathFn, head: int, path):
    n = f.trivial(head)
    for e in path:
        n = f.extend(n, e)
    return n


def _paths_for(pathset, all_paths_to_v, v):
    """Filter/restrict the candidate (head, path) list per the path-set term."""
    if isinstance(pathset, AllPaths):
        ps = all_paths_to_v
        if pathset.source is not None:
            ps = [(h, p) for (h, p) in ps if h == pathset.source]
            # C(⟨v,v⟩) = (head = s): trivial path only counts at the source
        return ps
    if isinstance(pathset, ArgsRestrict):
        inner = _paths_for(pathset.inner, all_paths_to_v, v)
        if not inner:
            return []
        vals = [_path_value(pathset.f, h, p) for (h, p) in inner]
        best = min(vals) if pathset.r == "min" else max(vals)
        return [hp for hp, val in zip(inner, vals) if val == best]
    raise TypeError(pathset)


def paths_semantics(term: Term, g: Graph, max_len: Optional[int] = None,
                    scalars: Optional[dict] = None):
    """⟦term⟧ by explicit enumeration of paths with length ≤ max_len
    (Def. 6; with max_len ≥ longest simple path this equals Def. 5 whenever
    the termination condition C10 holds)."""
    if max_len is None:
        max_len = g.n
    scalars = scalars or {}
    by_dst = _enum_paths(g, max_len)

    def eval_m(t):
        """m-term → np array of per-vertex values (reduce-identity = ⊥)."""
        if isinstance(t, PathReduce):
            out = np.full(g.n, reduce_identity(t.r), dtype=object)
            for v in range(g.n):
                acc = reduce_identity(t.r)
                for (h, p) in _paths_for(t.paths, by_dst[v], v):
                    acc = reduce_op(t.r, acc, _path_value(t.f, h, p))
                out[v] = acc
            return out
        if isinstance(t, PathSel):
            # lexicographic: best f_sel, tie-broken reduction of f by r
            out = np.full(g.n, reduce_identity(t.r), dtype=object)
            for v in range(g.n):
                cands = _paths_for(ArgsRestrict(t.r, t.f_sel, t.paths),
                                   by_dst[v], v)
                if not cands:
                    out[v] = reduce_identity("min")
                    continue
                acc = reduce_identity("min")
                for (h, p) in cands:
                    acc = reduce_op("min", acc, _path_value(t.f, h, p))
                out[v] = acc
            return out
        if isinstance(t, Cardinality):
            return eval_m(PathReduce("sum", ONE, t.paths))
        if isinstance(t, MBin):
            a, b = eval_m(t.a), eval_m(t.b)
            return np.array([reduce_op(t.op, x, y) if t.op in ("min", "max")
                             else _arith(t.op, x, y) for x, y in zip(a, b)],
                            dtype=object)
        if isinstance(t, MConst):
            return np.full(g.n, t.val, dtype=object)
        if isinstance(t, ScalarRef):
            return np.full(g.n, scalars[t.name], dtype=object)
        raise TypeError(t)

    def eval_r(t):
        if isinstance(t, VertexReduce):
            vals = eval_m(t.m)
            mask = np.ones(g.n, dtype=bool)
            if t.cond is not None:
                mask = np.array([bool(x) for x in eval_m(t.cond)])
            if t.r == "collect":
                return mask
            acc = reduce_identity(t.r)
            for v in range(g.n):
                # C6: ⊥ (identity / unreachable sentinel) is excluded.
                x = vals[v]
                is_bot = (isinstance(x, (int, float)) and
                          (x != x or abs(float(x)) >= 1e8))
                if mask[v] and not is_bot:
                    acc = reduce_op(t.r, acc, x)
            return acc
        if isinstance(t, RBin):
            a, b = eval_r(t.a), eval_r(t.b)
            return reduce_op(t.op, a, b) if t.op in ("min", "max") else _arith(t.op, a, b)
        if isinstance(t, RConst):
            return t.val
        if isinstance(t, ScalarRef):
            return scalars[t.name]
        if isinstance(t, LetRound):
            val = eval_r(t.bound)
            inner = dict(scalars)
            inner[t.name] = val
            return paths_semantics(t.body, g, max_len, inner)
        raise TypeError(t)

    if isinstance(term, (VertexReduce, RBin, RConst, LetRound)):
        return eval_r(term)
    return eval_m(term)


def _arith(op, a, b):
    """IEEE float semantics, matching the engines exactly: x/0 = ±inf,
    ±inf/±inf = nan (⊥-like results on unreachable vertices compare equal
    after the test-side sentinel normalization)."""
    if op == "+":
        return a + b
    if op == "-":
        return a - b
    if op == "*":
        return a * b
    if op == "/":
        with np.errstate(divide="ignore", invalid="ignore"):
            return float(np.float64(a) / np.float64(b))
    if op == ">=":
        return a >= b
    if op == "<=":
        return a <= b
    raise ValueError(op)
