"""The kernel-function expression language (paper Fig. 4a).

Bodies of the iteration kernel functions I, P, R, E are expressions over a
small typed grammar.  The same grammar drives (a) type-guided enumerative
synthesis (§5.2), (b) evaluation as JAX-traceable closures inside the
iteration engines, and (c) pretty-printing ("code generation" for the
backends).

Environment names available to expressions:
  n        current propagated value                (type = value type T)
  v        vertex id (init function)               VERT
  s        source vertex id (init function)        VERT
  w        weight(e)                               FLT
  c        capacity(e)                             FLT
  esrc     src(e)                                  VERT
  edst     dst(e)                                  VERT
  outdeg   outdeg(src(e))                          FLT (for PageRank-style P)
  indeg    indeg(dst(e))                           FLT
  nv       |V|                                     FLT
  bot      ⊥ of the value type                     T
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable

import jax.numpy as jnp
import numpy as np

INT, FLT, BOOL, VERT = "int", "float", "bool", "vert"


@dataclasses.dataclass(frozen=True)
class Expr:
    pass


@dataclasses.dataclass(frozen=True)
class Lit(Expr):
    val: float
    ty: str

    def __str__(self):
        return str(self.val)


@dataclasses.dataclass(frozen=True)
class Var(Expr):
    name: str
    ty: str

    def __str__(self):
        return self.name


@dataclasses.dataclass(frozen=True)
class Bin(Expr):
    op: str  # + - * / min max == < >
    a: Expr
    b: Expr

    def __str__(self):
        if self.op in ("min", "max"):
            return f"{self.op}({self.a}, {self.b})"
        return f"({self.a} {self.op} {self.b})"


@dataclasses.dataclass(frozen=True)
class ITE(Expr):
    c: Expr
    a: Expr
    b: Expr

    def __str__(self):
        return f"(if {self.c} then {self.a} else {self.b})"


_BIN_FNS = {
    "+": lambda a, b, xp: a + b,
    "-": lambda a, b, xp: a - b,
    "*": lambda a, b, xp: a * b,
    "/": lambda a, b, xp: a / b,
    "min": lambda a, b, xp: xp.minimum(a, b),
    "max": lambda a, b, xp: xp.maximum(a, b),
    "==": lambda a, b, xp: a == b,
    "<": lambda a, b, xp: a < b,
    ">": lambda a, b, xp: a > b,
    "<=": lambda a, b, xp: a <= b,
    ">=": lambda a, b, xp: a >= b,
}


def eval_expr(e: Expr, env: dict, xp=jnp):
    if isinstance(e, Lit):
        return e.val
    if isinstance(e, Var):
        return env[e.name]
    if isinstance(e, Bin):
        return _BIN_FNS[e.op](eval_expr(e.a, env, xp), eval_expr(e.b, env, xp), xp)
    if isinstance(e, ITE):
        c = eval_expr(e.c, env, xp)
        a, b = eval_expr(e.a, env, xp), eval_expr(e.b, env, xp)
        return xp.where(c, a, b) if xp is jnp else np.where(c, a, b)
    raise TypeError(e)


def compile_expr(e: Expr) -> Callable[[dict], object]:
    """Expr → JAX-traceable closure over an env of arrays/scalars."""
    return lambda env: eval_expr(e, env, jnp)


def expr_size(e: Expr) -> int:
    if isinstance(e, (Lit, Var)):
        return 1
    if isinstance(e, Bin):
        return 1 + expr_size(e.a) + expr_size(e.b)
    if isinstance(e, ITE):
        return 1 + expr_size(e.c) + expr_size(e.a) + expr_size(e.b)
    raise TypeError(e)


# ---------------------------------------------------------------------------
# Type-guided enumerative search (§5.2): expressions of a requested type in
# order of increasing size, memoized per (type, size).
# ---------------------------------------------------------------------------

_ARITH_OPS = ("+", "-", "min", "max", "*", "/")
_NUM = (INT, FLT)


class Enumerator:
    def __init__(self, terminals):
        """terminals: list[Expr] (Vars and Lits available in this context)."""
        self.terminals = list(terminals)
        self._memo: dict = {}

    def of(self, ty: str, size: int):
        """All expressions of type `ty` with exactly `size` AST nodes."""
        key = (ty, size)
        if key in self._memo:
            return self._memo[key]
        out = []
        if size == 1:
            out = [t for t in self.terminals
                   if t.ty == ty or (ty == FLT and t.ty == INT)]
        else:
            if ty in _NUM:
                for op in _ARITH_OPS:
                    # int expressions stay int-typed; '/' only for floats
                    if op == "/" and ty == INT:
                        continue
                    for sa in range(1, size - 1):
                        for a in self.of(ty, sa):
                            for b in self.of(ty, size - 1 - sa):
                                out.append(Bin(op, a, b))
            if ty == VERT and size >= 1:
                pass  # vertex-typed exprs are terminals only (ids aren't arithmetic)
            if ty == BOOL:
                for op in ("==", "<"):
                    for base_ty in (INT, FLT, VERT):
                        for sa in range(1, size - 1):
                            for a in self.of(base_ty, sa):
                                for b in self.of(base_ty, size - 1 - sa):
                                    out.append(Bin(op, a, b))
        self._memo[key] = out
        return out

    def upto(self, ty: str, max_size: int):
        for k in range(1, max_size + 1):
            yield from self.of(ty, k)


def default_terminals(value_ty: str, for_init: bool = False):
    """Terminal set for synthesizing P (or I when for_init)."""
    ts = [Lit(0, INT), Lit(1, INT)]
    if for_init:
        ts += [Var("v", VERT), Var("s", VERT)]
    else:
        ts += [Var("n", value_ty), Var("w", FLT), Var("c", FLT),
               Var("esrc", VERT), Var("edst", VERT), Var("outdeg", FLT),
               Var("nv", FLT)]
    return ts
