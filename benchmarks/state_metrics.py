"""Paper Table 2: per-vertex/per-edge state sizes and atomic-op counts.

TPU adaptation: "atomics per edge" becomes scatter/segment ops per edge
sweep — counted from the jitted iteration HLO; state bytes come from the
fused component dtypes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import engine, fusion, iterate
from repro.core import usecases as U
from repro.core.synthesis import synthesize_round
from repro.graph.structure import uniform_graph, undirected

CASES = ["BFS", "CC", "SSSP", "WP", "WSP", "NSP", "NWR", "Trust"]


def _hlo_scatter_ops(g, round_, model):
    synth = synthesize_round(round_)
    comps = iterate.comp_runtimes(
        round_, {k: v for k, v in synth.items() if not isinstance(k, tuple)})
    plans = [leaf.plan for leaf in round_.leaves]

    def one_iter():
        return iterate.iterate_graph(g, comps, plans, model=model,
                                     max_iter=1).state

    txt = jax.jit(one_iter).lower().compile().as_text()
    return txt.count(" scatter(") + txt.count(" scatter-"), \
        txt.count("segment") + txt.count(" reduce(")


def run():
    g = uniform_graph(64, 256, seed=5)
    rows = []
    for name in CASES:
        spec = U.ALL_SPECS[name]()
        gg = undirected(g) if name == "CC" else g
        prog = fusion.fuse(spec)
        round_ = prog.rounds[0][1]
        vertex_bytes = 0
        for comp in round_.components:
            vertex_bytes += jnp.dtype(
                iterate.DTYPES[comp.f.dtype]).itemsize
        edge_bytes = 4 * any(c.f.kind in ("weight",)
                             for c in round_.components) + \
            4 * any(c.f.kind == "capacity" for c in round_.components)
        scat_push, _ = _hlo_scatter_ops(gg, round_, "push+")
        _, red_pull = _hlo_scatter_ops(gg, round_, "pull+")
        rows.append([name, len(round_.components), vertex_bytes, edge_bytes,
                     scat_push, red_pull])
    return emit(rows, ["usecase", "components", "vertex_bytes", "edge_bytes",
                       "push_scatter_ops", "pull_reduce_ops"])


if __name__ == "__main__":
    run()
