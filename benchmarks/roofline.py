"""Roofline derivation from the dry-run artifacts (deliverable g).

Per (arch × shape × mesh):

  compute term    = HLO_FLOPs_total / (chips × peak)        [s]
  memory term     = HLO_bytes_total / (chips × HBM_bw)      [s]
  collective term = collective_bytes_per_chip / link_bw     [s]

Sources: FLOPs/bytes from the UNROLLED analysis lowering (exact — XLA's
cost_analysis counts while bodies once, so the production scan module
undercounts by the trip count; see launch/dryrun.py).  Collective bytes
are parsed from the post-SPMD compiled HLO with while-trip weighting; those
operand sizes are already per-device, so the per-chip time divides by
link_bw only (equivalently: total moved = per_chip × chips, then the
assignment formula's /(chips × link_bw) — same number, stated explicitly
to avoid double division).

Hardware (TPU v5e-class target): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import glob
import json
import os

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k",
               "full_graph_sm", "minibatch_lg", "ogb_products", "molecule",
               "train_batch", "serve_p99", "serve_bulk", "retrieval_cand"]


def load_cells(report_dir="reports/dryrun", mesh="pod16x16"):
    cells = []
    for path in sorted(glob.glob(os.path.join(report_dir, mesh, "*.json"))):
        with open(path) as f:
            cells.append(json.load(f))
    return cells


def derive(rec) -> dict:
    if rec.get("status") != "ok":
        return {"arch": rec["arch"], "shape": rec["shape"],
                "status": rec.get("status"),
                "note": rec.get("skip_reason", rec.get("error", ""))[:90]}
    chips = rec["devices"]
    an = rec.get("analysis_cost", {})
    flops_total = an.get("flops")
    bytes_total = an.get("bytes accessed")
    if flops_total is None or "error" in an:
        # fall back to the compiled (scan-undercounted) per-device numbers
        flops_total = rec["cost_analysis"].get("flops", 0) * chips
        bytes_total = rec["cost_analysis"].get("bytes accessed", 0) * chips
    coll_per_chip = sum(v["operand_bytes"]
                        for v in rec["collectives"].values())
    t_compute = flops_total / (chips * PEAK_FLOPS)
    t_memory = bytes_total / (chips * HBM_BW)
    t_coll = coll_per_chip / LINK_BW
    dom = max((t_compute, "compute"), (t_memory, "memory"),
              (t_coll, "collective"))[1]
    model_flops = rec["meta"].get("model_flops", 0)
    out = {
        "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
        "kind": rec.get("kind"),
        "chips": chips,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll, "dominant": dom,
        "hlo_flops_total": flops_total,
        "hlo_bytes_total": bytes_total,
        "collective_bytes_per_chip": coll_per_chip,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / flops_total)
        if flops_total else 0.0,
        "temp_bytes_per_chip": rec["memory_analysis"].get(
            "temp_size_in_bytes", 0),
        "arg_bytes_per_chip": rec["memory_analysis"].get(
            "argument_size_in_bytes", 0),
    }
    # roofline fraction: useful model FLOP/s achieved if the step ran at
    # the max of the three terms
    t_bound = max(t_compute, t_memory, t_coll)
    out["roofline_frac"] = (model_flops / (chips * PEAK_FLOPS)) / t_bound \
        if t_bound > 0 else 0.0
    return out


def table(mesh="pod16x16", report_dir="reports/dryrun"):
    rows = []
    for rec in load_cells(report_dir, mesh):
        rows.append(derive(rec))
    rows.sort(key=lambda r: (r["arch"],
                             SHAPE_ORDER.index(r["shape"])
                             if r["shape"] in SHAPE_ORDER else 99))
    return rows


def main():
    for mesh in ("pod16x16", "pod2x16x16"):
        rows = table(mesh)
        if not rows:
            continue
        print(f"\n== roofline ({mesh}) ==")
        hdr = ["arch", "shape", "dom", "t_comp(s)", "t_mem(s)", "t_coll(s)",
               "useful%", "roofline%", "temp_GB/chip"]
        print(",".join(hdr))
        for r in rows:
            if r.get("status") != "ok":
                print(f"{r['arch']},{r['shape']},{r.get('status')},"
                      f"{r.get('note', '')}")
                continue
            print(",".join([
                r["arch"], r["shape"], r["dominant"],
                f"{r['t_compute_s']:.4f}", f"{r['t_memory_s']:.4f}",
                f"{r['t_collective_s']:.4f}",
                f"{100 * r['useful_flops_frac']:.1f}",
                f"{100 * r['roofline_frac']:.1f}",
                f"{r['temp_bytes_per_chip'] / 1e9:.1f}"]))


if __name__ == "__main__":
    main()
