"""Paper Fig. 11 + Table 1: synthesized vs handwritten programs.

Metrics: edge-work ratio (number of edge propagations, synthesized ÷
handwritten — the paper's primary metric, size-independent) and wall time,
for BFS / CC / SSSP / WP / PR across the engines.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import BENCH_GRAPHS, emit, timed
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph.structure import undirected

ENGINES = ["pull", "push", "dense"]


def run(graph_names=("RM-S",), engines=ENGINES):
    rows = []
    for gname in graph_names:
        g = BENCH_GRAPHS[gname](True)
        gu = undirected(g)
        cases = [
            ("BFS", U.bfs_depth(0), U.HANDWRITTEN["BFS"], g),
            ("CC", U.cc(), U.HANDWRITTEN["CC"], gu),
            ("SSSP", U.sssp(0), U.HANDWRITTEN["SSSP"], g),
            ("WP", U.wp(0), U.HANDWRITTEN["WP"], g),
        ]
        for eng in engines:
            if eng == "dense" and g.n > 4000:
                continue
            for name, spec, hand, gg in cases:
                prog = fusion.fuse(spec)
                t_s, res_s = timed(lambda: engine.run_program(
                    gg, prog, engine=eng), repeats=3)
                t_h, res_h = timed(lambda: engine.run_direct(
                    gg, hand(), engine=eng), repeats=3)
                # correctness cross-check while we're here
                a = np.asarray(res_s.value, np.float64)
                b = np.asarray(res_h.value, np.float64)
                a = np.where(np.abs(a) >= 1e8, np.inf, a)
                b = np.where(np.abs(b) >= 1e8, np.inf, b)
                assert np.allclose(np.nan_to_num(a, posinf=1e9),
                                   np.nan_to_num(b, posinf=1e9),
                                   atol=1e-3), (name, eng)
                ew_ratio = res_s.stats.edge_work / max(res_h.stats.edge_work,
                                                       1.0)
                rows.append([gname, eng, name,
                             round(ew_ratio, 4),
                             round(res_s.stats.edge_work),
                             round(t_h / max(t_s, 1e-9), 3),
                             round(t_s * 1e3, 1), round(t_h * 1e3, 1)])
            # PR: handwritten only (paper has no synthesized PR — spec
            # language has no damped-path F; see DESIGN.md)
            from repro.core.synthesis import pagerank_kernels
            dk = pagerank_kernels(gu.n, tol=1e-6, max_iter=100)
            t_h, res_h = timed(lambda: engine.run_direct(gu, dk, engine=eng),
                               repeats=3)
            rows.append([gname, eng, "PR", "-", round(res_h.stats.edge_work),
                         "-", "-", round(t_h * 1e3, 1)])
    return emit(rows, ["graph", "engine", "usecase", "edge_work_ratio",
                       "edge_work_synth", "speedup_H_over_S",
                       "t_synth_ms", "t_hand_ms"])


if __name__ == "__main__":
    run()
