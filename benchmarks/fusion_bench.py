"""Paper Fig. 13 (WSP/NWR/RADIUS) + Fig. 14/Table 3 (DRR/Trust/RDS):
fused vs unfused edge-work ratio and wall time, weighted and unweighted
graphs — including the direction-optimized pallas engine with kernel-launch
counting and push/pull direction accounting.

For the pallas engine extra columns track the execution layer (DESIGN.md
§2/§7): ``launches`` is the number of ``pallas_call``s appearing in the
traced program per engine iteration (a direction-optimized round traces one
pull and one push sweep; exactly one executes per iteration), ``push/pull``
the runtime per-direction iteration counts, and ``seed_sweeps`` the
per-iteration sweep count of the pre-fusion execution model (one launch per
lex level per plan, plus one has-pred probe per component on pull− rounds).

``--engines pallas`` additionally benchmarks the direction switch itself on
the frontier workloads (BFS/SSSP): total edge work and sweep executions of
the adaptive engine vs the pull-only engine — the quantity the
direction-optimized engine must keep ≤ pull — and writes machine-readable
``BENCH_pallas.json`` so the perf trajectory is tracked across PRs.

``--engines pallas`` also runs the push-resolution section (DESIGN.md §10):
the adaptive engine with the dst-sorted segment resolution vs the
reference full-rectangle scatter on the frontier workloads — resolution
edge work (Σ nnz of the resolution tiles actually processed vs
push_iters·rectangle), traced launches per class, and wall time.  The
gated property is frontier-proportionality: sorted resolution work must
stay strictly under the scatter rectangle whenever push iterations ran,
the sorted/scatter work ratio must not regress vs the baseline, and the
in-kernel permutation gather must move frontier-proportional bytes —
``gather_work`` strictly under ``push_iters · n_pad · width`` with the
scatter path reporting exactly 0 (it performs no permutation gather).

``--engines pallas`` also runs the batched-throughput section (DESIGN.md
§9): a B-source sweep of one query shape served sequentially (the source
is a traced executor argument, so the sweep must hold ONE executor-cache
entry and re-trace nothing after the first query) against one
``run_program_batch`` vmapped launch (B queries per launch).  Gated
quantities: executor-cache entries of the sequential sweep (the
retrace-per-source regression this section exists for) and traced launch
counts, never wall time.

``--engines pallas`` also runs the sharded section (DESIGN.md §11) when the
process has ≥ 2 devices (CI forces host devices via XLA_FLAGS): the
``pallas_sharded`` engine on a 2-shard mesh vs the single-device engine on
BFS/SSSP/PageRank — per-shard edge work and traced launches, cross-shard
combine counts, and the compositional invariant that the global direction
switch keeps the sharded fixpoint on the single-device iteration sequence
(values bitwise-equal for the idempotent workloads, asserted in-bench).
The section also compares the sharded engine's default per-shard sorted
resolution against the per-shard scatter oracle: both must agree on
values, and sorted resolve work must stay strictly under the scatter
rectangle whenever push iterations ran.

``--engines pallas`` also runs the guard-overhead section (DESIGN.md §12):
default guarded execution (validation, termination precondition, divergence
sentinel, convergence check) vs guards-off on BFS/SSSP/PageRank.  The
guards are free at the fixpoint level, so the gated quantities are
deterministic: bitwise values, identical iterations/edge work, and traced
launches guarded ≤ guards-off.

``--engines pallas`` also runs the serving section (DESIGN.md §13): the
continuous-batching analytics service (``repro.launch.service``) driven by
a seeded open-loop arrival trace — mixed BFS/SSSP sweep queries through
the fixed-slot chunked batch lanes plus scalar radius/drr queries paired
via ``fusion.fuse_many``.  The scheduler runs on a virtual clock, so the
serving metrics (queries-per-launch, batch occupancy, launch/fused-round
counts, executor-cache entries, virtual p50/p99 latency and queries/sec)
are a deterministic function of the seed; every served answer is asserted
bitwise-equal to a solo ``run_program`` in-bench.  Wall-clock latency is
reported, never gated.

``--engines pallas`` also runs the planner section (DESIGN.md §14): the
query planner's default ``ExecutionPlan`` vs the same knobs pinned
explicitly (the historical kwarg surface) on BFS/SSSP/PageRank.  The gated
properties are deterministic: planned and pinned runs must produce
bitwise-identical values with identical iteration counts and edge work
(default plans reproduce the documented heuristics exactly), the planner
must add ZERO traced launches and zero executor-cache entries (planning is
a host-side cache lookup, invisible to the compiled program), and the
recorded-stats feedback cache must hold an entry per benched query shape.

``--engines pallas`` also runs the incremental section (DESIGN.md §15):
a small seeded insert-only perturbation (~0.5% of |E|) of the R-MAT graph,
then the delta-seeded warm-started fixpoint vs a cold full recompute on
the mutated graph, on the idempotent workloads (BFS/SSSP/CC).  Everything
gated is deterministic on the seeded trace: the answers must be
bitwise-equal (asserted in-bench — GraFS Def. 2 makes warm+delta exact for
idempotent insert-only batches), delta edge work must stay strictly under
the full recompute's, the planner must resolve ``incremental="delta"`` for
the small batch, and the patch-vs-rebuild layout counts are recorded so
the baseline gates the in-place ELL patch staying engaged.  Wall time is
reported, never gated.

``--baseline PATH`` reads a committed ``BENCH_pallas.json`` (before the
fresh run, which is never written over it) and fails (exit 1) if the fresh
run regresses on traced launches, the fused/unfused edge-work ratio, the
push-vs-pull work advantage, the resolution section's gather/resolve-work
bounds, the batched executor/retrace counts, the sharded engine's
iteration parity / launch / combine / resolution-work counts, the guard
section's launch parity, the serving section's queries-per-launch /
launch / fused-round / cache-entry counts, or the incremental section's
delta-vs-full edge-work ratio and patch-vs-rebuild layout counts — the one
comparison path shared by the CI bench-smoke gate and local runs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):           # `python benchmarks/fusion_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    try:
        import repro                    # noqa: F401  (pip install -e .)
    except ImportError:                 # fall back to the source tree
        sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.common import BENCH_GRAPHS, emit, timed
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.core.iterate import plan_idempotent
from repro.kernels.ops import _plan_levels

SIMPLE = ["WSP", "NWR", "RADIUS"]
MULTI = ["DRR", "Trust", "RDS"]
DIRECTION = ["BFS", "SSSP"]             # sparse-frontier direction workloads
RESOLUTION = ["BFS", "SSSP"]            # push-resolution (sorted vs scatter)
BATCHED = ["BFS", "SSSP"]               # single-source batched-query sweeps
SHARDED = ["BFS", "SSSP", "PR"]         # shard_map composition (PR = direct
                                        # PageRank, the epilogue pull− round)
GUARDED = ["BFS", "SSSP", "PR"]         # guarded vs guards-off execution
                                        # (validation + divergence sentinel)
SERVING = ["MIX"]                       # open-loop serving traces (the MIX
                                        # trace: BFS/SSSP sweeps + fused
                                        # radius/drr scalars)
PLANNER = ["BFS", "SSSP", "PR"]         # planned vs pinned-knob execution
                                        # (the ExecutionPlan default-parity
                                        # and zero-overhead contract)
INCREMENTAL = ["BFS", "SSSP", "CC"]     # delta-vs-full over a mutating
                                        # graph (idempotent rounds only:
                                        # bitwise parity is the contract)
_BATCHED_SPECS = {"BFS": U.bfs, "SSSP": U.sssp}
_BATCH_B = 8                            # sources per batched sweep
_SERVE_B = 6                            # continuous-batch slots per lane
_SERVE_CHUNK = 4                        # fixpoint iterations per launch
_SERVE_REQUESTS = 16                    # open-loop trace length
_SERVE_SEED = 0
_SHARD_K = 2                            # shards of the sharded section's mesh
_INCR_SEED = 7                          # perturbation RNG seed of the
                                        # incremental section (deterministic)
_INCR_FRAC = 0.005                      # inserted edges as a fraction of |E|
                                        # — well under the planner's
                                        # INCREMENTAL_DELTA threshold

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_pallas.json")

# tolerance for ratio comparisons against the baseline: iteration counts and
# edge work are deterministic on the seeded graphs, but leave headroom for
# jax-version differences in while_loop/cond accounting
_BASELINE_RTOL = 0.05


def seed_sweeps_per_iter(prog) -> int:
    """Per-iteration edge-sweep count of the one-launch-per-level execution
    model the fused sweep replaced (summed over the program's iteration
    rounds)."""
    total = 0
    for _name, round_ in prog.rounds:
        if not round_.leaves:
            continue
        plans = [leaf.plan for leaf in round_.leaves]
        idempotent = all(plan_idempotent(p) for p in plans)
        for p in plans:
            levels = _plan_levels(p)
            total += len(levels)
            if not idempotent:
                total += len(levels)        # one has-pred probe per component
    return total


def pallas_run_stats(g, prog, model=None):
    """Cold-build the pallas executors, run once, and return (result, sweep
    stats): trace-time launch counts plus runtime direction counts."""
    from repro.kernels import edge_reduce as er
    engine.clear_program_caches()
    er.reset_sweep_stats()
    res = engine.run_program(g, prog, engine="pallas", model=model)
    return res, dict(er.SWEEP_STATS)


def bench_direction(g, gname: str, weighted: bool, name: str) -> dict:
    """Adaptive (direction-optimized) vs pull-only pallas on one frontier
    workload: the acceptance quantity is edge work and sweep executions of
    adaptive ≤ pull-only (DESIGN.md §2/§7)."""
    prog = fusion.fuse(U.ALL_SPECS[name]())
    res_auto, s_auto = pallas_run_stats(g, prog, model=None)
    res_pull, s_pull = pallas_run_stats(g, prog, model="pull")
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "iterations": res_auto.stats.iterations,
        "edge_work_auto": float(res_auto.stats.edge_work),
        "edge_work_pull": float(res_pull.stats.edge_work),
        "sweeps_auto": s_auto["pull_iters"] + s_auto["push_iters"],
        "sweeps_pull": s_pull["pull_iters"] + s_pull["push_iters"],
        "push_iters": s_auto["push_iters"],
        "pull_iters": s_auto["pull_iters"],
        "launches_traced_auto": s_auto["launches"],
        "launches_traced_pull": s_pull["launches"],
    }


def bench_resolution(g, gname: str, weighted: bool, name: str) -> dict:
    """Push-resolution section (DESIGN.md §10): the adaptive engine with the
    dst-sorted segment resolution vs the reference full-rectangle scatter on
    one sparse-frontier workload.  The acceptance quantities are RESOLUTION
    edge work — sorted must stay frontier-proportional (Σ nnz of the
    resolution tiles actually processed), strictly under the scatter path's
    `push_iters · n_pad · width` rectangle cost, with bit-identical values —
    and GATHER work: the candidate slots the in-kernel permutation gather
    reads, strictly under the full rectangle per push iteration (skipped
    tiles move zero bytes) and 0 under scatter (no permutation gather).
    Wall time is reported, never gated (interpret-mode CPU noise)."""
    from repro.graph.structure import push_resolution_cached
    from repro.kernels import edge_reduce as er
    prog = fusion.fuse(U.ALL_SPECS[name]())
    pres = push_resolution_cached(g)
    rectangle = float(pres.n_pad * pres.width)

    def one(resolution):
        engine.clear_program_caches()
        er.reset_sweep_stats()
        t, res = timed(lambda: engine.run_program(
            g, prog, engine="pallas", push_resolution=resolution), repeats=1)
        return t, res, dict(er.SWEEP_STATS)

    t_sorted, res_sorted, s_sorted = one("sorted")
    t_scatter, res_scatter, s_scatter = one("scatter")
    import numpy as np
    assert np.array_equal(np.asarray(res_sorted.value),
                          np.asarray(res_scatter.value)), \
        f"{name}: sorted resolution diverged from scatter"
    assert res_sorted.stats.push_iters == res_scatter.stats.push_iters
    # the section must actually exercise push resolution — if a heuristic
    # change stops these workloads pushing, fail loud instead of silently
    # gating nothing
    assert res_sorted.stats.push_iters >= 1, \
        f"{name}: no push iterations — resolution section is vacuous"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "push_iters": res_sorted.stats.push_iters,
        "num_edges": g.num_edges,
        "edge_work": float(res_sorted.stats.edge_work),
        "resolve_work_sorted": float(res_sorted.stats.resolve_work),
        "resolve_work_scatter": float(res_scatter.stats.resolve_work),
        "gather_work_sorted": float(res_sorted.stats.gather_work),
        "gather_work_scatter": float(res_scatter.stats.gather_work),
        "rectangle": rectangle,
        "resolve_launches": s_sorted["resolve_launches"],
        "launches_traced_sorted": s_sorted["launches"],
        "launches_traced_scatter": s_scatter["launches"],
        "t_sorted_ms": t_sorted * 1e3, "t_scatter_ms": t_scatter * 1e3,
    }


def bench_batched(g, gname: str, weighted: bool, name: str,
                  batch: int = _BATCH_B) -> dict:
    """Batched-throughput section (DESIGN.md §9): B single-source queries of
    one shape, sequential (source as traced executor argument) vs one
    vmapped launch.  The gated quantities are the executor-cache entry count
    and traced launches of the sequential sweep — the per-source-retrace
    regression this PR class exists to prevent — plus the batched launch
    count (B queries : 1 executor)."""
    from repro.kernels import edge_reduce as er
    from repro.kernels import ops as kops
    spec_fn = _BATCHED_SPECS[name]
    srcs = list(range(min(batch, g.n)))
    prog = fusion.fuse(spec_fn(srcs[0]))

    def seq():
        # fresh spec per source: the exact shape that used to retrace
        return [engine.run_program(g, fusion.fuse(spec_fn(s)),
                                   engine="pallas") for s in srcs]

    engine.clear_program_caches()
    er.reset_sweep_stats()
    res_seq = seq()
    exec_seq = kops.executor_cache_size()
    launches_seq = er.SWEEP_STATS["launches"]       # trace-time = retraces
    t_seq, _ = timed(seq, repeats=1)

    def bat():
        return engine.run_program_batch(g, prog, sources=srcs,
                                        engine="pallas")

    engine.clear_program_caches()
    er.reset_sweep_stats()
    res_bat = bat()
    exec_bat = kops.executor_cache_size()
    launches_bat = er.SWEEP_STATS["launches"]
    t_bat, _ = timed(bat, repeats=1)
    assert all(int(a.stats.iterations) == int(b.stats.iterations)
               for a, b in zip(res_seq, res_bat))
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "batch": len(srcs),
        "exec_entries_seq": exec_seq,
        "exec_entries_batched": exec_bat,
        "launches_traced_seq": launches_seq,
        "launches_traced_batched": launches_bat,
        "t_seq_ms": t_seq * 1e3, "t_batched_ms": t_bat * 1e3,
        "queries_per_launch": len(srcs) / max(launches_bat, 1),
    }


def bench_sharded(g, gname: str, weighted: bool, name: str,
                  k: int = _SHARD_K):
    """Sharded section (DESIGN.md §11): ``pallas_sharded`` on a k-shard mesh
    vs the single-device pallas engine on one workload.  The acceptance
    quantities are compositional: the sharded run must take the SAME
    iteration sequence (the global direction switch — gated via iteration +
    push-iteration parity for the idempotent frontier workloads), its values
    must match (bitwise when idempotent, allclose for the float-sum PR round
    — asserted here, in-bench), and per-shard traced launches / cross-shard
    combine counts must not grow vs the baseline.  The sharded push sweep
    resolves through its per-shard sorted stack by default — the section
    also runs the per-shard scatter oracle and records both resolve/gather
    works so the baseline gates the sharded sorted resolve strictly under
    the per-shard scatter rectangle.  Wall time is reported, never gated.
    Returns None (section skipped) when the process has fewer than k
    devices — CI forces host devices via XLA_FLAGS."""
    import jax
    import numpy as np
    if len(jax.devices()) < k:
        return None
    from jax.sharding import Mesh

    from repro.kernels import edge_reduce as er
    mesh = Mesh(np.asarray(jax.devices()[:k]), ("data",))
    idempotent = name != "PR"

    def one(eng, **kw):
        engine.clear_program_caches()
        er.reset_sweep_stats()
        if name == "PR":
            dk = U.handwritten_pagerank(g.n)
            t, res = timed(lambda: engine.run_direct(
                g, dk, engine=eng, mesh=mesh, **kw), repeats=1)
        else:
            prog = fusion.fuse(U.ALL_SPECS[name]())
            t, res = timed(lambda: engine.run_program(
                g, prog, engine=eng, mesh=mesh, **kw), repeats=1)
        return t, res, dict(er.SWEEP_STATS)

    t_s, res_s, stats_s = one("pallas_sharded")
    _, res_sc, _ = one("pallas_sharded", push_resolution="scatter")
    t_1, res_1, stats_1 = one("pallas")
    res_match = (np.array_equal if idempotent else
                 lambda a, b: np.allclose(a, b, atol=1e-5))
    assert res_match(np.asarray(res_s.value), np.asarray(res_sc.value)), \
        f"{name}: sharded sorted resolution diverged from sharded scatter"
    v_s, v_1 = np.asarray(res_s.value), np.asarray(res_1.value)
    if idempotent:
        assert np.array_equal(v_1, v_s), \
            f"{name}: sharded diverged from single-device (bitwise)"
        assert res_s.stats.iterations == res_1.stats.iterations and \
            res_s.stats.push_iters == res_1.stats.push_iters, \
            f"{name}: sharded iteration sequence diverged " \
            f"({res_s.stats.iterations}/{res_s.stats.push_iters} vs " \
            f"{res_1.stats.iterations}/{res_1.stats.push_iters})"
    else:
        assert np.allclose(v_1, v_s, atol=1e-5), \
            f"{name}: sharded PR diverged beyond allclose"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "shards": k, "idempotent": idempotent,
        "iterations_sharded": res_s.stats.iterations,
        "iterations_single": res_1.stats.iterations,
        "push_iters_sharded": res_s.stats.push_iters,
        "edge_work_sharded": float(res_s.stats.edge_work),
        "edge_work_single": float(res_1.stats.edge_work),
        # per-shard resolution stack vs the per-shard scatter oracle
        "resolve_work_sharded_sorted": float(res_s.stats.resolve_work),
        "resolve_work_sharded_scatter": float(res_sc.stats.resolve_work),
        "gather_work_sharded": float(res_s.stats.gather_work),
        "shard_work": list(res_s.stats.shard_work),
        # SPMD traces the shard body once, so trace-time sweep counts ARE
        # per-shard launches (one per direction branch per round)
        "shard_launches_traced": stats_s["launches"],
        "launches_traced_single": stats_1["launches"],
        "cross_combines": res_s.stats.cross_combines,
        "t_sharded_ms": t_s * 1e3, "t_single_ms": t_1 * 1e3,
    }


def bench_guard(g, gname: str, weighted: bool, name: str) -> dict:
    """Guard-overhead section (DESIGN.md §12): the default guarded execution
    (graph validation + termination precondition + divergence sentinel +
    convergence check) vs guards-off on one workload.  The guards are
    designed to be free at the fixpoint level — the sentinel folds into the
    existing convergence reduction, validation is a cached host-side pass —
    so the acceptance quantities are DETERMINISTIC equalities: bitwise
    values, identical iteration counts and edge work, and traced launches
    guarded ≤ guards-off (asserted in-bench; launches also gated vs the
    committed baseline).  Wall time is reported, never gated."""
    import numpy as np

    from repro.kernels import edge_reduce as er

    def one(guarded):
        engine.clear_program_caches()
        er.reset_sweep_stats()
        off = dict(validate=False, divergence_sentinel=False,
                   on_nonconverge="ignore")
        kw = {} if guarded else off
        if name == "PR":
            dk = U.handwritten_pagerank(g.n)
            t, res = timed(lambda: engine.run_direct(
                g, dk, engine="pallas", **kw), repeats=1)
        else:
            prog = fusion.fuse(U.ALL_SPECS[name]())
            t, res = timed(lambda: engine.run_program(
                g, prog, engine="pallas", **kw), repeats=1)
        return t, res, dict(er.SWEEP_STATS)

    t_on, res_on, s_on = one(True)
    t_off, res_off, s_off = one(False)
    assert np.array_equal(np.asarray(res_on.value),
                          np.asarray(res_off.value)), \
        f"{name}: guarded execution changed the computed values"
    assert res_on.stats.iterations == res_off.stats.iterations, \
        f"{name}: guards changed the iteration count " \
        f"({res_on.stats.iterations} vs {res_off.stats.iterations})"
    assert float(res_on.stats.edge_work) == float(res_off.stats.edge_work), \
        f"{name}: guards changed the edge work"
    assert s_on["launches"] <= s_off["launches"], \
        f"{name}: guards added traced launches " \
        f"({s_on['launches']} vs {s_off['launches']})"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "iterations": res_on.stats.iterations,
        "edge_work": float(res_on.stats.edge_work),
        "launches_traced_guarded": s_on["launches"],
        "launches_traced_off": s_off["launches"],
        "t_guarded_ms": t_on * 1e3, "t_off_ms": t_off * 1e3,
    }


def bench_serving(g, gname: str, weighted: bool, name: str) -> dict:
    """Serving section (DESIGN.md §13): the continuous-batching analytics
    service under a seeded open-loop arrival trace.  The scheduler's virtual
    clock makes every serving metric deterministic — queries-per-launch
    (the continuous-batching win: answers per compiled launch), batch
    occupancy, launch and fused-round counts, executor-cache entries, and
    the virtual p50/p99 latencies — and every served answer is asserted
    bitwise-equal to a solo ``run_program`` here, in-bench.  Only wall time
    is machine-dependent, and only wall time goes ungated."""
    from repro.kernels import edge_reduce as er
    from repro.kernels import ops as kops
    from repro.launch import service as S

    engine.clear_program_caches()
    er.reset_sweep_stats()
    cfg = S.ServiceConfig(engine="pallas", max_batch=_SERVE_B,
                          chunk_iters=_SERVE_CHUNK)
    svc = S.AnalyticsService(cfg)
    svc.add_graph(gname, g)
    svc.register("BFS", U.bfs)
    svc.register("SSSP", U.sssp)
    # arrival rate ~16× the per-chunk virtual service time: the whole trace
    # lands within the first launches, so batches fill and scalar requests
    # queue up to be paired (the bench measures batching under pressure,
    # not an idle service)
    rate = 16.0 / (cfg.launch_overhead_s + cfg.chunk_iters * cfg.iter_cost_s)
    arrivals = S.open_loop_arrivals(
        _SERVE_REQUESTS, rate=rate, seed=_SERVE_SEED,
        make_request=S.standard_mix(gname, g.n))
    m = svc.run_open_loop(arrivals)
    # capture the gated execution-layer counters BEFORE verification runs
    # its own solo programs
    launches = er.SWEEP_STATS["launches"]
    exec_entries = kops.executor_cache_size()
    assert m["completed"] == _SERVE_REQUESTS, \
        f"serving trace lost requests: {m['completed']}/{_SERVE_REQUESTS}"
    checked = S.verify_sequential(svc)
    assert checked == _SERVE_REQUESTS, \
        f"serving answers not bitwise-equal to solo runs ({checked} checked)"
    assert m["queries_per_launch"] > 1.0, \
        f"continuous batching did not batch: queries_per_launch = " \
        f"{m['queries_per_launch']}"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "requests": _SERVE_REQUESTS,
        "completed": m["completed"],
        "batch_launches": m["batch_launches"],
        "queries_per_launch": m["queries_per_launch"],
        "occupancy": m["occupancy"],
        "scalar_rounds": m["scalar_rounds"],
        "scalar_fused": m["scalar_fused"],
        "solo_runs": m["solo_runs"],
        "total_iterations": m["total_iterations"],
        "launches_traced": launches,
        "exec_entries": exec_entries,
        "v_p50_ms": m["v_p50_ms"], "v_p99_ms": m["v_p99_ms"],
        "v_qps": m["v_qps"],
        "t_wall_ms": m["wall_s"] * 1e3,
    }


def bench_planner(g, gname: str, weighted: bool, name: str) -> dict:
    """Planner section (DESIGN.md §14): the default ``ExecutionPlan`` vs the
    same decisions pinned through the explicit kwarg surface on one
    workload.  Default plans must reproduce the documented heuristics
    BITWISE (asserted here, in-bench: values, iterations, edge work), and
    planning must be invisible to the compiled program — zero extra traced
    launches, zero extra executor-cache entries (a plan is a host-side LRU
    lookup).  Wall time is reported, never gated."""
    import numpy as np

    from repro.core import plan as P
    from repro.kernels import edge_reduce as er
    from repro.kernels import ops as kops

    pinned_kw = dict(model=None, switch_k=20.0, push_resolution="sorted")

    def one(kw):
        engine.clear_program_caches()
        er.reset_sweep_stats()
        if name == "PR":
            dk = U.handwritten_pagerank(g.n)
            t, res = timed(lambda: engine.run_direct(
                g, dk, engine="pallas", **kw), repeats=1)
        else:
            prog = fusion.fuse(U.ALL_SPECS[name]())
            t, res = timed(lambda: engine.run_program(
                g, prog, engine="pallas", **kw), repeats=1)
        return t, res, dict(er.SWEEP_STATS), kops.executor_cache_size()

    t_plan, res_plan, s_plan, exec_plan = one({})
    t_pin, res_pin, s_pin, exec_pin = one(pinned_kw)
    assert np.array_equal(np.asarray(res_plan.value),
                          np.asarray(res_pin.value)), \
        f"{name}: planned execution diverged from pinned knobs"
    assert res_plan.stats.iterations == res_pin.stats.iterations, \
        f"{name}: planner changed the iteration count " \
        f"({res_plan.stats.iterations} vs {res_pin.stats.iterations})"
    assert float(res_plan.stats.edge_work) == \
        float(res_pin.stats.edge_work), \
        f"{name}: planner changed the edge work"
    assert res_plan.stats.plan is not None and \
        res_plan.stats.plan.engine == "pallas", \
        f"{name}: resolved plan missing from ExecStats"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "iterations": res_plan.stats.iterations,
        "edge_work": float(res_plan.stats.edge_work),
        "launches_traced_planned": s_plan["launches"],
        "launches_traced_pinned": s_pin["launches"],
        "exec_entries_planned": exec_plan,
        "exec_entries_pinned": exec_pin,
        "plan_entries": P.plan_cache_size(),
        "feedback_entries": P.feedback_cache_size(),
        "t_planned_ms": t_plan * 1e3, "t_pinned_ms": t_pin * 1e3,
    }


def bench_incremental(g, gname: str, weighted: bool, name: str) -> dict:
    """Incremental section (DESIGN.md §15): converge once cold on ``g``
    with ``return_state=True``, apply a small seeded insert-only
    perturbation (~0.5% of |E|) through ``mutate_edges``, then run the
    delta-seeded warm-started fixpoint vs a cold full recompute on the
    mutated graph.  The acceptance quantities are deterministic on the
    seeded trace: BITWISE value parity (asserted here, in-bench — the
    workloads are idempotent rounds, so warm+delta is exact for insert-only
    batches), delta edge work strictly under the full recompute's, the
    planner resolving ``incremental="delta"`` for the small batch, and the
    patch-vs-rebuild layout counts (the in-place ELL patch must keep
    absorbing the batch).  Wall time is reported, never gated."""
    import numpy as np

    from repro.graph import mutate as M

    prog = fusion.fuse(U.ALL_SPECS[name]())
    engine.clear_program_caches()
    _res_prev, state = engine.run_program(g, prog, engine="pallas",
                                          return_state=True)
    rng = np.random.default_rng(_INCR_SEED)
    k = max(2, int(g.num_edges * _INCR_FRAC))
    src = rng.integers(0, g.n, size=k)
    dst = rng.integers(0, g.n, size=k)
    ins = (src, dst, (0.1 + rng.random(k)).astype(np.float32)) if weighted \
        else (src, dst)
    g2, md = M.mutate_edges(g, insert=ins)
    t_delta, res_delta = timed(lambda: engine.run_program(
        g2, prog, engine="pallas", init_state=state, delta=md), repeats=1)
    t_full, res_full = timed(lambda: engine.run_program(
        g2, prog, engine="pallas"), repeats=1)
    assert np.array_equal(np.asarray(res_delta.value),
                          np.asarray(res_full.value)), \
        f"{name}: delta-mode answer diverged from the cold recompute"
    assert res_delta.stats.plan is not None and \
        res_delta.stats.plan.incremental == "delta", \
        f"{name}: planner did not choose delta propagation for a " \
        f"{k}-edge insert batch"
    assert float(res_delta.stats.edge_work) < \
        float(res_full.stats.edge_work), \
        f"{name}: delta edge work {float(res_delta.stats.edge_work):.0f} " \
        f"not under the full recompute's " \
        f"{float(res_full.stats.edge_work):.0f}"
    return {
        "graph": gname, "weighted": weighted, "usecase": name,
        "num_edges": g.num_edges, "inserted": int(md.inserted),
        "touched": int(md.touched.size),
        "plan_incremental": res_delta.stats.plan.incremental,
        "iterations_delta": res_delta.stats.iterations,
        "iterations_full": res_full.stats.iterations,
        "edge_work_delta": float(res_delta.stats.edge_work),
        "edge_work_full": float(res_full.stats.edge_work),
        "patched_layouts": int(md.patched_layouts),
        "rebuilt_layouts": int(md.rebuilt_layouts),
        "t_delta_ms": t_delta * 1e3, "t_full_ms": t_full * 1e3,
    }


def run(graph_names=("RM-S",), usecases=SIMPLE + MULTI,
        engines=("pull", "push"), json_out=None, direction_usecases=None,
        batched_usecases=None, resolution_usecases=None,
        sharded_usecases=None, guard_usecases=None, serving_usecases=None,
        planner_usecases=None, incremental_usecases=None):
    rows = []
    json_rows = []
    direction_rows = []
    batched_rows = []
    resolution_rows = []
    sharded_rows = []
    guard_rows = []
    serving_rows = []
    planner_rows = []
    incremental_rows = []
    if direction_usecases and "pallas" not in engines:
        raise ValueError("direction_usecases bench the pallas engine's "
                         "push/pull switch; add 'pallas' to engines")
    if batched_usecases and "pallas" not in engines:
        raise ValueError("batched_usecases bench the pallas engine's "
                         "vmapped executors; add 'pallas' to engines")
    if resolution_usecases and "pallas" not in engines:
        raise ValueError("resolution_usecases bench the pallas engine's "
                         "push resolution; add 'pallas' to engines")
    if sharded_usecases and "pallas" not in engines:
        raise ValueError("sharded_usecases bench the pallas_sharded "
                         "engine; add 'pallas' to engines")
    if guard_usecases and "pallas" not in engines:
        raise ValueError("guard_usecases bench the pallas engine's guarded "
                         "execution; add 'pallas' to engines")
    if serving_usecases and "pallas" not in engines:
        raise ValueError("serving_usecases bench the continuous-batching "
                         "service on the pallas engine; add 'pallas' to "
                         "engines")
    if planner_usecases and "pallas" not in engines:
        raise ValueError("planner_usecases bench the query planner on the "
                         "pallas engine; add 'pallas' to engines")
    if incremental_usecases and "pallas" not in engines:
        raise ValueError("incremental_usecases bench the pallas engine's "
                         "delta-seeded warm starts; add 'pallas' to engines")
    if direction_usecases is None:
        direction_usecases = DIRECTION if "pallas" in engines else []
    if batched_usecases is None:
        batched_usecases = BATCHED if "pallas" in engines else []
    if resolution_usecases is None:
        resolution_usecases = RESOLUTION if "pallas" in engines else []
    if sharded_usecases is None:
        sharded_usecases = SHARDED if "pallas" in engines else []
    if guard_usecases is None:
        guard_usecases = GUARDED if "pallas" in engines else []
    if serving_usecases is None:
        serving_usecases = SERVING if "pallas" in engines else []
    if planner_usecases is None:
        planner_usecases = PLANNER if "pallas" in engines else []
    if incremental_usecases is None:
        incremental_usecases = INCREMENTAL if "pallas" in engines else []
    for gname in graph_names:
        for weighted in (False, True):
            g = BENCH_GRAPHS[gname](weighted)
            for eng in engines:
                for name in usecases:
                    spec = U.ALL_SPECS[name]()
                    fprog = fusion.fuse(spec)
                    uprog = fusion.lower_unfused(spec)
                    launches = ""
                    if eng == "pallas":
                        _res, sweep = pallas_run_stats(g, fprog)
                        launches = sweep["launches"]
                    t_f, rf = timed(lambda: engine.run_program(
                        g, fprog, engine=eng), repeats=3)
                    t_u, ru = timed(lambda: engine.run_program(
                        g, uprog, engine=eng), repeats=3)
                    ratio = rf.stats.edge_work / max(ru.stats.edge_work, 1.0)
                    row = [gname, "w" if weighted else "unw", eng, name,
                           round(ratio, 4),
                           round(t_u / max(t_f, 1e-9), 3),
                           rf.stats.rounds, ru.stats.rounds,
                           round(t_f * 1e3, 1), round(t_u * 1e3, 1),
                           launches, seed_sweeps_per_iter(fprog)]
                    rows.append(row)
                    if eng == "pallas":
                        json_rows.append({
                            "graph": gname, "weighted": weighted,
                            "usecase": name,
                            "edge_work_ratio": float(ratio),
                            "t_fused_ms": t_f * 1e3,
                            "t_unfused_ms": t_u * 1e3,
                            "rounds_fused": rf.stats.rounds,
                            "iterations_fused": rf.stats.iterations,
                            # pallas_calls in the traced program, summed
                            # over the program's rounds (≤ 2 per round:
                            # one per lax.cond direction branch)
                            "launches_traced": launches,
                            "push_iters": sweep["push_iters"],
                            "pull_iters": sweep["pull_iters"],
                            "seed_sweeps_per_iter":
                                seed_sweeps_per_iter(fprog)})
            if "pallas" in engines:
                for name in direction_usecases:
                    direction_rows.append(
                        bench_direction(g, gname, weighted, name))
                for name in resolution_usecases:
                    resolution_rows.append(
                        bench_resolution(g, gname, weighted, name))
                for name in batched_usecases:
                    batched_rows.append(
                        bench_batched(g, gname, weighted, name))
                for name in sharded_usecases:
                    row = bench_sharded(g, gname, weighted, name)
                    if row is None:
                        print(f"sharded section skipped ({name}): fewer "
                              f"than {_SHARD_K} devices — set XLA_FLAGS="
                              "--xla_force_host_platform_device_count")
                    else:
                        sharded_rows.append(row)
                for name in guard_usecases:
                    guard_rows.append(bench_guard(g, gname, weighted, name))
                for name in serving_usecases:
                    serving_rows.append(
                        bench_serving(g, gname, weighted, name))
                for name in planner_usecases:
                    planner_rows.append(
                        bench_planner(g, gname, weighted, name))
                for name in incremental_usecases:
                    incremental_rows.append(
                        bench_incremental(g, gname, weighted, name))
    header = ["graph", "weights", "engine", "usecase", "edge_work_ratio",
              "speedup", "rounds_fused", "rounds_unfused", "t_fused_ms",
              "t_unfused_ms", "launches", "seed_sweeps"]
    out = emit(rows, header)
    if direction_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["iterations"], round(r["edge_work_auto"], 1),
               round(r["edge_work_pull"], 1), r["push_iters"],
               r["pull_iters"], r["sweeps_auto"], r["sweeps_pull"]]
              for r in direction_rows],
             ["graph", "weights", "usecase", "iters", "work_auto",
              "work_pull", "push_iters", "pull_iters", "sweeps_auto",
              "sweeps_pull"])
    if resolution_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["push_iters"], round(r["resolve_work_sorted"], 1),
               round(r["resolve_work_scatter"], 1),
               round(r["resolve_work_sorted"]
                     / max(r["resolve_work_scatter"], 1.0), 4),
               round(r["gather_work_sorted"], 1),
               round(r["gather_work_sorted"]
                     / max(r["push_iters"] * r["rectangle"], 1.0), 4),
               r["resolve_launches"],
               round(r["t_sorted_ms"], 1), round(r["t_scatter_ms"], 1)]
              for r in resolution_rows],
             ["graph", "weights", "usecase", "push_iters", "res_work_sorted",
              "res_work_scatter", "res_ratio", "gather_work",
              "gather_vs_rect", "resolve_launches",
              "t_sorted_ms", "t_scatter_ms"])
    if batched_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["batch"], r["exec_entries_seq"], r["exec_entries_batched"],
               r["launches_traced_seq"], r["launches_traced_batched"],
               round(r["queries_per_launch"], 2),
               round(r["t_seq_ms"], 1), round(r["t_batched_ms"], 1)]
              for r in batched_rows],
             ["graph", "weights", "usecase", "batch", "exec_seq",
              "exec_batched", "traced_seq", "traced_batched",
              "queries_per_launch", "t_seq_ms", "t_batched_ms"])
    if sharded_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["shards"], r["iterations_sharded"], r["iterations_single"],
               round(r["edge_work_sharded"], 1),
               round(r["edge_work_single"], 1),
               round(r["resolve_work_sharded_sorted"], 1),
               round(r["resolve_work_sharded_scatter"], 1),
               round(r["gather_work_sharded"], 1),
               r["shard_launches_traced"], r["cross_combines"],
               round(r["t_sharded_ms"], 1), round(r["t_single_ms"], 1)]
              for r in sharded_rows],
             ["graph", "weights", "usecase", "shards", "iters_sharded",
              "iters_single", "work_sharded", "work_single",
              "res_sorted", "res_scatter", "gather_work",
              "shard_launches", "cross_combines", "t_sharded_ms",
              "t_single_ms"])
    if guard_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["iterations"], round(r["edge_work"], 1),
               r["launches_traced_guarded"], r["launches_traced_off"],
               round(r["t_guarded_ms"], 1), round(r["t_off_ms"], 1)]
              for r in guard_rows],
             ["graph", "weights", "usecase", "iters", "edge_work",
              "traced_guarded", "traced_off", "t_guarded_ms", "t_off_ms"])
    if serving_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["requests"], r["batch_launches"],
               round(r["queries_per_launch"], 2), round(r["occupancy"], 2),
               r["scalar_rounds"], r["scalar_fused"],
               r["launches_traced"], r["exec_entries"],
               round(r["v_p50_ms"], 2), round(r["v_p99_ms"], 2),
               r["v_qps"], round(r["t_wall_ms"], 1)]
              for r in serving_rows],
             ["graph", "weights", "trace", "requests", "batch_launches",
              "q_per_launch", "occupancy", "scalar_rounds", "scalar_fused",
              "traced", "exec_entries", "v_p50_ms", "v_p99_ms", "v_qps",
              "t_wall_ms"])
    if planner_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["iterations"], round(r["edge_work"], 1),
               r["launches_traced_planned"], r["launches_traced_pinned"],
               r["exec_entries_planned"], r["exec_entries_pinned"],
               r["plan_entries"], r["feedback_entries"],
               round(r["t_planned_ms"], 1), round(r["t_pinned_ms"], 1)]
              for r in planner_rows],
             ["graph", "weights", "usecase", "iters", "edge_work",
              "traced_planned", "traced_pinned", "exec_planned",
              "exec_pinned", "plan_entries", "feedback", "t_planned_ms",
              "t_pinned_ms"])
    if incremental_rows:
        emit([[r["graph"], "w" if r["weighted"] else "unw", r["usecase"],
               r["inserted"], r["touched"],
               r["iterations_delta"], r["iterations_full"],
               round(r["edge_work_delta"], 1), round(r["edge_work_full"], 1),
               round(r["edge_work_delta"]
                     / max(r["edge_work_full"], 1.0), 4),
               r["patched_layouts"], r["rebuilt_layouts"],
               round(r["t_delta_ms"], 1), round(r["t_full_ms"], 1)]
              for r in incremental_rows],
             ["graph", "weights", "usecase", "inserted", "touched",
              "iters_delta", "iters_full", "work_delta", "work_full",
              "work_ratio", "patched", "rebuilt", "t_delta_ms",
              "t_full_ms"])
    doc = {"bench": "fusion_bench", "engine": "pallas",
           "rows": json_rows, "direction_rows": direction_rows,
           "resolution_rows": resolution_rows,
           "batched_rows": batched_rows,
           "sharded_rows": sharded_rows,
           "guard_rows": guard_rows,
           "serving_rows": serving_rows,
           "planner_rows": planner_rows,
           "incremental_rows": incremental_rows,
           "table": out}
    if json_rows or direction_rows or batched_rows or resolution_rows \
            or sharded_rows or guard_rows or serving_rows or planner_rows \
            or incremental_rows:
        path = json_out or _JSON_PATH
        with open(path, "w") as f:
            json.dump({k: v for k, v in doc.items() if k != "table"},
                      f, indent=1)
        print(f"wrote {path}")
    return doc


# ---------------------------------------------------------------------------
# Baseline regression gate (shared by CI bench-smoke and local runs).
# ---------------------------------------------------------------------------

def _row_key(r):
    return (r["graph"], r["weighted"], r["usecase"])


def compare_baseline(current: dict, baseline: dict,
                     rtol: float = _BASELINE_RTOL) -> list:
    """Regressions of ``current`` against ``baseline``; empty list = pass.

    Gated quantities are the deterministic execution-layer metrics —
    launches per iteration, fused/unfused edge-work ratio, and the
    direction engine's work advantage — never wall time (machine noise).
    Comparison is over the intersection of rows: a smoke run may bench a
    subset of the baseline's usecases (the workflow controls coverage)."""
    errors = []
    cur_rows = {_row_key(r): r for r in current.get("rows", [])}
    base_rows = {_row_key(r): r for r in baseline.get("rows", [])}
    for key, b in base_rows.items():
        r = cur_rows.get(key)
        if r is None:
            continue
        # strict on purpose: a +1 here is exactly the "extra kernel launch
        # snuck in" regression this gate exists for.  Trace-time counts are
        # jax-version-sensitive in principle; if a jax upgrade changes how
        # often bodies trace, regenerate the baseline deliberately.
        if r["launches_traced"] > b["launches_traced"]:
            errors.append(
                f"{key}: traced launches {r['launches_traced']} > baseline "
                f"{b['launches_traced']}")
        if r["edge_work_ratio"] > b["edge_work_ratio"] * (1 + rtol):
            errors.append(
                f"{key}: edge_work_ratio {r['edge_work_ratio']:.4f} > "
                f"baseline {b['edge_work_ratio']:.4f} (+{rtol:.0%})")
    base_dir = {_row_key(r): r for r in baseline.get("direction_rows", [])}
    for r in current.get("direction_rows", []):
        key = _row_key(r)
        # The acceptance property on the committed direction workloads:
        # adaptive must not do more (tile-counted) work or more sweep
        # executions than pull-only.  NOT a theorem of the heuristic —
        # tile granularity can overcount a push block whose sparse
        # frontier is co-blocked with hubs — so the work check carries
        # the shared tolerance; treat a trip on a new workload as "tune
        # the threshold or drop the workload", not as noise.
        if r["edge_work_auto"] > r["edge_work_pull"] * (1 + rtol):
            errors.append(
                f"{key}: adaptive work {r['edge_work_auto']:.0f} > pull-only "
                f"{r['edge_work_pull']:.0f} (+{rtol:.0%})")
        if r["sweeps_auto"] > r["sweeps_pull"]:
            errors.append(
                f"{key}: adaptive sweeps {r['sweeps_auto']} > pull-only "
                f"{r['sweeps_pull']}")
        b = base_dir.get(key)
        if b is None:
            continue
        if b["edge_work_pull"] and r["edge_work_pull"]:
            adv_now = r["edge_work_auto"] / r["edge_work_pull"]
            adv_base = b["edge_work_auto"] / b["edge_work_pull"]
            if adv_now > adv_base * (1 + rtol):
                errors.append(
                    f"{key}: push/pull work advantage regressed "
                    f"{adv_now:.3f} > baseline {adv_base:.3f} (+{rtol:.0%})")
    base_res = {_row_key(r): r for r in baseline.get("resolution_rows", [])}
    for r in current.get("resolution_rows", []):
        key = _row_key(r)
        # Standing frontier-proportionality bounds, not just a diff.
        # (bench_resolution itself asserts push_iters >= 1, so the section
        # can never silently gate nothing.)  Two bounds: under the padded
        # scatter rectangle (the cost the sorted path replaces), and —
        # the sharper one — strictly under push_iters·|E|, which is
        # exactly what fully-disengaged tile compaction would cost (every
        # real slot reduced every push iteration).  A trip on the second
        # means the compaction stopped engaging.
        if r["push_iters"] > 0:
            if not (r["resolve_work_sorted"] < r["resolve_work_scatter"]):
                errors.append(
                    f"{key}: sorted resolution work "
                    f"{r['resolve_work_sorted']:.0f} not under scatter "
                    f"{r['resolve_work_scatter']:.0f}")
            full_nnz = r["push_iters"] * r.get("num_edges", 0)
            if full_nnz and not (r["resolve_work_sorted"] < full_nnz):
                errors.append(
                    f"{key}: sorted resolution work "
                    f"{r['resolve_work_sorted']:.0f} ≥ push_iters·|E| = "
                    f"{full_nnz:.0f} — tile compaction disengaged")
            # in-kernel gather bounds (DESIGN.md §10): the permutation
            # gather must be frontier-proportional — strictly under the
            # full `push_iters · n_pad · width` rectangle it replaced —
            # and the scatter path performs no permutation gather at all.
            full_rect = r["push_iters"] * r.get("rectangle", 0)
            if full_rect and not (r["gather_work_sorted"] < full_rect):
                errors.append(
                    f"{key}: gather work {r['gather_work_sorted']:.0f} ≥ "
                    f"push_iters·rectangle = {full_rect:.0f} — the in-kernel "
                    "gather stopped skipping tiles")
            if "gather_work_scatter" in r and r["gather_work_scatter"] != 0:
                errors.append(
                    f"{key}: scatter path reports gather work "
                    f"{r['gather_work_scatter']:.0f} (must be 0 — it "
                    "performs no permutation gather)")
        b = base_res.get(key)
        if b is None:
            continue
        if b["resolve_work_scatter"] and r["resolve_work_scatter"]:
            ratio_now = r["resolve_work_sorted"] / r["resolve_work_scatter"]
            ratio_base = b["resolve_work_sorted"] / b["resolve_work_scatter"]
            if ratio_now > ratio_base * (1 + rtol):
                errors.append(
                    f"{key}: resolution-work ratio regressed "
                    f"{ratio_now:.4f} > baseline {ratio_base:.4f} "
                    f"(+{rtol:.0%})")
        if r["launches_traced_sorted"] > b["launches_traced_sorted"]:
            errors.append(
                f"{key}: sorted traced sweep launches "
                f"{r['launches_traced_sorted']} > baseline "
                f"{b['launches_traced_sorted']}")
    base_sharded = {_row_key(r): r for r in baseline.get("sharded_rows", [])}
    for r in current.get("sharded_rows", []):
        key = _row_key(r)
        # Standing compositional properties (DESIGN.md §11), not just diffs:
        # the global direction switch must keep the sharded fixpoint on the
        # single-device iteration sequence for the idempotent frontier
        # workloads (value bitwise-equality is asserted inside
        # bench_sharded itself).
        if r.get("idempotent") and \
                r["iterations_sharded"] != r["iterations_single"]:
            errors.append(
                f"{key}: sharded iterations {r['iterations_sharded']} != "
                f"single-device {r['iterations_single']} — global direction "
                "switch diverged")
        # Standing bound for the per-shard resolution stack (DESIGN.md
        # §11): whenever push iterations ran, the sharded sorted resolve
        # must stay strictly under the per-shard scatter rectangle.
        if r.get("push_iters_sharded", 0) > 0 and \
                "resolve_work_sharded_sorted" in r:
            if not (r["resolve_work_sharded_sorted"]
                    < r["resolve_work_sharded_scatter"]):
                errors.append(
                    f"{key}: sharded sorted resolution work "
                    f"{r['resolve_work_sharded_sorted']:.0f} not under the "
                    f"per-shard scatter rectangle "
                    f"{r['resolve_work_sharded_scatter']:.0f}")
        b = base_sharded.get(key)
        if b is None:
            continue
        # per-shard traced launches and cross-shard combine counts are the
        # sharded engine's launch-contract analogues: strict, like
        # launches_traced
        if r["shard_launches_traced"] > b["shard_launches_traced"]:
            errors.append(
                f"{key}: per-shard traced launches "
                f"{r['shard_launches_traced']} > baseline "
                f"{b['shard_launches_traced']}")
        if r["cross_combines"] > b["cross_combines"]:
            errors.append(
                f"{key}: cross-shard combines {r['cross_combines']} > "
                f"baseline {b['cross_combines']}")
        if b["edge_work_single"] and r["edge_work_single"]:
            ovh_now = r["edge_work_sharded"] / r["edge_work_single"]
            ovh_base = b["edge_work_sharded"] / b["edge_work_single"]
            if ovh_now > ovh_base * (1 + rtol):
                errors.append(
                    f"{key}: sharded/single edge-work overhead regressed "
                    f"{ovh_now:.3f} > baseline {ovh_base:.3f} (+{rtol:.0%})")
    base_batched = {_row_key(r): r for r in baseline.get("batched_rows", [])}
    for r in current.get("batched_rows", []):
        key = _row_key(r)
        # Standing properties of the source-parameterized executors
        # (DESIGN.md §8/§9), not just diffs: a B-source sequential sweep
        # holds ONE executor entry, and the batched run ONE vmapped entry.
        # A 2 here is exactly the retrace-per-source regression.
        if r["exec_entries_seq"] > 1:
            errors.append(
                f"{key}: sequential {r['batch']}-source sweep holds "
                f"{r['exec_entries_seq']} executor entries (want 1 — "
                "the source is being baked into the trace again)")
        if r["exec_entries_batched"] > 1:
            errors.append(
                f"{key}: batched sweep holds {r['exec_entries_batched']} "
                "executor entries (want 1)")
        b = base_batched.get(key)
        if b is None:
            continue
        for field in ("launches_traced_seq", "launches_traced_batched"):
            if r[field] > b[field]:
                errors.append(f"{key}: {field} {r[field]} > baseline "
                              f"{b[field]} (a retrace snuck in)")
    base_guard = {_row_key(r): r for r in baseline.get("guard_rows", [])}
    for r in current.get("guard_rows", []):
        key = _row_key(r)
        # Standing property (DESIGN.md §12): the divergence sentinel and
        # convergence bookkeeping fold into the existing fixpoint cond —
        # guarded execution must never add a traced launch over guards-off
        # (bench_guard additionally asserts bitwise values and identical
        # iterations/edge work in-bench).
        if r["launches_traced_guarded"] > r["launches_traced_off"]:
            errors.append(
                f"{key}: guarded traced launches "
                f"{r['launches_traced_guarded']} > guards-off "
                f"{r['launches_traced_off']} — the sentinel grew the "
                "traced program")
        b = base_guard.get(key)
        if b is None:
            continue
        # strict vs the committed baseline, like launches_traced: a +1 is
        # exactly the "guard launch snuck in" regression this row gates
        if r["launches_traced_guarded"] > b["launches_traced_guarded"]:
            errors.append(
                f"{key}: guarded traced launches "
                f"{r['launches_traced_guarded']} > baseline "
                f"{b['launches_traced_guarded']}")
    base_serving = {_row_key(r): r for r in baseline.get("serving_rows", [])}
    for r in current.get("serving_rows", []):
        key = _row_key(r)
        # Standing property (DESIGN.md §13): continuous batching must
        # actually batch — more than one answer per compiled launch on the
        # seeded trace (bench_serving additionally asserts every answer
        # bitwise-equal to its solo run, in-bench).
        if r["queries_per_launch"] <= 1.0:
            errors.append(
                f"{key}: serving queries_per_launch "
                f"{r['queries_per_launch']:.3f} <= 1 — continuous batching "
                "disengaged")
        b = base_serving.get(key)
        if b is None:
            continue
        # every gated quantity here is a deterministic function of the
        # seeded trace and the virtual clock — wall time is never compared
        if r["queries_per_launch"] < b["queries_per_launch"] * (1 - rtol):
            errors.append(
                f"{key}: queries_per_launch {r['queries_per_launch']:.3f} < "
                f"baseline {b['queries_per_launch']:.3f} (-{rtol:.0%})")
        for field in ("batch_launches", "scalar_rounds", "launches_traced",
                      "exec_entries"):
            if r[field] > b[field]:
                errors.append(
                    f"{key}: serving {field} {r[field]} > baseline "
                    f"{b[field]}")
        if r["scalar_fused"] < b["scalar_fused"]:
            errors.append(
                f"{key}: serving scalar_fused {r['scalar_fused']} < "
                f"baseline {b['scalar_fused']} — fuse_many pairing "
                "stopped absorbing scalar requests")
    base_planner = {_row_key(r): r for r in baseline.get("planner_rows", [])}
    for r in current.get("planner_rows", []):
        key = _row_key(r)
        # Standing properties (DESIGN.md §14): planning is a host-side cache
        # lookup, so the planned run must trace exactly what the pinned run
        # traces and hold the same executor entries (bitwise value /
        # iteration / edge-work parity is asserted inside bench_planner
        # itself); and executed queries must leave recorded-stats feedback
        # for the adaptive loop to consume.
        if r["launches_traced_planned"] != r["launches_traced_pinned"]:
            errors.append(
                f"{key}: planner changed traced launches "
                f"({r['launches_traced_planned']} vs pinned "
                f"{r['launches_traced_pinned']}) — planning must be "
                "invisible to the compiled program")
        if r["exec_entries_planned"] != r["exec_entries_pinned"]:
            errors.append(
                f"{key}: planner changed executor-cache entries "
                f"({r['exec_entries_planned']} vs pinned "
                f"{r['exec_entries_pinned']})")
        if r["feedback_entries"] < 1:
            errors.append(
                f"{key}: no recorded-stats feedback after an executed "
                "query — the planner's feedback loop is disconnected")
        b = base_planner.get(key)
        if b is None:
            continue
        # strict vs the committed baseline, like launches_traced
        if r["launches_traced_planned"] > b["launches_traced_planned"]:
            errors.append(
                f"{key}: planned traced launches "
                f"{r['launches_traced_planned']} > baseline "
                f"{b['launches_traced_planned']}")
        if r["exec_entries_planned"] > b["exec_entries_planned"]:
            errors.append(
                f"{key}: planned executor entries "
                f"{r['exec_entries_planned']} > baseline "
                f"{b['exec_entries_planned']}")
    base_incr = {_row_key(r): r for r in baseline.get("incremental_rows", [])}
    for r in current.get("incremental_rows", []):
        key = _row_key(r)
        # Standing properties (DESIGN.md §15), not just diffs: delta
        # propagation must do strictly less edge work than the cold full
        # recompute it replaces (bench_incremental additionally asserts the
        # answers bitwise-equal in-bench), and the planner must actually
        # resolve delta propagation for the small seeded insert batch — a
        # "full" here means the mutation-size heuristic disengaged and the
        # whole section gates nothing.
        if not (r["edge_work_delta"] < r["edge_work_full"]):
            errors.append(
                f"{key}: delta edge work {r['edge_work_delta']:.0f} not "
                f"under the full recompute's {r['edge_work_full']:.0f} — "
                "delta propagation disengaged")
        if r.get("plan_incremental") != "delta":
            errors.append(
                f"{key}: planner resolved incremental="
                f"{r.get('plan_incremental')!r} for the small seeded "
                "insert batch (want 'delta')")
        b = base_incr.get(key)
        if b is None:
            continue
        if b["edge_work_full"] and r["edge_work_full"]:
            ratio_now = r["edge_work_delta"] / r["edge_work_full"]
            ratio_base = b["edge_work_delta"] / b["edge_work_full"]
            if ratio_now > ratio_base * (1 + rtol):
                errors.append(
                    f"{key}: delta/full work ratio regressed "
                    f"{ratio_now:.4f} > baseline {ratio_base:.4f} "
                    f"(+{rtol:.0%})")
        # strict, like launches_traced: a rebuild where the baseline
        # patched means the in-place ELL patch stopped absorbing the batch
        if r["rebuilt_layouts"] > b["rebuilt_layouts"]:
            errors.append(
                f"{key}: rebuilt layouts {r['rebuilt_layouts']} > baseline "
                f"{b['rebuilt_layouts']} — the in-place layout patch "
                "stopped absorbing the insert batch")
    return errors


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engines", default="pull,push",
                    help="comma list: pull,push,dense,adaptive,pallas")
    ap.add_argument("--graphs", default=None,
                    help=f"comma list from {sorted(BENCH_GRAPHS)}; defaults "
                         "to RM-S, or RM-XS when pallas is benchmarked "
                         "(interpret-mode grids step in Python on CPU)")
    ap.add_argument("--usecases", default=",".join(SIMPLE + MULTI))
    ap.add_argument("--batched", default=None, metavar="NAMES",
                    help="comma list of batched-sweep workloads "
                         f"(default {','.join(BATCHED)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--resolution", default=None, metavar="NAMES",
                    help="comma list of push-resolution workloads "
                         f"(default {','.join(RESOLUTION)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--sharded", default=None, metavar="NAMES",
                    help="comma list of sharded-engine workloads "
                         f"(default {','.join(SHARDED)} when pallas is "
                         "benchmarked and >= 2 devices exist; pass '' to "
                         "skip)")
    ap.add_argument("--guard", default=None, metavar="NAMES",
                    help="comma list of guard-overhead workloads "
                         f"(default {','.join(GUARDED)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--serving", default=None, metavar="NAMES",
                    help="comma list of open-loop serving traces "
                         f"(default {','.join(SERVING)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--planner", default=None, metavar="NAMES",
                    help="comma list of planner-parity workloads "
                         f"(default {','.join(PLANNER)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--incremental", default=None, metavar="NAMES",
                    help="comma list of delta-vs-full mutation workloads "
                         f"(default {','.join(INCREMENTAL)} when pallas is "
                         "benchmarked; pass '' to skip)")
    ap.add_argument("--json-out", default=None, metavar="PATH",
                    help="where to write the machine-readable results "
                         f"(default {_JSON_PATH})")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed BENCH_pallas.json to diff against; "
                         "regressions exit 1 (the CI perf gate)")
    args = ap.parse_args()
    engines = tuple(args.engines.split(","))
    graphs = args.graphs or ("RM-XS" if "pallas" in engines else "RM-S")
    baseline = None
    json_out = args.json_out
    if args.baseline:
        # read the baseline BEFORE running, and never write the fresh run
        # over it: `--baseline BENCH_pallas.json` without --json-out must
        # compare fresh-vs-committed, not fresh-vs-itself
        with open(args.baseline) as f:
            baseline = json.load(f)
        if json_out is None and os.path.realpath(args.baseline) == \
                os.path.realpath(_JSON_PATH):
            json_out = _JSON_PATH.replace(".json", ".fresh.json")
            print(f"baseline is the default output path; writing fresh "
                  f"results to {json_out}")
    batched = None if args.batched is None else \
        tuple(u for u in args.batched.split(",") if u)
    resolution = None if args.resolution is None else \
        tuple(u for u in args.resolution.split(",") if u)
    sharded = None if args.sharded is None else \
        tuple(u for u in args.sharded.split(",") if u)
    guard = None if args.guard is None else \
        tuple(u for u in args.guard.split(",") if u)
    serving = None if args.serving is None else \
        tuple(u for u in args.serving.split(",") if u)
    planner = None if args.planner is None else \
        tuple(u for u in args.planner.split(",") if u)
    incremental = None if args.incremental is None else \
        tuple(u for u in args.incremental.split(",") if u)
    result = run(graph_names=tuple(graphs.split(",")),
                 usecases=tuple(u for u in args.usecases.split(",") if u),
                 engines=engines, json_out=json_out,
                 batched_usecases=batched, resolution_usecases=resolution,
                 sharded_usecases=sharded, guard_usecases=guard,
                 serving_usecases=serving, planner_usecases=planner,
                 incremental_usecases=incremental)
    if baseline is not None:
        if not (result["rows"] or result["direction_rows"]
                or result["batched_rows"] or result["resolution_rows"]
                or result["sharded_rows"] or result["guard_rows"]
                or result["serving_rows"] or result["planner_rows"]
                or result["incremental_rows"]):
            print("--baseline requires the pallas engine in --engines "
                  "(no gated rows were produced)")
            sys.exit(2)
        errors = compare_baseline(result, baseline)
        if errors:
            print("PERF REGRESSION vs baseline:")
            for e in errors:
                print("  -", e)
            sys.exit(1)
        print(f"baseline check OK ({args.baseline}: "
              f"{len(baseline.get('rows', []))} rows, "
              f"{len(baseline.get('direction_rows', []))} direction rows, "
              f"{len(baseline.get('resolution_rows', []))} resolution rows, "
              f"{len(baseline.get('batched_rows', []))} batched rows, "
              f"{len(baseline.get('sharded_rows', []))} sharded rows, "
              f"{len(baseline.get('guard_rows', []))} guard rows, "
              f"{len(baseline.get('serving_rows', []))} serving rows, "
              f"{len(baseline.get('planner_rows', []))} planner rows, "
              f"{len(baseline.get('incremental_rows', []))} incremental "
              "rows)")
