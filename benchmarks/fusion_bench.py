"""Paper Fig. 13 (WSP/NWR/RADIUS) + Fig. 14/Table 3 (DRR/Trust/RDS):
fused vs unfused edge-work ratio and wall time, weighted and unweighted
graphs.

Theoretical bounds reproduced: simple pair fusions bound at 50% (two
passes → one), 4-reduction fusions at 25%, RDS at 50% (4 rounds → 2).
"""
from __future__ import annotations

from benchmarks.common import BENCH_GRAPHS, emit, timed
from repro.core import engine, fusion
from repro.core import usecases as U

SIMPLE = ["WSP", "NWR", "RADIUS"]
MULTI = ["DRR", "Trust", "RDS"]


def run(graph_names=("RM-S",), usecases=SIMPLE + MULTI,
        engines=("pull", "push")):
    rows = []
    for gname in graph_names:
        for weighted in (False, True):
            g = BENCH_GRAPHS[gname](weighted)
            for eng in engines:
                for name in usecases:
                    spec = U.ALL_SPECS[name]()
                    fprog = fusion.fuse(spec)
                    uprog = fusion.lower_unfused(spec)
                    t_f, rf = timed(lambda: engine.run_program(
                        g, fprog, engine=eng), repeats=3)
                    t_u, ru = timed(lambda: engine.run_program(
                        g, uprog, engine=eng), repeats=3)
                    ratio = rf.stats.edge_work / max(ru.stats.edge_work, 1.0)
                    rows.append([
                        gname, "w" if weighted else "unw", eng, name,
                        round(ratio, 4),
                        round(t_u / max(t_f, 1e-9), 3),
                        rf.stats.rounds, ru.stats.rounds,
                        round(t_f * 1e3, 1), round(t_u * 1e3, 1)])
    return emit(rows, ["graph", "weights", "engine", "usecase",
                       "edge_work_ratio", "speedup", "rounds_fused",
                       "rounds_unfused", "t_fused_ms", "t_unfused_ms"])


if __name__ == "__main__":
    run()
