"""Paper Fig. 13 (WSP/NWR/RADIUS) + Fig. 14/Table 3 (DRR/Trust/RDS):
fused vs unfused edge-work ratio and wall time, weighted and unweighted
graphs — now including the pallas engine with kernel-launch counting.

Theoretical bounds reproduced: simple pair fusions bound at 50% (two
passes → one), 4-reduction fusions at 25%, RDS at 50% (4 rounds → 2).

For the pallas engine two extra columns track the execution layer
(DESIGN.md §2/§7): ``launches`` is the measured number of ``pallas_call``
launches per engine iteration (trace-time count over all rounds) and
``seed_sweeps`` the per-iteration sweep count of the pre-fusion execution
model (one launch per lex level per plan, plus one has-pred probe per
component on pull− rounds) — the quantity the single-pass fused sweep
collapses to one launch per round.  ``--engines pallas`` additionally
writes machine-readable ``BENCH_pallas.json`` next to the repo root so
the perf trajectory is tracked across PRs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

if __package__ in (None, ""):           # `python benchmarks/fusion_bench.py`
    _root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    sys.path.insert(0, _root)
    try:
        import repro                    # noqa: F401  (pip install -e .)
    except ImportError:                 # fall back to the source tree
        sys.path.insert(0, os.path.join(_root, "src"))

from benchmarks.common import BENCH_GRAPHS, emit, timed
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.core.iterate import plan_idempotent
from repro.kernels.ops import _plan_levels

SIMPLE = ["WSP", "NWR", "RADIUS"]
MULTI = ["DRR", "Trust", "RDS"]

_JSON_PATH = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "BENCH_pallas.json")


def seed_sweeps_per_iter(prog) -> int:
    """Per-iteration edge-sweep count of the one-launch-per-level execution
    model this PR replaced (summed over the program's iteration rounds)."""
    total = 0
    for _name, round_ in prog.rounds:
        if not round_.leaves:
            continue
        plans = [leaf.plan for leaf in round_.leaves]
        idempotent = all(plan_idempotent(p) for p in plans)
        for p in plans:
            levels = _plan_levels(p)
            total += len(levels)
            if not idempotent:
                total += len(levels)        # one has-pred probe per component
    return total


def measured_launches(g, prog):
    """Cold-build the pallas executors and count pallas_call launches per
    iteration (the while_loop body traces each sweep exactly once)."""
    from repro.kernels import edge_reduce as er
    engine.clear_program_caches()
    er.reset_sweep_stats()
    engine.run_program(g, prog, engine="pallas")
    return er.SWEEP_STATS["launches"]


def run(graph_names=("RM-S",), usecases=SIMPLE + MULTI,
        engines=("pull", "push"), json_out: bool = True):
    rows = []
    json_rows = []
    for gname in graph_names:
        for weighted in (False, True):
            g = BENCH_GRAPHS[gname](weighted)
            for eng in engines:
                for name in usecases:
                    spec = U.ALL_SPECS[name]()
                    fprog = fusion.fuse(spec)
                    uprog = fusion.lower_unfused(spec)
                    launches = ""
                    if eng == "pallas":
                        launches = measured_launches(g, fprog)
                    t_f, rf = timed(lambda: engine.run_program(
                        g, fprog, engine=eng), repeats=3)
                    t_u, ru = timed(lambda: engine.run_program(
                        g, uprog, engine=eng), repeats=3)
                    ratio = rf.stats.edge_work / max(ru.stats.edge_work, 1.0)
                    row = [gname, "w" if weighted else "unw", eng, name,
                           round(ratio, 4),
                           round(t_u / max(t_f, 1e-9), 3),
                           rf.stats.rounds, ru.stats.rounds,
                           round(t_f * 1e3, 1), round(t_u * 1e3, 1),
                           launches, seed_sweeps_per_iter(fprog)]
                    rows.append(row)
                    if eng == "pallas":
                        json_rows.append({
                            "graph": gname, "weighted": weighted,
                            "usecase": name,
                            "edge_work_ratio": float(ratio),
                            "t_fused_ms": t_f * 1e3,
                            "t_unfused_ms": t_u * 1e3,
                            "rounds_fused": rf.stats.rounds,
                            "iterations_fused": rf.stats.iterations,
                            "launches_per_iter": launches,
                            "seed_sweeps_per_iter":
                                seed_sweeps_per_iter(fprog)})
    header = ["graph", "weights", "engine", "usecase", "edge_work_ratio",
              "speedup", "rounds_fused", "rounds_unfused", "t_fused_ms",
              "t_unfused_ms", "launches", "seed_sweeps"]
    out = emit(rows, header)
    if json_rows and json_out:
        with open(_JSON_PATH, "w") as f:
            json.dump({"bench": "fusion_bench", "engine": "pallas",
                       "rows": json_rows}, f, indent=1)
        print(f"wrote {_JSON_PATH}")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--engines", default="pull,push",
                    help="comma list: pull,push,dense,adaptive,pallas")
    ap.add_argument("--graphs", default=None,
                    help=f"comma list from {sorted(BENCH_GRAPHS)}; defaults "
                         "to RM-S, or RM-XS when pallas is benchmarked "
                         "(interpret-mode grids step in Python on CPU)")
    ap.add_argument("--usecases", default=",".join(SIMPLE + MULTI))
    args = ap.parse_args()
    engines = tuple(args.engines.split(","))
    graphs = args.graphs or ("RM-XS" if "pallas" in engines else "RM-S")
    run(graph_names=tuple(graphs.split(",")),
        usecases=tuple(args.usecases.split(",")),
        engines=engines)
