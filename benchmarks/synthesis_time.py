"""Paper Fig. 15: analysis time — #path-based reductions, fusion time (ms)
and kernel-synthesis (bounded constraint solving) time per use-case."""
from __future__ import annotations

import time

from benchmarks.common import emit
from repro.core import fusion
from repro.core import usecases as U
from repro.core.synthesis import _CACHE, synthesize_round

CASES = ["BFS", "CC", "SSSP", "WP", "WSP", "NSP", "NWR", "RADIUS", "DS",
         "DRR", "Trust", "RDS"]


def run():
    rows = []
    for name in CASES:
        spec = U.ALL_SPECS[name]()
        prog = fusion.fuse(spec)
        n_pbr = sum(len(r.components) for _, r in prog.rounds)
        _CACHE.clear()                      # honest cold-synthesis timing
        t0 = time.perf_counter()
        for _, round_ in prog.rounds:
            if round_.leaves:
                synthesize_round(round_)
        synth_ms = (time.perf_counter() - t0) * 1e3
        rows.append([name, n_pbr, round(prog.stats.wall_ms, 2),
                     round(synth_ms, 1), prog.stats.total_rules(),
                     prog.stats.cse])
    return emit(rows, ["usecase", "n_pbr", "fusion_ms", "synthesis_ms",
                       "fusion_rules_applied", "cse_eliminated"])


if __name__ == "__main__":
    run()
