"""Shared benchmark plumbing: seeded graphs matching the paper's regimes,
timing helpers, CSV emit.

The paper's datasets (LiveJournal 69M … Friendster 1.8B edges) do not fit a
1-core CPU container; benchmarks use seeded RMAT/uniform graphs with the
same metrics.  Edge-work ratio (the paper's primary fusion metric) is
size-independent by construction, so the ratios reproduce directly; the
full-scale shapes are exercised by the dry-run instead (DESIGN.md §7).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.graph.structure import rmat_graph, undirected, uniform_graph

BENCH_GRAPHS = {
    # XS regime for the interpret-mode pallas engine on CPU CI (the Pallas
    # interpreter steps the grid in Python; 2k-vertex graphs take ~10 s/query)
    "RM-XS": lambda weighted: rmat_graph(400, 3_200, seed=11,
                                         weighted=weighted),
    "RM-S": lambda weighted: rmat_graph(2_000, 16_000, seed=11,
                                        weighted=weighted),
    "RM-M": lambda weighted: rmat_graph(10_000, 80_000, seed=12,
                                        weighted=weighted),
    "UN-M": lambda weighted: uniform_graph(10_000, 60_000, seed=13,
                                           weighted=weighted),
}


def timed(fn, repeats: int = 3):
    """Median wall time (s) + last result; first call is burned (compile)."""
    fn()
    ts = []
    out = None
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(jax.tree.leaves(out)[0]) if jax.tree.leaves(out) else None
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)), out


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    return rows
