"""Benchmark aggregator: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default runs the small graph regime (1-core CPU container); --full adds the
medium graphs.  The roofline section reads the dry-run reports if present.
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    graphs = ("RM-S", "RM-M") if args.full else ("RM-S",)
    t0 = time.time()

    print("\n### Fig. 11 / Table 1 — synthesized vs handwritten")
    from benchmarks import synth_vs_hand
    synth_vs_hand.run(graph_names=graphs)

    print("\n### Fig. 13 + Fig. 14 / Table 3 — fusion (simple + multi)")
    from benchmarks import fusion_bench
    fusion_bench.run(graph_names=graphs)

    print("\n### Table 2 — state sizes / scatter-op counts")
    from benchmarks import state_metrics
    state_metrics.run()

    print("\n### Fig. 15 — fusion + synthesis time")
    from benchmarks import synthesis_time
    synthesis_time.run()

    print("\n### Roofline (from dry-run artifacts, if present)")
    from benchmarks import roofline
    roofline.main()

    print(f"\n[benchmarks.run] total wall: {time.time() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
