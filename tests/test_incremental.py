"""Incremental delta propagation over mutating graphs (DESIGN.md §15).

The contracts under test: ``mutate_edges`` carries cached blocked-ELL
layouts over by an in-place patch (slot reuse up to the padded width,
counted rebuild on row overflow) that is value-invisible against a
canonical from-scratch build; a delta-seeded warm start
(``run_program(..., init_state=prev, delta=touched)``) converges
BITWISE-equal to a cold recompute on the mutated graph for idempotent
rounds over insert-only batches, and to tolerance for non-idempotent
(PR-style) rounds; the planner's ``incremental`` knob resolves "delta"
for small batches and "full" for large ones or idempotent rounds after
deletions (whose stale monotone values cannot retract); the chunked
checkpointed fixpoint composes with the warm+delta path through a kill
and resume; and the serving layer's ``mutate_graph`` drains in-flight
lanes, patches the resident layout, and warm-starts queued repeat
queries from retired answers — all bitwise vs solo runs on the graph
that actually served each request.
"""
import numpy as np
import pytest

from repro.core import engine, fusion, iterate
from repro.core import usecases as U
from repro.core.fusion import Prim
from repro.core.guard import GraphValidationError
from repro.graph import mutate
from repro.graph.structure import from_edges, uniform_graph

pytestmark = pytest.mark.incremental


@pytest.fixture
def g():
    # 160 edges: a 4-edge batch sits well under the planner's 5% delta
    # threshold, a half-|E| batch well over it
    return uniform_graph(32, 160, seed=3, weighted=True)


def _run(g_, name, **kw):
    return engine.run_program(g_, fusion.fuse(U.ALL_SPECS[name]()),
                              engine="pallas", **kw)


def _canonical(g_):
    """The same edge multiset rebuilt from scratch: canonical slot order,
    no patched caches — the oracle a patched layout must agree with."""
    src, dst, w, c = g_.host_edges()
    return from_edges(g_.n, src, dst, w, c)


def _insert(rng, g_, k, weighted=True):
    parts = (rng.integers(0, g_.n, size=k), rng.integers(0, g_.n, size=k))
    if weighted:
        parts += ((0.1 + rng.random(k)).astype(np.float32),)
    return parts


# ---------------------------------------------------------------------------
# Layout patching: value-invisible vs a canonical rebuild
# ---------------------------------------------------------------------------

def test_patched_layouts_match_canonical_rebuild(g):
    for name in ("BFS", "CC"):
        _run(g, name)                       # warm g's layout caches
    src, dst, _w, _c = g.host_edges()
    mutate.reset_mutation_stats()
    g2, md = mutate.mutate_edges(g, insert=([1, 2, 3], [4, 5, 6]),
                                 delete=(src[:2], dst[:2]))
    assert md.inserted == 3 and md.deleted == 2 and md.has_deletes
    assert md.patched_layouts >= 1 and md.rebuilt_layouts == 0
    ref = _canonical(g2)
    for name in ("BFS", "CC"):
        a = _run(g2, name)                  # served by the patched caches
        b = _run(ref, name)                 # canonical lazy build
        np.testing.assert_array_equal(np.asarray(a.value),
                                      np.asarray(b.value), err_msg=name)


def test_chained_mutations_keep_patching_from_real_slots(g):
    """Patched slots are non-canonical; a second mutation must patch from
    the RECORDED positions (structure._SLOT_CACHE), not the fill order."""
    _run(g, "BFS")
    g1, md1 = mutate.mutate_edges(g, insert=([0, 1], [2, 3]))
    assert md1.patched_layouts >= 1
    src, dst, _w, _c = g1.host_edges()
    g2, md2 = mutate.mutate_edges(g1, insert=([4], [5]),
                                  delete=(src[:1], dst[:1]))
    assert md2.patched_layouts >= 1
    a = _run(g2, "BFS")
    b = _run(_canonical(g2), "BFS")
    np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))


def test_row_overflow_falls_back_to_counted_rebuild(g):
    _run(g, "BFS")                          # warm layout caches
    mutate.reset_mutation_stats()
    # 200 inserts all landing on dst=0 overflow row 0's padded in-width
    # (block_e=128 padding leaves ~123 free slots): the in-layout must
    # fall back to a counted rebuild, and values must still be canonical
    k = 200
    rng = np.random.default_rng(0)
    g2, md = mutate.mutate_edges(
        g, insert=(rng.integers(1, g.n, size=k), np.zeros(k, np.int64)))
    assert md.rebuilt_layouts >= 1
    assert mutate.MUTATION_STATS["rebuilt_layouts"] == md.rebuilt_layouts
    a = _run(g2, "BFS")
    b = _run(_canonical(g2), "BFS")
    np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))


# ---------------------------------------------------------------------------
# Mutation edge cases: policies and missing edges
# ---------------------------------------------------------------------------

def test_duplicate_insert_under_both_policies(g):
    src, dst, _w, _c = g.host_edges()
    dup = ([int(src[0])], [int(dst[0])])
    g2, md = mutate.mutate_edges(g, insert=dup, duplicates="allow")
    assert md.inserted == 1 and g2.num_edges == g.num_edges + 1
    with pytest.raises(GraphValidationError, match="duplicate"):
        mutate.mutate_edges(g, insert=dup, duplicates="error")


def test_delete_missing_edge_raises(g):
    src, dst, _w, _c = g.host_edges()
    present = set(zip(src.tolist(), dst.tolist()))
    missing = next((s, d) for s in range(g.n) for d in range(g.n)
                   if (s, d) not in present)
    with pytest.raises(GraphValidationError, match="not present"):
        mutate.mutate_edges(g, delete=([missing[0]], [missing[1]]))
    # a k-fold request needs k occurrences: one real edge + the same edge
    # again is missing unless the graph holds a parallel copy
    if (int(src[0]), int(dst[0])) not in \
            set(zip(src[1:].tolist(), dst[1:].tolist())):
        with pytest.raises(GraphValidationError, match="not present"):
            mutate.mutate_edges(
                g, delete=([int(src[0])] * 2, [int(dst[0])] * 2))


def test_empty_mutation_rejected(g):
    with pytest.raises(ValueError, match="insert batch"):
        mutate.mutate_edges(g)


# ---------------------------------------------------------------------------
# Delta-seeded fixpoints: bitwise parity with the cold recompute
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["BFS", "SSSP", "CC", "WP"])
def test_insert_only_delta_bitwise_equals_cold(g, name):
    _res0, state = _run(g, name, return_state=True)
    g2, md = mutate.mutate_edges(g, insert=_insert(
        np.random.default_rng(1), g, 4))
    assert not md.has_deletes
    warm = _run(g2, name, init_state=state, delta=md)
    assert warm.stats.plan.incremental == "delta"
    cold = _run(g2, name)
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value), err_msg=name)
    # ... and both agree with a from-scratch canonical graph
    scratch = _run(_canonical(g2), name)
    np.testing.assert_array_equal(np.asarray(cold.value),
                                  np.asarray(scratch.value), err_msg=name)


def test_deletes_plan_full_recompute_and_stay_correct(g):
    _res0, state = _run(g, "BFS", return_state=True)
    src, dst, _w, _c = g.host_edges()
    g2, md = mutate.mutate_edges(g, delete=(src[:2], dst[:2]))
    assert md.has_deletes
    # idempotent round after deletions: stale monotone values cannot
    # retract, so the planner must drop the warm hints and run cold
    warm = _run(g2, "BFS", init_state=state, delta=md)
    assert warm.stats.plan.incremental == "full"
    cold = _run(g2, "BFS")
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


def test_large_batch_plans_full(g):
    _res0, state = _run(g, "BFS", return_state=True)
    g2, md = mutate.mutate_edges(g, insert=_insert(
        np.random.default_rng(2), g, g.num_edges // 2))
    warm = _run(g2, "BFS", init_state=state, delta=md)
    assert warm.stats.plan.incremental == "full"
    cold = _run(g2, "BFS")
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


def test_explain_records_incremental_decision(g):
    _res0, state = _run(g, "BFS", return_state=True)
    g2, md = mutate.mutate_edges(g, insert=([0, 1], [2, 3]))
    exp = _run(g2, "BFS", init_state=state, delta=md, explain=True)
    assert exp.plan.incremental == "delta"
    assert "delta" in exp.decisions["incremental"]
    g3, md3 = mutate.mutate_edges(g, insert=_insert(
        np.random.default_rng(3), g, g.num_edges))
    exp3 = _run(g3, "BFS", init_state=state, delta=md3, explain=True)
    assert exp3.plan.incremental == "full"
    assert "full" in exp3.decisions["incremental"]


def test_raw_delta_array_is_honored_verbatim(g):
    """A raw vertex-id delta bypasses the planner's mutation heuristic: no
    MutationDelta, no incremental decision — the warm hints run as given."""
    _res0, state = _run(g, "BFS", return_state=True)
    g2, _md = mutate.mutate_edges(g, insert=([0, 1], [2, 3]))
    warm = _run(g2, "BFS", init_state=state,
                delta=np.array([0, 1, 2, 3], np.int64))
    assert warm.stats.plan.incremental is None
    cold = _run(g2, "BFS")
    np.testing.assert_array_equal(np.asarray(warm.value),
                                  np.asarray(cold.value))


# ---------------------------------------------------------------------------
# Non-idempotent (PR-style) rounds: rescaled warm start, tolerance parity
# ---------------------------------------------------------------------------

def test_pagerank_warm_delta_converges_to_tolerance(g):
    dk = U.handwritten_pagerank(g.n)
    prev = engine.run_direct(g, dk, engine="pallas")
    g2, md = mutate.mutate_edges(g, insert=([1, 2], [3, 4], [0.4, 0.6]))
    cold = engine.run_direct(g2, dk, engine="pallas")
    warm = engine.run_direct(g2, dk, engine="pallas",
                             init_state=[np.asarray(prev.value)],
                             delta=np.asarray(md.touched))
    assert np.allclose(np.asarray(warm.value), np.asarray(cold.value),
                       atol=1e-4)
    # the converged neighbouring state must not be slower than cold — the
    # regression the mass-preserving rescale exists to prevent
    assert warm.stats.iterations <= cold.stats.iterations


def test_delta_validation_guards(g):
    _res0, state = _run(g, "BFS", return_state=True)
    with pytest.raises(ValueError, match="init_state"):
        _run(g, "BFS", delta=np.array([0, 1]))
    with pytest.raises(ValueError, match="out of range"):
        _run(g, "BFS", init_state=state, delta=np.array([g.n + 5]))
    with pytest.raises(ValueError, match="pallas"):
        engine.run_program(g, fusion.fuse(U.bfs(0)), engine="pull",
                           init_state=state)
    with pytest.raises(ValueError, match="single-round"):
        engine.run_program(g, fusion.fuse(U.rds(0, 1)), engine="pallas",
                           init_state=state, delta=np.array([0]))
    # non-idempotent + tol=0: bitwise convergence is not a meaningful
    # contract for a contraction — the engine must refuse, not hand back
    # a state that merely stopped changing in float
    dk0 = U.pagerank_kernels(g.n, tol=0.0)
    with pytest.raises(ValueError, match="tol > 0"):
        engine.run_direct(g, dk0, engine="pallas",
                          init_state=[np.full(g.n, 1.0 / g.n, np.float32)],
                          delta=np.array([0]))


# ---------------------------------------------------------------------------
# Checkpointed fixpoint across a mutation: kill mid-delta-run, resume
# ---------------------------------------------------------------------------

class _Kill(Exception):
    pass


def test_mutation_then_kill_and_resume_bitwise(g, tmp_path):
    from repro.kernels import ops as kops
    dk = U.handwritten_sssp(0)
    comp = iterate.CompRuntime(idx=0, op=dk.rop,
                               dtype=iterate.DTYPES[dk.dtype],
                               p_fn=dk.p_fn, init_fn=dk.init_fn,
                               source=dk.source, e_fn=dk.e_fn)
    plans = [Prim(dk.rop, 0)]
    base = kops.iterate_pallas(g, [comp], plans)
    state = [np.asarray(s) for s in base.state]
    g2, md = mutate.mutate_edges(g, insert=([0, 3], [5, 7], [0.2, 0.3]))
    ref = kops.iterate_pallas(g2, [comp], plans, init_state=state,
                              delta=md.touched)
    d = str(tmp_path / "mut")

    def killer(k):
        raise _Kill

    with pytest.raises(_Kill):
        kops.iterate_pallas(g2, [comp], plans, init_state=state,
                            delta=md.touched, checkpoint_every=1,
                            ckpt_dir=d, fault_hook=killer)
    resumed = kops.iterate_pallas(g2, [comp], plans, init_state=state,
                                  delta=md.touched, checkpoint_every=1,
                                  ckpt_dir=d, resume=True)
    assert resumed.iterations == ref.iterations
    for a, b in zip(ref.state, resumed.state):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# Cache accounting: slot maps in the stats surface, cleared with the rest
# ---------------------------------------------------------------------------

def test_slot_cache_stats_and_clear(g):
    _run(g, "BFS")                          # warm layout caches
    mutate.reset_mutation_stats()
    _g2, md = mutate.mutate_edges(g, insert=([0], [1]))
    assert md.patched_layouts >= 1
    stats = engine.program_cache_stats()
    assert stats["slot_maps"] >= 1
    assert mutate.MUTATION_STATS["mutations"] == 1
    engine.clear_program_caches()
    stats = engine.program_cache_stats()
    assert stats["slot_maps"] == 0
    assert mutate.MUTATION_STATS["mutations"] == 0


# ---------------------------------------------------------------------------
# Serving layer: mutate under traffic — drain, patch, warm-join
# ---------------------------------------------------------------------------

def _service(g_):
    from repro.launch import service as S
    svc = S.AnalyticsService(S.ServiceConfig(engine="pallas", max_batch=4,
                                             chunk_iters=3))
    svc.add_graph("g", g_)
    svc.register("BFS", U.bfs)
    return S, svc


def _drain(svc, limit=10_000):
    steps = 0
    while svc.step():
        steps += 1
        assert steps < limit, "service failed to drain"


def test_service_mutate_drains_patches_and_warm_joins(g):
    S, svc = _service(g)
    for i in range(3):
        svc.submit("g", S.Request(rid=i, kind="BFS", source=i))
    _drain(svc)
    # repeats of two retired sources + a fresh one queue across the edit
    for i, s in enumerate((0, 1, 9)):
        svc.submit("g", S.Request(rid=10 + i, kind="BFS", source=s))
    md = svc.mutate_graph("g", insert=([2, 4], [6, 8], [0.5, 0.5]))
    assert md.inserted == 2 and md.patched_layouts >= 1
    _drain(svc)
    m = svc.metrics()
    assert m["completed"] == 6
    assert m["mutations"] == 1
    assert m["patched_layouts"] >= 1 and m["rebuilt_layouts"] == 0
    assert m["warm_joins"] >= 2             # both repeat queries joined warm
    # every answer must be bitwise-equal to a solo run on the graph that
    # actually served it: pre-mutation rids on the old graph, queued
    # post-mutation rids on the patched resident graph
    prog = fusion.fuse(U.bfs(0))
    new_g = svc.graphs["g"]
    for req in svc.completed:
        served_on = g if req.rid < 10 else new_g
        ref = engine.run_program(served_on, prog, engine="pallas",
                                 source=req.source).value
        np.testing.assert_array_equal(
            np.asarray(req.value), np.asarray(ref),
            err_msg=f"rid {req.rid} diverged from its solo run")


def test_service_deletes_invalidate_retired_memo(g):
    S, svc = _service(g)
    svc.submit("g", S.Request(rid=0, kind="BFS", source=0))
    _drain(svc)
    assert len(svc._retired) == 1
    src, dst, _w, _c = g.host_edges()
    md = svc.mutate_graph("g", delete=(src[:1], dst[:1]))
    assert md.has_deletes
    # deletions retract support: the retired-answer memo for this graph
    # must be dropped, and the repeat query must run cold — and correct —
    # on the mutated graph
    assert len(svc._retired) == 0
    svc.submit("g", S.Request(rid=1, kind="BFS", source=0))
    _drain(svc)
    m = svc.metrics()
    assert m["warm_joins"] == 0
    prog = fusion.fuse(U.bfs(0))
    req = svc.completed[-1]
    ref = engine.run_program(svc.graphs["g"], prog, engine="pallas",
                             source=0).value
    np.testing.assert_array_equal(np.asarray(req.value), np.asarray(ref))


def test_service_mutate_unknown_graph_raises(g):
    _S, svc = _service(g)
    with pytest.raises(KeyError, match="not resident"):
        svc.mutate_graph("nope", insert=([0], [1]))
