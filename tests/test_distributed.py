"""Distributed engine + sampler + partition tests on forced host devices.

These run in a SUBPROCESS with XLA_FLAGS=--xla_force_host_platform_device_count
because device count locks at first jax init (the main test process stays
1-device).  The shard_map cases carry the ``distributed`` marker and run in
the PR multi-device CI lane; only the heaviest also carry ``slow`` and stay
nightly-only (pyproject marker split)."""
import json

import numpy as np
import pytest

from conftest import run_forced_devices


def _run(code: str) -> str:
    return run_forced_devices(code, 4)


@pytest.mark.distributed
def test_distributed_engine_matches_oracle():
    out = _run("""
        import numpy as np, jax, json
        from repro.graph.structure import uniform_graph, undirected
        from repro.core import usecases as U, fusion, engine
        from repro.core.lang import paths_semantics
        mesh = jax.make_mesh((4,), ('data',))
        g = uniform_graph(9, 18, seed=3)
        ok = {}
        for name in ['SSSP','CC','WSP','NSP','Trust','RADIUS','RDS']:
            gg = undirected(g) if name=='CC' else g
            spec = U.ALL_SPECS[name]()
            want = paths_semantics(spec, gg, max_len=gg.n)
            if hasattr(want,'dtype') and want.dtype==object:
                want = np.array([float(x) for x in want])
            got = engine.run_program(gg, fusion.fuse(spec),
                                     engine='distributed', mesh=mesh).value
            w = np.nan_to_num(np.where(np.abs(np.asarray(want,np.float64))>=1e8,
                np.sign(np.asarray(want,np.float64))*np.inf, np.asarray(want,np.float64)),
                posinf=1e9, neginf=-1e9)
            gv = np.nan_to_num(np.where(np.abs(np.asarray(got,np.float64))>=1e8,
                np.sign(np.asarray(got,np.float64))*np.inf, np.asarray(got,np.float64)),
                posinf=1e9, neginf=-1e9)
            ok[name] = bool(np.allclose(w, gv, atol=1e-4))
        print(json.dumps(ok))
    """)
    ok = json.loads(out.strip().splitlines()[-1])
    assert all(ok.values()), ok


@pytest.mark.distributed
def test_compressed_cross_pod_allreduce():
    """int8 error-feedback all-reduce over a 'pod' axis ≈ exact mean."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        from repro.optim.compress import error_feedback_update, CompressState
        mesh = jax.make_mesh((4,), ('pod',))
        rng = np.random.default_rng(0)
        g_all = jnp.asarray(rng.normal(size=(4, 256)).astype(np.float32))
        def f(g, e):
            g, e = g[0], e[0]
            red, st = error_feedback_update({'w': g}, CompressState({'w': e}),
                                            'pod')
            return red['w'][None], st.error['w'][None]
        from repro.compat import shard_map
        fn = shard_map(f, mesh=mesh, in_specs=(P('pod'), P('pod')),
                       out_specs=(P('pod'), P('pod')))
        e0 = jnp.zeros((4, 256), jnp.float32)
        red, e1 = fn(g_all, e0)
        true = np.asarray(g_all).mean(axis=0)
        err = float(np.abs(np.asarray(red)[0] - true).max())
        scale = float(np.abs(np.asarray(g_all)).max() / 127.0)
        print(json.dumps({'err': err, 'bound': 4*scale}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["err"] <= rec["bound"], rec


@pytest.mark.distributed
def test_distributed_shard_stats_replicated():
    """The distributed engine asserts cross-shard replication of the
    iteration count (instead of silently trusting shard 0) and surfaces
    per-shard edge work whose sum is the total."""
    out = _run("""
        import numpy as np, jax, json
        from repro.core import usecases as U, fusion, engine
        from repro.graph.structure import uniform_graph
        mesh = jax.make_mesh((4,), ('data',))
        g = uniform_graph(12, 30, seed=7)
        res = engine.run_program(g, fusion.fuse(U.sssp(0)),
                                 engine='distributed', mesh=mesh)
        st = res.stats
        rec = {'shards': st.shards,
               'n_shard_work': len(st.shard_work),
               'sum_ok': abs(sum(st.shard_work) - st.edge_work) < 1e-6,
               'iters': st.iterations}
        print(json.dumps(rec))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert rec["shards"] == 4 and rec["n_shard_work"] == 4, rec
    assert rec["sum_ok"] and rec["iters"] > 0, rec


def test_neighbor_sampler_shapes_and_membership():
    from repro.graph.sampler import NeighborSampler, max_nodes_for
    from repro.graph.structure import rmat_graph
    g = rmat_graph(200, 1600, seed=0)
    fan = [4, 3]
    s = NeighborSampler(g, fan, seed=1)
    seeds = np.arange(8)
    batch = s.sample(seeds)
    assert batch.nodes.shape[0] == max_nodes_for(8, fan)
    assert len(batch.edge_src) == 2
    assert batch.edge_src[0].shape == batch.edge_dst[0].shape
    # sampled edges reference real in-neighbours
    src_g, dst_g, _, _ = g.host_edges()
    edge_set = set(zip(src_g.tolist(), dst_g.tolist()))
    hop = 1                                # seed-adjacent hop (last)
    srcs = batch.nodes[batch.edge_src[hop]]
    dsts = batch.nodes[batch.edge_dst[hop]]
    mask = batch.edge_mask[hop]
    ok = sum((int(a), int(b)) in edge_set
             for a, b, m in zip(srcs, dsts, mask) if m)
    tot = int(np.sum(mask))
    assert tot == 0 or ok / tot > 0.99


def test_partition_covers_all_edges():
    from repro.graph.partition import partition_edges
    from repro.graph.structure import rmat_graph
    g = rmat_graph(50, 300, seed=2)
    part = partition_edges(g, 4)
    assert int(np.sum(np.asarray(part.mask))) == g.num_edges
    src_g, dst_g, _, _ = g.host_edges()
    got = sorted(zip(np.asarray(part.src)[np.asarray(part.mask)].tolist(),
                     np.asarray(part.dst)[np.asarray(part.mask)].tolist()))
    want = sorted(zip(src_g.tolist(), dst_g.tolist()))
    assert got == want


@pytest.mark.distributed
@pytest.mark.slow                    # heaviest shard_map case: nightly-only
def test_mgn_dist_multishard_matches_reference():
    """Hillclimb B correctness: 4-shard vertex-cut MGN loss ≡ single-device
    reference on a real mesh graph."""
    out = _run("""
        import jax, jax.numpy as jnp, numpy as np, json
        from jax.sharding import PartitionSpec as P
        import repro.configs as C
        from repro.models import gnn as G
        from repro.data import graphs as DG
        from repro.data.graphs import dst_block_partition

        cfg = C.get('meshgraphnet').smoke()
        b = DG.mesh_batch(rows=8, cols=8, d_node_in=cfg.d_node_in,
                          d_edge_in=cfg.d_edge_in, d_out=cfg.d_out)
        key = jax.random.PRNGKey(0)
        p = G.mgn_init(cfg, key)
        ref = float(G.mgn_loss(cfg, p, b))

        k = 4
        n = b['node_x'].shape[0]
        src, dst = np.asarray(b['src']), np.asarray(b['dst'])
        part = dst_block_partition(src, dst, n, k, pad_factor=2.0)
        n_loc = part['n_loc']; npad = k * n_loc
        node_x = np.zeros((npad, cfg.d_node_in), np.float32)
        node_x[:n] = np.asarray(b['node_x'])
        target = np.zeros((npad, cfg.d_out), np.float32)
        target[:n] = np.asarray(b['target'])
        nmask = np.zeros(npad, bool); nmask[:n] = True
        ex = np.asarray(b['edge_x'])
        edge_x = np.zeros((k, part['e_pad'], cfg.d_edge_in), np.float32)
        blocks = dst // n_loc
        for j in range(k):
            sel = np.nonzero(blocks == j)[0][:part['e_pad']]
            edge_x[j, :len(sel)] = ex[sel]
        batch = {'node_x': jnp.asarray(node_x),
                 'edge_x': jnp.asarray(edge_x.reshape(-1, cfg.d_edge_in)),
                 'src': jnp.asarray(part['src'].reshape(-1)),
                 'dst': jnp.asarray(part['dst'].reshape(-1)),
                 'emask': jnp.asarray(part['mask'].reshape(-1)),
                 'nmask': jnp.asarray(nmask), 'target': jnp.asarray(target)}
        mesh = jax.make_mesh((4,), ('d',))
        bspecs = {kk: P('d', None) if v.ndim == 2 else P('d')
                  for kk, v in batch.items()}
        from repro.compat import shard_map
        fn = shard_map(
            lambda params, bb: G.mgn_loss_dist(cfg, params, bb, ('d',)),
            mesh=mesh, in_specs=(jax.tree.map(lambda _: P(), p), bspecs),
            out_specs=P(), check_vma=False)
        got = float(fn(p, batch))
        print(json.dumps({'ref': ref, 'got': got}))
    """)
    rec = json.loads(out.strip().splitlines()[-1])
    assert abs(rec["ref"] - rec["got"]) < 1e-4, rec
