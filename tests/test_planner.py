"""ExecutionPlan query planner (DESIGN.md §14).

The contract under test: every knob resolves in ONE place
(``plan_execution``) from cached per-graph statistics with caller kwargs as
hints; default plans reproduce the documented heuristics BITWISE (Gemini
SWITCH_K, dst-sorted resolution, auto direction), so planned execution is
bit-identical to the historical explicit-kwarg paths; identical decisions
hit identical executor-cache entries; and the recorded-stats feedback loop
adapts ``switch_k``/resolution only within bounds, only when opted in.
"""
import numpy as np
import pytest

from repro.core import engine, fusion, plan as P
from repro.core import usecases as U
from repro.graph import structure
from repro.kernels import ops as kops


@pytest.fixture
def g():
    return structure.uniform_graph(16, 48, seed=5, weighted=True)


class _FakeMesh:
    """Planning only reads ``mesh.devices`` (topology) and ``id(mesh)``
    (hint identity), so decision-table tests can model a multi-device mesh
    without forcing host devices."""

    def __init__(self, k):
        self.devices = np.empty((k,), dtype=object)


# ---------------------------------------------------------------------------
# Graph statistics (the planner's input)
# ---------------------------------------------------------------------------

def test_graph_stats_shape_and_skew(small_graphs):
    st_u = structure.graph_stats(small_graphs["uniform"])
    st_r = structure.graph_stats(small_graphs["rmat"])
    assert st_u.n == 9 and 0 < st_u.num_edges <= 18   # generator dedupes
    assert st_u.avg_degree == pytest.approx(st_u.num_edges / st_u.n)
    # R-MAT hubs: max degree further above the mean than a uniform draw
    assert st_r.degree_skew > st_u.degree_skew
    assert st_r.max_out_degree >= st_r.avg_degree
    assert st_u.device_count >= 1 and st_u.backend


def test_graph_stats_weight_range(small_graphs):
    st_w = structure.graph_stats(small_graphs["line"])    # weighted
    g_unw = structure.uniform_graph(9, 18, seed=3, weighted=False)
    st_u = structure.graph_stats(g_unw)
    assert st_w.weighted and st_w.w_min <= st_w.w_max
    assert not st_u.weighted and st_u.w_min == st_u.w_max == 1.0


def test_graph_stats_memoized(g):
    assert structure.graph_stats(g) is structure.graph_stats(g)
    assert engine.program_cache_stats()["graph_stats"] >= 1


# ---------------------------------------------------------------------------
# Decision table: hints, defaults, statistics-driven choices
# ---------------------------------------------------------------------------

def test_default_plan_reproduces_documented_heuristics(g):
    prog = fusion.fuse(U.bfs(0))
    plan = engine.plan_execution(g, prog, engine="pallas")
    assert plan.engine == "pallas"
    assert plan.direction == "auto"
    assert plan.switch_k == P.SWITCH_K == 20.0
    assert plan.dense_threshold == P.DENSE_FRONTIER == 0.05
    assert plan.push_resolution == P.PUSH_RESOLUTION == "sorted"
    assert plan.shard_strategy == "contiguous"
    assert plan.validate and plan.on_nonconverge == "raise"
    assert not plan.fallback and plan.divergence_sentinel


def test_engine_hints_and_defaults(g):
    prog = fusion.fuse(U.bfs(0))
    assert engine.plan_execution(g, prog).engine == "pull"
    assert engine.plan_execution(g, prog, default_engine="pallas").engine \
        == "pallas"
    assert engine.plan_execution(g, prog, engine="adaptive").engine \
        == "adaptive"
    with pytest.raises(ValueError, match="unknown engine"):
        engine.plan_execution(g, prog, engine="gpu_magic")


def test_auto_engine_follows_device_topology(g):
    prog = fusion.fuse(U.bfs(0))
    assert engine.plan_execution(g, prog, engine="auto").engine == "pallas"
    plan = engine.plan_execution(g, prog, engine="auto", mesh=_FakeMesh(4))
    assert plan.engine == "pallas_sharded"
    assert plan.push_resolution == "sorted"      # per-shard sorted stack:
    assert plan.resolution_hint is None          # same default everywhere
    assert engine.plan_execution(g, prog, engine="auto",
                                 mesh=_FakeMesh(1)).engine == "pallas"


def test_sharded_resolution_hints_first_class(g):
    """Resolution is engine-independent now that the sharded engine runs its
    own per-shard sorted stack: "sorted" is accepted (and the default),
    "scatter" pins the reference oracle, junk still raises the shared
    normalizer error."""
    prog = fusion.fuse(U.bfs(0))
    srt = engine.plan_execution(g, prog, engine="pallas_sharded",
                                push_resolution="sorted")
    assert srt.push_resolution == "sorted" and srt.resolution_hint == "sorted"
    sct = engine.plan_execution(g, prog, engine="pallas_sharded",
                                push_resolution="scatter")
    assert sct.push_resolution == "scatter"
    with pytest.raises(ValueError, match="push_resolution must be"):
        engine.plan_execution(g, prog, engine="pallas_sharded",
                              push_resolution="radix")


def test_knob_normalization_single_copy(g):
    prog = fusion.fuse(U.bfs(0))
    assert engine.plan_execution(g, prog, switch_k=None).switch_k is None
    assert engine.plan_execution(g, prog, switch_k=8).switch_k == 8.0
    with pytest.raises(ValueError, match="switch_k must be"):
        engine.plan_execution(g, prog, switch_k="fast")
    with pytest.raises(ValueError, match="switch_k must be > 0"):
        engine.plan_execution(g, prog, switch_k=-1)
    with pytest.raises(ValueError, match="push_resolution must be"):
        engine.plan_execution(g, prog, push_resolution="atomic")
    with pytest.raises(ValueError, match="dense_threshold only governs"):
        engine.plan_execution(g, prog, switch_k=5.0, dense_threshold=0.5)
    with pytest.raises(ValueError, match="on_nonconverge must be"):
        engine.plan_execution(g, prog, on_nonconverge="retry")
    with pytest.raises(ValueError, match="unknown model"):
        engine.plan_execution(g, prog, engine="pallas", model="sideways")
    with pytest.raises(ValueError, match="unknown shard strategy"):
        engine.plan_execution(g, prog, shard_strategy="random")


def test_model_hint_forces_direction(g):
    prog = fusion.fuse(U.bfs(0))
    for model, want in [(None, "auto"), ("pull", "pull"), ("push+", "push")]:
        got = engine.plan_execution(g, prog, engine="pallas", model=model)
        assert got.direction == want
    # reference engines take the model directly; direction stays "auto"
    assert engine.plan_execution(g, prog, engine="pull",
                                 model="pull+").direction == "auto"


def test_program_kind_is_source_free(g):
    k0 = P.program_kind(fusion.fuse(U.bfs(0)))
    k3 = P.program_kind(fusion.fuse(U.bfs(3)))
    ks = P.program_kind(fusion.fuse(U.sssp(0)))
    assert k0 == k3                      # every source shares one identity
    assert k0 != ks                      # distinct shapes stay distinct
    kd = P.program_kind(U.handwritten_sssp(0))
    assert kd[0] == "direct" and kd != k0


# ---------------------------------------------------------------------------
# Determinism + cache identity
# ---------------------------------------------------------------------------

def test_plan_determinism_and_cache_hit(g):
    prog = fusion.fuse(U.bfs(0))
    p1 = engine.plan_execution(g, prog, engine="pallas")
    p2 = engine.plan_execution(g, prog, engine="pallas")
    assert p1 is p2                      # LRU hit: same frozen plan object
    assert engine.program_cache_stats()["plans"] >= 1
    # a different hint is a different plan, same normalized result
    p3 = engine.plan_execution(g, prog, engine="pallas", switch_k=20.0)
    assert p3 is not p1 and p3.switch_k == p1.switch_k


def test_identical_decisions_share_executor_cache_entries(g):
    """The tentpole cache contract: plan-lowered execution and the legacy
    explicit-kwarg kernels API produce THE SAME ``_EXEC_CACHE`` keys, so
    identical decisions never compile twice."""
    prog = fusion.fuse(U.bfs(0))
    engine.run_program(g, prog, engine="pallas")
    n0 = kops.executor_cache_size()
    keys0 = set(kops._EXEC_CACHE)
    # the same round through the legacy kwarg surface: no new entry
    rnd = prog.rounds[0][1]
    synth, _ = engine._synthesize_timed(rnd)
    comps, plans = engine._round_runtime(rnd, synth)
    kops.iterate_pallas(g, comps, plans, direction="auto", switch_k="auto",
                        push_resolution="sorted")
    assert kops.executor_cache_size() == n0
    assert set(kops._EXEC_CACHE) == keys0
    # and re-planning the same query is also a no-op on the cache
    engine.run_program(g, prog, engine="pallas", source=5)
    assert kops.executor_cache_size() == n0


def test_degrade_plan_reresolves_engine_dependent_fields(g):
    prog = fusion.fuse(U.bfs(0))
    sharded = engine.plan_execution(g, prog, engine="pallas_sharded")
    assert sharded.push_resolution == "sorted"   # per-shard sorted default
    down = P.degrade_plan(sharded, "pallas")
    assert down.engine == "pallas"
    assert down.push_resolution == "sorted"   # hintless → sorted default
    assert down.switch_k == sharded.switch_k
    # an explicit caller hint survives the walk down the chain — both ways
    pinned = engine.plan_execution(g, prog, engine="pallas",
                                   push_resolution="scatter")
    assert P.degrade_plan(pinned, "adaptive").push_resolution == "scatter"
    assert P.degrade_plan(pinned, "pallas") is pinned
    pinned_sh = engine.plan_execution(g, prog, engine="pallas_sharded",
                                      push_resolution="scatter")
    assert P.degrade_plan(pinned_sh, "pallas").push_resolution == "scatter"


# ---------------------------------------------------------------------------
# Bitwise parity: planned vs explicit-kwarg execution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("eng", ["pull", "push", "adaptive", "dense",
                                 "pallas"])
def test_planned_matches_explicit_kwargs_bitwise(eng, small_graphs):
    for spec in (U.bfs(2), U.sssp(2), U.wp(2)):
        prog = fusion.fuse(spec)
        for g in (small_graphs["uniform"], small_graphs["rmat"]):
            got = engine.run_program(g, prog, engine=eng)
            want = engine.run_program(g, prog, engine=eng, model=None,
                                      switch_k=20.0, push_resolution="sorted"
                                      if eng == "pallas" else None)
            a, b = np.asarray(got.value), np.asarray(want.value)
            assert a.dtype == b.dtype and a.tobytes() == b.tobytes()
            assert got.stats.iterations == want.stats.iterations
            ka, kb = got.stats.plan.knobs(), want.stats.plan.knobs()
            ka.pop("resolution_hint"), kb.pop("resolution_hint")
            assert ka == kb              # raw hints differ; decisions don't


def test_direct_planned_matches_explicit_bitwise(g):
    dk = U.handwritten_sssp(3)
    got = engine.run_direct(g, dk, engine="pallas")
    want = engine.run_direct(g, dk, engine="pallas", switch_k=20.0,
                             push_resolution="sorted")
    a, b = np.asarray(got.value), np.asarray(want.value)
    assert a.tobytes() == b.tobytes()
    assert got.stats.iterations == want.stats.iterations


# ---------------------------------------------------------------------------
# ExecStats.plan + explain mode
# ---------------------------------------------------------------------------

def test_exec_stats_record_plan_on_every_entry_point(g):
    prog = fusion.fuse(U.bfs(0))
    r = engine.run_program(g, prog, engine="pallas")
    assert r.stats.plan.engine == "pallas"
    outs = engine.run_program_batch(g, prog, [0, 2], engine="pallas")
    assert all(o.stats.plan.batch_lane == "vmapped" for o in outs)
    d = engine.run_direct(g, U.handwritten_sssp(0), engine="pull")
    assert d.stats.plan.engine == "pull"
    # every resolved knob is reported by name
    assert set(r.stats.plan.knobs()) >= {
        "engine", "model", "direction", "switch_k", "dense_threshold",
        "push_resolution", "shard_strategy", "axes", "batch_size",
        "batch_lane", "validate", "on_nonconverge", "fallback",
        "divergence_sentinel"}


def test_explain_reports_plan_and_driving_statistics(g):
    prog = fusion.fuse(U.sssp(0))
    before = engine.program_cache_stats()["feedback"]
    ex = engine.run_program(g, prog, engine="pallas", explain=True)
    assert isinstance(ex, P.PlanExplanation)
    assert ex.plan.engine == "pallas"
    assert ex.stats is structure.graph_stats(g)
    for field in ("engine", "direction", "switch_k", "push_resolution",
                  "shard_strategy"):
        assert field in ex.decisions
    # explain never executes: no feedback recorded
    assert engine.program_cache_stats()["feedback"] == before
    exd = engine.run_direct(g, U.handwritten_sssp(0), engine="pull",
                            explain=True)
    assert exd.plan.engine == "pull"
    exb = engine.run_program_batch(g, prog, [0, 1], explain=True)
    assert exb.plan.batch_lane == "vmapped" and exb.plan.batch_size == 2


# ---------------------------------------------------------------------------
# Heterogeneous-batch degradation: an explicit, recorded decision
# ---------------------------------------------------------------------------

def test_sequential_batch_lane_is_recorded(g):
    prog = fusion.fuse(U.bfs(0))
    outs = engine.run_program_batch(g, prog, [0, 3], engine="pull")
    want = [engine.run_program(g, prog, engine="pull", source=s)
            for s in (0, 3)]
    for o, w in zip(outs, want):
        assert np.asarray(o.value).tobytes() == np.asarray(w.value).tobytes()
        assert o.stats.plan.batch_lane == "sequential"
        frm, to, why = o.stats.fallbacks[0]
        assert frm == "batch[2]:pull" and to == "sequential:pull"
        assert "no batched fixpoint" in why
    douts = engine.run_direct(g, U.handwritten_sssp(0), engine="adaptive",
                              sources=[1, 4])
    assert all(o.stats.fallbacks[0][0] == "batch[2]:adaptive" for o in douts)


# ---------------------------------------------------------------------------
# Recorded-stats feedback loop
# ---------------------------------------------------------------------------

def test_feedback_recorded_per_graph_and_kind(g):
    prog = fusion.fuse(U.bfs(0))
    engine.run_program(g, prog, engine="pallas", source=1)
    kind = P.program_kind(prog)
    rec = P.feedback_for(g, kind)
    assert rec is not None and rec.queries == 1
    assert rec.iterations == rec.push_iters + rec.pull_iters > 0
    engine.run_program(g, prog, engine="pallas", source=2)
    assert rec.queries == 2
    # a different shape gets its own record
    assert P.feedback_for(g, P.program_kind(fusion.fuse(U.sssp(0)))) is None


def test_adapted_switch_k_stays_within_bounds():
    lo = P.SWITCH_K / P.ADAPT_SPAN
    hi = P.SWITCH_K * P.ADAPT_SPAN
    for push, total in [(0, 1), (1, 1), (99, 100), (1, 100), (50, 100)]:
        rec = P.FeedbackRecord(queries=1, iterations=total, push_iters=push,
                               pull_iters=total - push)
        k = P._adapted_switch_k(rec)
        assert lo <= k <= hi
    all_push = P.FeedbackRecord(queries=1, iterations=10, push_iters=10)
    no_push = P.FeedbackRecord(queries=1, iterations=10, push_iters=0)
    assert P._adapted_switch_k(all_push) == P.SWITCH_K / 2
    assert P._adapted_switch_k(no_push) == P.SWITCH_K * 2
    assert P._adapted_switch_k(P.FeedbackRecord()) == P.SWITCH_K


def test_adaptive_plans_consult_feedback_only_when_opted_in(g):
    prog = fusion.fuse(U.bfs(0))
    engine.run_program(g, prog, engine="pallas", source=0)
    rec = P.feedback_for(g, P.program_kind(prog))
    assert rec is not None
    # force a decisive push fraction so adaptation must move k
    rec.iterations, rec.push_iters, rec.pull_iters = 10, 10, 0
    rec.epoch += 1
    dflt = engine.plan_execution(g, prog, engine="pallas")
    assert dflt.switch_k == P.SWITCH_K          # default stays bitwise-stable
    adapted = engine.plan_execution(g, prog, engine="pallas", adaptive=True)
    assert adapted.switch_k == P.SWITCH_K / 2
    # an explicit hint always beats feedback
    pinned = engine.plan_execution(g, prog, engine="pallas", adaptive=True,
                                   switch_k=7.0)
    assert pinned.switch_k == 7.0


def test_adaptive_resolution_flip_needs_observed_waste():
    wasteful = P.FeedbackRecord(queries=3, iterations=9, push_iters=6,
                                pull_iters=3, edge_work=100.0,
                                resolve_work=500.0)
    lean = P.FeedbackRecord(queries=3, iterations=9, push_iters=6,
                            pull_iters=3, edge_work=500.0,
                            resolve_work=100.0)
    assert P._adapted_resolution(wasteful) == "scatter"
    assert P._adapted_resolution(lean) is None


def test_adaptive_execution_stays_correct(g):
    """Adaptation may change the direction SCHEDULE, never the value:
    idempotent rounds are bitwise direction-independent per iteration."""
    prog = fusion.fuse(U.bfs(1))
    base = engine.run_program(g, prog, engine="pallas")
    for _ in range(3):
        r = engine.run_program(g, prog, engine="pallas", adaptive=True)
        assert np.asarray(r.value).tobytes() == \
            np.asarray(base.value).tobytes()
    lo = P.SWITCH_K / P.ADAPT_SPAN
    hi = P.SWITCH_K * P.ADAPT_SPAN
    assert r.stats.plan.switch_k is None or lo <= r.stats.plan.switch_k <= hi


def test_nonidempotent_shapes_never_adapt(g):
    dk = U.handwritten_pagerank(g.n)
    assert not P._prog_idempotent(dk)
    r = engine.run_direct(g, dk, engine="pallas", adaptive=True)
    assert r.stats.plan.switch_k == P.SWITCH_K


# ---------------------------------------------------------------------------
# Cache plumbing (satellite 2)
# ---------------------------------------------------------------------------

def test_cache_stats_and_per_graph_eviction(g):
    g2 = structure.uniform_graph(12, 30, seed=7)
    prog = fusion.fuse(U.bfs(0))
    engine.run_program(g, prog, engine="pallas")
    engine.run_program(g2, prog, engine="pallas")
    st = engine.program_cache_stats()
    assert st["plans"] >= 2 and st["feedback"] >= 2 and st["graph_stats"] == 2
    dropped = engine.clear_graph_caches(g)
    assert dropped > 0
    st2 = engine.program_cache_stats()
    assert st2["graph_stats"] == 1
    assert P.feedback_for(g, P.program_kind(prog)) is None
    assert P.feedback_for(g2, P.program_kind(prog)) is not None
    engine.clear_program_caches()
    st3 = engine.program_cache_stats()
    assert st3["plans"] == st3["feedback"] == st3["graph_stats"] == 0


def test_plan_caches_are_lru_bounded(g):
    prog = fusion.fuse(U.bfs(0))
    for k in range(P._PLAN_CACHE_MAX + 16):
        engine.plan_execution(g, prog, switch_k=float(k + 1))
    assert P.plan_cache_size() <= P._PLAN_CACHE_MAX


def test_service_adaptive_serving_stays_bitwise(g):
    from repro.launch import service as S
    svc = S.AnalyticsService(S.ServiceConfig(max_batch=4, chunk_iters=3,
                                             adaptive=True))
    svc.add_graph("g", g)
    svc.register("BFS", U.bfs)
    svc.register("SSSP", U.sssp)
    arrivals = S.open_loop_arrivals(
        24, rate=800.0, seed=11, make_request=S.standard_mix("g", g.n))
    svc.run_open_loop(arrivals)
    assert S.verify_sequential(svc) == 24
    assert engine.program_cache_stats()["feedback"] >= 1
