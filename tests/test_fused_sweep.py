"""The fused single-launch Pallas sweep (DESIGN.md §2).

Covers the acceptance contract of the fused execution layer:

* multi-level lexicographic plans (WSP/DRR-style) on the pallas engine are
  bit-compatible with the pull engine and the dense oracle engine,
* one engine iteration of ANY fused plan executes exactly ONE
  ``pallas_call`` at runtime; a forced direction traces exactly 1 per
  round, the direction-optimized default traces 2 (one per lax.cond
  branch) while still executing one per iteration (``SWEEP_STATS``
  trace-time launch counters + runtime direction counters),
* frontier-skipped tiles (no active source) return identities bit-for-bit,
* cross-tile lexicographic resolution on graphs whose padded width spans
  several slot tiles,
* the compiled-executor cache reuses traced fixpoints across repeats.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph import segment
from repro.graph.structure import (blocked_ell_cached, from_edges, rmat_graph,
                                   to_blocked_ell, uniform_graph)
from repro.kernels import edge_reduce as er

MULTI_LEVEL = ["WSP", "NSP", "Trust", "DRR", "RDS"]
PRIM_ONLY = ["SSSP", "BFS", "WP", "REACH"]


def _run(g, name, eng):
    prog = fusion.fuse(U.ALL_SPECS[name]())
    return engine.run_program(g, prog, engine=eng)


def _cold():
    engine.clear_program_caches()
    er.reset_sweep_stats()


# ---------------------------------------------------------------------------
# multi-level lex plans: pallas ≡ pull ≡ dense
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", MULTI_LEVEL)
def test_fused_lex_matches_pull_and_dense(name, small_graphs):
    g = small_graphs["rmat"]
    a = _run(g, name, "pull").value
    b = _run(g, name, "pallas").value
    c = _run(g, name, "dense").value
    np.testing.assert_allclose(np.asarray(a, np.float64),
                               np.asarray(b, np.float64), atol=1e-4)
    np.testing.assert_allclose(np.asarray(c, np.float64),
                               np.asarray(b, np.float64), atol=1e-4)


def test_fused_lex_cross_tile_resolution():
    """Hub graph: one vertex with 300 predecessors ⇒ width spans 3 slot
    tiles, so lexicographic ties must resolve across tile boundaries."""
    rng = np.random.default_rng(7)
    src = np.concatenate([np.arange(1, 301), np.ones(150, np.int64), [0]])
    dst = np.concatenate([np.zeros(300, np.int64), np.arange(2, 152), [301]])
    w = rng.integers(1, 9, size=src.shape[0]).astype(np.float32)
    c = rng.integers(1, 9, size=src.shape[0]).astype(np.float32)
    g = from_edges(302, src, dst, w, c)
    assert to_blocked_ell(g).width > 128
    for name in ("SSSP", "WSP", "NSP", "Trust"):
        a = _run(g, name, "pull").value
        b = _run(g, name, "pallas").value
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-4)


# ---------------------------------------------------------------------------
# launch counting: ≤ 2 per iteration, exactly 1 for Prim-only plans
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", PRIM_ONLY)
def test_prim_only_plans_single_launch(name, small_graphs):
    """BFS/SSSP/WP/REACH with a forced direction: exactly ONE pallas_call
    per engine iteration (the while_loop body traces once, so trace-time
    launch counts ARE the per-iteration launch counts)."""
    for model, counter in (("pull", "pull_launches"), ("push", "push_launches")):
        _cold()
        prog = fusion.fuse(U.ALL_SPECS[name]())
        res = engine.run_program(small_graphs["rmat"], prog, engine="pallas",
                                 model=model)
        assert res.stats.rounds == 1
        assert er.SWEEP_STATS["launches"] == 1
        assert er.SWEEP_STATS[counter] == 1


@pytest.mark.parametrize("name", PRIM_ONLY)
def test_prim_only_auto_traces_one_sweep_per_direction(name, small_graphs):
    """The direction-optimized default traces BOTH lax.cond branches — one
    pull and one push pallas_call per round — but executes exactly one sweep
    per iteration at runtime (pull_iters + push_iters == iterations)."""
    _cold()
    res = _run(small_graphs["rmat"], name, "pallas")
    assert res.stats.rounds == 1
    assert er.SWEEP_STATS["launches"] == 2
    assert er.SWEEP_STATS["pull_launches"] == 1
    assert er.SWEEP_STATS["push_launches"] == 1
    assert (er.SWEEP_STATS["pull_iters"] + er.SWEEP_STATS["push_iters"]
            == res.stats.iterations)


@pytest.mark.parametrize("name", MULTI_LEVEL)
def test_fused_plans_at_most_two_launches_per_round(name, small_graphs):
    """Any fused plan (multi-level lex, non-idempotent with has-pred probe,
    multi-plan rounds like Trust's 4 reductions) traces ≤ 2 launches per
    round — one per admissible direction; non-idempotent rounds keep the
    single pull− sweep.  A forced direction is always exactly 1 per round."""
    _cold()
    res = _run(small_graphs["rmat"], name, "pallas")
    assert er.SWEEP_STATS["launches"] <= 2 * res.stats.rounds
    _cold()
    res = engine.run_program(small_graphs["rmat"],
                             fusion.fuse(U.ALL_SPECS[name]()),
                             engine="pallas", model="pull")
    assert er.SWEEP_STATS["launches"] == res.stats.rounds


def test_haspred_probe_is_fused(small_graphs):
    """NSP's secondary is a non-idempotent sum ⇒ pull− model with the
    has-pred probe — still one launch per iteration."""
    _cold()
    _run(small_graphs["rmat"], "NSP", "pallas")
    assert er.SWEEP_STATS["launches"] == 1


def test_pagerank_direct_pallas_single_launch(small_graphs):
    """PageRank (non-idempotent sum + epilogue, Fig. 4b direct kernels):
    pull− recompute with the fused has-pred probe — one launch, matching
    the pull engine."""
    from repro.core.synthesis import pagerank_kernels
    g = small_graphs["rmat"]
    dk = pagerank_kernels(g.n)
    a = engine.run_direct(g, dk, engine="pull").value
    _cold()
    b = engine.run_direct(g, dk, engine="pallas").value
    assert er.SWEEP_STATS["launches"] == 1
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_all_specs_match_pull(small_graphs):
    """The full use-case suite: pallas ≡ pull bit-for-bit through norm_inf."""
    from conftest import norm_inf
    from repro.graph.structure import undirected
    for name in U.ALL_SPECS:
        g = small_graphs["uniform"]
        g = undirected(g) if name == "CC" else g
        a = _run(g, name, "pull").value
        b = _run(g, name, "pallas").value
        np.testing.assert_allclose(norm_inf(a), norm_inf(b), atol=1e-4,
                                   err_msg=name)


# ---------------------------------------------------------------------------
# frontier-aware tile skipping
# ---------------------------------------------------------------------------

def test_frontier_skipped_tiles_return_identities():
    """Tiles with zero active sources must emit the reduction identities
    bit-for-bit (the pl.when short-circuit path)."""
    g = uniform_graph(48, 300, seed=2)
    ell = to_blocked_ell(g)
    rng = np.random.default_rng(2)
    state = jnp.asarray(rng.uniform(1, 9, ell.n_pad).astype(np.float32))
    ident = float(segment.identity("min", jnp.float32))
    outdeg = jnp.ones(ell.n_pad, jnp.float32)

    # no active sources at all: every tile must short-circuit
    active = jnp.zeros(ell.n_pad, jnp.int32)
    tile_act = er.tile_activity(ell.srcs, ell.mask, ell.tile_nnz, active,
                                ell.block_v, ell.block_e)
    assert not np.asarray(tile_act).any()
    red, _, cands = er.fused_ell_sweep(
        ell.srcs, ell.weight, ell.capacity, ell.mask, tile_act,
        {0: state}, active, outdeg, plans=(((0, "min"),),), idents={0: ident},
        p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n,
        return_candidates=True)
    assert np.all(np.asarray(cands[0]) == np.float32(ident))
    assert np.all(np.asarray(red[0]) == np.float32(ident))


def test_frontier_partial_skip_matches_full_sweep():
    """A sparse frontier must give the same reduction as running every tile
    (identity contributions are absorbed by the monoid)."""
    g = uniform_graph(64, 400, seed=5)
    ell = to_blocked_ell(g)
    rng = np.random.default_rng(5)
    state = jnp.asarray(rng.uniform(1, 9, ell.n_pad).astype(np.float32))
    ident = float(segment.identity("min", jnp.float32))
    outdeg = jnp.ones(ell.n_pad, jnp.float32)
    active = jnp.asarray((rng.random(ell.n_pad) < 0.1).astype(np.int32))
    kw = dict(plans=(((0, "min"),),), idents={0: ident},
              p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n)
    tile_act = er.tile_activity(ell.srcs, ell.mask, ell.tile_nnz, active,
                                ell.block_v, ell.block_e)
    red_skip, _ = er.fused_ell_sweep(ell.srcs, ell.weight, ell.capacity,
                                     ell.mask, tile_act, {0: state}, active,
                                     outdeg, **kw)
    all_tiles = jnp.ones_like(ell.tile_nnz, jnp.int32)
    red_full, _ = er.fused_ell_sweep(ell.srcs, ell.weight, ell.capacity,
                                     ell.mask, all_tiles, {0: state}, active,
                                     outdeg, **kw)
    np.testing.assert_array_equal(np.asarray(red_skip[0]),
                                  np.asarray(red_full[0]))


def test_tile_nnz_marks_padding_tiles():
    g = rmat_graph(64, 256, seed=3)          # power-law: padded tail tiles
    ell = to_blocked_ell(g)
    nnz = np.asarray(ell.tile_nnz)
    mask = np.asarray(ell.mask)
    n_i, n_j = nnz.shape
    want = mask.reshape(n_i, ell.block_v, n_j, ell.block_e).sum(axis=(1, 3))
    np.testing.assert_array_equal(nnz, want)


# ---------------------------------------------------------------------------
# compiled-program cache
# ---------------------------------------------------------------------------

def test_executor_cache_reused_across_repeats(small_graphs):
    from repro.kernels import ops as kops
    _cold()
    g = small_graphs["rmat"]
    r1 = _run(g, "WSP", "pallas")
    n_exec = kops.executor_cache_size()
    launches = er.SWEEP_STATS["launches"]
    assert n_exec >= 1
    r2 = _run(g, "WSP", "pallas")            # repeat: no new trace
    assert kops.executor_cache_size() == n_exec
    assert er.SWEEP_STATS["launches"] == launches
    np.testing.assert_array_equal(np.asarray(r1.value), np.asarray(r2.value))


def test_ell_cache_keyed_on_graph_identity(small_graphs):
    g1 = small_graphs["rmat"]
    g2 = small_graphs["uniform"]
    assert blocked_ell_cached(g1) is blocked_ell_cached(g1)
    assert blocked_ell_cached(g1) is not blocked_ell_cached(g2)


def test_cache_stats_and_clear(small_graphs):
    _cold()
    assert engine.program_cache_stats()["pallas_executors"] == 0
    _run(small_graphs["rmat"], "SSSP", "pallas")
    stats = engine.program_cache_stats()
    assert stats["pallas_executors"] >= 1 and stats["synth_rounds"] >= 1
    engine.clear_program_caches()
    assert engine.program_cache_stats()["pallas_executors"] == 0
