"""Launch-layer tests: workload construction for all 40 cells (abstract
only — instant), plus a reduced-config lower+compile smoke on a small
forced-device mesh in a subprocess."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_all_cells_enumerate():
    import repro.configs as configs
    from repro.launch.workloads import all_cells
    cells = all_cells()
    assert len(cells) == 40
    skips = [c for c in cells if c[2]]
    # 4 pure full-attention LMs skip long_500k (llama3.2/qwen2/yi/deepseek;
    # MLA is compressed-KV FULL attention) — llama4's chunked attn runs it
    assert len(skips) == 4
    assert all(s == "long_500k" for _, s, _ in skips)
    assert not any(a == "llama4-maverick-400b-a17b" for a, _, _ in skips)


def test_skip_reasons():
    import repro.configs as configs
    assert configs.skip_reason("llama3.2-3b", "long_500k") is not None
    assert configs.skip_reason("llama4-maverick-400b-a17b",
                               "long_500k") is None     # chunked attn runs
    assert configs.skip_reason("deepseek-v3-671b", "long_500k") is not None
    assert configs.skip_reason("gat-cora", "molecule") is None


def test_sanitize_spec():
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.launch.workloads import _sanitize_spec
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    m = FakeMesh()
    # 24 heads can't split 16 ways → dropped
    assert _sanitize_spec(m, P(None, "model", None), (3072, 24, 128)) == \
        P(None, None, None)
    # tuple prefix fallback: batch 32 over pod·data=32 keeps both
    assert _sanitize_spec(m, P(("pod", "data"), None), (32, 128)) == \
        P(("pod", "data"), None)
    # batch 16 over pod·data → falls back to ("pod",)
    got = _sanitize_spec(m, P(("pod", "data"), None), (16, 128))
    assert got == P("pod", None)
    # batch 1 → unsharded
    assert _sanitize_spec(m, P(("pod", "data")), (1,)) == P(None)


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.dryrun import collective_bytes
    hlo = textwrap.dedent("""\
        HloModule test
        %region_body (arg: (s32[], f32[128])) -> (s32[], f32[128]) {
          %p = f32[128]{0} parameter(0)
          %ar = f32[128]{0} all-reduce(%p), replica_groups={}
          ROOT %t = (s32[], f32[128]) tuple(%ar, %ar)
        }
        %region_cond (arg: (s32[], f32[128])) -> pred[] {
          %c = s32[] constant(7)
          ROOT %cmp = pred[] compare(%c, %c), direction=LT
        }
        ENTRY %main (x: f32[128]) -> f32[128] {
          %x = f32[128]{0} parameter(0)
          %ag = f32[256]{0} all-gather(f32[128]{0} %x), dimensions={0}
          %w = (s32[], f32[128]) while(%x), condition=%region_cond, body=%region_body
          ROOT %r = f32[128]{0} get-tuple-element(%w), index=1
        }
    """)
    out, top = collective_bytes(hlo)
    assert out["all-gather"]["count"] == 1
    assert out["all-gather"]["operand_bytes"] == 128 * 4
    # the while body's all-reduce is weighted by the trip count 7
    assert out["all-reduce"]["count"] == 7
    assert out["all-reduce"]["operand_bytes"] == 7 * 128 * 4
    assert top and top[0]["kind"] == "all-reduce" and top[0]["trips"] == 7


@pytest.mark.slow
def test_smoke_dryrun_cells_compile():
    """Reduced-config lower+compile for one cell per family on a 4-device
    mesh (subprocess: forced host devices)."""
    code = """
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, json
        import repro.configs
        import repro.launch.workloads as W
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        done = {}
        for arch, shape, variant in [
                ("llama3.2-3b", "train_4k", "baseline"),
                ("deepseek-v3-671b", "decode_32k", "baseline"),
                ("qwen2-72b", "decode_32k", "kvq"),
                ("gat-cora", "full_graph_sm", "baseline"),
                ("meshgraphnet", "molecule", "dist"),
                ("egnn", "full_graph_sm", "dist"),
                ("dlrm-rm2", "retrieval_cand", "baseline")]:
            wl = W.build_workload(arch, shape, mesh, smoke=True,
                                  variant=variant)
            with mesh:
                c = jax.jit(wl.step_fn, in_shardings=wl.in_shardings,
                            out_shardings=wl.out_shardings,
                            donate_argnums=wl.donate).lower(
                                *wl.abstract_args).compile()
            ca = c.cost_analysis()
            ca = ca[0] if isinstance(ca, (list, tuple)) else ca
            done[f"{arch}:{shape}"] = ca.get("flops", 0) > 0
        print(json.dumps(done))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = _SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    done = json.loads(out.stdout.strip().splitlines()[-1])
    assert all(done.values()), done


def test_roofline_derivation_from_record():
    from benchmarks.roofline import derive
    rec = {
        "arch": "x", "shape": "train", "status": "ok", "kind": "train",
        "devices": 256,
        "analysis_cost": {"flops": 1e18, "bytes accessed": 1e15},
        "cost_analysis": {},
        "collectives": {"all-reduce": {"count": 1, "operand_bytes": 50e9}},
        "meta": {"model_flops": 5e17},
        "memory_analysis": {"temp_size_in_bytes": 1} ,
    }
    d = derive(rec)
    assert abs(d["t_compute_s"] - 1e18 / (256 * 197e12)) < 1e-9
    assert abs(d["t_collective_s"] - 1.0) < 1e-9
    assert d["dominant"] == "compute"
    assert 0 < d["roofline_frac"] <= 1
    assert abs(d["useful_flops_frac"] - 0.5) < 1e-9
