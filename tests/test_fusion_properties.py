"""Hypothesis property tests: fusion is semantics-preserving (Thm. 1) on
RANDOM specifications over random graphs, and the engines agree with the
oracle on randomly generated spec trees — the paper's core guarantee as a
property-based test."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import engine, fusion
from repro.core import lang as L
from repro.core.lang import paths_semantics
from repro.graph.structure import uniform_graph

from conftest import norm_inf

# ---------------------------------------------------------------------------
# random specification generator (core grammar of Fig. 6)
# ---------------------------------------------------------------------------

_pathfns = st.sampled_from([(L.WEIGHT, "min"), (L.LENGTH, "min"),
                            (L.CAPACITY, "max"), (L.CAPACITY, "min"),
                            (L.HEAD, "min")])


@st.composite
def m_terms(draw, depth=0):
    f, r = draw(_pathfns)
    src = draw(st.sampled_from([0, 1, None]))
    if f.kind == "head":
        src = None
    base = L.PathReduce(r, f, L.AllPaths(src))
    if depth >= 2:
        return base
    kind = draw(st.sampled_from(["leaf", "nested", "bin"]))
    if kind == "leaf":
        return base
    if kind == "nested" and src is not None:
        f2, r2 = draw(st.sampled_from([(L.LENGTH, "min"),
                                       (L.WEIGHT, "min")]))
        return L.PathReduce(r, f, L.ArgsRestrict(r2, f2, L.AllPaths(src)))
    op = draw(st.sampled_from(["+", "max", "min"]))
    return L.MBin(op, base, draw(m_terms(depth + 1)))


@st.composite
def r_terms(draw):
    m = draw(m_terms())
    red = draw(st.sampled_from(["min", "max", "sum"]))
    base = L.VertexReduce(red, m)
    if draw(st.booleans()):
        return base
    op = draw(st.sampled_from(["+", "max", "min"]))
    return L.RBin(op, base, L.VertexReduce(
        draw(st.sampled_from(["min", "max"])), draw(m_terms())))


@settings(max_examples=25, deadline=None)
@given(spec=m_terms(), seed=st.integers(0, 5))
def test_random_m_spec_fused_matches_oracle(spec, seed):
    g = uniform_graph(7, 14, seed=seed)
    want = paths_semantics(spec, g, max_len=g.n)
    if hasattr(want, "dtype") and want.dtype == object:
        want = np.array([float(x) for x in want])
    got = engine.run_program(g, fusion.fuse(spec), engine="pull").value
    np.testing.assert_allclose(norm_inf(got), norm_inf(want), atol=1e-3)


@settings(max_examples=20, deadline=None)
@given(spec=r_terms(), seed=st.integers(0, 3))
def test_random_r_spec_fused_equals_unfused(spec, seed):
    """Thm. 1 as a property: fused ≡ unfused on random r-terms."""
    g = uniform_graph(8, 18, seed=seed)
    fused = engine.run_program(g, fusion.fuse(spec), engine="pull").value
    unfused = engine.run_program(g, fusion.lower_unfused(spec),
                                 engine="pull").value
    np.testing.assert_allclose(norm_inf(fused), norm_inf(unfused), atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(spec=m_terms(), seed=st.integers(0, 3))
def test_random_spec_engines_agree(spec, seed):
    g = uniform_graph(7, 16, seed=seed)
    prog = fusion.fuse(spec)
    a = engine.run_program(g, prog, engine="pull").value
    b = engine.run_program(g, prog, engine="push").value
    c = engine.run_program(g, prog, engine="dense").value
    np.testing.assert_allclose(norm_inf(a), norm_inf(b), atol=1e-3)
    np.testing.assert_allclose(norm_inf(a), norm_inf(c), atol=1e-3)


# ---------------------------------------------------------------------------
# segment/scatter substrate invariants
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.integers(0, 7),
                          st.floats(-100, 100, allow_nan=False)),
                min_size=1, max_size=40),
       st.sampled_from(["min", "max", "sum"]))
def test_segment_reduce_matches_numpy(pairs, op):
    import jax.numpy as jnp
    from repro.graph import segment
    ids = np.array([p[0] for p in pairs], np.int32)
    vals = np.array([p[1] for p in pairs], np.float32)
    got = np.asarray(segment.segment_reduce(op, jnp.asarray(vals),
                                            jnp.asarray(ids), 8))
    for s in range(8):
        sel = vals[ids == s]
        if sel.size == 0:
            want = float(segment.identity(op, np.float32))
        else:
            want = {"min": np.min, "max": np.max, "sum": np.sum}[op](sel)
        assert np.isclose(got[s], want, rtol=1e-5, atol=1e-5), (s, op)


@settings(max_examples=50, deadline=None)
@given(st.sampled_from(["min", "max", "sum", "or", "and"]),
       st.lists(st.floats(-50, 50, allow_nan=False), min_size=2,
                max_size=16))
def test_scatter_and_combine_agree(op, vals):
    import jax.numpy as jnp
    from repro.graph import segment
    x = jnp.asarray(np.array(vals, np.float32))
    if op in ("or", "and"):
        x = (x > 0).astype(jnp.float32)
    ident = segment.identity(op, jnp.float32)
    init = jnp.full((1,), ident)
    ids = jnp.zeros(x.shape[0], jnp.int32)
    a = segment.scatter_reduce(op, init, x, ids)[0]
    b = x[0]
    for i in range(1, x.shape[0]):
        b = segment.combine(op, b, x[i])
    assert np.isclose(float(a), float(b), rtol=1e-5, atol=1e-5)
