"""Source-parameterized batched executors (DESIGN.md §8/§9).

The contract under test: the query source is a *traced argument* of the
compiled pallas executor — never a closure constant — so

* one ``_EXEC_CACHE`` entry (and zero re-traces) serves a sweep over many
  distinct sources of the same query shape,
* ``jax.vmap``-batched runs over a batch of sources are BIT-identical to
  the per-source sequential runs, under pull, push and auto directions,
* ``run_direct(engine="pallas")`` defaults to the documented per-iteration
  direction heuristic (regression: ``pull_like`` used to pin push),
* the executor cache is a true LRU (hits refresh recency),
* ``ExecStats.synth_ms`` is populated (cold > warm ≈ 0).
"""
import numpy as np
import pytest

from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph.structure import line_graph, rmat_graph
from repro.kernels import edge_reduce as er
from repro.kernels import ops as kops

BATCHABLE = {"BFS": U.bfs, "SSSP": U.sssp, "WP": U.wp}


def _cold():
    engine.clear_program_caches()
    er.reset_sweep_stats()


def _sources(g, k, seed):
    rng = np.random.default_rng(seed)
    return [int(s) for s in rng.choice(g.n, size=min(k, g.n), replace=False)]


# ---------------------------------------------------------------------------
# batched ≡ sequential, bit-for-bit, all directions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(BATCHABLE))
@pytest.mark.parametrize("model", [None, "pull", "push"])
def test_batched_matches_sequential_bitwise(name, model, small_graphs):
    """vmap-batched fixpoints must agree with per-source sequential runs
    bit-for-bit: the while_loop batching rule freezes converged queries via
    per-element carry selects, and the direction lax.cond lowers to a
    per-query select of identically-computed branch values."""
    g = small_graphs["rmat"]
    srcs = _sources(g, 6, seed=11)
    prog = fusion.fuse(BATCHABLE[name](srcs[0]))
    seq = [np.asarray(engine.run_program(g, prog, engine="pallas",
                                         model=model, source=s).value)
           for s in srcs]
    batch = engine.run_program_batch(g, prog, sources=srcs, engine="pallas",
                                     model=model)
    for s, got, want in zip(srcs, batch, seq):
        np.testing.assert_array_equal(np.asarray(got.value), want,
                                      err_msg=f"{name} model={model} src={s}")


def test_batched_direction_switch_bitwise():
    """Auto direction on a graph whose BFS frontier goes sparse: some
    queries take push iterations, and the batched select-of-both-branches
    still reproduces the sequential runs exactly."""
    g = line_graph(48, weighted=True, seed=3)
    prog = fusion.fuse(U.bfs_depth(0))
    srcs = [0, 7, 23, 40]
    seq = [engine.run_program(g, prog, engine="pallas", source=s)
           for s in srcs]
    assert any(r.stats.push_iters > 0 for r in seq)   # heuristic does switch
    batch = engine.run_program_batch(g, prog, sources=srcs, engine="pallas")
    for s, got, want in zip(srcs, batch, seq):
        np.testing.assert_array_equal(np.asarray(got.value),
                                      np.asarray(want.value),
                                      err_msg=f"src={s}")
        assert got.stats.iterations == want.stats.iterations
        assert got.stats.push_iters == want.stats.push_iters


@pytest.mark.parametrize("resolution", ["sorted", "scatter"])
def test_batched_matches_sequential_both_resolutions(resolution, small_graphs):
    """The dst-sorted push resolution composes with the vmapped executors:
    each resolution path's batched run is bit-identical to its own
    sequential runs AND the two paths agree bit-for-bit on the batch."""
    g = small_graphs["rmat"]
    srcs = _sources(g, 5, seed=13)
    prog = fusion.fuse(U.bfs(srcs[0]))
    seq = [np.asarray(engine.run_program(
        g, prog, engine="pallas", model="push", source=s,
        push_resolution=resolution).value) for s in srcs]
    batch = engine.run_program_batch(g, prog, sources=srcs, engine="pallas",
                                     model="push",
                                     push_resolution=resolution)
    other = engine.run_program_batch(g, prog, sources=srcs, engine="pallas",
                                     model="push",
                                     push_resolution=("scatter" if resolution
                                                      == "sorted" else
                                                      "sorted"))
    for s, got, alt, want in zip(srcs, batch, other, seq):
        np.testing.assert_array_equal(np.asarray(got.value), want,
                                      err_msg=f"src={s} {resolution}")
        np.testing.assert_array_equal(np.asarray(got.value),
                                      np.asarray(alt.value),
                                      err_msg=f"src={s} cross-resolution")
        assert got.stats.resolve_work > 0


def test_batched_reports_per_query_resolve_work(small_graphs):
    """Batched stats carry per-query resolution work, matching the
    sequential runs exactly (deterministic tile counts)."""
    g = small_graphs["rmat"]
    srcs = _sources(g, 4, seed=3)
    prog = fusion.fuse(U.sssp(srcs[0]))
    batch = engine.run_program_batch(g, prog, sources=srcs, engine="pallas")
    for s, got in zip(srcs, batch):
        want = engine.run_program(g, prog, engine="pallas", source=s)
        assert got.stats.resolve_work == want.stats.resolve_work
        assert got.stats.push_iters == want.stats.push_iters


def test_batched_matches_reference_engines(small_graphs):
    """The batched pallas path agrees with the pull reference engine (which
    run_program_batch uses as its sequential fallback) across sources."""
    g = small_graphs["uniform2"]
    srcs = _sources(g, 5, seed=2)
    prog = fusion.fuse(U.sssp(0))
    ref = engine.run_program_batch(g, prog, sources=srcs, engine="pull")
    got = engine.run_program_batch(g, prog, sources=srcs, engine="pallas")
    for s, a, b in zip(srcs, ref, got):
        np.testing.assert_allclose(np.asarray(a.value, np.float64),
                                   np.asarray(b.value, np.float64),
                                   atol=1e-5, err_msg=f"src={s}")


def test_run_direct_batched_matches_sequential(small_graphs):
    g = small_graphs["rmat"]
    dk = U.handwritten_sssp(0)
    srcs = _sources(g, 5, seed=7)
    batch = engine.run_direct(g, dk, engine="pallas", sources=srcs)
    for s, got in zip(srcs, batch):
        want = engine.run_direct(g, dk, engine="pallas", source=s)
        np.testing.assert_array_equal(np.asarray(got.value),
                                      np.asarray(want.value))
        assert got.stats.iterations == want.stats.iterations


def test_run_direct_source_override_needs_generic_kernels(small_graphs):
    from repro.core.synthesis import pagerank_kernels
    dk = pagerank_kernels(small_graphs["rmat"].n)      # sourceless
    with pytest.raises(ValueError, match="source-generic"):
        engine.run_direct(small_graphs["rmat"], dk, engine="pallas",
                          sources=[0, 1])


def test_run_direct_rejects_source_with_legacy_init(small_graphs):
    """A legacy 1-arg init closure bakes its source; pairing it with the
    ``source`` field would let an override move the ⊥-mask without moving
    the init value — must raise, never silently corrupt."""
    import jax.numpy as jnp
    from repro.core.synthesis import DirectKernels
    dk = DirectKernels(
        name="sssp", rop="min", dtype="float",
        p_fn=lambda env: env["n"] + env["w"],
        init_fn=lambda v: jnp.where(v == 3, 0.0, jnp.inf),   # baked source
        source=3)
    for kwargs in ({}, {"source": 5}, {"sources": [1, 2]}):
        with pytest.raises(ValueError, match="source-generic init_fn"):
            engine.run_direct(small_graphs["rmat"], dk, engine="pull",
                              **kwargs)


def test_run_program_batch_rejects_2d_sources(small_graphs):
    """[B, n_comps] per-component batching is the kernels-layer API; the
    engine wrapper takes a flat [B] source vector and must not silently
    flatten a 2-D array into B*n_comps separate queries."""
    prog = fusion.fuse(U.sssp(0))
    with pytest.raises(ValueError, match=r"\[B\] vector"):
        engine.run_program_batch(small_graphs["rmat"], prog,
                                 sources=np.array([[0, 1], [2, 3]]))


# ---------------------------------------------------------------------------
# cache stability: one executor, zero re-traces, across distinct sources
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["BFS", "SSSP"])
def test_executor_cache_stable_across_32_sources(name):
    """32 distinct sources of one query shape: exactly ONE executor cache
    entry, and the trace-time launch counters stop moving after the first
    query (zero re-traces — the bug this PR fixes gave one entry and one
    full while_loop retrace PER source).  The acceptance criterion of the
    source-parameterized executors, verbatim."""
    g = rmat_graph(64, 256, seed=9)
    _cold()
    results = {}
    for i, s in enumerate(_sources(g, 32, seed=5)):
        prog = fusion.fuse(BATCHABLE[name](s))         # fresh spec per source
        results[s] = engine.run_program(g, prog, engine="pallas")
        if i == 0:
            launches = er.SWEEP_STATS["launches"]
    assert len(results) == 32
    assert engine.program_cache_stats()["pallas_executors"] == 1
    assert er.SWEEP_STATS["launches"] == launches
    # sanity: different sources really produce different answers
    vals = [np.asarray(r.value) for r in results.values()]
    assert any(not np.array_equal(vals[0], v) for v in vals[1:])


def test_batched_run_adds_one_executor_entry(small_graphs):
    """A batched sweep compiles its own (vmapped) executor — one entry for
    ANY batch size, alongside the sequential entry."""
    g = small_graphs["rmat"]
    prog = fusion.fuse(U.sssp(0))
    _cold()
    engine.run_program_batch(g, prog, sources=[0, 1, 2], engine="pallas")
    assert engine.program_cache_stats()["pallas_executors"] == 1
    engine.run_program_batch(g, prog, sources=[3, 4, 5, 6], engine="pallas")
    assert engine.program_cache_stats()["pallas_executors"] == 1
    engine.run_program(g, prog, engine="pallas", source=7)
    assert engine.program_cache_stats()["pallas_executors"] == 2


def test_round_cache_source_free(small_graphs):
    """synthesize_round memoizes across sources too: the synthesized closure
    set (and hence the executor key) is shared by BFS(0) and BFS(5)."""
    _cold()
    for s in (0, 3, 5):
        engine.run_program(small_graphs["rmat"],
                           fusion.fuse(U.bfs_depth(s)), engine="pallas")
    assert engine.program_cache_stats()["synth_rounds"] == 1


# ---------------------------------------------------------------------------
# run_direct pallas direction regression
# ---------------------------------------------------------------------------

def test_run_direct_pallas_auto_direction():
    """Regression (engine.py pull_like omitted "pallas"): run_direct on the
    pallas engine must default to the per-iteration direction heuristic —
    on a sparse-frontier BFS both directions execute (dense first wave →
    pull, sparse tail → push), not push-pinned for every iteration."""
    g = line_graph(48, weighted=True, seed=3)
    dk = U.handwritten_bfs_depth(0)
    _cold()
    res = engine.run_direct(g, dk, engine="pallas")
    assert res.stats.pull_iters > 0, "auto must pull on the dense first wave"
    assert res.stats.push_iters > 0, "auto must push on the sparse tail"
    assert res.stats.pull_iters + res.stats.push_iters == res.stats.iterations
    # both traced branches present: pull and push sweeps in one executor
    assert er.SWEEP_STATS["pull_launches"] == 1
    assert er.SWEEP_STATS["push_launches"] == 1
    want = engine.run_direct(g, dk, engine="pull")
    np.testing.assert_array_equal(np.asarray(res.value),
                                  np.asarray(want.value))


def test_run_direct_model_forces_direction(small_graphs):
    """An explicit model pins the sweep (one traced launch per direction)."""
    g = small_graphs["rmat"]
    dk = U.handwritten_sssp(0)
    for model, counter in (("pull", "pull_launches"),
                           ("push", "push_launches")):
        _cold()
        res = engine.run_direct(g, dk, engine="pallas", model=model)
        assert er.SWEEP_STATS["launches"] == 1
        assert er.SWEEP_STATS[counter] == 1
        want = engine.run_direct(g, dk, engine="pull")
        np.testing.assert_array_equal(np.asarray(res.value),
                                      np.asarray(want.value))


# ---------------------------------------------------------------------------
# LRU cache behaviour + synth_ms
# ---------------------------------------------------------------------------

def test_exec_cache_is_lru(small_graphs, monkeypatch):
    """Hits refresh recency: with capacity 2, re-touching the oldest entry
    before inserting a third must evict the *untouched* entry (FIFO would
    evict the hot one — the serving-churn bug)."""
    g = small_graphs["rmat"]
    _cold()
    monkeypatch.setattr(kops, "_EXEC_CACHE_MAX", 2)
    progs = {n: fusion.fuse(BATCHABLE[n](0)) for n in ("SSSP", "WP", "BFS")}
    engine.run_program(g, progs["SSSP"], engine="pallas")
    engine.run_program(g, progs["WP"], engine="pallas")
    assert kops.executor_cache_size() == 2
    engine.run_program(g, progs["SSSP"], engine="pallas")   # touch: SSSP hot
    launches = er.SWEEP_STATS["launches"]
    engine.run_program(g, progs["BFS"], engine="pallas")    # evicts WP
    assert kops.executor_cache_size() == 2
    engine.run_program(g, progs["SSSP"], engine="pallas")   # still cached:
    assert er.SWEEP_STATS["launches"] > launches            # (BFS traced)
    launches = er.SWEEP_STATS["launches"]
    engine.run_program(g, progs["SSSP"], engine="pallas")
    assert er.SWEEP_STATS["launches"] == launches           # no re-trace


def test_exec_cache_pins_keyed_closures(small_graphs):
    """Cache values hold strong references to the kernel closures whose ids
    the key carries, so id() reuse after GC can never alias an entry."""
    g = small_graphs["rmat"]
    _cold()
    engine.run_program(g, fusion.fuse(U.sssp(0)), engine="pallas")
    ((key, (run, keyed)),) = list(kops._EXEC_CACHE.items())
    pinned = {id(f) for fns in keyed for f in fns if f is not None}
    assert pinned, "executor entry pins no closures"

    def flat(t):
        for x in t:
            if isinstance(x, tuple):
                yield from flat(x)
            else:
                yield x

    key_ints = {x for x in flat(key) if isinstance(x, int)}
    assert pinned <= key_ints, "a keyed closure id is missing from the key"


def test_synth_ms_populated(small_graphs):
    """Cold runs report the synthesis wall time; warm (round-cache hit)
    runs report ~0."""
    g = small_graphs["rmat"]
    _cold()
    cold = engine.run_program(g, fusion.fuse(U.wsp(0)), engine="pallas")
    warm = engine.run_program(g, fusion.fuse(U.wsp(0)), engine="pallas")
    assert cold.stats.synth_ms > 0.0
    assert warm.stats.synth_ms <= cold.stats.synth_ms
    assert warm.stats.synth_ms < 50.0      # memo hit: microseconds, not a
    np.testing.assert_array_equal(         # fresh enumerative search
        np.asarray(cold.value), np.asarray(warm.value))
