"""Checkpointed/chunked fixpoints + engine fallback chain (DESIGN.md §12).

The contract under test: restructuring the jitted ``while_loop`` into
host-stepped chunks — with or without ``CheckpointManager`` snapshots, kill
and resume, or a warm start — must stay BITWISE-identical to the monolithic
loop; and infrastructure failures must degrade down ``guard.FALLBACK_CHAIN``
without changing results.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import engine, guard, iterate
from repro.core import usecases as U
from repro.core.fusion import Prim
from repro.graph.structure import uniform_graph
from repro.kernels import ops as kops

pytestmark = pytest.mark.faults


def _comp(dk):
    return iterate.CompRuntime(idx=0, op=dk.rop,
                               dtype=iterate.DTYPES[dk.dtype],
                               p_fn=dk.p_fn, init_fn=dk.init_fn,
                               source=dk.source, e_fn=dk.e_fn)


def _kernel_sets(n):
    return [("bfs", U.handwritten_bfs_depth(0)),
            ("sssp", U.handwritten_sssp(0)),
            ("pagerank", U.pagerank_kernels(n, tol=1e-6, max_iter=60))]


def _states(res):
    return [np.asarray(s) for s in res.state]


class _Kill(Exception):
    pass


@pytest.fixture
def g():
    return uniform_graph(16, 48, seed=5, weighted=True)


# ---------------------------------------------------------------------------
# Chunked ≡ monolithic (bitwise, no fault fired)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("direction", ["pull", "push", "auto"])
def test_chunked_bitwise_equals_monolithic(g, direction, tmp_path):
    for name, dk in _kernel_sets(g.n):
        comp, plans = _comp(dk), [Prim(dk.rop, 0)]
        mono = kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                                   tol=dk.tol, direction=direction)
        chunked = kops.iterate_pallas(
            g, [comp], plans, max_iter=dk.max_iter, tol=dk.tol,
            direction=direction, checkpoint_every=2,
            ckpt_dir=str(tmp_path / f"{name}_{direction}"))
        assert chunked.iterations == mono.iterations, name
        for a, b in zip(_states(mono), _states(chunked)):
            np.testing.assert_array_equal(a, b, err_msg=name)
        assert chunked.converged == mono.converged


def test_single_chunk_mode_bitwise(g):
    """fault_hook alone flips to chunked execution with one max_iter-sized
    chunk — still bitwise-identical."""
    dk = U.handwritten_sssp(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    mono = kops.iterate_pallas(g, [comp], plans)
    seen = []
    chunked = kops.iterate_pallas(g, [comp], plans, fault_hook=seen.append)
    np.testing.assert_array_equal(_states(mono)[0], _states(chunked)[0])
    assert seen == [mono.iterations]


# ---------------------------------------------------------------------------
# Kill mid-fixpoint → resume → bitwise match
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kernel", ["bfs", "pagerank"])
def test_kill_and_resume_bitwise(g, kernel, tmp_path):
    dk = dict(_kernel_sets(g.n))[kernel]
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    ref = kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                              tol=dk.tol)
    assert ref.iterations > 2, "need a multi-chunk fixpoint to kill"
    d = str(tmp_path / kernel)

    def killer(k):
        if k >= 2:
            raise _Kill()

    with pytest.raises(_Kill):
        kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                            tol=dk.tol, checkpoint_every=1, ckpt_dir=d,
                            fault_hook=killer)
    resumed = kops.iterate_pallas(g, [comp], plans, max_iter=dk.max_iter,
                                  tol=dk.tol, checkpoint_every=1,
                                  ckpt_dir=d, resume=True)
    assert resumed.iterations == ref.iterations
    for a, b in zip(_states(ref), _states(resumed)):
        np.testing.assert_array_equal(a, b)


def test_resume_on_empty_dir_is_fresh_start(g, tmp_path):
    dk = U.handwritten_bfs_depth(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    ref = kops.iterate_pallas(g, [comp], plans)
    res = kops.iterate_pallas(g, [comp], plans, checkpoint_every=2,
                              ckpt_dir=str(tmp_path / "fresh"), resume=True)
    np.testing.assert_array_equal(_states(ref)[0], _states(res)[0])


def test_resume_rejects_fingerprint_mismatch(g, tmp_path):
    dk = U.handwritten_bfs_depth(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    d = str(tmp_path / "fp")
    kops.iterate_pallas(g, [comp], plans, checkpoint_every=1, ckpt_dir=d)
    # a DIFFERENT query source must refuse the stored snapshot
    with pytest.raises(guard.CheckpointMismatchError):
        kops.iterate_pallas(g, [comp], plans, sources={0: 3},
                            checkpoint_every=1, ckpt_dir=d, resume=True)


def test_checkpoint_knob_validation(g):
    dk = U.handwritten_bfs_depth(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    with pytest.raises(ValueError, match="ckpt_dir"):
        kops.iterate_pallas(g, [comp], plans, checkpoint_every=2)
    with pytest.raises(ValueError, match="checkpoint_every"):
        kops.iterate_pallas(g, [comp], plans, checkpoint_every=0,
                            ckpt_dir="/tmp/x")


# ---------------------------------------------------------------------------
# Warm start (init_state override — the ROADMAP warm-start primitive)
# ---------------------------------------------------------------------------

def test_warm_start_from_converged_state(g):
    dk = U.handwritten_sssp(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    cold = kops.iterate_pallas(g, [comp], plans)
    warm = kops.iterate_pallas(g, [comp], plans, init_state=cold.state)
    assert warm.iterations <= 1 < cold.iterations
    np.testing.assert_array_equal(_states(cold)[0], _states(warm)[0])


def test_warm_start_shape_validation(g):
    dk = U.handwritten_bfs_depth(0)
    comp, plans = _comp(dk), [Prim(dk.rop, 0)]
    with pytest.raises(ValueError, match="init_state"):
        kops.iterate_pallas(g, [comp], plans,
                            init_state=[np.zeros(g.n - 1, np.int32)])
    with pytest.raises(ValueError, match="components"):
        kops.iterate_pallas(g, [comp], plans,
                            init_state=[np.zeros(g.n, np.int32)] * 2)


# ---------------------------------------------------------------------------
# Engine-level threading (run_direct / run_program)
# ---------------------------------------------------------------------------

def test_run_direct_checkpointed_matches_plain(g, tmp_path):
    dk = U.pagerank_kernels(g.n, tol=1e-6, max_iter=60)
    plain = engine.run_direct(g, dk, engine="pallas")
    ck = engine.run_direct(g, dk, engine="pallas", checkpoint_every=3,
                           ckpt_dir=str(tmp_path / "pr"))
    np.testing.assert_array_equal(np.asarray(plain.value),
                                  np.asarray(ck.value))
    assert ck.stats.iterations == plain.stats.iterations


def test_checkpoint_knobs_rejected_off_pallas(g, tmp_path):
    dk = U.handwritten_bfs_depth(0)
    with pytest.raises(ValueError, match="pallas"):
        engine.run_direct(g, dk, engine="pull", checkpoint_every=2,
                          ckpt_dir=str(tmp_path))
    with pytest.raises(ValueError, match="pallas"):
        engine.run_direct(g, dk, engine="adaptive",
                          init_state=[np.zeros(g.n, np.int32)])


# ---------------------------------------------------------------------------
# Graceful degradation: the engine fallback chain
# ---------------------------------------------------------------------------

def test_pallas_falls_back_to_adaptive(g, monkeypatch):
    dk = U.handwritten_bfs_depth(0)
    ref = engine.run_direct(g, dk, engine="adaptive")

    def boom(*a, **k):
        raise RuntimeError("forced lowering failure")

    monkeypatch.setattr(kops, "iterate_pallas", boom)
    r = engine.run_direct(g, dk, engine="pallas", fallback=True)
    np.testing.assert_array_equal(np.asarray(ref.value), np.asarray(r.value))
    assert r.stats.engine_used == "adaptive"
    assert len(r.stats.fallbacks) == 1
    frm, to, err = r.stats.fallbacks[0]
    assert (frm, to) == ("pallas", "adaptive")
    assert "forced lowering failure" in err
    assert r.stats.exec_retries >= 1          # same-engine retry spent first


def test_sharded_falls_back_down_the_chain(g, monkeypatch):
    import jax
    from jax.sharding import Mesh
    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    dk = U.handwritten_bfs_depth(0)
    ref = engine.run_direct(g, dk, engine="pallas")

    def boom(*a, **k):
        raise RuntimeError("forced collective failure")

    monkeypatch.setattr(kops, "iterate_pallas_sharded", boom)
    r = engine.run_direct(g, dk, engine="pallas_sharded", mesh=mesh,
                          fallback=True)
    np.testing.assert_array_equal(np.asarray(ref.value), np.asarray(r.value))
    assert r.stats.engine_used == "pallas"
    assert [(f, t) for f, t, _ in r.stats.fallbacks] == \
        [("pallas_sharded", "pallas")]


def test_fallback_never_swallows_guard_verdicts(g, monkeypatch):
    from repro.graph.structure import from_edges
    gneg = from_edges(4, [0, 1, 2, 3], [1, 2, 3, 0],
                      weight=[1.0, -2.0, 1.0, 1.0])
    with pytest.raises(guard.TerminationPreconditionError):
        engine.run_direct(gneg, U.handwritten_sssp(0), engine="pallas",
                          fallback=True)
    dk1 = dataclasses.replace(U.handwritten_bfs_depth(0), max_iter=1)
    with pytest.raises(guard.NonConvergenceError):
        engine.run_direct(g, dk1, engine="pallas", fallback=True)


def test_fallback_off_propagates(g, monkeypatch):
    def boom(*a, **k):
        raise RuntimeError("forced lowering failure")
    monkeypatch.setattr(kops, "iterate_pallas", boom)
    with pytest.raises(RuntimeError, match="forced lowering"):
        engine.run_direct(g, U.handwritten_bfs_depth(0), engine="pallas")


def test_batched_launch_degrades_to_sequential(g, monkeypatch):
    dk = U.handwritten_bfs_depth(0)
    refs = engine.run_direct(g, dk, engine="adaptive",
                             sources=[0, 3, 5])

    def boom(*a, **k):
        raise RuntimeError("forced batch failure")

    monkeypatch.setattr(kops, "iterate_pallas_batch", boom)
    outs = engine.run_direct(g, dk, engine="pallas", sources=[0, 3, 5],
                             fallback=True)
    assert len(outs) == 3
    for ref, out in zip(refs, outs):
        np.testing.assert_array_equal(np.asarray(ref.value),
                                      np.asarray(out.value))
        assert out.stats.engine_used == "adaptive"
        assert out.stats.fallbacks[0][:2] == ("pallas", "adaptive")
