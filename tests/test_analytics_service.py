"""Continuous-batching analytics service (repro.launch.service +
fusion.fuse_many + the engine batch-join hooks).  The serving invariants
under test, per DESIGN.md §13:

* queue drain: every submitted request completes, on every lane;
* batch-join determinism: replaying a seeded open-loop trace reproduces
  the scheduling metrics exactly and the answers bitwise;
* bitwise sequential equivalence: chunked warm-resume batching, slot
  joins and cross-kind scalar fusion are invisible in the answer bits;
* convergence skew: a short query sharing a batch with a long one
  retires at ITS convergence, never the batch maximum;
* late joiners (the continuous part of continuous batching) match their
  solo runs, across engines;
* graph-LRU eviction keeps derived-structure caches bounded;
* fuse_many answers each paired request from ONE execution with less
  edge work than solo runs.
"""
import numpy as np
import pytest

from repro.core import engine, fusion
from repro.core import lang as L
from repro.core import usecases as U
from repro.graph import structure
from repro.launch import service as S

pytestmark = pytest.mark.service


def _service(g, gname="g", max_batch=4, chunk_iters=3, **kw):
    svc = S.AnalyticsService(S.ServiceConfig(
        engine="pallas", max_batch=max_batch, chunk_iters=chunk_iters, **kw))
    svc.add_graph(gname, g)
    svc.register("BFS", U.bfs)
    svc.register("SSSP", U.sssp)
    return svc


def _drain(svc, limit=10_000):
    steps = 0
    while svc.step():
        steps += 1
        assert steps < limit, "service failed to drain"
    return steps


def _skewed_graph():
    """One graph, two disconnected components with wildly different
    convergence depths: a 48-vertex line (SSSP from vertex 0 walks ~47
    rounds) plus a 6-vertex clique on vertices 48..53 (any query there
    converges in ~2)."""
    line_src = np.arange(47)
    line_dst = np.arange(1, 48)
    cl = np.arange(48, 54)
    a, b = np.meshgrid(cl, cl)
    keep = a.ravel() != b.ravel()
    src = np.concatenate([line_src, a.ravel()[keep]]).astype(np.int32)
    dst = np.concatenate([line_dst, b.ravel()[keep]]).astype(np.int32)
    w = np.ones(src.size, np.float32)
    return structure.from_edges(54, src, dst, weight=w)


# ---------------------------------------------------------------------------
# queue drain
# ---------------------------------------------------------------------------


def test_queue_drain_all_lanes(small_graphs):
    g = small_graphs["uniform2"]
    svc = _service(g)
    reqs = []
    for i in range(6):                       # batch lane (two kinds)
        r = S.Request(rid=i, kind=("BFS", "SSSP")[i % 2], source=i % g.n)
        reqs.append(r)
        svc.submit("g", r)
    for i in range(6, 9):                    # scalar lane
        r = S.Request(rid=i, spec=U.radius(i % g.n, (i + 1) % g.n))
        reqs.append(r)
        svc.submit("g", r)
    r = S.Request(rid=9, spec=U.rds(0, 1))   # LetRound -> solo
    reqs.append(r)
    svc.submit("g", r)
    _drain(svc)
    assert len(svc.completed) == len(reqs)
    assert {q.rid for q in svc.completed} == {q.rid for q in reqs}
    assert all(q.value is not None for q in svc.completed)
    assert svc.solo_runs == 1
    assert svc.scalar_fused == 3 and svc.scalar_rounds == 1
    assert svc.batch_completed == 6
    assert not svc._has_work()


def test_submit_validation(small_graphs):
    svc = _service(small_graphs["uniform"])
    with pytest.raises(KeyError, match="not resident"):
        svc.submit("nope", S.Request(rid=0, kind="BFS", source=0))
    with pytest.raises(KeyError, match="unregistered"):
        svc.submit("g", S.Request(rid=0, kind="PAGERANK", source=0))
    with pytest.raises(ValueError, match="kind or a spec"):
        svc.submit("g", S.Request(rid=0))


# ---------------------------------------------------------------------------
# batch-join determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 11])
def test_open_loop_replay_is_deterministic(small_graphs, seed):
    g = small_graphs["rmat"]

    def run():
        svc = _service(g, max_batch=3, chunk_iters=2)
        arrivals = S.open_loop_arrivals(
            16, rate=800.0, seed=seed,
            make_request=S.standard_mix("g", g.n))
        m = svc.run_open_loop(arrivals)
        return svc, m

    svc1, m1 = run()
    svc2, m2 = run()
    wall = {k for k in m1 if k.startswith("wall")}
    assert {k: v for k, v in m1.items() if k not in wall} == \
           {k: v for k, v in m2.items() if k not in wall}
    by_rid = {r.rid: r for r in svc2.completed}
    for r1 in svc1.completed:
        r2 = by_rid[r1.rid]
        assert r1.joined_launch == r2.joined_launch
        assert r1.chunks == r2.chunks
        assert np.asarray(r1.value).tobytes() == \
            np.asarray(r2.value).tobytes()


# ---------------------------------------------------------------------------
# bitwise equivalence to sequential execution
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [1, 5, 9])
def test_open_loop_bitwise_vs_sequential(small_graphs, seed):
    g = small_graphs["uniform2"]
    svc = _service(g, max_batch=3, chunk_iters=4)
    arrivals = S.open_loop_arrivals(
        14, rate=6000.0, seed=seed, make_request=S.standard_mix("g", g.n))
    m = svc.run_open_loop(arrivals)
    assert m["completed"] == 14
    assert S.verify_sequential(svc) == 14
    # pressure at rate >> service time must actually fill batches
    assert m["queries_per_launch"] > 1.0


# ---------------------------------------------------------------------------
# convergence skew: short queries never wait for long batchmates
# ---------------------------------------------------------------------------


def test_short_query_retires_before_long_batchmate():
    g = _skewed_graph()
    svc = _service(g, max_batch=4, chunk_iters=4)
    long_q = S.Request(rid=0, kind="SSSP", source=0)    # line head: ~47 rounds
    short_q = S.Request(rid=1, kind="SSSP", source=50)  # clique: ~2 rounds
    svc.submit("g", long_q)
    svc.submit("g", short_q)
    _drain(svc)
    assert long_q.joined_launch == short_q.joined_launch  # same first launch
    assert short_q.chunks == 1                 # retired after one quantum
    assert long_q.chunks > 3                   # kept iterating for many
    assert short_q.completed < long_q.completed
    # retiring early must not have corrupted either answer
    assert S.verify_sequential(svc) == 2


def test_late_joiner_into_live_batch_matches_solo():
    """The continuous part: a query admitted while the batch is mid-flight
    (some slots retired, others still iterating) splices fresh init rows
    into a retired slot and must still produce its solo bits."""
    g = _skewed_graph()
    svc = _service(g, max_batch=2, chunk_iters=4)
    svc.submit("g", S.Request(rid=0, kind="SSSP", source=0))
    svc.submit("g", S.Request(rid=1, kind="SSSP", source=48))
    # let the short slot retire while the long one is still live
    assert svc.step()
    assert len(svc.completed) == 1 and svc.completed[0].rid == 1
    late = S.Request(rid=2, kind="SSSP", source=52)
    svc.submit("g", late)
    _drain(svc)
    assert late.joined_launch > 0              # joined mid-flight, not cold
    assert len(svc.completed) == 3
    assert S.verify_sequential(svc) == 3


@pytest.mark.parametrize("seed", [3, 17])
@pytest.mark.parametrize("ref_engine", ["pallas", "pull"])
def test_late_joiner_values_across_engines(small_graphs, seed, ref_engine):
    """Seeded arrivals force joins at random chunk boundaries; the served
    answers must match solo runs bitwise on the serving engine and
    value-wise on an independent engine."""
    g = small_graphs["uniform2"]
    svc = _service(g, max_batch=2, chunk_iters=2)
    rng = np.random.default_rng(seed)

    def make(r, i):
        kind = ("BFS", "SSSP")[int(r.integers(2))]
        return "g", S.Request(kind=kind, source=int(r.integers(g.n)))

    arrivals = S.open_loop_arrivals(10, rate=600.0, seed=seed,
                                    make_request=make)
    svc.run_open_loop(arrivals)
    assert len(svc.completed) == 10
    if ref_engine == "pallas":
        assert S.verify_sequential(svc) == 10
    else:
        for req in svc.completed:
            _, prog, _ = svc._kinds[req.kind]
            ref = engine.run_program(g, prog, engine="pull",
                                     source=req.source).value
            np.testing.assert_allclose(
                np.asarray(req.value, np.float64),
                np.asarray(ref, np.float64), rtol=1e-6)
    del rng


# ---------------------------------------------------------------------------
# graph LRU / cache-eviction bounds
# ---------------------------------------------------------------------------


def test_graph_lru_eviction_bounds_caches():
    graphs = [structure.uniform_graph(10 + i, 24, seed=i) for i in range(4)]
    svc = _service(graphs[0], gname="g0", max_graphs=2)
    # touch g0 so its layouts exist, then churn three more graphs through
    svc.submit("g0", S.Request(rid=0, kind="BFS", source=0))
    _drain(svc)
    per_graph = engine.program_cache_stats()["ell_layouts"]   # one resident
    assert per_graph > 0
    for i in (1, 2, 3):
        svc.add_graph(f"g{i}", graphs[i])
        svc.submit(f"g{i}", S.Request(rid=i, kind="BFS", source=0))
        _drain(svc)
    assert len(svc.graphs) <= 2
    assert svc.graph_evictions == 2
    assert set(svc.graphs) == {"g2", "g3"}     # LRU order respected
    stats = engine.program_cache_stats()
    # evicted graphs' derived layouts are gone: layout residency stays
    # bounded by max_graphs × the per-graph footprint, under any churn
    assert stats["ell_layouts"] <= 2 * per_graph
    # answers from before the evictions are still intact and verifiable
    # against the graphs we kept alive out-of-band
    checked = S.verify_sequential(
        svc, graphs={f"g{i}": graphs[i] for i in range(4)})
    assert checked == 4


def test_busy_graph_is_never_evicted(small_graphs):
    svc = _service(small_graphs["uniform"], gname="g", max_graphs=1)
    svc.submit("g", S.Request(rid=0, kind="SSSP", source=0))   # queued work
    svc.add_graph("g2", small_graphs["uniform2"])
    assert "g" in svc.graphs          # busy: capacity bound is soft
    _drain(svc)
    svc.add_graph("g3", small_graphs["rmat"])
    assert "g" not in svc.graphs      # idle now: evicted
    assert svc.graph_evictions >= 1


def test_clear_graph_caches_is_per_graph(small_graphs):
    g1, g2 = small_graphs["uniform"], small_graphs["uniform2"]
    engine.run_program(g1, fusion.fuse(U.bfs(0)), engine="pallas")
    engine.run_program(g2, fusion.fuse(U.bfs(0)), engine="pallas")
    before = engine.program_cache_stats()["ell_layouts"]
    dropped = engine.clear_graph_caches(g1)
    assert dropped > 0
    after = engine.program_cache_stats()["ell_layouts"]
    assert 0 < after < before          # g2's layouts survived


# ---------------------------------------------------------------------------
# fuse_many: multi-value pairing
# ---------------------------------------------------------------------------


def test_fuse_many_per_request_answers(small_graphs):
    g = small_graphs["uniform2"]
    reqs = {"rad01": U.radius(0, 1), "drr23": U.drr(2, 3),
            "rad45": U.radius(4, 5)}
    stats = fusion.FusionStats()
    res = engine.run_program(g, fusion.fuse_many(reqs, stats=stats),
                             engine="pallas")
    assert set(res.value) == set(reqs)
    solo_work = 0.0
    for k, spec in reqs.items():
        solo = engine.run_program(g, fusion.fuse(spec), engine="pallas")
        assert float(np.asarray(res.value[k])) == float(np.asarray(solo.value))
        solo_work += solo.stats.edge_work
    assert res.stats.edge_work < solo_work
    assert stats.frpair > 0            # reductions actually paired


def test_fuse_many_rejects_non_scalar_and_empty():
    with pytest.raises(ValueError, match="at least one"):
        fusion.fuse_many([])
    with pytest.raises(TypeError, match="single-round scalar"):
        fusion.fuse_many({"v": U.bfs(0)})
    with pytest.raises(TypeError, match="single-round scalar"):
        fusion.fuse_many({"lr": U.rds(0, 1)})


def test_fuse_many_single_request_matches_fuse(small_graphs):
    g = small_graphs["line"]
    res = engine.run_program(g, fusion.fuse_many({"r": U.radius(0, 3)}),
                             engine="pallas")
    solo = engine.run_program(g, fusion.fuse(U.radius(0, 3)),
                              engine="pallas")
    assert float(np.asarray(res.value["r"])) == float(np.asarray(solo.value))


# ---------------------------------------------------------------------------
# engine-level batch-join hooks
# ---------------------------------------------------------------------------


def test_batchable_program_classification():
    assert engine.batchable_program(fusion.fuse(U.bfs(0)))
    assert engine.batchable_program(fusion.fuse(U.sssp(0)))
    assert not engine.batchable_program(fusion.fuse(U.rds(0, 1)))
    assert not engine.batchable_program(fusion.fuse(U.cc()))


def test_chunked_warm_resume_matches_monolithic(small_graphs):
    g = small_graphs["uniform2"]
    prog = fusion.fuse(U.sssp(0))
    srcs = [0, 3, 7]
    mono = engine.run_program_batch(g, prog, srcs, engine="pallas")
    outs, state = engine.run_program_batch(
        g, prog, srcs, engine="pallas", max_iter=2,
        on_nonconverge="ignore", return_state=True)
    guard = 0
    while not all(o.stats.converged for o in outs):
        outs, state = engine.run_program_batch(
            g, prog, srcs, engine="pallas", max_iter=2,
            on_nonconverge="ignore",
            init_state=tuple(np.array(s) for s in state), return_state=True)
        guard += 1
        assert guard < 64
    for m, c in zip(mono, outs):
        assert np.asarray(m.value).tobytes() == np.asarray(c.value).tobytes()


def test_init_state_requires_pallas_single_round(small_graphs):
    g = small_graphs["uniform"]
    prog = fusion.fuse(U.sssp(0))
    init = engine.batch_init_state(g, prog, [0, 1])
    with pytest.raises(ValueError, match="pallas"):
        engine.run_program_batch(g, prog, [0, 1], engine="pull",
                                 init_state=init)
    with pytest.raises(ValueError, match="fallback"):
        engine.run_program_batch(g, prog, [0, 1], engine="pallas",
                                 init_state=init, fallback=True)
    multi = fusion.fuse(U.rds(0, 1))
    with pytest.raises(ValueError, match="single"):
        engine.run_program_batch(g, multi, [0, 1], engine="pallas",
                                 return_state=True)
