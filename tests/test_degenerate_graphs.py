"""Degenerate-graph coverage (DESIGN.md §12): zero-edge, single-vertex,
fully-isolated-source and all-self-loop graphs through ``run_program``,
``run_direct`` and ``run_program_batch`` across engines.

These shapes hit every edge-handling boundary at once — empty ELL blocks,
frontiers that drain on the first sweep, sources with out-degree 0 — and
all engines must agree with the pull reference bit-for-bit under the
``norm_inf`` ⊥-collapse."""
import numpy as np
import pytest

from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph.structure import from_edges

from conftest import norm_inf

BOT = np.float64(1e9)                     # norm_inf's collapsed ⊥ token
ENGINES = ["pull", "push", "adaptive", "pallas"]


def _cases():
    return {
        # no edges at all: only the source is reachable
        "zero_edge": from_edges(4, [], []),
        # a single vertex and nothing else
        "single_vertex": from_edges(1, [], []),
        # vertex 0 (the query source) touches no edge; the rest form a path
        "isolated_source": from_edges(4, [1, 2], [2, 3],
                                      weight=[1.0, 1.0]),
        # every edge is a self-loop: nothing propagates anywhere
        "all_self_loop": from_edges(4, [0, 1, 2, 3], [0, 1, 2, 3],
                                    weight=[1.0, 1.0, 1.0, 1.0]),
    }


@pytest.fixture(params=sorted(_cases()))
def degen(request):
    return request.param, _cases()[request.param]


@pytest.mark.parametrize("eng", ENGINES)
@pytest.mark.parametrize("spec_name", ["BFS", "SSSP"])
def test_run_program_on_degenerate_graphs(degen, spec_name, eng):
    name, g = degen
    prog = fusion.fuse(U.ALL_SPECS[spec_name]())
    ref = engine.run_program(g, prog, engine="pull", source=0)
    res = engine.run_program(g, prog, engine=eng, source=0)
    np.testing.assert_array_equal(norm_inf(ref.value), norm_inf(res.value),
                                  err_msg=f"{name}/{eng}")
    v = norm_inf(res.value)
    assert v[0] != BOT                    # source resolves to itself
    if name != "single_vertex":
        assert (v[1:] == BOT).all(), f"{name}: non-source must stay ⊥"
    assert res.stats.iterations >= 1


@pytest.mark.parametrize("eng", ["pull", "adaptive", "pallas"])
def test_run_direct_on_degenerate_graphs(degen, eng):
    name, g = degen
    for dk in (U.handwritten_bfs_depth(0), U.handwritten_sssp(0)):
        ref = engine.run_direct(g, dk, engine="pull")
        res = engine.run_direct(g, dk, engine=eng)
        np.testing.assert_array_equal(norm_inf(ref.value),
                                      norm_inf(res.value),
                                      err_msg=f"{name}/{eng}/{dk.name}")
        assert res.stats.converged if hasattr(res.stats, "converged") \
            else True


@pytest.mark.parametrize("eng", ["pull", "pallas"])
def test_run_program_batch_on_degenerate_graphs(degen, eng):
    name, g = degen
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    srcs = list(range(g.n))
    outs = engine.run_program_batch(g, prog, sources=srcs, engine=eng)
    assert len(outs) == g.n
    for s, out in zip(srcs, outs):
        ref = engine.run_program(g, prog, engine="pull", source=s)
        np.testing.assert_array_equal(norm_inf(ref.value),
                                      norm_inf(out.value),
                                      err_msg=f"{name}/{eng}/src={s}")
        assert norm_inf(out.value)[s] != BOT


def test_isolated_source_reaches_only_itself_but_rest_connects():
    """Sanity on the isolated_source shape: querying from a NON-isolated
    vertex still walks the path — isolation is a property of the query
    source, not the graph."""
    g = _cases()["isolated_source"]
    prog = fusion.fuse(U.ALL_SPECS["SSSP"]())
    v = norm_inf(engine.run_program(g, prog, engine="pallas",
                                    source=1).value)
    assert v[1] == 0.0 and v[2] == 1.0 and v[3] == 2.0
    assert v[0] == BOT


def test_validation_accepts_degenerate_shapes():
    """validate_graph must not reject legal degenerate graphs."""
    from repro.graph import structure
    for name, g in _cases().items():
        chk = structure.validate_graph(g)
        assert chk.n == g.n, name
    # all_self_loop is only rejected under the opt-in strict policy
    with pytest.raises(Exception, match="self-loop"):
        from_edges(2, [0, 1], [0, 1], self_loops="error")
