"""Fault-tolerance runtime + checkpoint tests: checkpoint/restart with
pipeline state, atomic publish, retry with restore, straggler detection,
elastic remesh, gradient compression."""
import os
import shutil
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.ckpt import (CheckpointManager, latest_step,
                                   restore_checkpoint, save_checkpoint)
from repro.data.tokens import TokenStream
from repro.runtime.ft import FTConfig, FaultTolerantDriver, StragglerDetector


@pytest.fixture
def tmp_ckpt(tmp_path):
    return str(tmp_path / "ckpt")


def _toy_state():
    return {"w": jnp.arange(12.0).reshape(3, 4),
            "b": jnp.ones((4,)), "step": jnp.int32(0)}


def test_checkpoint_roundtrip(tmp_ckpt):
    state = _toy_state()
    save_checkpoint(tmp_ckpt, 3, state, extra={"data": {"cursor": 7}})
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step, extra = restore_checkpoint(tmp_ckpt, like)
    assert step == 3 and extra["data"]["cursor"] == 7
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 restored, state)


def test_checkpoint_atomic_publish(tmp_ckpt):
    state = _toy_state()
    save_checkpoint(tmp_ckpt, 1, state)
    # a stale .tmp dir (simulated mid-write preemption) is invisible
    os.makedirs(os.path.join(tmp_ckpt, "step_0000000009.tmp"))
    assert latest_step(tmp_ckpt) == 1


def test_checkpoint_manager_async_and_gc(tmp_ckpt):
    mgr = CheckpointManager(tmp_ckpt, keep=2)
    state = _toy_state()
    for s in (1, 2, 3, 4):
        mgr.save_async(s, state)
        mgr.wait()
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(tmp_ckpt))
    assert steps == [3, 4]


def test_restore_across_shardings(tmp_ckpt):
    """Mesh-independent restore: save unsharded, restore with an explicit
    (single-device) sharding tree — the elastic-remesh path."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    state = _toy_state()
    save_checkpoint(tmp_ckpt, 5, state)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda x: NamedSharding(mesh, P()), state)
    restored, step, _ = restore_checkpoint(tmp_ckpt, state, shardings=sh)
    assert step == 5
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 restored, state)


def test_token_stream_checkpointable():
    s1 = TokenStream(vocab=97, batch=2, seq=8, seed=3)
    b1 = [s1.next_batch() for _ in range(3)]
    st = s1.state()
    b_next = s1.next_batch()
    s2 = TokenStream.from_state(97, 2, 8, st)
    b_re = s2.next_batch()
    np.testing.assert_array_equal(b_next["tokens"], b_re["tokens"])


def test_straggler_detector():
    d = StragglerDetector(factor=3.0, alpha=0.5)
    for _ in range(5):
        assert not d.observe(0.10)
    assert d.observe(1.0)                 # 10× the EWMA → flagged
    assert d.flagged == 1
    assert not d.observe(0.1)             # baseline not poisoned


def _driver(tmp_ckpt, step_fn, stream):
    return FaultTolerantDriver(
        FTConfig(ckpt_dir=tmp_ckpt, ckpt_every=2, max_retries=2,
                 backoff_s=0.001),
        step_fn,
        data_state_fn=stream.state,
        data_restore_fn=lambda st: stream.__dict__.update(
            seed=int(st["seed"]), step=int(st["step"])))


def test_ft_train_loop_and_resume(tmp_ckpt):
    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)

    def step_fn(state, batch):
        w = state["w"] + 1.0
        return {"w": w}, {"loss": jnp.float32(1.0)}

    ft = _driver(tmp_ckpt, step_fn, stream)
    state = {"w": jnp.zeros(())}
    state, step, _ = ft.train(state, 5, stream.next_batch)
    assert step == 5 and float(state["w"]) == 5.0
    # resume from the published checkpoint (data cursor restored too)
    ft2 = _driver(tmp_ckpt, step_fn, stream)
    restored, rstep = ft2.restore({"w": jnp.zeros(())})
    assert rstep == 5 and float(restored["w"]) == 5.0


def test_ft_retry_recovers_from_transient_failure(tmp_ckpt):
    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)
    fails = {"n": 2}

    def step_fn(state, batch):
        if fails["n"] > 0:
            fails["n"] -= 1
            raise RuntimeError("injected transient fault")
        return {"w": state["w"] + 1.0}, {}

    ft = _driver(tmp_ckpt, step_fn, stream)
    state, _ = ft.run_step({"w": jnp.zeros(())}, stream.next_batch())
    assert float(state["w"]) == 1.0
    assert ft.stats.retries == 2


def test_ft_restore_after_persistent_failure(tmp_ckpt):
    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)

    def good(state, batch):
        return {"w": state["w"] + 1.0}, {}

    ft = _driver(tmp_ckpt, good, stream)
    state = {"w": jnp.zeros(())}
    state, step, _ = ft.train(state, 4, stream.next_batch)   # ckpt at 4

    crash = {"on": True}

    def flaky(st, batch):
        if crash["on"]:
            raise RuntimeError("persistent node failure")
        return {"w": st["w"] + 1.0}, {}

    ft2 = _driver(tmp_ckpt, flaky, stream)

    # after max_retries the driver restores the checkpoint; stop crashing
    orig_restore = ft2.restore

    def restore_and_heal(like):
        crash["on"] = False
        return orig_restore(like)

    ft2.restore = restore_and_heal
    out, _ = ft2.run_step({"w": jnp.full((), 99.0)}, stream.next_batch(),
                          state_like={"w": jnp.zeros(())})
    assert float(out["w"]) == 5.0          # restored 4.0 + one good step
    assert ft2.stats.restores == 1


def test_ft_restore_budget_is_per_incident(tmp_ckpt):
    """Regression: the abort decision must use the per-incident restore
    count, not lifetime ``stats.restores`` — a long run that survives many
    separate incidents (each healed by one restore) must never abort."""
    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)

    def good(state, batch):
        return {"w": state["w"] + 1.0}, {}

    ft = _driver(tmp_ckpt, good, stream)
    state = {"w": jnp.zeros(())}
    state, step, _ = ft.train(state, 4, stream.next_batch)   # ckpt at 4

    crash = {"on": False}

    def flaky(st, batch):
        if crash["on"]:
            raise RuntimeError("injected node failure")
        return {"w": st["w"] + 1.0}, {}

    ft2 = _driver(tmp_ckpt, flaky, stream)
    orig_restore = ft2.restore

    def restore_and_heal(like):
        crash["on"] = False
        return orig_restore(like)

    ft2.restore = restore_and_heal
    # three independent incidents; each exhausts the retry budget and needs
    # one restore.  Lifetime restores (3) exceeds max_retries (2) — the old
    # lifetime-budget code aborted on the second incident.
    for _ in range(3):
        crash["on"] = True
        out, _ = ft2.run_step({"w": jnp.full((), 99.0)}, stream.next_batch(),
                              state_like={"w": jnp.zeros(())})
        assert float(out["w"]) == 5.0      # restored 4.0 + one good step
    assert ft2.stats.restores == 3


def test_ft_no_fractional_backoff_after_restore(tmp_ckpt, monkeypatch):
    """Regression: after a restore resets the attempt counter the driver
    retries immediately; it must never sleep ``backoff_s * 2**(-1)``."""
    import repro.runtime.ft as ft_mod
    sleeps = []
    monkeypatch.setattr(ft_mod.time, "sleep", sleeps.append)

    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)

    def good(state, batch):
        return {"w": state["w"] + 1.0}, {}

    ft = _driver(tmp_ckpt, good, stream)
    state, step, _ = ft.train({"w": jnp.zeros(())}, 4, stream.next_batch)

    crash = {"on": True}

    def flaky(st, batch):
        if crash["on"]:
            raise RuntimeError("persistent node failure")
        return {"w": st["w"] + 1.0}, {}

    ft2 = _driver(tmp_ckpt, flaky, stream)
    orig_restore = ft2.restore

    def restore_and_heal(like):
        crash["on"] = False
        return orig_restore(like)

    ft2.restore = restore_and_heal
    sleeps.clear()
    ft2.run_step({"w": jnp.zeros(())}, stream.next_batch(),
                 state_like={"w": jnp.zeros(())})
    b = ft2.cfg.backoff_s
    assert sleeps == [b, 2 * b]            # attempts 1..2 only, no 0.5·b
    assert all(s >= b for s in sleeps)


def test_bounded_retry():
    from repro.runtime.ft import bounded_retry
    fails = {"n": 2}

    def fn():
        if fails["n"] > 0:
            fails["n"] -= 1
            raise RuntimeError("transient")
        return 42

    out, retries = bounded_retry(fn, max_retries=3, backoff_s=0.0)
    assert (out, retries) == (42, 2)

    calls = {"n": 0}

    def always(exc_type):
        def f():
            calls["n"] += 1
            raise exc_type("boom")
        return f

    with pytest.raises(RuntimeError):      # budget exhausted → re-raise
        bounded_retry(always(RuntimeError), max_retries=2, backoff_s=0.0)
    assert calls["n"] == 3                 # initial call + 2 retries

    calls["n"] = 0
    with pytest.raises(ValueError):        # non-retryable → no retry at all
        bounded_retry(always(ValueError), max_retries=2, backoff_s=0.0,
                      retryable=lambda e: not isinstance(e, ValueError))
    assert calls["n"] == 1


def test_gradient_compression_error_feedback():
    from repro.optim.compress import (compress_grads, decompress_grads,
                                      init_compress_state)
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.normal(size=(64, 64)).astype(np.float32))}
    st = init_compress_state(g)
    # single-shot quantization error is bounded by the step size
    q, s, st2 = compress_grads(g, st)
    deq = decompress_grads(q, s)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    assert err <= float(s["w"]) * 0.51 + 1e-6
    # error feedback: accumulated mean error decays over repeats
    total = jax.tree.map(jnp.zeros_like, g)
    st = init_compress_state(g)
    for _ in range(50):
        q, s, st = compress_grads(g, st)
        total = jax.tree.map(lambda t, d: t + d, total,
                             decompress_grads(q, s))
    mean_err = float(jnp.abs(total["w"] / 50 - g["w"]).mean())
    assert mean_err < 1e-3


def test_compressed_allreduce_matches_mean(tmp_path):
    """int8 psum with error feedback ≈ the true cross-shard mean."""
    import os
    from repro.optim.compress import error_feedback_update, init_compress_state
    from jax.sharding import PartitionSpec as P

    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device to be meaningful")


def test_remesh_changes_shardings(tmp_ckpt):
    stream = TokenStream(vocab=17, batch=2, seq=4, seed=1)

    def step_fn(state, batch):
        return {"w": state["w"] + 1.0}, {}

    ft = _driver(tmp_ckpt, step_fn, stream)
    state = {"w": jnp.arange(8.0)}
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1,), ("data",))
    new_sh = {"w": NamedSharding(mesh, P("data"))}
    state2 = ft.remesh(state, 1, new_sh)
    np.testing.assert_array_equal(np.asarray(state2["w"]),
                                  np.arange(8.0))
    assert state2["w"].sharding == new_sh["w"]
