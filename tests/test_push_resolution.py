"""Dst-sorted push resolution + the Gemini direction autotune (DESIGN.md §10).

Covers the acceptance contract of the frontier-proportional resolution path:

* the `structure.PushResolution` permutation maps every dst-major slot to
  the out-layout slot of the SAME edge (weights/destinations round-trip),
* `fused_ell_push_sweep(resolution="sorted")` ≡ `"scatter"` bit-for-bit at
  the kernel level across random graphs and frontier densities,
* resolution work is frontier-proportional: Σ tile_nnz of the resolution
  tiles actually processed, strictly under the scatter's full rectangle on
  sparse frontiers, and 0 when nothing is active,
* the resolution tile pass is its own launch class (`resolve_launches`):
  1 per traced push sweep under "sorted", 0 under "scatter"/pull — the
  edge-sweep launch contract (`launches`) is unchanged,
* `push_resolution` is an executor-cache key (no silent cross-knob reuse),
* the Gemini |E_frontier| ≤ |E|/k switch replaces the fixed vertex-fraction
  threshold, is per-query tunable, and `switch_k=None` falls back to the
  documented `DENSE_FRONTIER` rule,
* stat bumps happen only after a successful launch construction.
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import norm_inf
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph import segment
from repro.graph.structure import (push_resolution_cached, rmat_graph,
                                   to_blocked_ell, to_push_resolution,
                                   uniform_graph)
from repro.kernels import edge_reduce as er
from repro.kernels import ops as kops

SAMPLES = [(9, 1.5, 11), (17, 2.5, 22), (26, 3.0, 33)]


def _cold():
    engine.clear_program_caches()
    er.reset_sweep_stats()


# ---------------------------------------------------------------------------
# layout: the dst-major permutation is exact
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,density,seed", SAMPLES)
def test_resolution_permutation_roundtrips_edges(n, density, seed):
    """in2out must map the k-th dst-major slot of v to the out-layout slot
    holding the SAME edge: gathering the out rectangle's weights and
    destinations through it reproduces the in-layout rectangle exactly,
    and `valid` IS the in-layout mask (same fill order ⇒ the sorted
    reduction tree is the pull sweep's reduction tree)."""
    g = uniform_graph(n, max(1, int(density * n)), seed=seed)
    res = to_push_resolution(g)
    ell_in = to_blocked_ell(g)
    ell_out = to_blocked_ell(g, direction="out")
    valid = np.asarray(res.valid)
    in2out = np.asarray(res.in2out)
    assert res.width == ell_in.width and res.out_width == ell_out.width
    np.testing.assert_array_equal(valid, np.asarray(ell_in.mask))
    w_via = np.asarray(ell_out.weight).reshape(-1)[in2out]
    np.testing.assert_array_equal(np.where(valid, w_via, 0),
                                  np.where(valid, np.asarray(ell_in.weight), 0))
    # the out-slot's stored destination is the dst-major slot's own row
    dst_via = np.asarray(ell_out.nbrs).reshape(-1)[in2out]
    rows = np.broadcast_to(np.arange(res.n_pad)[:, None], valid.shape)
    np.testing.assert_array_equal(dst_via[valid], rows[valid])
    # every real out-slot is hit exactly once (it is a permutation of edges)
    assert sorted(in2out[valid].tolist()) == \
        sorted(np.flatnonzero(np.asarray(ell_out.mask).reshape(-1)).tolist())
    # src_tile agrees with the out-layout grid geometry
    n_j_out = ell_out.width // ell_out.block_e
    want_tile = ((in2out // ell_out.width) // res.block_v) * n_j_out + \
        (in2out % ell_out.width) // res.block_e
    np.testing.assert_array_equal(np.asarray(res.src_tile), want_tile)


def test_resolution_layout_cached_per_graph():
    g1 = uniform_graph(12, 30, seed=1)
    g2 = uniform_graph(12, 30, seed=2)
    assert push_resolution_cached(g1) is push_resolution_cached(g1)
    assert push_resolution_cached(g1) is not push_resolution_cached(g2)
    assert engine.program_cache_stats()["push_resolutions"] >= 2


# ---------------------------------------------------------------------------
# kernel level: sorted ≡ scatter, and work is frontier-proportional
# ---------------------------------------------------------------------------

def _push_sweep(g, frontier_frac, seed, resolution):
    ell = to_blocked_ell(g, direction="out")
    res = to_push_resolution(g)
    rng = np.random.default_rng(seed)
    state = jnp.asarray(rng.integers(1, 9, ell.n_pad).astype(np.float32))
    ident = float(segment.identity("min", jnp.float32))
    active = jnp.asarray((rng.random(ell.n_pad) < frontier_frac)
                         .astype(np.int32))
    tile_act = er.tile_activity_push(ell.tile_nnz, active, ell.block_v)
    kw = dict(plans=(((0, "min"),),), idents={0: ident},
              p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n)
    if resolution == "sorted":
        res_tile_act = er.resolution_tile_activity(
            res.contrib, tile_act, res.tile_nnz)
        red, _ = er.fused_ell_push_sweep(
            ell.nbrs, ell.weight, ell.capacity, ell.mask, tile_act,
            {0: state}, active, jnp.ones(ell.n_pad, jnp.float32),
            resolution="sorted",
            res=(res.in2out, res.valid, res_tile_act), **kw)
        work = float(jnp.sum(res.tile_nnz * res_tile_act))
    else:
        red, _ = er.fused_ell_push_sweep(
            ell.nbrs, ell.weight, ell.capacity, ell.mask, tile_act,
            {0: state}, active, jnp.ones(ell.n_pad, jnp.float32),
            resolution="scatter", **kw)
        work = float(ell.n_pad * ell.width)
    return np.asarray(red[0]), work


@pytest.mark.parametrize("n,density,seed", SAMPLES)
@pytest.mark.parametrize("frontier", [0.0, 0.1, 0.5, 1.0])
def test_sorted_resolution_matches_scatter_kernel_level(n, density, seed,
                                                        frontier):
    g = uniform_graph(n, max(1, int(density * n)), seed=seed)
    got, w_sorted = _push_sweep(g, frontier, seed, "sorted")
    want, w_scatter = _push_sweep(g, frontier, seed, "scatter")
    np.testing.assert_array_equal(got, want)
    assert w_sorted <= w_scatter


def test_sorted_resolution_work_frontier_proportional():
    """A one-vertex frontier on a power-law graph must keep only the
    resolution tiles holding that vertex's successors — Σ kept nnz bounded
    by the frontier's out-edges padded to tile granularity, and far under
    the scatter's full rectangle."""
    g = rmat_graph(128, 1024, seed=4)
    ell = to_blocked_ell(g, direction="out")
    res = to_push_resolution(g)
    # a TAIL vertex (power-law: low out-degree, co-blocked with other tail
    # rows) — a hub frontier legitimately lights most resolution tiles
    active = jnp.zeros(ell.n_pad, jnp.int32).at[125].set(1)
    tile_act = er.tile_activity_push(ell.tile_nnz, active, ell.block_v)
    res_tile_act = er.resolution_tile_activity(
        res.contrib, tile_act, res.tile_nnz)
    kept = float(jnp.sum(res.tile_nnz * res_tile_act))
    full = float(jnp.sum(res.tile_nnz))
    # the frontier-active out tiles hold ≤ block_v rows of successors; their
    # candidates land in ≤ that many resolution tiles' worth of real slots
    out_edge_bound = float(jnp.sum(ell.tile_nnz * tile_act))
    assert kept <= out_edge_bound * res.block_v * res.block_e
    assert kept < full, "sparse frontier must not light every resolution tile"
    # and an empty frontier keeps nothing
    none_act = er.resolution_tile_activity(
        res.contrib, jnp.zeros_like(tile_act), res.tile_nnz)
    assert float(jnp.sum(none_act)) == 0.0


# ---------------------------------------------------------------------------
# engine level: knob equivalence, launch classes, cache keying, work stats
# ---------------------------------------------------------------------------

def _value(g, name, model=None, push_resolution=None, **kw):
    prog = fusion.fuse(U.ALL_SPECS[name]())
    return engine.run_program(g, prog, engine="pallas", model=model,
                              push_resolution=push_resolution, **kw)


@pytest.mark.parametrize("name", ["BFS", "SSSP", "CC"])
@pytest.mark.parametrize("model", ["push", None])
def test_sorted_matches_scatter_engine_level(name, model, small_graphs):
    from repro.graph.structure import undirected
    g = small_graphs["rmat"]
    g = undirected(g) if name == "CC" else g
    a = _value(g, name, model=model, push_resolution="sorted")
    _cold()
    b = _value(g, name, model=model, push_resolution="scatter")
    np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))
    want = norm_inf(engine.run_program(
        g, fusion.fuse(U.ALL_SPECS[name]()), engine="pull").value)
    np.testing.assert_allclose(norm_inf(a.value), want, atol=1e-4)


def test_sorted_matches_scatter_nonidempotent_push():
    """NSP forced push−: the full-recompute scatter path vs the sorted
    segment path (sum secondary — candidate multisets are identical and the
    test values are exactly representable, so bitwise still holds)."""
    g = uniform_graph(14, 34, seed=6)
    a = _value(g, "NSP", model="push", push_resolution="sorted")
    _cold()
    b = _value(g, "NSP", model="push", push_resolution="scatter")
    np.testing.assert_array_equal(np.asarray(a.value), np.asarray(b.value))


def test_resolve_launch_class(small_graphs):
    """"sorted" adds exactly one resolution tile pass per traced push sweep
    — counted under resolve_launches, NEVER under the edge-sweep counters
    (the sweep launch contract of DESIGN.md §2 is direction-symmetric)."""
    g = small_graphs["rmat"]
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    _cold()
    engine.run_program(g, prog, engine="pallas", model="push",
                       push_resolution="sorted")
    assert er.SWEEP_STATS["launches"] == 1
    assert er.SWEEP_STATS["push_launches"] == 1
    assert er.SWEEP_STATS["resolve_launches"] == 1
    _cold()
    engine.run_program(g, prog, engine="pallas", model="push",
                       push_resolution="scatter")
    assert er.SWEEP_STATS["launches"] == 1
    assert er.SWEEP_STATS["resolve_launches"] == 0
    _cold()
    engine.run_program(g, prog, engine="pallas", model="pull")
    assert er.SWEEP_STATS["resolve_launches"] == 0
    _cold()
    engine.run_program(g, prog, engine="pallas")      # auto: 1 traced push
    assert er.SWEEP_STATS["launches"] == 2
    assert er.SWEEP_STATS["resolve_launches"] == 1


def test_push_resolution_is_cache_key(small_graphs):
    g = small_graphs["rmat"]
    prog = fusion.fuse(U.ALL_SPECS["SSSP"]())
    _cold()
    engine.run_program(g, prog, engine="pallas", push_resolution="sorted")
    assert kops.executor_cache_size() == 1
    engine.run_program(g, prog, engine="pallas", push_resolution="scatter")
    assert kops.executor_cache_size() == 2
    engine.run_program(g, prog, engine="pallas", push_resolution="sorted")
    assert kops.executor_cache_size() == 2              # hit, no new entry


def test_resolve_work_reported_and_frontier_proportional():
    """The engine-level acceptance quantity: on a power-law BFS the sorted
    path's resolution work must stay strictly under the scatter path's
    full-rectangle cost and be reported through ExecStats + SWEEP_STATS."""
    g = rmat_graph(256, 2048, seed=17)
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    _cold()
    srt = engine.run_program(g, prog, engine="pallas",
                             push_resolution="sorted")
    rw_sorted = srt.stats.resolve_work
    assert er.SWEEP_STATS["resolve_work"] == rw_sorted
    _cold()
    sct = engine.run_program(g, prog, engine="pallas",
                             push_resolution="scatter")
    assert sct.stats.push_iters >= 1, "heuristic must take push iterations"
    assert srt.stats.push_iters == sct.stats.push_iters
    assert 0 < rw_sorted < sct.stats.resolve_work
    np.testing.assert_array_equal(np.asarray(srt.value),
                                  np.asarray(sct.value))


def test_invalid_push_resolution_rejected(small_graphs):
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    with pytest.raises(ValueError, match="push_resolution"):
        engine.run_program(small_graphs["rmat"], prog, engine="pallas",
                           push_resolution="radix")


# ---------------------------------------------------------------------------
# Gemini direction autotune (|E_frontier| vs |E|/k)
# ---------------------------------------------------------------------------

def test_switch_k_is_edge_mass_not_vertex_fraction():
    """A single active HUB carries pull-worthy edge volume: under the
    Gemini rule a k that classifies the hub's edge mass as dense must force
    pull even though the vertex fraction is tiny — the case the old
    DENSE_FRONTIER vertex rule gets wrong by construction."""
    # star: vertex 0 → all others; BFS from 0 has a 1-vertex frontier with
    # (n−1)/|E| = 100% of the edges behind it
    n = 40
    src = np.zeros(n - 1, np.int64)
    dst = np.arange(1, n)
    from repro.graph.structure import from_edges
    g = from_edges(n, src, dst)
    dk = U.handwritten_bfs_depth(0)
    _cold()
    res = engine.run_direct(g, dk, engine="pallas", switch_k=2.0)
    # iteration 1: e_frontier = |E| > |E|/2 → pull, every iteration after
    # has an empty-out-degree frontier (leaves) → push
    assert res.stats.pull_iters >= 1
    _cold()
    res2 = engine.run_direct(g, dk, engine="pallas", switch_k=0.5)
    # |E|/0.5 = 2|E|: even the full-graph frontier reads as sparse → push
    assert res2.stats.pull_iters == 0 and res2.stats.push_iters >= 1
    np.testing.assert_array_equal(np.asarray(res.value),
                                  np.asarray(res2.value))


def test_switch_k_none_falls_back_to_dense_frontier():
    """switch_k=None restores the documented vertex-fraction fallback, and
    both rules agree on the fixpoint (direction never changes values)."""
    from repro.graph.structure import line_graph
    g = line_graph(48, weighted=True, seed=3)
    dk = U.handwritten_bfs_depth(0)
    _cold()
    gem = engine.run_direct(g, dk, engine="pallas")           # Gemini default
    _cold()
    frac = engine.run_direct(g, dk, engine="pallas", switch_k=None)
    np.testing.assert_array_equal(np.asarray(gem.value),
                                  np.asarray(frac.value))
    for r in (gem, frac):
        assert r.stats.pull_iters > 0 and r.stats.push_iters > 0
    # distinct heuristics are distinct executor entries (key carries k)
    _cold()
    engine.run_direct(g, dk, engine="pallas", switch_k=10.0)
    engine.run_direct(g, dk, engine="pallas", switch_k=30.0)
    assert kops.executor_cache_size() == 2


def test_switch_k_rejects_junk():
    from repro.graph.structure import line_graph
    g = line_graph(8)
    dk = U.handwritten_bfs_depth(0)
    with pytest.raises(ValueError, match="switch_k"):
        engine.run_direct(g, dk, engine="pallas", switch_k="fastest")
    for bad in (0.0, -5):
        with pytest.raises(ValueError, match="switch_k must be > 0"):
            engine.run_direct(g, dk, engine="pallas", switch_k=bad)


def test_dense_threshold_conflict_rejected():
    """A custom dense_threshold while the Gemini rule is active would be
    silently inert — reject it instead; switch_k=None restores it."""
    from repro.core import iterate
    from repro.graph.structure import line_graph
    from repro.core.synthesis import synthesize_round
    g = line_graph(8)
    dk = U.handwritten_bfs_depth(0)
    from repro.core.fusion import Prim
    comp = iterate.CompRuntime(idx=0, op=dk.rop, dtype=iterate.DTYPES[dk.dtype],
                               p_fn=dk.p_fn, init_fn=dk.init_fn,
                               source=dk.source)
    with pytest.raises(ValueError, match="dense_threshold"):
        kops.iterate_pallas(g, [comp], [Prim(dk.rop, 0)],
                            dense_threshold=0.2)
    res = kops.iterate_pallas(g, [comp], [Prim(dk.rop, 0)],
                              dense_threshold=0.2, switch_k=None)
    assert res.iterations > 0
    # a PINNED direction never traces the switch, so a custom threshold is
    # harmless there and must not raise (pre-PR calls keep working)
    res = kops.iterate_pallas(g, [comp], [Prim(dk.rop, 0)],
                              direction="pull", dense_threshold=0.2)
    assert res.iterations > 0


def test_pinned_direction_ignores_unused_knobs_in_cache_key(small_graphs):
    """model="pull" never traces a push resolution or a direction switch —
    varying those knobs must reuse ONE compiled executor, not retrace."""
    g = small_graphs["rmat"]
    prog = fusion.fuse(U.ALL_SPECS["SSSP"]())
    _cold()
    engine.run_program(g, prog, engine="pallas", model="pull",
                       push_resolution="sorted")
    engine.run_program(g, prog, engine="pallas", model="pull",
                       push_resolution="scatter")
    engine.run_program(g, prog, engine="pallas", model="pull",
                       switch_k=7.0)
    assert kops.executor_cache_size() == 1


# ---------------------------------------------------------------------------
# gather_work: the in-kernel permutation gather is frontier-proportional
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("frontier", [0.0, 0.05, 0.3, 1.0])
def test_gather_work_bounded_by_active_resolution_nnz(frontier):
    """Satellite (b): the candidate slots the in-kernel gather reads are
    exactly the ACTIVE resolution tiles' real slots — ≤ Σ nnz over active
    tiles with skipped tiles contributing zero, and 0 on an empty
    frontier."""
    g = rmat_graph(128, 1024, seed=11)
    res = to_push_resolution(g)
    ell = to_blocked_ell(g, direction="out")
    rng = np.random.default_rng(21)
    active = jnp.asarray((rng.random(ell.n_pad) < frontier).astype(np.int32))
    tile_act = er.tile_activity_push(ell.tile_nnz, active, ell.block_v)
    res_tile_act = er.resolution_tile_activity(
        res.contrib, tile_act, res.tile_nnz)
    gather = float(jnp.sum(res.tile_nnz * res_tile_act))
    active_nnz = float(jnp.sum(jnp.where(res_tile_act > 0, res.tile_nnz, 0)))
    assert gather <= active_nnz
    skipped_nnz = float(jnp.sum(jnp.where(res_tile_act == 0, res.tile_nnz, 0)))
    assert gather + skipped_nnz == float(jnp.sum(res.tile_nnz))
    if frontier == 0.0:
        assert gather == 0.0


def test_gather_work_reported_and_under_rectangle():
    """Engine level: gather_work rides the fixpoint into ExecStats and
    SWEEP_STATS, equals resolve_work under "sorted" (the gather reads
    exactly the kept resolution slots), stays strictly under the
    full-rectangle n_pad·width per push iteration, and is 0 under
    "scatter" (no permutation gather at all)."""
    g = rmat_graph(256, 2048, seed=17)
    res = to_push_resolution(g)
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    _cold()
    srt = engine.run_program(g, prog, engine="pallas",
                             push_resolution="sorted")
    assert srt.stats.push_iters >= 1
    gw = srt.stats.gather_work
    assert er.SWEEP_STATS["gather_work"] == gw
    assert gw == srt.stats.resolve_work
    rectangle = float(res.n_pad * res.width)
    assert 0 < gw < srt.stats.push_iters * rectangle
    _cold()
    sct = engine.run_program(g, prog, engine="pallas",
                             push_resolution="scatter")
    assert sct.stats.gather_work == 0.0
    assert er.SWEEP_STATS["gather_work"] == 0.0


# ---------------------------------------------------------------------------
# stat bumps only after successful launch construction
# ---------------------------------------------------------------------------

def test_launch_stats_not_bumped_on_failed_trace(monkeypatch):
    """A pallas_call whose construction/trace raises must leave every
    launch counter untouched (interrupted traces used to pre-increment
    push_launches and skew bench launch counts)."""
    g = uniform_graph(12, 30, seed=5)
    ell = to_blocked_ell(g, direction="out")
    state = jnp.ones(ell.n_pad, jnp.float32)
    ident = float(segment.identity("min", jnp.float32))
    active = jnp.ones(ell.n_pad, jnp.int32)
    er.reset_sweep_stats()

    def boom(*a, **k):
        raise RuntimeError("trace interrupted")

    monkeypatch.setattr(er.pl, "pallas_call", boom)
    kw = dict(plans=(((0, "min"),),), idents={0: ident},
              p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n)
    with pytest.raises(RuntimeError, match="trace interrupted"):
        er.fused_ell_push_sweep(
            ell.nbrs, ell.weight, ell.capacity, ell.mask,
            jnp.ones_like(ell.tile_nnz), {0: state}, active,
            jnp.ones(ell.n_pad, jnp.float32), **kw)
    ell_in = to_blocked_ell(g)
    with pytest.raises(RuntimeError, match="trace interrupted"):
        er.fused_ell_sweep(
            ell_in.srcs, ell_in.weight, ell_in.capacity, ell_in.mask,
            jnp.ones_like(ell_in.tile_nnz), {0: state}, active,
            jnp.ones(ell_in.n_pad, jnp.float32), **kw)
    assert all(v == 0 for v in er.SWEEP_STATS.values())


def test_resolve_launch_not_bumped_on_failed_resolve_trace(monkeypatch):
    """Satellite fix: a sorted push sweep whose RESOLUTION pallas_call fails
    to construct must leave resolve_launches untouched — the edge sweep's
    own launch (the first pallas_call, which succeeded) still counts, but
    the interrupted resolution pass must not (the same skew PR 4 fixed for
    edge sweeps)."""
    g = uniform_graph(12, 30, seed=5)
    ell = to_blocked_ell(g, direction="out")
    res = to_push_resolution(g)
    state = jnp.ones(ell.n_pad, jnp.float32)
    ident = float(segment.identity("min", jnp.float32))
    active = jnp.ones(ell.n_pad, jnp.int32)
    er.reset_sweep_stats()
    real = er.pl.pallas_call
    calls = {"n": 0}

    def second_call_boom(*a, **k):
        calls["n"] += 1
        if calls["n"] >= 2:                 # 1st: push sweep, 2nd: resolve
            raise RuntimeError("resolve trace interrupted")
        return real(*a, **k)

    monkeypatch.setattr(er.pl, "pallas_call", second_call_boom)
    tile_act = er.tile_activity_push(ell.tile_nnz, active, ell.block_v)
    res_tile_act = er.resolution_tile_activity(
        res.contrib, tile_act, res.tile_nnz)
    kw = dict(plans=(((0, "min"),),), idents={0: ident},
              p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n)
    with pytest.raises(RuntimeError, match="resolve trace interrupted"):
        er.fused_ell_push_sweep(
            ell.nbrs, ell.weight, ell.capacity, ell.mask, tile_act,
            {0: state}, active, jnp.ones(ell.n_pad, jnp.float32),
            resolution="sorted",
            res=(res.in2out, res.valid, res_tile_act), **kw)
    assert calls["n"] == 2
    assert er.SWEEP_STATS["resolve_launches"] == 0
    # the successfully constructed edge-sweep launch still counts
    assert er.SWEEP_STATS["launches"] == 1
    assert er.SWEEP_STATS["push_launches"] == 1


# ---------------------------------------------------------------------------
# weighted push− epilogue parity (weighted PageRank)
# ---------------------------------------------------------------------------

def test_weighted_pagerank_pull_push_parity():
    """The weighted push− epilogue round: reference pull−/push−/dense agree
    to float tolerance, and on the pallas engine the dst-sorted resolution
    reduces the SAME dst-major rectangle as the pull sweep — so forced push
    is bitwise identical to pull, float sums included (DESIGN.md §10)."""
    g = rmat_graph(48, 220, seed=7, weighted=True)
    dk = U.handwritten_weighted_pagerank(g.n)
    pull_ref = engine.run_direct(g, dk, engine="pull")
    push_ref = engine.run_direct(g, dk, engine="push")
    dense = engine.run_direct(g, dk, engine="dense")
    np.testing.assert_allclose(np.asarray(pull_ref.value),
                               np.asarray(push_ref.value), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(pull_ref.value),
                               np.asarray(dense.value), rtol=1e-4)
    _cold()
    pp = engine.run_direct(g, dk, engine="pallas", model="pull")
    ps = engine.run_direct(g, dk, engine="pallas", model="push")  # sorted
    np.testing.assert_array_equal(np.asarray(pp.value), np.asarray(ps.value))
    np.testing.assert_allclose(np.asarray(pp.value),
                               np.asarray(pull_ref.value), rtol=1e-5)
    # mass actually flows along weights: the unweighted kernels disagree
    uw = engine.run_direct(g, U.handwritten_pagerank(g.n), engine="pull")
    assert not np.allclose(np.asarray(uw.value), np.asarray(pull_ref.value))


def test_weighted_pagerank_scatter_close():
    """The scatter fallback associates the float sums differently, so it is
    only allclose — which is exactly why the sorted path is the one that
    carries the bitwise pull ≡ push guarantee."""
    g = rmat_graph(48, 220, seed=9, weighted=True)
    dk = U.handwritten_weighted_pagerank(g.n)
    _cold()
    a = engine.run_direct(g, dk, engine="pallas", model="push",
                          push_resolution="sorted")
    _cold()
    b = engine.run_direct(g, dk, engine="pallas", model="push",
                          push_resolution="scatter")
    np.testing.assert_allclose(np.asarray(a.value), np.asarray(b.value),
                               rtol=1e-5)
