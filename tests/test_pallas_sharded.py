"""Sharded pallas engine (DESIGN.md §11): shard-local fused ELL sweeps under
shard_map must reproduce the single-device pallas engine exactly.

The multi-device equivalence tests run in a SUBPROCESS with
XLA_FLAGS=--xla_force_host_platform_device_count=8 because device count
locks at first jax init (the main test process stays 1-device); they carry
the ``distributed`` marker so the PR multi-device CI lane runs them without
waiting for nightly.  Layout invariants and the k=1 degenerate mesh run
in-process in the fast lane."""
import json

import numpy as np
import pytest

from conftest import run_forced_devices


def _run(code: str) -> str:
    return run_forced_devices(code, 8)


# ---------------------------------------------------------------------------
# In-process: sharded layout invariants (no mesh needed).
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("strategy", ["contiguous", "dst_hash"])
@pytest.mark.parametrize("direction", ["in", "out"])
def test_sharded_ell_covers_partition(strategy, direction):
    """Each shard's layout holds exactly its partition block's edges; the
    union over shards is the graph, and row_deg psums to the global degree."""
    from repro.graph.structure import rmat_graph, to_sharded_ell
    g = rmat_graph(50, 300, seed=2)
    k = 3                                   # uneven split exercises padding
    ell = to_sharded_ell(g, k, strategy=strategy, direction=direction)
    assert ell.num_edges == g.num_edges
    nbrs = np.asarray(ell.nbrs)
    mask = np.asarray(ell.mask)
    rows = np.broadcast_to(np.arange(ell.n_pad)[None, :, None], nbrs.shape)
    if direction == "in":                   # rows = dst, slots = src
        got = sorted(zip(nbrs[mask].tolist(), rows[mask].tolist()))
    else:                                   # rows = src, slots = dst
        got = sorted(zip(rows[mask].tolist(), nbrs[mask].tolist()))
    src_g, dst_g, _, _ = g.host_edges()
    assert got == sorted(zip(src_g.tolist(), dst_g.tolist()))
    # per-shard row degrees sum to the global degree of the direction
    deg = np.asarray(ell.row_deg).sum(axis=0)[:g.n]
    want = np.asarray(g.in_deg if direction == "in" else g.out_deg)
    assert np.array_equal(deg, want.astype(np.float32))
    # tile_nnz counts exactly the real slots of each tile
    n_i = ell.n_pad // ell.block_v
    n_j = ell.width // ell.block_e
    nnz = mask.reshape(k, n_i, ell.block_v, n_j, ell.block_e).sum(axis=(2, 4))
    assert np.array_equal(np.asarray(ell.tile_nnz), nnz.astype(np.int32))


def test_sharded_ell_cache_and_clear():
    from repro.core.engine import clear_program_caches, program_cache_stats
    from repro.graph.structure import sharded_ell_cached, uniform_graph
    g = uniform_graph(12, 30, seed=7)
    a = sharded_ell_cached(g, 2, direction="in")
    assert sharded_ell_cached(g, 2, direction="in") is a
    assert sharded_ell_cached(g, 2, direction="out") is not a
    assert program_cache_stats()["sharded_layouts"] == 2
    clear_program_caches()
    assert program_cache_stats()["sharded_layouts"] == 0


@pytest.mark.parametrize("strategy", ["contiguous", "dst_hash"])
def test_sharded_push_resolution_roundtrips_per_shard(strategy):
    """Each shard's in2out permutation must address its own WIDENED out
    rectangle (all shards share the max width so shard_map can stack them)
    and round-trip exactly the shard's edges — the same contract
    test_push_resolution checks on one device, per shard."""
    from repro.graph.structure import (rmat_graph, to_sharded_ell,
                                       to_sharded_push_resolution)
    g = rmat_graph(50, 300, seed=2)
    k = 3
    sres = to_sharded_push_resolution(g, k, strategy=strategy)
    ell_out = to_sharded_ell(g, k, strategy=strategy, direction="out")
    assert sres.out_width == ell_out.width
    got = []
    for s in range(k):
        valid = np.asarray(sres.valid[s])
        in2out = np.asarray(sres.in2out[s])
        # every real out-slot of THIS shard is hit exactly once
        out_mask = np.asarray(ell_out.mask[s]).reshape(-1)
        assert sorted(in2out[valid].tolist()) == \
            np.flatnonzero(out_mask).tolist()
        # the out-slot's stored destination is the dst-major slot's own row
        dst_via = np.asarray(ell_out.nbrs[s]).reshape(-1)[in2out]
        rows = np.broadcast_to(np.arange(sres.n_pad)[:, None], valid.shape)
        np.testing.assert_array_equal(dst_via[valid], rows[valid])
        src_rows = np.asarray(in2out // sres.out_width)
        got += list(zip(src_rows[valid].tolist(), dst_via[valid].tolist()))
    # the union over shards is the graph
    src_g, dst_g, _, _ = g.host_edges()
    assert sorted(got) == sorted(zip(src_g.tolist(), dst_g.tolist()))
    # contrib lists cover every resolution tile that holds real slots
    contrib = np.asarray(sres.contrib)
    nnz = np.asarray(sres.tile_nnz).reshape(k, -1)
    assert ((contrib >= 0).any(axis=2).reshape(k, -1) == (nnz > 0)).all()


def test_sharded_empty_shards_are_all_padding():
    """k > |E| leaves empty shards whose tiles all skip (mask/tile_nnz 0)."""
    from repro.graph.structure import line_graph, to_sharded_ell
    g = line_graph(4)                       # 3 edges
    ell = to_sharded_ell(g, 5, direction="in")
    mask = np.asarray(ell.mask)
    per_shard = mask.sum(axis=(1, 2))
    assert per_shard.sum() == g.num_edges
    assert (np.asarray(ell.tile_nnz)[per_shard == 0] == 0).all()


# ---------------------------------------------------------------------------
# In-process: k=1 degenerate mesh (single cpu device) + argument validation.
# ---------------------------------------------------------------------------


def _mesh1():
    import jax
    from jax.sharding import Mesh
    return Mesh(np.asarray(jax.devices()[:1]), ("data",))


def test_sharded_k1_matches_single_device_bitwise():
    """A 1-shard mesh must reproduce the single-device engine exactly —
    the degenerate case runs in the fast lane on one CPU device."""
    from repro.core import engine, fusion
    from repro.core import usecases as U
    from repro.graph.structure import uniform_graph
    g = uniform_graph(9, 18, seed=3)
    mesh = _mesh1()
    for name in ("BFS", "SSSP", "NSP"):
        prog = fusion.fuse(U.ALL_SPECS[name]())
        r1 = engine.run_program(g, prog, engine="pallas")
        rs = engine.run_program(g, prog, engine="pallas_sharded", mesh=mesh)
        assert np.array_equal(np.asarray(r1.value), np.asarray(rs.value)), name
        assert rs.stats.iterations == r1.stats.iterations
        assert rs.stats.shards == 1
        assert len(rs.stats.shard_work) == 1


def test_sharded_resolution_knob_validation():
    """The sharded engine takes the same push_resolution surface as the
    single-device one: "sorted" (default) runs the per-shard resolution
    stack, "scatter" stays the reference oracle, junk is rejected with the
    shared normalizer text — the old "single-device-only" rejection of
    "sorted" is gone."""
    from repro.core import engine, fusion
    from repro.core import usecases as U
    from repro.graph.structure import uniform_graph
    g = uniform_graph(9, 18, seed=3)
    prog = fusion.fuse(U.bfs(0))
    mesh = _mesh1()
    with pytest.raises(ValueError, match="push_resolution"):
        engine.run_program(g, prog, engine="pallas_sharded", mesh=mesh,
                           push_resolution="radix")
    with pytest.raises(ValueError, match="strategy"):
        engine.run_program(g, prog, engine="pallas_sharded", mesh=mesh,
                           shard_strategy="nope")
    with pytest.raises(AssertionError, match="mesh"):
        engine.run_program(g, prog, engine="pallas_sharded")
    # both resolutions are first-class on the sharded engine and agree
    rs = engine.run_program(g, prog, engine="pallas_sharded", mesh=mesh,
                            push_resolution="sorted")
    rc = engine.run_program(g, prog, engine="pallas_sharded", mesh=mesh,
                            push_resolution="scatter")
    assert rs.stats.iterations > 0
    np.testing.assert_array_equal(np.asarray(rs.value), np.asarray(rc.value))


def test_sharded_resolution_cache_and_clear():
    """Per-shard resolution stacks are identity-cached, reported by
    program_cache_stats, and dropped per graph by clear_graph_caches."""
    from repro.core.engine import (clear_graph_caches, clear_program_caches,
                                   program_cache_stats)
    from repro.graph.structure import (sharded_push_resolution_cached,
                                       uniform_graph)
    g1 = uniform_graph(12, 30, seed=7)
    g2 = uniform_graph(12, 30, seed=8)
    a = sharded_push_resolution_cached(g1, 2)
    assert sharded_push_resolution_cached(g1, 2) is a
    assert sharded_push_resolution_cached(g1, 3) is not a
    sharded_push_resolution_cached(g2, 2)
    assert program_cache_stats()["sharded_resolutions"] == 3
    dropped = clear_graph_caches(g1)
    assert dropped >= 2
    assert program_cache_stats()["sharded_resolutions"] == 1   # g2 survives
    assert sharded_push_resolution_cached(g2, 2) is not None
    clear_program_caches()
    assert program_cache_stats()["sharded_resolutions"] == 0


# ---------------------------------------------------------------------------
# Multi-device equivalence (subprocess, 8 forced host devices).
# ---------------------------------------------------------------------------

_EQUIV_CODE = """
    import numpy as np, jax, json
    from jax.sharding import Mesh
    from repro.graph.structure import uniform_graph, rmat_graph
    from repro.core import usecases as U, fusion, engine

    graphs = {{'uniform': uniform_graph(9, 18, seed=3),
               'rmat': rmat_graph(16, 48, seed=5)}}
    ok = {{}}
    for gname, g in graphs.items():
        for name in {usecases}:
            prog = fusion.fuse(U.ALL_SPECS[name]())
            refs = {{m: engine.run_program(g, prog, engine='pallas', model=m)
                     for m in {models}}}
            for k in {ks}:
                mesh = Mesh(np.asarray(jax.devices()[:k]), ('data',))
                for model in {models}:
                    rs = engine.run_program(
                        g, prog, engine='pallas_sharded', mesh=mesh,
                        model=model, shard_strategy={strategy!r})
                    r1 = refs[model]
                    key = f'{{gname}}/{{name}}/k{{k}}/{{model}}'
                    ok[key] = (
                        bool({cmp}) and
                        rs.stats.iterations == r1.stats.iterations and
                        rs.stats.push_iters == r1.stats.push_iters and
                        rs.stats.shards == k and
                        len(rs.stats.shard_work) == k)
    print(json.dumps(ok))
"""

_BITWISE = ("np.array_equal(np.asarray(r1.value), np.asarray(rs.value))")
_ALLCLOSE = ("np.allclose(np.nan_to_num(np.asarray(r1.value, np.float64)),"
             " np.nan_to_num(np.asarray(rs.value, np.float64)),"
             " atol=1e-5, rtol=1e-5)")


def _check(out: str):
    ok = json.loads(out.strip().splitlines()[-1])
    bad = {k: v for k, v in ok.items() if not v}
    assert not bad, bad


@pytest.mark.distributed
@pytest.mark.parametrize("strategy", ["contiguous", "dst_hash"])
def test_sharded_idempotent_bitwise(strategy):
    """pallas_sharded ≡ pallas BITWISE for idempotent (pull+/push+) rounds:
    BFS and SSSP, k ∈ {2, 4}, direction ∈ {pull, push, auto} — and the
    global direction switch must take the identical push/pull sequence."""
    _check(_run(_EQUIV_CODE.format(
        usecases=("BFS", "SSSP"), models=(None, "pull", "push"),
        ks=(2, 4), strategy=strategy, cmp=_BITWISE)))


@pytest.mark.distributed
@pytest.mark.parametrize("strategy", ["contiguous", "dst_hash"])
def test_sharded_pull_minus_allclose(strategy):
    """Non-idempotent (pull−) rounds: cross-shard psum reassociates float
    sums, so NSP/NWR are allclose (min/lex primaries stay exact)."""
    _check(_run(_EQUIV_CODE.format(
        usecases=("NSP", "NWR"), models=(None,),
        ks=(2, 4), strategy=strategy, cmp=_ALLCLOSE)))


@pytest.mark.distributed
def test_sharded_pagerank_direct_allclose():
    """run_direct PageRank (epilogue pull− round) on the sharded engine."""
    out = _run("""
        import numpy as np, jax, json
        from jax.sharding import Mesh
        from repro.core import usecases as U, engine
        from repro.graph.structure import uniform_graph
        g = uniform_graph(12, 30, seed=7)
        ok = {}
        r1 = engine.run_direct(g, U.handwritten_pagerank(g.n),
                               engine='pallas')
        for k in (2, 4):
            mesh = Mesh(np.asarray(jax.devices()[:k]), ('data',))
            rs = engine.run_direct(g, U.handwritten_pagerank(g.n),
                                   engine='pallas_sharded', mesh=mesh)
            ok[f'k{k}'] = bool(
                np.allclose(np.asarray(r1.value), np.asarray(rs.value),
                            atol=1e-5)
                and rs.stats.iterations == r1.stats.iterations)
        print(json.dumps(ok))
    """)
    _check(out)


@pytest.mark.distributed
def test_sharded_reshaped_mesh_does_not_collide():
    """Two meshes over the SAME devices with the same axis names but
    different shapes must compile separate executors: the cache key carries
    the axis name→size layout, not just the device set (a collision would
    silently split a [k2, ...] stack over a k1-sized axis and drop edges)."""
    out = _run("""
        import numpy as np, jax, json
        from jax.sharding import Mesh
        from repro.core import usecases as U, fusion, engine
        from repro.graph.structure import uniform_graph
        g = uniform_graph(12, 30, seed=7)
        devs = np.asarray(jax.devices()[:4])
        mesh_a = Mesh(devs.reshape(2, 2), ('data', 'model'))
        mesh_b = Mesh(devs.reshape(4, 1), ('data', 'model'))
        prog = fusion.fuse(U.sssp(0))
        ref = engine.run_program(g, prog, engine='pallas')
        ra = engine.run_program(g, prog, engine='pallas_sharded', mesh=mesh_a)
        rb = engine.run_program(g, prog, engine='pallas_sharded', mesh=mesh_b)
        ok = {'k_a': ra.stats.shards == 2, 'k_b': rb.stats.shards == 4,
              'a_bitwise': bool(np.array_equal(np.asarray(ref.value),
                                               np.asarray(ra.value))),
              'b_bitwise': bool(np.array_equal(np.asarray(ref.value),
                                               np.asarray(rb.value)))}
        print(json.dumps(ok))
    """)
    _check(out)


@pytest.mark.distributed
@pytest.mark.parametrize("resolution", ["sorted", "scatter"])
def test_sharded_resolution_matches_single_device(resolution):
    """Satellite (c): sharded resolution ≡ single-device resolution BITWISE
    for pull/push/auto × k∈{2,4} on idempotent rounds — under the default
    per-shard sorted stack AND the scatter oracle — and the sorted stack's
    resolve_work stays strictly under the per-shard scatter rectangle."""
    out = _run("""
        import numpy as np, jax, json
        from jax.sharding import Mesh
        from repro.core import usecases as U, fusion, engine
        from repro.graph.structure import rmat_graph
        resolution = {resolution!r}
        g = rmat_graph(16, 48, seed=5)
        prog = fusion.fuse(U.ALL_SPECS['BFS']())
        ok = {{}}
        for model in (None, 'pull', 'push'):
            r1 = engine.run_program(g, prog, engine='pallas', model=model,
                                    push_resolution=resolution)
            for k in (2, 4):
                mesh = Mesh(np.asarray(jax.devices()[:k]), ('data',))
                rs = engine.run_program(
                    g, prog, engine='pallas_sharded', mesh=mesh, model=model,
                    push_resolution=resolution)
                rec = (np.array_equal(np.asarray(r1.value),
                                      np.asarray(rs.value))
                       and rs.stats.iterations == r1.stats.iterations
                       and rs.stats.push_iters == r1.stats.push_iters)
                if resolution == 'sorted' and rs.stats.push_iters:
                    # the sharded sorted resolve is frontier-proportional:
                    # strictly under the per-shard scatter rectangle, and
                    # gather bytes == kept resolution slots
                    sc = engine.run_program(
                        g, prog, engine='pallas_sharded', mesh=mesh,
                        model=model, push_resolution='scatter')
                    rec = (rec and
                           0 < rs.stats.resolve_work < sc.stats.resolve_work
                           and rs.stats.gather_work == rs.stats.resolve_work
                           and sc.stats.gather_work == 0)
                ok[f'{{model}}/k{{k}}'] = bool(rec)
        print(json.dumps(ok))
    """.format(resolution=resolution))
    _check(out)


@pytest.mark.distributed
def test_sharded_sources_share_one_executor():
    """The sharded executor is source-generic like the single-device one:
    an N-source sweep holds ONE cache entry, and the sharded stats carry
    per-shard work + cross-combine counts."""
    out = _run("""
        import numpy as np, jax, json
        from jax.sharding import Mesh
        from repro.core import usecases as U, fusion, engine
        from repro.kernels import ops as kops
        from repro.graph.structure import uniform_graph
        g = uniform_graph(12, 30, seed=7)
        mesh = Mesh(np.asarray(jax.devices()[:4]), ('data',))
        prog = fusion.fuse(U.sssp(0))
        res = [engine.run_program(g, prog, engine='pallas_sharded',
                                  mesh=mesh, source=s) for s in range(6)]
        ref = [engine.run_program(g, prog, engine='pallas', source=s)
               for s in range(6)]
        st = res[0].stats
        rec = {
          'one_entry': kops.executor_cache_size() == 2,  # sharded + single
          'bitwise': all(np.array_equal(np.asarray(a.value),
                                        np.asarray(b.value))
                         for a, b in zip(res, ref)),
          'shards': st.shards == 4,
          'shard_work': len(st.shard_work) == 4 and
                        abs(sum(st.shard_work) - st.edge_work) < 1e-6,
          'launches': st.shard_launches >= 1,
          'combines': st.cross_combines == st.iterations * 1,
        }
        print(json.dumps(rec))
    """)
    _check(out)
