"""Synthesis (§5.2) + correctness-condition (Fig. 9) tests, including
hypothesis property tests of the verified conditions."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:                     # deterministic tests still run
    _HAVE_HYPOTHESIS = False

    class _St:                          # placeholder strategies; the skip
        def __getattr__(self, name):    # below fires before they are drawn
            return lambda *a, **k: None

    st = _St()
    given = lambda *a, **k: pytest.mark.skip(reason="needs hypothesis")
    settings = lambda *a, **k: (lambda f: f)

from repro.core import conditions as C
from repro.core import lang as L
from repro.core.kernel_lang import eval_expr
from repro.core.synthesis import SynthesisError, synthesize_component

CASES = [
    ("min", L.WEIGHT), ("min", L.LENGTH), ("max", L.CAPACITY),
    ("min", L.CAPACITY), ("min", L.HEAD), ("sum", L.ONE),
]


@pytest.mark.parametrize("rop,f", CASES)
def test_synthesizes(rop, f):
    sk = synthesize_component(f, rop)
    assert sk.p_expr is not None and sk.i_expr is not None
    assert sk.candidates_tried >= 1


def test_synthesized_sssp_kernels_are_canonical():
    """For min/weight the synthesizer must find P = n + w (Fig. 4b)."""
    sk = synthesize_component(L.WEIGHT, "min")
    env = {"n": 3.0, "w": 2.0, "c": 5.0, "esrc": 0, "edst": 1,
           "outdeg": 2.0, "nv": 8.0}
    assert eval_expr(sk.p_expr, env, np) == 5.0
    assert sk.terminating          # C10 holds for min/weight (w ≥ 0)


def test_capacity_max_not_terminating_is_flagged_correctly():
    sk = synthesize_component(L.CAPACITY, "max")
    env = {"n": 3.0, "w": 2.0, "c": 2.0, "esrc": 0, "edst": 1,
           "outdeg": 2.0, "nv": 8.0}
    # P = min(n, c): extension law of capacity
    assert eval_expr(sk.p_expr, env, np) == 2.0


def test_sum_length_rejected():
    """Σ length violates C4 (sum distributes wrongly over extension) —
    synthesis must fail rather than emit a wrong kernel."""
    with pytest.raises(SynthesisError):
        synthesize_component(L.LENGTH, "sum", require_idempotent=False)


def test_idempotency_check_rejects_sum():
    rng = np.random.default_rng(0)
    assert C.check_R("min", True, rng)
    assert C.check_R("sum", False, rng)
    assert not C.check_R("sum", True, rng)


# ---------------------------------------------------------------------------
# Hypothesis property tests: the verified conditions hold on random inputs
# far outside the bounded-verification sample set.
# ---------------------------------------------------------------------------

_fin = st.floats(min_value=0.0, max_value=1e6, allow_nan=False,
                 allow_infinity=False)


@settings(max_examples=200, deadline=None)
@given(n1=_fin, n2=_fin, w=_fin, c=st.floats(min_value=0.01, max_value=1e6))
def test_c4_sssp_property(n1, n2, w, c):
    """C4 for the synthesized SSSP kernel: P(R(n1,n2),e) = R(P(n1,e),P(n2,e))."""
    sk = synthesize_component(L.WEIGHT, "min")
    p = lambda n: eval_expr(sk.p_expr, {"n": n, "w": w, "c": c, "esrc": 0,
                                        "edst": 1, "outdeg": 1.0, "nv": 4.0},
                            np)
    lhs = p(min(n1, n2))
    rhs = min(p(n1), p(n2))
    assert np.isclose(lhs, rhs, rtol=1e-9, atol=1e-9)


@settings(max_examples=200, deadline=None)
@given(n=_fin, w=_fin, c=st.floats(min_value=0.01, max_value=1e6))
def test_c5_extension_law_property(n, w, c):
    """C5: P(F(p), e) = F(p·e) via the extension laws, for all kernels."""
    for rop, f in (("min", L.WEIGHT), ("max", L.CAPACITY)):
        sk = synthesize_component(f, rop)
        got = eval_expr(sk.p_expr, {"n": n, "w": w, "c": c, "esrc": 0,
                                    "edst": 1, "outdeg": 1.0, "nv": 4.0}, np)
        want = f.extend(n, (0, 1, w, c))
        assert np.isclose(float(got), float(want), rtol=1e-9, atol=1e-9)


@settings(max_examples=100, deadline=None)
@given(n=_fin, w=_fin)
def test_c10_termination_property(n, w):
    """Strengthened C10 for SSSP: min(F(p), F(p·e)) = F(p) (w ≥ 0)."""
    f = L.WEIGHT
    ext = f.extend(n, (0, 1, w, 1.0))
    assert min(n, ext) == n


def test_emitted_source_mentions_kernels():
    from repro.core.synthesis import emit_source
    sk = synthesize_component(L.WEIGHT, "min")
    for engine in ("pull", "push", "dense", "distributed", "pallas"):
        src = emit_source(sk, engine)
        assert "propagate" in src
        assert str(sk.p_expr) in src
