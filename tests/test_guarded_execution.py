"""Guarded execution layer (DESIGN.md §12): input validation, termination
preconditions, structured convergence outcomes, divergence sentinels, and
the engine fallback chain."""
import dataclasses
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, guard, iterate
from repro.core import usecases as U
from repro.core.fusion import Prim
from repro.core.synthesis import DirectKernels
from repro.graph import structure
from repro.graph.structure import from_edges, uniform_graph


# ---------------------------------------------------------------------------
# Graph validation (structure.validate_graph / from_edges)
# ---------------------------------------------------------------------------

def test_from_edges_rejects_out_of_range_indices():
    with pytest.raises(guard.GraphValidationError, match="out of range"):
        from_edges(4, [0, 1, 9], [1, 2, 3])
    with pytest.raises(guard.GraphValidationError, match="out of range"):
        from_edges(4, [0, 1, 2], [1, -1, 3])


def test_from_edges_rejects_non_finite_weights():
    with pytest.raises(guard.GraphValidationError, match="non-finite"):
        from_edges(3, [0, 1], [1, 2], weight=[1.0, np.nan])
    with pytest.raises(guard.GraphValidationError, match="non-finite"):
        from_edges(3, [0, 1], [1, 2], capacity=[np.inf, 1.0])


def test_from_edges_rejects_float_index_arrays():
    with pytest.raises(guard.GraphValidationError, match="integer"):
        from_edges(3, np.array([0.5, 1.0]), np.array([1, 2]))


def test_from_edges_length_mismatch_and_empty():
    with pytest.raises(guard.GraphValidationError, match="length"):
        from_edges(3, [0, 1], [1])
    g = from_edges(4, [], [])                 # zero-edge graph is LEGAL
    assert g.num_edges == 0 and g.n == 4


def test_self_loop_and_duplicate_policies():
    src, dst = [0, 1, 1], [0, 2, 2]
    assert from_edges(3, src, dst).num_edges == 3          # allow (default)
    with pytest.raises(guard.GraphValidationError, match="self-loop"):
        from_edges(3, src, dst, self_loops="error")
    g = from_edges(3, src, dst, self_loops="drop")
    assert g.num_edges == 2
    with pytest.raises(guard.GraphValidationError, match="duplicate"):
        from_edges(3, src, dst, duplicates="error")
    with pytest.raises(ValueError, match="self_loops"):
        from_edges(3, src, dst, self_loops="maybe")


def test_validate_graph_check_and_cache():
    g = from_edges(4, [0, 1, 2], [1, 2, 0], weight=[1.0, 2.0, -3.0])
    chk = structure.validate_graph(g)
    assert chk.n == 4 and chk.num_edges == 3
    assert chk.w_min == -3.0 and chk.w_max == 2.0
    assert structure.validate_graph(g) is chk     # identity-keyed cache hit


def test_source_out_of_range_rejected():
    g = uniform_graph(9, 18, seed=3)
    dk = U.handwritten_bfs_depth(0)
    with pytest.raises(guard.GraphValidationError, match="out of range"):
        engine.run_direct(g, dk, engine="pull", source=9)
    with pytest.raises(guard.GraphValidationError, match="out of range"):
        engine.run_direct(g, dk, engine="pallas", sources=[0, 99])


# ---------------------------------------------------------------------------
# Termination preconditions (strengthened C10 vs actual edge ranges)
# ---------------------------------------------------------------------------

def _neg_weight_graph():
    return from_edges(4, [0, 1, 2, 3], [1, 2, 3, 0],
                      weight=[1.0, -2.0, 1.0, 1.0])


@pytest.mark.parametrize("eng", ["pull", "adaptive", "pallas"])
def test_min_plus_on_negative_weights_rejected(eng):
    dk = U.handwritten_sssp(0)
    with pytest.raises(guard.TerminationPreconditionError) as ei:
        engine.run_direct(_neg_weight_graph(), dk, engine=eng)
    assert ei.value.condition == "C10"
    assert ei.value.component == 0


def test_validate_false_skips_precondition():
    dk = U.handwritten_sssp(0)
    r = engine.run_direct(_neg_weight_graph(), dk, engine="pull",
                          validate=False, on_nonconverge="ignore")
    assert r.stats.iterations > 0


def test_in_contract_graph_not_probed():
    g = uniform_graph(9, 18, seed=3, weighted=True)   # w >= 0 generator
    dk = U.handwritten_sssp(0)
    r = engine.run_direct(g, dk, engine="pull")
    assert r.stats.iterations > 0


def test_bfs_unaffected_by_negative_weights():
    """BFS ignores w (P = n + 1), so C10 holds even out of contract."""
    dk = U.handwritten_bfs_depth(0)
    r = engine.run_direct(_neg_weight_graph(), dk, engine="pull")
    assert int(np.asarray(r.value)[3]) == 3


# ---------------------------------------------------------------------------
# Structured convergence outcomes
# ---------------------------------------------------------------------------

def test_iteration_result_converged_fields():
    g = uniform_graph(12, 30, seed=7)
    dk = U.handwritten_bfs_depth(0)
    comp = iterate.CompRuntime(idx=0, op=dk.rop,
                               dtype=iterate.DTYPES[dk.dtype],
                               p_fn=dk.p_fn, init_fn=dk.init_fn,
                               source=dk.source)
    res = iterate.iterate_graph(g, [comp], [Prim(dk.rop, 0)])
    assert res.converged is True and res.diverged is False
    assert res.active_count == 0
    res1 = iterate.iterate_graph(g, [comp], [Prim(dk.rop, 0)], max_iter=1)
    assert res1.converged is False and res1.active_count > 0


@pytest.mark.parametrize("eng", ["pull", "dense", "pallas"])
def test_nonconvergence_raises_with_diagnostics(eng):
    g = uniform_graph(12, 30, seed=7)
    dk = dataclasses.replace(U.handwritten_bfs_depth(0), max_iter=1)
    with pytest.raises(guard.NonConvergenceError) as ei:
        engine.run_direct(g, dk, engine=eng)
    assert ei.value.iterations == 1 and ei.value.max_iter == 1
    assert ei.value.active_count > 0


def test_nonconvergence_warn_and_ignore():
    g = uniform_graph(12, 30, seed=7)
    dk = dataclasses.replace(U.handwritten_bfs_depth(0), max_iter=1)
    r = engine.run_direct(g, dk, engine="pull", on_nonconverge="ignore")
    assert r.stats.iterations == 1
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        engine.run_direct(g, dk, engine="pull", on_nonconverge="warn")
    assert any(issubclass(x.category, RuntimeWarning) for x in w)
    with pytest.raises(ValueError, match="on_nonconverge"):
        engine.run_direct(g, dk, engine="pull", on_nonconverge="explode")


def _doubling_kernels(max_iter=300):
    """A non-idempotent kernel whose fixpoint blows up: new[v] = 4 · Σ n —
    values grow geometrically until float32 overflows to inf."""
    return DirectKernels(
        name="blowup", rop="sum", dtype="float",
        p_fn=lambda env: env["n"] * 4.0,
        init_fn=lambda v, s: jnp.where(v == s, 1.0, 0.0),
        source=0, max_iter=max_iter)


@pytest.mark.parametrize("eng", ["pull", "pallas"])
def test_divergence_sentinel_fires(eng):
    g = from_edges(3, [0, 1, 2], [1, 2, 0], weight=[1.0, 1.0, 1.0])
    with pytest.raises(guard.DivergenceError):
        engine.run_direct(g, dk=_doubling_kernels(), engine=eng)


def test_divergence_sentinel_off_returns_silent_state():
    g = from_edges(3, [0, 1, 2], [1, 2, 0], weight=[1.0, 1.0, 1.0])
    r = engine.run_direct(g, _doubling_kernels(), engine="pallas",
                          divergence_sentinel=False,
                          on_nonconverge="ignore")
    assert np.isinf(np.asarray(r.value)).any()    # the silent wrong answer


def test_batched_outcomes_name_offending_sources():
    g = uniform_graph(12, 30, seed=7)
    dk = dataclasses.replace(U.handwritten_bfs_depth(0), max_iter=1)
    with pytest.raises(guard.NonConvergenceError, match="sources"):
        engine.run_direct(g, dk, engine="pallas", sources=[0, 3])
    outs = engine.run_direct(g, dk, engine="pallas", sources=[0, 3],
                             on_nonconverge="ignore")
    assert len(outs) == 2


# ---------------------------------------------------------------------------
# Per-shard replication diagnostics (satellite: distributed iteration-count
# divergence error reports per-shard counts and offending shard ids)
# ---------------------------------------------------------------------------

def test_check_shard_replication_names_offenders():
    iterate.check_shard_replication(np.array([5, 5, 5]), "iteration count",
                                    "distributed")          # no raise
    with pytest.raises(RuntimeError) as ei:
        iterate.check_shard_replication(np.array([5, 5, 7, 5, 9]),
                                        "iteration count", "distributed")
    msg = str(ei.value)
    assert "[5, 5, 7, 5, 9]" in msg          # per-shard counts
    assert "offending shard ids [2, 4]" in msg
    assert "majority value 5" in msg


def test_check_shard_replication_two_way_tie():
    with pytest.raises(RuntimeError, match="offending shard ids"):
        iterate.check_shard_replication(np.array([5, 7]), "iteration count",
                                        "pallas_sharded")
