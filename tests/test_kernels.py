"""Pallas kernel sweeps: shapes × dtypes against the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.graph.structure import rmat_graph, to_blocked_ell, uniform_graph
from repro.kernels import ops, ref
from repro.kernels.edge_reduce import ell_level_reduce


# ---------------------------------------------------------------------------
# embedding_bag
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("v,d,b,k", [(64, 128, 128, 1), (100, 64, 256, 4),
                                     (37, 256, 128, 8), (16, 128, 512, 2)])
@pytest.mark.parametrize("mode", ["sum", "mean"])
def test_embedding_bag_sweep(v, d, b, k, mode):
    rng = np.random.default_rng(v + d)
    table = jnp.asarray(rng.normal(size=(v, d)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, v, size=(b, k)).astype(np.int32))
    got = ops.embedding_bag(table, idx, mode=mode)
    want = ref.ref_embedding_bag(table, idx, mode=mode)
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_embedding_bag_weighted():
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(50, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 50, size=(128, 4)).astype(np.int32))
    w = jnp.asarray(rng.normal(size=(128, 4)).astype(np.float32))
    got = ops.embedding_bag(table, idx, weights=w, mode="sum")
    want = ref.ref_embedding_bag(table, idx, weights=w, mode="sum")
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_embedding_bag_bf16():
    rng = np.random.default_rng(1)
    table = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32)
                        ).astype(jnp.bfloat16)
    idx = jnp.asarray(rng.integers(0, 64, size=(128, 2)).astype(np.int32))
    got = ops.embedding_bag(table, idx)
    want = ref.ref_embedding_bag(table, idx)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=2e-2, atol=2e-2)


# ---------------------------------------------------------------------------
# ell_softmax
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,e,seed", [(64, 400, 0), (100, 600, 1),
                                      (128, 2000, 2)])
def test_ell_softmax_sweep(n, e, seed):
    g = rmat_graph(n, e, seed=seed)
    ell = to_blocked_ell(g)
    rng = np.random.default_rng(seed)
    scores = jnp.asarray(
        rng.normal(size=ell.srcs.shape).astype(np.float32)) * 5
    got = ops.ell_softmax(scores, ell.mask)
    want = ref.ref_ell_softmax(scores, ell.mask)
    np.testing.assert_allclose(got, want, atol=1e-5)
    rows = np.asarray(got).sum(axis=1)
    real = np.asarray(ell.mask).any(axis=1)
    np.testing.assert_allclose(rows[real], 1.0, atol=1e-5)
    assert np.all(np.asarray(got)[~np.asarray(ell.mask)] == 0.0)


def test_ell_softmax_online_stability():
    """Online recurrence must survive large score magnitudes (±1e4)."""
    g = uniform_graph(32, 200, seed=3)
    ell = to_blocked_ell(g, block_e=128)
    rng = np.random.default_rng(3)
    scores = jnp.asarray(rng.normal(size=ell.srcs.shape)
                         .astype(np.float32)) * 1e4
    got = np.asarray(ops.ell_softmax(scores, ell.mask))
    assert np.all(np.isfinite(got))


# ---------------------------------------------------------------------------
# edge_reduce (the GraFS edge sweep)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("op,dtype", [("min", jnp.float32),
                                      ("max", jnp.float32),
                                      ("sum", jnp.float32),
                                      ("min", jnp.int32)])
def test_edge_level_reduce_vs_ref(op, dtype):
    from repro.graph import segment
    g = rmat_graph(48, 256, seed=9)
    ell = to_blocked_ell(g)
    rng = np.random.default_rng(9)
    ident = segment.identity(op, dtype)
    state = jnp.asarray(rng.integers(0, 50, size=ell.n_pad).astype(
        np.dtype(dtype)))
    outdeg = jnp.ones(ell.n_pad, jnp.float32)
    active = jnp.ones(ell.n_pad, jnp.int32)
    p_fn = lambda env: env["n"] + env["w"].astype(env["n"].dtype)

    got = ell_level_reduce(ell, op, [p_fn], [state], [ident], active,
                           outdeg)
    want = ref.ref_edge_level(
        op, state, ell.srcs, ell.mask,
        lambda nv, srcs: nv + jnp.asarray(ell.weight, nv.dtype),
        ident, ident)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_edge_reduce_frontier_mask():
    """Inactive sources must contribute the identity."""
    from repro.graph import segment
    g = uniform_graph(24, 80, seed=4)
    ell = to_blocked_ell(g)
    rng = np.random.default_rng(4)
    state = jnp.asarray(rng.uniform(1, 9, ell.n_pad).astype(np.float32))
    outdeg = jnp.ones(ell.n_pad, jnp.float32)
    active = jnp.zeros(ell.n_pad, jnp.int32)     # nothing active
    ident = segment.identity("min", jnp.float32)
    p_fn = lambda env: env["n"] + env["w"]
    got = ell_level_reduce(ell, "min", [p_fn], [state], [ident], active,
                           outdeg)
    assert np.all(np.asarray(got) == np.float32(ident))


@pytest.mark.parametrize("block_v,block_e", [(8, 128), (16, 128), (8, 256)])
def test_edge_reduce_block_shapes(block_v, block_e):
    from repro.graph import segment
    g = rmat_graph(40, 200, seed=11)
    ell = to_blocked_ell(g, block_v=block_v, block_e=block_e)
    rng = np.random.default_rng(11)
    state = jnp.asarray(rng.uniform(0, 5, ell.n_pad).astype(np.float32))
    outdeg = jnp.ones(ell.n_pad, jnp.float32)
    active = jnp.ones(ell.n_pad, jnp.int32)
    ident = segment.identity("min", jnp.float32)
    p_fn = lambda env: env["n"] + env["w"]
    got = ell_level_reduce(ell, "min", [p_fn], [state], [ident], active,
                           outdeg, block_v=block_v, block_e=block_e)
    want = ref.ref_edge_level(
        "min", state, ell.srcs, ell.mask,
        lambda nv, srcs: nv + ell.weight, ident, ident)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-4)


def test_pallas_engine_equals_pull(small_graphs):
    from repro.core import engine, fusion, usecases as U
    g = small_graphs["rmat"]
    for name in ("SSSP", "WSP", "NSP"):
        prog = fusion.fuse(U.ALL_SPECS[name]())
        a = engine.run_program(g, prog, engine="pull").value
        b = engine.run_program(g, prog, engine="pallas").value
        np.testing.assert_allclose(np.asarray(a, np.float64),
                                   np.asarray(b, np.float64), atol=1e-4)


# ---------------------------------------------------------------------------
# flash_attention (forward kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,h,hkv,s,d", [(1, 4, 4, 64, 32), (2, 4, 2, 128, 16),
                                         (1, 8, 1, 256, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(b, h, hkv, s, d, causal):
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(h * s)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    want = ref.ref_flash_attention(q, k, v, causal=causal)
    # fully-masked first row in causal=False is impossible; causal row 0
    # attends to itself only — both finite
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_chunked_local():
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(0)
    b, h, s, d, chunk = 1, 2, 128, 32, 32
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    got = flash_attention(q, k, v, causal=True, chunk=chunk,
                          block_q=64, block_k=64)
    want = ref.ref_flash_attention(q, k, v, causal=True, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-3, atol=2e-3)


def test_flash_attention_bf16():
    from repro.kernels.flash_attention import flash_attention
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    k = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    v = jnp.asarray(rng.normal(size=(1, 2, 64, 32))).astype(jnp.bfloat16)
    got = flash_attention(q, k, v)
    want = ref.ref_flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=3e-2, atol=3e-2)
