"""Push ≡ pull ≡ reference across randomized graphs (DESIGN.md §2).

The direction-optimized pallas engine is only sound if every direction of
every admissible round computes the same fixpoint.  The tests below drive
randomized graphs (seeded parametrized samples always; hypothesis fuzzing
on top when available) through

  * the pallas push sweep (``model="push"``: Defs. 3/4 on the out-edge
    blocked layout),
  * the pallas pull sweep (``model="pull"``: Defs. 1/2 on the in-edge
    layout),
  * the direction-optimized default (per-iteration heuristic switch),
  * the segment-op pull/push engines (``iterate.iterate_graph``), and
  * the ``kernels/ref.py`` oracle at the single-sweep level,

and require agreement through ``conftest.norm_inf`` for BFS / SSSP / WCC
(idempotent, frontier-masked + models) plus one non-idempotent round (NSP's
count-of-shortest-paths sum ⇒ the − full-recompute models with the
has-pred probe).
"""
import numpy as np
import pytest

import jax.numpy as jnp

from conftest import norm_inf
from repro.core import engine, fusion
from repro.core import usecases as U
from repro.graph import segment
from repro.graph.structure import to_blocked_ell, undirected, uniform_graph
from repro.kernels import edge_reduce as er
from repro.kernels import ref

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                       # container without the test extra:
    HAVE_HYPOTHESIS = False               # the seeded samples below still run

# seeded (n, edge-density, seed) samples — deterministic "randomized graphs"
SAMPLES = [(7, 1.2, 101), (10, 2.0, 202), (13, 2.8, 303),
           (16, 1.6, 404), (19, 2.4, 505), (24, 3.0, 606)]

IDEMPOTENT = ["BFS", "SSSP", "CC"]        # CC == WCC on the symmetrized graph


def _rand_graph(n, density, seed, symmetric=False):
    g = uniform_graph(n, max(1, int(density * n)), seed=seed)
    return undirected(g) if symmetric else g


def _value(g, name, eng, model=None, **kw):
    prog = fusion.fuse(U.ALL_SPECS[name]())
    return engine.run_program(g, prog, engine=eng, model=model, **kw).value


def _assert_directions_agree_idempotent(name, n, density, seed):
    g = _rand_graph(n, density, seed, symmetric=(name == "CC"))
    want = norm_inf(_value(g, name, "pull"))
    resolutions = {}
    for eng, model, resolution in (
            ("push", None, None), ("pallas", "pull", None),
            ("pallas", "push", "sorted"), ("pallas", "push", "scatter"),
            ("pallas", None, "sorted"), ("pallas", None, "scatter")):
        raw = _value(g, name, eng, model=model,
                     **({} if resolution is None else
                        {"push_resolution": resolution}))
        got = norm_inf(raw)
        np.testing.assert_allclose(got, want, atol=1e-4,
                                   err_msg=f"{name} {eng}/{model}")
        # the two resolution paths of one (engine, model) must agree
        # bit-for-bit, not just through norm_inf
        if resolution is not None:
            other = resolutions.setdefault((eng, model), np.asarray(raw))
            np.testing.assert_array_equal(
                np.asarray(raw), other,
                err_msg=f"{name} {model}: sorted != scatter bitwise")


def _assert_directions_agree_nonidempotent(n, density, seed):
    """NSP fuses a min-lex primary with a non-idempotent sum secondary ⇒
    the engines run the − (full recompute) models with the has-pred probe:
    pallas pull− and forced push− (both resolution paths) must all match
    the pull engine."""
    g = _rand_graph(n, density, seed)
    want = norm_inf(_value(g, "NSP", "pull"))
    for eng, model, kw in (("pallas", None, {}),
                           ("pallas", "push", {"push_resolution": "sorted"}),
                           ("pallas", "push", {"push_resolution": "scatter"})):
        got = norm_inf(_value(g, "NSP", eng, model=model, **kw))
        np.testing.assert_allclose(got, want, atol=1e-4,
                                   err_msg=f"NSP {eng}/{model}")


def _assert_push_sweep_matches_ref(n, density, seed, frontier):
    """One frontier-masked min sweep: ``fused_ell_push_sweep`` over the
    out-edge layout must equal ``ref.ref_edge_level`` over the in-edge
    layout bit-for-bit (both reduce the same logical edge set)."""
    g = _rand_graph(n, density, seed)
    ell_in = to_blocked_ell(g)
    ell_out = to_blocked_ell(g, direction="out")
    rng = np.random.default_rng(seed)
    n_pad = ell_in.n_pad
    state = jnp.asarray(rng.integers(1, 9, n_pad).astype(np.float32))
    ident = float(segment.identity("min", jnp.float32))
    active = jnp.asarray((rng.random(n_pad) < frontier).astype(np.int32))
    outdeg = jnp.ones(n_pad, jnp.float32)

    # oracle: pull-layout gather with frontier-inactive sources masked to ⊥
    masked_state = jnp.where(active != 0, state, ident)
    want = ref.ref_edge_level(
        "min", masked_state, ell_in.srcs, ell_in.mask,
        lambda nvals, srcs: nvals + ell_in.weight, ident, ident)

    tile_act = er.tile_activity_push(ell_out.tile_nnz, active, ell_out.block_v)
    got, _ = er.fused_ell_push_sweep(
        ell_out.nbrs, ell_out.weight, ell_out.capacity, ell_out.mask,
        tile_act, {0: state}, active, outdeg,
        plans=(((0, "min"),),), idents={0: ident},
        p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n)
    np.testing.assert_array_equal(np.asarray(got[0]), np.asarray(want))


# ---------------------------------------------------------------------------
# seeded parametrized samples (always run, no optional deps)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", IDEMPOTENT)
@pytest.mark.parametrize("n,density,seed", SAMPLES[:3])
def test_push_pull_auto_agree_idempotent(name, n, density, seed):
    _assert_directions_agree_idempotent(name, n, density, seed)


@pytest.mark.parametrize("n,density,seed", SAMPLES[:3])
def test_push_pull_agree_nonidempotent_round(n, density, seed):
    _assert_directions_agree_nonidempotent(n, density, seed)


@pytest.mark.parametrize("n,density,seed", SAMPLES)
@pytest.mark.parametrize("frontier", [0.1, 0.6, 1.0])
def test_push_sweep_matches_ref_oracle(n, density, seed, frontier):
    _assert_push_sweep_matches_ref(n, density, seed, frontier)


def test_push_sweep_skipped_row_tiles_emit_identities():
    """Row tiles with no frontier-active source must short-circuit and emit
    the reduction identities bit-for-bit (pl.when path, C6)."""
    g = uniform_graph(48, 300, seed=9)
    ell = to_blocked_ell(g, direction="out")
    rng = np.random.default_rng(9)
    state = jnp.asarray(rng.uniform(1, 9, ell.n_pad).astype(np.float32))
    ident = float(segment.identity("min", jnp.float32))
    active = jnp.zeros(ell.n_pad, jnp.int32)   # nothing active anywhere
    tile_act = er.tile_activity_push(ell.tile_nnz, active, ell.block_v)
    assert not np.asarray(tile_act).any()
    red, _, cands = er.fused_ell_push_sweep(
        ell.nbrs, ell.weight, ell.capacity, ell.mask, tile_act, {0: state},
        active, jnp.ones(ell.n_pad, jnp.float32),
        plans=(((0, "min"),),), idents={0: ident},
        p_fns={0: lambda env: env["n"] + env["w"]}, nv=g.n,
        return_candidates=True)
    assert np.all(np.asarray(cands[0]) == np.float32(ident))
    assert np.all(np.asarray(red[0]) == np.float32(ident))


def test_direction_optimized_does_less_work_on_sparse_frontier():
    """The tentpole claim at engine level: on a power-law BFS the adaptive
    pallas engine's total edge work is ≤ the pull-only engine's, with at
    least one iteration actually taking the push direction."""
    from repro.graph.structure import rmat_graph
    from repro.kernels import edge_reduce as er
    g = rmat_graph(256, 2048, seed=17)
    prog = fusion.fuse(U.ALL_SPECS["BFS"]())
    engine.clear_program_caches()
    er.reset_sweep_stats()
    auto = engine.run_program(g, prog, engine="pallas")
    pushed = er.SWEEP_STATS["push_iters"]
    engine.clear_program_caches()
    pull = engine.run_program(g, prog, engine="pallas", model="pull")
    assert pushed >= 1
    assert auto.stats.edge_work <= pull.stats.edge_work
    np.testing.assert_allclose(norm_inf(auto.value), norm_inf(pull.value),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# hypothesis fuzz layer (runs wherever the test extra is installed, e.g. CI)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=6, deadline=None)
    @given(name=st.sampled_from(IDEMPOTENT), n=st.integers(6, 20),
           density=st.floats(1.0, 3.0), seed=st.integers(0, 10_000))
    @pytest.mark.slow
    def test_push_pull_fuzz_idempotent(name, n, density, seed):
        _assert_directions_agree_idempotent(name, n, density, seed)

    @settings(max_examples=6, deadline=None)
    @given(n=st.integers(6, 16), density=st.floats(1.0, 2.5),
           seed=st.integers(0, 10_000))
    @pytest.mark.slow
    def test_push_pull_fuzz_nonidempotent(n, density, seed):
        _assert_directions_agree_nonidempotent(n, density, seed)

    @settings(max_examples=10, deadline=None)
    @given(n=st.integers(8, 48), density=st.floats(1.0, 6.0),
           seed=st.integers(0, 10_000), frontier=st.floats(0.05, 1.0))
    def test_push_sweep_fuzz_matches_ref_oracle(n, density, seed, frontier):
        _assert_push_sweep_matches_ref(n, density, seed, frontier)
