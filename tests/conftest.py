import os
import subprocess
import sys
import textwrap

# Tests run single-device (the dry-run sets its own 512-device flag in its
# own process; see src/repro/launch/dryrun.py).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest

_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_forced_devices(code: str, device_count: int) -> str:
    """Run ``code`` in a subprocess on ``device_count`` forced host devices
    (device count locks at first jax init, so multi-device suites cannot run
    in the main test process).  JAX_PLATFORMS is pinned to cpu: the forced
    host devices need the cpu platform, and leaving the choice to
    auto-detection stalls on hosts whose TPU plugin probes — and retries —
    instance metadata before falling back.  Shared by test_distributed and
    test_pallas_sharded."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = \
        f"--xla_force_host_platform_device_count={device_count}"
    env["PYTHONPATH"] = _SRC
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def norm_inf(x):
    """Collapse every ⊥-ish value to one token before comparing.

    The engines use finite sentinels (±~1e9 int, ±inf float) for ⊥; the
    oracle uses IEEE ±inf/nan.  Arithmetic over unreachable vertices may
    produce any of them (-inf vs nan for -⊥/⊥ etc.) — all mean "undefined"
    in the paper's domain, so they compare equal."""
    v = np.asarray(x, dtype=np.float64)
    return np.where(np.isnan(v) | (np.abs(v) >= 1e8), np.float64(1e9), v)


@pytest.fixture(autouse=True)
def _fresh_program_caches():
    """Drop every compiled-program cache layer after each test so
    ``_EXEC_CACHE`` / ``blocked_ell_cached`` / ``synthesize_round`` state —
    and in particular the id()-reuse hazard of identity-keyed caches when a
    test's graph is garbage-collected — can never leak across tests.  Tests
    that assert warm-cache behaviour do so within a single test body."""
    yield
    from repro.core import engine
    engine.clear_program_caches()


@pytest.fixture(scope="session")
def small_graphs():
    from repro.graph.structure import line_graph, rmat_graph, uniform_graph
    return {
        "uniform": uniform_graph(9, 18, seed=3),
        "uniform2": uniform_graph(12, 30, seed=7),
        "rmat": rmat_graph(16, 48, seed=5),
        "line": line_graph(8, weighted=True, seed=2),
    }
