"""Equivalence tests for the §Perf optimization paths: every beyond-paper
optimization must be bit-compatible (or f32-roundoff-compatible) with its
reference formulation."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.models import transformer as tf
from repro.models.layers import moe_ffn

KEY = jax.random.PRNGKey(0)


@pytest.mark.slow
def test_moe_grouped_dispatch_equals_flat():
    """A3: grouped-local dispatch ≡ flat dispatch (same caps ⇒ same drops)."""
    for arch in ("deepseek-v3-671b", "llama4-maverick-400b-a17b"):
        cfg = configs.get(arch).smoke()
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
        params = tf.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab)
        batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
        l1, g1 = jax.value_and_grad(
            lambda p: tf.loss_fn(cfg, p, batch))(params)
        cfg4 = dataclasses.replace(cfg, moe_groups=4)
        l2, g2 = jax.value_and_grad(
            lambda p: tf.loss_fn(cfg4, p, batch))(params)
        assert abs(float(l1 - l2)) < 1e-6
        md = jax.tree.reduce(max, jax.tree.map(
            lambda a, b: float(jnp.abs(a - b).max()), g1, g2))
        assert md < 1e-5


def test_moe_capacity_drops_tokens():
    """With a tight capacity factor some assignments must drop (overflow
    slot) without NaNs."""
    cfg = configs.get("deepseek-v3-671b").smoke()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=0.25))
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (4, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    loss = tf.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))


def test_absorbed_mla_equals_expanded_decode():
    """C: absorbed MLA decode ≡ latent-expansion decode."""
    cfg = configs.get("deepseek-v3-671b").smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    cache = tf.init_cache(cfg, 2, 32)
    _, cache = tf.prefill(cfg, params, toks, cache)
    outs = {}
    for mode in ("expanded", "absorbed"):
        c2 = dataclasses.replace(cfg, mla_decode=mode)
        lg, _ = tf.decode_step(c2, params, toks[:, -1], jnp.int32(16), cache)
        outs[mode] = np.asarray(lg)
    np.testing.assert_allclose(outs["absorbed"], outs["expanded"],
                               rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_microbatched_train_step_equals_flat():
    """Gradient accumulation over strided microbatches ≡ one big batch
    (loss linearity; bf16-grad roundoff tolerance)."""
    import repro.launch.workloads as W
    from repro.launch.mesh import make_host_mesh
    from repro.optim.adamw import AdamWConfig, adamw_init

    cfg = configs.get("llama3.2-3b").smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (8, 16), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    loss_flat, g_flat = jax.value_and_grad(
        lambda p: tf.loss_fn(cfg, p, batch))(params)

    # manual 4-way strided accumulation (mirrors workloads.train_step)
    n_micro = 4
    mb = jax.tree.map(
        lambda x: jnp.swapaxes(
            x.reshape((x.shape[0] // n_micro, n_micro) + x.shape[1:]),
            0, 1), batch)
    losses, gsum = [], jax.tree.map(
        lambda x: jnp.zeros(x.shape, jnp.float32), params)
    for i in range(n_micro):
        one = jax.tree.map(lambda x: x[i], mb)
        l, g = jax.value_and_grad(lambda p: tf.loss_fn(cfg, p, one))(params)
        losses.append(float(l))
        gsum = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), gsum, g)
    loss_micro = np.mean(losses)
    # per-microbatch token masks are all-full → mean-of-means == flat mean
    assert abs(loss_micro - float(loss_flat)) < 5e-3
    g_micro = jax.tree.map(lambda g: g / n_micro, gsum)
    rel = jax.tree.reduce(max, jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b).max()
                           / (jnp.abs(b).max() + 1e-6)), g_micro, g_flat))
    assert rel < 0.05


def test_unfused_nested_reduction_adds_phases():
    """Fig. 13 premise: the unfused WSP runs its nested restriction as a
    separate phase (2 rounds), the fused one as a single lex round."""
    from repro.core import fusion, usecases as U
    fused = fusion.fuse(U.wsp(0))
    unfused = fusion.lower_unfused(U.wsp(0))
    f_iter_rounds = sum(1 for _, r in fused.rounds if r.leaves)
    u_iter_rounds = sum(1 for _, r in unfused.rounds if r.leaves)
    assert f_iter_rounds == 1
    assert u_iter_rounds == 2


def test_mgn_dist_loss_matches_reference():
    """B: the shard_map vertex-cut loss ≡ the single-device loss (run here
    with a 1-shard 'partition' — the multi-shard case is covered by the
    subprocess test in test_distributed.py)."""
    from repro.data import graphs as dg
    from repro.data.graphs import dst_block_partition
    from repro.models import gnn as G

    cfg = configs.get("meshgraphnet").smoke()
    b = dg.mesh_batch(rows=6, cols=6, d_node_in=cfg.d_node_in,
                      d_edge_in=cfg.d_edge_in, d_out=cfg.d_out)
    p = G.mgn_init(cfg, KEY)
    ref = float(G.mgn_loss(cfg, p, b))
    n = b["node_x"].shape[0]
    src, dst = np.asarray(b["src"]), np.asarray(b["dst"])
    part = dst_block_partition(src, dst, n, 1, pad_factor=2.0)
    ex = np.zeros((part["e_pad"], cfg.d_edge_in), np.float32)
    sel = np.nonzero(part["mask"][0])[0]
    order = np.nonzero(dst // part["n_loc"] == 0)[0][:part["e_pad"]]
    ex[:order.shape[0]] = np.asarray(b["edge_x"])[order]
    batch = {"node_x": b["node_x"], "edge_x": jnp.asarray(ex),
             "src": jnp.asarray(part["src"][0]),
             "dst": jnp.asarray(part["dst"][0]),
             "emask": jnp.asarray(part["mask"][0]),
             "nmask": jnp.ones((n,), bool), "target": b["target"]}
    got = float(G.mgn_loss_dist(cfg, p, batch, ()))
    assert abs(got - ref) < 1e-5


def test_flash_core_handles_fully_masked_rows():
    """Rows with zero valid keys (future positions) must yield 0, not NaN,
    in both directions."""
    from repro.models.layers import _sdpa
    q = jax.random.normal(KEY, (1, 4, 2, 8))
    k = jax.random.normal(KEY, (1, 8, 1, 8))
    v = jax.random.normal(KEY, (1, 8, 1, 8))
    # positions force row 0 to have NO valid keys (pos=-1)
    pos = jnp.asarray([[-1, 0, 1, 2]])

    def f(q):
        return _sdpa(q, k, v, pos, None, jnp.float32, kv_chunk=4).sum()

    val, grad = jax.value_and_grad(f)(q)
    assert np.isfinite(float(val))
    assert bool(jnp.all(jnp.isfinite(grad)))


def test_kv_cache_int8_quantization():
    """§Perf D: int8 KV cache — halves cache bytes; outputs stay aligned
    (cosine ≥ 0.98, greedy tokens identical on the smoke model)."""
    cfg = configs.get("llama3.2-3b").smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    outs = {}
    for kvq in (False, True):
        c2 = dataclasses.replace(cfg, kv_quant=kvq)
        cache = tf.init_cache(c2, 2, 32)
        lg, cache = tf.prefill(c2, params, toks, cache)
        lg2, _ = tf.decode_step(c2, params, toks[:, -1], jnp.int32(16),
                                cache)
        outs[kvq] = (np.asarray(lg), np.asarray(lg2))
        if kvq:
            assert cache["k"].dtype == jnp.int8
    for i in range(2):
        a, b = outs[False][i], outs[True][i]
        cos = float((a * b).sum() / (np.linalg.norm(a) * np.linalg.norm(b)))
        assert cos > 0.98, (i, cos)
        # greedy agreement is mostly preserved (random-weights logits are
        # near-uniform, so exact argmax equality is too strict a bar)
        assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5


def test_kv_cache_int8_bytes_halved():
    cfg = configs.get("llama3.2-3b").smoke()
    cq = dataclasses.replace(cfg, kv_quant=True)
    full = tf.init_cache(cfg, 2, 64)
    quant = tf.init_cache(cq, 2, 64)
    bytes_full = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(full))
    bytes_q = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(quant))
    assert bytes_q < 0.6 * bytes_full
