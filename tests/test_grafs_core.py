"""End-to-end behaviour of the paper's system: every use-case (Fig. 1) on
every engine must match the path-enumeration denotational-semantics oracle
(lang.paths_semantics)."""
import numpy as np
import pytest

from repro.core import engine, fusion
from repro.core import usecases as U
from repro.core.lang import paths_semantics
from repro.graph.structure import undirected, uniform_graph

from conftest import norm_inf

# CC runs on the symmetrized graph, where the path-enumeration oracle
# dominates wall time (~25 s per engine) — slow-marked for the CI fast lane.
USECASES = ["SSSP", pytest.param("CC", marks=pytest.mark.slow), "BFS", "WP",
            "WSP", "NSP", "NWR", "Trust", "RADIUS", "DRR", "DS", "RDS"]
ENGINES = ["pull", "push", "dense", "pallas"]


def _oracle(name, g):
    spec = U.ALL_SPECS[name]()
    val = paths_semantics(spec, g, max_len=g.n)
    if hasattr(val, "shape") and val.dtype == object:
        val = np.array([float(x) for x in val])
    return spec, val


def _graph_for(name, base):
    return undirected(base) if name == "CC" else base


@pytest.mark.parametrize("engine_name", ENGINES)
@pytest.mark.parametrize("name", USECASES)
def test_usecase_matches_oracle(name, engine_name, small_graphs):
    g = _graph_for(name, small_graphs["uniform"])
    spec, want = _oracle(name, g)
    prog = fusion.fuse(spec)
    res = engine.run_program(g, prog, engine=engine_name)
    np.testing.assert_allclose(norm_inf(res.value), norm_inf(want),
                               atol=1e-4)


@pytest.mark.parametrize("name", ["SSSP", "WSP", "NSP", "Trust", "RDS"])
def test_usecase_second_graph(name, small_graphs):
    g = _graph_for(name, small_graphs["uniform2"])
    spec, want = _oracle(name, g)
    prog = fusion.fuse(spec)
    res = engine.run_program(g, prog, engine="pull")
    np.testing.assert_allclose(norm_inf(res.value), norm_inf(want),
                               atol=1e-4)


@pytest.mark.parametrize("name", USECASES)
def test_unfused_equals_fused(name, small_graphs):
    """Theorem 1 (semantics preservation) checked operationally: the
    unfused lowering computes the same value as the fused program."""
    g = _graph_for(name, small_graphs["uniform"])
    spec = U.ALL_SPECS[name]()
    fused = engine.run_program(g, fusion.fuse(spec), engine="pull")
    unfused = engine.run_program(g, fusion.lower_unfused(spec), engine="pull")
    np.testing.assert_allclose(norm_inf(fused.value), norm_inf(unfused.value),
                               atol=1e-4)


@pytest.mark.parametrize("name", ["WSP", "NWR", "RADIUS", "Trust", "DRR"])
def test_fusion_reduces_edge_work(name, small_graphs):
    """The paper's Fig. 13/14 claim: fused programs process fewer edges."""
    g = small_graphs["rmat"]
    spec = U.ALL_SPECS[name]()
    fused = engine.run_program(g, fusion.fuse(spec), engine="pull")
    unfused = engine.run_program(g, fusion.lower_unfused(spec), engine="pull")
    assert fused.stats.edge_work <= unfused.stats.edge_work
    assert fused.stats.rounds <= unfused.stats.rounds


def test_fusion_stats_counted():
    stats = fusion.fuse(U.ALL_SPECS["RADIUS"]()).stats
    assert stats.fmpair >= 1            # paired path reductions (Fig. 2)
    assert stats.frpair >= 1            # paired vertex reductions
    stats = fusion.fuse(U.ALL_SPECS["WSP"]()).stats
    assert stats.fpnest >= 1            # nested reduction flattened
    stats = fusion.fuse(U.ALL_SPECS["DRR"]()).stats
    assert stats.cse >= 1               # common operation elimination


def test_handwritten_kernels_match_synthesized(small_graphs):
    """Fig. 11 premise: handwritten kernel programs compute the same values
    as synthesized ones."""
    g = small_graphs["uniform"]
    for name in ("SSSP", "BFS", "WP"):
        spec = {"SSSP": U.sssp(0), "BFS": U.bfs_depth(0),
                "WP": U.wp(0)}[name]
        want = engine.run_program(g, fusion.fuse(spec), engine="pull").value
        got = engine.run_direct(g, U.HANDWRITTEN[name](), engine="pull").value
        np.testing.assert_allclose(norm_inf(got), norm_inf(want), atol=1e-4)
    gu = undirected(g)
    want = engine.run_program(gu, fusion.fuse(U.cc()), engine="pull").value
    got = engine.run_direct(gu, U.HANDWRITTEN["CC"](), engine="pull").value
    np.testing.assert_allclose(norm_inf(got), norm_inf(want), atol=1e-4)


def test_pagerank_direct_kernels(small_graphs):
    """PageRank (Fig. 4b kernels): converges, sums to ~1 under the damping
    normalization for graphs where every vertex has out-degree ≥ 1."""
    import jax.numpy as jnp
    import numpy as np
    from repro.core.synthesis import pagerank_kernels
    from repro.graph.structure import from_edges, undirected
    base = small_graphs["rmat"]
    # guarantee out-degree ≥ 1 (Fig. 4b kernels don't redistribute
    # dangling mass, so isolated vertices legitimately leak rank); the
    # ring may duplicate R-MAT edges — undirected() dedupes (the dense
    # engine is an adjacency matrix: simple graphs only)
    src, dst, w, c = base.host_edges()
    ring = np.arange(base.n, dtype=np.int32)
    g = undirected(from_edges(base.n,
                              np.concatenate([src, ring]),
                              np.concatenate([dst, (ring + 1) % base.n])))
    dk = pagerank_kernels(g.n, tol=1e-7, max_iter=200)
    res = engine.run_direct(g, dk, engine="pull")
    pr = np.asarray(res.value)
    assert np.all(pr > 0)
    assert abs(pr.sum() - 1.0) < 0.05
    # dense engine agrees
    res2 = engine.run_direct(g, dk, engine="dense")
    np.testing.assert_allclose(pr, np.asarray(res2.value), atol=1e-4)


def test_push_models_on_nonidempotent(small_graphs):
    """NSP uses a sum (non-idempotent) secondary; push model must agree."""
    g = small_graphs["uniform"]
    spec = U.nsp(0)
    want = paths_semantics(spec, g, max_len=g.n)
    want = np.array([float(x) for x in want])
    for eng in ("pull", "push"):
        got = engine.run_program(g, fusion.fuse(spec), engine=eng).value
        np.testing.assert_allclose(norm_inf(got), norm_inf(want), atol=1e-4)


def test_reach_boolean_monoid_all_engines(small_graphs):
    """REACH exercises the ∨-monoid through every engine."""
    g = small_graphs["uniform"]
    spec, want = _oracle("REACH", g)
    prog = fusion.fuse(spec)
    for eng in ENGINES + ["adaptive"]:
        got = engine.run_program(g, prog, engine=eng).value
        np.testing.assert_allclose(norm_inf(got), norm_inf(want), atol=1e-6,
                                   err_msg=eng)


def test_adaptive_engine_matches_pull(small_graphs):
    """The Gemini-style direction-adaptive engine agrees with pull+ and
    actually uses both directions across the run."""
    from repro.core import iterate
    from repro.core.synthesis import synthesize_round
    g = small_graphs["rmat"]
    for name in ("SSSP", "WSP", "Trust", "RDS"):
        spec = U.ALL_SPECS[name]()
        prog = fusion.fuse(spec)
        a = engine.run_program(g, prog, engine="pull").value
        b = engine.run_program(g, prog, engine="adaptive").value
        np.testing.assert_allclose(norm_inf(a), norm_inf(b), atol=1e-4,
                                   err_msg=name)
    # direction switching is observable on a sparse-frontier problem
    round_ = fusion.fuse(U.sssp(0)).rounds[0][1]
    synth = synthesize_round(round_)
    comps = iterate.comp_runtimes(
        round_, {k: v for k, v in synth.items() if not isinstance(k, tuple)})
    res = iterate.iterate_adaptive(
        g, comps, [l.plan for l in round_.leaves], dense_threshold=0.5)
    assert 0 < res.pull_iters <= res.iterations
