"""Per-architecture smoke tests (reduced configs): one forward/train step on
CPU asserting output shapes + finite values, plus model-specific invariants
(flash≡naive attention, MLA cache equivalence, EGNN equivariance, MoE
routing mass, DLRM retrieval)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.configs as configs
from repro.data import graphs as dg
from repro.models import dlrm as dlrm_mod
from repro.models import gnn as gnn_mod
from repro.models import transformer as tf

KEY = jax.random.PRNGKey(0)
LM_ARCHS = ["llama3.2-3b", "qwen2-72b", "yi-9b", "deepseek-v3-671b",
            "llama4-maverick-400b-a17b"]


def _lm_batch(cfg, b=2, s=16):
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    return {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_train_step(arch):
    cfg = configs.get(arch).smoke()
    params = tf.init_params(cfg, KEY)
    batch = _lm_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: tf.loss_fn(cfg, p, batch)))(params)
    assert np.isfinite(float(loss))
    gn = jax.tree.reduce(lambda a, x: a + float(jnp.sum(x * x)), grads, 0.0)
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_smoke_serve(arch):
    cfg = configs.get(arch).smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    cache = tf.init_cache(cfg, 2, 32)
    logits, cache = jax.jit(
        lambda p, t, c: tf.prefill(cfg, p, t, c))(params, toks, cache)
    assert logits.shape == (2, cfg.vocab)
    lg, cache = jax.jit(
        lambda p, tk, pos, c: tf.decode_step(cfg, p, tk, pos, c))(
            params, toks[:, -1], jnp.int32(16), cache)
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(lg)))


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_lm_prefill_matches_forward(arch):
    """Serving path ≡ training forward at the last prompt position."""
    cfg = configs.get(arch).smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 16), 0, cfg.vocab)
    cache = tf.init_cache(cfg, 2, 32)
    lg, _ = tf.prefill(cfg, params, toks, cache)
    fw, _, _ = tf.forward(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(fw[:, -1]),
                               rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("arch", ["llama3.2-3b", "deepseek-v3-671b",
                                  "llama4-maverick-400b-a17b"])
def test_flash_attention_equals_naive(arch):
    """The custom-VJP chunked attention must equal naive attention in both
    the loss and the gradients."""
    cfg = configs.get(arch).smoke()
    ncfg = dataclasses.replace(cfg, attn_impl="naive")
    params = tf.init_params(cfg, KEY)
    batch = _lm_batch(cfg)
    l1, g1 = jax.jit(jax.value_and_grad(
        lambda p: tf.loss_fn(cfg, p, batch)))(params)
    l2, g2 = jax.jit(jax.value_and_grad(
        lambda p: tf.loss_fn(ncfg, p, batch)))(params)
    assert abs(float(l1 - l2)) < 1e-4
    md = jax.tree.reduce(
        max, jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), g1, g2))
    assert md < 5e-3


def test_decode_matches_forward_next_token():
    """Greedy decode after prefill ≡ forward over the extended sequence."""
    cfg = configs.get("llama3.2-3b").smoke()
    params = tf.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (2, 17), 0, cfg.vocab)
    cache = tf.init_cache(cfg, 2, 32)
    _, cache = tf.prefill(cfg, params, toks[:, :16], cache)
    lg, _ = tf.decode_step(cfg, params, toks[:, 16], jnp.int32(16), cache)
    fw, _, _ = tf.forward(cfg, params, toks)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(fw[:, -1]),
                               rtol=3e-2, atol=3e-2)


def test_moe_routing_mass_and_aux():
    from repro.models.layers import moe_ffn
    cfg = configs.get("deepseek-v3-671b").smoke()
    params = tf.init_params(cfg, KEY)
    moe_p = jax.tree.map(lambda a: a[1], params["layers"]["moe"])
    x = jax.random.normal(KEY, (2, 16, cfg.d_model))
    y, aux = moe_ffn(cfg, moe_p, x)
    assert y.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 0
    assert bool(jnp.all(jnp.isfinite(y)))


def test_scan_groups_periodic_for_llama4():
    cfg = configs.get("llama4-maverick-400b-a17b").full()
    plan = tf._scan_groups(cfg)
    assert plan[0] == "periodic"
    assert plan[1] == 4            # dense/MoE × local/global 4-cycle


def test_scan_groups_runs_for_deepseek():
    cfg = configs.get("deepseek-v3-671b").full()
    plan = tf._scan_groups(cfg)
    assert plan[0] == "runs"
    assert len(plan[1]) == 2       # 3-dense prefix + 58-MoE body


# --- GNN smokes --------------------------------------------------------------

def test_gat_smoke():
    cfg = configs.get("gat-cora").smoke()
    b = dg.cora_batch(n=64, e=256, d_feat=cfg.d_in)
    p = gnn_mod.gat_init(cfg, KEY)
    out = gnn_mod.gat_forward(cfg, p, b["x"], b["src"], b["dst"], 64)
    assert out.shape == (64, cfg.n_classes)
    loss = jax.jit(lambda p, b: gnn_mod.gat_loss(cfg, p, b))(p, b)
    assert np.isfinite(float(loss))


def test_egnn_smoke_and_equivariance():
    cfg = configs.get("egnn").smoke()
    b = dg.egnn_batch(n_graphs=4, n_atoms=10)
    p = gnn_mod.egnn_init(cfg, KEY)
    n = b["feats"].shape[0]
    out, x1 = gnn_mod.egnn_forward(cfg, p, b["feats"], b["coords"],
                                   b["src"], b["dst"], n)
    assert out.shape == (n, cfg.d_out)
    th = 0.7
    R = jnp.asarray(np.array([[np.cos(th), -np.sin(th), 0],
                              [np.sin(th), np.cos(th), 0],
                              [0, 0, 1]], np.float32))
    out2, x2 = gnn_mod.egnn_forward(cfg, p, b["feats"], b["coords"] @ R.T,
                                    b["src"], b["dst"], n)
    np.testing.assert_allclose(out, out2, atol=1e-4)          # invariant
    np.testing.assert_allclose(x1 @ R.T, x2, atol=1e-4)       # equivariant


def test_mgn_smoke():
    cfg = configs.get("meshgraphnet").smoke()
    b = dg.mesh_batch(rows=6, cols=6, d_node_in=cfg.d_node_in,
                      d_edge_in=cfg.d_edge_in, d_out=cfg.d_out)
    p = gnn_mod.mgn_init(cfg, KEY)
    loss = jax.jit(lambda p, b: gnn_mod.mgn_loss(cfg, p, b))(p, b)
    assert np.isfinite(float(loss))


def test_dimenet_smoke():
    cfg = configs.get("dimenet").smoke()
    b = dg.molecule_batch(n_graphs=4, n_atoms=8, n_species=cfg.n_species)
    b.pop("n_graphs")
    p = gnn_mod.dimenet_init(cfg, KEY)
    loss = jax.jit(lambda p, b: gnn_mod.dimenet_loss(cfg, p, b))(p, b)
    assert np.isfinite(float(loss))


def test_dimenet_rotation_invariance():
    """DimeNet consumes distances/angles only — energy is rotation
    invariant."""
    cfg = configs.get("dimenet").smoke()
    b = dg.molecule_batch(n_graphs=2, n_atoms=8, n_species=cfg.n_species)
    p = gnn_mod.dimenet_init(cfg, KEY)
    n = b["species"].shape[0]
    out1 = gnn_mod.dimenet_forward(cfg, p, b["species"], b["coords"],
                                   b["src"], b["dst"], b["t_kj"],
                                   b["t_ji"], n)
    th = 1.1
    R = jnp.asarray(np.array([[np.cos(th), -np.sin(th), 0],
                              [np.sin(th), np.cos(th), 0],
                              [0, 0, 1]], np.float32))
    out2 = gnn_mod.dimenet_forward(cfg, p, b["species"], b["coords"] @ R.T,
                                   b["src"], b["dst"], b["t_kj"],
                                   b["t_ji"], n)
    np.testing.assert_allclose(out1, out2, atol=1e-3)


def test_triplets_are_wedges():
    b = dg.molecule_batch(n_graphs=2, n_atoms=6)
    src, dst = np.asarray(b["src"]), np.asarray(b["dst"])
    t_kj, t_ji = np.asarray(b["t_kj"]), np.asarray(b["t_ji"])
    # dst of edge (k→j) must equal src of edge (j→i)
    ok = dst[t_kj] == src[t_ji]
    assert ok.mean() > 0.95        # (degenerate pad triplet allowed)


# --- DLRM --------------------------------------------------------------------

def test_dlrm_smoke_and_shapes():
    cfg = configs.get("dlrm-rm2").smoke()
    b = dg.dlrm_batch(cfg, 64)
    p = dlrm_mod.dlrm_init(cfg, KEY)
    logits = dlrm_mod.dlrm_forward(cfg, p, b["dense"], b["sparse"])
    assert logits.shape == (64,)
    loss, grads = jax.jit(jax.value_and_grad(
        lambda p: dlrm_mod.dlrm_loss(cfg, p, b)))(p)
    assert np.isfinite(float(loss))


def test_dlrm_interaction_feature_count():
    cfg = configs.get("dlrm-rm2").full()
    f = cfg.n_sparse + 1
    assert cfg.d_interact == f * (f - 1) // 2 + cfg.embed_dim == 415


def test_dlrm_retrieval_is_batched_dot():
    cfg = configs.get("dlrm-rm2").smoke()
    b = dg.dlrm_batch(cfg, 4)
    p = dlrm_mod.dlrm_init(cfg, KEY)
    cand = jax.random.normal(KEY, (1000, cfg.embed_dim))
    sc = dlrm_mod.dlrm_retrieval_scores(cfg, p, b["dense"], b["sparse"], cand)
    assert sc.shape == (4, 1000)
    u = dlrm_mod.dlrm_user_vector(cfg, p, b["dense"], b["sparse"])
    np.testing.assert_allclose(sc, u @ cand.T, atol=1e-5)


def test_embedding_bag_kernel_matches_dlrm_lookup():
    """The Pallas embedding-bag kernel computes the same bags as the model's
    gather path (single table, multi-hot)."""
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    table = jnp.asarray(rng.normal(size=(64, 128)).astype(np.float32))
    idx = jnp.asarray(rng.integers(0, 64, size=(128, 4)).astype(np.int32))
    got = ops.embedding_bag(table, idx, mode="sum")
    want = table[idx].sum(axis=1)
    np.testing.assert_allclose(got, want, atol=1e-5)
