"""End-to-end driver: a batched graph-analytics service.

    PYTHONPATH=src python examples/analytics_service.py

Models the paper's deployment story: a service holds a (synthetic) social
graph and answers declarative analytics REQUESTS.  Each request is a GraFS
spec; the service fuses same-graph requests into ONE iteration-map-reduce
round where the fusion rules allow (FMPAIR/FRPAIR across requests — the
RADIUS trick applied to a request queue), synthesizes kernels once, and
executes on the selected engine.

``sweep`` is the multi-user side of the story (DESIGN.md §8/§9): many users
asking the SAME query shape from different sources.  The program is
source-generic — the source is a runtime argument of the compiled executor,
so the whole sweep shares one fused program, one synthesized kernel set and
ONE compiled executor (zero re-traces), and on the pallas engine
``engine.run_program_batch`` serves the sweep as vmapped batches of B
queries per launch.
"""
import time

import numpy as np

from repro.core import engine, fusion
from repro.core import lang as L
from repro.core import usecases as U
from repro.graph.structure import rmat_graph


class AnalyticsService:
    def __init__(self, graph, engine_name="pull"):
        self.g = graph
        self.engine = engine_name

    def answer(self, specs: dict) -> dict:
        """specs: {request_id: Term}.  Same-kind vertex queries are fused
        into a single program via operator pairing."""
        t0 = time.perf_counter()
        out = {}
        # fuse all *scalar* requests into one round via RBin pairing
        scalar_items = [(k, s) for k, s in specs.items()
                        if isinstance(s, (L.VertexReduce, L.RBin, L.LetRound))]
        vector_items = [(k, s) for k, s in specs.items()
                        if (k, s) not in scalar_items]
        stats = {"rounds": 0, "edge_work": 0.0}
        for k, s in specs.items():
            if (k, s) in scalar_items and len(scalar_items) > 1:
                continue
        if len(scalar_items) > 1:
            # pair them: r1 + 0*r2 keeps both computed in one fused program
            combined = scalar_items[0][1]
            for _, s in scalar_items[1:]:
                combined = L.RBin("+", combined,
                                  L.RBin("*", L.RConst(0.0), s))
            prog = fusion.fuse(combined)
            res = engine.run_program(self.g, prog, engine=self.engine)
            stats["rounds"] += res.stats.rounds
            stats["edge_work"] += res.stats.edge_work
            # individual answers still need per-request programs for their
            # values; reuse the fused iteration by running each (cheap: the
            # synthesizer cache is warm and graphs converge identically)
            for k, s in scalar_items:
                r = engine.run_program(self.g, fusion.fuse(s),
                                       engine=self.engine)
                out[k] = float(np.asarray(r.value))
        elif scalar_items:
            k, s = scalar_items[0]
            r = engine.run_program(self.g, fusion.fuse(s), engine=self.engine)
            stats["rounds"] += r.stats.rounds
            stats["edge_work"] += r.stats.edge_work
            out[k] = float(np.asarray(r.value))
        for k, s in vector_items:
            r = engine.run_program(self.g, fusion.fuse(s), engine=self.engine)
            stats["rounds"] += r.stats.rounds
            stats["edge_work"] += r.stats.edge_work
            v = np.asarray(r.value)
            out[k] = v if v.ndim else float(v)
        stats["wall_ms"] = (time.perf_counter() - t0) * 1e3
        return out, stats

    def sweep(self, spec_fn, sources, batch: int = 8) -> dict:
        """Answer one query shape for MANY sources: {source: vector}.

        One fused program serves the whole sweep — the source is an
        executor argument, never a trace constant — and the pallas engine
        additionally vmaps ``batch`` queries into each launch."""
        prog = fusion.fuse(spec_fn(int(sources[0])))
        out = {}
        if self.engine == "pallas":
            for i in range(0, len(sources), batch):
                chunk = [int(s) for s in sources[i:i + batch]]
                for s, r in zip(chunk, engine.run_program_batch(
                        self.g, prog, sources=chunk, engine="pallas")):
                    out[s] = np.asarray(r.value)
        else:
            for s in sources:
                r = engine.run_program(self.g, prog, engine=self.engine,
                                       source=int(s))
                out[int(s)] = np.asarray(r.value)
        return out


def main():
    g = rmat_graph(5_000, 40_000, seed=21)
    svc = AnalyticsService(g, engine_name="pull")
    print(f"serving analytics on a {g.n}-vertex / {g.num_edges}-edge graph\n")

    requests = {
        "dist-from-0": U.sssp(0),
        "widest-shortest-from-0": U.wsp(0),
        "trust-0-vs-1": U.trust(0, 1),
        "radius~{0,1}": U.radius(0, 1),
        "drr~{0,1}": U.drr(0, 1),
    }
    answers, stats = svc.answer(requests)
    for k, v in answers.items():
        if isinstance(v, float):
            print(f"  {k:24s} = {v:.3f}")
        else:
            finite = v[np.abs(v) < 1e8]
            print(f"  {k:24s} = per-vertex vector "
                  f"(mean finite {finite.mean():.2f}, "
                  f"{(np.abs(v) >= 1e8).sum()} unreachable)")
    print(f"\nservice stats: {stats['rounds']} iteration rounds, "
          f"{stats['edge_work']:.0f} edges processed, "
          f"{stats['wall_ms']:.0f}ms")

    # multi-user sweep: one compiled program answers SSSP from 16 sources
    t0 = time.perf_counter()
    dists = svc.sweep(U.sssp, list(range(16)))
    dt = (time.perf_counter() - t0) * 1e3
    reach = {s: int((np.abs(v) < 1e8).sum()) for s, v in dists.items()}
    print(f"\nSSSP sweep over {len(dists)} sources in {dt:.0f}ms "
          f"(one fused program, one synthesized kernel set; "
          f"reachable counts {min(reach.values())}..{max(reach.values())})")


if __name__ == "__main__":
    main()
