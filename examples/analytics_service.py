"""End-to-end driver: a batched graph-analytics service.

    PYTHONPATH=src python examples/analytics_service.py

Models the paper's deployment story: a service holds a (synthetic) social
graph and answers declarative analytics REQUESTS.  Each request is a GraFS
spec; the service fuses same-graph requests into ONE iteration-map-reduce
round where the fusion rules allow (FMPAIR/FRPAIR across requests — the
RADIUS trick applied to a request queue), synthesizes kernels once, and
executes on the selected engine.

``sweep`` is the multi-user side of the story (DESIGN.md §8/§9): many users
asking the SAME query shape from different sources.  The program is
source-generic — the source is a runtime argument of the compiled executor,
so the whole sweep shares one fused program, one synthesized kernel set and
ONE compiled executor (zero re-traces), and on the pallas engine
``engine.run_program_batch`` serves the sweep as vmapped batches of B
queries per launch.
"""
import time

import numpy as np

from repro.core import engine, fusion
from repro.core import lang as L
from repro.core import usecases as U
from repro.graph.structure import rmat_graph


class AnalyticsService:
    def __init__(self, graph, engine_name="pull"):
        self.g = graph
        self.engine = engine_name

    def answer(self, specs: dict) -> dict:
        """specs: {request_id: Term}.  Scalar requests are paired into ONE
        fused round via ``fusion.fuse_many`` (FMPAIR/FRPAIR across the
        request queue) and every request reads its own answer from that
        single execution — no per-request re-runs."""
        t0 = time.perf_counter()
        out = {}
        scalar_items = [(k, s) for k, s in specs.items()
                        if fusion._is_r_term(s)
                        and not isinstance(s, L.LetRound)]
        vector_items = [(k, s) for k, s in specs.items()
                        if (k, s) not in scalar_items]
        stats = {"rounds": 0, "edge_work": 0.0}
        if scalar_items:
            prog = fusion.fuse_many(scalar_items)
            res = engine.run_program(self.g, prog, engine=self.engine)
            stats["rounds"] += res.stats.rounds
            stats["edge_work"] += res.stats.edge_work
            for k, _ in scalar_items:
                out[k] = float(np.asarray(res.value[k]))
        for k, s in vector_items:
            r = engine.run_program(self.g, fusion.fuse(s), engine=self.engine)
            stats["rounds"] += r.stats.rounds
            stats["edge_work"] += r.stats.edge_work
            v = np.asarray(r.value)
            out[k] = v if v.ndim else float(v)
        stats["wall_ms"] = (time.perf_counter() - t0) * 1e3
        return out, stats

    def sweep(self, spec_fn, sources, batch: int = 8) -> dict:
        """Answer one query shape for MANY sources: {source: vector}.

        One fused program serves the whole sweep — the source is an
        executor argument, never a trace constant — and the pallas engine
        additionally vmaps ``batch`` queries into each launch."""
        prog = fusion.fuse(spec_fn(int(sources[0])))
        out = {}
        if self.engine == "pallas":
            for i in range(0, len(sources), batch):
                chunk = [int(s) for s in sources[i:i + batch]]
                for s, r in zip(chunk, engine.run_program_batch(
                        self.g, prog, sources=chunk, engine="pallas")):
                    out[s] = np.asarray(r.value)
        else:
            for s in sources:
                r = engine.run_program(self.g, prog, engine=self.engine,
                                       source=int(s))
                out[int(s)] = np.asarray(r.value)
        return out


def main():
    g = rmat_graph(5_000, 40_000, seed=21)
    svc = AnalyticsService(g, engine_name="pull")
    print(f"serving analytics on a {g.n}-vertex / {g.num_edges}-edge graph\n")

    requests = {
        "dist-from-0": U.sssp(0),
        "widest-shortest-from-0": U.wsp(0),
        "trust-0-vs-1": U.trust(0, 1),
        "radius~{0,1}": U.radius(0, 1),
        "drr~{0,1}": U.drr(0, 1),
    }
    answers, stats = svc.answer(requests)
    for k, v in answers.items():
        if isinstance(v, float):
            print(f"  {k:24s} = {v:.3f}")
        else:
            finite = v[np.abs(v) < 1e8]
            print(f"  {k:24s} = per-vertex vector "
                  f"(mean finite {finite.mean():.2f}, "
                  f"{(np.abs(v) >= 1e8).sum()} unreachable)")
    print(f"\nservice stats: {stats['rounds']} iteration rounds, "
          f"{stats['edge_work']:.0f} edges processed, "
          f"{stats['wall_ms']:.0f}ms")

    # cross-request fusion must WIN: pairing the scalar requests into one
    # round (shared eccentricity sweeps dedup via CSE) does strictly less
    # edge work than answering each scalar request on its own
    scalar = {k: s for k, s in requests.items()
              if fusion._is_r_term(s) and not isinstance(s, L.LetRound)}
    fused_res = engine.run_program(g, fusion.fuse_many(scalar),
                                   engine=svc.engine)
    solo_work = 0.0
    for k, s in scalar.items():
        r = engine.run_program(g, fusion.fuse(s), engine=svc.engine)
        solo_work += r.stats.edge_work
        assert float(np.asarray(fused_res.value[k])) == \
            float(np.asarray(r.value)), f"fused answer for {k} diverged"
    assert fused_res.stats.edge_work < solo_work, (
        f"fusion did not reduce edge work: fused "
        f"{fused_res.stats.edge_work:.0f} vs solo {solo_work:.0f}")
    print(f"cross-request fusion: {len(scalar)} scalar requests in one "
          f"round, edge work {fused_res.stats.edge_work:.0f} vs "
          f"{solo_work:.0f} solo ({solo_work / fused_res.stats.edge_work:.1f}x)")

    # multi-user sweep: one compiled program answers SSSP from 16 sources
    t0 = time.perf_counter()
    dists = svc.sweep(U.sssp, list(range(16)))
    dt = (time.perf_counter() - t0) * 1e3
    reach = {s: int((np.abs(v) < 1e8).sum()) for s, v in dists.items()}
    print(f"\nSSSP sweep over {len(dists)} sources in {dt:.0f}ms "
          f"(one fused program, one synthesized kernel set; "
          f"reachable counts {min(reach.values())}..{max(reach.values())})")


if __name__ == "__main__":
    main()
